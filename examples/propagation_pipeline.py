"""k-core propagation sweep (paper Fig. 2): F1 and time vs initial core k0.

    PYTHONPATH=src python examples/propagation_pipeline.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import kcore
from repro.core.pipeline import EmbedConfig, embed_graph
from repro.eval.linkpred import evaluate_link_prediction
from repro.graph import datasets, splits
from repro.skipgram.trainer import SGNSConfig


def main():
    g = datasets.load("facebook-like")
    sp = splits.make_link_split(g, 0.1, seed=0)
    pairs, labels = sp.eval_arrays()
    core = kcore.core_numbers_host(sp.train_graph)
    kdeg = kcore.degeneracy(core)
    print(f"facebook-like: {g.n_nodes} nodes {g.n_edges} edges degeneracy {kdeg}")

    sgns = SGNSConfig(dim=128, batch=8192, epochs=0.5, impl="ref", seed=0)
    base = embed_graph(sp.train_graph, EmbedConfig(method="deepwalk", sgns=sgns))
    f1_base = evaluate_link_prediction(base.embeddings, pairs, labels).f1 * 100
    print(f"{'model':>14s} {'F1':>7s} {'drop':>6s} {'time':>8s} {'speedup':>8s}")
    print(f"{'DeepWalk':>14s} {f1_base:7.2f} {'':>6s} {base.times['total']:7.1f}s")

    for frac in (0.2, 0.4, 0.6, 0.8, 0.95):
        k0 = max(2, int(kdeg * frac))
        res = embed_graph(
            sp.train_graph,
            EmbedConfig(method="deepwalk", k0=k0, sgns=sgns),
        )
        f1 = evaluate_link_prediction(res.embeddings, pairs, labels).f1 * 100
        print(f"{f'{k0}-core (Dw)':>14s} {f1:7.2f} {f1 - f1_base:+6.1f} "
              f"{res.times['total']:7.1f}s x{base.times['total']/res.times['total']:6.1f}")


if __name__ == "__main__":
    main()

"""Batched LM serving demo on any assigned architecture (reduced config).

    PYTHONPATH=src python examples/serve_lm.py --arch gemma2-2b

Runs the continuous-batching loop from repro.launch.serve: one prefill and
one decode lowering, finished slots swapped for queued requests in place.
"""
import sys

sys.path.insert(0, "src")

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    argv = sys.argv[1:] or ["--arch", "gemma2-2b"]
    serve_main(argv + ["--preset", "reduced"])

"""Quickstart: the paper's two techniques on a small graph, in ~a minute.

    PYTHONPATH=src python examples/quickstart.py

Builds a synthetic social-style graph, decomposes it into k-cores, and
compares DeepWalk vs CoreWalk (§2.1) vs k-core mean-propagation (§2.2) on
link prediction — the paper's Table-3 protocol in miniature.
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import kcore
from repro.core.pipeline import EmbedConfig, embed_graph
from repro.eval.linkpred import evaluate_link_prediction
from repro.graph import generators, splits
from repro.skipgram.trainer import SGNSConfig


def main():
    g = generators.barabasi_albert_varying(600, 10.0, seed=0)
    print(f"graph: {g.n_nodes} nodes, {g.n_edges} edges")

    core = kcore.core_numbers_host(g)
    kdeg = kcore.degeneracy(core)
    ks, cnt = np.unique(core, return_counts=True)
    print(f"degeneracy: {kdeg}; nodes per core index (first 8): "
          + ", ".join(f"{k}:{c}" for k, c in zip(ks[:8], cnt[:8])))

    sp = splits.make_link_split(g, 0.1, seed=0)
    pairs, labels = sp.eval_arrays()
    sgns = SGNSConfig(dim=64, batch=2048, epochs=1.0, impl="ref", seed=0)

    rows = []
    for label, method, k0 in [
        ("DeepWalk (baseline)", "deepwalk", None),
        ("CoreWalk  (§2.1)", "corewalk", None),
        (f"{max(2, kdeg // 2)}-core+prop (§2.2)", "deepwalk", max(2, kdeg // 2)),
    ]:
        cfg = EmbedConfig(method=method, k0=k0, n_walks=10, walk_length=20,
                          sgns=sgns)
        res = embed_graph(sp.train_graph, cfg)
        lp = evaluate_link_prediction(res.embeddings, pairs, labels, seed=0)
        rows.append((label, lp.f1 * 100, res.times["total"], res.n_walks_run))

    base_t = rows[0][2]
    print(f"\n{'model':24s} {'F1':>6s} {'time':>8s} {'speedup':>8s} {'walks':>7s}")
    for label, f1, t, walks in rows:
        print(f"{label:24s} {f1:6.2f} {t:7.2f}s x{base_t / t:6.1f} {walks:7d}")


if __name__ == "__main__":
    main()

"""End-to-end driver: train a ~100M-parameter graph embedding for a few
hundred steps (the paper's workload at the assignment's end-to-end scale).

    PYTHONPATH=src python examples/train_sgns_100m.py [--nodes 400000]

400k nodes x dim 128 x two tables = 102.4M parameters. The full production
pipeline runs: k-core decomposition -> CoreWalk budget plan -> walk corpus ->
SGNS training with the fused-kernel loss path -> checkpoint -> restore ->
resume, reporting corpus reduction and throughput.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.core import corewalk, kcore
from repro.distributed.checkpoint import CheckpointManager
from repro.graph import generators
from repro.skipgram.corpus import build_corpus
from repro.skipgram.model import init_params
from repro.skipgram.trainer import SGNSConfig, train_sgns


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=400_000)
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8192)
    ap.add_argument("--ckpt", default="/tmp/sgns100m_ckpt")
    args = ap.parse_args()

    t0 = time.time()
    print(f"[1/5] generating graph ({args.nodes} nodes)...")
    g = generators.barabasi_albert_varying(args.nodes, 6.0, m_max=40, seed=0)
    print(f"      {g.n_nodes} nodes, {g.n_edges} edges ({time.time()-t0:.0f}s)")

    t0 = time.time()
    print("[2/5] k-core decomposition + CoreWalk plan...")
    core = kcore.core_numbers_host(g)
    plan_dw = corewalk.deepwalk_plan(g.n_nodes, 4)
    plan_cw = corewalk.corewalk_plan(core, 4)
    print(f"      degeneracy {kcore.degeneracy(core)}; corpus reduction "
          f"x{plan_cw.reduction_vs(plan_dw):.2f} "
          f"({plan_cw.n_real} vs {plan_dw.n_real} walks) ({time.time()-t0:.0f}s)")

    t0 = time.time()
    print("[3/5] walk corpus (ELL width-capped at 64 for hub-heavy graphs)...")
    ell = g.to_ell(max_width=64)
    corpus = build_corpus(ell, plan_cw, 20, jax.random.PRNGKey(0))
    corpus.walks.block_until_ready()
    print(f"      {corpus.n_real} walks x {corpus.length} "
          f"= {corpus.n_tokens/1e6:.1f}M tokens ({time.time()-t0:.0f}s)")

    n_params = 2 * g.n_nodes * args.dim
    print(f"[4/5] SGNS training: {n_params/1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch}")
    cfg = SGNSConfig(dim=args.dim, batch=args.batch, seed=0, impl="ref")
    params = init_params(corpus.n_nodes, args.dim, jax.random.PRNGKey(1))
    half = args.steps // 2
    t0 = time.time()
    res1 = train_sgns(corpus, cfg, params=params, steps=half)
    dt = time.time() - t0
    print(f"      first {half} steps: loss {res1.final_loss:.4f}, "
          f"{half * args.batch / dt / 1e3:.0f}k pairs/s")

    mgr = CheckpointManager(args.ckpt, keep=2)
    mgr.save(half, {"emb": res1.embeddings})
    print(f"[5/5] checkpointed at step {half}; restoring + resuming...")
    restored = mgr.restore(half, {"emb": res1.embeddings})
    assert np.allclose(restored["emb"], res1.embeddings)
    params2 = {
        "emb_in": jax.numpy.asarray(restored["emb"]),
        "emb_out": init_params(corpus.n_nodes, args.dim, jax.random.PRNGKey(1))["emb_out"],
    }
    res2 = train_sgns(corpus, cfg, params=params2, steps=args.steps - half)
    print(f"      resumed {args.steps - half} steps: loss {res2.final_loss:.4f}")
    print(f"done: {n_params/1e6:.1f}M-param embedding trained, "
          f"corpus was x{plan_cw.reduction_vs(plan_dw):.2f} smaller via CoreWalk")


if __name__ == "__main__":
    main()

"""Render EXPERIMENTS.md §Dry-run table from results/dryrun.json."""
import json, sys

with open("results/dryrun.json") as f:
    recs = json.load(f)

GiB = 2**30
print("| arch | shape | mesh | status | args GiB | temp GiB | flops/dev | bytes/dev | AG MiB | AR MiB | A2A MiB | CP MiB |")
print("|---|---|---|---|---|---|---|---|---|---|---|---|")
for r in recs:
    if r["status"] == "ok":
        m, c = r["memory"], r["collective_bytes"]
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
              f"{m['argument_bytes']/GiB:.2f} | {m['temp_bytes']/GiB:.2f} | "
              f"{r['flops']:.2e} | {r['bytes_accessed']:.2e} | "
              f"{c['all-gather']/2**20:.0f} | {c['all-reduce']/2**20:.0f} | "
              f"{c['all-to-all']/2**20:.0f} | {c['collective-permute']/2**20:.0f} |")
    elif r["status"] == "skip":
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP | — | — | — | — | — | — | — | — |")
    else:
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | **FAIL** | | | | | | | | |")
n_ok = sum(r["status"]=="ok" for r in recs)
n_skip = sum(r["status"]=="skip" for r in recs)
n_fail = sum(r["status"]=="fail" for r in recs)
print(f"\nTotals: {n_ok} ok / {n_skip} skip / {n_fail} fail", file=sys.stderr)

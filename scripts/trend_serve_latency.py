"""Diff two ``results/serve_latency.json`` artifacts (trend first step).

CI uploads the serving benchmark's JSON per PR; this prints a compact
old -> new comparison of every numeric metric (recursively flattened with
dotted keys), flagging regressions so a human can eyeball the trajectory
before a real dashboard exists.

Both artifacts are validated against the checked-in schema
(``results/serve_latency.schema.json``) before diffing: a renamed or
mistyped section would otherwise silently flatten to *nothing* and the
trend would look flat. ``--no-validate`` skips the check (e.g. to diff an
artifact written before the schema existed).

With ``--gate-pct`` the diff also becomes a CI gate: per-phase repair
seconds (region / candidates / descend / fallback) are aggregated across
the ingest sweep and the churn run by phase name, query latencies ride
along, and the script exits 2 if any aggregate grew more than the given
percentage *and* more than ``--gate-min-ms`` absolute (the noise floor —
shared runners jitter small phases by far more than 25%). A phase that
appears only in the new artifact is not a regression: the adaptive repair
policy legitimately shifts seconds between paths (that shift is the
point), and the gate compares like with like.

Usage::

    python scripts/trend_serve_latency.py old.json new.json
    python scripts/trend_serve_latency.py old.json new.json --min-delta 5
    python scripts/trend_serve_latency.py prev.json new.json \
        --gate-pct 25 --gate-min-ms 3
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
)

from repro.obs import load_schema, validate_or_raise  # noqa: E402

SCHEMA_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "results", "serve_latency.schema.json",
)


def flatten(obj, prefix=""):
    """dict/list tree -> {dotted.key: leaf} (numbers and bools only)."""
    out = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(flatten(v, f"{prefix}{k}."))
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            out.update(flatten(v, f"{prefix}{i}."))
    elif isinstance(obj, bool):
        out[prefix[:-1]] = int(obj)
    elif isinstance(obj, (int, float)):
        out[prefix[:-1]] = float(obj)
    return out


# metrics where an increase is an improvement; everything else (latencies,
# mismatches, staleness) improves downward. Substring match on the key.
HIGHER_IS_BETTER = (
    "edges_per_s", "qps", "speedup", "auc", "queries", "retrains",
)


def direction(key: str) -> int:
    return 1 if any(tok in key for tok in HIGHER_IS_BETTER) else -1


def phase_aggregates(raw: dict) -> dict:
    """Artifact -> {name: seconds} totals the gate compares.

    Repair phase seconds are summed across every ingest-sweep row plus the
    churn run, keyed by phase name (region / candidates / descend /
    fallback), so the gate tracks where repair time goes overall rather
    than per block size — a single noisy row can't trip it, a systematic
    slowdown in one phase can. Query p50/p99 (the flush-visible latencies)
    ride along as their own rows.
    """
    agg: dict = {}
    sections = list(raw.get("ingest_sweep") or [])
    if raw.get("churn"):
        sections.append(raw["churn"])
    for sec in sections:
        for phase, info in (sec.get("phases") or {}).items():
            agg[phase] = agg.get(phase, 0.0) + float(info.get("seconds", 0))
    for key in ("query_p50_s", "query_p99_s"):
        if key in raw:
            agg[key] = float(raw[key])
    # retrieval latencies (the --topk leg) ride along under their own keys,
    # on both the single-device payload and the sharded section
    for prefix, sec in (("topk", raw.get("topk")),
                        ("sharding.topk", (raw.get("sharding") or {}).get(
                            "topk"))):
        for key in ("query_p50_s", "query_p99_s"):
            if sec and key in sec:
                agg[f"{prefix}.{key}"] = float(sec[key])
    return agg


def gate_failures(old_raw: dict, new_raw: dict, pct: float,
                  min_ms: float) -> list:
    """(name, old_s, new_s, rel_pct) rows exceeding both thresholds."""
    old_a, new_a = phase_aggregates(old_raw), phase_aggregates(new_raw)
    bad = []
    for key in sorted(set(old_a) | set(new_a)):
        a, b = old_a.get(key, 0.0), new_a.get(key, 0.0)
        if a <= 0:  # phase newly appearing (policy shifted paths) — not a
            continue  # regression; next run's artifact becomes its baseline
        if (b - a) * 1e3 <= min_ms:
            continue
        rel = (b - a) / a * 100
        if rel > pct:
            bad.append((key, a, b, rel))
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("old", help="previous serve_latency.json")
    ap.add_argument("new", help="current serve_latency.json")
    ap.add_argument("--min-delta", type=float, default=1.0,
                    help="hide rows whose relative change is below this %%")
    ap.add_argument("--no-validate", action="store_true",
                    help="skip schema validation of the two artifacts")
    ap.add_argument("--gate-pct", type=float, default=None,
                    help="fail (exit 2) if any per-phase seconds aggregate "
                         "grew more than this %% vs the old artifact")
    ap.add_argument("--gate-min-ms", type=float, default=3.0,
                    help="absolute growth a gated aggregate must exceed "
                         "before the %% threshold applies (noise floor)")
    args = ap.parse_args(argv)

    with open(args.old) as f:
        old_raw = json.load(f)
    with open(args.new) as f:
        new_raw = json.load(f)
    if not args.no_validate:
        schema = load_schema(SCHEMA_PATH)
        validate_or_raise(old_raw, schema, args.old)
        validate_or_raise(new_raw, schema, args.new)
    old = flatten(old_raw)
    new = flatten(new_raw)

    keys = sorted(set(old) | set(new))
    width = max((len(k) for k in keys), default=0)
    regressions = 0
    for k in keys:
        a, b = old.get(k), new.get(k)
        if a is None or b is None:
            tag = "added" if a is None else "removed"
            print(f"  {k:<{width}}  [{tag}] {a if b is None else b:g}")
            continue
        if a == b:
            continue
        rel = (b - a) / abs(a) * 100 if a else float("inf")
        if abs(rel) < args.min_delta:
            continue
        better = (b - a) * direction(k) > 0
        mark = "+" if better else "!"
        if not better:
            regressions += 1
        print(f"{mark} {k:<{width}}  {a:g} -> {b:g}  ({rel:+.1f}%)")
    print(f"\n{regressions} metric(s) moved the wrong way "
          f"(threshold {args.min_delta}%).")

    if args.gate_pct is not None:
        bad = gate_failures(old_raw, new_raw, args.gate_pct, args.gate_min_ms)
        for key, a, b, rel in bad:
            print(f"GATE {key}: {a * 1e3:.2f}ms -> {b * 1e3:.2f}ms "
                  f"({rel:+.0f}% > {args.gate_pct:g}%)")
        if bad:
            print(f"trend gate FAILED: {len(bad)} phase aggregate(s) "
                  f"regressed beyond {args.gate_pct:g}% "
                  f"(+{args.gate_min_ms:g}ms floor).")
            return 2
        print(f"trend gate passed ({args.gate_pct:g}% / "
              f"{args.gate_min_ms:g}ms floor).")
    return 0


if __name__ == "__main__":
    sys.exit(main())

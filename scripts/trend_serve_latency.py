"""Diff/trend-gate ``results/serve_latency.json`` artifacts.

Two modes, one script:

**Pairwise** (two positional artifacts): prints a compact old -> new
comparison of every numeric metric (recursively flattened with dotted
keys), flagging regressions; with ``--gate-pct`` it becomes a CI gate —
per-phase repair seconds are aggregated across the ingest sweep and the
churn run, query/topk latencies ride along, and the script exits 2 if any
aggregate grew more than the given percentage *and* more than
``--gate-min-ms`` absolute (the noise floor).

**Slope** (``--gate-slope N``): reads the benchmark history series
(``results/history/serve_latency.jsonl``, appended by every
``benchmarks/serve_latency.py`` run), fits a robust Theil–Sen trend over
the last N records per series, and exits 2 when the projected drift across
the window exceeds both the ``--gate-pct`` relative threshold and the
noise floor — catching sustained creep split into many small steps that
each pass the pairwise gate.

Both pairwise artifacts are validated against the checked-in schema
(``results/serve_latency.schema.json``) before diffing, and their
``schema_version`` fields must match: diffing across an artifact-layout
version silently flattens to a near-empty diff that reads as "all flat",
so the differ refuses loudly instead. By default the refusal exits 0 (so
the first CI run after a schema bump, diffing a cached old-version
baseline, resets the baseline rather than failing); ``--strict-version``
turns it into exit 4. ``--no-validate`` skips schema validation only.

Usage::

    python scripts/trend_serve_latency.py old.json new.json
    python scripts/trend_serve_latency.py prev.json new.json \
        --gate-pct 25 --gate-min-ms 3
    python scripts/trend_serve_latency.py --gate-slope 20 --gate-pct 25 \
        --history results/history/serve_latency.jsonl
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
)

from repro.obs import load_schema, validate_or_raise  # noqa: E402
from repro.obs.history import (  # noqa: E402,F401  (re-exported: one
    HIGHER_IS_BETTER,  # definition of the trend series, used by tests and
    SCHEMA_VERSION,  # any older callers that imported from this script)
    direction,
    flatten,
    load_history,
    phase_aggregates,
    slope_failures,
)

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCHEMA_PATH = os.path.join(_ROOT, "results", "serve_latency.schema.json")
HISTORY_PATH = os.path.join(_ROOT, "results", "history",
                            "serve_latency.jsonl")


def gate_failures(old_raw: dict, new_raw: dict, pct: float,
                  min_ms: float) -> list:
    """(name, old_s, new_s, rel_pct) rows exceeding both thresholds."""
    old_a, new_a = phase_aggregates(old_raw), phase_aggregates(new_raw)
    bad = []
    for key in sorted(set(old_a) | set(new_a)):
        a, b = old_a.get(key, 0.0), new_a.get(key, 0.0)
        if a <= 0:  # phase newly appearing (policy shifted paths) — not a
            continue  # regression; next run's artifact becomes its baseline
        if (b - a) * 1e3 <= min_ms:
            continue
        rel = (b - a) / a * 100
        if rel > pct:
            bad.append((key, a, b, rel))
    return bad


def _version_of(raw: dict) -> int:
    """Artifact schema version; artifacts predating the field are v1."""
    return int(raw.get("schema_version", 1))


def _slope_gate(args) -> int:
    records = load_history(args.history, last=args.gate_slope,
                           schema_version=SCHEMA_VERSION)
    pct = args.gate_pct if args.gate_pct is not None else 25.0
    if len(records) < args.gate_min_runs:
        print(f"slope gate: only {len(records)} comparable run(s) in "
              f"{args.history} (need {args.gate_min_runs}) — skipping.")
        return 0
    print(f"slope gate: Theil-Sen over last {len(records)} runs "
          f"({records[0]['git_sha'][:12]} .. {records[-1]['git_sha'][:12]})")
    bad = slope_failures(records, pct=pct, min_ms=args.gate_min_ms,
                         min_abs=args.gate_min_abs,
                         min_runs=args.gate_min_runs)
    for name, med, drift, rel in bad:
        print(f"SLOPE {name}: projected drift {drift:+.4g} over "
              f"{len(records)} runs ({rel:+.0f}% of median {med:.4g} "
              f"> {pct:g}%)")
    if bad:
        print(f"slope gate FAILED: {len(bad)} series creeping beyond "
              f"{pct:g}% across the window — per-step deltas may each "
              f"look flat; the trend is not.")
        return 2
    print(f"slope gate passed ({pct:g}% over {len(records)} runs).")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("old", nargs="?", help="previous serve_latency.json")
    ap.add_argument("new", nargs="?", help="current serve_latency.json")
    ap.add_argument("--min-delta", type=float, default=1.0,
                    help="hide rows whose relative change is below this %%")
    ap.add_argument("--no-validate", action="store_true",
                    help="skip schema validation of the two artifacts")
    ap.add_argument("--gate-pct", type=float, default=None,
                    help="fail (exit 2) if any per-phase seconds aggregate "
                         "grew more than this %% vs the old artifact "
                         "(pairwise), or if a series' projected drift "
                         "exceeds this %% of its median (--gate-slope)")
    ap.add_argument("--gate-min-ms", type=float, default=3.0,
                    help="absolute growth a gated aggregate must exceed "
                         "before the %% threshold applies (noise floor)")
    ap.add_argument("--gate-slope", type=int, default=None, metavar="N",
                    help="slope mode: fit Theil-Sen over the last N history "
                         "records instead of diffing two artifacts")
    ap.add_argument("--history", default=HISTORY_PATH,
                    help="JSON-lines history file for --gate-slope")
    ap.add_argument("--gate-min-abs", type=float, default=0.01,
                    help="slope-mode noise floor for unitless series "
                         "(AUC, recall, fractions)")
    ap.add_argument("--gate-min-runs", type=int, default=4,
                    help="slope mode needs at least this many comparable "
                         "runs; fewer skips the gate (exit 0)")
    ap.add_argument("--strict-version", action="store_true",
                    help="exit 4 on a schema_version mismatch between the "
                         "two artifacts instead of skipping the diff")
    args = ap.parse_args(argv)

    if args.gate_slope is not None:
        return _slope_gate(args)
    if not args.old or not args.new:
        ap.error("old and new artifacts are required unless --gate-slope")

    with open(args.old) as f:
        old_raw = json.load(f)
    with open(args.new) as f:
        new_raw = json.load(f)
    v_old, v_new = _version_of(old_raw), _version_of(new_raw)
    if v_old != v_new:
        print(f"REFUSING to diff across artifact schema versions: "
              f"{args.old} is v{v_old}, {args.new} is v{v_new}. A cross-"
              f"version diff silently flattens to a near-empty comparison "
              f"that reads as 'all flat' — regenerate the baseline with "
              f"the current benchmark instead.")
        return 4 if args.strict_version else 0
    if not args.no_validate:
        schema = load_schema(SCHEMA_PATH)
        validate_or_raise(old_raw, schema, args.old)
        validate_or_raise(new_raw, schema, args.new)
    old = flatten(old_raw)
    new = flatten(new_raw)

    keys = sorted(set(old) | set(new))
    width = max((len(k) for k in keys), default=0)
    regressions = 0
    for k in keys:
        a, b = old.get(k), new.get(k)
        if a is None or b is None:
            tag = "added" if a is None else "removed"
            print(f"  {k:<{width}}  [{tag}] {a if b is None else b:g}")
            continue
        if a == b:
            continue
        rel = (b - a) / abs(a) * 100 if a else float("inf")
        if abs(rel) < args.min_delta:
            continue
        better = (b - a) * direction(k) > 0
        mark = "+" if better else "!"
        if not better:
            regressions += 1
        print(f"{mark} {k:<{width}}  {a:g} -> {b:g}  ({rel:+.1f}%)")
    print(f"\n{regressions} metric(s) moved the wrong way "
          f"(threshold {args.min_delta}%).")

    if args.gate_pct is not None:
        bad = gate_failures(old_raw, new_raw, args.gate_pct, args.gate_min_ms)
        for key, a, b, rel in bad:
            print(f"GATE {key}: {a * 1e3:.2f}ms -> {b * 1e3:.2f}ms "
                  f"({rel:+.0f}% > {args.gate_pct:g}%)")
        if bad:
            print(f"trend gate FAILED: {len(bad)} phase aggregate(s) "
                  f"regressed beyond {args.gate_pct:g}% "
                  f"(+{args.gate_min_ms:g}ms floor).")
            return 2
        print(f"trend gate passed ({args.gate_pct:g}% / "
              f"{args.gate_min_ms:g}ms floor).")
    return 0


if __name__ == "__main__":
    sys.exit(main())

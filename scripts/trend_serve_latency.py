"""Diff two ``results/serve_latency.json`` artifacts (trend first step).

CI uploads the serving benchmark's JSON per PR; this prints a compact
old -> new comparison of every numeric metric (recursively flattened with
dotted keys), flagging regressions so a human can eyeball the trajectory
before a real dashboard exists.

Both artifacts are validated against the checked-in schema
(``results/serve_latency.schema.json``) before diffing: a renamed or
mistyped section would otherwise silently flatten to *nothing* and the
trend would look flat. ``--no-validate`` skips the check (e.g. to diff an
artifact written before the schema existed).

Usage::

    python scripts/trend_serve_latency.py old.json new.json
    python scripts/trend_serve_latency.py old.json new.json --min-delta 5
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
)

from repro.obs import load_schema, validate_or_raise  # noqa: E402

SCHEMA_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "results", "serve_latency.schema.json",
)


def flatten(obj, prefix=""):
    """dict/list tree -> {dotted.key: leaf} (numbers and bools only)."""
    out = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(flatten(v, f"{prefix}{k}."))
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            out.update(flatten(v, f"{prefix}{i}."))
    elif isinstance(obj, bool):
        out[prefix[:-1]] = int(obj)
    elif isinstance(obj, (int, float)):
        out[prefix[:-1]] = float(obj)
    return out


# metrics where an increase is an improvement; everything else (latencies,
# mismatches, staleness) improves downward. Substring match on the key.
HIGHER_IS_BETTER = (
    "edges_per_s", "qps", "speedup", "auc", "queries", "retrains",
)


def direction(key: str) -> int:
    return 1 if any(tok in key for tok in HIGHER_IS_BETTER) else -1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("old", help="previous serve_latency.json")
    ap.add_argument("new", help="current serve_latency.json")
    ap.add_argument("--min-delta", type=float, default=1.0,
                    help="hide rows whose relative change is below this %%")
    ap.add_argument("--no-validate", action="store_true",
                    help="skip schema validation of the two artifacts")
    args = ap.parse_args(argv)

    with open(args.old) as f:
        old_raw = json.load(f)
    with open(args.new) as f:
        new_raw = json.load(f)
    if not args.no_validate:
        schema = load_schema(SCHEMA_PATH)
        validate_or_raise(old_raw, schema, args.old)
        validate_or_raise(new_raw, schema, args.new)
    old = flatten(old_raw)
    new = flatten(new_raw)

    keys = sorted(set(old) | set(new))
    width = max((len(k) for k in keys), default=0)
    regressions = 0
    for k in keys:
        a, b = old.get(k), new.get(k)
        if a is None or b is None:
            tag = "added" if a is None else "removed"
            print(f"  {k:<{width}}  [{tag}] {a if b is None else b:g}")
            continue
        if a == b:
            continue
        rel = (b - a) / abs(a) * 100 if a else float("inf")
        if abs(rel) < args.min_delta:
            continue
        better = (b - a) * direction(k) > 0
        mark = "+" if better else "!"
        if not better:
            regressions += 1
        print(f"{mark} {k:<{width}}  {a:g} -> {b:g}  ({rel:+.1f}%)")
    print(f"\n{regressions} metric(s) moved the wrong way "
          f"(threshold {args.min_delta}%).")
    return 0


if __name__ == "__main__":
    sys.exit(main())

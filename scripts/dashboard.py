"""Self-contained HTML dashboard over the benchmark history series.

Reads ``results/history/serve_latency.jsonl`` (the records
``benchmarks/serve_latency.py`` appends each run) and emits one static HTML
file with inline SVG — no external assets, no JS/CSS dependencies — so CI
can upload it as an artifact and anyone can open it from disk.

Layout: a KPI row of stat tiles for the latest run (ingest rate, query p99,
recall@k, link-pred AUC, SLO status), then per-section grids of **small
multiples** — one line chart per series (phase seconds, latencies,
throughput, quality, SLO compliance), each a single 2px accent line over a
hairline grid with the latest value direct-labeled and the run-over-run
delta colored by whether the move is an improvement (arrow + sign carry the
meaning, not color alone). Small multiples rather than one many-series
plot: phase aggregates routinely exceed a legible series count, and every
facet shares the x axis (run index), so trajectories still compare. Each
section carries a collapsible table view of the raw numbers — the chart
never gates a value.

Usage::

    python scripts/dashboard.py                      # results/dashboard.html
    python scripts/dashboard.py --last 30 --out /tmp/dash.html
"""
from __future__ import annotations

import argparse
import html
import os
import sys

sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
)

from repro.obs.history import direction, load_history  # noqa: E402

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
HISTORY_PATH = os.path.join(_ROOT, "results", "history",
                            "serve_latency.jsonl")

# series are faceted into sections by key substring; first match wins
SECTIONS = (
    ("SLO compliance", lambda k: k.startswith("slo.")),
    ("Quality", lambda k: any(t in k for t in
                              ("auc", "recall", "staleness", "fraction"))),
    ("Throughput", lambda k: "per_s" in k or "qps" in k),
    ("Latency & phases", lambda k: True),  # catch-all: seconds series
)

W, H = 264, 96          # plot box of one small multiple (px)
PAD_L, PAD_R = 8, 64    # right pad holds the direct end-label


def fmt(v: float, key: str = "") -> str:
    """Human number: seconds get ms/s units, rates get k-compaction."""
    if "per_s" in key or "qps" in key:
        return f"{v / 1e3:.1f}k" if abs(v) >= 1e3 else f"{v:.0f}"
    if any(t in key for t in ("auc", "recall", "compliance", "fraction",
                              "staleness")):
        return f"{v:.3f}"
    if abs(v) >= 1.0:
        return f"{v:.2f}s"
    return f"{v * 1e3:.1f}ms" if abs(v) >= 1e-3 else f"{v * 1e6:.0f}µs"


def _points(ys, lo, hi):
    """Polyline coordinates for one series inside the plot box."""
    n = len(ys)
    span = (hi - lo) or 1.0
    xs = [PAD_L + (W - PAD_L - PAD_R) * (i / max(n - 1, 1))
          for i in range(n)]
    return [(x, 8 + (H - 16) * (1.0 - (y - lo) / span))
            for x, y in zip(xs, ys)]


def chart(key: str, ys, shas) -> str:
    """One small multiple: hairline grid, 2px accent line, ringed end dot,
    direct end label, and a hover strip per run feeding the shared
    tooltip."""
    lo, hi = min(ys), max(ys)
    pts = _points(ys, lo, hi)
    line = " ".join(f"{x:.1f},{y:.1f}" for x, y in pts)
    ex, ey = pts[-1]
    delta = ""
    if len(ys) >= 2 and ys[-2]:
        move = (ys[-1] - ys[-2]) * direction(key)
        arrow = "▲" if ys[-1] >= ys[-2] else "▼"
        cls = "good" if move > 0 else ("bad" if move < 0 else "flat")
        delta = (f'<span class="delta {cls}">{arrow} '
                 f'{(ys[-1] - ys[-2]) / abs(ys[-2]) * 100:+.1f}%</span>')
    # hover strips: one generous hit band per run (≥24px when room allows)
    n = len(ys)
    band = (W - PAD_L - PAD_R) / max(n - 1, 1)
    strips = "".join(
        f'<rect class="hit" x="{x - max(band, 24) / 2:.1f}" y="0" '
        f'width="{max(band, 24):.1f}" height="{H}" '
        f'data-tip="run {i + 1} · {html.escape(shas[i][:10])} · '
        f'{fmt(ys[i], key)}"></rect>'
        for i, (x, _) in enumerate(pts)
    )
    grid = "".join(
        f'<line class="grid" x1="{PAD_L}" x2="{W - PAD_R + 40}" '
        f'y1="{gy}" y2="{gy}"></line>'
        for gy in (8, H / 2, H - 8)
    )
    return f"""
<figure class="cell">
  <figcaption title="{html.escape(key)}">{html.escape(key)}</figcaption>
  <svg viewBox="0 0 {W} {H}" role="img"
       aria-label="{html.escape(key)}: latest {fmt(ys[-1], key)}">
    {grid}
    <polyline class="series" points="{line}"></polyline>
    <circle class="dot" cx="{ex:.1f}" cy="{ey:.1f}" r="4"></circle>
    <text class="endlabel" x="{ex + 8:.1f}" y="{ey + 4:.1f}">
      {fmt(ys[-1], key)}</text>
    {strips}
  </svg>
  <div class="meta"><span class="range">{fmt(lo, key)} – {fmt(hi, key)}
  </span>{delta}</div>
</figure>"""


def table(section: str, keys, records) -> str:
    head = "".join(f"<th>{html.escape(k)}</th>" for k in keys)
    rows = []
    for i, rec in enumerate(records):
        cells = "".join(
            f"<td>{fmt(rec['metrics'][k], k)}</td>" if k in rec["metrics"]
            else "<td>—</td>"
            for k in keys
        )
        rows.append(f"<tr><td>{i + 1}</td>"
                    f"<td>{html.escape(rec['git_sha'][:10])}</td>{cells}</tr>")
    return (f'<details><summary>Table view — {html.escape(section)}'
            f'</summary><div class="scroll"><table><thead><tr><th>run</th>'
            f'<th>sha</th>{head}</tr></thead><tbody>{"".join(rows)}'
            f"</tbody></table></div></details>")


def kpi_row(records) -> str:
    latest = records[-1]["metrics"]
    slo_keys = [k for k in latest if k.startswith("slo.")
                and k.endswith(".compliance")]
    slo_ok = all(latest[k] >= 0.99 for k in slo_keys) if slo_keys else None
    tiles = []
    for label, key in (("Ingest rate", "ingest_edges_per_s"),
                       ("Query p99", "query_p99_s"),
                       ("Recall@k", "topk.recall_at_k"),
                       ("Link-pred AUC", "retrain.auc_after")):
        if key in latest:
            tiles.append(
                f'<div class="tile"><div class="label">{label}</div>'
                f'<div class="value">{fmt(latest[key], key)}</div></div>'
            )
    if slo_ok is not None:
        badge = ("✓ meeting objectives" if slo_ok
                 else "✗ objective breached")
        cls = "ok" if slo_ok else "alert"
        tiles.append(
            f'<div class="tile"><div class="label">SLO status</div>'
            f'<div class="value badge {cls}">{badge}</div></div>'
        )
    return f'<div class="kpis">{"".join(tiles)}</div>'


CSS = """
:root { color-scheme: light;
  --surface:#fcfcfb; --page:#f9f9f7; --ink:#0b0b0b; --ink2:#52514e;
  --muted:#898781; --grid:#e1e0d9; --series:#2a78d6;
  --good:#006300; --bad:#d03b3b; --ring:rgba(11,11,11,0.10); }
@media (prefers-color-scheme: dark) { :root { color-scheme: dark;
  --surface:#1a1a19; --page:#0d0d0d; --ink:#ffffff; --ink2:#c3c2b7;
  --muted:#898781; --grid:#2c2c2a; --series:#3987e5;
  --good:#0ca30c; --bad:#d03b3b; --ring:rgba(255,255,255,0.10); } }
* { box-sizing: border-box; }
body { margin:0; padding:24px; background:var(--page); color:var(--ink);
  font:14px/1.45 system-ui,-apple-system,"Segoe UI",sans-serif; }
h1 { font-size:20px; margin:0 0 4px; }
h2 { font-size:15px; margin:28px 0 10px; color:var(--ink2); }
.sub { color:var(--muted); margin-bottom:18px; }
.kpis { display:flex; flex-wrap:wrap; gap:12px; margin:16px 0 8px; }
.tile { background:var(--surface); border:1px solid var(--ring);
  border-radius:8px; padding:12px 16px; min-width:130px; }
.tile .label { color:var(--ink2); font-size:12px; }
.tile .value { font-size:26px; font-weight:600; margin-top:2px; }
.badge { font-size:14px !important; font-weight:600; }
.badge.ok { color:var(--good); } .badge.alert { color:var(--bad); }
.grid-cells { display:grid; gap:12px;
  grid-template-columns:repeat(auto-fill,minmax(280px,1fr)); }
.cell { background:var(--surface); border:1px solid var(--ring);
  border-radius:8px; padding:10px 8px 6px; margin:0; }
.cell figcaption { font-size:12px; color:var(--ink2); padding:0 4px 6px;
  white-space:nowrap; overflow:hidden; text-overflow:ellipsis; }
.cell svg { width:100%; height:auto; display:block; }
.grid { stroke:var(--grid); stroke-width:1; }
.series { fill:none; stroke:var(--series); stroke-width:2;
  stroke-linejoin:round; stroke-linecap:round; }
.dot { fill:var(--series); stroke:var(--surface); stroke-width:2; }
.endlabel { fill:var(--ink2); font-size:11px; }
.hit { fill:transparent; cursor:crosshair; }
.meta { display:flex; justify-content:space-between; font-size:11px;
  color:var(--muted); padding:2px 4px 0;
  font-variant-numeric:tabular-nums; }
.delta.good { color:var(--good); } .delta.bad { color:var(--bad); }
.delta.flat { color:var(--muted); }
details { margin:10px 0 0; font-size:12px; color:var(--ink2); }
summary { cursor:pointer; }
.scroll { overflow-x:auto; }
table { border-collapse:collapse; margin-top:8px;
  font-variant-numeric:tabular-nums; }
th,td { padding:3px 10px; text-align:right; border-bottom:1px solid
  var(--grid); white-space:nowrap; }
th { color:var(--muted); font-weight:500; }
#tip { position:fixed; pointer-events:none; background:var(--surface);
  color:var(--ink); border:1px solid var(--ring); border-radius:6px;
  padding:4px 8px; font-size:12px; display:none; z-index:9;
  box-shadow:0 2px 8px rgba(0,0,0,0.15); }
"""

JS = """
var tip = document.getElementById('tip');
document.addEventListener('mousemove', function (e) {
  var t = e.target.closest ? e.target.closest('.hit') : null;
  if (t && t.dataset.tip) {
    tip.textContent = t.dataset.tip;
    tip.style.display = 'block';
    tip.style.left = Math.min(e.clientX + 12,
        window.innerWidth - tip.offsetWidth - 8) + 'px';
    tip.style.top = (e.clientY + 14) + 'px';
  } else { tip.style.display = 'none'; }
});
"""


def render(records, *, title="Serving benchmark trends") -> str:
    if not records:
        body = ("<p class='sub'>No history yet — run "
                "<code>benchmarks/serve_latency.py</code> to start the "
                "series.</p>")
        return (f"<!doctype html><html><head><meta charset='utf-8'>"
                f"<title>{title}</title><style>{CSS}</style></head>"
                f"<body><h1>{title}</h1>{body}</body></html>")
    keys = sorted({k for r in records for k in r["metrics"]})
    shas = [r["git_sha"] for r in records]
    assigned = set()
    sections = []
    for name, match in SECTIONS:
        sec_keys = [k for k in keys if k not in assigned and match(k)]
        assigned.update(sec_keys)
        if not sec_keys:
            continue
        cells = "".join(
            chart(k, [float(r["metrics"][k]) for r in records
                      if k in r["metrics"]],
                  [s for r, s in zip(records, shas) if k in r["metrics"]])
            for k in sec_keys
        )
        sections.append(
            f"<h2>{html.escape(name)}</h2>"
            f'<div class="grid-cells">{cells}</div>'
            f"{table(name, sec_keys, records)}"
        )
    sub = (f"{len(records)} runs · {shas[0][:10]} → {shas[-1][:10]} · "
           f"x axis is run order")
    return (f"<!doctype html><html><head><meta charset='utf-8'>"
            f"<meta name='viewport' content='width=device-width,"
            f"initial-scale=1'><title>{title}</title>"
            f"<style>{CSS}</style></head><body>"
            f"<h1>{title}</h1><div class='sub'>{sub}</div>"
            f"{kpi_row(records)}{''.join(sections)}"
            f"<div id='tip'></div><script>{JS}</script></body></html>")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--history", default=HISTORY_PATH,
                    help="JSON-lines benchmark history to plot")
    ap.add_argument("--out", default=os.path.join(_ROOT, "results",
                                                  "dashboard.html"))
    ap.add_argument("--last", type=int, default=50,
                    help="plot at most the newest N runs")
    args = ap.parse_args(argv)
    records = load_history(args.history, last=args.last)
    doc = render(records)
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        f.write(doc)
    print(f"[dashboard] {args.out}: {len(records)} runs, "
          f"{len({k for r in records for k in r['metrics']})} series")
    return 0


if __name__ == "__main__":
    sys.exit(main())

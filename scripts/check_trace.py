"""Assert a Chrome trace_event export covers the serving pipeline.

CI's observability leg runs the serving benchmark with ``--trace`` and then
runs this over the exported JSON: the trace must load, contain complete
("ph": "X") events, and cover every required stage of the pipeline —
ingest, at least one core-repair phase (region / candidates / descend /
fallback), and query flushes — plus the retrain stages when the run
included retraining. A refactor that silently drops a span (or renames one
without updating its consumers) fails here instead of producing
quietly-empty traces.

Usage::

    python scripts/check_trace.py results/serve_trace.json
    python scripts/check_trace.py results/serve_trace.json --expect-retrain
    python scripts/check_trace.py results/serve_trace.json --expect-recovery
    python scripts/check_trace.py results/serve_trace.json --expect-topk
"""
from __future__ import annotations

import argparse
import json
import sys

REQUIRED = [
    "serve.ingest",
    "serve.flush",
    "store.gather",
    "graph.add_edges",
]
# block repair always runs the region phase; which later phase fires
# (candidates/descend vs fallback) depends on region size, so any one of
# them satisfies the repair requirement
REPAIR_ANY = ["repair.candidates", "repair.descend", "repair.fallback"]
RETRAIN_REQUIRED = [
    "retrain.plan",
    "retrain.train",
    "retrain.align",
    "retrain.propagate",
    "retrain.swap",
]
RECOVERY_REQUIRED = [
    "recovery.wal_append",
    "recovery.snapshot",
    "recovery.restore",
    "recovery.replay",
]
# the query-engine leg (--topk benchmark runs): retrieval spans plus the
# fused-gather dispatch (store.gather tagged fused=1) the flush path uses
TOPK_REQUIRED = ["serve.topk"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="Chrome trace_event JSON to check")
    ap.add_argument("--expect-retrain", action="store_true",
                    help="also require the retrain stage spans")
    ap.add_argument("--expect-recovery", action="store_true",
                    help="also require the WAL/snapshot/restore/replay "
                         "recovery spans")
    ap.add_argument("--expect-topk", action="store_true",
                    help="also require the serve.topk retrieval span and a "
                         "fused store.gather dispatch (args.fused == 1)")
    args = ap.parse_args(argv)

    with open(args.trace) as f:
        doc = json.load(f)
    events = [e for e in doc.get("traceEvents", []) if e.get("ph") == "X"]
    names = {e["name"] for e in events}
    print(f"[check-trace] {args.trace}: {len(events)} complete events, "
          f"{len(names)} span kinds: {', '.join(sorted(names))}")

    missing = [n for n in REQUIRED if n not in names]
    if "repair.region" not in names:
        missing.append("repair.region")
    if not any(n in names for n in REPAIR_ANY):
        missing.append(" | ".join(REPAIR_ANY))
    if args.expect_retrain:
        missing += [n for n in RETRAIN_REQUIRED if n not in names]
    if args.expect_recovery:
        missing += [n for n in RECOVERY_REQUIRED if n not in names]
    if args.expect_topk:
        missing += [n for n in TOPK_REQUIRED if n not in names]
        fused = any(
            e["name"] == "store.gather"
            and (e.get("args") or {}).get("fused") == 1
            for e in events
        )
        if not fused:
            missing.append("store.gather{fused=1}")
    if missing:
        print(f"[check-trace] FAIL: missing spans: {missing}")
        return 1

    bad = [e for e in events
           if "ts" not in e or "dur" not in e or e["dur"] < 0]
    if bad:
        print(f"[check-trace] FAIL: {len(bad)} events without valid ts/dur")
        return 1
    # nesting sanity: at least one repair span strictly inside an ingest span
    ingests = [e for e in events if e["name"] == "serve.ingest"]
    repairs = [e for e in events if e["name"].startswith("repair.")]
    nested = any(
        i["ts"] <= r["ts"] and r["ts"] + r["dur"] <= i["ts"] + i["dur"]
        for r in repairs for i in ingests
    )
    if repairs and not nested:
        print("[check-trace] FAIL: no repair span nests inside an ingest "
              "span — the span hierarchy is broken")
        return 1
    print("[check-trace] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())

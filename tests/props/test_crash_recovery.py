"""Hypothesis property: crash anywhere, recover bit-identically.

For a random small graph, a random mixed insert/delete op stream, and a
random crash point/hit drawn over the WAL, snapshot, apply, and repair
injection sites, a service running under WAL + snapshots is killed with
``InjectedCrash``, recovered from durable state, and resumed over the
remaining ops. The final state must equal the uninterrupted twin
byte-for-byte (graph tables, store rows/versions, core numbers, baseline,
counters) *and* the core numbers must match the from-scratch peeling
oracle. When the drawn hit count never fires, the run completes normally —
the equality property must hold either way.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dependency (pip extra: dev)
from hypothesis import given, settings, strategies as st

from repro.core.kcore import core_numbers_host
from repro.graph.csr import Graph
from repro.launch.serve_embed import build_service
from repro.serve import faults
from repro.serve.faults import FaultPlan, InjectedCrash
from repro.serve.recovery import RecoveryManager, capture_state

CRASHABLE = ("wal_append", "wal_fsync", "snapshot_write", "snapshot_commit",
             "ingest_apply", "repair")


@st.composite
def scenarios(draw):
    n = draw(st.integers(30, 80))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    edges = set()
    perm = rng.permutation(n)
    for a, b in zip(perm[:-1], perm[1:]):
        edges.add((min(a, b), max(a, b)))
    target = draw(st.integers(2 * n, 4 * n))
    while len(edges) < target:
        u, v = rng.integers(0, n, size=2)
        if u != v:
            edges.add((min(u, v), max(u, v)))
    g = Graph.from_edges(n, np.array(sorted(edges)))
    return dict(
        g=g,
        seed=seed % 1000,
        block=draw(st.integers(4, 16)),
        churn_seed=draw(st.integers(0, 1000)),
        point=draw(st.sampled_from(CRASHABLE)),
        hit=draw(st.integers(1, 12)),
        snapshot_every=draw(st.integers(1, 4)),
    )


def _plan_ops(stream, *, block, churn_seed):
    """Mixed insert/delete stream, a pure function of its inputs: churn is
    drawn from previously *submitted* edges so the twin, the crash run, and
    the replay all see the identical op list (ops map 1:1 to WAL records)."""
    rng = np.random.default_rng(churn_seed)
    live, ops = [], []
    for s in range(0, len(stream), block):
        blk = np.asarray(stream[s:s + block], np.int64)
        ops.append(("ingest", blk))
        live.extend(map(tuple, blk))
        n_del = min(int(rng.integers(0, max(len(blk) // 2, 1) + 1)), len(live))
        if n_del:
            pick = rng.choice(len(live), size=n_del, replace=False)
            ops.append(("retract",
                        np.asarray([live[i] for i in pick], np.int64)))
            gone = set(pick.tolist())
            live = [e for i, e in enumerate(live) if i not in gone]
    return ops


def _apply(svc, ops, start=0):
    for kind, blk in ops[start:]:
        (svc.ingest_block if kind == "ingest" else svc.retract_block)(blk)
    svc.sync()


def _arrays(svc):
    arrays, _ = capture_state(svc, 0)
    return arrays


@given(scenarios())
@settings(max_examples=8, deadline=None)
def test_crash_anywhere_recovers_bit_identical(tmp_path_factory, sc):
    faults.install(None)
    svc0, stream, _, _ = build_service(sc["g"], seed=sc["seed"], batch=16,
                                       stream_frac=0.5, compact_every=64)
    ops = _plan_ops(stream, block=sc["block"], churn_seed=sc["churn_seed"])
    _apply(svc0, ops)
    truth = _arrays(svc0)

    waldir = str(tmp_path_factory.mktemp("recov"))
    svc, _, _, _ = build_service(sc["g"], seed=sc["seed"], batch=16,
                                 stream_frac=0.5, compact_every=64)
    mgr = RecoveryManager(svc, waldir, snapshot_every=sc["snapshot_every"],
                          fsync=False)
    faults.install(FaultPlan.parse(f"{sc['point']}:{sc['hit']}:crash"))
    crashed = False
    try:
        _apply(svc, ops)
    except InjectedCrash:
        crashed = True
    finally:
        faults.install(None)
    try:
        mgr.wait()  # quiesce the dead process's snapshot writer
    except BaseException:
        pass
    mgr.wal.close()

    if crashed:
        svc, mgr, report = RecoveryManager.recover(
            waldir, snapshot_every=sc["snapshot_every"], fsync=False
        )
        # ops ↔ WAL records 1:1: the durable seq is the resume index
        _apply(svc, ops, start=report["wal_seq"])
    got = _arrays(svc)
    bad = [k for k in sorted(set(truth) | set(got))
           if k not in truth or k not in got
           or not np.array_equal(truth[k], got[k])]
    assert bad == [], f"crash at {sc['point']}:{sc['hit']} diverged: {bad}"

    oracle = core_numbers_host(svc.graph.snapshot())
    assert (np.asarray(svc.cores.core[: len(oracle)]) == oracle).all()
    mgr.close()

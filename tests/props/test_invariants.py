"""Hypothesis property tests on the system's graph/degeneracy invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dependency (pip extra: dev)
from hypothesis import given, settings, strategies as st

from repro.core import corewalk, kcore
from repro.graph.csr import Graph
from repro.kernels import ops, ref
from repro.serve import DynamicGraph, EmbeddingStore, IncrementalCore, ShardPlan
from repro.walks.engine import random_walks


@st.composite
def graphs(draw, max_nodes=40):
    n = draw(st.integers(5, max_nodes))
    n_edges = draw(st.integers(n - 1, min(3 * n, n * (n - 1) // 2)))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    edges = set()
    # spanning chain ensures no isolated nodes
    perm = rng.permutation(n)
    for a, b in zip(perm[:-1], perm[1:]):
        edges.add((min(a, b), max(a, b)))
    while len(edges) < n_edges:
        u, v = rng.integers(0, n, size=2)
        if u != v:
            edges.add((min(u, v), max(u, v)))
    return Graph.from_edges(n, np.array(sorted(edges)))


@given(graphs())
@settings(max_examples=40, deadline=None)
def test_core_number_invariants(g):
    core = kcore.core_numbers_host(g)
    deg = g.degrees()
    # 1. core number is at most the degree
    assert np.all(core <= deg)
    # 2. k-core has min degree >= k inside itself, for every k
    for k in range(1, kcore.degeneracy(core) + 1):
        sub = kcore.kcore_subgraph(g, core, k)
        members = core >= k
        if members.any():
            assert sub.degrees()[members].min() >= k
    # 3. degeneracy bounds: <= max degree
    assert kcore.degeneracy(core) <= deg.max()


@given(graphs(max_nodes=30))
@settings(max_examples=25, deadline=None)
def test_jax_core_equals_host_core(g):
    host = kcore.core_numbers_host(g)
    dev = np.asarray(kcore.core_numbers_jax(g.to_ell()))
    np.testing.assert_array_equal(host, dev)


@given(
    graphs(max_nodes=35),
    st.integers(1, 48),  # insert block size
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_block_repair_and_deletion_match_peeling(g, block_size, seed):
    """``on_edge_block`` / ``on_remove`` agree exactly with Matula–Beck
    peeling on random insert/delete interleavings, across compaction
    boundaries."""
    rng = np.random.default_rng(seed)
    edges = g.edge_list()
    edges = edges[rng.permutation(len(edges))]
    dyn = DynamicGraph(g.n_nodes, width=2)  # tiny width: overflow + compaction
    inc = IncrementalCore(dyn)
    live: list = []
    step = 0
    for start in range(0, len(edges), block_size):
        step += 1
        accepted = dyn.add_edges(edges[start : start + block_size])
        inc.on_edge_block(accepted)
        live.extend(map(tuple, accepted))
        if step % 2 == 0 and len(live) > 4:
            k = int(rng.integers(1, max(len(live) // 3, 2)))
            pick = rng.choice(len(live), size=k, replace=False)
            removed = dyn.remove_edges(np.array([live[i] for i in pick]))
            inc.on_remove(removed)
            gone = {tuple(e) for e in removed}
            live = [e for e in live if e not in gone]
        if step % 3 == 0:
            dyn.compact()  # double-buffered swap must not disturb repair
        oracle = kcore.core_numbers_host(dyn.snapshot())
        np.testing.assert_array_equal(inc.core, oracle)
    assert inc.resync() == 0


@given(
    graphs(max_nodes=30),
    st.integers(1, 40),  # insert block size
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=15, deadline=None)
def test_device_region_matches_host_bfs(g, block_size, seed):
    """Frontier-masked region growing (vectorized host + jitted device ELL
    traversal with the removed-edge/overflow side table) returns exactly the
    host BFS ``_region`` node set on random graphs under mixed insert/delete
    blocks with compaction boundaries."""
    rng = np.random.default_rng(seed)
    edges = g.edge_list()
    edges = edges[rng.permutation(len(edges))]
    dyn = DynamicGraph(g.n_nodes, width=2)  # tiny width: overflow side arcs
    inc = IncrementalCore(dyn)
    live: list = []
    step = 0
    for start in range(0, len(edges), block_size):
        step += 1
        added = dyn.add_edges(edges[start : start + block_size])
        inc.on_edge_block(added)
        live.extend(map(tuple, added))
        removed = np.zeros((0, 2), np.int64)
        if step % 2 == 0 and len(live) > 4:
            k = int(rng.integers(1, max(len(live) // 3, 2)))
            pick = rng.choice(len(live), size=k, replace=False)
            removed = dyn.remove_edges(np.array([live[i] for i in pick]))
            inc.on_remove(removed)
            gone = {tuple(e) for e in removed}
            live = [e for e in live if e not in gone]
        if step % 3 == 0:
            dyn.compact()
        touched = np.concatenate([added, removed]) if len(removed) else added
        if not len(touched):
            continue
        core = inc.core
        k_edge = np.minimum(core[touched[:, 0]], core[touched[:, 1]])
        lo = max(0, int(k_edge.min()) - 2)
        hi = int(k_edge.max()) + 2
        ends = np.unique(touched.reshape(-1))
        want = np.asarray(inc._region(ends, lo, hi, removed), np.int64)
        ov_src, ov_dst = dyn.overflow_arc_arrays()
        side_src = np.concatenate([ov_src, removed[:, 0], removed[:, 1]])
        side_dst = np.concatenate([ov_dst, removed[:, 1], removed[:, 0]])
        cap = 1 << 30  # unbounded: compare complete regions
        got_np = inc._region_np(ends, lo, hi, side_src, side_dst, cap)
        got_dev = inc._region_device(ends, lo, hi, side_src, side_dst, cap)
        np.testing.assert_array_equal(got_np, want)
        np.testing.assert_array_equal(got_dev, want)


@given(
    graphs(max_nodes=30),
    st.sampled_from([1, 2, 4, 8]),  # shard counts
    st.integers(1, 32),  # insert block size
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=10, deadline=None)
def test_sharded_repair_and_store_are_shard_count_invariant(
    g, n_shards, block_size, seed
):
    """Row-sharding is placement-only: for any shard count, sharded core
    numbers equal the peeling oracle on random mixed insert/delete blocks,
    and the store's staleness / version histogram / eviction count are
    identical to the single-device run of the same seeded op stream."""
    if jax.device_count() < n_shards:
        pytest.skip(f"needs {n_shards} devices")
    plan = ShardPlan.build(n_shards)
    rng = np.random.default_rng(seed)
    edges = g.edge_list()
    edges = edges[rng.permutation(len(edges))]
    dyn = DynamicGraph(g.n_nodes, width=2, plan=plan)  # tiny width: overflow
    inc = IncrementalCore(dyn)
    ref_store = EmbeddingStore(capacity=8, dim=4, node_cap=g.n_nodes)
    sh_store = EmbeddingStore(capacity=8, dim=4, node_cap=g.n_nodes, plan=plan)
    live: list = []
    step = 0
    for start in range(0, len(edges), block_size):
        step += 1
        accepted = dyn.add_edges(edges[start : start + block_size])
        inc.on_edge_block(accepted)
        live.extend(map(tuple, accepted))
        if step % 2 == 0 and len(live) > 4:
            k = int(rng.integers(1, max(len(live) // 3, 2)))
            pick = rng.choice(len(live), size=k, replace=False)
            removed = dyn.remove_edges(np.array([live[i] for i in pick]))
            inc.on_remove(removed)
            gone = {tuple(e) for e in removed}
            live = [e for e in live if e not in gone]
        if step % 3 == 0:
            dyn.compact()
        oracle = kcore.core_numbers_host(dyn.snapshot())
        np.testing.assert_array_equal(inc.core, oracle)
        # same store ops against both placements
        nodes = rng.integers(0, g.n_nodes, size=3)
        vecs = rng.normal(size=(3, 4)).astype(np.float32)
        cores_w = oracle[nodes]
        ref_store.put_many(nodes, vecs, cores_w)
        sh_store.put_many(nodes, vecs, cores_w)
        q = rng.integers(0, g.n_nodes, size=4)
        vr, fr = ref_store.gather(q)
        vs, fs = sh_store.gather(q)
        np.testing.assert_array_equal(fr, fs)
        np.testing.assert_array_equal(np.asarray(vr), np.asarray(vs))
    assert inc.resync() == 0
    assert ref_store.evictions == sh_store.evictions
    assert ref_store.version_counts() == sh_store.version_counts()
    assert ref_store.staleness(inc.core) == sh_store.staleness(inc.core)


@given(
    graphs(max_nodes=35),
    st.sampled_from(["adaptive", "region", "fallback"]),
    st.integers(1, 40),  # insert block size
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=15, deadline=None)
def test_repair_policy_is_cost_only(g, mode, block_size, seed):
    """The repair policy decides *which* exact path runs, never the result:
    any mode (measured crossover, legacy static trigger, always-fallback)
    matches the peeling oracle on random mixed insert/delete streams — and
    the pipelined begin/finish split matches the synchronous entry point."""
    rng = np.random.default_rng(seed)
    edges = g.edge_list()
    edges = edges[rng.permutation(len(edges))]
    dyn = DynamicGraph(g.n_nodes, width=2)  # tiny width: overflow side arcs
    inc = IncrementalCore(dyn, repair_policy=mode)
    live: list = []
    step = 0
    for start in range(0, len(edges), block_size):
        step += 1
        accepted = dyn.add_edges(edges[start : start + block_size])
        if step % 2:
            inc.on_edge_block(accepted)
        else:  # pipelined split: overlapped begin/finish must commit the same
            inc.finish_update(inc.begin_update(added=accepted))
        live.extend(map(tuple, accepted))
        if step % 2 == 0 and len(live) > 4:
            k = int(rng.integers(1, max(len(live) // 3, 2)))
            pick = rng.choice(len(live), size=k, replace=False)
            removed = dyn.remove_edges(np.array([live[i] for i in pick]))
            inc.on_remove(removed)
            gone = {tuple(e) for e in removed}
            live = [e for e in live if e not in gone]
        oracle = kcore.core_numbers_host(dyn.snapshot())
        np.testing.assert_array_equal(inc.core, oracle)
    assert inc.resync() == 0


@given(graphs(max_nodes=30), st.integers(2, 10), st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_walks_follow_edges(g, length, seed):
    ell = g.to_ell()
    roots = jnp.arange(g.n_nodes, dtype=jnp.int32)
    walks = np.asarray(random_walks(ell, roots, length, jax.random.PRNGKey(seed)))
    assert walks.shape == (g.n_nodes, length)
    for w in walks:
        for a, b in zip(w[:-1], w[1:]):
            assert a == b or g.has_edge(int(a), int(b))


@given(graphs(max_nodes=30), st.integers(1, 20))
@settings(max_examples=25, deadline=None)
def test_corewalk_budget_bounds(g, n):
    core = kcore.core_numbers_host(g)
    plan = corewalk.corewalk_plan(core, n)
    # Eq.13 bounds: 1 <= n_v <= n; degeneracy nodes get exactly n
    assert plan.per_node.min() >= 1
    assert plan.per_node.max() <= max(n, 1)
    kdeg = kcore.degeneracy(core)
    assert np.all(plan.per_node[core == kdeg] == max(n, 1))
    # monotone in core index
    order = np.argsort(core)
    assert np.all(np.diff(plan.per_node[order]) >= 0)


@given(
    st.integers(1, 12),  # N
    st.integers(1, 6),  # L
    st.integers(2, 10),  # M
    st.integers(1, 40),  # D
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=30, deadline=None)
def test_ell_mean_ref_matches_manual(N, L, M, D, seed):
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, M, size=(N, L)).astype(np.int32)
    valid = rng.random((N, L)) < 0.6
    emb = rng.standard_normal((M, D)).astype(np.float32)
    got = np.asarray(ref.ell_mean_ref(jnp.asarray(idx), jnp.asarray(valid), jnp.asarray(emb)))
    for i in range(N):
        rows = idx[i][valid[i]]
        want = emb[rows].mean(axis=0) if len(rows) else np.zeros(D, np.float32)
        np.testing.assert_allclose(got[i], want, rtol=1e-5, atol=1e-6)


@given(st.integers(1, 16), st.integers(1, 8), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_sgns_loss_positive_and_monotone_in_negatives(B, K, seed):
    rng = np.random.default_rng(seed)
    c = jnp.asarray(rng.standard_normal((B, 16)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((B, 16)), jnp.float32)
    n = jnp.asarray(rng.standard_normal((B, K, 16)), jnp.float32)
    loss_k = ref.sgns_loss_ref(c, x, n)
    assert np.all(np.asarray(loss_k) > 0)
    if K > 1:
        loss_k1 = ref.sgns_loss_ref(c, x, n[:, :1])
        assert np.all(np.asarray(loss_k) >= np.asarray(loss_k1) - 1e-5)


@given(
    st.integers(8, 40),  # anchors
    st.integers(2, 24),  # dim
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=40, deadline=None)
def test_procrustes_alignment_is_orthogonal(n, d, seed):
    """The retraining aligner's rotation is always orthogonal: row norms and
    anchor dot products survive alignment within tolerance, for any pair of
    anchor clouds (related by a planted rotation or not)."""
    from repro.serve import procrustes_rotation

    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, d)).astype(np.float32)
    q, r = np.linalg.qr(rng.standard_normal((d, d)))
    planted = (q * np.sign(np.diag(r))).astype(np.float32)
    for Y in (X @ planted, rng.standard_normal((n, d)).astype(np.float32)):
        R = procrustes_rotation(X, Y)
        np.testing.assert_allclose(R @ R.T, np.eye(d), atol=1e-4)
        np.testing.assert_allclose(R.T @ R, np.eye(d), atol=1e-4)
        aligned = X @ R
        np.testing.assert_allclose(
            np.linalg.norm(aligned, axis=1),
            np.linalg.norm(X, axis=1),
            rtol=1e-3, atol=1e-4,
        )
        np.testing.assert_allclose(
            aligned @ aligned.T, X @ X.T, rtol=1e-3, atol=1e-3
        )
    # and a planted rotation is recovered exactly (up to float error)
    np.testing.assert_allclose(
        procrustes_rotation(X, X @ planted), planted, atol=1e-3
    )

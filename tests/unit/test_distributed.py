"""Distributed substrate: sharding rules, checkpoint, compression, watchdog,
data pipeline. Multi-device behaviours run in an 8-CPU-device subprocess so
the main test session keeps the default 1-device view."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import PrefetchIterator, SyntheticLMData, pack_documents
from repro.distributed.compression import (
    ErrorFeedbackInt8,
    dequantize_int8,
    quantize_int8,
)
from repro.distributed.watchdog import HangWatchdog, StragglerMonitor

# ----------------------------------------------------------- compression --


def test_int8_quant_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) / 2 + 1e-6


def test_error_feedback_telescopes():
    """With EF, the SUM of compressed grads tracks the sum of true grads —
    the residual carries over instead of accumulating."""
    rng = np.random.default_rng(1)
    comp = ErrorFeedbackInt8()
    g_true = {"w": jnp.zeros(64)}
    state = comp.init(g_true)
    total_true = np.zeros(64)
    total_comp = np.zeros(64)
    for step in range(50):
        g = {"w": jnp.asarray(rng.standard_normal(64) * 0.01, jnp.float32)}
        total_true += np.asarray(g["w"])
        out, state = comp.compress_decompress(g, state)
        total_comp += np.asarray(out["w"])
    # telescoping: |sum difference| = |final residual| <= one quantisation step
    resid = np.abs(total_true - total_comp)
    assert resid.max() < 1e-3, resid.max()


# -------------------------------------------------------------- watchdog --


def test_straggler_monitor_flags_slow_steps():
    t = [0.0]

    def clock():
        return t[0]

    mon = StragglerMonitor(threshold=2.0, clock=clock)
    for dt in [1.0, 1.0, 1.0, 5.0, 1.0]:
        mon.start_step()
        t[0] += dt
        mon.end_step()
    assert mon.slow_steps == [3]
    assert 0 < mon.straggler_fraction < 0.5


def test_hang_watchdog_fires_and_disarms():
    import time

    fired = []
    wd = HangWatchdog(0.05, lambda: fired.append(1))
    wd.arm()
    time.sleep(0.15)
    assert fired
    wd2 = HangWatchdog(0.2, lambda: fired.append(2))
    with wd2:
        wd2.pet()
    time.sleep(0.3)
    assert 2 not in fired  # disarmed on exit


# ------------------------------------------------------------------ data --


def test_synthetic_data_deterministic_restart():
    d = SyntheticLMData(vocab_size=100, batch=4, seq_len=16, seed=7)
    a = d.batch_at(12)
    b = d.batch_at(12)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = d.batch_at(13)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # targets are next-token shifted
    full_a = d.batch_at(12)
    assert full_a["tokens"].shape == (4, 16)


def test_pack_documents_no_token_loss():
    docs = [[5, 6, 7], [8, 9], [10] * 7]
    toks, mask = pack_documents(docs, seq_len=8, eos_id=1)
    flat = toks[mask > 0]
    # all doc tokens present, in order, with EOS separators
    assert list(flat) == [5, 6, 7, 1, 8, 9, 1, 10, 10, 10, 10, 10, 10, 10, 1]


def test_prefetch_iterator_preserves_order_and_errors():
    it = PrefetchIterator(iter(range(10)), depth=3)
    assert list(it) == list(range(10))

    def boom():
        yield 1
        raise RuntimeError("boom")

    it2 = PrefetchIterator(boom())
    assert next(it2) == 1
    with pytest.raises(RuntimeError):
        next(it2)
        next(it2)


# -------------------------------------------- multi-device via subprocess --

MULTIDEV_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_mesh, use_mesh
    from repro.distributed.sharding import sharding_scope, constrain, named_sharding
    from repro.distributed.checkpoint import CheckpointManager

    assert jax.device_count() == 8, jax.device_count()

    # --- logical rules end-to-end: constrain inside jit on a (4,2) mesh ---
    mesh = make_mesh((4, 2), ("data", "model"))
    with use_mesh(mesh), sharding_scope(mesh):
        x = jnp.arange(8 * 6, dtype=jnp.float32).reshape(8, 6)

        @jax.jit
        def f(x):
            return constrain(x * 2, "batch", "mlp")

        y = f(x)
        spec = y.sharding.spec
        assert spec == P("data", "model"), spec

        # divisibility fallback: dim 6 not divisible by model=2? it is; use 7
        z = jnp.zeros((8, 7))
        @jax.jit
        def g(z):
            return constrain(z + 1, "batch", "mlp")
        spec2 = g(z).sharding.spec
        # replicated fallback: trailing None may be omitted from the spec
        assert len(spec2) < 2 or spec2[1] is None, spec2

        # --- sharded checkpoint save -> restore on a DIFFERENT mesh ---
        sh = named_sharding((8, 6), ("batch", "mlp"))
        big = jax.device_put(jnp.arange(48, dtype=jnp.float32).reshape(8, 6), sh)
        tree = {"w": big, "step": jnp.asarray(3)}
        mgr = CheckpointManager(sys.argv[1], keep=2)
        mgr.save(100, tree)
        assert mgr.latest_step() == 100

    mesh2 = make_mesh((2, 4), ("data", "model"))
    with use_mesh(mesh2), sharding_scope(mesh2):
        sh2 = {"w": named_sharding((8, 6), ("batch", None)),
               "step": named_sharding((), ())}
        target = {"w": jax.ShapeDtypeStruct((8, 6), jnp.float32),
                  "step": jax.ShapeDtypeStruct((), jnp.int32)}
        restored = mgr.restore(100, target, sh2)
        np.testing.assert_array_equal(
            np.asarray(restored["w"]), np.arange(48, dtype=np.float32).reshape(8, 6)
        )
        assert int(restored["step"]) == 3
        assert restored["w"].sharding.spec == P("data", None)

    # async save + retention
    with use_mesh(mesh2), sharding_scope(mesh2):
        mgr.save(101, tree, blocking=False)
        mgr.wait()
        mgr.save(102, tree)
        assert mgr.all_steps() == [101, 102]  # keep=2 pruned step 100
    print("MULTIDEV_OK")
    """
)


def test_multidevice_sharding_and_checkpoint(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath("src")
    proc = subprocess.run(
        [sys.executable, "-c", MULTIDEV_SCRIPT, str(tmp_path / "ckpt")],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MULTIDEV_OK" in proc.stdout


# ------------------------------------------------- torn checkpoint dirs --


def _ckpt_tree(v):
    return {"w": np.full((4, 3), float(v), np.float32),
            "step": np.asarray(v, np.int32)}


def _ckpt_target():
    return {"w": jax.ShapeDtypeStruct((4, 3), jnp.float32),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def test_checkpoint_skips_torn_dirs_even_when_newest(tmp_path):
    """A crash can leave a ``step_*`` dir without ``_COMMITTED``, or — if it
    raced the rename — with the marker but a torn manifest. Neither may
    shadow an older committed step."""
    from repro.distributed.checkpoint import CheckpointManager

    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, _ckpt_tree(1))
    mgr.save(2, _ckpt_tree(2))

    torn = tmp_path / "step_000000003"
    torn.mkdir()
    (torn / "leaf_00000_0000.npy").write_bytes(b"\x93NUMPY")
    assert mgr.all_steps() == [1, 2]

    torn2 = tmp_path / "step_000000004"
    torn2.mkdir()
    (torn2 / "manifest.json").write_text('{"step": 4, "leaves": [{"na')
    (torn2 / "_COMMITTED").write_text("ok")
    assert mgr.all_steps() == [1, 2] and mgr.latest_step() == 2

    step, tree = mgr.restore_latest(_ckpt_target())
    assert step == 2
    np.testing.assert_array_equal(np.asarray(tree["w"]), _ckpt_tree(2)["w"])


def test_checkpoint_restore_latest_falls_back_past_torn_shards(tmp_path):
    """A commit marker that raced the rename can cover missing shard files;
    ``restore_latest`` must fall back to the previous committed step instead
    of failing the restart."""
    from repro.distributed.checkpoint import CheckpointManager

    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, _ckpt_tree(1))
    mgr.save(2, _ckpt_tree(2))
    # step 2 looks committed but a payload file is gone
    os.remove(tmp_path / "step_000000002" / "leaf_00000_0000.npy")

    step, tree = mgr.restore_latest(_ckpt_target())
    assert step == 1
    np.testing.assert_array_equal(np.asarray(tree["w"]), _ckpt_tree(1)["w"])
    assert int(tree["step"]) == 1

    os.remove(tmp_path / "step_000000001" / "leaf_00000_0000.npy")
    with pytest.raises(FileNotFoundError):
        mgr.restore_latest(_ckpt_target())

"""EmbeddingService: microbatched serving + §2.2 cold-start propagation."""
import numpy as np
import pytest

from repro.core.kcore import core_numbers_host
from repro.core.propagation import propagate
from repro.graph import generators
from repro.graph.csr import Graph
from repro.serve import DynamicGraph, EmbeddingService, EmbeddingStore, IncrementalCore

DIM = 8


def _service_from(graph, emb_nodes, emb, *, batch=16, capacity=None, **kw):
    dyn = DynamicGraph(graph.n_nodes, graph.edge_list(), width=16)
    inc = IncrementalCore(dyn)
    store = EmbeddingStore(
        capacity=capacity or graph.n_nodes, dim=DIM, node_cap=dyn.node_cap
    )
    store.put_many(emb_nodes, emb[emb_nodes], inc.core[emb_nodes])
    return EmbeddingService(dyn, inc, store, batch=batch, **kw)


def test_cold_start_equals_propagate_on_clique_pendant():
    """One-shot neighbour mean == propagate() restricted to the queried node.

    K6 clique (core 5) + node 6 attached to three clique members (core 3):
    propagate's shell-3 system for node 6 has only fixed (k0-core) neighbours,
    so every Jacobi iterate equals the one-shot mean the service computes.
    """
    edges = [(i, j) for i in range(6) for j in range(i + 1, 6)] + [
        (6, 0), (6, 1), (6, 2)
    ]
    g = Graph.from_edges(7, np.array(edges))
    core = core_numbers_host(g)
    np.testing.assert_array_equal(core, [5] * 6 + [3])
    k0 = 5
    rng = np.random.default_rng(0)
    base = np.zeros((7, DIM), np.float32)
    base[:6] = rng.normal(size=(6, DIM)).astype(np.float32)

    want = propagate(g, core, k0, base, n_iters=17)

    svc = _service_from(g, np.arange(6), base)
    got = svc.embed([6])
    np.testing.assert_allclose(got[0], want[6], rtol=1e-5, atol=1e-6)
    assert svc.stats.cold_starts == 1 and svc.stats.unresolved == 0


def test_cold_start_equals_propagate_on_random_graph():
    """Same equivalence on a random graph, for every shell-(k0-1) node whose
    allowed neighbours are all inside the k0-core (no same-shell coupling)."""
    g = generators.barabasi_albert_varying(200, 5.0, seed=3)
    core = core_numbers_host(g)
    rng = np.random.default_rng(1)
    checked = 0
    for k0 in range(int(core.max()), 2, -1):
        fixed = core >= k0
        cands = [
            int(t)
            for t in np.where(core == k0 - 1)[0]
            if np.all(core[g.neighbours(t)] >= k0)
        ]
        if not cands:
            continue
        base = np.zeros((g.n_nodes, DIM), np.float32)
        base[fixed] = rng.normal(size=(int(fixed.sum()), DIM)).astype(np.float32)
        want = propagate(g, core, k0, base, n_iters=9)
        svc = _service_from(g, np.where(fixed)[0], base)
        got = svc.embed(cands)
        for i, t in enumerate(cands):
            np.testing.assert_allclose(got[i], want[t], rtol=1e-5, atol=1e-6)
        checked += len(cands)
    assert checked > 0, "graph/seed must yield at least one decoupled shell node"


def test_cold_start_sees_spilled_neighbours():
    """Neighbour embeddings evicted to host spill still feed the §2.2 mean."""
    edges = [(i, j) for i in range(6) for j in range(i + 1, 6)] + [
        (6, 0), (6, 1), (6, 2)
    ]
    g = Graph.from_edges(7, np.array(edges))
    rng = np.random.default_rng(7)
    base = np.zeros((7, DIM), np.float32)
    base[:6] = rng.normal(size=(6, DIM)).astype(np.float32)
    # capacity 4 < 6 embedded nodes: some of node 6's neighbours are spilled
    svc = _service_from(g, np.arange(6), base, capacity=4)
    assert svc.store.spilled > 0
    got = svc.embed([6])
    np.testing.assert_allclose(got[0], base[:3].mean(axis=0), rtol=1e-5, atol=1e-6)
    assert svc.stats.unresolved == 0


def test_working_set_beyond_capacity_is_served_from_spill():
    """Querying more stored nodes than the device table holds must serve the
    spill-tier rows correctly — never zeros, never cold-start overwrites."""
    g = generators.barabasi_albert(30, 2, seed=9)
    rng = np.random.default_rng(8)
    emb = rng.normal(size=(30, DIM)).astype(np.float32)
    svc = _service_from(g, np.arange(30), emb, capacity=2, batch=8)
    out = svc.embed(list(range(30)))  # working set 4x the table capacity
    for v in range(30):
        np.testing.assert_allclose(out[v], emb[v], rtol=1e-6)
    assert svc.stats.cold_starts == 0  # every row was a store hit
    # nothing got overwritten by a cold-start write-back
    out2 = svc.embed(list(range(30)))
    np.testing.assert_allclose(out2, out, rtol=1e-6)


def test_static_batches_pad_and_preserve_order():
    g = generators.barabasi_albert(60, 3, seed=4)
    rng = np.random.default_rng(2)
    emb = rng.normal(size=(60, DIM)).astype(np.float32)
    svc = _service_from(g, np.arange(60), emb, batch=16)
    nodes = [5, 3, 41, 17, 3]  # shorter than batch; duplicates allowed
    out = svc.embed(nodes)
    assert out.shape == (5, DIM)
    for i, v in enumerate(nodes):
        np.testing.assert_allclose(out[i], emb[v], rtol=1e-6)
    assert svc.stats.queries == 5  # padding slots are not counted
    assert svc.stats.flushes == 1


def test_write_back_turns_cold_into_hit():
    g = generators.barabasi_albert(40, 3, seed=5)
    rng = np.random.default_rng(3)
    emb = rng.normal(size=(40, DIM)).astype(np.float32)
    known = np.arange(39)  # node 39 is cold
    svc = _service_from(g, known, emb, write_back=True)
    svc.embed([39])
    assert svc.stats.cold_starts == 1
    svc.embed([39])
    assert svc.stats.cold_starts == 1  # second hit comes from the store
    assert svc.stats.store_hits == 1
    # write-back stamped the node's current core level for staleness tracking
    assert 39 in svc.store
    assert svc.store.staleness(svc.cores.core) == 0.0


def test_isolated_cold_node_is_unresolved_zero():
    g = Graph.from_edges(4, np.array([[0, 1], [1, 2]]))
    rng = np.random.default_rng(4)
    emb = rng.normal(size=(4, DIM)).astype(np.float32)
    svc = _service_from(g, np.array([0, 1, 2]), emb)
    out = svc.embed([3])  # node 3 has no edges at all
    np.testing.assert_allclose(out[0], 0.0)
    assert svc.stats.unresolved == 1


def test_link_scores_are_cosines():
    """Scores are cosine (matching the retrain-eval AUC ranking), and a
    self-pair scores exactly 1 regardless of the embedding's norm."""
    g = generators.barabasi_albert(30, 2, seed=6)
    rng = np.random.default_rng(5)
    emb = rng.normal(size=(30, DIM)).astype(np.float32)
    svc = _service_from(g, np.arange(30), emb)
    pairs = np.array([[0, 1], [5, 9], [2, 2]])
    got = svc.link_scores(pairs)

    def cos(u, v):
        a, b = emb[u], emb[v]
        return a @ b / (np.linalg.norm(a) * np.linalg.norm(b))

    want = np.array([cos(u, v) for u, v in pairs])
    np.testing.assert_allclose(got, want, rtol=1e-5)
    np.testing.assert_allclose(got[2], 1.0, rtol=1e-6)


def test_link_scores_dedup_endpoints():
    """A pair list with few distinct endpoints flushes each node once —
    duplicate cold endpoints must not inflate the cold-start count."""
    g = generators.barabasi_albert(30, 2, seed=6)
    rng = np.random.default_rng(5)
    emb = rng.normal(size=(30, DIM)).astype(np.float32)
    svc = _service_from(g, np.arange(29), emb)  # node 29 is cold
    pairs = np.array([[29, 0], [29, 1], [0, 29], [29, 29]])
    svc.link_scores(pairs)
    assert svc.stats.cold_starts == 1
    assert svc.stats.queries == 3  # 29, 0, 1 — one flush slot each


def test_duplicate_cold_nodes_in_one_batch_count_once():
    """Regression: duplicates of one cold id inside a single padded batch
    must share one write-back slot and count as one cold start."""
    g = generators.barabasi_albert(40, 3, seed=5)
    rng = np.random.default_rng(3)
    emb = rng.normal(size=(40, DIM)).astype(np.float32)
    svc = _service_from(g, np.arange(39), emb, batch=16)
    free_before = svc.store.capacity - svc.store.resident - svc.store.spilled
    out = svc.embed([39, 39, 5, 39])
    assert svc.stats.cold_starts == 1
    np.testing.assert_allclose(out[0], out[1])
    np.testing.assert_allclose(out[0], out[3])
    # exactly one slot was consumed by the write-back, not three
    free_after = svc.store.capacity - svc.store.resident - svc.store.spilled
    assert free_before - free_after == 1
    svc.embed([39])
    assert svc.stats.cold_starts == 1  # resident now


def test_graph_growth_between_submit_and_flush():
    """Regression: flush() padding must survive node_cap growth. Queries
    enqueued before ingest_edges mints new ids (growing the sentinel) must
    still resolve — a padding value snapshotted from the old node_cap could
    alias a freshly minted real node."""
    g = generators.barabasi_albert(30, 2, seed=12)
    rng = np.random.default_rng(15)
    emb = rng.normal(size=(30, DIM)).astype(np.float32)
    svc = _service_from(g, np.arange(30), emb, batch=8)
    svc.submit_many([3, 7, 11])  # short batch -> 5 padding lanes
    cap_before = svc.graph.node_cap
    # grow the graph past its node capacity so the sentinel moves
    new_edges = [(30 + i, int(rng.integers(0, 30))) for i in range(40)]
    svc.ingest_edges(new_edges)
    assert svc.graph.node_cap > cap_before
    out = svc.flush()
    assert out.shape == (3, DIM)
    for i, v in enumerate([3, 7, 11]):
        np.testing.assert_allclose(out[i], emb[v], rtol=1e-6)
    assert svc.stats.queries == 3  # padding lanes never counted


def test_top_k_neighbors_matches_oracle():
    g = generators.barabasi_albert(40, 3, seed=5)
    rng = np.random.default_rng(3)
    emb = rng.normal(size=(40, DIM)).astype(np.float32)
    svc = _service_from(g, np.arange(40), emb)
    q = [0, 7, 13]
    ids, scores = svc.top_k_neighbors(q, 5)
    assert ids.shape == (3, 5) and scores.shape == (3, 5)
    en = emb / np.maximum(
        np.linalg.norm(emb, axis=1, keepdims=True), 1e-9
    )
    sim = en @ en.T
    for qi, v in enumerate(q):
        s = sim[v].copy()
        s[v] = -np.inf  # self-exclusion
        want = np.lexsort((np.arange(40), -s))[:5]
        slots = svc.store.slots_of(ids[qi])
        np.testing.assert_array_equal(np.sort(slots), np.sort(
            svc.store.slots_of(want)
        ))
        np.testing.assert_allclose(
            np.sort(scores[qi]), np.sort(s[want]), rtol=1e-5
        )
        assert v not in ids[qi]
        # descending score order
        assert np.all(np.diff(scores[qi]) <= 1e-7)


def test_top_k_neighbors_pads_when_few_candidates():
    g = generators.barabasi_albert(10, 2, seed=11)
    rng = np.random.default_rng(16)
    emb = rng.normal(size=(10, DIM)).astype(np.float32)
    svc = _service_from(g, np.arange(3), emb, capacity=10)
    ids, scores = svc.top_k_neighbors([0], 6)
    # only nodes 1, 2 are candidates (0 excludes itself)
    assert set(ids[0][ids[0] >= 0]) == {1, 2}
    np.testing.assert_array_equal(ids[0][2:], -1)
    assert np.all(scores[0][2:] == -np.inf)
    # empty / degenerate shapes
    i0, s0 = svc.top_k_neighbors([], 4)
    assert i0.shape == (0, 4) and s0.shape == (0, 4)
    i1, s1 = svc.top_k_neighbors([1], 0)
    assert i1.shape == (1, 0)
    assert svc.stats.topk_queries == 1


def test_ingest_compacts_and_stays_exact():
    g = generators.barabasi_albert_varying(120, 4.0, seed=7)
    edges = g.edge_list()
    half = len(edges) // 2
    dyn = DynamicGraph(g.n_nodes, edges[:half], width=4)
    inc = IncrementalCore(dyn)
    store = EmbeddingStore(capacity=g.n_nodes, dim=DIM, node_cap=dyn.node_cap)
    svc = EmbeddingService(dyn, inc, store, batch=8, compact_every=64)
    n = svc.ingest_edges(edges[half:])
    assert n == len(edges) - half
    assert svc.stats.compactions >= 1
    oracle = core_numbers_host(dyn.snapshot())
    np.testing.assert_array_equal(inc.core, oracle)


def test_submit_many_matches_per_node_submits():
    g = generators.barabasi_albert(40, 3, seed=10)
    rng = np.random.default_rng(9)
    emb = rng.normal(size=(40, DIM)).astype(np.float32)
    svc_a = _service_from(g, np.arange(40), emb, batch=16)
    svc_b = _service_from(g, np.arange(40), emb, batch=16)
    nodes = [5, 3, 3, 17, 39, 0, 12]
    idx = svc_a.submit_many(nodes)
    np.testing.assert_array_equal(idx, np.arange(len(nodes)))
    assert svc_a.pending == len(nodes)
    for n in nodes:
        svc_b.submit(n)
    out_a, out_b = svc_a.flush(), svc_b.flush()
    np.testing.assert_allclose(out_a, out_b, rtol=1e-6)
    assert svc_a.pending == 0
    # indices keep accumulating across mixed submit/submit_many calls
    assert svc_a.submit(2) == 0
    np.testing.assert_array_equal(svc_a.submit_many([4, 6]), [1, 2])
    assert svc_a.flush().shape == (3, DIM)


def test_submit_many_rejects_negative_ids_and_accepts_empty():
    g = generators.barabasi_albert(10, 2, seed=11)
    emb = np.zeros((10, DIM), np.float32)
    svc = _service_from(g, np.arange(10), emb)
    with pytest.raises(ValueError):
        svc.submit_many([1, -2, 3])
    assert svc.pending == 0  # the failed batch queued nothing
    assert svc.submit_many([]).size == 0
    assert svc.embed([]).shape == (0, DIM)


def test_retrain_pressure_rises_with_membership_churn():
    g = generators.barabasi_albert(80, 3, seed=8)
    rng = np.random.default_rng(6)
    emb = rng.normal(size=(80, DIM)).astype(np.float32)
    core = core_numbers_host(g)
    k0 = int(core.max())
    svc = _service_from(g, np.arange(80), emb, k0=k0, retrain_threshold=0.01)
    svc.cores.mark_refresh()
    assert svc.retrain_pressure() == 0.0
    # wire low-core nodes into a dense pocket to push them into the k0-core
    low = np.argsort(core)[:10]
    with pytest.raises(AssertionError):
        np.testing.assert_array_equal(core[low], k0)  # genuinely below k0
    for i in range(len(low)):
        for j in range(i + 1, len(low)):
            svc.ingest(int(low[i]), int(low[j]))
    assert svc.retrain_pressure() > 0.0
    assert svc.should_retrain()

"""Chunked LM loss == direct softmax cross-entropy; mask semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.transformer import init_model, lm_loss, logits_from_hidden


def _setup(chunk):
    cfg = get_config("qwen3-4b").reduced(loss_chunk=chunk)
    params = init_model(jax.random.PRNGKey(0), cfg)
    B, S = 2, 64
    hidden = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.3
    targets = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    return cfg, params, hidden, targets


def _direct(params, hidden, targets, mask, cfg):
    logits = logits_from_hidden(params, hidden, cfg)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return float(((lse - gold) * mask).sum() / mask.sum())


def test_chunked_equals_direct():
    cfg, params, hidden, targets = _setup(chunk=16)
    mask = jnp.ones_like(targets, jnp.float32)
    got = float(lm_loss(params, hidden, targets, mask, cfg))
    want = _direct(params, hidden, targets, mask, cfg)
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_chunk_size_does_not_matter():
    cfg, params, hidden, targets = _setup(chunk=16)
    mask = jnp.ones_like(targets, jnp.float32)
    a = float(lm_loss(params, hidden, targets, mask, cfg))
    cfg64 = dataclasses.replace(cfg, loss_chunk=64)
    b = float(lm_loss(params, hidden, targets, mask, cfg64))
    np.testing.assert_allclose(a, b, rtol=1e-6)


def test_mask_excludes_positions():
    cfg, params, hidden, targets = _setup(chunk=16)
    mask = jnp.ones_like(targets, jnp.float32).at[:, ::2].set(0.0)
    got = float(lm_loss(params, hidden, targets, mask, cfg))
    want = _direct(params, hidden, targets, mask, cfg)
    np.testing.assert_allclose(got, want, rtol=1e-5)
    # poisoning masked targets must not change the loss
    poisoned = targets.at[:, ::2].set(0)
    got2 = float(lm_loss(params, hidden, poisoned, mask, cfg))
    np.testing.assert_allclose(got, got2, rtol=1e-6)


def test_gradients_flow_through_chunked_loss():
    cfg, params, hidden, targets = _setup(chunk=16)
    mask = jnp.ones_like(targets, jnp.float32)
    g = jax.grad(lambda h: lm_loss(params, h, targets, mask, cfg))(hidden)
    assert np.isfinite(np.asarray(g)).all()
    assert float(jnp.abs(g).max()) > 0

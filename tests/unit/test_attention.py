"""Attention paths: chunked online-softmax vs full-scores reference, across
GQA/windows/softcaps; decode-vs-full consistency; RoPE properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.attention import attention_chunked, attention_reference
from repro.models.layers import apply_mrope, apply_rope, rope_frequencies


def _qkv(B, Sq, Sk, H, Hkv, Dh, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, Dh)) * 0.5
    k = jax.random.normal(ks[1], (B, Sk, Hkv, Dh)) * 0.5
    v = jax.random.normal(ks[2], (B, Sk, Hkv, Dh)) * 0.5
    return q, k, v


@pytest.mark.parametrize("H,Hkv", [(4, 4), (8, 2), (6, 2)])
@pytest.mark.parametrize("causal", [True, False])
def test_chunked_matches_reference(H, Hkv, causal):
    q, k, v = _qkv(2, 128, 128, H, Hkv, 32)
    ref = attention_reference(q, k, v, causal=causal)
    got = attention_chunked(q, k, v, causal=causal, chunk_q=32, chunk_kv=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window,softcap", [(16, 0.0), (0, 20.0), (32, 50.0)])
def test_chunked_variants_match_reference(window, softcap):
    q, k, v = _qkv(1, 96, 96, 4, 2, 32, seed=1)
    ref = attention_reference(q, k, v, causal=True, window=window, softcap=softcap)
    got = attention_chunked(
        q, k, v, causal=True, window=window, softcap=softcap, chunk_q=32, chunk_kv=32
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_chunked_gradients_match_reference():
    q, k, v = _qkv(1, 64, 64, 4, 4, 16, seed=2)

    def loss_ref(q, k, v):
        return attention_reference(q, k, v, causal=True).sum()

    def loss_chunk(q, k, v):
        return attention_chunked(q, k, v, causal=True, chunk_q=16, chunk_kv=32).sum()

    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    g_chk = jax.grad(loss_chunk, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_ref, g_chk):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=1e-4, atol=1e-4)


def test_dynamic_window_equals_static():
    q, k, v = _qkv(1, 64, 64, 4, 2, 16, seed=3)
    stat = attention_reference(q, k, v, causal=True, window=16)
    dyn = attention_reference(q, k, v, causal=True, window=jnp.asarray(16))
    np.testing.assert_allclose(np.asarray(dyn), np.asarray(stat), rtol=1e-6)
    off = attention_reference(q, k, v, causal=True, window=jnp.asarray(0))
    full = attention_reference(q, k, v, causal=True, window=0)
    np.testing.assert_allclose(np.asarray(off), np.asarray(full), rtol=1e-6)


# ----------------------------------------------------------------- rope ----


def test_rope_preserves_norm_and_relativity():
    cfg = get_config("qwen3-4b").reduced()
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, cfg.head_dim))
    pos = jnp.arange(8)[None]
    y = apply_rope(x, pos, cfg)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1),
        rtol=1e-5,
    )
    # relative property: <R(p)q, R(p+d)k> depends only on d
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, cfg.head_dim))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, cfg.head_dim))
    def dot_at(p):
        qp = apply_rope(q, jnp.array([[p]]), cfg)
        kp = apply_rope(k, jnp.array([[p + 3]]), cfg)
        return float(jnp.sum(qp * kp))
    assert abs(dot_at(0) - dot_at(17)) < 1e-4


def test_partial_rope_leaves_tail_unrotated():
    cfg = get_config("nemotron-4-15b").reduced()
    assert cfg.rope_fraction == 0.5
    _, rot = rope_frequencies(cfg.head_dim, cfg.rope_fraction, cfg.rope_theta)
    assert rot == cfg.head_dim // 2
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 1, cfg.head_dim))
    y = apply_rope(x, jnp.arange(4)[None], cfg)
    np.testing.assert_allclose(
        np.asarray(y[..., rot:]), np.asarray(x[..., rot:]), rtol=1e-6
    )


def test_mrope_matches_rope_when_positions_agree():
    cfg = get_config("qwen2-vl-7b").reduced()
    S = 6
    x = jax.random.normal(jax.random.PRNGKey(0), (1, S, 2, cfg.head_dim))
    pos = jnp.arange(S)[None]
    pos3 = jnp.broadcast_to(pos[None], (3, 1, S))
    a = apply_mrope(x, pos3, cfg)
    b = apply_rope(x, pos, cfg)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)

"""Retraining subsystem: planner, Procrustes aligner, rollout, full loop."""
import numpy as np
import pytest

from repro.core.kcore import core_numbers_host, degeneracy
from repro.graph import generators
from repro.serve import (
    DynamicGraph,
    EmbeddingAligner,
    EmbeddingService,
    EmbeddingStore,
    IncrementalCore,
    RetrainConfig,
    RetrainPlanner,
    Retrainer,
    VersionRollout,
    procrustes_rotation,
)
from repro.skipgram.trainer import SGNSConfig

DIM = 12


def _random_rotation(dim, rng):
    q, r = np.linalg.qr(rng.normal(size=(dim, dim)))
    return (q * np.sign(np.diag(r))).astype(np.float32)


def _service(n=120, seed=0, k0=None, **kw):
    g = generators.barabasi_albert_varying(n, 4.0, seed=seed)
    dyn = DynamicGraph(g.n_nodes, g.edge_list(), width=16)
    inc = IncrementalCore(dyn)
    store = EmbeddingStore(capacity=dyn.node_cap, dim=DIM,
                           node_cap=dyn.node_cap)
    rng = np.random.default_rng(seed)
    emb = rng.normal(size=(g.n_nodes, DIM)).astype(np.float32)
    served = np.where(g.degrees() > 0)[0]
    store.put_many(served, emb[served], inc.core[served])
    if k0 is None:
        k0 = max(2, degeneracy(inc.core) // 2)
    svc = EmbeddingService(dyn, inc, store, batch=16, k0=k0, **kw)
    inc.mark_refresh()
    return svc, g, emb


def _tiny_cfg(**kw):
    kw.setdefault("n_walks", 3)
    kw.setdefault("walk_length", 8)
    kw.setdefault("min_sgns_steps", 5)
    kw.setdefault("prop_iters", 4)
    kw.setdefault("sgns", SGNSConfig(dim=DIM, epochs=0.05, impl="ref"))
    return RetrainConfig(**kw)


def _force_drift(svc, n_wire=8):
    """Wire low-core nodes into a dense pocket to flip k0-core membership."""
    core = svc.cores.core
    low = np.argsort(core)[:n_wire]
    assert (core[low] < svc.k0).any()
    edges = [(int(low[i]), int(low[j]))
             for i in range(n_wire) for j in range(i + 1, n_wire)]
    svc.ingest_block(np.asarray(edges, np.int64))
    svc.sync()  # land the pipelined repair + deferred auto-retrain tail


# ------------------------------------------------------------- procrustes


def test_procrustes_recovers_a_planted_rotation():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(40, DIM)).astype(np.float32)
    R0 = _random_rotation(DIM, rng)
    R = procrustes_rotation(X, X @ R0)
    np.testing.assert_allclose(R, R0, atol=1e-4)
    np.testing.assert_allclose(R @ R.T, np.eye(DIM), atol=1e-5)


def test_procrustes_is_orthogonal_even_for_unrelated_clouds():
    rng = np.random.default_rng(1)
    X = rng.normal(size=(25, DIM)).astype(np.float32)
    Y = rng.normal(size=(25, DIM)).astype(np.float32)
    R = procrustes_rotation(X, Y)
    np.testing.assert_allclose(R @ R.T, np.eye(DIM), atol=1e-5)
    # applying R preserves norms and pairwise dot products of ANY table
    A = rng.normal(size=(30, DIM)).astype(np.float32)
    np.testing.assert_allclose(
        np.linalg.norm(A @ R, axis=1), np.linalg.norm(A, axis=1), rtol=1e-4
    )
    np.testing.assert_allclose((A @ R) @ (A @ R).T, A @ A.T, atol=1e-3)


def test_aligner_identity_below_min_anchors():
    rng = np.random.default_rng(2)
    emb = rng.normal(size=(10, DIM)).astype(np.float32)
    aligner = EmbeddingAligner(min_anchors=8)
    out, rep = aligner.align(emb, emb[:3], np.arange(3))
    assert not rep["aligned"] and rep["anchors"] == 3
    np.testing.assert_array_equal(out, emb)


def test_aligner_maps_back_into_old_space():
    rng = np.random.default_rng(3)
    old = rng.normal(size=(50, DIM)).astype(np.float32)
    R0 = _random_rotation(DIM, rng)
    new = old @ R0.T  # the fresh run landed in a rotated copy of the space
    aligner = EmbeddingAligner(min_anchors=8)
    anchors = np.arange(0, 50, 2)
    out, rep = aligner.align(new, old[anchors], anchors)
    assert rep["aligned"] and rep["residual"] < 1e-4
    np.testing.assert_allclose(out, old, atol=1e-3)


# ---------------------------------------------------------------- planner


def test_planner_snapshots_exact_drifted_core():
    svc, _, _ = _service(seed=4)
    _force_drift(svc)
    plan = RetrainPlanner(svc.graph, svc.cores, svc.k0).plan()
    oracle = core_numbers_host(plan.snapshot)
    np.testing.assert_array_equal(plan.core, oracle)
    np.testing.assert_array_equal(plan.nodes, np.where(oracle >= plan.k0)[0])
    assert plan.drifted > 0  # the pocket flipped membership
    # the subgraph is induced on the k0-core with original ids
    in_core = oracle >= plan.k0
    deg_sub = plan.sub.degrees()
    assert (deg_sub[~in_core] == 0).all()
    assert deg_sub[in_core].min() >= plan.k0


def test_planner_clamps_k0_to_current_degeneracy():
    svc, _, _ = _service(seed=5)
    kdeg = degeneracy(svc.cores.core)
    plan = RetrainPlanner(svc.graph, svc.cores, kdeg + 10).plan()
    assert plan.k0 == kdeg
    assert len(plan.nodes) > 0


# ---------------------------------------------------------------- rollout


def test_rollout_chunked_swap_interleaves_and_tags_versions():
    store = EmbeddingStore(capacity=16, dim=DIM, node_cap=32)
    rng = np.random.default_rng(6)
    old = rng.normal(size=(8, DIM)).astype(np.float32)
    store.put_many(np.arange(8), old, np.ones(8))
    assert store.version_counts() == {0: 8}

    new = rng.normal(size=(6, DIM)).astype(np.float32)
    rollout = VersionRollout(store, chunk=2)
    rollout.stage(np.arange(6), new, np.full(6, 2))
    calls = []
    rep = rollout.commit(between=lambda: calls.append(store.version))
    assert rep["version"] == 1 and rep["rows"] == 6 and rep["chunks"] == 3
    assert len(calls) == 3  # serving yielded between every chunk
    # per-node version reconciliation: swapped rows new, the rest old
    assert store.version_counts() == {0: 2, 1: 6}
    vecs, found = store.gather(np.arange(8))
    assert found.all()
    np.testing.assert_allclose(np.asarray(vecs)[:6], new, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(vecs)[6:], old[6:], rtol=1e-6)


def test_rollout_requires_staging():
    store = EmbeddingStore(capacity=4, dim=DIM, node_cap=8)
    with pytest.raises(RuntimeError):
        VersionRollout(store).commit()


# ------------------------------------------------------------- full loop


def test_maybe_retrain_gates_on_threshold_and_budget():
    svc, _, _ = _service(seed=7, retrain_threshold=0.9)
    svc.set_retrainer(Retrainer(svc, _tiny_cfg()), budget=1)
    assert svc.maybe_retrain() is None  # pressure 0 < 0.9
    assert svc.maybe_retrain(force=True) is not None
    assert svc.stats.retrains == 1
    assert svc.maybe_retrain(force=True) is None  # budget spent
    assert svc.stats.retrains == 1


@pytest.mark.slow
def test_drift_triggered_retrain_hot_swap_end_to_end():
    """The CI smoke: forced drift -> auto retrain -> aligned hot swap, with
    cores oracle-exact and staleness back to ~0 afterwards."""
    svc, _, _ = _service(seed=8, retrain_threshold=0.02)
    svc.set_retrainer(Retrainer(svc, _tiny_cfg()), auto=True, budget=1)
    v0 = svc.store.version
    _force_drift(svc)  # auto mode retrains inside ingest_block
    assert svc.stats.retrains == 1
    assert svc.stats.last_swap_version == svc.store.version == v0 + 1
    rep_pressure = svc.retrain_pressure()
    assert rep_pressure < svc.retrain_threshold  # baseline was reset
    assert svc.store.staleness(svc.cores.core) == 0.0
    assert svc.cores.resync() == 0  # maintained cores still oracle-exact
    out = svc.embed(list(range(20)))
    assert np.isfinite(out).all()
    # swapped rows carry the new version; spill/untouched rows may keep old
    counts = svc.store.version_counts()
    assert counts.get(v0 + 1, 0) > 0


def test_retrain_warm_start_and_anchor_accounting():
    svc, _, _ = _service(seed=9)
    _force_drift(svc)
    rep = Retrainer(svc, _tiny_cfg()).run()
    assert rep is not None
    assert rep.core_size == len(np.where(svc.cores.core >= rep.k0)[0])
    assert rep.warm_rows > 0  # persisted nodes seeded emb_in
    assert rep.anchors >= 8 and rep.aligned
    assert rep.rows_swapped >= rep.core_size  # propagation covers shells
    assert rep.staleness_after == 0.0
    assert rep.times["total"] > 0


def test_retrain_without_alignment_or_propagation():
    svc, _, _ = _service(seed=10)
    _force_drift(svc)
    cfg = _tiny_cfg(align=False, propagate=False)
    rep = Retrainer(svc, cfg).run()
    assert rep is not None and not rep.aligned
    assert rep.rows_swapped == rep.core_size  # only the subcore was written

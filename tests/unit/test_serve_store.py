"""EmbeddingStore: LRU eviction, host spillover, versioning, staleness."""
import numpy as np

from repro.serve import EmbeddingStore


def _vec(i, dim=8):
    return np.full(dim, float(i), np.float32)


def test_put_gather_roundtrip():
    st = EmbeddingStore(capacity=8, dim=8, node_cap=32)
    nodes = np.array([3, 7, 11])
    st.put_many(nodes, np.stack([_vec(i) for i in nodes]), np.array([2, 2, 3]))
    vecs, found = st.gather(np.array([7, 5, 11]))
    assert found.tolist() == [True, False, True]
    np.testing.assert_allclose(np.asarray(vecs[0]), _vec(7))
    np.testing.assert_allclose(np.asarray(vecs[1]), 0.0)  # miss -> zero sentinel
    np.testing.assert_allclose(np.asarray(vecs[2]), _vec(11))


def test_lru_eviction_spills_and_promotes_back():
    st = EmbeddingStore(capacity=3, dim=8, node_cap=16)
    for i in range(3):
        st.put(i, _vec(i), core=1)
    st.gather(np.array([0, 2]))  # touch 0 and 2 -> node 1 is LRU
    st.put(5, _vec(5), core=1)  # forces eviction
    assert st.evictions == 1
    assert st.spilled == 1
    assert 1 in st  # spilled, not lost
    assert st.slots_of(np.array([1]))[0] == st.capacity  # not resident
    # gather transparently promotes the spilled row (evicting another LRU)
    vecs, found = st.gather(np.array([1]))
    assert found[0]
    np.testing.assert_allclose(np.asarray(vecs[0]), _vec(1))
    assert st.slots_of(np.array([1]))[0] < st.capacity
    assert st.evictions == 2


def test_versioning_tracks_refresh_generations():
    st = EmbeddingStore(capacity=8, dim=8, node_cap=16)
    st.put_many(np.arange(4), np.stack([_vec(i) for i in range(4)]), np.ones(4))
    st.bump_version()
    st.put_many(np.array([4, 5]), np.stack([_vec(4), _vec(5)]), np.ones(2))
    counts = st.version_counts()
    assert counts == {0: 4, 1: 2}
    # overwriting an old row moves it to the current version
    st.put(0, _vec(100), core=1)
    assert st.version_counts() == {0: 3, 1: 3}
    # promotion after eviction preserves the row's original write version
    st2 = EmbeddingStore(capacity=2, dim=8, node_cap=8)
    st2.put(0, _vec(0), core=1)
    st2.bump_version()
    st2.put(1, _vec(1), core=1)
    st2.put(2, _vec(2), core=1)  # evicts node 0 (version 0)
    st2.gather(np.array([0]))  # promote back
    assert st2.version_counts().get(0) == 1


def test_staleness_follows_core_drift():
    st = EmbeddingStore(capacity=8, dim=8, node_cap=16)
    cores = np.array([1, 2, 3, 4])
    st.put_many(np.arange(4), np.stack([_vec(i) for i in range(4)]), cores)
    now = cores.copy()
    assert st.staleness(now) == 0.0
    now[0] += 1  # one of four rows drifted a level
    assert st.staleness(now) == 0.25
    assert st.staleness(now + 1) == 1.0


def test_gather_promotion_never_evicts_batch_residents():
    """Promoting a spilled row must not evict a node requested in the same
    batch (it would be misreported as a miss and served as cold)."""
    st = EmbeddingStore(capacity=2, dim=8, node_cap=8)
    st.put(0, _vec(0), core=1)
    st.put(1, _vec(1), core=1)
    st.put(2, _vec(2), core=1)  # evicts node 0 (LRU) to spill
    assert st.spilled == 1 and 0 in st
    # node 1 is now LRU among residents {1, 2}; requesting [1, 0] promotes 0,
    # which must evict 2 (unrequested), not 1
    vecs, found = st.gather(np.array([1, 0]))
    assert found.tolist() == [True, True]
    np.testing.assert_allclose(np.asarray(vecs[0]), _vec(1))
    np.testing.assert_allclose(np.asarray(vecs[1]), _vec(0))


def test_batch_put_larger_than_capacity_spills_true_values():
    """Evictions triggered mid-batch must spill the values written earlier in
    the same batch (the device scatter is deferred), not stale table rows."""
    st = EmbeddingStore(capacity=4, dim=8, node_cap=16)
    st.put_many(np.arange(6), np.stack([_vec(i) for i in range(6)]), np.ones(6))
    assert st.spilled == 2
    spilled = sorted(n for n in range(6) if st.slots_of(np.array([n]))[0] == 4)
    vecs, found = st.gather(np.array(spilled))  # promotes the pair back
    assert found.all()
    for i, n in enumerate(spilled):
        np.testing.assert_allclose(np.asarray(vecs[i]), _vec(n))


def test_node_map_grows_geometrically():
    st = EmbeddingStore(capacity=4, dim=8, node_cap=16)
    st.put(16, _vec(1), core=1)  # one id past the map
    assert st.node_cap >= 24  # grew by >= 1.5x, not to exactly 17


def test_overwrite_does_not_leak_slots():
    st = EmbeddingStore(capacity=4, dim=8, node_cap=8)
    for _ in range(5):
        st.put(2, _vec(2), core=1)
    assert st.resident == 1
    assert st.evictions == 0


def test_gather_serves_rows_bounced_back_to_spill_after_growth():
    """Regression: promote/ensure_nodes interaction under slot pressure.

    After ``ensure_nodes`` growth admits more ids than the table has slots,
    a row promoted from spill early in a ``gather`` can be bounced straight
    back to spill by a *later* promotion in the same request — its
    ``_slot_of`` entry is left at the sentinel, and ``gather`` used to
    misreport the node as absent (found=False, zero vector) even though the
    store still holds it. The spill-tier overlay must serve it instead.
    """
    st = EmbeddingStore(capacity=2, dim=8, node_cap=3)
    st.put_many(np.array([1, 2, 7]), np.stack([_vec(n) for n in (1, 2, 7)]),
                np.ones(3))  # grows node_cap 3 -> >= 8
    st.put_many(np.array([4, 5, 8]), np.stack([_vec(n) for n in (4, 5, 8)]),
                np.ones(3))  # grows again; most rows now live in spill
    assert st.node_cap >= 9 and st.spilled == 4
    # request three held nodes through a two-slot table: promotions must
    # bounce at least one of them, and every row must still be served
    vecs, found = st.gather(np.array([5, 8, 7]))
    assert found.tolist() == [True, True, True]
    vecs = np.asarray(vecs)
    for i, n in enumerate((5, 8, 7)):
        np.testing.assert_allclose(vecs[i], _vec(n))
    # nothing was lost either way: every written node is still in a tier
    for n in (1, 2, 4, 5, 7, 8):
        assert n in st


def test_mixed_version_rows_served_from_one_gather():
    """bump_version + partial put_many: one gather serves old and new rows
    side by side, and staleness/version_counts stay correct."""
    st = EmbeddingStore(capacity=8, dim=8, node_cap=16)
    cores0 = np.array([1, 2, 3, 4])
    st.put_many(np.arange(4), np.stack([_vec(i) for i in range(4)]), cores0)
    st.bump_version()
    # refresh only rows 0 and 1 (new values, new cores) — a partial rollout
    st.put_many(np.array([0, 1]), np.stack([_vec(10), _vec(11)]),
                np.array([5, 6]))
    assert st.version_counts() == {0: 2, 1: 2}
    vecs, found = st.gather(np.arange(4))
    assert found.all()
    vecs = np.asarray(vecs)
    np.testing.assert_allclose(vecs[0], _vec(10))
    np.testing.assert_allclose(vecs[1], _vec(11))
    np.testing.assert_allclose(vecs[2], _vec(2))  # old version, old value
    np.testing.assert_allclose(vecs[3], _vec(3))
    # staleness tracks per-row write-time cores across the version mixture
    now = np.array([5, 6, 3, 4])
    assert st.staleness(now) == 0.0
    now_drift = np.array([5, 6, 9, 4])  # only an old-version row drifted
    assert st.staleness(now_drift) == 0.25


def test_mixed_version_survives_eviction_and_promotion():
    """Version tags ride along through spill and promotion, so a partial
    rollout stays reconcilable under capacity pressure."""
    st = EmbeddingStore(capacity=2, dim=8, node_cap=8)
    st.put(0, _vec(0), core=1)
    st.bump_version()
    st.put(1, _vec(1), core=1)
    st.put(2, _vec(2), core=1)  # evicts node 0 (version-0 row) to spill
    assert st.version_counts() == {1: 2}
    vecs, found = st.gather(np.array([0]))  # promotes the version-0 row back
    assert found[0]
    np.testing.assert_allclose(np.asarray(vecs)[0], _vec(0))
    assert st.version_counts().get(0) == 1  # original tag preserved


def test_peek_many_reads_both_tiers_without_side_effects():
    st = EmbeddingStore(capacity=2, dim=8, node_cap=8)
    st.put(0, _vec(0), core=3)
    st.bump_version()
    st.put(1, _vec(1), core=4)
    st.put(2, _vec(2), core=5)  # evicts node 0 to spill
    evictions, clock = st.evictions, st._clock
    spilled = st.spilled
    vecs, found, vers, cores = st.peek_many(np.array([0, 1, 2, 7]))
    assert found.tolist() == [True, True, True, False]
    np.testing.assert_allclose(vecs[0], _vec(0))  # served from spill
    np.testing.assert_allclose(vecs[1], _vec(1))
    np.testing.assert_allclose(vecs[2], _vec(2))
    np.testing.assert_allclose(vecs[3], 0.0)
    assert vers.tolist()[:3] == [0, 1, 1] and cores.tolist()[:3] == [3, 4, 5]
    # nothing moved: no promotion, no eviction, no LRU tick
    assert st.evictions == evictions and st._clock == clock
    assert st.spilled == spilled and 0 in st._spill


def test_peek_many_handles_out_of_range_ids():
    st = EmbeddingStore(capacity=2, dim=8, node_cap=4)
    st.put(1, _vec(1), core=1)
    vecs, found, _, _ = st.peek_many(np.array([1, 100]))
    assert found.tolist() == [True, False]
    np.testing.assert_allclose(vecs[1], 0.0)


def test_promote_after_ensure_nodes_growth_restores_mapping():
    """A spilled row promoted after the node map grew lands in a real slot
    (no stale sentinel left in ``_slot_of``)."""
    st = EmbeddingStore(capacity=2, dim=8, node_cap=4)
    st.put(0, _vec(0), core=1)
    st.put(1, _vec(1), core=1)
    st.put(2, _vec(2), core=1)  # evicts node 0 to spill
    assert 0 in st._spill
    st.ensure_nodes(100)  # geometric growth reallocates the slot map
    assert st.promote(np.array([0])) == 1
    assert st._slot_of[0] < st.capacity
    assert 0 not in st._spill
    vecs, found = st.gather(np.array([0]))
    assert found[0]
    np.testing.assert_allclose(np.asarray(vecs)[0], _vec(0))

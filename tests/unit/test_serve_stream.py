"""DynamicGraph: streaming ingestion, ELL slack, compaction, device mirror."""
import numpy as np

from repro.graph import generators
from repro.graph.csr import Graph
from repro.serve import DynamicGraph


def _random_graph(seed):
    return generators.barabasi_albert_varying(150, 4.0, seed=seed)


def test_snapshot_matches_batch_csr():
    g = _random_graph(0)
    dyn = DynamicGraph(g.n_nodes, g.edge_list(), width=4)
    snap = dyn.snapshot()
    ref = Graph.from_edges(g.n_nodes, g.edge_list())
    np.testing.assert_array_equal(snap.indptr, ref.indptr)
    np.testing.assert_array_equal(snap.indices, ref.indices)
    assert dyn.n_edges == g.n_edges


def test_duplicate_and_self_loop_rejected():
    dyn = DynamicGraph(4)
    assert dyn.add_edge(0, 1)
    assert not dyn.add_edge(1, 0)  # same undirected edge
    assert not dyn.add_edge(2, 2)
    assert dyn.n_edges == 1


def test_negative_node_ids_rejected():
    import pytest

    dyn = DynamicGraph(4)
    with pytest.raises(ValueError):
        dyn.add_edge(-1, 2)  # would wrap into the sentinel row
    # sentinel row untouched
    assert dyn.degree(dyn.node_cap) == 0
    np.testing.assert_array_equal(dyn._nbr[-1], dyn.node_cap)


def test_overflow_spills_then_compaction_repacks():
    # width 2 forces overflow on a star centre
    dyn = DynamicGraph(10, width=2)
    for v in range(1, 8):
        dyn.add_edge(0, v)
    assert dyn.needs_compact and dyn.overflow_arcs > 0
    assert dyn.degree(0) == 7  # host adjacency sees every arc
    assert set(dyn.neighbours(0).tolist()) == set(range(1, 8))
    # device view is capped until compaction
    ell = dyn.ell()
    in_table = (np.asarray(ell.neighbours)[0] != dyn.node_cap).sum()
    assert in_table == 2
    dyn.compact()
    assert not dyn.needs_compact
    assert dyn.width >= 7
    ell = dyn.ell()
    row = np.asarray(ell.neighbours)[0]
    assert set(row[row != dyn.node_cap].tolist()) == set(range(1, 8))


def test_device_mirror_applies_incremental_writes():
    g = _random_graph(1)
    edges = g.edge_list()
    dyn = DynamicGraph(g.n_nodes, edges[: len(edges) // 2], width=16)
    dyn.ell()  # force the initial full upload
    for u, v in edges[len(edges) // 2 :]:
        dyn.add_edge(int(u), int(v))
    ell = dyn.ell()  # batched scatter of the pending writes
    nbr = np.asarray(ell.neighbours)
    for v in range(g.n_nodes):
        row = nbr[v][nbr[v] != dyn.node_cap]
        in_table = set(row.tolist())
        true = set(dyn.neighbours(v).tolist())
        overflow = true - in_table
        assert in_table | overflow == true
        assert len(overflow) == 0 or dyn.needs_compact


def test_node_growth_preserves_adjacency():
    dyn = DynamicGraph(4, np.array([[0, 1], [1, 2]]), width=4)
    cap0 = dyn.node_cap
    big = cap0 + 100
    assert dyn.add_edge(1, big)  # forces node growth + re-upload
    assert dyn.n_nodes == big + 1
    assert dyn.node_cap > big
    assert set(dyn.neighbours(1).tolist()) == {0, 2, big}
    snap = dyn.snapshot()
    assert snap.has_edge(1, big) and snap.has_edge(0, 1)
    ell = dyn.ell()
    row = np.asarray(ell.neighbours)[big]
    assert set(row[row != dyn.node_cap].tolist()) == {1}


def test_add_edges_block_dedups_and_matches_sequential():
    """One staged block == the same edges inserted one at a time."""
    g = _random_graph(3)
    edges = g.edge_list()
    rng = np.random.default_rng(4)
    # duplicates within the block, reversed arcs, and self-loops
    block = np.concatenate([edges, edges[::-1, ::-1], [[5, 5], [7, 7]]])
    block = block[rng.permutation(len(block))]
    blk = DynamicGraph(g.n_nodes, width=4)
    accepted = blk.add_edges(block)
    assert len(accepted) == g.n_edges
    assert blk.n_edges == g.n_edges
    seq = DynamicGraph(g.n_nodes, width=4)
    for u, v in edges:
        assert seq.add_edge(int(u), int(v))
    snap_b, snap_s = blk.snapshot(), seq.snapshot()
    np.testing.assert_array_equal(snap_b.indptr, snap_s.indptr)
    np.testing.assert_array_equal(snap_b.indices, snap_s.indices)
    # a second staging of the same block is a full dedup no-op
    assert len(blk.add_edges(block)) == 0


def test_remove_edges_block_and_reinsert_round_trip():
    g = _random_graph(4)
    edges = g.edge_list()
    dyn = DynamicGraph(g.n_nodes, edges, width=4)  # width 4 forces overflow
    rng = np.random.default_rng(5)
    sel = edges[rng.choice(len(edges), 80, replace=False)]
    removed = dyn.remove_edges(np.concatenate([sel, sel]))  # dup-tolerant
    assert len(removed) == 80
    assert dyn.n_edges == g.n_edges - 80
    for u, v in sel:
        assert not dyn.has_edge(int(u), int(v))
    # unknown edges and unknown ids are skipped, not errors
    assert len(dyn.remove_edges(np.array([sel[0], [0, dyn.node_cap + 9]]))) == 0
    assert len(dyn.add_edges(sel)) == 80
    ref = Graph.from_edges(g.n_nodes, edges)
    snap = dyn.snapshot()
    np.testing.assert_array_equal(snap.indptr, ref.indptr)
    np.testing.assert_array_equal(snap.indices, ref.indices)


def test_remove_edge_backfills_from_overflow():
    dyn = DynamicGraph(10, width=2)  # star centre overflows
    for v in range(1, 8):
        dyn.add_edge(0, v)
    assert dyn.overflow_arcs > 0
    in_table_before = set(dyn._nbr[0, : dyn._deg[0]].tolist())
    victim = next(iter(in_table_before))
    assert dyn.remove_edge(0, victim)
    # the freed slot was backfilled from overflow: table stays full
    assert int(dyn._deg[0]) == 2
    assert dyn.degree(0) == 6
    assert set(dyn.neighbours(0).tolist()) == set(range(1, 8)) - {victim}


def test_device_mirror_tracks_removals():
    g = _random_graph(5)
    edges = g.edge_list()
    dyn = DynamicGraph(g.n_nodes, edges, width=16)
    dyn.ell()  # full upload; later mutations go through the pending scatter
    rng = np.random.default_rng(6)
    sel = edges[rng.choice(len(edges), 60, replace=False)]
    dyn.remove_edges(sel)
    dyn.add_edges(sel[:30])  # re-insert some into the freed slots
    ell = dyn.ell()
    nbr, deg = np.asarray(ell.neighbours), np.asarray(ell.degrees)
    for v in range(g.n_nodes):
        true = set(dyn.neighbours(v).tolist())
        in_table = set(nbr[v, : deg[v]].tolist())
        overflow_rows = dyn._overflow.get(v, [])
        assert in_table | set(overflow_rows) == true


def test_compact_is_double_buffered():
    """Old ELL views survive compaction; the new view needs no re-upload."""
    g = _random_graph(6)
    dyn = DynamicGraph(g.n_nodes, g.edge_list(), width=2)
    assert dyn.needs_compact
    old = dyn.ell()
    old_nbr = np.asarray(old.neighbours).copy()
    dyn.compact()
    # the pre-swap view is untouched (immutable device buffer)
    np.testing.assert_array_equal(np.asarray(old.neighbours), old_nbr)
    # the swap pre-uploaded the new buffer: no dirty flag, no pending writes
    assert dyn._dirty_full is False and not dyn._pending
    new = dyn.ell()
    nbr = np.asarray(new.neighbours)
    for v in range(g.n_nodes):
        row = nbr[v][nbr[v] != dyn.node_cap]
        np.testing.assert_array_equal(np.sort(row), g.neighbours(v))


def test_ell_view_consistent_with_to_ell_after_compact():
    g = _random_graph(2)
    dyn = DynamicGraph(g.n_nodes, g.edge_list(), width=2)
    dyn.compact()
    ell = dyn.ell()
    nbr = np.asarray(ell.neighbours)
    deg = np.asarray(ell.degrees)
    for v in range(g.n_nodes):
        row = np.sort(nbr[v][nbr[v] != dyn.node_cap])
        np.testing.assert_array_equal(row, g.neighbours(v))
        assert deg[v] == len(g.neighbours(v))

"""Benchmark history store: records, Theil-Sen fits, and the slope gate."""
import json

import pytest

from repro.obs.history import (
    SCHEMA_VERSION,
    append_record,
    direction,
    load_history,
    slope_failures,
    theil_sen,
    trend_series,
)


def payload(**over):
    """Minimal benchmark artifact with every trend-series source section."""
    p = {
        "schema_version": SCHEMA_VERSION,
        "ingest_sweep": [
            {"phases": {"region": {"seconds": 0.05},
                        "descend": {"seconds": 0.02}}},
        ],
        "churn": {"phases": {"region": {"seconds": 0.01}}},
        "query_p50_s": 0.004,
        "query_p99_s": 0.020,
        "ingest_edges_per_s": 10_000.0,
        "qps": 900.0,
        "cold_start_fraction": 0.02,
        "topk": {"recall_at_k": 1.0, "query_p99_s": 0.03},
        "retrain": {"auc_after": 0.8, "auc_all_after": 0.6,
                    "staleness_after": 0.1},
        "slo": {"status": "ok",
                "objectives": {"flush_latency": {"compliance": 0.99}}},
    }
    p.update(over)
    return p


# -------------------------------------------------------------- trend series


def test_trend_series_covers_quality_and_slo():
    s = trend_series(payload())
    # phase aggregates sum sweep + churn
    assert s["region"] == pytest.approx(0.06)
    assert s["descend"] == pytest.approx(0.02)
    assert s["query_p99_s"] == 0.020
    assert s["topk.query_p99_s"] == 0.03
    assert s["ingest_edges_per_s"] == 10_000.0
    # the quality series ride the same machinery as latency
    assert s["topk.recall_at_k"] == 1.0
    assert s["retrain.auc_after"] == 0.8
    assert s["slo.flush_latency.compliance"] == 0.99


def test_direction_quality_metrics_improve_upward():
    assert direction("retrain.auc_after") == 1
    assert direction("topk.recall_at_k") == 1
    assert direction("slo.flush_latency.compliance") == 1
    assert direction("query_p99_s") == -1
    assert direction("region") == -1


# ------------------------------------------------------------- append / load


def test_append_and_load_round_trip(tmp_path):
    path = str(tmp_path / "hist" / "serve.jsonl")  # parent made on demand
    r1 = append_record(path, payload(), sha="a" * 40, timestamp=1.0)
    append_record(path, payload(), sha="b" * 40, timestamp=2.0, quick=True)
    assert r1["schema_version"] == SCHEMA_VERSION
    recs = load_history(path)
    assert [r["git_sha"][0] for r in recs] == ["a", "b"]
    assert recs[1]["quick"] is True
    assert recs[0]["metrics"]["query_p99_s"] == 0.020
    assert load_history(path, last=1)[0]["git_sha"][0] == "b"


def test_load_missing_file_is_empty(tmp_path):
    assert load_history(str(tmp_path / "nope.jsonl")) == []


def test_load_rejects_torn_line_with_lineno(tmp_path):
    path = tmp_path / "h.jsonl"
    append_record(str(path), payload(), sha="a" * 40, timestamp=1.0)
    with open(path, "a") as f:
        f.write('{"schema_version": 2, "git_sha": "x", "tim')  # torn tail
    with pytest.raises(ValueError, match=r"h\.jsonl:2"):
        load_history(str(path))


def test_load_filters_schema_version(tmp_path):
    path = str(tmp_path / "h.jsonl")
    append_record(path, payload(), sha="a" * 40, timestamp=1.0)
    append_record(path, payload(schema_version=1), sha="b" * 40,
                  timestamp=2.0)
    assert len(load_history(path)) == 2
    only = load_history(path, schema_version=SCHEMA_VERSION)
    assert len(only) == 1 and only[0]["git_sha"][0] == "a"


def test_append_validates_record(tmp_path):
    path = str(tmp_path / "h.jsonl")
    with pytest.raises(Exception):
        append_record(path, payload(), sha="x", timestamp=-5.0)


# ---------------------------------------------------------------- Theil-Sen


def test_theil_sen_recovers_linear_slope():
    slope, intercept = theil_sen([3.0 + 0.5 * i for i in range(10)])
    assert slope == pytest.approx(0.5)
    assert intercept == pytest.approx(3.0)


def test_theil_sen_robust_to_outlier():
    ys = [1.0] * 9 + [100.0] + [1.0] * 10  # one loaded-runner spike
    slope, _ = theil_sen(ys)
    assert abs(slope) < 0.05  # median-of-slopes barely moves


def test_theil_sen_degenerate():
    assert theil_sen([]) == (0.0, 0.0)
    assert theil_sen([7.0]) == (0.0, 7.0)


# --------------------------------------------------------------- slope gate


def hist(values, key="query_p99_s"):
    return [
        {"schema_version": SCHEMA_VERSION, "git_sha": f"{i:040x}",
         "timestamp": float(i), "metrics": {key: v}}
        for i, v in enumerate(values)
    ]


def test_slope_gate_catches_gradual_creep():
    # +10% per step: every pairwise diff is below a 25% gate, but the
    # projected drift over the window is ~90% of the median
    ys = [0.010 + 0.001 * i for i in range(10)]
    bad = slope_failures(hist(ys), pct=25.0)
    assert [b[0] for b in bad] == ["query_p99_s"]
    name, med, drift, rel = bad[0]
    assert drift == pytest.approx(0.009, rel=0.05)
    assert rel > 25.0


def test_slope_gate_passes_flat_but_noisy():
    ys = [0.010 + (0.004 if i % 2 else -0.004) for i in range(10)]
    assert slope_failures(hist(ys), pct=25.0) == []


def test_slope_gate_ignores_improvements():
    ys = [0.020 - 0.001 * i for i in range(10)]  # latency falling = good
    assert slope_failures(hist(ys), pct=25.0) == []


def test_slope_gate_quality_decline_fails():
    # AUC sliding down: higher-is-better, so a negative slope is drift
    ys = [0.90 - 0.01 * i for i in range(10)]
    bad = slope_failures(hist(ys, key="retrain.auc_after"), pct=5.0)
    assert [b[0] for b in bad] == ["retrain.auc_after"]


def test_slope_gate_noise_floor_absorbs_tiny_phases():
    # 50% relative creep, but only 0.9ms over the window (< 3ms floor)
    ys = [0.001 + 0.0001 * i for i in range(10)]
    assert slope_failures(hist(ys), pct=25.0) == []


def test_slope_gate_needs_min_runs():
    ys = [0.010, 0.020, 0.030]
    assert slope_failures(hist(ys), pct=25.0, min_runs=4) == []


def test_slope_gate_only_series_common_to_all_runs():
    recs = hist([0.010 + 0.001 * i for i in range(10)])
    recs[3]["metrics"] = {"other": 1.0}  # one run missing the series
    assert slope_failures(recs, pct=25.0) == []


def test_history_record_is_json_stable(tmp_path):
    path = str(tmp_path / "h.jsonl")
    append_record(path, payload(), sha="a" * 40, timestamp=1.0)
    line = open(path).read().strip()
    assert json.loads(line) == load_history(path)[0]

"""Graph containers, generators, datasets, and link splits."""
import numpy as np
import pytest

from repro.graph import datasets, generators, splits
from repro.graph.csr import Graph


def test_csr_roundtrip_and_dedupe():
    edges = np.array([[0, 1], [1, 2], [0, 1], [2, 0], [3, 3]])
    g = Graph.from_edges(4, edges)
    assert g.n_edges == 3  # dup removed, self-loop removed
    assert g.has_edge(0, 1) and g.has_edge(1, 0)
    assert not g.has_edge(1, 3)
    np.testing.assert_array_equal(g.degrees(), [2, 2, 2, 0])


def test_edge_list_is_unique_upper():
    g = generators.barabasi_albert(50, 2, seed=0)
    el = g.edge_list()
    assert np.all(el[:, 0] < el[:, 1])
    assert len(el) == g.n_edges


def test_ell_table_matches_csr():
    g = generators.erdos_renyi(40, 100, seed=1)
    ell = g.to_ell()
    nbr = np.asarray(ell.neighbours)
    deg = np.asarray(ell.degrees)
    for v in range(g.n_nodes):
        row = nbr[v][nbr[v] != g.n_nodes]
        np.testing.assert_array_equal(np.sort(row), g.neighbours(v))
        assert deg[v] == len(g.neighbours(v))
    assert deg[-1] == 0  # sentinel


def test_ell_width_cap_subsamples():
    g = generators.barabasi_albert(100, 10, seed=2)
    ell = g.to_ell(max_width=4)
    assert ell.width == 4
    nbr = np.asarray(ell.neighbours)
    for v in range(g.n_nodes):
        row = nbr[v][nbr[v] != g.n_nodes]
        assert set(row).issubset(set(g.neighbours(v).tolist()))


def test_ell_width_cap_is_deterministic():
    g = generators.barabasi_albert(120, 8, seed=7)
    a = g.to_ell(max_width=5, seed=3)
    b = g.to_ell(max_width=5, seed=3)
    np.testing.assert_array_equal(np.asarray(a.neighbours), np.asarray(b.neighbours))
    np.testing.assert_array_equal(np.asarray(a.degrees), np.asarray(b.degrees))
    # a different seed draws a different subsample (on a hub-heavy graph)
    c = g.to_ell(max_width=5, seed=4)
    assert not np.array_equal(np.asarray(a.neighbours), np.asarray(c.neighbours))


def test_ell_width_cap_effective_degrees():
    g = generators.barabasi_albert(100, 10, seed=8)
    width = 6
    ell = g.to_ell(max_width=width)
    deg = np.asarray(ell.degrees)[:-1]
    np.testing.assert_array_equal(deg, np.minimum(g.degrees(), width))
    # every capped row is exactly full: width entries, no padding wasted
    nbr = np.asarray(ell.neighbours)
    full = g.degrees() >= width
    assert np.all((nbr[:-1][full] != g.n_nodes).sum(axis=1) == width)


def test_capped_core_numbers_are_lower_bound():
    """core_numbers_jax on a width-capped table is a documented lower bound."""
    from repro.core import kcore

    g = generators.barabasi_albert(150, 8, seed=9)
    host = kcore.core_numbers_host(g)
    capped = np.asarray(kcore.core_numbers_jax(g.to_ell(max_width=4)))
    assert np.all(capped <= host), "capped h-index fixpoint must lower-bound"
    # and the bound is tight somewhere below the cap
    assert np.any(capped < host), "cap of 4 on an 8-core graph must bind"
    # uncapped stays exact
    exact = np.asarray(kcore.core_numbers_jax(g.to_ell()))
    np.testing.assert_array_equal(exact, host)


def test_generators_hit_target_sizes():
    g = generators.barabasi_albert(500, 5, seed=3)
    assert g.n_nodes == 500
    assert abs(g.n_edges - 5 * 500) < 5 * 6  # ~ m*n edges
    g2 = generators.erdos_renyi(100, 250, seed=4)
    assert g2.n_edges == 250


def test_dataset_presets_are_calibrated():
    g = datasets.load("cora-like")
    # LCC trimming loses a few nodes; stay within 10% of the paper's counts
    assert abs(g.n_nodes - 2708) < 300
    assert abs(g.n_edges - 5429) < 600


def test_dataset_facebook_like_core_profile():
    g = datasets.load("tiny")
    assert g.n_nodes > 10
    mask = g.largest_connected_component()
    assert mask.all()  # presets return connected graphs


@pytest.mark.parametrize("frac", [0.1, 0.3, 0.5])
def test_link_split_properties(frac):
    g = generators.barabasi_albert(300, 4, seed=5)
    sp = splits.make_link_split(g, frac, seed=0)
    # sizes
    expect = int(round(frac * g.n_edges))
    assert abs(len(sp.pos_edges) - expect) <= max(2, expect // 20)
    assert len(sp.neg_edges) == len(sp.pos_edges)
    # no isolated nodes in the residual graph
    assert sp.train_graph.degrees().min() >= 1
    # removed edges are edges of g but not of the train graph
    for u, v in sp.pos_edges[:50]:
        assert g.has_edge(int(u), int(v))
        assert not sp.train_graph.has_edge(int(u), int(v))
    # negatives are non-edges of g
    for u, v in sp.neg_edges[:50]:
        assert not g.has_edge(int(u), int(v))


def test_split_edge_conservation():
    g = generators.barabasi_albert(200, 3, seed=6)
    sp = splits.make_link_split(g, 0.3, seed=1)
    assert sp.train_graph.n_edges + len(sp.pos_edges) == g.n_edges

"""SLO engine: objectives, rolling windows, burn-rate alerts, exports."""
import pytest

from repro.obs.metrics import MetricsRegistry
from repro.obs.slo import Objective, SLOEngine, default_slos


class Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def engine(**obj_over):
    clk = Clock()
    eng = SLOEngine(clock=clk)
    eng.add(Objective("flush", 0.050, "<=", objective=0.9,
                      long_window=60.0, short_window=5.0,
                      alert_burn_rate=2.0, **obj_over))
    return eng, clk


# ---------------------------------------------------------------- objectives


def test_objective_validation():
    with pytest.raises(ValueError):
        Objective("x", 1.0, "==")
    with pytest.raises(ValueError):
        Objective("x", 1.0, "<=", objective=1.0)
    with pytest.raises(ValueError):
        Objective("x", 1.0, "<=", long_window=1.0, short_window=5.0)


def test_good_by_op():
    assert Objective("lat", 0.05, "<=").good(0.04)
    assert not Objective("lat", 0.05, "<=").good(0.06)
    assert Objective("rate", 100.0, ">=").good(150.0)
    assert not Objective("rate", 100.0, ">=").good(50.0)


def test_duplicate_and_unknown_names():
    eng, _ = engine()
    with pytest.raises(ValueError):
        eng.add(Objective("flush", 1.0))
    with pytest.raises(KeyError):
        eng.observe("typo", 1.0)


# ------------------------------------------------------ windows & compliance


def test_compliance_over_long_window():
    eng, clk = engine()
    for i in range(10):
        clk.t = float(i)
        eng.observe("flush", 0.010 if i < 8 else 0.100)
    ev = eng.evaluate("flush")
    assert ev["events"] == 10 and ev["bad_events"] == 2
    assert ev["compliance"] == pytest.approx(0.8)


def test_events_age_out_of_window():
    eng, clk = engine()
    eng.observe("flush", 0.100)  # bad at t=0
    clk.t = 120.0  # > long_window later
    eng.observe("flush", 0.010)
    ev = eng.evaluate("flush")
    assert ev["events"] == 1 and ev["bad_events"] == 0
    assert ev["compliance"] == 1.0


# ------------------------------------------------------- burn-rate alerting


def test_alert_requires_both_windows():
    # budget = 0.1, alert at burn 2.0 => bad fraction >= 0.2 in BOTH windows
    eng, clk = engine()
    # sustained badness long ago, all-good recently: long burns, short clean
    for i in range(20):
        clk.t = float(i)
        eng.observe("flush", 0.100)
    for i in range(20, 30):
        clk.t = float(i)
        eng.observe("flush", 0.010)
    ev = eng.evaluate("flush")
    assert ev["burn_rate_long"] >= 2.0
    assert ev["burn_rate_short"] == 0.0
    assert not ev["alerting"]  # incident over: long window alone must not page
    # still happening: bad events continue into the short window
    for i in range(30, 40):
        clk.t = float(i)
        eng.observe("flush", 0.100)
    ev = eng.evaluate("flush")
    assert ev["burn_rate_short"] >= 2.0 and ev["alerting"]


def test_one_spike_does_not_alert():
    eng, clk = engine()
    for i in range(50):
        clk.t = float(i)
        eng.observe("flush", 0.010)
    clk.t = 50.0
    eng.observe("flush", 5.0)  # single outlier
    ev = eng.evaluate("flush")
    assert not ev["alerting"]  # long-window burn stays under threshold


def test_alerts_total_counts_onsets_not_evaluations():
    eng, clk = engine()
    for i in range(10):
        clk.t = float(i)
        eng.observe("flush", 0.100)
    assert eng.evaluate("flush")["alerting"]
    assert eng.evaluate("flush")["alerts_total"] == 1
    eng.evaluate("flush")  # still alerting: no second onset
    assert eng.evaluate("flush")["alerts_total"] == 1
    clk.t = 200.0  # everything ages out; alert clears
    assert not eng.evaluate("flush")["alerting"]
    for i in range(10):
        clk.t = 200.0 + i
        eng.observe("flush", 0.100)
    assert eng.evaluate("flush")["alerts_total"] == 2  # a fresh onset


def test_no_data_does_not_alert():
    eng, _ = engine()
    ev = eng.evaluate("flush")
    assert ev["events"] == 0 and not ev["alerting"]
    assert ev["compliance"] == 1.0


# ----------------------------------------------------------- health & export


def test_health_status_transitions():
    eng, clk = engine()
    assert eng.health()["status"] == "no_data"
    eng.observe("flush", 0.010)
    assert eng.health()["status"] == "ok"
    for i in range(10):
        clk.t = float(i)
        eng.observe("flush", 0.100)
    h = eng.health()
    assert h["status"] == "alert"
    assert h["objectives"]["flush"]["alerting"]


def test_provider_backed_objective_sampled_by_health():
    clk = Clock()
    readings = [0.9, 0.9]
    eng = SLOEngine(clock=clk)
    eng.add(Objective("stale", 0.5, "<=", objective=0.9),
            provider=lambda: readings.pop(0))
    h = eng.health()  # pulls one reading (0.9 > 0.5 target: bad)
    assert h["objectives"]["stale"]["events"] == 1
    assert h["objectives"]["stale"]["bad_events"] == 1
    eng.sample()
    assert eng.evaluate("stale")["events"] == 2


def test_publish_exports_gauges_and_counters():
    eng, clk = engine()
    for i in range(10):
        clk.t = float(i)
        eng.observe("flush", 0.100)
    reg = MetricsRegistry()
    eng.publish(reg)
    assert reg.get("slo_compliance", slo="flush").value == 0.0
    assert reg.get("slo_alert", slo="flush").value == 1
    assert reg.get("slo_healthy").value == 0
    assert reg.get("slo_alerts_total", slo="flush").value == 1
    eng.publish(reg)  # still alerting: the onset counter must not re-count
    assert reg.get("slo_alerts_total", slo="flush").value == 1
    assert reg.get("slo_burn_rate", slo="flush", window="long").value >= 2.0


def test_default_slos_shape():
    clk = Clock()
    eng = default_slos(clock=clk, staleness_provider=lambda: 0.1)
    assert eng.names() == ["degraded_serving", "flush_latency",
                           "ingest_rate", "staleness"]
    eng.observe("flush_latency", 0.010)
    eng.observe("ingest_rate", 5000.0)
    eng.observe("degraded_serving", 0.0)
    h = eng.health()  # samples staleness via the provider
    assert h["status"] == "ok"
    assert h["objectives"]["staleness"]["events"] == 1
    # a degraded flush is a bad event against a zero target
    eng.observe("degraded_serving", 1.0)
    assert eng.evaluate("degraded_serving")["bad_events"] == 1

"""Mamba2 SSD: chunked vs sequential oracle; full-forward vs decode-chain."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.mamba2 import (
    init_mamba,
    init_mamba_cache,
    mamba_decode,
    mamba_forward,
    ssd_chunked,
    ssd_reference,
)


@pytest.mark.parametrize("chunk", [8, 16, 64])
@pytest.mark.parametrize("seed", [0, 1])
def test_ssd_chunked_matches_reference(chunk, seed):
    B, S, h, p, g, n = 2, 64, 4, 8, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, S, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    Bi = jax.random.normal(ks[3], (B, S, g, n)) * 0.5
    C = jax.random.normal(ks[4], (B, S, g, n)) * 0.5
    y_ref, st_ref = ssd_reference(x, dt, a, Bi, C, h_per_g=h // g)
    y_ch, st_ch = ssd_chunked(x, dt, a, Bi, C, h_per_g=h // g, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_ch), np.asarray(y_ref), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_ch), np.asarray(st_ref), rtol=2e-4, atol=2e-4)


def test_forward_then_decode_matches_longer_forward():
    """Running S tokens through mamba_forward, then decoding token S+1 with
    the returned state, must equal a full forward over S+1 tokens."""
    cfg = get_config("mamba2-2.7b").reduced()
    params = init_mamba(jax.random.PRNGKey(0), cfg)
    B, S = 2, 33
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.3

    full = mamba_forward(params, x, cfg)  # (B, S, d)

    out, (conv_state, ssm_state) = mamba_forward(
        params, x[:, :-1], cfg, return_state=True
    )
    y_step, _ = mamba_decode(params, x[:, -1:], conv_state, ssm_state, cfg)
    np.testing.assert_allclose(
        np.asarray(y_step[:, 0]), np.asarray(full[:, -1]), rtol=2e-3, atol=2e-3
    )


def test_decode_chain_matches_forward():
    """Decoding token-by-token from the zero state reproduces the parallel
    (chunked) forward — the SSD duality in action."""
    cfg = get_config("mamba2-2.7b").reduced()
    params = init_mamba(jax.random.PRNGKey(0), cfg)
    B, S = 1, 12
    x = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model)) * 0.3
    full = mamba_forward(params, x, cfg)

    conv, ssd = init_mamba_cache(B, cfg)
    outs = []
    for t in range(S):
        y, (conv, ssd) = mamba_decode(params, x[:, t : t + 1], conv, ssd, cfg)
        outs.append(y[:, 0])
    chain = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(chain), np.asarray(full), rtol=2e-3, atol=2e-3
    )

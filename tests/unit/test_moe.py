"""MoE routing invariants: top-k selection, capacity dropping, gate mass."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig, MoEConfig
from repro.models.moe import _top_k_gates, apply_moe, init_moe


def _cfg(E=8, k=2, cap=1.25, group=64):
    return ModelConfig(
        name="test-moe", family="moe", n_layers=1, d_model=32, n_heads=4,
        n_kv_heads=4, head_dim=8, d_ff=64, vocab_size=64,
        moe=MoEConfig(n_experts=E, top_k=k, d_ff_expert=16, capacity_factor=cap,
                      group_size=group),
        param_dtype="float32", compute_dtype="float32",
    )


def test_top_k_gates_select_k_and_normalise():
    logits = jax.random.normal(jax.random.PRNGKey(0), (4, 32, 8))
    gates = _top_k_gates(logits, 2)
    n_active = np.asarray((gates > 0).sum(-1))
    assert (n_active == 2).all()
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)


def test_moe_output_finite_and_shaped():
    cfg = _cfg()
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
    y, aux = apply_moe(params, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux["load_balance_loss"]) > 0
    assert float(aux["router_z_loss"]) >= 0


def test_capacity_drops_overflow_tokens():
    """With a tiny capacity factor most tokens overflow: the layer must stay
    finite and pass through less gate mass than with ample capacity."""
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 32))
    big = _cfg(cap=8.0)
    small = _cfg(cap=0.1)
    params = init_moe(jax.random.PRNGKey(0), big)
    y_big, _ = apply_moe(params, x, big)
    y_small, _ = apply_moe(params, x, small)
    assert np.isfinite(np.asarray(y_small)).all()
    assert np.linalg.norm(np.asarray(y_small)) < np.linalg.norm(np.asarray(y_big))


def test_uniform_router_balances_load():
    """A zero router (uniform probs) routes ~evenly -> lb loss ~= 1."""
    cfg = _cfg(E=4, k=1)
    params = init_moe(jax.random.PRNGKey(0), cfg)
    params = dict(params)
    params["router"] = jnp.zeros_like(params["router"]) + \
        jax.random.normal(jax.random.PRNGKey(2), params["router"].shape) * 1e-4
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 64, 32))
    _, aux = apply_moe(params, x, cfg)
    assert abs(float(aux["load_balance_loss"]) - 1.0) < 0.15


def test_grouped_routing_matches_token_count():
    cfg = _cfg(group=32)
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 64, 32))
    y, _ = apply_moe(params, x, cfg)  # 128 tokens -> 4 groups of 32
    assert y.shape == (2, 64, 32)

"""int8 KV cache: quantised decode tracks the fp path within int8 tolerance."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops, ref
from repro.models.attention import quantize_kv_rows
from repro.models.steps import make_decode_step, make_prefill_step
from repro.models.transformer import init_model


def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 64))
    q, s = quantize_kv_rows(x)
    deq = q.astype(jnp.float32) * s[..., None]
    err = np.abs(np.asarray(deq - x))
    assert err.max() <= float(np.asarray(s).max()) / 2 + 1e-6


def test_decode_attention_quantised_matches_fp():
    B, H, Hkv, Dh, S = 2, 8, 4, 64, 256
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (B, H, Dh))
    k = jax.random.normal(ks[1], (B, S, Hkv, Dh))
    v = jax.random.normal(ks[2], (B, S, Hkv, Dh))
    cache_len = jnp.array([100, 256], jnp.int32)
    fp = ref.decode_attention_ref(q, k, v, cache_len)
    kq, ksc = quantize_kv_rows(k)
    vq, vsc = quantize_kv_rows(v)
    qd = ref.decode_attention_ref(q, kq, vq, cache_len, k_scale=ksc, v_scale=vsc)
    np.testing.assert_allclose(np.asarray(qd), np.asarray(fp), rtol=0.08, atol=0.05)


def test_pallas_quantised_kernel_matches_ref():
    B, H, Hkv, Dh, S = 2, 8, 4, 128, 256
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    q = jax.random.normal(ks[0], (B, H, Dh))
    k = jax.random.normal(ks[1], (B, S, Hkv, Dh))
    v = jax.random.normal(ks[2], (B, S, Hkv, Dh))
    cache_len = jnp.array([77, 200], jnp.int32)
    kq, ksc = quantize_kv_rows(k)
    vq, vsc = quantize_kv_rows(v)
    want = ref.decode_attention_ref(q, kq, vq, cache_len, k_scale=ksc, v_scale=vsc)
    got = ops.decode_attention(
        q, kq, vq, cache_len, impl="pallas_interpret", block_s=64,
        k_scale=ksc, v_scale=vsc,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("name", ["qwen3-4b", "moonshot-v1-16b-a3b"])
def test_end_to_end_quantised_decode_close_to_fp(name):
    cfg = get_config(name).reduced()
    cfg_q = dataclasses.replace(cfg, kv_quant=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    B, S = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    outs = {}
    for c in (cfg, cfg_q):
        logits_p, cache = make_prefill_step(c, max_len=S + 4)(params, {"tokens": toks})
        nxt = jnp.argmax(logits_p[:, -1], -1)[:, None].astype(jnp.int32)
        logits_d, cache2 = make_decode_step(c)(params, cache, nxt)
        outs[c.kv_quant] = np.asarray(logits_d)
        if c.kv_quant:
            assert cache2["k"].dtype == jnp.int8
            assert "k_scale" in cache2
    # logits agree to int8-cache tolerance; argmax token identical
    np.testing.assert_allclose(outs[True], outs[False], rtol=0.25, atol=0.25)
    assert (outs[True].argmax(-1) == outs[False].argmax(-1)).mean() > 0.95

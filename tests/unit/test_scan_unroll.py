"""scan-over-layers and unrolled layers must be numerically identical —
the roofline depth-calibration and scan/unroll perf experiments rely on it."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.steps import loss_fn, make_decode_step, make_prefill_step
from repro.models.transformer import init_model

ARCHS = ["qwen3-4b", "gemma2-2b", "mamba2-2.7b", "zamba2-7b", "grok-1-314b",
         "seamless-m4t-large-v2"]
B, S = 2, 32


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "targets": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.family == "encdec":
        batch["src_embeds"] = jax.random.normal(ks[2], (B, S // 4, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("name", ARCHS)
def test_unrolled_matches_scanned(name):
    cfg = get_config(name).reduced()
    cfg_unroll = dataclasses.replace(cfg, scan_layers=False)
    params = init_model(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    l1, _ = loss_fn(params, batch, cfg)
    l2, _ = loss_fn(params, batch, cfg_unroll)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


@pytest.mark.parametrize("name", ["qwen3-4b", "mamba2-2.7b", "zamba2-7b"])
def test_unrolled_decode_matches_scanned(name):
    cfg = get_config(name).reduced()
    cfg_unroll = dataclasses.replace(cfg, scan_layers=False)
    params = init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    for c in (cfg, cfg_unroll):
        logits_p, cache = make_prefill_step(c, max_len=S + 4)(params, {"tokens": toks})
        nxt = jnp.argmax(logits_p[:, -1], -1)[:, None].astype(jnp.int32)
        logits_d, _ = make_decode_step(c)(params, cache, nxt)
        if c is cfg:
            ref = np.asarray(logits_d)
        else:
            np.testing.assert_allclose(np.asarray(logits_d), ref, rtol=2e-4, atol=2e-4)

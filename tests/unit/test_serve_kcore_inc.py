"""Incremental core maintenance: insertion-only exactness vs the peeling oracle."""
import numpy as np
import pytest

from repro.core.kcore import core_numbers_host
from repro.graph import generators
from repro.serve import DynamicGraph, IncrementalCore


def _stream_and_check(g, seed, check_every=50):
    """Stream every edge of ``g`` in random order, checking exactness."""
    edges = g.edge_list()
    rng = np.random.default_rng(seed)
    edges = edges[rng.permutation(len(edges))]
    dyn = DynamicGraph(g.n_nodes, width=4)
    inc = IncrementalCore(dyn)
    for i, (u, v) in enumerate(edges):
        assert dyn.add_edge(int(u), int(v))
        inc.on_edge(int(u), int(v))
        if (i + 1) % check_every == 0:
            oracle = core_numbers_host(dyn.snapshot())
            np.testing.assert_array_equal(inc.core, oracle)
    oracle = core_numbers_host(dyn.snapshot())
    np.testing.assert_array_equal(inc.core, oracle)
    return inc


@pytest.mark.parametrize(
    "maker,seed",
    [
        (lambda: generators.barabasi_albert(120, 3, seed=1), 10),
        (lambda: generators.erdos_renyi(100, 300, seed=2), 11),
        (lambda: generators.powerlaw_cluster(110, 4, 0.3, seed=3), 12),
        (lambda: generators.barabasi_albert_varying(130, 5.0, seed=4), 13),
    ],
)
def test_streaming_exactness_random_graphs(maker, seed):
    inc = _stream_and_check(maker(), seed)
    assert inc.repairs > 0 and inc.promoted > 0


def test_exact_after_every_compaction():
    g = generators.barabasi_albert_varying(150, 5.0, seed=5)
    edges = g.edge_list()
    rng = np.random.default_rng(6)
    edges = edges[rng.permutation(len(edges))]
    dyn = DynamicGraph(g.n_nodes, width=2)  # tiny width: compaction matters
    inc = IncrementalCore(dyn)
    compactions = 0
    for i, (u, v) in enumerate(edges):
        dyn.add_edge(int(u), int(v))
        inc.on_edge(int(u), int(v))
        if (i + 1) % 100 == 0:
            dyn.compact()
            compactions += 1
            oracle = core_numbers_host(dyn.snapshot())
            np.testing.assert_array_equal(inc.core, oracle)
            assert inc.resync() == 0  # resync finds nothing to fix
    assert compactions >= 3


def test_new_nodes_enter_at_correct_level():
    dyn = DynamicGraph(3, np.array([[0, 1], [1, 2], [0, 2]]))  # triangle
    inc = IncrementalCore(dyn)
    np.testing.assert_array_equal(inc.core, [2, 2, 2])
    dyn.add_edge(0, 3)  # pendant: core 1
    inc.on_edge(0, 3)
    np.testing.assert_array_equal(inc.core, [2, 2, 2, 1])
    # attach node 3 to the rest of the triangle -> K4, everyone at core 3
    for t in (1, 2):
        dyn.add_edge(3, t)
        inc.on_edge(3, t)
    np.testing.assert_array_equal(inc.core, [3, 3, 3, 3])


def test_block_insert_cascade_promotes_multiple_levels():
    """K4 staged as one block: every core jumps 0 -> 3 in a single repair.

    Per-edge seeding (old core + 1) would cap the sweep at level 1; the block
    path must seed at the block-wide upper bound and cascade."""
    dyn = DynamicGraph(4)
    inc = IncrementalCore(dyn)
    accepted = dyn.add_edges([[i, j] for i in range(4) for j in range(i + 1, 4)])
    promoted = inc.on_edge_block(accepted)
    assert promoted == 4
    np.testing.assert_array_equal(inc.core, [3, 3, 3, 3])
    assert inc.repairs == 1  # one repair for the whole block


@pytest.mark.parametrize("block_size", [16, 64, 300])
def test_block_insert_stream_matches_oracle(block_size):
    g = generators.barabasi_albert_varying(200, 5.0, seed=21)
    edges = g.edge_list()
    rng = np.random.default_rng(block_size)
    edges = edges[rng.permutation(len(edges))]
    dyn = DynamicGraph(g.n_nodes, width=4)
    inc = IncrementalCore(dyn)
    for start in range(0, len(edges), block_size):
        accepted = dyn.add_edges(edges[start : start + block_size])
        inc.on_edge_block(accepted)
        oracle = core_numbers_host(dyn.snapshot())
        np.testing.assert_array_equal(inc.core, oracle)
    assert inc.repairs <= -(-len(edges) // block_size)


def test_block_delete_matches_oracle():
    g = generators.barabasi_albert_varying(180, 5.0, seed=22)
    edges = g.edge_list()
    dyn = DynamicGraph(g.n_nodes, edges, width=6)
    inc = IncrementalCore(dyn)
    rng = np.random.default_rng(23)
    perm = rng.permutation(len(edges))
    for start in range(0, len(edges) // 2, 40):
        removed = dyn.remove_edges(edges[perm[start : start + 40]])
        inc.on_remove(removed)
        oracle = core_numbers_host(dyn.snapshot())
        np.testing.assert_array_equal(inc.core, oracle)
    assert inc.demoted > 0


def test_delete_then_reinsert_restores_levels():
    dyn = DynamicGraph(4, np.array([[i, j] for i in range(4)
                                    for j in range(i + 1, 4)]))  # K4
    inc = IncrementalCore(dyn)
    np.testing.assert_array_equal(inc.core, [3, 3, 3, 3])
    removed = dyn.remove_edges(np.array([[0, 1], [2, 3]]))
    demoted = inc.on_remove(removed)
    assert demoted == 4  # 4-cycle: everyone down to core 2
    np.testing.assert_array_equal(inc.core, [2, 2, 2, 2])
    accepted = dyn.add_edges(np.array([[0, 1], [2, 3]]))
    inc.on_edge_block(accepted)
    np.testing.assert_array_equal(inc.core, [3, 3, 3, 3])
    assert inc.resync() == 0


def test_isolating_deletion_drops_to_zero():
    dyn = DynamicGraph(3, np.array([[0, 1], [1, 2], [0, 2]]))
    inc = IncrementalCore(dyn)
    removed = dyn.remove_edges(np.array([[0, 1], [0, 2]]))
    inc.on_remove(removed)
    np.testing.assert_array_equal(inc.core, [0, 1, 1])
    assert inc.resync() == 0


def test_repeel_fallback_is_exact_and_counted():
    """A graph-sized block trips the bounded re-peel fallback, exactly."""
    g = generators.barabasi_albert_varying(400, 5.0, seed=24)
    edges = g.edge_list()
    dyn = DynamicGraph(g.n_nodes, width=4)
    inc = IncrementalCore(dyn, repeel_frac=0.05)  # tiny bound: force fallback
    accepted = dyn.add_edges(edges)
    inc.on_edge_block(accepted)
    assert inc.repeels >= 1
    np.testing.assert_array_equal(inc.core, core_numbers_host(dyn.snapshot()))


def test_mixed_blocks_with_compactions_stay_exact():
    g = generators.barabasi_albert_varying(150, 4.0, seed=25)
    edges = g.edge_list()
    rng = np.random.default_rng(26)
    order = rng.permutation(len(edges))
    dyn = DynamicGraph(g.n_nodes, width=3)
    inc = IncrementalCore(dyn)
    live: list = []
    for step, start in enumerate(range(0, len(edges), 24)):
        accepted = dyn.add_edges(edges[order[start : start + 24]])
        inc.on_edge_block(accepted)
        live.extend(map(tuple, accepted))
        if step % 2 == 1 and len(live) > 10:
            pick = rng.choice(len(live), size=8, replace=False)
            removed = dyn.remove_edges(np.array([live[i] for i in pick]))
            inc.on_remove(removed)
            gone = {tuple(e) for e in removed}
            live = [e for e in live if e not in gone]
        if step % 3 == 2:
            dyn.compact()
        oracle = core_numbers_host(dyn.snapshot())
        np.testing.assert_array_equal(inc.core, oracle)
    assert inc.promoted > 0 and inc.demoted > 0
    assert inc.resync() == 0


def test_drift_and_membership_gate():
    g = generators.barabasi_albert(80, 3, seed=7)
    dyn = DynamicGraph(g.n_nodes, g.edge_list())
    inc = IncrementalCore(dyn)
    inc.mark_refresh()
    assert inc.drift() == 0
    k0 = 3
    changed0, size0 = inc.membership_drift(k0)
    assert changed0 == 0 and size0 > 0
    # densify a low-core pocket until levels move
    low = np.argsort(inc.core)[:6]
    for i in range(len(low)):
        for j in range(i + 1, len(low)):
            if dyn.add_edge(int(low[i]), int(low[j])):
                inc.on_edge(int(low[i]), int(low[j]))
    assert inc.drift() > 0
    oracle = core_numbers_host(dyn.snapshot())
    np.testing.assert_array_equal(inc.core, oracle)

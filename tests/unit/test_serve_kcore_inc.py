"""Incremental core maintenance: insertion-only exactness vs the peeling oracle."""
import numpy as np
import pytest

from repro.core.kcore import core_numbers_host
from repro.graph import generators
from repro.serve import DynamicGraph, IncrementalCore


def _stream_and_check(g, seed, check_every=50):
    """Stream every edge of ``g`` in random order, checking exactness."""
    edges = g.edge_list()
    rng = np.random.default_rng(seed)
    edges = edges[rng.permutation(len(edges))]
    dyn = DynamicGraph(g.n_nodes, width=4)
    inc = IncrementalCore(dyn)
    for i, (u, v) in enumerate(edges):
        assert dyn.add_edge(int(u), int(v))
        inc.on_edge(int(u), int(v))
        if (i + 1) % check_every == 0:
            oracle = core_numbers_host(dyn.snapshot())
            np.testing.assert_array_equal(inc.core, oracle)
    oracle = core_numbers_host(dyn.snapshot())
    np.testing.assert_array_equal(inc.core, oracle)
    return inc


@pytest.mark.parametrize(
    "maker,seed",
    [
        (lambda: generators.barabasi_albert(120, 3, seed=1), 10),
        (lambda: generators.erdos_renyi(100, 300, seed=2), 11),
        (lambda: generators.powerlaw_cluster(110, 4, 0.3, seed=3), 12),
        (lambda: generators.barabasi_albert_varying(130, 5.0, seed=4), 13),
    ],
)
def test_streaming_exactness_random_graphs(maker, seed):
    inc = _stream_and_check(maker(), seed)
    assert inc.repairs > 0 and inc.promoted > 0


def test_exact_after_every_compaction():
    g = generators.barabasi_albert_varying(150, 5.0, seed=5)
    edges = g.edge_list()
    rng = np.random.default_rng(6)
    edges = edges[rng.permutation(len(edges))]
    dyn = DynamicGraph(g.n_nodes, width=2)  # tiny width: compaction matters
    inc = IncrementalCore(dyn)
    compactions = 0
    for i, (u, v) in enumerate(edges):
        dyn.add_edge(int(u), int(v))
        inc.on_edge(int(u), int(v))
        if (i + 1) % 100 == 0:
            dyn.compact()
            compactions += 1
            oracle = core_numbers_host(dyn.snapshot())
            np.testing.assert_array_equal(inc.core, oracle)
            assert inc.resync() == 0  # resync finds nothing to fix
    assert compactions >= 3


def test_new_nodes_enter_at_correct_level():
    dyn = DynamicGraph(3, np.array([[0, 1], [1, 2], [0, 2]]))  # triangle
    inc = IncrementalCore(dyn)
    np.testing.assert_array_equal(inc.core, [2, 2, 2])
    dyn.add_edge(0, 3)  # pendant: core 1
    inc.on_edge(0, 3)
    np.testing.assert_array_equal(inc.core, [2, 2, 2, 1])
    # attach node 3 to the rest of the triangle -> K4, everyone at core 3
    for t in (1, 2):
        dyn.add_edge(3, t)
        inc.on_edge(3, t)
    np.testing.assert_array_equal(inc.core, [3, 3, 3, 3])


def test_drift_and_membership_gate():
    g = generators.barabasi_albert(80, 3, seed=7)
    dyn = DynamicGraph(g.n_nodes, g.edge_list())
    inc = IncrementalCore(dyn)
    inc.mark_refresh()
    assert inc.drift() == 0
    k0 = 3
    changed0, size0 = inc.membership_drift(k0)
    assert changed0 == 0 and size0 > 0
    # densify a low-core pocket until levels move
    low = np.argsort(inc.core)[:6]
    for i in range(len(low)):
        for j in range(i + 1, len(low)):
            if dyn.add_edge(int(low[i]), int(low[j])):
                inc.on_edge(int(low[i]), int(low[j]))
    assert inc.drift() > 0
    oracle = core_numbers_host(dyn.snapshot())
    np.testing.assert_array_equal(inc.core, oracle)

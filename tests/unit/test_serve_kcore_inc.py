"""Incremental core maintenance: insertion-only exactness vs the peeling oracle.

Stream/graph boilerplate lives in the shared ``stream_case`` fixture
(``tests/conftest.py``); the full-stream per-edge replays and the forced
fallback cases carry ``pytest.mark.slow`` (kept on in CI, deselect locally
with ``-m "not slow"``).
"""
import numpy as np
import pytest

from repro.core.kcore import core_numbers_host
from repro.graph import generators
from repro.serve import DynamicGraph, IncrementalCore


def _stream_and_check(edges, dyn, inc, check_every=50):
    """Stream ``edges`` one at a time, checking exactness periodically."""
    for i, (u, v) in enumerate(edges):
        assert dyn.add_edge(int(u), int(v))
        inc.on_edge(int(u), int(v))
        if (i + 1) % check_every == 0:
            oracle = core_numbers_host(dyn.snapshot())
            np.testing.assert_array_equal(inc.core, oracle)
    oracle = core_numbers_host(dyn.snapshot())
    np.testing.assert_array_equal(inc.core, oracle)
    return inc


@pytest.mark.slow
@pytest.mark.parametrize(
    "maker,seed",
    [
        (lambda: generators.barabasi_albert(100, 3, seed=1), 10),
        (lambda: generators.erdos_renyi(90, 260, seed=2), 11),
        (lambda: generators.powerlaw_cluster(95, 4, 0.3, seed=3), 12),
        (lambda: generators.barabasi_albert_varying(110, 5.0, seed=4), 13),
    ],
)
def test_streaming_exactness_random_graphs(stream_case, maker, seed):
    _, edges, dyn, inc = stream_case(maker, seed=seed)
    _stream_and_check(edges, dyn, inc)
    assert inc.repairs > 0 and inc.promoted > 0


@pytest.mark.slow
def test_exact_after_every_compaction(stream_case):
    _, edges, dyn, inc = stream_case(
        lambda: generators.barabasi_albert_varying(130, 5.0, seed=5),
        seed=6, width=2,  # tiny width: compaction matters
    )
    compactions = 0
    for i, (u, v) in enumerate(edges):
        dyn.add_edge(int(u), int(v))
        inc.on_edge(int(u), int(v))
        if (i + 1) % 100 == 0:
            dyn.compact()
            compactions += 1
            oracle = core_numbers_host(dyn.snapshot())
            np.testing.assert_array_equal(inc.core, oracle)
            assert inc.resync() == 0  # resync finds nothing to fix
    assert compactions >= 3


def test_new_nodes_enter_at_correct_level():
    dyn = DynamicGraph(3, np.array([[0, 1], [1, 2], [0, 2]]))  # triangle
    inc = IncrementalCore(dyn)
    np.testing.assert_array_equal(inc.core, [2, 2, 2])
    dyn.add_edge(0, 3)  # pendant: core 1
    inc.on_edge(0, 3)
    np.testing.assert_array_equal(inc.core, [2, 2, 2, 1])
    # attach node 3 to the rest of the triangle -> K4, everyone at core 3
    for t in (1, 2):
        dyn.add_edge(3, t)
        inc.on_edge(3, t)
    np.testing.assert_array_equal(inc.core, [3, 3, 3, 3])


def test_block_insert_cascade_promotes_multiple_levels():
    """K4 staged as one block: every core jumps 0 -> 3 in a single repair.

    Per-edge seeding (old core + 1) would cap the sweep at level 1; the block
    path must seed at the block-wide upper bound and cascade."""
    dyn = DynamicGraph(4)
    inc = IncrementalCore(dyn)
    accepted = dyn.add_edges([[i, j] for i in range(4) for j in range(i + 1, 4)])
    promoted = inc.on_edge_block(accepted)
    assert promoted == 4
    np.testing.assert_array_equal(inc.core, [3, 3, 3, 3])
    assert inc.repairs == 1  # one repair for the whole block


@pytest.mark.parametrize("block_size", [16, 64, 300])
def test_block_insert_stream_matches_oracle(stream_case, block_size):
    _, edges, dyn, inc = stream_case(
        lambda: generators.barabasi_albert_varying(200, 5.0, seed=21),
        seed=block_size,
    )
    for start in range(0, len(edges), block_size):
        accepted = dyn.add_edges(edges[start : start + block_size])
        inc.on_edge_block(accepted)
        oracle = core_numbers_host(dyn.snapshot())
        np.testing.assert_array_equal(inc.core, oracle)
    assert inc.repairs <= -(-len(edges) // block_size)


def test_block_delete_matches_oracle(stream_case):
    _, edges, dyn, inc = stream_case(
        lambda: generators.barabasi_albert_varying(180, 5.0, seed=22),
        width=6, preload=True, shuffle=False,
    )
    rng = np.random.default_rng(23)
    perm = rng.permutation(len(edges))
    for start in range(0, len(edges) // 2, 40):
        removed = dyn.remove_edges(edges[perm[start : start + 40]])
        inc.on_remove(removed)
        oracle = core_numbers_host(dyn.snapshot())
        np.testing.assert_array_equal(inc.core, oracle)
    assert inc.demoted > 0


def test_delete_then_reinsert_restores_levels():
    dyn = DynamicGraph(4, np.array([[i, j] for i in range(4)
                                    for j in range(i + 1, 4)]))  # K4
    inc = IncrementalCore(dyn)
    np.testing.assert_array_equal(inc.core, [3, 3, 3, 3])
    removed = dyn.remove_edges(np.array([[0, 1], [2, 3]]))
    demoted = inc.on_remove(removed)
    assert demoted == 4  # 4-cycle: everyone down to core 2
    np.testing.assert_array_equal(inc.core, [2, 2, 2, 2])
    accepted = dyn.add_edges(np.array([[0, 1], [2, 3]]))
    inc.on_edge_block(accepted)
    np.testing.assert_array_equal(inc.core, [3, 3, 3, 3])
    assert inc.resync() == 0


def test_isolating_deletion_drops_to_zero():
    dyn = DynamicGraph(3, np.array([[0, 1], [1, 2], [0, 2]]))
    inc = IncrementalCore(dyn)
    removed = dyn.remove_edges(np.array([[0, 1], [0, 2]]))
    inc.on_remove(removed)
    np.testing.assert_array_equal(inc.core, [0, 1, 1])
    assert inc.resync() == 0


@pytest.mark.slow
def test_repeel_fallback_is_exact_and_counted(stream_case):
    """A graph-sized block trips the bounded re-peel fallback, exactly."""
    _, edges, dyn, inc = stream_case(
        lambda: generators.barabasi_albert_varying(400, 5.0, seed=24),
        shuffle=False, repeel_frac=0.05,  # tiny bound: force fallback
        repair_policy="region",  # legacy static trigger (adaptive would descend)
    )
    accepted = dyn.add_edges(edges)
    inc.on_edge_block(accepted)
    assert inc.repeels >= 1
    np.testing.assert_array_equal(inc.core, core_numbers_host(dyn.snapshot()))


@pytest.mark.parametrize("impl", ["ref", "device"])
def test_mixed_blocks_with_compactions_stay_exact(stream_case, impl):
    _, edges, dyn, inc = stream_case(
        lambda: generators.barabasi_albert_varying(150, 4.0, seed=25),
        seed=26, width=3, impl=impl,
    )
    rng = np.random.default_rng(26)
    live: list = []
    for step, start in enumerate(range(0, len(edges), 24)):
        accepted = dyn.add_edges(edges[start : start + 24])
        inc.on_edge_block(accepted)
        live.extend(map(tuple, accepted))
        if step % 2 == 1 and len(live) > 10:
            pick = rng.choice(len(live), size=8, replace=False)
            removed = dyn.remove_edges(np.array([live[i] for i in pick]))
            inc.on_remove(removed)
            gone = {tuple(e) for e in removed}
            live = [e for e in live if e not in gone]
        if step % 3 == 2:
            dyn.compact()
        oracle = core_numbers_host(dyn.snapshot())
        np.testing.assert_array_equal(inc.core, oracle)
    assert inc.promoted > 0 and inc.demoted > 0
    assert inc.resync() == 0


@pytest.mark.slow
def test_fused_descent_matches_host_descent_on_blocks(stream_case):
    """The one-dispatch fused descent and the PR 2 host descent agree level
    by level on the same block/deletion stream (same graph, same blocks)."""
    maker = lambda: generators.barabasi_albert_varying(160, 4.0, seed=31)
    _, edges, dyn_ref, ref = stream_case(maker, seed=32, impl="ref")
    _, _, dyn_dev, dev = stream_case(maker, seed=32, impl="device")
    rng = np.random.default_rng(32)
    live: list = []
    for step, start in enumerate(range(0, len(edges), 32)):
        block = edges[start : start + 32]
        a_ref = dyn_ref.add_edges(block)
        a_dev = dyn_dev.add_edges(block)
        np.testing.assert_array_equal(a_ref, a_dev)
        ref.on_edge_block(a_ref)
        dev.on_edge_block(a_dev)
        live.extend(map(tuple, a_ref))
        if step % 2 == 1 and len(live) > 8:
            pick = rng.choice(len(live), size=6, replace=False)
            rm = np.array([live[i] for i in pick])
            ref.on_remove(dyn_ref.remove_edges(rm))
            dev.on_remove(dyn_dev.remove_edges(rm))
            gone = {tuple(e) for e in rm}
            live = [e for e in live if e not in gone]
        np.testing.assert_array_equal(ref.core, dev.core)
    assert dev.descends > 0  # the fused path actually ran
    assert ref.descends == 0  # and the host oracle never did
    assert ref.resync() == 0 and dev.resync() == 0


def test_kernel_backed_descent_stays_exact(stream_case):
    """End-to-end adoption check: the fused descent driven through the
    Pallas kernel (interpret mode) still matches the peeling oracle."""
    _, edges, dyn, inc = stream_case(
        lambda: generators.barabasi_albert(60, 3, seed=33),
        shuffle=False, impl="device", kernel_impl="pallas_interpret",
        region_impl="jit",
    )
    for start in range(0, len(edges), 40):
        accepted = dyn.add_edges(edges[start : start + 40])
        inc.on_edge_block(accepted)
    oracle = core_numbers_host(dyn.snapshot())
    np.testing.assert_array_equal(inc.core, oracle)
    assert inc.descends > 0


@pytest.mark.slow
@pytest.mark.parametrize("repeel_impl", ["rounds", "descend", "shell"])
def test_repeel_fallback_impls_are_exact(stream_case, repeel_impl):
    """Both device-path fallbacks (vectorized rounds peel, full-graph fused
    descent) recompute the exact core numbers, insertions and deletions."""
    _, edges, dyn, inc = stream_case(
        lambda: generators.barabasi_albert_varying(300, 5.0, seed=34),
        shuffle=False, repeel_frac=0.05, repeel_impl=repeel_impl,
        repair_policy="region",  # legacy static trigger (adaptive would descend)
    )
    inc.on_edge_block(dyn.add_edges(edges))
    assert inc.repeels >= 1
    np.testing.assert_array_equal(inc.core, core_numbers_host(dyn.snapshot()))
    rng = np.random.default_rng(35)
    rm = dyn.remove_edges(edges[rng.permutation(len(edges))[: len(edges) // 2]])
    inc.on_remove(rm)
    np.testing.assert_array_equal(inc.core, core_numbers_host(dyn.snapshot()))


@pytest.mark.slow
@pytest.mark.parametrize("repeel_impl", [None, "descend"])
def test_truncated_descent_falls_back_to_exact(repeel_impl):
    """A sweep cap below the cascade depth must never commit non-converged
    estimates: the repair detects the truncation and recovers through an
    uncapped exact recompute (even when the fallback itself is the capped
    full-graph descent)."""
    edges = np.array([[i, i + 1] for i in range(59)], np.int64)  # deep chain
    dyn = DynamicGraph(60, width=4)
    inc = IncrementalCore(dyn, max_sweeps=5, repeel_impl=repeel_impl)
    inc.on_edge_block(dyn.add_edges(edges))
    np.testing.assert_array_equal(inc.core, core_numbers_host(dyn.snapshot()))
    assert inc.repeels >= 1  # the truncation was detected, not ignored


def test_phase_report_tracks_repair_phases():
    g = generators.barabasi_albert(80, 3, seed=36)
    dyn = DynamicGraph(g.n_nodes, width=4)
    inc = IncrementalCore(dyn)
    inc.on_edge_block(dyn.add_edges(g.edge_list()))
    report = inc.phase_report()
    assert "region" in report
    assert report["region"]["seconds"] >= 0.0
    assert {"descend", "fallback"} & set(report)  # one of them repaired
    inc.reset_phases()
    assert inc.phase_report() == {}


def test_drift_and_membership_gate():
    g = generators.barabasi_albert(80, 3, seed=7)
    dyn = DynamicGraph(g.n_nodes, g.edge_list())
    inc = IncrementalCore(dyn)
    inc.mark_refresh()
    assert inc.drift() == 0
    k0 = 3
    changed0, size0 = inc.membership_drift(k0)
    assert changed0 == 0 and size0 > 0
    # densify a low-core pocket until levels move
    low = np.argsort(inc.core)[:6]
    for i in range(len(low)):
        for j in range(i + 1, len(low)):
            if dyn.add_edge(int(low[i]), int(low[j])):
                inc.on_edge(int(low[i]), int(low[j]))
    assert inc.drift() > 0
    oracle = core_numbers_host(dyn.snapshot())
    np.testing.assert_array_equal(inc.core, oracle)

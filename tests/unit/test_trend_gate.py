"""Span-diff trend gate (``scripts/trend_serve_latency.py --gate-pct``).

The gate aggregates per-phase repair seconds across the ingest sweep and
the churn run and fails (exit 2) when an aggregate regresses past both the
relative threshold and the absolute noise floor. Tested against synthetic
artifacts with injected regressions, and against the checked-in benchmark
artifact (self-diff must pass, a perturbed copy must fail) so the exact
invocation CI runs is covered.
"""
import importlib.util
import json
import pathlib

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[2]
SCRIPT = ROOT / "scripts" / "trend_serve_latency.py"
ARTIFACT = ROOT / "results" / "serve_latency.json"

spec = importlib.util.spec_from_file_location("trend_serve_latency", SCRIPT)
trend = importlib.util.module_from_spec(spec)
spec.loader.exec_module(trend)


def art(*, fallback=0.010, descend=0.004, churn_fallback=0.020,
        p50=0.0010, p99=0.0030, extra=None):
    """Minimal artifact with the sections phase_aggregates reads."""
    phases = {
        "fallback": {"seconds": fallback, "impl": "peel"},
        "descend": {"seconds": descend, "impl": "count"},
    }
    if extra:
        phases.update(extra)
    return {
        "ingest_sweep": [
            {"block": 64, "phases": phases},
            {"block": 1024, "phases": {"fallback": {"seconds": fallback}}},
        ],
        "churn": {"phases": {"fallback": {"seconds": churn_fallback}}},
        "query_p50_s": p50,
        "query_p99_s": p99,
    }


def run_main(tmp_path, old, new, *flags):
    a, b = tmp_path / "old.json", tmp_path / "new.json"
    a.write_text(json.dumps(old))
    b.write_text(json.dumps(new))
    return trend.main([str(a), str(b), "--no-validate", *flags])


def test_phase_aggregates_sums_sweep_and_churn():
    agg = trend.phase_aggregates(art())
    # fallback: two sweep rows + churn; descend: one sweep row
    assert agg["fallback"] == pytest.approx(0.010 + 0.010 + 0.020)
    assert agg["descend"] == pytest.approx(0.004)
    assert agg["query_p50_s"] == pytest.approx(0.0010)
    assert agg["query_p99_s"] == pytest.approx(0.0030)


def test_gate_flags_injected_regression(tmp_path):
    old, new = art(), art(fallback=0.030)  # 3x the fallback seconds
    bad = trend.gate_failures(old, new, 25.0, 3.0)
    assert [k for k, *_ in bad] == ["fallback"]
    assert run_main(tmp_path, old, new, "--gate-pct", "25") == 2


def test_gate_passes_unchanged_and_improved(tmp_path):
    assert run_main(tmp_path, art(), art(), "--gate-pct", "25") == 0
    faster = art(fallback=0.002, churn_fallback=0.005, p99=0.0015)
    assert run_main(tmp_path, art(), faster, "--gate-pct", "25") == 0


def test_gate_noise_floor_absorbs_small_absolute_growth(tmp_path):
    # +50% relative but only +0.5ms per row — under the 3ms floor
    noisy = art(fallback=0.0105, descend=0.006, p99=0.0045)
    assert trend.gate_failures(art(), noisy, 25.0, 3.0) == []
    assert run_main(tmp_path, art(), noisy, "--gate-pct", "25") == 0
    # same relative growth above the floor does fail
    big = art(fallback=0.015)
    assert run_main(tmp_path, art(), big, "--gate-pct", "25") == 2


def test_gate_new_phase_is_not_a_regression(tmp_path):
    # the adaptive policy routing seconds into a previously-unused phase
    # (e.g. descend starts winning) must not trip the gate
    new = art(extra={"region": {"seconds": 0.050}})
    assert run_main(tmp_path, art(), new, "--gate-pct", "25") == 0


def test_gate_latency_regression_fails(tmp_path):
    slow = art(p99=0.0090)  # 3x p99, +6ms
    bad = trend.gate_failures(art(), slow, 25.0, 3.0)
    assert [k for k, *_ in bad] == ["query_p99_s"]
    assert run_main(tmp_path, art(), slow, "--gate-pct", "25") == 2


def test_old_artifact_without_recovery_section(tmp_path):
    """Diffing against an artifact that predates the recovery section must
    neither crash nor trip the gate — the new section's metrics appear as
    [added] rows and its aggregates have no old baseline to regress from."""
    old = art()
    new = art()
    new["recovery"] = {
        "ops": 40,
        "points_crashed": 14,
        "points_recovered_bit_identical": 14,
        "state_mismatches": 0,
        "core_mismatches": 0,
        "recovery_seconds_max": 5.2,
        "replayed_edges_total": 1420,
        "crash_points": [
            {"point": "wal_append", "hit": 7, "crashed": True,
             "recovered": True, "replayed_edges": 120,
             "state_mismatch_keys": []},
        ],
        "retrain_rollback": {"mixed_version_rows": 0,
                             "store_rolled_back": True},
        "degradation": {"degraded_queries": 64},
    }
    assert run_main(tmp_path, old, new, "--gate-pct", "25") == 0
    # and the reverse direction (new baseline, old candidate) as well
    assert run_main(tmp_path, new, old, "--gate-pct", "25") == 0


@pytest.mark.skipif(not ARTIFACT.exists(), reason="no benchmark artifact")
def test_gate_on_checked_in_artifact(tmp_path):
    """The exact CI invocation: schema validation on, real artifact shape."""
    raw = json.loads(ARTIFACT.read_text())
    assert trend.main(
        [str(ARTIFACT), str(ARTIFACT), "--gate-pct", "25"]
    ) == 0
    # inject a systematic fallback regression into a valid copy
    slow = json.loads(ARTIFACT.read_text())
    for sec in list(slow.get("ingest_sweep") or []) + [slow.get("churn")]:
        for info in (sec or {}).get("phases", {}).values():
            info["seconds"] = float(info["seconds"]) * 4 + 0.01
    perturbed = tmp_path / "perturbed.json"
    perturbed.write_text(json.dumps(slow))
    assert trend.main(
        [str(ARTIFACT), str(perturbed), "--gate-pct", "25"]
    ) == 2
    assert trend.phase_aggregates(raw)  # artifact actually has phases


# ----------------------------------------------------- schema-version refusal


def test_version_mismatch_refuses_softly(tmp_path, capsys):
    old = art()
    old["schema_version"] = 1
    new = art()
    new["schema_version"] = 2
    # soft refusal: loud message, exit 0 so CI resets the cached baseline
    assert run_main(tmp_path, old, new, "--gate-pct", "25") == 0
    out = capsys.readouterr().out
    assert "REFUSING to diff across artifact schema versions" in out
    assert "v1" in out and "v2" in out
    # and no diff/gate output may follow the refusal
    assert "trend gate" not in out


def test_version_mismatch_strict_exits_4(tmp_path):
    old = art()
    old["schema_version"] = 1
    new = art()  # no field at all: treated as v1
    newer = art()
    newer["schema_version"] = 2
    assert run_main(tmp_path, old, new, "--strict-version") == 0  # both v1
    assert run_main(tmp_path, old, newer, "--strict-version") == 4


# ----------------------------------------------------------- slope-gate CLI


def hist_file(tmp_path, values, key="query_p99_s"):
    path = tmp_path / "hist.jsonl"
    with open(path, "w") as f:
        for i, v in enumerate(values):
            f.write(json.dumps({
                "schema_version": trend.SCHEMA_VERSION,
                "git_sha": f"{i:040x}",
                "timestamp": float(i),
                "metrics": {key: v},
            }) + "\n")
    return str(path)


def slope_main(path, *flags):
    return trend.main(["--gate-slope", "20", "--history", path,
                       "--gate-pct", "25", *flags])


def test_slope_cli_exits_2_on_gradual_creep(tmp_path, capsys):
    # each step is +10% — under the 25% pairwise gate — but the projected
    # drift over ten runs is ~90% of the median: exactly what slope catches
    path = hist_file(tmp_path, [0.010 + 0.001 * i for i in range(10)])
    assert slope_main(path) == 2
    out = capsys.readouterr().out
    assert "SLOPE query_p99_s" in out
    assert "slope gate FAILED" in out


def test_slope_cli_exits_0_on_flat_noisy(tmp_path, capsys):
    path = hist_file(
        tmp_path, [0.010 + (0.004 if i % 2 else -0.004) for i in range(10)])
    assert slope_main(path) == 0
    assert "slope gate passed" in capsys.readouterr().out


def test_slope_cli_skips_below_min_runs(tmp_path, capsys):
    path = hist_file(tmp_path, [0.010, 0.020, 0.030])
    assert slope_main(path) == 0
    assert "skipping" in capsys.readouterr().out


def test_slope_cli_missing_history_skips(tmp_path, capsys):
    assert slope_main(str(tmp_path / "absent.jsonl")) == 0
    assert "skipping" in capsys.readouterr().out


def test_slope_cli_ignores_older_schema_records(tmp_path):
    # creep lives entirely in v1 records; only 2 current-version runs remain,
    # so the gate must skip rather than fit a slope across the version bump
    path = tmp_path / "hist.jsonl"
    with open(path, "w") as f:
        for i in range(10):
            f.write(json.dumps({
                "schema_version": 1, "git_sha": f"{i:040x}",
                "timestamp": float(i),
                "metrics": {"query_p99_s": 0.010 + 0.002 * i},
            }) + "\n")
        for i in range(10, 12):
            f.write(json.dumps({
                "schema_version": trend.SCHEMA_VERSION,
                "git_sha": f"{i:040x}", "timestamp": float(i),
                "metrics": {"query_p99_s": 0.010},
            }) + "\n")
    assert slope_main(str(path)) == 0

"""Per-architecture smoke tests: reduced config, one forward + one train step
on CPU; asserts output shapes and no NaNs. The FULL configs are exercised only
by the dry-run (ShapeDtypeStruct, no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, get_config, list_archs
from repro.models.steps import loss_fn, make_decode_step, make_prefill_step, make_train_step
from repro.models.transformer import init_model, model_specs
from repro.train import optim

ARCHS = list_archs()
B, S = 2, 64


def _reduced(name):
    return get_config(name).reduced()


def _batch(cfg, key, *, train=True):
    ks = jax.random.split(key, 4)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
    }
    if train:
        batch["targets"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
        batch["mask"] = jnp.ones((B, S), jnp.float32)
    if cfg.family == "encdec":
        batch["src_embeds"] = (
            jax.random.normal(ks[2], (B, S // 4, cfg.d_model)) * 0.02
        )
    if cfg.frontend == "vision":
        P = cfg.n_vision_patches
        batch["vision_embeds"] = jax.random.normal(ks[3], (B, P, cfg.d_model)) * 0.02
        pos = jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
        batch["positions"] = pos
    return batch


@pytest.fixture(scope="module")
def states():
    return {}


def _get_state(states, name):
    if name not in states:
        cfg = _reduced(name)
        params = init_model(jax.random.PRNGKey(0), cfg)
        states[name] = (cfg, params)
    return states[name]


@pytest.mark.parametrize("name", ARCHS)
def test_forward_loss_finite(states, name):
    cfg, params = _get_state(states, name)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    loss, metrics = loss_fn(params, batch, cfg)
    loss = float(loss)
    assert np.isfinite(loss), (name, loss)
    # xent should start near log(vocab) for random params
    assert 0.5 * np.log(cfg.vocab_size) < float(metrics["xent"]) < 2.5 * np.log(
        cfg.vocab_size
    ), (name, float(metrics["xent"]))


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_updates_params(states, name):
    cfg, params = _get_state(states, name)
    opt = optim.adamw(1e-3)
    step = jax.jit(make_train_step(cfg, opt))
    batch = _batch(cfg, jax.random.PRNGKey(2))
    new_params, _, metrics = step(params, opt.init(params), batch)
    assert np.isfinite(float(metrics["loss"])), name
    # embeddings must have moved
    delta = np.abs(
        np.asarray(new_params["embed"]["embedding"], np.float32)
        - np.asarray(params["embed"]["embedding"], np.float32)
    ).max()
    assert delta > 0, name
    for leaf in jax.tree.leaves(new_params):
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), name


@pytest.mark.parametrize("name", ARCHS)
def test_specs_mirror_params(states, name):
    cfg, params = _get_state(states, name)
    specs = model_specs(cfg)
    pt = jax.tree.structure(params)
    st = jax.tree.structure(
        specs,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(i, (str, type(None))) for i in x),
    )
    assert pt == st, f"{name}: spec tree != param tree\n{pt}\n{st}"
    # every spec names exactly the param's rank
    flat_p = jax.tree.leaves(params)
    flat_s = pt.flatten_up_to(specs)
    for p, s in zip(flat_p, flat_s):
        assert len(s) == p.ndim, (name, p.shape, s)


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_then_decode_matches_full(states, name):
    """Serving path consistency: prefill(S) + decode(1) logits == full
    forward logits at position S (teacher forcing)."""
    cfg, params = _get_state(states, name)
    if cfg.family == "encdec":
        batch = _batch(cfg, jax.random.PRNGKey(3), train=False)
    else:
        batch = {"tokens": _batch(cfg, jax.random.PRNGKey(3))["tokens"]}
        if cfg.frontend == "vision":
            batch = _batch(cfg, jax.random.PRNGKey(3), train=False)
    prefill = jax.jit(make_prefill_step(cfg, max_len=S + 8))
    decode = jax.jit(make_decode_step(cfg))
    logits_p, cache = prefill(params, batch)
    assert np.isfinite(np.asarray(logits_p)).all(), name
    next_tok = jnp.argmax(logits_p[:, -1], axis=-1)[:, None].astype(jnp.int32)
    logits_d, cache2 = decode(params, cache, next_tok)
    assert logits_d.shape == (B, 1, cfg.vocab_size), name
    assert np.isfinite(np.asarray(logits_d)).all(), name
    assert int(cache2["len"][0]) == S + 1


def test_registry_has_all_ten():
    assert len(REGISTRY) == 10
    families = {cfg.family for cfg in REGISTRY.values()}
    assert families == {"dense", "moe", "ssm", "hybrid", "encdec"}

"""Span tracer: nesting, attributes, exports, and the zero-work no-op path."""
import json

import pytest

from repro.obs import trace as obs
from repro.obs.trace import NULL_SPAN, Span, Tracer


class FakeClock:
    """Deterministic monotonic clock that counts how often it is read."""

    def __init__(self):
        self.t = 0.0
        self.reads = 0

    def __call__(self):
        self.reads += 1
        self.t += 1.0
        return self.t


@pytest.fixture
def default_tracer():
    """Swap in a fresh enabled default tracer; restore the original after."""
    prev = obs.tracer()
    t = obs.set_tracer(Tracer(enabled=True))
    yield t
    obs.set_tracer(prev)


# ------------------------------------------------------------------ recording


def test_nested_spans_record_depth_and_attrs():
    t = Tracer(enabled=True, clock=FakeClock())
    with t.span("outer", block=256) as outer:
        with t.span("inner") as inner:
            inner.set(rows=7)
        outer.set(accepted=250)
    # inner closes (and emits) first
    assert [e["name"] for e in t.events] == ["inner", "outer"]
    inner_e, outer_e = t.events
    assert inner_e["depth"] == 1 and outer_e["depth"] == 0
    assert inner_e["attrs"] == {"rows": 7}
    assert outer_e["attrs"] == {"block": 256, "accepted": 250}
    # fake clock ticks 1s per read: outer [1, 4], inner [2, 3]
    assert outer_e["ts"] == 1.0 and outer_e["dur"] == 3.0
    assert inner_e["ts"] == 2.0 and inner_e["dur"] == 1.0


def test_late_attrs_after_exit_still_land():
    # service code closes a span then attaches results computed right after;
    # the event holds the attrs dict by reference, so this must work
    t = Tracer(enabled=True, clock=FakeClock())
    sp = t.span("flush").__enter__()
    sp.__exit__(None, None, None)
    sp.set(hits=3)
    assert t.events[0]["attrs"] == {"hits": 3}


def test_decorator_and_record():
    t = Tracer(enabled=True, clock=FakeClock())

    @t.wrap("work")
    def work(x):
        return x + 1

    assert work(1) == 2
    t.record("pretimed", 10.0, 12.5, impl="np")
    names = [e["name"] for e in t.events]
    assert names == ["work", "pretimed"]
    pre = t.events[1]
    assert pre["ts"] == 10.0 and pre["dur"] == 2.5
    assert pre["attrs"] == {"impl": "np"}


def test_exception_still_emits_span():
    t = Tracer(enabled=True, clock=FakeClock())
    with pytest.raises(RuntimeError):
        with t.span("boom"):
            raise RuntimeError("x")
    assert t.span_names() == {"boom"}


def test_max_events_drops_and_counts():
    t = Tracer(enabled=True, clock=FakeClock(), max_events=2)
    for i in range(5):
        with t.span(f"s{i}"):
            pass
    assert len(t.events) == 2
    assert t.dropped == 3


def test_reset_clears_events():
    t = Tracer(enabled=True, clock=FakeClock())
    with t.span("a"):
        pass
    t.reset()
    assert t.events == [] and t.dropped == 0


# ----------------------------------------------------------- disabled = no-op


def test_disabled_tracer_is_zero_work():
    clock = FakeClock()
    t = Tracer(enabled=False, clock=clock)
    sp = t.span("hot", block=1024)
    assert sp is NULL_SPAN  # the one shared singleton — no allocation
    with sp as s:
        s.set(anything=1)
    t.record("hot2", 0.0, 1.0)
    assert clock.reads == 0  # clock never touched
    assert t.events == []


def test_module_level_fast_path_disabled(default_tracer):
    clock = FakeClock()
    obs.set_tracer(Tracer(enabled=False, clock=clock))
    assert obs.span("x") is NULL_SPAN
    obs.record("y", 0.0, 1.0)
    assert clock.reads == 0


def test_module_enable_disable(default_tracer):
    t = obs.enable()
    with obs.span("a", k=1):
        pass
    assert t.span_names() == {"a"}
    obs.disable()
    assert obs.span("b") is NULL_SPAN
    assert t.span_names() == {"a"}  # nothing new recorded


def test_wrap_disabled_calls_through():
    t = Tracer(enabled=False, clock=FakeClock())

    @t.wrap("work")
    def work():
        return 42

    assert work() == 42
    assert t.events == []


# -------------------------------------------------------------------- exports


def test_export_jsonl_round_trip(tmp_path):
    t = Tracer(enabled=True, clock=FakeClock())
    with t.span("a", n=1):
        with t.span("b"):
            pass
    path = tmp_path / "spans.jsonl"
    assert t.export_jsonl(str(path)) == 2
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    assert [l["name"] for l in lines] == ["b", "a"]
    assert lines[1]["attrs"] == {"n": 1}


def test_chrome_export_is_loadable_complete_events(tmp_path):
    t = Tracer(enabled=True, clock=FakeClock())
    with t.span("outer"):
        with t.span("inner"):
            pass
    path = tmp_path / "trace.json"
    assert t.export_chrome(str(path)) == 2
    doc = json.loads(path.read_text())
    ev = doc["traceEvents"]
    assert all(e["ph"] == "X" for e in ev)
    by_name = {e["name"]: e for e in ev}
    outer, inner = by_name["outer"], by_name["inner"]
    # microseconds, rebased to the earliest span start (outer opens first)
    assert outer["ts"] == 0.0
    assert inner["ts"] == 1e6 and inner["dur"] == 1e6
    assert outer["dur"] == 3e6
    # containment (what the viewers use to nest) + depth rides in args
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
    assert inner["args"]["depth"] == 1


def test_chrome_export_records_drops(tmp_path):
    t = Tracer(enabled=True, clock=FakeClock(), max_events=1)
    for _ in range(3):
        with t.span("s"):
            pass
    path = tmp_path / "trace.json"
    t.export_chrome(str(path))
    doc = json.loads(path.read_text())
    assert doc["metadata"]["dropped_events"] == 2

"""Span tracer: nesting, attributes, exports, and the zero-work no-op path."""
import json

import pytest

from repro.obs import trace as obs
from repro.obs.trace import NULL_SPAN, Span, Tracer


class FakeClock:
    """Deterministic monotonic clock that counts how often it is read."""

    def __init__(self):
        self.t = 0.0
        self.reads = 0

    def __call__(self):
        self.reads += 1
        self.t += 1.0
        return self.t


@pytest.fixture
def default_tracer():
    """Swap in a fresh enabled default tracer; restore the original after."""
    prev = obs.tracer()
    t = obs.set_tracer(Tracer(enabled=True))
    yield t
    obs.set_tracer(prev)


# ------------------------------------------------------------------ recording


def test_nested_spans_record_depth_and_attrs():
    t = Tracer(enabled=True, clock=FakeClock())
    with t.span("outer", block=256) as outer:
        with t.span("inner") as inner:
            inner.set(rows=7)
        outer.set(accepted=250)
    # inner closes (and emits) first
    assert [e["name"] for e in t.events] == ["inner", "outer"]
    inner_e, outer_e = t.events
    assert inner_e["depth"] == 1 and outer_e["depth"] == 0
    assert inner_e["attrs"] == {"rows": 7}
    assert outer_e["attrs"] == {"block": 256, "accepted": 250}
    # fake clock ticks 1s per read: outer [1, 4], inner [2, 3]
    assert outer_e["ts"] == 1.0 and outer_e["dur"] == 3.0
    assert inner_e["ts"] == 2.0 and inner_e["dur"] == 1.0


def test_late_attrs_after_exit_still_land():
    # service code closes a span then attaches results computed right after;
    # the event holds the attrs dict by reference, so this must work
    t = Tracer(enabled=True, clock=FakeClock())
    sp = t.span("flush").__enter__()
    sp.__exit__(None, None, None)
    sp.set(hits=3)
    assert t.events[0]["attrs"] == {"hits": 3}


def test_decorator_and_record():
    t = Tracer(enabled=True, clock=FakeClock())

    @t.wrap("work")
    def work(x):
        return x + 1

    assert work(1) == 2
    t.record("pretimed", 10.0, 12.5, impl="np")
    names = [e["name"] for e in t.events]
    assert names == ["work", "pretimed"]
    pre = t.events[1]
    assert pre["ts"] == 10.0 and pre["dur"] == 2.5
    assert pre["attrs"] == {"impl": "np"}


def test_exception_still_emits_span():
    t = Tracer(enabled=True, clock=FakeClock())
    with pytest.raises(RuntimeError):
        with t.span("boom"):
            raise RuntimeError("x")
    assert t.span_names() == {"boom"}


def test_max_events_drops_and_counts():
    t = Tracer(enabled=True, clock=FakeClock(), max_events=2)
    for i in range(5):
        with t.span(f"s{i}"):
            pass
    assert len(t.events) == 2
    assert t.dropped == 3


def test_reset_clears_events():
    t = Tracer(enabled=True, clock=FakeClock())
    with t.span("a"):
        pass
    t.reset()
    assert t.events == [] and t.dropped == 0


# ----------------------------------------------------------- disabled = no-op


def test_disabled_tracer_is_zero_work():
    clock = FakeClock()
    t = Tracer(enabled=False, clock=clock)
    sp = t.span("hot", block=1024)
    assert sp is NULL_SPAN  # the one shared singleton — no allocation
    with sp as s:
        s.set(anything=1)
    t.record("hot2", 0.0, 1.0)
    assert clock.reads == 0  # clock never touched
    assert t.events == []


def test_module_level_fast_path_disabled(default_tracer):
    clock = FakeClock()
    obs.set_tracer(Tracer(enabled=False, clock=clock))
    assert obs.span("x") is NULL_SPAN
    obs.record("y", 0.0, 1.0)
    assert clock.reads == 0


def test_module_enable_disable(default_tracer):
    t = obs.enable()
    with obs.span("a", k=1):
        pass
    assert t.span_names() == {"a"}
    obs.disable()
    assert obs.span("b") is NULL_SPAN
    assert t.span_names() == {"a"}  # nothing new recorded


def test_wrap_disabled_calls_through():
    t = Tracer(enabled=False, clock=FakeClock())

    @t.wrap("work")
    def work():
        return 42

    assert work() == 42
    assert t.events == []


# -------------------------------------------------------------------- exports


def test_export_jsonl_round_trip(tmp_path):
    t = Tracer(enabled=True, clock=FakeClock())
    with t.span("a", n=1):
        with t.span("b"):
            pass
    path = tmp_path / "spans.jsonl"
    assert t.export_jsonl(str(path)) == 2
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    assert [l["name"] for l in lines] == ["b", "a"]
    assert lines[1]["attrs"] == {"n": 1}


def test_chrome_export_is_loadable_complete_events(tmp_path):
    t = Tracer(enabled=True, clock=FakeClock())
    with t.span("outer"):
        with t.span("inner"):
            pass
    path = tmp_path / "trace.json"
    assert t.export_chrome(str(path)) == 2
    doc = json.loads(path.read_text())
    ev = doc["traceEvents"]
    assert all(e["ph"] == "X" for e in ev)
    by_name = {e["name"]: e for e in ev}
    outer, inner = by_name["outer"], by_name["inner"]
    # microseconds, rebased to the earliest span start (outer opens first)
    assert outer["ts"] == 0.0
    assert inner["ts"] == 1e6 and inner["dur"] == 1e6
    assert outer["dur"] == 3e6
    # containment (what the viewers use to nest) + depth rides in args
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
    assert inner["args"]["depth"] == 1


def test_chrome_export_records_drops(tmp_path):
    t = Tracer(enabled=True, clock=FakeClock(), max_events=1)
    for _ in range(3):
        with t.span("s"):
            pass
    path = tmp_path / "trace.json"
    t.export_chrome(str(path))
    doc = json.loads(path.read_text())
    assert doc["metadata"]["dropped_events"] == 2


# ------------------------------------------------------- tail-sampled exemplars


def feed(t, name, dur, n=1, start=0.0, gap=100.0):
    """Record n back-to-back pre-timed spans of the given duration."""
    for i in range(n):
        t0 = start + i * gap
        t.record(name, t0, t0 + dur)


def test_tail_span_becomes_exemplar():
    t = Tracer(enabled=True, exemplar_min_samples=4)
    feed(t, "serve.flush", 0.010, n=8)
    assert t.exemplars == {}  # steady state: nothing crosses its own tail
    feed(t, "serve.flush", 0.080, start=10_000.0)
    recs = t.exemplar_records()
    assert len(recs) == 1
    rec = recs[0]
    assert rec["name"] == "serve.flush"
    assert rec["dur"] == pytest.approx(0.080)
    assert rec["threshold"] == pytest.approx(0.010)
    # the bucket link invariant: lower < root duration <= le
    assert rec["bucket_lower_s"] < rec["dur"]
    assert rec["dur"] <= rec["bucket_le_s"]


def test_no_capture_before_min_samples():
    t = Tracer(enabled=True, exemplar_min_samples=16)
    feed(t, "serve.flush", 0.001, n=10)
    feed(t, "serve.flush", 5.0, start=10_000.0)  # huge, but ring too young
    assert t.exemplars == {}


def test_watch_prefix_matches_namespace():
    t = Tracer(enabled=True, exemplar_min_samples=1)
    # "repair." watches the whole namespace; serve.query is not watched
    feed(t, "repair.repeel", 0.001)
    feed(t, "repair.repeel", 0.050, start=100.0)
    feed(t, "serve.query", 0.001)
    feed(t, "serve.query", 0.050, start=100.0)
    names = {r["name"] for r in t.exemplar_records()}
    assert names == {"repair.repeel"}
    assert "serve.query" not in t._tail_durs  # unwatched: zero state kept


def test_same_bucket_keeps_slowest():
    t = Tracer(enabled=True, exemplar_min_samples=1)
    feed(t, "serve.flush", 0.001)
    feed(t, "serve.flush", 0.009, start=1_000.0)  # captured
    feed(t, "serve.flush", 0.012, start=2_000.0)  # same bucket, slower
    recs = t.exemplar_records()
    assert len(recs) == 1
    assert recs[0]["dur"] == pytest.approx(0.012)
    # a direct slower->faster attempt must keep the slow representative
    t._capture_exemplar("serve.flush", 0.0, 0.009, 0, recs[0]["tid"], 0.001)
    assert t.exemplar_records()[0]["dur"] == pytest.approx(0.012)


def test_max_exemplars_cap_counts_drops():
    t = Tracer(enabled=True, exemplar_min_samples=1, max_exemplars=2)
    feed(t, "serve.flush", 0.0001)
    feed(t, "serve.flush", 0.003, start=1_000.0)   # bucket A
    feed(t, "serve.flush", 0.006, start=2_000.0)   # bucket B -> at cap
    feed(t, "serve.flush", 0.024, start=3_000.0)   # bucket C -> dropped
    assert len(t.exemplars) == 2
    assert t.exemplars_dropped == 1


def test_exemplar_retains_full_subtree():
    t = Tracer(enabled=True, clock=FakeClock(), exemplar_min_samples=1)
    with t.span("serve.flush"):          # [1, 2] seeds the ring
        pass
    with t.span("serve.flush"):          # [3, 8], dur 5 > threshold 1
        with t.span("store.gather"):     # [4, 5]
            pass
        with t.span("merge"):            # [6, 7]
            pass
    recs = t.exemplar_records()
    assert len(recs) == 1
    rec = recs[0]
    assert [s["name"] for s in rec["spans"]] == \
        ["store.gather", "merge", "serve.flush"]
    t0, t1 = rec["ts"], rec["ts"] + rec["dur"]
    for s in rec["spans"]:
        assert t0 <= s["ts"] and s["ts"] + s["dur"] <= t1
    # the earlier steady-state flush is NOT part of the subtree
    assert all(s["ts"] != 1.0 for s in rec["spans"])
    assert rec["dur"] > rec["bucket_lower_s"]


def test_reset_clears_exemplar_state():
    t = Tracer(enabled=True, exemplar_min_samples=1)
    feed(t, "serve.flush", 0.001)
    feed(t, "serve.flush", 0.050, start=100.0)
    assert t.exemplars
    t.reset()
    assert t.exemplars == {} and t.exemplars_dropped == 0
    assert t._tail_durs == {}


def test_export_exemplars_loads(tmp_path):
    t = Tracer(enabled=True, exemplar_min_samples=1)
    feed(t, "serve.flush", 0.001)
    feed(t, "serve.flush", 0.050, start=100.0)
    path = tmp_path / "ex.json"
    assert t.export_exemplars(str(path)) == 1
    doc = json.loads(path.read_text())
    assert doc["dropped"] == 0
    assert doc["quantile"] == 99.0
    assert "serve.flush" in doc["watch"]
    rec = doc["exemplars"][0]
    assert isinstance(rec["spans"], list)
    assert rec["bucket_lower_s"] < rec["dur"]
    assert rec["bucket_le_s"] is None or rec["dur"] <= rec["bucket_le_s"]


def test_disabled_tracer_keeps_no_exemplar_state():
    t = Tracer(enabled=False, exemplar_min_samples=1)
    assert t.span("serve.flush") is NULL_SPAN
    t.record("serve.flush", 0.0, 9.0)
    assert t.events == [] and t.exemplars == {} and t._tail_durs == {}


# ------------------------------------------- pipelined ingest trace integrity


def test_chrome_export_nests_under_pipelined_ingest(default_tracer, tmp_path):
    """Overlapped block staging must not produce interleaved (half-
    overlapping) spans: within each thread lane the exported Chrome trace
    has to stay strictly containment-nested, or the viewers render garbage
    nesting for exactly the runs where the pipeline is interesting."""
    np = pytest.importorskip("numpy")
    from repro.graph import generators
    from repro.launch.serve_embed import build_service

    g = generators.barabasi_albert_varying(240, 4.0, seed=5)
    svc, stream, _, _ = build_service(
        g, pipeline=True, seed=5, batch=32, compact_every=64)
    rng = np.random.default_rng(7)
    for start in range(0, len(stream), 48):
        svc.ingest_block(stream[start:start + 48])
        if (start // 48) % 2:
            # queries settle the in-flight block mid-stream
            svc.embed(rng.integers(0, svc.graph.n_nodes, size=8))
    svc.sync()

    path = tmp_path / "pipeline_trace.json"
    n = default_tracer.export_chrome(str(path))
    assert n > 0
    doc = json.loads(path.read_text())  # loads cleanly, no torn events
    events = doc["traceEvents"]
    assert {e["ph"] for e in events} == {"X"}
    lanes = {}
    for e in events:
        lanes.setdefault(e["tid"], []).append(e)
    assert any(len(v) > 1 for v in lanes.values())
    for lane in lanes.values():
        # sweep with an interval stack: every pair of spans in a lane must
        # be disjoint or fully nested — a span may never half-overlap the
        # one below it
        lane.sort(key=lambda e: (e["ts"], -e["dur"]))
        stack = []  # open end-times
        for e in lane:
            t0, t1 = e["ts"], e["ts"] + e["dur"]
            while stack and stack[-1] <= t0:
                stack.pop()
            assert not stack or t1 <= stack[-1], (
                f"span {e['name']} [{t0}, {t1}] half-overlaps an "
                f"enclosing span ending at {stack[-1]}")
            stack.append(t1)

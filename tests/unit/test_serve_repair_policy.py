"""Adaptive repair policy + shell-incremental re-peel (exactness + decisions).

The policy only ever picks *which* exact repair path runs, so every test
here asserts two things: the decision machinery behaves (cold start, EMA
crossover, one-shot exploration, stale-path probing), and the computed core
numbers never deviate from the Matula–Beck oracle no matter what it picks.
"""
import numpy as np
import pytest

from repro.core.kcore import (
    core_numbers_host,
    core_numbers_rounds,
    core_numbers_shell_peel,
)
from repro.graph import generators
from repro.obs.metrics import MetricsRegistry
from repro.serve import DynamicGraph, IncrementalCore
from repro.serve.kcore_inc import RepairPolicy


# ------------------------------------------------------------ RepairPolicy


def test_unknown_mode_rejected():
    with pytest.raises(ValueError, match="unknown repair policy"):
        RepairPolicy("always-descend")


def test_cold_start_heuristic_shapes_first_decision():
    p = RepairPolicy()
    # modest matrix vs real arc mass: descend, counted as a cold decision
    assert p.choose(cells=4096, repeel_work=4096, budget=1 << 20) == "descend"
    assert p.cold_decisions == 1
    # padded matrix dwarfs the shell arc mass: don't burn time measuring it
    assert p.choose(cells=1 << 24, repeel_work=128, budget=1 << 30) == "repeel"
    # over the hard cold budget: repeel regardless of the ratio
    assert p.choose(cells=1 << 22, repeel_work=1 << 20, budget=1 << 10) \
        == "repeel"
    assert p.decisions == {"descend": 1, "repeel": 2}


def test_ema_observe_predict_and_regime_extrapolation():
    p = RepairPolicy()
    for _ in range(4):
        p.observe("descend", 4096, 0.010)
    assert p.predict("descend", 4096) == pytest.approx(0.010, rel=0.05)
    # an unmeasured regime extrapolates linearly in work from the nearest
    far = p.predict("descend", 4 * 4096)
    assert far == pytest.approx(4 * 0.010, rel=0.25)
    # EMA tracks drift toward new observations
    for _ in range(16):
        p.observe("descend", 4096, 0.002)
    assert p.predict("descend", 4096) < 0.004


def test_unmeasured_repeel_is_explored_once():
    p = RepairPolicy()
    p.observe("descend", 4096, 0.001)  # descend measured, repeel never
    assert p.choose(cells=4096, repeel_work=4096, budget=1 << 20) == "repeel"
    p.observe("repeel", 4096, 0.010)
    # both measured now: the crossover picks the cheap path
    assert p.choose(cells=4096, repeel_work=4096, budget=1 << 20) == "descend"
    assert p.cold_decisions == 0


def test_stale_loser_is_probed():
    p = RepairPolicy(probe_every=4)
    p.observe("descend", 4096, 0.001)
    p.observe("repeel", 4096, 0.100)  # repeel loses the crossover hard
    choices = [
        p.choose(cells=4096, repeel_work=4096, budget=1 << 20)
        for _ in range(5)
    ]
    # the loser goes unmeasured for probe_every decisions, then gets probed
    assert choices[:4] == ["descend"] * 4
    assert choices[4] == "repeel"
    assert p.probes == 1
    # measuring the probed path resets its staleness: back to the winner
    p.observe("repeel", 4096, 0.100)
    assert p.choose(cells=4096, repeel_work=4096, budget=1 << 20) == "descend"


def test_registry_prior_warm_starts_predictions():
    reg = MetricsRegistry()
    reg.histogram("repair_phase_seconds", phase="fallback").observe(0.02)
    reg.histogram("repair_phase_seconds", phase="descend").observe(0.004)
    p = RepairPolicy()
    p.refresh_from_metrics(reg)
    # no own measurements yet: the work-blind registry prior stands in
    assert p.predict("repeel", 10_000) == pytest.approx(0.02)
    assert p.predict("descend", 10_000) == pytest.approx(0.004)
    # own measurements take precedence once they exist
    p.observe("repeel", 10_000, 0.5)
    assert p.predict("repeel", 10_000) == pytest.approx(0.5)


def test_report_counts_probes_and_decisions():
    p = RepairPolicy(probe_every=2)
    p.observe("descend", 1024, 0.001)
    p.observe("repeel", 1024, 0.1)
    for _ in range(6):
        p.choose(cells=1024, repeel_work=1024, budget=1 << 20)
    rep = p.report()
    assert rep["mode"] == "adaptive"
    assert rep["probes"] >= 1
    assert sum(rep["decisions"].values()) == 6
    assert rep["regimes"]  # learned EMA cells are exported


# ------------------------------------------------- shell-incremental peel


def _arc_arrays(g):
    e = g.edge_list()
    return (
        np.concatenate([e[:, 0], e[:, 1]]),
        np.concatenate([e[:, 1], e[:, 0]]),
    )


def test_shell_peel_exact_against_frozen_upper_shells():
    g = generators.barabasi_albert_varying(300, 5.0, seed=40)
    src, dst = _arc_arrays(g)
    oracle = core_numbers_rounds(g.n_nodes, src, dst)
    deg = np.bincount(src, minlength=g.n_nodes)
    for hi in (1, int(np.median(oracle)), int(oracle.max()) - 1):
        peel = oracle <= hi
        inner = peel[src] & peel[dst]
        core, ok = core_numbers_shell_peel(
            g.n_nodes, src[inner], dst[inner], peel, deg, hi
        )
        assert ok
        np.testing.assert_array_equal(core[peel], oracle[peel])


def test_shell_peel_detects_ceiling_violation():
    g = generators.barabasi_albert_varying(200, 5.0, seed=41)
    src, dst = _arc_arrays(g)
    oracle = core_numbers_rounds(g.n_nodes, src, dst)
    assert oracle.max() > 1
    # lie: claim every node sits at level <= 1 and peel the whole graph.
    # Survivors need k > hi, so the freeze must be disproved, not trusted.
    peel = np.ones(g.n_nodes, bool)
    deg = np.bincount(src, minlength=g.n_nodes)
    _, ok = core_numbers_shell_peel(g.n_nodes, src, dst, peel, deg, hi=1)
    assert not ok


def test_fallback_policy_stays_shell_incremental_and_exact():
    """repair_policy="fallback" re-peels every block through the shell path;
    mixed inserts/deletes down to an empty graph (shell 0) stay oracle-exact."""
    g = generators.barabasi_albert_varying(250, 4.0, seed=42)
    edges = g.edge_list()
    rng = np.random.default_rng(43)
    edges = edges[rng.permutation(len(edges))]
    dyn = DynamicGraph(g.n_nodes, width=4)
    inc = IncrementalCore(dyn, repair_policy="fallback")
    for start in range(0, len(edges), 48):
        inc.on_edge_block(dyn.add_edges(edges[start : start + 48]))
        np.testing.assert_array_equal(
            inc.core, core_numbers_host(dyn.snapshot())
        )
    assert inc.repeels > 0 and inc.descends == 0
    # drain the graph: deletion blocks drive every node to shell 0. With no
    # insertions levels only fall, so the peel window always certifies —
    # this leg is where the fallback stays genuinely shell-incremental
    # (insert blocks can push hi past the top level, degenerating to the
    # full rounds peel).
    while dyn.n_edges:
        live = dyn.snapshot().edge_list()
        inc.on_remove(dyn.remove_edges(live[:64]))
        np.testing.assert_array_equal(
            inc.core, core_numbers_host(dyn.snapshot())
        )
    assert inc.shell_repeels > 0  # the fallback stayed incremental
    assert not inc.core.any()  # everyone drifted to shell 0
    assert inc.resync() == 0


def test_shell_peel_widens_on_ceiling_hit():
    """A block that vaults low-shell nodes past the frozen ceiling must be
    caught (ok=False inside), widened geometrically, and still land exact."""
    g = generators.barabasi_albert_varying(300, 5.0, seed=44)
    dyn = DynamicGraph(g.n_nodes, width=16)
    # margin0=1: the peel window hugs the block's levels, so a big jump hits
    inc = IncrementalCore(dyn, repair_policy="fallback", margin0=1)
    inc.on_edge_block(dyn.add_edges(g.edge_list()))
    base = inc.core.copy()
    assert base.max() >= 6  # enough frozen levels above the periphery
    # clique a handful of periphery nodes: their level jumps far past hi
    low = np.argsort(base, kind="stable")[:8]
    assert base[low].max() <= 2
    block = np.array(
        [[low[i], low[j]] for i in range(8) for j in range(i + 1, 8)],
        np.int64,
    )
    widens0 = inc.shell_widens
    inc.on_edge_block(dyn.add_edges(block))
    assert inc.shell_widens > widens0
    np.testing.assert_array_equal(inc.core, core_numbers_host(dyn.snapshot()))
    assert inc.resync() == 0


# ------------------------------------------------------ adaptive == exact


def test_adaptive_policy_never_changes_results():
    """Three maintainers (adaptive / legacy region trigger / always-fallback)
    driven with the same mixed stream agree with each other and the oracle at
    every step — the policy is cost-only."""
    g = generators.barabasi_albert_varying(180, 4.0, seed=45)
    edges = g.edge_list()
    rng = np.random.default_rng(46)
    edges = edges[rng.permutation(len(edges))]
    stacks = [
        (DynamicGraph(g.n_nodes, width=4), mode)
        for mode in ("adaptive", "region", "fallback")
    ]
    incs = [
        IncrementalCore(d, repair_policy=mode) for d, mode in stacks
    ]
    live: list = []
    for step, start in enumerate(range(0, len(edges), 40)):
        block = edges[start : start + 40]
        accepted = [d.add_edges(block) for d, _ in stacks]
        for a in accepted[1:]:
            np.testing.assert_array_equal(accepted[0], a)
        for inc, a in zip(incs, accepted):
            inc.on_edge_block(a)
        live.extend(map(tuple, accepted[0]))
        if step % 2 and len(live) > 8:
            pick = rng.choice(len(live), size=6, replace=False)
            rm = np.array([live[i] for i in pick])
            for (d, _), inc in zip(stacks, incs):
                inc.on_remove(d.remove_edges(rm))
            gone = {tuple(e) for e in rm}
            live = [e for e in live if e not in gone]
        oracle = core_numbers_host(stacks[0][0].snapshot())
        for inc in incs:
            np.testing.assert_array_equal(inc.core, oracle)
    assert all(inc.resync() == 0 for inc in incs)

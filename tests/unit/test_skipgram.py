"""SGNS corpus sampling and training: loss decreases, structure is learned."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.corewalk import deepwalk_plan
from repro.graph import generators
from repro.skipgram.corpus import build_corpus, sample_batch
from repro.skipgram.model import batch_loss, init_params
from repro.skipgram.trainer import SGNSConfig, train_sgns


def _corpus(seed=0, n=60, m=3, walks=6, length=12):
    g = generators.barabasi_albert(n, m, seed=seed)
    ell = g.to_ell()
    plan = deepwalk_plan(g.n_nodes, walks)
    return g, build_corpus(ell, plan, length, jax.random.PRNGKey(seed))


def test_corpus_shapes_and_noise_cdf():
    g, corpus = _corpus()
    assert corpus.walks.shape == (g.n_nodes * 6, 12)
    cdf = np.asarray(corpus.noise_cdf)
    assert cdf.shape == (g.n_nodes,)
    assert np.all(np.diff(cdf) >= -1e-7)
    np.testing.assert_allclose(cdf[-1], 1.0, rtol=1e-5)


def test_sample_batch_contexts_are_within_window():
    _, corpus = _corpus(seed=1)
    centers, contexts, negs = sample_batch(
        corpus, jax.random.PRNGKey(0), batch=512, window=4, n_neg=5
    )
    assert centers.shape == (512,)
    assert negs.shape == (512, 5)
    walks = np.asarray(corpus.walks)
    c, x = np.asarray(centers), np.asarray(contexts)
    # every (center, context) pair must co-occur within the window in some walk
    ok = 0
    for i in range(128):
        rows, cols = np.where(walks == c[i])
        hit = False
        for r, col in zip(rows, cols):
            lo, hi = max(0, col - 4), min(walks.shape[1], col + 5)
            if x[i] in walks[r, lo:hi]:
                hit = True
                break
        ok += hit
    assert ok >= 126  # allow tiny slack for duplicate node ids


def test_training_reduces_loss():
    _, corpus = _corpus(seed=2)
    cfg = SGNSConfig(dim=32, batch=1024, epochs=0.0, seed=0, impl="ref")
    params = init_params(corpus.n_nodes, 32, jax.random.PRNGKey(0))
    c0, x0, n0 = sample_batch(corpus, jax.random.PRNGKey(9), batch=2048, window=4, n_neg=5)
    before = float(batch_loss(params, c0, x0, n0, "ref"))
    res = train_sgns(corpus, cfg, steps=300)
    params_after = {
        "emb_in": jnp.asarray(res.embeddings),
        "emb_out": params["emb_out"],
    }
    # evaluate with the trained input table against the *trained* run's loss
    assert res.final_loss < before, (res.final_loss, before)


def test_embeddings_capture_adjacency():
    """Connected pairs should score higher (dot product) than random pairs."""
    g, corpus = _corpus(seed=3, n=80, m=3, walks=10, length=20)
    cfg = SGNSConfig(dim=48, batch=2048, seed=1, impl="ref")
    res = train_sgns(corpus, cfg, steps=800)
    emb = res.embeddings
    edges = g.edge_list()
    rng = np.random.default_rng(0)
    pos = np.mean(
        [emb[u] @ emb[v] for u, v in edges[rng.permutation(len(edges))[:200]]]
    )
    neg_pairs = rng.integers(0, g.n_nodes, size=(400, 2))
    neg_pairs = [(u, v) for u, v in neg_pairs if u != v and not g.has_edge(u, v)]
    neg = np.mean([emb[u] @ emb[v] for u, v in neg_pairs])
    assert pos > neg, (pos, neg)

"""Crash safety: WAL torn-tail handling, atomic snapshots, fault plans,
input validation, crash/recover bit-identity, and graceful degradation.

Every durability claim in ``serve.recovery`` is exercised directly: a WAL
crash mid-append must leave a tail the next open truncates; a snapshot
directory without ``_COMMITTED`` (or with a corrupt manifest / payload)
must be skipped even when newest; recovery from snapshot + WAL replay must
reproduce the uninterrupted run byte-for-byte; and the degradation paths
(flush retry → stale-row fallback, transactional retrain rollback, hang
watchdog) must absorb injected faults without corrupting state.
"""
import os
import struct
import time
import zlib

import numpy as np
import pytest

from repro.graph import generators
from repro.launch.serve_embed import build_service
from repro.serve import faults
from repro.serve.faults import FaultPlan, InjectedCrash, InjectedFault
from repro.serve.recovery import (
    _HEADER,
    _MAGIC,
    KIND_INGEST,
    KIND_RETRACT,
    RecoveryManager,
    SnapshotStore,
    WriteAheadLog,
    capture_state,
)


@pytest.fixture(autouse=True)
def _no_leaked_fault_plan():
    faults.install(None)
    yield
    faults.install(None)


def _edges(*pairs):
    return np.asarray(pairs, np.int64)


# ------------------------------------------------------------------- WAL --


def test_wal_roundtrip_and_seq(tmp_path):
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path, fsync=False)
    a = _edges((0, 1), (1, 2))
    b = _edges((2, 3))
    assert wal.append(KIND_INGEST, a) == 1
    assert wal.append(KIND_RETRACT, b) == 2
    wal.close()

    wal2 = WriteAheadLog(path, fsync=False)
    assert wal2.seq == 2 and wal2.torn_truncated == 0
    recs = list(wal2.records())
    assert [(s, k) for s, k, _ in recs] == [(1, KIND_INGEST), (2, KIND_RETRACT)]
    np.testing.assert_array_equal(recs[0][2], a)
    np.testing.assert_array_equal(recs[1][2], b)
    # replay-from-offset skips already-applied records
    assert [s for s, _, _ in wal2.records(after_seq=1)] == [2]
    # appends continue the sequence
    assert wal2.append(KIND_INGEST, _edges((5, 6))) == 3
    wal2.close()


def test_wal_truncates_garbage_tail(tmp_path):
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path, fsync=False)
    wal.append(KIND_INGEST, _edges((0, 1)))
    wal.close()
    good = os.path.getsize(path)
    with open(path, "ab") as f:
        f.write(b"\x00garbage-that-is-not-a-record")

    wal2 = WriteAheadLog(path, fsync=False)
    assert wal2.seq == 1 and wal2.torn_truncated > 0
    assert os.path.getsize(path) == good
    assert len(list(wal2.records())) == 1
    wal2.close()


def test_wal_truncates_partial_record_and_bad_crc(tmp_path):
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path, fsync=False)
    wal.append(KIND_INGEST, _edges((0, 1), (1, 2)))
    wal.close()
    good = os.path.getsize(path)

    # a half-written record: header promises 4 edges, payload cut short
    head = _HEADER.pack(_MAGIC, KIND_INGEST, 2, 4)
    with open(path, "ab") as f:
        f.write(head + b"\x01" * 24)
    wal2 = WriteAheadLog(path, fsync=False)
    assert wal2.seq == 1 and wal2.torn_truncated > 0
    assert os.path.getsize(path) == good
    wal2.close()

    # a complete record with a corrupted CRC trailer
    payload = _edges((7, 8)).tobytes()
    head = _HEADER.pack(_MAGIC, KIND_INGEST, 2, 1)
    crc = struct.pack("<I", zlib.crc32(head + payload) ^ 0xFFFF)
    with open(path, "ab") as f:
        f.write(head + payload + crc)
    wal3 = WriteAheadLog(path, fsync=False)
    assert wal3.seq == 1 and wal3.torn_truncated > 0
    assert len(list(wal3.records())) == 1
    wal3.close()


def test_wal_crash_mid_append_leaves_real_torn_tail(tmp_path):
    """``wal_append`` fires mid-record: half the bytes reach the file, the
    crash propagates, and the next open truncates back to the last good
    record — exactly the torn tail a power loss mid-write produces."""
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path, fsync=False)
    wal.append(KIND_INGEST, _edges((0, 1)))
    good = os.path.getsize(path)

    faults.install(FaultPlan.parse("wal_append:1:crash"))
    with pytest.raises(InjectedCrash):
        wal.append(KIND_INGEST, _edges((2, 3), (3, 4)))
    faults.install(None)
    wal.close()
    assert os.path.getsize(path) > good  # partial bytes really hit disk

    wal2 = WriteAheadLog(path, fsync=False)
    assert wal2.seq == 1 and wal2.torn_truncated > 0
    assert os.path.getsize(path) == good
    # the log is append-ready again at the right sequence number
    assert wal2.append(KIND_RETRACT, _edges((2, 3))) == 2
    wal2.close()


def test_wal_fsync_fault_loses_record_cleanly(tmp_path):
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path, fsync=False)
    wal.append(KIND_INGEST, _edges((0, 1)))
    good = os.path.getsize(path)

    faults.install(FaultPlan.parse("wal_fsync:1"))
    with pytest.raises(InjectedFault):
        wal.append(KIND_INGEST, _edges((2, 3)))
    faults.install(None)
    # the record is gone entirely — as if the OS never wrote it back
    assert os.path.getsize(path) == good and wal.seq == 1
    assert wal.append(KIND_INGEST, _edges((2, 3))) == 2
    wal.close()


# ------------------------------------------------------------- snapshots --


def _snap_payload(seed=0, wal_seq=7):
    rng = np.random.default_rng(seed)
    arrays = {"a": rng.normal(size=(4, 3)).astype(np.float32),
              "b": np.arange(5, dtype=np.int64)}
    return arrays, {"wal_seq": wal_seq, "stats": {"queries": 3}}


def test_snapshot_roundtrip_and_gc(tmp_path):
    store = SnapshotStore(str(tmp_path), keep=2)
    for seq in (3, 7, 11):
        arrays, manifest = _snap_payload(seed=seq, wal_seq=seq)
        store.write(arrays, manifest)
    got, manifest, skipped = store.load_latest()
    assert skipped == 0 and manifest["wal_seq"] == 11
    want, _ = _snap_payload(seed=11, wal_seq=11)
    for k in want:
        np.testing.assert_array_equal(got[k], want[k])
    # retention: only the newest ``keep`` survive
    names = sorted(d for d in os.listdir(tmp_path) if d.startswith("snap_"))
    assert names == ["snap_000000000007", "snap_000000000011"]


def test_snapshot_skips_torn_dirs_even_when_newest(tmp_path):
    store = SnapshotStore(str(tmp_path), keep=5)
    arrays, manifest = _snap_payload(wal_seq=5)
    store.write(arrays, manifest)

    # newest dir, no _COMMITTED: a crash before the marker
    torn = tmp_path / "snap_000000000009"
    torn.mkdir()
    (torn / "state.npz").write_bytes(b"\x00\x01")
    got, m, skipped = store.load_latest()
    assert m["wal_seq"] == 5 and skipped == 1

    # newer still, committed but the manifest is torn mid-write
    torn2 = tmp_path / "snap_000000000010"
    torn2.mkdir()
    (torn2 / "manifest.json").write_text('{"wal_seq": 10, "npz')
    (torn2 / "_COMMITTED").write_text("ok")
    got, m, skipped = store.load_latest()
    assert m["wal_seq"] == 5 and skipped == 2

    # newest of all: committed, manifest fine, payload corrupted (CRC)
    import shutil

    torn3 = tmp_path / "snap_000000000012"
    shutil.copytree(tmp_path / "snap_000000000005", torn3)
    raw = bytearray((torn3 / "state.npz").read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    (torn3 / "state.npz").write_bytes(bytes(raw))
    got, m, skipped = store.load_latest()
    assert m["wal_seq"] == 5 and skipped == 3
    np.testing.assert_array_equal(got["a"], arrays["a"])


def test_snapshot_crash_before_commit_is_invisible(tmp_path):
    store = SnapshotStore(str(tmp_path), keep=5)
    faults.install(FaultPlan.parse("snapshot_write:1:crash"))
    with pytest.raises(InjectedCrash):
        store.write(*_snap_payload(wal_seq=3))
    faults.install(None)
    assert store.load_latest() == (None, None, 0)  # tmp dir never visible

    # crash after _COMMITTED but before the rename: tmp is garbage, a
    # retried write of the same snapshot succeeds over it
    faults.install(FaultPlan.parse("snapshot_commit:1:crash"))
    with pytest.raises(InjectedCrash):
        store.write(*_snap_payload(wal_seq=3))
    faults.install(None)
    assert store.load_latest()[1] is None
    store.write(*_snap_payload(wal_seq=3))
    assert store.load_latest()[1]["wal_seq"] == 3


# ------------------------------------------------------------ fault plans --


def test_fault_plan_parse_validates():
    with pytest.raises(ValueError, match="unknown fault point"):
        FaultPlan.parse("not_a_point:1")
    with pytest.raises(ValueError, match="bad fault spec"):
        FaultPlan.parse("wal_append")
    with pytest.raises(ValueError, match="bad fault mode"):
        FaultPlan.parse("wal_append:1:explode")
    with pytest.raises(ValueError, match="hit index"):
        FaultPlan.parse("wal_append:0")
    # every published point parses
    plan = FaultPlan.parse(",".join(f"{p}:1" for p in faults.POINTS))
    assert set(plan.rules) == set(faults.POINTS)


def test_fault_plan_hit_and_sticky_semantics():
    plan = FaultPlan.parse("repair:2,device_dispatch:3+:crash")
    faults.install(plan)
    faults.check("repair")  # hit 1: silent
    with pytest.raises(InjectedFault):
        faults.check("repair")  # hit 2: fires
    faults.check("repair")  # hit 3: one-shot rule is spent

    faults.check("device_dispatch")
    faults.check("device_dispatch")
    for _ in range(3):  # sticky: every hit from the 3rd on
        with pytest.raises(InjectedCrash):
            faults.check("device_dispatch")
    assert plan.fired == {"repair": 1, "device_dispatch": 3}
    assert plan.total_fired == 4


def test_injected_crash_is_not_an_exception():
    """The whole degradation design rests on this: ``except Exception``
    recovery paths must never swallow a simulated process death."""
    assert not issubclass(InjectedCrash, Exception)
    assert issubclass(InjectedFault, Exception)
    faults.install(FaultPlan.parse("repair:1:crash"))
    with pytest.raises(InjectedCrash):
        try:
            faults.check("repair")
        except Exception:  # noqa: BLE001 - the point of the test
            pytest.fail("InjectedCrash was swallowed by `except Exception`")


# ------------------------------------------------------- input validation --


def _svc(n=150, seed=0, **kw):
    g = generators.barabasi_albert_varying(n, 4.0, seed=seed)
    svc, stream, _, _ = build_service(g, seed=seed, batch=16,
                                      stream_frac=0.3, **kw)
    return svc, stream


def test_ingest_block_rejects_malformed_input():
    svc, _ = _svc()
    with pytest.raises(ValueError, match="non-negative"):
        svc.ingest_block(_edges((0, 1), (-3, 2)))
    with pytest.raises(ValueError, match="self-loops"):
        svc.ingest_block(_edges((0, 1), (4, 4)))
    with pytest.raises(ValueError, match="integer dtype"):
        svc.ingest_block(np.array([[0.5, 1.5]]))
    with pytest.raises(ValueError, match="integer dtype"):
        svc.ingest_block(np.array([["a", "b"]], dtype=object))
    with pytest.raises(ValueError, match=r"\(m, 2\)-shaped"):
        svc.ingest_block(np.arange(9, dtype=np.int64))
    with pytest.raises(ValueError, match="non-negative"):
        svc.retract_block(_edges((-1, 0)))
    with pytest.raises(ValueError, match="self-loops"):
        svc.retract_block(_edges((2, 2)))
    # rejected blocks mutate nothing
    assert svc.stats.edges_ingested == 0 and svc.stats.ingest_blocks == 0


def test_validation_happens_before_wal_logging(tmp_path):
    """A malformed block must not reach the durable log: replaying it after
    a crash would re-raise during recovery."""
    svc, _ = _svc()
    mgr = RecoveryManager(svc, str(tmp_path), snapshot_every=1000,
                          fsync=False)
    with pytest.raises(ValueError):
        svc.ingest_block(_edges((0, 0)))
    assert mgr.wal.seq == 0
    mgr.close()


# ------------------------------------------------- crash/recover identity --


def _ops_from(stream, block=24):
    """Deterministic ingest/retract mix: every third block retracts half of
    the block ingested two steps earlier."""
    ops = []
    blocks = [np.asarray(stream[s:s + block], np.int64)
              for s in range(0, len(stream), block)]
    for i, blk in enumerate(blocks):
        ops.append(("ingest", blk))
        if i % 3 == 2:
            prev = blocks[i - 2]
            ops.append(("retract", prev[: len(prev) // 2]))
    return ops


def _apply(svc, ops, start=0):
    for kind, blk in ops[start:]:
        (svc.ingest_block if kind == "ingest" else svc.retract_block)(blk)
    svc.sync()


def _arrays(svc):
    arrays, _ = capture_state(svc, 0)
    return arrays


def test_crash_recover_resume_matches_uninterrupted_twin(tmp_path):
    svc0, stream = _svc(n=250, seed=3)
    ops = _ops_from(stream)
    _apply(svc0, ops)
    truth = _arrays(svc0)

    svc, _ = _svc(n=250, seed=3)
    mgr = RecoveryManager(svc, str(tmp_path), snapshot_every=3, fsync=False)
    faults.install(FaultPlan.parse("ingest_apply:5:crash"))
    with pytest.raises(InjectedCrash):
        _apply(svc, ops)
    faults.install(None)
    try:
        mgr.wait()
    except BaseException:
        pass
    mgr.wal.close()

    svc2, mgr2, report = RecoveryManager.recover(
        str(tmp_path), snapshot_every=3, fsync=False
    )
    # the WAL append runs before the injected ingest_apply crash, so the
    # crashing op IS logged and replayed; ops map 1:1 onto WAL records, so
    # the durable seq is exactly the resume index
    assert report["wal_seq"] == 5 and report["replayed_records"] >= 1
    _apply(svc2, ops, start=report["wal_seq"])
    got = _arrays(svc2)
    bad = [k for k in truth
           if k not in got or not np.array_equal(truth[k], got[k])]
    assert bad == [], f"state diverged after recovery: {bad}"

    from repro.core.kcore import core_numbers_host

    oracle = core_numbers_host(svc2.graph.snapshot())
    assert (np.asarray(svc2.cores.core[: len(oracle)]) == oracle).all()
    mgr2.close()


def test_recover_requires_a_committed_snapshot(tmp_path):
    with pytest.raises(FileNotFoundError):
        RecoveryManager.recover(str(tmp_path))


# --------------------------------------------------- graceful degradation --


def test_flush_falls_back_to_stale_rows_then_recovers():
    svc, stream = _svc()
    svc.ingest_edges(stream, block_size=64)
    svc.flush_retries = 0  # no retry sleeps in tests
    known = np.arange(8)
    healthy = svc.embed(known)
    assert not svc.degraded

    faults.install(FaultPlan.parse("flush_dispatch:1+"))  # sticky fault
    degraded = svc.embed(known)
    assert svc.degraded and svc.stats.degraded_queries == len(known)
    # stale-row answers come straight from the store tiers
    np.testing.assert_array_equal(degraded, healthy)

    faults.install(None)  # the device comes back
    after = svc.embed(known)
    assert not svc.degraded  # a healthy flush clears degraded mode
    np.testing.assert_array_equal(after, healthy)


def test_flush_retry_absorbs_transient_fault():
    svc, stream = _svc()
    svc.ingest_edges(stream, block_size=64)
    svc.flush_retries, svc.retry_backoff = 2, 0.0
    faults.install(FaultPlan.parse("flush_dispatch:1"))  # one-shot fault
    out = svc.embed(np.arange(8))
    assert not svc.degraded and svc.stats.degraded_queries == 0
    assert np.isfinite(out).all()


def _attach_retrainer(svc, seed=0):
    from repro.serve.retrain import RetrainConfig, Retrainer
    from repro.skipgram.trainer import SGNSConfig

    cfg = RetrainConfig(
        n_walks=4, walk_length=8, min_sgns_steps=30,
        sgns=SGNSConfig(dim=svc.store.dim, epochs=0.05, impl="ref",
                        seed=seed),
        prop_iters=3, swap_chunk=8, seed=seed,
    )
    svc.set_retrainer(Retrainer(svc, cfg), auto=False)


@pytest.mark.parametrize("point", ["retrain_swap_chunk:2", "retrain_train:1"])
def test_failed_retrain_rolls_back_store(point):
    """A retrain that dies mid-cycle — even mid-VersionRollout, inside the
    mixed-version window — must leave the store byte-identical to before
    and zero rows on the aborted version."""
    svc, stream = _svc(n=200, seed=1, dim=16)
    svc.ingest_edges(stream, block_size=64)
    _attach_retrainer(svc)
    pre = svc.store.state_dict()
    pre_counts = svc.store.version_counts()

    faults.install(FaultPlan.parse(f"{point}:fault"))
    report = svc.maybe_retrain(force=True)
    faults.install(None)
    assert report is None and svc.stats.retrain_failures == 1
    assert svc.stats.retrains == 0
    assert svc.store.version_counts() == pre_counts
    post = svc.store.state_dict()
    bad = [k for k in pre if not np.array_equal(pre[k], post[k])]
    assert bad == [], f"store not rolled back: {bad}"

    # and with the fault gone the same forced retrain completes
    assert svc.maybe_retrain(force=True) is not None
    assert svc.stats.retrains == 1


def test_retrain_crash_passes_through_transaction():
    """InjectedCrash is process death: the transactional handler must NOT
    catch it — durable recovery owns that case."""
    svc, stream = _svc(n=200, seed=1, dim=16)
    svc.ingest_edges(stream, block_size=64)
    _attach_retrainer(svc)
    faults.install(FaultPlan.parse("retrain_plan:1:crash"))
    with pytest.raises(InjectedCrash):
        svc.maybe_retrain(force=True)
    assert svc.stats.retrain_failures == 0  # not a counted (handled) failure


def test_hang_watchdog_enters_degraded_mode():
    from repro.distributed.watchdog import HangWatchdog

    g = generators.barabasi_albert_varying(120, 4.0, seed=0)
    svc, stream, _, _ = build_service(g, seed=0, batch=16)
    svc._watchdog = HangWatchdog(0.02, svc._on_hang)
    svc._watchdog.arm()
    deadline = time.monotonic() + 2.0
    while not svc._watchdog.fired and time.monotonic() < deadline:
        time.sleep(0.005)
    assert svc.stats.hangs == 1 and svc.degraded

    # pet_watchdog only touches an armed watchdog
    svc._watchdog.disarm()
    svc.pet_watchdog()  # disarmed: no-op, must not re-arm
    assert not svc._watchdog.armed
    svc._watchdog.arm()
    svc.pet_watchdog()
    assert svc._watchdog.armed
    svc._watchdog.disarm()

"""Optimizer library: convergence + state dtype contracts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import optim


def _rosenbrock_quadratic(params):
    # simple strongly-convex quadratic
    return jnp.sum((params["w"] - 3.0) ** 2) + jnp.sum((params["b"] + 1.0) ** 2)


def _fit(opt, steps=400):
    params = {"w": jnp.zeros((4,)), "b": jnp.zeros((2,))}
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        g = jax.grad(_rosenbrock_quadratic)(params)
        upd, state = opt.update(g, state, params)
        return optim.apply_updates(params, upd), state

    for _ in range(steps):
        params, state = step(params, state)
    return params


@pytest.mark.parametrize(
    "opt",
    [
        optim.sgd(0.1, momentum=0.9),
        optim.adam(0.05),
        optim.adamw(0.05, weight_decay=0.0),
        # adafactor's update is RMS-normalised (~lr-sized steps), so it needs
        # a decaying schedule to settle — as in real large-model configs.
        optim.adafactor(
            lambda c: 0.5 / (1.0 + 0.05 * c.astype("float32")),
            min_dim_size_to_factor=1024,
        ),
    ],
    ids=["sgd", "adam", "adamw", "adafactor"],
)
def test_optimizers_converge(opt):
    params = _fit(opt)
    np.testing.assert_allclose(np.asarray(params["w"]), 3.0, atol=0.05)
    np.testing.assert_allclose(np.asarray(params["b"]), -1.0, atol=0.05)


def test_adafactor_factored_state_shapes():
    opt = optim.adafactor(0.01, min_dim_size_to_factor=8)
    params = {"m": jnp.zeros((16, 32)), "v": jnp.zeros((4,))}
    state = opt.init(params)
    assert state.vr["m"].shape == (16,)
    assert state.vc["m"].shape == (32,)
    assert state.vr["v"].shape == (4,)  # unfactored
    assert state.vc["v"] == ()


def test_adam_state_is_fp32_for_bf16_params():
    opt = optim.adam(0.01)
    params = {"w": jnp.zeros((8,), jnp.bfloat16)}
    state = opt.init(params)
    adam_state = state[0]
    assert adam_state.mu["w"].dtype == jnp.float32
    g = {"w": jnp.ones((8,), jnp.bfloat16)}
    upd, _ = opt.update(g, state, params)
    new = optim.apply_updates(params, upd)
    assert new["w"].dtype == jnp.bfloat16


def test_clip_by_global_norm():
    t = optim.clip_by_global_norm(1.0)
    g = {"a": jnp.full((4,), 10.0)}
    upd, _ = t.update(g, t.init(g), None)
    np.testing.assert_allclose(float(optim.global_norm(upd)), 1.0, rtol=1e-5)


def test_warmup_cosine_schedule_shape():
    sched = optim.warmup_cosine(1.0, 10, 100)
    vals = [float(sched(jnp.asarray(i))) for i in range(0, 100, 5)]
    assert vals[1] > vals[0]  # warming up
    assert vals[-1] < vals[3]  # decayed
    assert abs(float(sched(jnp.asarray(9))) - 1.0) < 0.11  # hits peak

"""Random-walk engine: validity, distribution, and CoreWalk budgets."""
import jax
import numpy as np
import pytest

from repro.core import corewalk, kcore
from repro.graph import generators
from repro.walks.engine import node2vec_walks, random_walks


@pytest.fixture(scope="module")
def graph():
    return generators.barabasi_albert(120, 3, seed=0)


def _assert_walks_valid(g, walks):
    walks = np.asarray(walks)
    for w in walks[:200]:
        for a, b in zip(w[:-1], w[1:]):
            assert g.has_edge(int(a), int(b)) or a == b


def test_uniform_walks_are_paths(graph):
    ell = graph.to_ell()
    roots = np.arange(graph.n_nodes, dtype=np.int32)
    walks = random_walks(ell, roots, 12, jax.random.PRNGKey(0))
    assert walks.shape == (graph.n_nodes, 12)
    assert np.all(np.asarray(walks[:, 0]) == roots)
    _assert_walks_valid(graph, walks)


def test_node2vec_walks_are_paths(graph):
    ell = graph.to_ell()
    roots = np.arange(graph.n_nodes, dtype=np.int32)
    walks = node2vec_walks(ell, roots, 10, jax.random.PRNGKey(1), p=0.5, q=2.0)
    assert walks.shape == (graph.n_nodes, 10)
    _assert_walks_valid(graph, walks)


def test_node2vec_return_bias():
    """p << 1 makes immediate backtracking much more likely than p >> 1."""
    g = generators.barabasi_albert(80, 3, seed=1)
    ell = g.to_ell()
    roots = np.zeros(4096, dtype=np.int32) + 5
    back = {}
    for p, tag in [(0.05, "low"), (20.0, "high")]:
        w = np.asarray(node2vec_walks(ell, roots, 3, jax.random.PRNGKey(2), p=p, q=1.0))
        back[tag] = np.mean(w[:, 2] == w[:, 0])
    assert back["low"] > back["high"] + 0.2


def test_uniform_step_distribution():
    """From a fixed node, the first step is ~uniform over neighbours."""
    g = generators.erdos_renyi(30, 120, seed=2)
    ell = g.to_ell()
    v = int(np.argmax(g.degrees()))
    nbrs = g.neighbours(v)
    roots = np.full(20000, v, dtype=np.int32)
    w = np.asarray(random_walks(ell, roots, 2, jax.random.PRNGKey(3)))
    counts = np.bincount(w[:, 1], minlength=g.n_nodes)[nbrs]
    freq = counts / counts.sum()
    assert np.all(np.abs(freq - 1 / len(nbrs)) < 0.02)


def test_corewalk_budgets_follow_eq13(graph):
    core = kcore.core_numbers_host(graph)
    kdeg = kcore.degeneracy(core)
    n = 15
    plan = corewalk_plan = corewalk.corewalk_plan(core, n)
    expect = np.maximum((n * core.astype(np.int64)) // kdeg, 1)
    np.testing.assert_array_equal(plan.per_node, expect)
    assert plan.n_real == expect.sum()
    # max budget reached exactly on the degeneracy core
    assert plan.per_node[core == kdeg].max() == n


def test_corewalk_reduces_corpus():
    # needs a graph with a *spread* of core numbers (plain BA is single-shell)
    g = generators.barabasi_albert_varying(300, 6.0, seed=0)
    core = kcore.core_numbers_host(g)
    dw = corewalk.deepwalk_plan(g.n_nodes, 15)
    cw = corewalk.corewalk_plan(core, 15)
    assert cw.n_real < dw.n_real  # the paper's speedup mechanism
    assert cw.reduction_vs(dw) > 1.5


def test_plan_padding():
    plan = corewalk.deepwalk_plan(10, 3, pad_to=8)
    assert plan.n_slots % 8 == 0
    assert plan.n_real == 30

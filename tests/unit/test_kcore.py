"""K-core decomposition: host peeling and JAX h-index fixpoint vs networkx."""
import networkx as nx
import numpy as np
import pytest

from repro.core import kcore
from repro.graph import generators
from repro.graph.csr import Graph


def _to_nx(g: Graph) -> nx.Graph:
    G = nx.Graph()
    G.add_nodes_from(range(g.n_nodes))
    G.add_edges_from(map(tuple, g.edge_list()))
    return G


@pytest.mark.parametrize(
    "maker",
    [
        lambda: generators.barabasi_albert(200, 3, seed=1),
        lambda: generators.erdos_renyi(150, 400, seed=2),
        lambda: generators.powerlaw_cluster(180, 4, 0.3, seed=3),
    ],
)
def test_host_core_matches_networkx(maker):
    g = maker()
    want = nx.core_number(_to_nx(g))
    got = kcore.core_numbers_host(g)
    for v in range(g.n_nodes):
        assert got[v] == want.get(v, 0), f"node {v}"


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_jax_core_matches_host(seed):
    g = generators.barabasi_albert(120, 4, seed=seed)
    host = kcore.core_numbers_host(g)
    dev = np.asarray(kcore.core_numbers_jax(g.to_ell()))
    np.testing.assert_array_equal(host, dev)


def test_kcore_subgraph_min_degree():
    g = generators.barabasi_albert(300, 5, seed=4)
    core = kcore.core_numbers_host(g)
    k = max(2, kcore.degeneracy(core) // 2)
    sub = kcore.kcore_subgraph(g, core, k)
    deg = sub.degrees()
    members = kcore.core_mask(core, k)
    assert np.all(deg[members] >= k), "k-core nodes must have degree >= k inside it"
    assert np.all(deg[~members] == 0)


def test_degeneracy_is_max_core():
    g = generators.erdos_renyi(100, 300, seed=5)
    core = kcore.core_numbers_host(g)
    kdeg = kcore.degeneracy(core)
    assert np.any(core == kdeg)
    # (kdeg+1)-core is empty
    assert not kcore.core_mask(core, kdeg + 1).any()


def test_shells_partition_nodes():
    g = generators.barabasi_albert(150, 3, seed=6)
    core = kcore.core_numbers_host(g)
    sh = kcore.shells(core)
    all_nodes = np.concatenate(list(sh.values()))
    assert len(all_nodes) == g.n_nodes
    assert len(np.unique(all_nodes)) == g.n_nodes

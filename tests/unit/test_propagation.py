"""Mean-embedding propagation: Jacobi backends vs exact solve, fixed points."""
import numpy as np
import pytest

from repro.core import kcore, propagation
from repro.graph import generators


@pytest.fixture(scope="module")
def setup():
    g = generators.barabasi_albert(150, 4, seed=0)
    core = kcore.core_numbers_host(g)
    kdeg = kcore.degeneracy(core)
    k0 = max(2, kdeg - 1)
    rng = np.random.default_rng(0)
    emb = np.zeros((g.n_nodes, 16), np.float32)
    members = core >= k0
    emb[members] = rng.standard_normal((members.sum(), 16)).astype(np.float32)
    return g, core, k0, emb


def test_embedded_rows_unchanged(setup):
    g, core, k0, emb = setup
    out = propagation.propagate(g, core, k0, emb, backend="scipy")
    members = core >= k0
    np.testing.assert_array_equal(out[members], emb[members])


def test_scipy_matches_exact_solve_single_shell(setup):
    g, core, k0, emb = setup
    # restrict to one shell: compare Jacobi vs exact on shell k0-1
    k = k0 - 1
    if not np.any(core == k):
        pytest.skip("no shell at k0-1")
    jac = propagation.propagate(g, core, k0, emb, n_iters=300, backend="scipy")
    exact = propagation.solve_shell_exact(g, core, k, emb)
    T = core == k
    np.testing.assert_allclose(jac[T], exact[T], rtol=5e-3, atol=5e-3)


def test_jax_backend_matches_scipy(setup):
    g, core, k0, emb = setup
    a = propagation.propagate(g, core, k0, emb, n_iters=40, backend="scipy")
    b = propagation.propagate(g, core, k0, emb, n_iters=40, backend="jax", impl="ref")
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_jax_backend_pallas_interpret_small():
    g = generators.barabasi_albert(40, 3, seed=1)
    core = kcore.core_numbers_host(g)
    k0 = kcore.degeneracy(core)
    rng = np.random.default_rng(1)
    emb = np.zeros((g.n_nodes, 8), np.float32)
    emb[core >= k0] = rng.standard_normal(((core >= k0).sum(), 8))
    a = propagation.propagate(g, core, k0, emb, n_iters=10, backend="jax", impl="ref")
    b = propagation.propagate(
        g, core, k0, emb, n_iters=10, backend="jax", impl="pallas_interpret"
    )
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


def test_fixed_point_property(setup):
    """At convergence every propagated node equals the mean of its allowed
    neighbours (the defining equation of §2.2)."""
    g, core, k0, emb = setup
    out = propagation.propagate(g, core, k0, emb, n_iters=500, backend="scipy")
    for k in propagation.propagation_schedule(core, k0):
        allowed = core >= k
        for t in np.where(core == k)[0][:20]:
            nbrs = [u for u in g.neighbours(t) if allowed[u]]
            if not nbrs:
                continue
            mean = out[nbrs].mean(axis=0)
            np.testing.assert_allclose(out[t], mean, rtol=2e-2, atol=2e-2)


def test_schedule_descends(setup):
    g, core, k0, _ = setup
    sched = propagation.propagation_schedule(core, k0)
    assert sched == sorted(sched, reverse=True)
    assert all(k < k0 for k in sched)

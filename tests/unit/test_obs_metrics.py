"""Metrics registry: counters/gauges/histograms, exporters, schema checks."""
import json
import os

import numpy as np
import pytest

from repro.obs import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    SchemaError,
    load_schema,
    validate,
    validate_or_raise,
)

SCHEMA_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))),
    "results", "serve_latency.schema.json",
)


# ----------------------------------------------------------- scalar metrics


def test_counter_monotone():
    c = Counter()
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(ValueError):
        c.inc(-1)


def test_gauge_set_inc_dec():
    g = Gauge()
    g.set(10)
    g.inc(2.5)
    g.dec()
    assert g.value == 11.5


# -------------------------------------------------------------- histograms


def test_histogram_percentile_exact_vs_numpy():
    rng = np.random.default_rng(0)
    xs = rng.lognormal(mean=-6, sigma=1.5, size=500)
    h = Histogram()
    for x in xs:
        h.observe(x)
    for q in (50, 90, 99):
        assert h.percentile(q) == pytest.approx(np.percentile(xs, q))
    p50, p99 = h.percentile([50, 99])
    assert p50 == pytest.approx(np.percentile(xs, 50))
    assert p99 == pytest.approx(np.percentile(xs, 99))


def test_histogram_window_overflow_keeps_latest_exact():
    h = Histogram(window=128)
    xs = np.arange(1000, dtype=np.float64) * 1e-4
    for x in xs:
        h.observe(x)
    assert h.count == 1000
    assert len(h) == 128
    # retained window = the latest 128 samples, oldest first
    np.testing.assert_allclose(h.values(), xs[-128:])
    assert h.percentile(50) == pytest.approx(np.percentile(xs[-128:], 50))
    # lifetime stats still cover everything
    assert h.sum == pytest.approx(xs.sum())
    assert h.min == xs[0] and h.max == xs[-1]
    assert int(h.counts.sum()) == 1000


def test_histogram_deque_compat_surface():
    h = Histogram(window=16)
    assert not h  # empty -> falsy (len == 0)
    h.append(0.5)
    h.append(1.5)
    assert len(h) == 2
    assert list(h) == [0.5, 1.5]
    np.testing.assert_allclose(np.asarray(h, np.float64), [0.5, 1.5])
    assert h.percentile(50) == pytest.approx(1.0)
    h.clear()
    assert len(h) == 0 and h.percentile(99) == 0.0


def test_bucket_percentile_within_bucket_resolution():
    rng = np.random.default_rng(1)
    xs = rng.lognormal(mean=-6, sigma=1.0, size=2000)
    h = Histogram()
    for x in xs:
        h.observe(x)
    for q in (50, 99):
        exact = float(np.percentile(xs, q))
        est = h.bucket_percentile(q)
        # the estimate must land inside the bucket containing the exact
        # percentile — that is what "accurate to bucket resolution" means
        i = int(np.searchsorted(h.buckets, exact, side="left"))
        lo = 0.0 if i == 0 else h.buckets[i - 1]
        hi = h.buckets[i] if i < len(h.buckets) else np.inf
        assert lo <= est <= hi, (q, exact, est, lo, hi)


def test_histogram_rejects_bad_buckets_and_window():
    with pytest.raises(ValueError):
        Histogram(np.asarray([2.0, 1.0]))
    with pytest.raises(ValueError):
        Histogram(window=0)


# ---------------------------------------------------------------- registry


def test_registry_get_or_create_and_labels():
    reg = MetricsRegistry()
    a = reg.counter("hits", shard=0)
    b = reg.counter("hits", shard=1)
    assert a is not b
    assert reg.counter("hits", shard=0) is a  # same labels -> same object
    a.inc(3)
    b.inc(4)
    assert reg.sum_series("hits") == 7
    assert reg.get("hits", shard=1) is b
    assert reg.get("absent") is None


def test_registry_kind_conflict_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(ValueError):
        reg.gauge("x")


def test_register_adopts_by_reference():
    reg = MetricsRegistry()
    h = Histogram(window=8)
    reg.register("flush_seconds", h)
    h.observe(0.25)  # owner keeps mutating its own object
    assert reg.get("flush_seconds") is h
    assert reg.snapshot()["flush_seconds"]["series"][0]["value"]["count"] == 1
    with pytest.raises(ValueError):
        reg.register("flush_seconds", Histogram())  # clobber needs replace
    h2 = Histogram()
    reg.register("flush_seconds", h2, replace=True)
    assert reg.get("flush_seconds") is h2
    with pytest.raises(ValueError):
        reg.register("flush_seconds", Counter(), replace=True)  # kind clash


def test_json_snapshot_shape(tmp_path):
    reg = MetricsRegistry()
    reg.counter("edges_total").inc(10)
    reg.gauge("resident", shard=2).set(5)
    reg.histogram("lat").observe(0.001)
    path = tmp_path / "metrics.json"
    reg.export_json(str(path))
    snap = json.loads(path.read_text())
    assert snap["edges_total"]["kind"] == "counter"
    assert snap["edges_total"]["series"][0]["value"] == 10
    assert snap["resident"]["series"][0]["labels"] == {"shard": "2"}
    hist = snap["lat"]["series"][0]["value"]
    assert hist["count"] == 1 and hist["p50"] == pytest.approx(0.001)
    # cumulative bucket counts end at the total count
    assert hist["buckets"][-1][1] == 1


def test_prometheus_exposition_format():
    reg = MetricsRegistry()
    reg.counter("requests_total", path="embed").inc(3)
    reg.gauge("rows").set(12)
    h = reg.histogram("lat_seconds", buckets=np.asarray([0.001, 0.01, 0.1]))
    for x in (0.0005, 0.005, 0.05, 0.5):
        h.observe(x)
    text = reg.to_prometheus()
    assert '# TYPE requests_total counter' in text
    assert 'requests_total{path="embed"} 3' in text
    assert "# TYPE rows gauge\nrows 12" in text
    assert "# TYPE lat_seconds histogram" in text
    # cumulative le buckets, ending with +Inf == _count
    assert 'lat_seconds_bucket{le="0.001"} 1' in text
    assert 'lat_seconds_bucket{le="0.01"} 2' in text
    assert 'lat_seconds_bucket{le="0.1"} 3' in text
    assert 'lat_seconds_bucket{le="+Inf"} 4' in text
    assert "lat_seconds_count 4" in text
    assert "lat_seconds_sum 0.5555" in text


def test_prometheus_help_lines_and_describe():
    reg = MetricsRegistry()
    reg.counter("requests_total").inc()
    reg.describe("requests_total", "Total embed requests served.")
    reg.gauge("rows").set(1)
    text = reg.to_prometheus()
    # described metric gets its text; undescribed falls back to the name
    assert "# HELP requests_total Total embed requests served." in text
    assert "# HELP rows rows" in text
    # HELP precedes TYPE for each family
    assert text.index("# HELP requests_total") < text.index(
        "# TYPE requests_total")


def test_prometheus_escapes_label_values():
    reg = MetricsRegistry()
    reg.counter("hits", path='a\\b').inc()
    reg.counter("hits", path='say "hi"').inc(2)
    reg.counter("hits", path="two\nlines").inc(3)
    text = reg.to_prometheus()
    # exposition-format escapes: \ -> \\, " -> \", newline -> \n
    assert 'hits{path="a\\\\b"} 1' in text
    assert 'hits{path="say \\"hi\\""} 2' in text
    assert 'hits{path="two\\nlines"} 3' in text
    # no raw newline may survive inside a sample line
    for line in text.splitlines():
        assert line.count('"') % 2 == 0  # quotes stay balanced per line


def test_prometheus_escapes_help_text():
    reg = MetricsRegistry()
    reg.gauge("g").set(0)
    reg.describe("g", 'multi\nline with back\\slash and "quotes"')
    text = reg.to_prometheus()
    # HELP escapes backslash and newline; quotes pass through unescaped
    assert '# HELP g multi\\nline with back\\\\slash and "quotes"' in text


def test_prometheus_backslash_before_quote_order():
    # a value ending in a backslash right before the closing quote is the
    # classic double-escape trap: \ must be escaped FIRST so the later
    # quote-escape does not get its own backslash re-escaped
    reg = MetricsRegistry()
    reg.counter("c", k='trailing\\').inc()
    assert 'c{k="trailing\\\\"} 1' in reg.to_prometheus()


# ------------------------------------------------------------------- schema


def test_validator_subset():
    schema = {
        "type": "object",
        "required": ["a", "b"],
        "properties": {
            "a": {"type": "integer", "minimum": 0},
            "b": {"type": "array", "items": {"type": "number"}},
            "c": {"enum": ["x", "y"]},
        },
    }
    assert validate({"a": 1, "b": [1.5], "c": "x"}, schema) == []
    errs = validate({"a": -1, "b": [1, "no"]}, schema)
    assert any("minimum" in e for e in errs)
    assert any("b[1]" in e for e in errs)
    errs = validate({"a": True, "b": []}, schema)  # bool is not an integer
    assert any("expected type integer" in e for e in errs)
    assert validate({"a": 0, "b": [], "c": "z"}, schema)  # enum violation
    with pytest.raises(SchemaError):
        validate_or_raise({"a": 1}, schema)


def test_checked_in_schema_accepts_benchmark_shape():
    schema = load_schema(SCHEMA_PATH)
    run_item = {
        "block_size": 256, "edges_in": 100, "edges_out": 0,
        "edges_per_s": 1e4, "seconds": 0.01, "mismatches": 0,
        "compactions": 1, "repeels": 0, "descends": 2, "phases": {},
    }
    payload = {
        "schema_version": 2,
        "n_nodes": 1000, "n_edges": 5000, "k0": 4, "ingest_edges": 800,
        "ingest_sweep": [run_item], "ingest_edges_per_s": 1e4,
        "ingest_speedup_block256_vs_per_edge": 50.0, "churn": dict(run_item),
        "core_mismatches": 0, "compactions": 3, "queries": 256, "batch": 64,
        "query_p50_s": 0.005, "query_p99_s": 0.05, "qps": 1000.0,
        "cold_start_fraction": 0.01, "unresolved": 0,
        "sharding": {"n_shards": 1},
        "obs": {
            "overhead": {"block_size": 256, "seconds_off": 0.1,
                         "seconds_on": 0.11, "overhead_pct": 1.0},
            "dispatch_cost": {"flops": 1.0},
        },
    }
    assert validate(payload, schema) == []
    # renaming a required section must fail loudly
    bad = dict(payload)
    bad["query_p99"] = bad.pop("query_p99_s")
    errs = validate(bad, schema)
    assert any("query_p99_s" in e for e in errs)

"""Row-sharded ``EmbeddingStore`` == single-device store, op for op."""
import numpy as np
import pytest

from repro.serve import EmbeddingStore, ShardPlan

DIM = 8


def _twin(capacity, node_cap, plan):
    return (
        EmbeddingStore(capacity=capacity, dim=DIM, node_cap=node_cap),
        EmbeddingStore(capacity=capacity, dim=DIM, node_cap=node_cap,
                       plan=plan),
    )


def _assert_state_equal(a, b):
    assert a.evictions == b.evictions
    assert a.spilled == b.spilled
    assert a.resident == b.resident
    assert a.version_counts() == b.version_counts()
    np.testing.assert_array_equal(a._slot_of, b._slot_of)
    # the sharded table's shard-padding rows must never hold data
    ta, tb = np.asarray(a.table()), np.asarray(b.table())
    np.testing.assert_array_equal(ta, tb[: ta.shape[0]])
    assert not tb[ta.shape[0]:].any()


def test_put_gather_promote_evict_parity_on_random_stream(plan8):
    """Identical op streams leave identical state and identical answers."""
    rng = np.random.default_rng(0)
    a, b = _twin(6, 8, plan8)
    for op in range(120):
        kind = int(rng.integers(0, 4))
        hi = a.node_cap + int(rng.integers(0, 5))
        if kind == 0:
            nodes = np.unique(rng.integers(0, hi, size=rng.integers(1, 5)))
            vecs = rng.normal(size=(len(nodes), DIM)).astype(np.float32)
            cores = rng.integers(0, 5, size=len(nodes)).astype(np.int32)
            a.put_many(nodes, vecs, cores)
            b.put_many(nodes, vecs, cores)
        elif kind == 1:
            q = rng.integers(0, hi, size=rng.integers(1, 6))
            va, fa = a.gather(q)
            vb, fb = b.gather(q)
            np.testing.assert_array_equal(fa, fb)
            np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))
        elif kind == 2:
            q = rng.integers(0, hi, size=rng.integers(1, 4))
            assert a.promote(q) == b.promote(q)
        else:
            grow = int(rng.integers(0, 2 * hi))
            a.ensure_nodes(grow)
            b.ensure_nodes(grow)
        _assert_state_equal(a, b)


def test_eviction_and_staleness_parity_under_pressure(plan8):
    """Capacity far below the working set: every eviction/spill/promotion
    decision (and the staleness signal derived from them) matches."""
    rng = np.random.default_rng(1)
    a, b = _twin(4, 16, plan8)
    cores = rng.integers(0, 6, size=64).astype(np.int32)
    for step in range(40):
        nodes = rng.integers(0, 64, size=3)
        vecs = rng.normal(size=(3, DIM)).astype(np.float32)
        a.put_many(nodes, vecs, cores[nodes])
        b.put_many(nodes, vecs, cores[nodes])
        q = rng.integers(0, 64, size=4)
        va, fa = a.gather(q)
        vb, fb = b.gather(q)
        np.testing.assert_array_equal(fa, fb)
        np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))
        drift = cores + rng.integers(0, 2, size=64).astype(np.int32)
        assert a.staleness(drift) == b.staleness(drift)
    assert a.evictions == b.evictions and a.evictions > 0
    assert a.spilled == b.spilled and a.spilled > 0


@pytest.mark.parametrize("n_shards", [2, 4, 8])
def test_parity_across_shard_counts(n_shards):
    """Every power-of-two shard count gives the same bits (capacity not a
    multiple of the shard count, so padding rows are genuinely exercised)."""
    plan = ShardPlan.build(n_shards)
    rng = np.random.default_rng(2)
    a, b = _twin(5, 8, plan)
    for _ in range(30):
        nodes = np.unique(rng.integers(0, 32, size=rng.integers(1, 4)))
        vecs = rng.normal(size=(len(nodes), DIM)).astype(np.float32)
        a.put_many(nodes, vecs, np.ones(len(nodes), np.int32))
        b.put_many(nodes, vecs, np.ones(len(nodes), np.int32))
        q = rng.integers(0, 32, size=3)
        va, fa = a.gather(q)
        vb, fb = b.gather(q)
        np.testing.assert_array_equal(fa, fb)
        np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))
        _assert_state_equal(a, b)


def test_shard_report_balance_and_traffic(plan8):
    """Accounting: resident counts split by owning shard, gather ownership
    histogram sums to gathered resident rows, copies = rows * (S - 1)."""
    st = EmbeddingStore(capacity=16, dim=DIM, node_cap=32, plan=plan8)
    rng = np.random.default_rng(3)
    st.put_many(np.arange(16), rng.normal(size=(16, DIM)).astype(np.float32),
                np.ones(16, np.int32))
    rep = st.shard_report()
    assert rep["n_shards"] == 8
    assert sum(rep["resident_per_shard"]) == 16
    _, found = st.gather(np.arange(8))
    assert found.all()
    rep = st.shard_report()
    assert sum(rep["gather_rows_per_shard"]) == 8
    assert rep["cross_shard_row_copies"] == 8 * (8 - 1)

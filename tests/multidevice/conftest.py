"""Multi-device sharding parity suite.

Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the root
``tests/conftest.py`` forces this for the whole suite, so a plain
``pytest tests/multidevice`` works too). Everything here asserts **exact**
equality between the row-sharded serve stack and the single-device path:
sharding is placement-only, so embeddings, core numbers, staleness,
eviction counts, and version histograms must match bit-for-bit.
"""
import jax
import pytest

from repro.serve import ShardPlan

N_SHARDS = 8


def pytest_collection_modifyitems(config, items):
    if jax.device_count() >= N_SHARDS:
        return
    skip = pytest.mark.skip(
        reason=f"needs {N_SHARDS} devices; set XLA_FLAGS="
               f"--xla_force_host_platform_device_count={N_SHARDS}"
    )
    for item in items:
        if item.path and "multidevice" in str(item.path):
            item.add_marker(skip)


@pytest.fixture(scope="session")
def plan8():
    return ShardPlan.build(N_SHARDS)

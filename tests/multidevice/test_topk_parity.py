"""``top_k_neighbors`` shard-count invariance + exact recall vs the oracle.

The per-shard partial top-k (``ShardPlan.partial_topk_fn``) plus the host
stitch (``merge_topk``) must return exactly the single-device result at any
shard count — any global top-k row is necessarily in its owner's local
top-k, so the stitch loses nothing. On top of parity, the merged result is
checked against a numpy all-pairs cosine oracle: recall@k must be 1.0 with
zero mismatches, ids and scores both.
"""
import jax.numpy as jnp
import numpy as np

from repro.graph import generators
from repro.kernels import ops
from repro.launch.serve_embed import build_service

K = 7


def _built(shards, seed=0, n=300):
    g = generators.barabasi_albert_varying(n, 5.0, seed=seed)
    svc, stream, _, _ = build_service(
        g, seed=seed, batch=32, capacity=0, compact_every=128,
        shards=shards,
    )
    svc.ingest_edges(stream, block_size=64)
    return svc


def _oracle(svc, q, k):
    """All-pairs cosine over resident rows, self-excluded, lexsorted."""
    st = svc.store
    tab = np.asarray(st.table())[: st.capacity]
    valid = np.asarray(st.row_valid())[: st.capacity]
    tn = np.asarray(ops.normalize_rows(jnp.asarray(tab)))
    qn = np.asarray(ops.normalize_rows(jnp.asarray(svc.embed(q))))
    sim = qn @ tn.T
    sim[:, ~valid] = -np.inf
    own = st.slots_of(np.asarray(q, np.int64))
    ids = np.full((len(q), k), -1, np.int64)
    scores = np.full((len(q), k), -np.inf, np.float32)
    for i in range(len(q)):
        s = sim[i].copy()
        if own[i] < st.capacity:
            s[own[i]] = -np.inf
        order = np.lexsort((np.arange(len(s)), -s))[:k]
        live = s[order] > -np.inf
        order = order[live]
        ids[i, : len(order)] = st.node_of_slots(order)
        scores[i, : len(order)] = s[order]
    return ids, scores


def test_topk_shard_count_invariance(plan8):
    svc1 = _built(1)
    svc2 = _built(2)
    svc8 = _built(8)
    rng = np.random.default_rng(21)
    q = rng.integers(0, svc1.graph.n_nodes, size=24)
    ids1, sc1 = svc1.top_k_neighbors(q, K)
    ids2, sc2 = svc2.top_k_neighbors(q, K)
    ids8, sc8 = svc8.top_k_neighbors(q, K)
    np.testing.assert_array_equal(ids1, ids2)
    np.testing.assert_array_equal(ids1, ids8)
    np.testing.assert_array_equal(sc1, sc2)
    np.testing.assert_array_equal(sc1, sc8)


def test_topk_recall_is_exact_at_1_and_8_shards(plan8):
    for shards in (1, 8):
        svc = _built(shards, seed=3)
        rng = np.random.default_rng(22)
        q = rng.integers(0, svc.graph.n_nodes, size=16)
        ids, scores = svc.top_k_neighbors(q, K)
        want_ids, want_scores = _oracle(svc, q, K)
        mismatches = int((ids != want_ids).sum())
        assert mismatches == 0, f"shards={shards}: {mismatches} mismatches"
        np.testing.assert_allclose(scores, want_scores, rtol=1e-5,
                                   atol=1e-6)
        # recall@k == 1.0 by construction of the exact-match check, but
        # assert the set form too so a future reordering bug reads clearly
        for i in range(len(q)):
            assert set(ids[i]) == set(want_ids[i])

"""End-to-end serving parity: ``--shards 8`` == ``--shards 1`` bit-for-bit.

The same seeded build + ingest/churn stream + query replay is driven through
the single-device service and the row-sharded one; every externally
observable output — embeddings (store hits *and* §2.2 cold-start means),
core numbers, staleness, eviction counts, cold/unresolved counters, retrain
pressure — must be exactly equal.
"""
import numpy as np

from repro.graph import generators
from repro.launch.serve_embed import build_service


def _build_pair(capacity=0, seed=0, n=400):
    g = generators.barabasi_albert_varying(n, 5.0, seed=seed)
    kw = dict(seed=seed, batch=32, capacity=capacity, compact_every=128)
    svc1, stream1, core1, k01 = build_service(g, **kw)
    svc8, stream8, core8, k08 = build_service(g, shards=8, **kw)
    np.testing.assert_array_equal(stream1, stream8)
    np.testing.assert_array_equal(core1, core8)
    assert k01 == k08
    return svc1, svc8, stream1


def test_stream_then_query_parity():
    svc1, svc8, stream = _build_pair()
    r1 = svc1.stream_with_churn(stream, block_size=64, churn=0.2,
                                rng=np.random.default_rng(11))
    r8 = svc8.stream_with_churn(stream, block_size=64, churn=0.2,
                                rng=np.random.default_rng(11))
    assert r1 == r8
    assert svc1.cores.resync() == 0 and svc8.cores.resync() == 0
    np.testing.assert_array_equal(svc1.cores.core, svc8.cores.core)

    rng = np.random.default_rng(12)
    n_now = svc1.graph.n_nodes
    for _ in range(6):
        q = rng.integers(0, n_now, size=24)
        out1 = svc1.embed(q)
        out8 = svc8.embed(q)
        np.testing.assert_array_equal(out1, out8)
    assert svc1.stats.cold_starts == svc8.stats.cold_starts
    assert svc1.stats.store_hits == svc8.stats.store_hits
    assert svc1.stats.unresolved == svc8.stats.unresolved
    assert svc1.store.evictions == svc8.store.evictions
    assert svc1.store.staleness(svc1.cores.core) == svc8.store.staleness(
        svc8.cores.core
    )
    assert svc1.store.version_counts() == svc8.store.version_counts()
    assert svc1.retrain_pressure() == svc8.retrain_pressure()


def test_parity_under_capacity_pressure():
    """Capacity << working set: LRU eviction, host spill, spill-tier serving
    and promotion churn all run — and still match exactly."""
    svc1, svc8, stream = _build_pair(capacity=48, seed=1)
    assert svc1.ingest_edges(stream, block_size=64) == svc8.ingest_edges(
        stream, block_size=64
    )
    rng = np.random.default_rng(13)
    n_now = svc1.graph.n_nodes
    for _ in range(8):
        q = rng.integers(0, n_now, size=32)
        np.testing.assert_array_equal(svc1.embed(q), svc8.embed(q))
    assert svc1.store.evictions == svc8.store.evictions
    assert svc1.store.evictions > 0  # pressure was real
    assert svc1.store.spilled == svc8.store.spilled
    assert svc1.stats.cold_starts == svc8.stats.cold_starts


def test_link_scores_parity():
    svc1, svc8, stream = _build_pair(seed=2, n=200)
    svc1.ingest_edges(stream, block_size=64)
    svc8.ingest_edges(stream, block_size=64)
    pairs = np.random.default_rng(14).integers(
        0, svc1.graph.n_nodes, size=(24, 2)
    )
    np.testing.assert_array_equal(
        svc1.link_scores(pairs), svc8.link_scores(pairs)
    )

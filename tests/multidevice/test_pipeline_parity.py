"""Pipelined block ingest == serial ingest, bit-for-bit, at 1 and 8 shards.

``EmbeddingService(pipeline=True)`` stages block N+1's host dedup while
block N's fused-descent dispatch is in flight and defers the per-block tail
to the next sync point. That overlap must be pure scheduling: twin services
driven with identical seeded streams (ingest blocks, churny retractions,
interleaved queries — queries force a mid-stream settle) must expose exactly
the same cores, embeddings, stats, and store state as ``pipeline=False``.
"""
import numpy as np
import pytest

from repro.graph import generators
from repro.launch.serve_embed import build_service


def _pair(shards, *, seed=21, n=300):
    g = generators.barabasi_albert_varying(n, 5.0, seed=seed)
    kw = dict(seed=seed, batch=32, compact_every=128, shards=shards)
    svc_p, stream_p, core_p, _ = build_service(g, pipeline=True, **kw)
    svc_s, stream_s, core_s, _ = build_service(g, pipeline=False, **kw)
    np.testing.assert_array_equal(stream_p, stream_s)
    np.testing.assert_array_equal(core_p, core_s)
    assert svc_p.pipeline and not svc_s.pipeline
    return svc_p, svc_s, stream_p


@pytest.mark.parametrize("shards", [1, 8])
def test_pipelined_ingest_matches_serial(plan8, shards):
    svc_p, svc_s, stream = _pair(shards)
    rng_q = np.random.default_rng(22)
    n_now = svc_p.graph.n_nodes
    for start in range(0, len(stream), 48):
        block = stream[start : start + 48]
        a_p = svc_p.ingest_block(block)
        a_s = svc_s.ingest_block(block)
        np.testing.assert_array_equal(a_p, a_s)
        if (start // 48) % 2:
            rm = block[: len(block) // 3]
            assert svc_p.retract_block(rm) == svc_s.retract_block(rm)
        if (start // 48) % 3 == 2:
            # queries settle the in-flight repair mid-stream
            q = rng_q.integers(0, n_now, size=16)
            np.testing.assert_array_equal(svc_p.embed(q), svc_s.embed(q))
    svc_p.sync()
    svc_s.sync()
    np.testing.assert_array_equal(svc_p.cores.core, svc_s.cores.core)
    assert svc_p.cores.resync() == 0 and svc_s.cores.resync() == 0
    assert svc_p.stats.edges_ingested == svc_s.stats.edges_ingested
    assert svc_p.stats.edges_removed == svc_s.stats.edges_removed
    assert svc_p.stats.compactions == svc_s.stats.compactions
    assert svc_p.stats.cold_starts == svc_s.stats.cold_starts
    assert svc_p.store.evictions == svc_s.store.evictions
    assert svc_p.store.version_counts() == svc_s.store.version_counts()
    assert svc_p.store.staleness(svc_p.cores.core) == svc_s.store.staleness(
        svc_s.cores.core
    )


def test_pipelined_churn_replay_matches_serial(plan8):
    """The benchmark's own churny driver, replayed on both modes at 8
    shards, produces identical result dicts (counts, retrains, drift)."""
    svc_p, svc_s, stream = _pair(8, seed=23)
    r_p = svc_p.stream_with_churn(stream, block_size=64, churn=0.2,
                                  rng=np.random.default_rng(24))
    r_s = svc_s.stream_with_churn(stream, block_size=64, churn=0.2,
                                  rng=np.random.default_rng(24))
    assert r_p == r_s
    np.testing.assert_array_equal(svc_p.cores.core, svc_s.cores.core)
    assert svc_p.cores.resync() == 0 and svc_s.cores.resync() == 0

"""Retrain + hot-swap parity: the aligned swap is shard-count invariant.

The rollout writes through ``EmbeddingStore.put_many`` (shard-local scatter
under a ``ShardPlan``) and the retrainer reads previous vectors through
``peek_many`` — placement-only paths, so a drift-triggered retrain must
produce bit-identical reports, version histograms, and served embeddings at
``--shards 1`` and ``--shards 8``.
"""
import numpy as np

from repro.core.kcore import degeneracy
from repro.graph import generators
from repro.serve import (
    DynamicGraph,
    EmbeddingService,
    EmbeddingStore,
    IncrementalCore,
    RetrainConfig,
    Retrainer,
)
from repro.skipgram.trainer import SGNSConfig

DIM = 8
N = 150


def _run_retrain(plan, *, capacity=None, seed=0):
    g = generators.barabasi_albert_varying(N, 4.0, seed=seed)
    edges = g.edge_list()
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(edges))
    base, stream = edges[perm[len(edges) // 4:]], edges[perm[: len(edges) // 4]]
    dyn = DynamicGraph(g.n_nodes, base, width=8, plan=plan)
    inc = IncrementalCore(dyn)
    store = EmbeddingStore(
        capacity=capacity or dyn.node_cap, dim=DIM, node_cap=dyn.node_cap,
        plan=plan,
    )
    emb = np.asarray(
        np.random.default_rng(seed + 1).normal(size=(g.n_nodes, DIM)),
        np.float32,
    )
    served = np.where(dyn.degrees() > 0)[0]
    store.put_many(served, emb[served], inc.core[served])
    k0 = max(2, degeneracy(inc.core) // 2)
    svc = EmbeddingService(dyn, inc, store, batch=16, k0=k0)
    inc.mark_refresh()
    svc.ingest_edges(stream, block_size=64)  # drives membership drift
    cfg = RetrainConfig(
        n_walks=3, walk_length=8, min_sgns_steps=5, prop_iters=4,
        swap_chunk=64, sgns=SGNSConfig(dim=DIM, epochs=0.05, impl="ref"),
    )
    svc.set_retrainer(Retrainer(svc, cfg))
    report = svc.maybe_retrain(force=True)
    assert report is not None
    return svc, report


def test_retrain_swap_matches_unsharded(plan8):
    svc1, rep1 = _run_retrain(None)
    svc8, rep8 = _run_retrain(plan8)
    # identical decisions and accounting
    assert rep1.k0 == rep8.k0 and rep1.core_size == rep8.core_size
    assert rep1.anchors == rep8.anchors and rep1.aligned == rep8.aligned
    assert rep1.version == rep8.version
    assert rep1.rows_swapped == rep8.rows_swapped
    assert rep1.warm_rows == rep8.warm_rows
    np.testing.assert_allclose(rep1.align_residual, rep8.align_residual,
                               rtol=1e-5)
    # identical store state after the swap
    assert svc1.store.version_counts() == svc8.store.version_counts()
    assert svc1.store.evictions == svc8.store.evictions
    assert svc1.store.staleness(svc1.cores.core) == svc8.store.staleness(
        svc8.cores.core
    )
    np.testing.assert_array_equal(svc1.cores.core, svc8.cores.core)
    # identical served embeddings, bit for bit
    nodes = list(range(svc1.graph.n_nodes))
    np.testing.assert_array_equal(svc1.embed(nodes), svc8.embed(nodes))


def test_retrain_swap_matches_under_capacity_pressure(plan8):
    """Same parity with spill in play: peek/warm-start/rollout cross tiers."""
    svc1, rep1 = _run_retrain(None, capacity=48, seed=3)
    svc8, rep8 = _run_retrain(plan8, capacity=48, seed=3)
    assert svc1.store.spilled == svc8.store.spilled
    assert rep1.rows_swapped == rep8.rows_swapped
    assert rep1.warm_rows == rep8.warm_rows
    assert svc1.store.version_counts() == svc8.store.version_counts()
    nodes = list(range(svc1.graph.n_nodes))
    np.testing.assert_array_equal(svc1.embed(nodes), svc8.embed(nodes))

"""Observability parity: metrics are shard-invariant where semantics are.

The same seeded workload runs through the single-device stack and the
8-shard one, each against its own fresh :class:`MetricsRegistry`. Counters
that describe *semantics* (edges ingested, gather requests/hits, evictions,
rows written) must be identical — sharding is placement-only — while the
sharded run's per-shard traffic gauges must be self-consistent: the
``store_gather_rows{shard=s}`` ownership histogram sums to the resident
gather hits, and the registry copies agree with the store's own counters.
"""
import numpy as np
import pytest

from repro.graph import generators
from repro.launch.serve_embed import build_service
from repro.obs import MetricsRegistry, set_metrics
from repro.obs import metrics as get_metrics

SEMANTIC_COUNTERS = [
    "serve_edges_ingested_total",
    "serve_edges_removed_total",
    "serve_queries_total",
    "serve_store_hits_total",
    "serve_cold_starts_total",
    "serve_unresolved_total",
    "graph_edges_added_total",
    "graph_edges_removed_total",
    "store_gather_requests_total",
    "store_gather_found_total",
    "store_rows_written_total",
    "store_evictions_total",
]


@pytest.fixture
def fresh_registry():
    """Isolate each run's numbers; restore the process default after."""
    prev = get_metrics()
    yield
    set_metrics(prev)


def _run(shards: int, seed: int = 0) -> tuple:
    """One seeded build + churn stream + query replay under a fresh registry.

    Returns ``(service, {counter_name: total across label sets})``.
    """
    reg = set_metrics(MetricsRegistry())
    g = generators.barabasi_albert_varying(400, 5.0, seed=seed)
    svc, stream, _, _ = build_service(
        g, seed=seed, batch=32, compact_every=128, shards=shards
    )
    svc.stream_with_churn(stream, block_size=64, churn=0.2,
                          rng=np.random.default_rng(11))
    rng = np.random.default_rng(12)
    n_now = svc.graph.n_nodes
    for _ in range(6):
        svc.embed(rng.integers(0, n_now, size=24))
    totals = {name: reg.sum_series(name) for name in SEMANTIC_COUNTERS}
    svc.publish_metrics(reg)
    return svc, reg, totals


def test_semantic_counters_shard_invariant(fresh_registry):
    _, _, t1 = _run(shards=1)
    _, _, t8 = _run(shards=8)
    assert t1 == t8
    assert t1["store_gather_requests_total"] > 0  # the workload was real


def test_shard_traffic_gauges_sum_consistent(fresh_registry):
    svc, reg, _ = _run(shards=8)
    store = svc.store
    per_shard = [
        reg.get("store_gather_rows", shard=s).value for s in range(8)
    ]
    # registry gauges mirror the store's own ownership histogram
    np.testing.assert_array_equal(per_shard, store.shard_gather_rows)
    assert sum(per_shard) > 0
    # each resident gathered row is owned by exactly one shard, so the
    # per-shard histogram partitions the resident gather traffic exactly
    found = reg.sum_series("store_gather_found_total")
    spill = reg.sum_series("store_spill_serves_total")
    assert sum(per_shard) == found - spill
    # and the stitching all-gather copies each such row to the other 7 shards
    copies = reg.get("store_cross_shard_row_copies").value
    assert copies == store.cross_shard_row_copies
    assert copies == (found - spill) * 7


def test_registries_are_isolated(fresh_registry):
    _, reg1, _ = _run(shards=1, seed=3)
    before = reg1.sum_series("serve_queries_total")
    _, reg8, _ = _run(shards=8, seed=3)
    assert reg1 is not reg8
    # the second run never leaked into the first run's registry
    assert reg1.sum_series("serve_queries_total") == before

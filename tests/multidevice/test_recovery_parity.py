"""Crash recovery is shard-count invariant.

Snapshots strip the shard padding from the store table and the ELL mirror
(``state_dict``/``from_state``), so the durable state is placement-agnostic:
a snapshot + WAL taken under ``--shards 8`` must restore bit-identically on
a single device, a single-device snapshot must restore under ``--shards 8``,
and a crash/recover/resume cycle under sharding must land on exactly the
uninterrupted single-device twin's state.
"""
import numpy as np
import pytest

from repro.graph import generators
from repro.launch.serve_embed import build_service
from repro.serve import RecoveryManager, faults
from repro.serve.faults import FaultPlan, InjectedCrash
from repro.serve.recovery import capture_state, restore_service

N = 300
SEED = 5


@pytest.fixture(autouse=True)
def _no_leaked_fault_plan():
    faults.install(None)
    yield
    faults.install(None)


def _fresh(shards=1):
    g = generators.barabasi_albert_varying(N, 4.0, seed=SEED)
    svc, stream, _, _ = build_service(
        g, seed=SEED, batch=16, stream_frac=0.4, compact_every=128,
        shards=shards,
    )
    return svc, stream


def _ops(stream, block=24):
    ops = []
    blocks = [np.asarray(stream[s:s + block], np.int64)
              for s in range(0, len(stream), block)]
    for i, blk in enumerate(blocks):
        ops.append(("ingest", blk))
        if i % 3 == 2:
            prev = blocks[i - 2]
            ops.append(("retract", prev[: len(prev) // 2]))
    return ops


def _apply(svc, ops, start=0):
    for kind, blk in ops[start:]:
        (svc.ingest_block if kind == "ingest" else svc.retract_block)(blk)
    svc.sync()


def _arrays(svc):
    arrays, _ = capture_state(svc, 0)
    return arrays


def _diff(a, b):
    return [k for k in sorted(set(a) | set(b))
            if k not in a or k not in b or not np.array_equal(a[k], b[k])]


def test_snapshot_restores_across_shard_counts(plan8):
    """capture at shards=8 -> restore at shards=1 (and the reverse) is
    byte-equal: the snapshot payload is placement-free."""
    svc8, stream = _fresh(shards=8)
    ops = _ops(stream)
    _apply(svc8, ops)
    arrays8, manifest8 = capture_state(svc8, 0)

    svc1 = restore_service(arrays8, manifest8, plan=None)  # 8 -> 1
    assert _diff(arrays8, _arrays(svc1)) == []

    arrays1, manifest1 = capture_state(svc1, 0)
    svc8b = restore_service(arrays1, manifest1, plan=plan8)  # 1 -> 8
    assert _diff(arrays8, _arrays(svc8b)) == []

    # restored services keep serving identically on both placements
    q = np.arange(16)
    np.testing.assert_array_equal(svc1.embed(q), svc8b.embed(q))
    np.testing.assert_array_equal(svc1.embed(q), svc8.embed(q))


def test_sharded_crash_recovers_on_any_shard_count(tmp_path, plan8):
    """Crash under shards=8; recover at 8 *and* at 1 from the same durable
    directory; resume both; both must equal the uninterrupted single-device
    twin byte-for-byte."""
    svc0, stream = _fresh(shards=1)
    ops = _ops(stream)
    _apply(svc0, ops)
    truth = _arrays(svc0)

    svc8, _ = _fresh(shards=8)
    mgr = RecoveryManager(svc8, str(tmp_path), snapshot_every=3, fsync=False)
    faults.install(FaultPlan.parse("ingest_apply:6:crash"))
    with pytest.raises(InjectedCrash):
        _apply(svc8, ops)
    faults.install(None)
    try:
        mgr.wait()
    except BaseException:
        pass
    mgr.wal.close()

    # recover sharded, resume, compare to the single-device twin
    r8, m8, report8 = RecoveryManager.recover(
        str(tmp_path), plan=plan8, snapshot_every=1000, fsync=False
    )
    _apply(r8, ops, start=report8["wal_seq"])
    assert _diff(truth, _arrays(r8)) == []
    m8.wal.close()

    # recover the same durable state single-device, resume, same check
    r1, m1, report1 = RecoveryManager.recover(
        str(tmp_path), plan=None, snapshot_every=1000, fsync=False
    )
    assert report1["snapshot_wal_seq"] == report8["snapshot_wal_seq"]
    _apply(r1, ops, start=report1["wal_seq"])
    assert _diff(truth, _arrays(r1)) == []
    m1.close()

"""Sharded ELL mirror + sharded block core repair == single-device, exactly.

Twin ``DynamicGraph``/``IncrementalCore`` stacks are driven with identical
seeded streams (inserts, deletions, churn, compaction boundaries); the
sharded stack must match the unsharded one *and* the peeling oracle at
every step.
"""
import numpy as np
import pytest

from repro.core.kcore import core_numbers_host
from repro.graph import generators
from repro.serve import DynamicGraph, IncrementalCore


def _mirror_equal(d1, d8):
    e1, e8 = d1.ell(), d8.ell()
    n1 = d1.node_cap + 1
    nbr8 = np.asarray(e8.neighbours)
    deg8 = np.asarray(e8.degrees)
    np.testing.assert_array_equal(np.asarray(e1.neighbours), nbr8[:n1])
    np.testing.assert_array_equal(np.asarray(e1.degrees), deg8[:n1])
    # shard-padding rows are pure sentinel
    assert (nbr8[n1:] == d8.node_cap).all()
    assert not deg8[n1:].any()


def test_mirror_parity_under_mixed_blocks_and_compaction(plan8):
    g = generators.barabasi_albert_varying(120, 4.0, seed=3)
    edges = g.edge_list()
    rng = np.random.default_rng(4)
    edges = edges[rng.permutation(len(edges))]
    d1 = DynamicGraph(g.n_nodes, width=3)
    d8 = DynamicGraph(g.n_nodes, width=3, plan=plan8)
    live = []
    for step, start in enumerate(range(0, len(edges), 24)):
        block = edges[start : start + 24]
        a1, a8 = d1.add_edges(block), d8.add_edges(block)
        np.testing.assert_array_equal(a1, a8)
        live.extend(map(tuple, a1))
        if step % 2 and len(live) > 8:
            pick = rng.choice(len(live), size=6, replace=False)
            rm = np.array([live[i] for i in pick])
            np.testing.assert_array_equal(
                d1.remove_edges(rm), d8.remove_edges(rm)
            )
            gone = {tuple(e) for e in rm}
            live = [e for e in live if e not in gone]
        if step % 3 == 2:
            d1.compact()
            d8.compact()
        _mirror_equal(d1, d8)
    assert d8.compactions >= 2


@pytest.mark.parametrize("region_impl", ["np", "jit"])
def test_block_repair_parity_insert_delete_churn(plan8, region_impl):
    """Sharded repair (host and jitted sharded region traversal) matches the
    unsharded stack and the peeling oracle on the same churny stream."""
    g = generators.barabasi_albert_varying(130, 4.0, seed=5)
    edges = g.edge_list()
    rng = np.random.default_rng(6)
    edges = edges[rng.permutation(len(edges))]
    d1 = DynamicGraph(g.n_nodes, width=3)
    d8 = DynamicGraph(g.n_nodes, width=3, plan=plan8)
    i1 = IncrementalCore(d1)
    i8 = IncrementalCore(d8, region_impl=region_impl)
    live = []
    for step, start in enumerate(range(0, len(edges), 32)):
        block = edges[start : start + 32]
        a1, a8 = d1.add_edges(block), d8.add_edges(block)
        i1.on_edge_block(a1)
        i8.on_edge_block(a8)
        live.extend(map(tuple, a1))
        if step % 2 and len(live) > 8:
            pick = rng.choice(len(live), size=6, replace=False)
            rm = np.array([live[i] for i in pick])
            i1.on_remove(d1.remove_edges(rm))
            i8.on_remove(d8.remove_edges(rm))
            gone = {tuple(e) for e in rm}
            live = [e for e in live if e not in gone]
        if step % 3 == 2:
            d1.compact()
            d8.compact()
        np.testing.assert_array_equal(i1.core, i8.core)
        np.testing.assert_array_equal(
            i8.core, core_numbers_host(d8.snapshot())
        )
    assert i8.promoted > 0 and i8.demoted > 0
    assert i8.descends > 0  # the sharded fused descent actually ran
    assert i8.resync() == 0


def test_sharded_fallback_repeel_stays_exact(plan8):
    """A graph-sized block on the sharded stack trips the bounded re-peel
    (rounds on host) and still lands on the oracle."""
    g = generators.barabasi_albert_varying(400, 5.0, seed=7)
    d8 = DynamicGraph(g.n_nodes, width=4, plan=plan8)
    i8 = IncrementalCore(d8, repeel_frac=0.05, repair_policy="region")
    i8.on_edge_block(d8.add_edges(g.edge_list()))
    assert i8.repeels >= 1
    np.testing.assert_array_equal(
        i8.core, core_numbers_host(d8.snapshot())
    )

"""Shared test configuration and fixtures.

The XLA flag below MUST be set before the first ``import jax`` anywhere in
the process: the whole suite runs against 8 forced host-platform devices so
the multi-device sharding parity tests (``tests/multidevice/``, the shard
property test in ``tests/props/``) exercise real multi-device placement on
CPU-only machines. Single-device tests are unaffected — unsharded arrays
live on device 0 exactly as before.
"""
import os

_FLAG = "--xla_force_host_platform_device_count=8"
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " " + _FLAG
    ).strip()

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def stream_case():
    """Factory for the serve-stack streaming tests' shared boilerplate.

    ``make(maker, seed=..., width=..., preload=False, **inc_kw)`` builds the
    graph, shuffles its edge list with a seeded rng, and returns
    ``(g, edges, dyn, inc)`` where ``dyn`` is a fresh ``DynamicGraph``
    (pre-loaded with every edge when ``preload=True``, empty otherwise) and
    ``inc`` an ``IncrementalCore`` over it with ``inc_kw`` forwarded.
    """
    from repro.serve import DynamicGraph, IncrementalCore

    def make(maker, *, seed=0, width=4, preload=False, shuffle=True,
             plan=None, **inc_kw):
        g = maker() if callable(maker) else maker
        edges = g.edge_list()
        if shuffle:
            rng = np.random.default_rng(seed)
            edges = edges[rng.permutation(len(edges))]
        dyn = DynamicGraph(
            g.n_nodes, edges if preload else None, width=width, plan=plan
        )
        inc = IncrementalCore(dyn, **inc_kw)
        return g, edges, dyn, inc

    return make

"""Row-masked h-index kernel vs the sort-based oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

CASES = [
    (1, 1),
    (3, 5),
    (8, 16),
    (17, 130),  # unaligned rows and lanes exercise both paddings
    (128, 256),
    (5, 300),
    (200, 7),
]


def _inputs(R, W, seed=0, max_val=25):
    rng = np.random.default_rng(seed)
    vals = jnp.asarray(rng.integers(0, max_val, (R, W)).astype(np.int32))
    valid = jnp.asarray(rng.random((R, W)) < 0.6)
    est = jnp.asarray(rng.integers(0, max_val + 5, R).astype(np.int32))
    return vals, valid, est


def _h_oracle(vals, valid, est):
    """Brute-force per-row h-index, independent of both implementations."""
    out = np.zeros(len(vals), np.int64)
    for i in range(len(vals)):
        row = np.sort(np.asarray(vals[i])[np.asarray(valid[i])])[::-1]
        h = 0
        for j, v in enumerate(row, start=1):
            if v >= j:
                h = j
        out[i] = min(h, int(est[i]))
    return out


@pytest.mark.parametrize("R,W", CASES)
@pytest.mark.parametrize("impl", ["count", "pallas_interpret"])
def test_h_index_matches_ref(R, W, impl):
    vals, valid, est = _inputs(R, W, seed=R * 31 + W)
    want = np.asarray(ref.h_index_ref(vals, valid, est))
    got = np.asarray(ops.h_index_sweep(vals, valid, est, impl=impl))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("seed", range(5))
def test_ref_matches_brute_force(seed):
    vals, valid, est = _inputs(13, 21, seed=seed)
    want = _h_oracle(vals, valid, est)
    got = np.asarray(ref.h_index_ref(vals, valid, est))
    np.testing.assert_array_equal(got, want)


def test_all_invalid_rows_are_zero():
    vals, valid, est = _inputs(6, 9, seed=3)
    valid = valid.at[2].set(False)
    for impl in ["ref", "count", "pallas_interpret"]:
        got = np.asarray(ops.h_index_sweep(vals, valid, est, impl=impl))
        assert got[2] == 0, impl


def test_est_caps_the_h_index():
    # a row of large values has H = W; est must clip it
    vals = jnp.full((4, 16), 100, jnp.int32)
    valid = jnp.ones((4, 16), bool)
    est = jnp.asarray([0, 3, 16, 99], jnp.int32)
    for impl in ["ref", "count", "pallas_interpret"]:
        got = np.asarray(ops.h_index_sweep(vals, valid, est, impl=impl))
        np.testing.assert_array_equal(got, [0, 3, 16, 16], impl)


def test_h_index_bounds():
    vals, valid, est = _inputs(32, 40, seed=9)
    got = np.asarray(ops.h_index_sweep(vals, valid, est, impl="count"))
    assert np.all(got >= 0)
    assert np.all(got <= np.asarray(est))
    assert np.all(got <= np.asarray(valid).sum(axis=1))

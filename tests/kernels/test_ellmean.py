"""ELL neighbour-mean DMA kernel vs oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

CASES = [
    (8, 4, 16, 128),
    (16, 7, 32, 128),
    (5, 3, 8, 150),  # unaligned D exercises padding
    (12, 1, 4, 256),
]


def _inputs(N, L, M, D, dtype, seed=0):
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, M, size=(N, L)).astype(np.int32)
    valid = rng.random((N, L)) < 0.7
    emb = rng.standard_normal((M, D)).astype(np.float32)
    return (
        jnp.asarray(idx),
        jnp.asarray(valid),
        jnp.asarray(emb, dtype=dtype),
    )


@pytest.mark.parametrize("N,L,M,D", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ell_mean_matches_ref(N, L, M, D, dtype):
    idx, valid, emb = _inputs(N, L, M, D, dtype)
    got = ops.ell_mean(idx, valid, emb, impl="pallas_interpret")
    want = ref.ell_mean_ref(idx, valid, emb)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_ell_mean_empty_rows_are_zero():
    idx, valid, emb = _inputs(6, 5, 10, 128, jnp.float32, seed=1)
    valid = valid.at[2].set(False)
    got = ops.ell_mean(idx, valid, emb, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(got[2]), 0.0, atol=1e-7)


def test_ell_mean_ref_is_row_mean():
    # all-valid single neighbour -> exactly that row
    emb = jnp.arange(40, dtype=jnp.float32).reshape(10, 4)
    idx = jnp.array([[3], [7]], jnp.int32)
    valid = jnp.ones((2, 1), bool)
    out = ref.ell_mean_ref(idx, valid, emb)
    np.testing.assert_allclose(np.asarray(out), np.asarray(emb[jnp.array([3, 7])]))

"""Flash-decode GQA attention kernel vs oracle (softcap/window/ragged sweeps)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

CASES = [
    # B, H, Hkv, Dh, S
    (2, 8, 4, 128, 512),
    (1, 4, 4, 128, 1024),  # MHA (G=1)
    (2, 16, 2, 128, 256),
    (3, 8, 8, 256, 512),
]


def _inputs(B, H, Hkv, Dh, S, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, H, Dh), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, Dh), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, Dh), dtype)
    cache_len = jax.random.randint(ks[3], (B,), 1, S + 1)
    return q, k, v, cache_len


@pytest.mark.parametrize("B,H,Hkv,Dh,S", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_matches_ref(B, H, Hkv, Dh, S, dtype):
    q, k, v, cache_len = _inputs(B, H, Hkv, Dh, S, dtype)
    got = ops.decode_attention(q, k, v, cache_len, impl="pallas_interpret", block_s=128)
    want = ref.decode_attention_ref(q, k, v, cache_len)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("softcap,window", [(50.0, 0), (0.0, 128), (30.0, 64)])
def test_decode_variants_match_ref(softcap, window):
    q, k, v, cache_len = _inputs(2, 8, 4, 128, 512, jnp.float32, seed=1)
    got = ops.decode_attention(
        q, k, v, cache_len, softcap=softcap, window=window,
        impl="pallas_interpret", block_s=128,
    )
    want = ref.decode_attention_ref(q, k, v, cache_len, softcap=softcap, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_decode_ref_matches_full_softmax():
    """Oracle vs direct full-cache softmax (no masking subtleties: full cache)."""
    B, H, Hkv, Dh, S = 2, 8, 4, 64, 128
    q, k, v, _ = _inputs(B, H, Hkv, Dh, S, jnp.float32, seed=2)
    cache_len = jnp.full((B,), S, jnp.int32)
    want = ref.decode_attention_ref(q, k, v, cache_len)
    G = H // Hkv
    qf = q.reshape(B, Hkv, G, Dh)
    logits = jnp.einsum("bhgd,bshd->bhgs", qf, k) / np.sqrt(Dh)
    p = jax.nn.softmax(logits, -1)
    direct = jnp.einsum("bhgs,bshd->bhgd", p, v).reshape(B, H, Dh)
    np.testing.assert_allclose(np.asarray(want), np.asarray(direct), rtol=1e-5, atol=1e-5)


def test_decode_ragged_lengths_ignore_padding():
    q, k, v, _ = _inputs(2, 8, 4, 128, 512, jnp.float32, seed=3)
    cache_len = jnp.array([100, 333], jnp.int32)
    out1 = ops.decode_attention(q, k, v, cache_len, impl="pallas_interpret", block_s=128)
    # poison the padding region; result must not change
    poison = k.at[0, 100:].set(1e4).at[1, 333:].set(1e4)
    out2 = ops.decode_attention(q, poison, v, cache_len, impl="pallas_interpret", block_s=128)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)

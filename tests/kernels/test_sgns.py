"""SGNS fused kernel vs pure-jnp oracle: shape/dtype sweeps + gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [
    (8, 128, 5),
    (32, 128, 1),
    (64, 256, 8),
    (16, 150, 5),  # paper's dim=150 (non-aligned, exercises padding)
    (256, 128, 20),
]


def _inputs(B, D, K, dtype, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    center = jax.random.normal(k1, (B, D), dtype) * 0.3
    ctx = jax.random.normal(k2, (B, D), dtype) * 0.3
    neg = jax.random.normal(k3, (B, K, D), dtype) * 0.3
    return center, ctx, neg


@pytest.mark.parametrize("B,D,K", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sgns_loss_matches_ref(B, D, K, dtype):
    center, ctx, neg = _inputs(B, D, K, dtype)
    got = ops.sgns_loss(center, ctx, neg, impl="pallas_interpret")
    want = ref.sgns_loss_ref(center, ctx, neg)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


@pytest.mark.parametrize("B,D,K", [(8, 128, 5), (16, 150, 3)])
def test_sgns_grads_match_autodiff_of_ref(B, D, K):
    center, ctx, neg = _inputs(B, D, K, jnp.float32, seed=1)

    def mean_pallas(c, x, n):
        return ops.sgns_loss(c, x, n, impl="pallas_interpret").mean()

    def mean_ref(c, x, n):
        return ref.sgns_loss_ref(c, x, n).mean()

    g_pallas = jax.grad(mean_pallas, argnums=(0, 1, 2))(center, ctx, neg)
    g_ref = jax.grad(mean_ref, argnums=(0, 1, 2))(center, ctx, neg)
    for gp, gr in zip(g_pallas, g_ref):
        np.testing.assert_allclose(np.asarray(gp), np.asarray(gr), rtol=1e-5, atol=1e-6)


def test_sgns_analytic_grads_match_autodiff():
    center, ctx, neg = _inputs(16, 128, 4, jnp.float32, seed=2)
    dout = jax.random.normal(jax.random.PRNGKey(3), (16,))
    want = jax.vjp(ref.sgns_loss_ref, center, ctx, neg)[1](dout)
    got = ref.sgns_grads_ref(center, ctx, neg, dout)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-5, atol=1e-6)


def test_sgns_loss_value_sanity():
    # identical center/context with zero negatives: loss = softplus(-|c|^2)
    c = jnp.ones((4, 128), jnp.float32) * 0.1
    neg = jnp.zeros((4, 2, 128), jnp.float32)
    loss = ops.sgns_loss(c, c, neg, impl="ref")
    expect = jax.nn.softplus(-jnp.sum(c * c, -1)) + 2 * jnp.log(2.0)
    np.testing.assert_allclose(np.asarray(loss), np.asarray(expect), rtol=1e-6)

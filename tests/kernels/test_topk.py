"""Blockwise top-k kernel vs the sort-based ref and a numpy oracle.

The contract under test (shared by ``ref.topk_ref`` and the Pallas kernel
behind ``ops.top_k_scores``): per-query top-k rows of ``q @ table.T`` under
the total order (score desc, index asc), masked rows excluded, -inf / -1
padding when fewer than k valid candidates exist.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

# (Q, N, D, k) — unaligned shapes exercise every padding path (sublane,
# lane, table-block); k > 128 exercises the Kp lane padding; k > N the
# short-candidate padding
CASES = [
    (1, 1, 1, 1),
    (4, 100, 16, 5),
    (8, 1024, 32, 10),
    (3, 7, 8, 10),       # k > N: every valid row returned, rest padded
    (17, 513, 130, 13),  # nothing aligned
    (2, 300, 8, 140),    # k past one lane width
]


def _inputs(Q, N, D, seed=0, density=0.8):
    rng = np.random.default_rng(seed)
    q = rng.normal(size=(Q, D)).astype(np.float32)
    table = rng.normal(size=(N, D)).astype(np.float32)
    valid = rng.random(N) < density
    return q, table, valid


def _oracle(q, table, k, valid=None):
    """Brute-force all-pairs scores + lexsort, independent of both impls."""
    scores = q.astype(np.float64) @ table.astype(np.float64).T
    scores = scores.astype(np.float32)
    if valid is not None:
        scores[:, ~np.asarray(valid)] = -np.inf
    Q, N = scores.shape
    vals = np.full((Q, k), -np.inf, np.float32)
    idx = np.full((Q, k), -1, np.int64)
    for i in range(Q):
        order = np.lexsort((np.arange(N), -scores[i]))[: min(k, N)]
        keep = scores[i][order] > -np.inf
        order = order[keep]
        vals[i, : len(order)] = scores[i][order]
        idx[i, : len(order)] = order
    return vals, idx


@pytest.mark.parametrize("Q,N,D,k", CASES)
def test_ref_matches_oracle(Q, N, D, k):
    q, table, valid = _inputs(Q, N, D, seed=Q * 7 + N)
    want_v, want_i = _oracle(q, table, k, valid)
    got_v, got_i = ref.topk_ref(
        jnp.asarray(q), jnp.asarray(table), k, valid=jnp.asarray(valid)
    )
    np.testing.assert_array_equal(np.asarray(got_i, np.int64), want_i)
    np.testing.assert_allclose(np.asarray(got_v), want_v, rtol=1e-5,
                               atol=1e-6)


@pytest.mark.parametrize("Q,N,D,k", CASES)
def test_pallas_interpret_matches_ref(Q, N, D, k):
    q, table, valid = _inputs(Q, N, D, seed=Q * 13 + N + 1)
    want_v, want_i = ref.topk_ref(
        jnp.asarray(q), jnp.asarray(table), k, valid=jnp.asarray(valid)
    )
    got_v, got_i = ops.top_k_scores(
        jnp.asarray(q), jnp.asarray(table), k, valid=jnp.asarray(valid),
        impl="pallas_interpret",
    )
    # index equality is exact (shared total order breaks every tie)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_allclose(np.asarray(got_v), np.asarray(want_v),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("impl", ["ref", "pallas_interpret"])
def test_ties_break_toward_lower_index(impl):
    # duplicate rows -> identical scores; the lower row index must win
    rng = np.random.default_rng(4)
    base = rng.normal(size=(5, 16)).astype(np.float32)
    table = np.tile(base, (4, 1))  # rows i, i+5, i+10, i+15 identical
    q = base[:2]
    vals, idx = ops.top_k_scores(
        jnp.asarray(q), jnp.asarray(table), 6, impl=impl
    )
    idx = np.asarray(idx)
    # each query's own row scores highest, then its three clones in order
    assert idx[0, 0] == 0 and idx[1, 0] == 1
    np.testing.assert_array_equal(idx[0, :4], [0, 5, 10, 15])
    np.testing.assert_array_equal(idx[1, :4], [1, 6, 11, 16])


@pytest.mark.parametrize("impl", ["ref", "pallas_interpret"])
def test_all_rows_masked_is_fully_padded(impl):
    q, table, _ = _inputs(3, 40, 8, seed=6)
    valid = jnp.zeros(40, bool)
    vals, idx = ops.top_k_scores(
        jnp.asarray(q), jnp.asarray(table), 4, valid=valid, impl=impl
    )
    np.testing.assert_array_equal(np.asarray(idx), -1)
    assert np.all(np.asarray(vals) == -np.inf)


@pytest.mark.parametrize("impl", ["ref", "pallas_interpret"])
def test_k_exceeding_valid_rows_pads_tail(impl):
    q, table, _ = _inputs(2, 20, 8, seed=8)
    valid = np.zeros(20, bool)
    valid[[3, 11, 17]] = True
    vals, idx = ops.top_k_scores(
        jnp.asarray(q), jnp.asarray(table), 7, valid=jnp.asarray(valid),
        impl=impl,
    )
    vals, idx = np.asarray(vals), np.asarray(idx)
    assert np.all(np.isin(idx[:, :3], [3, 11, 17]))
    np.testing.assert_array_equal(idx[:, 3:], -1)
    assert np.all(vals[:, 3:] == -np.inf)
    # returned scores are ordered descending among the filled lanes
    assert np.all(np.diff(vals[:, :3], axis=1) <= 0)


def test_block_streaming_is_shape_invariant():
    """The per-block tournament must not depend on the block size."""
    q, table, valid = _inputs(4, 1024, 32, seed=10)
    ref_v, ref_i = ops.top_k_scores(
        jnp.asarray(q), jnp.asarray(table), 9, valid=jnp.asarray(valid),
        impl="pallas_interpret", block_n=1024,
    )
    for bn in (128, 256, 512):
        got_v, got_i = ops.top_k_scores(
            jnp.asarray(q), jnp.asarray(table), 9, valid=jnp.asarray(valid),
            impl="pallas_interpret", block_n=bn,
        )
        np.testing.assert_array_equal(np.asarray(got_i), np.asarray(ref_i))
        np.testing.assert_allclose(np.asarray(got_v), np.asarray(ref_v),
                                   rtol=1e-6)

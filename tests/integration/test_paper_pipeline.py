"""End-to-end reproduction of the paper's protocol on a small graph:

edge split -> (DeepWalk | CoreWalk | k-core+propagation) -> logistic
regression -> F1. Asserts the qualitative claims: all pipelines beat chance,
CoreWalk shrinks the corpus, k-core pipelines cut SGNS steps further.
"""
import numpy as np
import pytest

from repro.core import kcore
from repro.core.pipeline import EmbedConfig, embed_graph
from repro.eval.linkpred import evaluate_link_prediction
from repro.graph import generators, splits
from repro.skipgram.trainer import SGNSConfig


@pytest.fixture(scope="module")
def setting():
    g = generators.barabasi_albert_varying(240, 7.0, seed=0)
    sp = splits.make_link_split(g, 0.1, seed=0)
    return g, sp


def _run(sp, method, k0=None, steps_scale=1.0):
    cfg = EmbedConfig(
        method=method,
        k0=k0,
        n_walks=8,
        walk_length=16,
        sgns=SGNSConfig(dim=32, batch=1024, epochs=0.4, impl="ref", seed=0),
        prop_iters=25,
    )
    return embed_graph(sp.train_graph, cfg)


def test_deepwalk_beats_chance(setting):
    g, sp = setting
    res = _run(sp, "deepwalk")
    pairs, labels = sp.eval_arrays()
    lp = evaluate_link_prediction(res.embeddings, pairs, labels, seed=0)
    assert lp.f1 > 0.55, lp
    assert not np.isnan(res.embeddings).any()


def test_corewalk_shrinks_corpus_keeps_quality(setting):
    g, sp = setting
    dw = _run(sp, "deepwalk")
    cw = _run(sp, "corewalk")
    assert cw.n_walks_run < dw.n_walks_run
    assert cw.n_sgns_steps < dw.n_sgns_steps
    pairs, labels = sp.eval_arrays()
    f1_dw = evaluate_link_prediction(dw.embeddings, pairs, labels, seed=0).f1
    f1_cw = evaluate_link_prediction(cw.embeddings, pairs, labels, seed=0).f1
    # paper: CoreWalk holds or improves F1 at a x2-3 corpus reduction
    assert f1_cw > f1_dw - 0.12, (f1_cw, f1_dw)


def test_kcore_propagation_pipeline(setting):
    g, sp = setting
    core = kcore.core_numbers_host(sp.train_graph)
    kdeg = kcore.degeneracy(core)
    k0 = max(2, kdeg // 2)
    res = _run(sp, "deepwalk", k0=k0)
    # every node embedded (propagation filled the shells)
    norms = np.linalg.norm(res.embeddings, axis=1)
    deg = sp.train_graph.degrees()
    assert (norms[deg > 0] > 0).mean() > 0.99
    assert not np.isnan(res.embeddings).any()
    pairs, labels = sp.eval_arrays()
    lp = evaluate_link_prediction(res.embeddings, pairs, labels, seed=0)
    assert lp.f1 > 0.5, lp
    # embeds fewer walks than the full-graph baseline
    full = _run(sp, "deepwalk")
    assert res.n_walks_run < full.n_walks_run
    assert res.times["propagation"] > 0


def test_time_breakdown_reported(setting):
    g, sp = setting
    res = _run(sp, "deepwalk", k0=2)
    for key in ("decomposition", "walks", "embedding", "propagation", "total"):
        assert key in res.times and res.times[key] >= 0

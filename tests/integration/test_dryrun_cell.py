"""The dry-run deliverable, in CI form: lower+compile real cells on the
production 512-device mesh inside a subprocess (so the main session keeps its
1-device view). Uses the cheapest cells; the full 66-cell sweep output is
checked into results/dryrun.json by launch/dryrun.py.
"""
import json
import os
import subprocess
import sys

import pytest


def _run_cells(tmp_path, arch, shapes, mesh):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath("src")
    out = tmp_path / "dr.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--mesh", mesh,
         "--arch", arch, "--shape", shapes, "--out", str(out)],
        capture_output=True, text=True, env=env, timeout=1200,
    )
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    with open(out) as f:
        return json.load(f)


def test_dryrun_decode_cell_single_pod(tmp_path):
    recs = _run_cells(tmp_path, "mamba2-2.7b", "decode_32k", "single")
    (rec,) = recs
    assert rec["status"] == "ok"
    assert rec["flops"] > 0
    assert rec["memory"]["temp_bytes"] > 0
    # decode collectives go over collective-permute/all-gather on this config
    assert sum(rec["collective_counts"].values()) > 0


def test_dryrun_multi_pod_mesh_shards_pod_axis(tmp_path):
    recs = _run_cells(tmp_path, "mamba2-2.7b", "train_4k", "multi")
    (rec,) = recs
    assert rec["status"] == "ok"
    # pod axis is pure DP: the gradient all-reduce must exist
    assert rec["collective_bytes"]["all-reduce"] > 0


def test_dryrun_skips_long500k_for_full_attention(tmp_path):
    recs = _run_cells(tmp_path, "qwen3-4b", "long_500k", "single")
    (rec,) = recs
    assert rec["status"] == "skip"
    assert "sub-quadratic" in rec["reason"]


def test_full_sweep_results_are_green():
    """The checked-in sweep (launch/dryrun.py over all cells) has no failures
    and covers every (arch, shape, mesh) combination."""
    path = "results/dryrun.json"
    if not os.path.exists(path):
        pytest.skip("full sweep not yet run in this checkout")
    with open(path) as f:
        recs = json.load(f)
    by_status = {}
    for r in recs:
        by_status.setdefault(r["status"], []).append(r)
    assert not by_status.get("fail"), [
        (r["arch"], r["shape"], r["mesh"]) for r in by_status.get("fail", [])
    ]
    oks = by_status.get("ok", [])
    assert len(oks) >= 64  # 32 live LM cells + graph cell, on two meshes
    meshes = {r["mesh"] for r in oks}
    assert meshes == {"single", "multi"}

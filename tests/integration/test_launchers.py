"""Launcher integration: train loop with checkpoint/resume + serving loop."""
import json
import os

import numpy as np
import pytest

from repro.launch.serve import main as serve_main
from repro.launch.train import main as train_main


def test_train_checkpoint_resume_continuity(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    losses1 = train_main([
        "--arch", "qwen3-4b", "--preset", "reduced", "--steps", "8",
        "--batch", "2", "--seq", "32", "--ckpt-dir", ckpt, "--ckpt-every", "4",
    ])
    assert len(losses1) == 8
    # resume: picks up from step 8, runs 4 more
    losses2 = train_main([
        "--arch", "qwen3-4b", "--preset", "reduced", "--steps", "12",
        "--batch", "2", "--seq", "32", "--ckpt-dir", ckpt, "--ckpt-every", "4",
    ])
    assert len(losses2) == 4
    # training is making progress across the restart
    assert np.mean(losses2) < np.mean(losses1[:4])
    # metrics file written
    recs = [json.loads(l) for l in open(os.path.join(ckpt, "metrics.jsonl"))]
    assert {r["step"] for r in recs} == set(range(12))


def test_train_with_grad_compression(tmp_path):
    losses = train_main([
        "--arch", "qwen3-4b", "--preset", "reduced", "--steps", "6",
        "--batch", "2", "--seq", "32", "--compress-grads",
        "--metrics", str(tmp_path / "m.jsonl"),
    ])
    assert losses[-1] < losses[0]  # int8+EF still converges


def test_train_with_accumulation(tmp_path):
    losses = train_main([
        "--arch", "qwen3-4b", "--preset", "reduced", "--steps", "4",
        "--batch", "4", "--seq", "32", "--accum", "2",
        "--metrics", str(tmp_path / "m.jsonl"),
    ])
    assert np.isfinite(losses).all()


def test_serve_continuous_batching():
    n = serve_main([
        "--arch", "qwen3-4b", "--preset", "reduced", "--slots", "2",
        "--requests", "5", "--prompt-len", "8", "--max-new", "4",
    ])
    assert n >= 5 * 4  # every request got its budget

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes, and record the roofline inputs.

For each cell this script:
  1. builds the step (train_step with optimizer, prefill_step, or decode_step),
  2. jits it with in/out shardings derived from the logical rules,
  3. ``.lower().compile()`` — a failure here (sharding mismatch, OOM at
     compile, unsupported collective) is a bug in the system,
  4. prints ``compiled.memory_analysis()`` (proves it fits) and
     ``cost_analysis()`` (FLOPs/bytes for §Roofline),
  5. parses the post-SPMD HLO for collective bytes,
  6. appends a JSON record consumed by benchmarks/roofline.py.

Usage:
  python -m repro.launch.dryrun --mesh single --arch all --shape all
  python -m repro.launch.dryrun --mesh multi  --arch gemma2-2b --shape train_4k
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, get_config, sharding_overrides
from repro.configs.deepwalk_web import CONFIG as DW_CONFIG
from repro.configs.shapes import (
    SHAPES,
    batch_logical_names,
    input_specs,
    shape_supported,
)
from repro.distributed.sharding import sharding_scope, tree_shardings
from repro.launch.mesh import make_production_mesh, use_mesh
from repro.models.steps import make_decode_step, make_prefill_step, make_train_step
from repro.models.transformer import cache_specs, init_model, model_specs
from repro.train import optim

DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
               "pred": 1, "f64": 8, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
               "s16": 2, "u16": 2, "bf8": 1}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """bytes of an HLO shape string like 'bf16[16,512,128]{2,1,0}'."""
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def parse_collective_bytes(hlo_text: str):
    """Sum per-collective operand/result bytes from post-SPMD HLO."""
    out = {c: 0 for c in COLLECTIVES}
    counts = {c: 0 for c in COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (.+?) (all-gather|all-reduce|"
                     r"reduce-scatter|all-to-all|collective-permute)", line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        out[op] += _shape_bytes(shape_str)
        counts[op] += 1
    return out, counts


def _avals(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def build_cell(arch: str, shape_name: str, mesh):
    """Returns (fn, args_avals, in_shardings, donate) for one cell."""
    shape = SHAPES[shape_name]
    if arch == DW_CONFIG.name:
        return build_graph_cell(shape, mesh)
    cfg = get_config(arch)
    ok, why = shape_supported(cfg, shape)
    if not ok:
        raise SkipCell(why)

    params_avals = jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg))
    p_specs = model_specs(cfg)
    params_sh = tree_shardings(params_avals, p_specs)

    if shape.kind == "train":
        opt = optim.make_optimizer(cfg.optimizer, 1e-4)
        opt_avals = jax.eval_shape(opt.init, params_avals)
        opt_specs = optim.optimizer_state_specs(cfg.optimizer, params_avals, p_specs)
        opt_sh = tree_shardings(opt_avals, opt_specs)
        (batch_avals,) = input_specs(cfg, shape)
        batch_sh = tree_shardings(batch_avals, batch_logical_names(cfg, train=True))
        step = make_train_step(cfg, opt, accum_steps=ACCUM_OVERRIDES.get(arch, 1))
        return (
            step,
            (params_avals, opt_avals, batch_avals),
            (params_sh, opt_sh, batch_sh),
            (0, 1),
        )

    if shape.kind == "prefill":
        (batch_avals,) = input_specs(cfg, shape)
        batch_sh = tree_shardings(batch_avals, batch_logical_names(cfg, train=False))
        step = make_prefill_step(cfg)
        return step, (params_avals, batch_avals), (params_sh, batch_sh), ()

    # decode
    cache_avals, tok_aval = input_specs(cfg, shape)
    cache_sh = tree_shardings(cache_avals, cache_specs(cfg))
    tok_sh = tree_shardings(tok_aval, ("batch", None))
    step = make_decode_step(cfg)
    return step, (params_avals, cache_avals, tok_aval), (params_sh, cache_sh, tok_sh), (1,)


class SkipCell(Exception):
    pass


# Microbatch gradient accumulation for the biggest trainers: shrinks remat
# carries and per-layer backward peaks by the accumulation factor (the
# standard grok-scale answer). One scan body either way — compile stays flat.
ACCUM_OVERRIDES = {"grok-1-314b": 4, "nemotron-4-15b": 2}


def build_graph_cell(shape, mesh):
    """The paper's own workload: sharded SGNS train step (deepwalk-web1b)."""
    from repro.skipgram.model import batch_loss

    c = DW_CONFIG
    V, D, K, B = c.n_nodes, c.dim, c.n_neg, c.global_batch
    pdt = jnp.dtype(c.param_dtype)
    params_avals = {
        "emb_in": jax.ShapeDtypeStruct((V, D), pdt),
        "emb_out": jax.ShapeDtypeStruct((V, D), pdt),
    }
    p_specs = {"emb_in": ("vocab", None), "emb_out": ("vocab", None)}
    params_sh = tree_shardings(params_avals, p_specs)
    opt = optim.adam(0.025)
    opt_avals = jax.eval_shape(opt.init, params_avals)
    opt_sh = tree_shardings(opt_avals, optim.adam_state_specs(p_specs))
    batch_avals = {
        "centers": jax.ShapeDtypeStruct((B,), jnp.int32),
        "contexts": jax.ShapeDtypeStruct((B,), jnp.int32),
        "negatives": jax.ShapeDtypeStruct((B, K), jnp.int32),
    }
    batch_sh = tree_shardings(
        batch_avals,
        {"centers": ("batch",), "contexts": ("batch",), "negatives": ("batch", None)},
    )

    def step(params, opt_state, batch):
        def loss_fn(p):
            return batch_loss(p, batch["centers"], batch["contexts"],
                              batch["negatives"], "ref")

        loss, grads = jax.value_and_grad(loss_fn)(params)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
        return params, opt_state, loss

    return (
        step,
        (params_avals, opt_avals, batch_avals),
        (params_sh, opt_sh, batch_sh),
        (0, 1),
    )


def cell_overrides(arch: str, shape_name: str, model_axis: int = 16) -> dict:
    """Logical-rule overrides for one cell: per-arch + per-shape-kind."""
    overrides = sharding_overrides(arch)
    kind = SHAPES[shape_name].kind
    if kind == "train" and "res_seq" not in overrides:
        # sequence-parallel residual stream: bounds full-remat carries
        # (see distributed/sharding.py); train cells only
        overrides["res_seq"] = ("model",)
    if arch in REGISTRY and kind in ("decode", "prefill"):
        cfg = get_config(arch)
        if cfg.n_kv_heads % model_axis != 0 and "kv_seq" not in overrides:
            # KV heads can't shard the model axis: shard the cache's
            # sequence dim instead (flash-decode parallelism; GSPMD inserts
            # the partial-softmax all-reduce)
            overrides["kv_seq"] = ("model",)
    return overrides


def run_cell(arch: str, shape_name: str, multi_pod: bool, out):
    mesh_name = "multi" if multi_pod else "single"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        overrides = cell_overrides(arch, shape_name)
        with use_mesh(mesh), sharding_scope(mesh, **overrides):
            fn, avals, in_sh, donate = build_cell(arch, shape_name, mesh)
            jitted = jax.jit(fn, in_shardings=in_sh, donate_argnums=donate)
            lowered = jitted.lower(*avals)
            compiled = lowered.compile()
            ma = compiled.memory_analysis()
            ca = compiled.cost_analysis() or {}
            if isinstance(ca, (list, tuple)):  # older jax: one dict per program
                ca = ca[0] if ca else {}
            hlo = compiled.as_text()
        coll_bytes, coll_counts = parse_collective_bytes(hlo)
        rec.update(
            status="ok",
            compile_seconds=round(time.time() - t0, 2),
            memory=dict(
                argument_bytes=ma.argument_size_in_bytes,
                output_bytes=ma.output_size_in_bytes,
                temp_bytes=ma.temp_size_in_bytes,
                alias_bytes=ma.alias_size_in_bytes,
                code_bytes=ma.generated_code_size_in_bytes,
            ),
            flops=ca.get("flops", 0.0),
            bytes_accessed=ca.get("bytes accessed", 0.0),
            collective_bytes=coll_bytes,
            collective_counts=coll_counts,
        )
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: OK "
              f"({rec['compile_seconds']}s)")
        print(f"  memory_analysis: args={ma.argument_size_in_bytes/2**30:.2f}GiB "
              f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB "
              f"out={ma.output_size_in_bytes/2**30:.2f}GiB "
              f"aliased={ma.alias_size_in_bytes/2**30:.2f}GiB (per device)")
        print(f"  cost_analysis: flops={rec['flops']:.3e} "
              f"bytes={rec['bytes_accessed']:.3e} (per device)")
        print(f"  collectives: " + ", ".join(
            f"{k}={v/2**20:.1f}MiB(x{coll_counts[k]})"
            for k, v in coll_bytes.items() if v))
    except SkipCell as e:
        rec.update(status="skip", reason=str(e))
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: SKIP ({e})")
    except Exception as e:  # a failure here is a deliverable failure
        rec.update(status="fail", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: FAIL {e}")
    out.append(rec)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--include-graph", action="store_true",
                    help="also dry-run the paper's deepwalk-web1b SGNS step")
    args = ap.parse_args()

    archs = sorted(REGISTRY) if args.arch == "all" else args.arch.split(",")
    if args.include_graph or args.arch == DW_CONFIG.name:
        if DW_CONFIG.name not in archs:
            archs.append(DW_CONFIG.name)
        if args.arch == DW_CONFIG.name:
            archs = [DW_CONFIG.name]
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    out = []
    for multi in meshes:
        for arch in archs:
            for shape in shapes:
                if arch == DW_CONFIG.name and shape != "train_4k":
                    continue  # graph workload has one canonical shape
                run_cell(arch, shape, multi, out)

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    # merge with existing records (other shards may write too)
    existing = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            existing = json.load(f)
    key = lambda r: (r["arch"], r["shape"], r["mesh"])
    merged = {key(r): r for r in existing}
    merged.update({key(r): r for r in out})
    with open(args.out, "w") as f:
        json.dump(sorted(merged.values(), key=key), f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in out)
    n_skip = sum(r["status"] == "skip" for r in out)
    n_fail = sum(r["status"] == "fail" for r in out)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skip, {n_fail} fail -> {args.out}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())

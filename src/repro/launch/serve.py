"""Serving launcher: continuous-batched decode.

``python -m repro.launch.serve --arch qwen3-4b --preset reduced --requests 12``

One prefill lowering + one decode lowering serve the whole run. Slots are a
fixed-size batch; finished sequences (EOS or budget) are swapped for queued
requests by resetting that row's cache in place (functional cache, so this is
a cheap host-side gather/update). Reports tokens/s and per-phase timings.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, sharding_overrides
from repro.distributed.sharding import sharding_scope
from repro.launch.mesh import make_mesh, use_mesh
from repro.models.steps import make_decode_step, make_prefill_step
from repro.models.transformer import init_model


def cache_batch_axes(cfg):
    """Which axis of each cache leaf is the batch axis."""
    axes = {"len": 0}
    if cfg.family in ("dense", "moe", "encdec"):
        axes.update(k=1, v=1)
        if cfg.kv_quant:
            axes.update(k_scale=1, v_scale=1)
    if cfg.family == "encdec":
        axes.update(cross_k=1, cross_v=1)
    if cfg.family == "ssm":
        axes.update(conv=1, ssd=1)
    if cfg.family == "hybrid":
        axes.update(conv=2, ssd=2, k=1, v=1, tail_conv=1, tail_ssd=1)
    return axes


def _set_row(buf, row, b, axis):
    idx = [slice(None)] * buf.ndim
    idx[axis] = slice(b, b + 1)
    return buf.at[tuple(idx)].set(row)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--preset", choices=["reduced", "full"], default="reduced")
    ap.add_argument("--slots", type=int, default=4, help="batch slots")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.preset == "reduced":
        cfg = cfg.reduced()
    if cfg.family == "encdec" or cfg.frontend == "vision":
        raise SystemExit("serve demo targets decoder-only text archs")

    mesh = make_mesh((jax.device_count(), 1), ("data", "model"))
    max_len = args.prompt_len + args.max_new
    rng = np.random.default_rng(args.seed)
    queue = [
        rng.integers(2, cfg.vocab_size, size=args.prompt_len).astype(np.int32)
        for _ in range(args.requests)
    ]

    with use_mesh(mesh), sharding_scope(mesh, **sharding_overrides(cfg.name)):
        params = init_model(jax.random.PRNGKey(args.seed), cfg)
        prefill = jax.jit(make_prefill_step(cfg, max_len=max_len))
        decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))

        B = args.slots
        t0 = time.perf_counter()
        prompts = np.stack([queue.pop(0) for _ in range(min(B, len(queue) + B))][:B]) \
            if len(queue) >= B else None
        if prompts is None:  # fewer requests than slots: pad with repeats
            rows = [queue.pop(0) if queue else np.zeros(args.prompt_len, np.int32)
                    for _ in range(B)]
            prompts = np.stack(rows)
        logits, cache = prefill(params, {"tokens": jnp.asarray(prompts)})
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0

        remaining = [args.max_new] * B
        served = B
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        n_decoded = 0
        t0 = time.perf_counter()
        while True:
            logits, cache = decode(params, cache, tok)
            n_decoded += B
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            done = []
            for b in range(B):
                remaining[b] -= 1
                if remaining[b] <= 0:
                    done.append(b)
            if done and queue:
                # continuous batching: swap finished rows for queued requests
                for b in done:
                    if not queue:
                        break
                    prompt = queue.pop(0)
                    _, row_cache = prefill(
                        params, {"tokens": jnp.asarray(prompt[None])}
                    )
                    axes = cache_batch_axes(cfg)
                    cache = {
                        k: _set_row(cache[k], row_cache[k], b, axes[k])
                        for k in cache
                    }
                    remaining[b] = args.max_new
                    served += 1
            elif done and not queue:
                if all(r <= 0 for r in remaining):
                    break
            if n_decoded > (args.requests + B) * args.max_new * 2:
                break  # safety
        jax.block_until_ready(tok)
        t_decode = time.perf_counter() - t0

    print(f"[serve] {served} requests, {n_decoded} tokens decoded")
    print(f"[serve] prefill {t_prefill*1e3:.1f} ms; decode "
          f"{n_decoded / max(t_decode, 1e-9):.1f} tok/s "
          f"({t_decode*1e3/max(n_decoded,1):.2f} ms/tok)")
    return n_decoded


if __name__ == "__main__":
    main()

"""Online embedding service launcher — synthetic-traffic demo.

``python -m repro.launch.serve_embed --dataset synthetic --requests 256``

Flow: build a base graph, hold out a fraction of edges (plus the nodes that
only appear in them — the "future users") as an ingestion stream; embed the
base graph's k0-core and mean-propagate it offline (paper §2.2) to fill the
store; then stream the held-out edges in **blocks** (one staged insert + one
block core repair each, ``--block-size``), optionally retracting a
``--churn`` fraction of previously streamed edges after each block
(deletion-aware maintenance), with incremental cores verified against the
Matula–Beck oracle at the end; finally replay microbatched query traffic
over both existing and brand-new nodes. Reports ingest throughput, p50/p99
query latency, QPS, cold-start fraction, store staleness, and retrain
pressure.

Embeddings default to a fast random table for the k0-core (the serving layer
is agnostic to embedding quality); pass ``--train`` to run the real
CoreWalk+SGNS pipeline instead.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.kcore import core_numbers_host, degeneracy
from repro.core.propagation import propagate
from repro.graph import datasets, generators
from repro.obs import device_profile, metrics, record_memory
from repro.obs import trace as obs
from repro.serve import (
    DynamicGraph,
    EmbeddingService,
    EmbeddingStore,
    IncrementalCore,
    RecoveryManager,
    ShardPlan,
    faults,
)

__all__ = ["main", "build_service"]


def _load_graph(name: str, seed: int):
    if name == "synthetic":
        return generators.barabasi_albert_varying(2000, 6.0, seed=seed)
    if name not in datasets.DATASETS:
        raise SystemExit(
            f"unknown dataset {name!r}; options: "
            f"{['synthetic'] + sorted(datasets.DATASETS)}"
        )
    return datasets.load(name, seed=seed)


def _split_stream(g, stream_frac: float, seed: int):
    """Split edges into (base, stream); stream arrives later, in order."""
    edges = g.edge_list()
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(edges))
    n_stream = int(round(stream_frac * len(edges)))
    stream = edges[perm[:n_stream]]
    base = edges[perm[n_stream:]]
    return base, stream


def build_service(
    g,
    *,
    stream_frac: float = 0.15,
    k0_frac: float = 0.5,
    dim: int = 64,
    batch: int = 64,
    capacity: int = 0,
    compact_every: int = 512,
    train: bool = False,
    prop_iters: int = 20,
    seed: int = 0,
    shards: int = 1,
    retrain: bool = False,
    retrain_threshold: float = 0.1,
    retrain_budget: int = 0,
    repair_policy: str = "adaptive",
    crossover_margin: float = 1.0,
    cold_cells_per_arc: float = 32.0,
    pipeline: bool = True,
):
    """Returns (service, stream_edges, base_core, k0).

    ``shards > 1`` row-shards the store table and ELL mirror across that
    many devices (``ShardPlan``); 1 keeps the exact single-device path.
    ``retrain=True`` attaches a drift-triggered ``Retrainer`` in auto mode:
    after every ingested block the service re-checks ``retrain_pressure``
    against ``retrain_threshold`` and, while ``retrain_budget`` allows,
    refreshes the k0-core embeddings (CoreWalk+SGNS warm start, Procrustes
    alignment, chunked hot swap) in place. ``repair_policy`` selects the
    block-repair decision rule (``adaptive`` measured crossover /
    ``region`` legacy static trigger / ``fallback`` always re-peel) and
    ``pipeline`` overlaps block staging with the in-flight descent — both
    exist so A/B runs can reach every old behaviour.
    """
    plan = ShardPlan.build(shards)
    base_edges, stream_edges = _split_stream(g, stream_frac, seed)
    # nodes that only appear in the stream are the future cold-start users
    base = DynamicGraph(g.n_nodes, base_edges, width=16, plan=plan)
    base_graph = base.snapshot()
    core = core_numbers_host(base_graph)
    k0 = max(2, int(round(degeneracy(core) * k0_frac)))
    k0 = min(k0, degeneracy(core))

    in_core = core >= k0
    if train:
        from repro.core.pipeline import EmbedConfig, embed_graph
        from repro.skipgram.trainer import SGNSConfig

        res = embed_graph(
            base_graph,
            EmbedConfig(
                method="corewalk",
                k0=k0,
                sgns=SGNSConfig(dim=dim, impl="ref", seed=seed),
                prop_iters=prop_iters,
                seed=seed,
            ),
        )
        emb = res.embeddings
    else:
        rng = np.random.default_rng(seed)
        emb = np.zeros((g.n_nodes, dim), np.float32)
        emb[in_core] = rng.normal(size=(int(in_core.sum()), dim)).astype(
            np.float32
        ) / np.sqrt(dim)
        emb = propagate(base_graph, core, k0, emb, n_iters=prop_iters)

    # store every base node the offline pass embedded (the paper's batch
    # output); capacity < n exercises LRU eviction + host spillover
    served = np.where(base_graph.degrees() > 0)[0]
    cap = capacity if capacity > 0 else g.n_nodes
    store = EmbeddingStore(
        capacity=cap, dim=dim, node_cap=base.node_cap, plan=plan
    )
    store.put_many(served, emb[served], core[served])

    inc = IncrementalCore(
        base, core, repair_policy=repair_policy,
        crossover_margin=crossover_margin,
        cold_cells_per_arc=cold_cells_per_arc,
    )
    inc.mark_refresh()
    svc = EmbeddingService(
        base, inc, store, batch=batch, compact_every=compact_every, k0=k0,
        retrain_threshold=retrain_threshold, pipeline=pipeline,
    )
    if retrain:
        from repro.serve.retrain import RetrainConfig, Retrainer
        from repro.skipgram.trainer import SGNSConfig

        cfg = RetrainConfig(
            n_walks=8,
            walk_length=16,
            sgns=SGNSConfig(dim=dim, epochs=0.25, impl="ref", seed=seed),
            prop_iters=prop_iters,
            seed=seed,
        )
        svc.set_retrainer(Retrainer(svc, cfg), auto=True,
                          budget=retrain_budget)
    return svc, stream_edges, core, k0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="synthetic",
                    help="synthetic | " + " | ".join(sorted(datasets.DATASETS)))
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--stream-frac", type=float, default=0.15)
    ap.add_argument("--k0-frac", type=float, default=0.5)
    ap.add_argument("--capacity", type=int, default=0,
                    help="store capacity (0 = all nodes)")
    ap.add_argument("--compact-every", type=int, default=512)
    ap.add_argument("--block-size", type=int, default=256,
                    help="edges per ingest block (1 = per-edge baseline)")
    ap.add_argument("--churn", type=float, default=0.0,
                    help="fraction of each block re-drawn as deletions of "
                         "previously streamed edges")
    ap.add_argument("--shards", type=int, default=1,
                    help="row-shard the store table + ELL mirror across N "
                         "devices (power of two; 1 = single-device path; on "
                         "CPU set XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N)")
    ap.add_argument("--train", action="store_true",
                    help="real CoreWalk+SGNS base embeddings (slow)")
    ap.add_argument("--retrain", action="store_true",
                    help="attach the drift-triggered retraining loop: "
                         "re-embed the k0-core (CoreWalk+SGNS warm start), "
                         "Procrustes-align, and hot-swap store versions "
                         "whenever retrain pressure crosses the threshold")
    ap.add_argument("--retrain-threshold", type=float, default=0.1,
                    help="k0-core membership drift fraction that triggers "
                         "a retrain")
    ap.add_argument("--retrain-budget", type=int, default=2,
                    help="max drift-triggered retrains per run (0 = no cap)")
    ap.add_argument("--repair-policy", default="adaptive",
                    choices=["adaptive", "region", "fallback"],
                    help="block core-repair decision rule: adaptive = "
                         "measured descend-vs-repeel crossover (default), "
                         "region = legacy static candidate-region trigger, "
                         "fallback = always re-peel")
    ap.add_argument("--crossover-margin", type=float, default=1.0,
                    help="adaptive policy prefers the fused descent while "
                         "predicted descend cost <= margin * repeel cost")
    ap.add_argument("--cold-cells-per-arc", type=float, default=32.0,
                    help="cold-start shape heuristic: descend while padded "
                         "cells <= this many per affected-shell arc")
    ap.add_argument("--no-pipeline", action="store_true",
                    help="disable pipelined block ingest (serial staging)")
    ap.add_argument("--verify", action="store_true",
                    help="assert incremental cores match the oracle at the end")
    ap.add_argument("--score-frac", type=float, default=0.3,
                    help="fraction of requests that are link-score pairs")
    ap.add_argument("--topk", type=int, default=0, metavar="K",
                    help="also replay top_k_neighbors retrieval traffic "
                         "with this k (0 = off): per-call p50/p99 through "
                         "the blockwise score+reduce kernel")
    ap.add_argument("--warmup", type=int, default=2,
                    help="untimed warmup batches (jit compilation)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="record nested spans for the whole run and write a "
                         "Chrome trace_event JSON loadable in "
                         "chrome://tracing / Perfetto")
    ap.add_argument("--metrics-out", metavar="PATH", default=None,
                    help="write the metrics registry as a JSON snapshot, "
                         "plus a Prometheus text sibling (.prom)")
    ap.add_argument("--jax-profile", metavar="DIR", default=None,
                    help="capture a jax.profiler device trace of the ingest "
                         "phase into DIR (view with TensorBoard/Perfetto)")
    ap.add_argument("--wal-dir", metavar="DIR", default=None,
                    help="crash-safe serving: write-ahead-log every ingest/"
                         "retract block and keep atomic state snapshots "
                         "under DIR; an injected crash recovers from the "
                         "newest committed snapshot + WAL tail replay")
    ap.add_argument("--snapshot-every", type=int, default=64,
                    help="blocks between background snapshots (--wal-dir)")
    ap.add_argument("--fault-plan", metavar="SPEC", default=None,
                    help="deterministic fault injection: 'point:hit[:mode]"
                         ",...' — mode fault (recoverable error) or crash "
                         "(process death; with --wal-dir the run recovers "
                         "and continues); points: "
                         + ", ".join(faults.POINTS))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.trace:
        obs.enable()

    g = _load_graph(args.dataset, args.seed)
    print(f"[serve-embed] {args.dataset}: {g.n_nodes} nodes, {g.n_edges} edges")
    svc, stream_edges, core0, k0 = build_service(
        g,
        stream_frac=args.stream_frac,
        k0_frac=args.k0_frac,
        dim=args.dim,
        batch=args.batch,
        capacity=args.capacity,
        compact_every=args.compact_every,
        train=args.train,
        seed=args.seed,
        shards=args.shards,
        retrain=args.retrain,
        retrain_threshold=args.retrain_threshold,
        retrain_budget=args.retrain_budget,
        repair_policy=args.repair_policy,
        crossover_margin=args.crossover_margin,
        cold_cells_per_arc=args.cold_cells_per_arc,
        pipeline=not args.no_pipeline,
    )
    print(f"[serve-embed] base: {svc.graph.n_edges} edges, k0={k0}, "
          f"store {svc.store.resident}/{svc.store.capacity} resident")

    recovery = None
    if args.wal_dir:
        recovery = RecoveryManager(
            svc, args.wal_dir, snapshot_every=args.snapshot_every
        )
        print(f"[serve-embed] crash safety: WAL + snapshots under "
              f"{args.wal_dir} (snapshot every {args.snapshot_every} blocks)")
    if args.fault_plan:
        faults.install(faults.FaultPlan.parse(args.fault_plan))
        print(f"[serve-embed] fault plan armed: {args.fault_plan}")

    # re-attach the retraining loop on a recovered service *before* WAL
    # replay, so auto-retrains that fired in the original stream re-fire
    # identically during replay
    def _reconfigure(s):
        if args.retrain:
            from repro.serve.retrain import RetrainConfig, Retrainer
            from repro.skipgram.trainer import SGNSConfig

            cfg = RetrainConfig(
                n_walks=8, walk_length=16,
                sgns=SGNSConfig(dim=args.dim, epochs=0.25, impl="ref",
                                seed=args.seed),
                seed=args.seed,
            )
            s.set_retrainer(Retrainer(s, cfg), auto=True,
                            budget=args.retrain_budget)

    # --- ingest the stream in blocks, with churn (deletions of streamed
    # edges) interleaved, periodic compaction + oracle verification
    t0 = time.perf_counter()
    crashed = False
    try:
        with device_profile(args.jax_profile) as prof:
            n_in, n_out = svc.stream_with_churn(
                stream_edges,
                block_size=args.block_size,
                churn=args.churn,
                rng=np.random.default_rng(args.seed + 2),
            )
    except faults.InjectedCrash as e:
        if recovery is None:
            raise
        crashed = True
        plan = faults.active()
        faults.install(None)  # the "new process" runs without the plan
        recovery.wal.close()  # simulate process death: drop live handles
        print(f"[serve-embed] CRASH injected ({e}; "
              f"{plan.total_fired if plan else '?'} faults fired) — "
              f"recovering from {args.wal_dir}")
        svc, recovery, report = RecoveryManager.recover(
            args.wal_dir, snapshot_every=args.snapshot_every,
            configure=_reconfigure,
        )
        print(f"[serve-embed] recovered: snapshot@wal_seq "
              f"{report['snapshot_wal_seq']} + {report['replayed_records']} "
              f"replayed records ({report['replayed_edges']} edges) in "
              f"{report['recovery_seconds']:.2f}s")
        n_in = svc.stats.edges_ingested
        n_out = svc.stats.edges_removed
    t_ingest = time.perf_counter() - t0
    if args.jax_profile and not crashed:
        print(f"[serve-embed] jax profile: "
              f"{'captured to ' + prof['logdir'] if prof['active'] else 'unavailable (' + str(prof.get('error')) + ')'}")
    mismatches = svc.cores.resync()  # oracle check (exactness expected)
    eps = (n_in + n_out) / max(t_ingest, 1e-9)
    print(f"[serve-embed] ingested {n_in} edges (+{n_out} retracted) in "
          f"{t_ingest:.2f}s ({eps:.0f} edges/s, blocks of "
          f"{args.block_size}), {svc.stats.compactions} compactions, "
          f"{svc.cores.repeels} re-peels, core mismatches vs oracle: "
          f"{mismatches}")
    phases = "  ".join(
        f"{k} {v['seconds'] * 1e3:.0f}ms[{v['impl']}]"
        for k, v in svc.cores.phase_report().items()
    )
    if phases:
        print(f"[serve-embed] repair phases: {phases} "
              f"({svc.cores.descends} fused descents, "
              f"{svc.cores.sweeps} sweeps)")
    pol = svc.cores.policy_report()
    print(f"[serve-embed] repair policy[{pol['mode']}]: "
          f"decisions {pol['decisions']} (cold {pol['cold_decisions']}), "
          f"shell re-peels {pol['shell_repeel']['count']} "
          f"(widened {pol['shell_repeel']['widens']}, mean frac peeled "
          f"{pol['shell_repeel']['mean_frac_peeled']})")
    st_i = svc.stats
    if st_i.degraded_queries or st_i.retrain_failures or st_i.hangs:
        print(f"[serve-embed] degradation: {st_i.degraded_queries} degraded "
              f"queries, {st_i.retrain_failures} retrain rollbacks, "
              f"{st_i.hangs} hangs (degraded={svc.degraded})")
    if recovery is not None:
        recovery.snapshot(blocking=True)  # durable final state
        print(f"[serve-embed] durability: wal_seq {recovery.wal.seq}, "
              f"{recovery.snapshots_written} snapshots written"
              + (f", recovered after injected crash" if crashed else ""))
    if args.verify and mismatches:
        raise SystemExit(f"incremental core drifted from oracle: {mismatches}")
    if args.retrain:
        st = svc.stats
        rt = np.asarray(st.retrain_seconds) if st.retrain_seconds else None
        print(f"[serve-embed] retraining loop: {st.retrains} drift-triggered "
              f"retrains (budget {args.retrain_budget or 'uncapped'}), "
              f"last swap version {st.last_swap_version}, "
              f"store versions {svc.store.version_counts()}"
              + (f", retrain wall {rt.sum():.2f}s (max {rt.max():.2f}s)"
                 if rt is not None else ""))

    # --- synthetic traffic: embeds over old+new nodes, plus link scores
    rng = np.random.default_rng(args.seed + 1)
    n_now = svc.graph.n_nodes
    from repro.serve import ServiceStats

    for _ in range(args.warmup):  # compile the static batch programs untimed
        svc.embed(rng.integers(0, n_now, size=args.batch))
    st0 = svc.stats
    svc.stats = ServiceStats(
        edges_ingested=st0.edges_ingested, compactions=st0.compactions,
        retrains=st0.retrains, last_swap_version=st0.last_swap_version,
    )

    n_scores = int(round(args.requests * args.score_frac))
    n_embeds = args.requests - n_scores
    t0 = time.perf_counter()
    for start in range(0, n_embeds, args.batch):
        n = min(args.batch, n_embeds - start)
        svc.embed(rng.integers(0, n_now, size=n))
    if n_scores:
        pairs = rng.integers(0, n_now, size=(n_scores, 2))
        svc.link_scores(pairs)
    t_query = time.perf_counter() - t0

    p50, p99 = svc.latency_percentiles()
    st = svc.stats
    qps = st.queries / max(t_query, 1e-9)
    print(f"[serve-embed] served {st.queries} queries in {st.flushes} "
          f"static batches of {args.batch}")
    print(f"[serve-embed] p50 {p50 * 1e3:.2f} ms  p99 {p99 * 1e3:.2f} ms  "
          f"per flush; {qps:.0f} queries/s")
    print(f"[serve-embed] cold-start {st.cold_fraction * 100:.1f}%  "
          f"unresolved {st.unresolved}  store hits {st.store_hits}  "
          f"evictions {svc.store.evictions}  spilled {svc.store.spilled}")

    # --- top-k retrieval traffic (the device-resident query engine's
    # second endpoint: blockwise score+reduce over the resident table)
    if args.topk > 0:
        svc.top_k_neighbors(rng.integers(0, n_now, size=args.batch),
                            args.topk)  # untimed compile
        svc.stats.topk_seconds.clear()
        t0 = time.perf_counter()
        n_topk = 0
        for start in range(0, args.requests, args.batch):
            n = min(args.batch, args.requests - start)
            ids, _ = svc.top_k_neighbors(
                rng.integers(0, n_now, size=n), args.topk
            )
            n_topk += n
        t_topk = time.perf_counter() - t0
        tp50, tp99 = svc.topk_latency_percentiles()
        print(f"[serve-embed] top-{args.topk}: {n_topk} queries, "
              f"p50 {tp50 * 1e3:.2f} ms  p99 {tp99 * 1e3:.2f} ms per call; "
              f"{n_topk / max(t_topk, 1e-9):.0f} queries/s over "
              f"{svc.store.resident} resident rows")
    # the retrain signal is actionable now: alongside yes/no, report how many
    # refreshes actually ran and which store version the last swap installed
    print(f"[serve-embed] staleness {svc.store.staleness(svc.cores.core):.3f}  "
          f"retrain pressure {svc.retrain_pressure():.3f} "
          f"(threshold {svc.retrain_threshold}, "
          f"retrain={'yes' if svc.should_retrain() else 'no'}, "
          f"retrains={st.retrains}, "
          f"last_swap_version={st.last_swap_version})")
    if svc.store.plan is not None:
        rep = svc.store.shard_report()
        print(f"[serve-embed] shards {rep['n_shards']}: resident/shard "
              f"{rep['resident_per_shard']} (imbalance "
              f"{rep['imbalance']:.2f}x), gather rows/shard "
              f"{rep['gather_rows_per_shard']}, cross-shard row copies "
              f"{rep['cross_shard_row_copies']}")

    if args.metrics_out:
        svc.publish_metrics()
        record_memory()
        reg = metrics()
        reg.export_json(args.metrics_out)
        prom = args.metrics_out.rsplit(".", 1)[0] + ".prom"
        reg.export_prometheus(prom)
        print(f"[serve-embed] metrics snapshot: {args.metrics_out} "
              f"(+ {prom})")
    if args.trace:
        t = obs.tracer()
        t.export_chrome(args.trace)
        names = sorted(t.span_names())
        print(f"[serve-embed] trace: {len(t.events)} spans "
              f"({len(names)} kinds: {', '.join(names)}) -> {args.trace}"
              + (f" [{t.dropped} dropped]" if t.dropped else ""))
    if recovery is not None:
        recovery.close()
    if args.fault_plan:
        faults.install(None)  # don't leak the plan to in-process callers
    return st.queries


if __name__ == "__main__":
    main()

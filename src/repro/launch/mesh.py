"""Production mesh factory.

Per-pod mesh is 16x16 = 256 chips (v5e pod), axes (data, model); the
multi-pod mesh prepends a pure-DP "pod" axis: (2, 16, 16) = 512 chips.
A FUNCTION, not a module constant — importing this module must never touch
jax device state (smoke tests see 1 CPU device; only dryrun.py forces 512).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_mesh(shape, axes):
    """Arbitrary mesh for elastic restarts / tests (e.g. (2, 4) on 8 CPUs)."""
    return jax.make_mesh(
        tuple(shape), tuple(axes), axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )

"""Production mesh factory.

Per-pod mesh is 16x16 = 256 chips (v5e pod), axes (data, model); the
multi-pod mesh prepends a pure-DP "pod" axis: (2, 16, 16) = 512 chips.
A FUNCTION, not a module constant — importing this module must never touch
jax device state (smoke tests see 1 CPU device; only dryrun.py forces 512).

``jax.sharding.AxisType`` only exists on newer jax; on older installs
``jax.make_mesh`` simply takes no ``axis_types`` and every axis is the
implicit default, so the kwarg is version-gated rather than required.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh", "use_mesh"]


def _axis_kwargs(n_axes: int) -> dict:
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:  # older jax: no AxisType, no axis_types kwarg
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def use_mesh(mesh):
    """Context manager activating ``mesh`` for jit/sharding, version-gated.

    Newer jax spells this ``jax.set_mesh(mesh)``; on older installs the
    ``Mesh`` object itself is the (legacy global-context) context manager.
    Every launcher/benchmark/test should enter meshes through this helper
    rather than naming either API directly.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh for elastic restarts / tests (e.g. (2, 4) on 8 CPUs)."""
    return jax.make_mesh(tuple(shape), tuple(axes), **_axis_kwargs(len(axes)))

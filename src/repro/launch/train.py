"""Training launcher: ``python -m repro.launch.train --arch qwen3-4b ...``

Production loop wiring on any device topology (1-CPU smoke to multi-pod):
mesh + logical sharding rules, jit'd train step (optional microbatch
accumulation + cross-pod gradient compression), synthetic-but-deterministic
data pipeline with prefetch, straggler monitor, hang watchdog, preemption
handler, and atomic checkpoints with auto-resume — every fault-tolerance
feature in DESIGN.md §6 is exercised by this driver.

CPU quickstart (reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --preset reduced \
      --steps 20 --batch 4 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, sharding_overrides
from repro.configs.shapes import batch_logical_names
from repro.data.pipeline import PrefetchIterator, SyntheticLMData
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.compression import ErrorFeedbackInt8
from repro.distributed.sharding import sharding_scope, tree_shardings
from repro.distributed.watchdog import HangWatchdog, StragglerMonitor
from repro.launch.mesh import make_mesh, use_mesh
from repro.models.steps import make_train_step
from repro.models.transformer import init_model, model_specs
from repro.train import optim


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", choices=["reduced", "full"], default="reduced")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--mesh", default="", help="e.g. 2x4 -> (data=2, model=4)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics", default="")
    return ap.parse_args(argv)


def main(argv=None):
    args = parse_args(argv)
    cfg = get_config(args.arch)
    if args.preset == "reduced":
        cfg = cfg.reduced()

    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
    else:
        dims = (jax.device_count(), 1)
    mesh = make_mesh(dims, ("data", "model")[: len(dims)] if len(dims) == 2
                     else ("pod", "data", "model"))

    data = SyntheticLMData(cfg.vocab_size, args.batch, args.seq, seed=args.seed)
    opt = optim.make_optimizer(
        cfg.optimizer, optim.warmup_cosine(args.lr, 10, max(args.steps, 20))
    )

    compressor = ErrorFeedbackInt8() if args.compress_grads else None
    comp_state = {}

    def grad_transform(grads):
        if compressor is None:
            return grads
        out, comp_state["s"] = compressor.compress_decompress(
            grads, comp_state.get("s") or compressor.init(grads)
        )
        return out

    step_fn = make_train_step(cfg, opt, accum_steps=args.accum,
                              grad_transform=grad_transform if compressor else None)

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    stop = {"now": False}
    signal.signal(signal.SIGTERM, lambda *_: stop.update(now=True))

    with use_mesh(mesh), sharding_scope(mesh, **sharding_overrides(cfg.name)):
        p_specs = model_specs(cfg)
        params_avals = jax.eval_shape(lambda: init_model(jax.random.PRNGKey(args.seed), cfg))
        params_sh = tree_shardings(params_avals, p_specs)
        opt_avals = jax.eval_shape(opt.init, params_avals)
        opt_sh = tree_shardings(
            opt_avals, optim.optimizer_state_specs(cfg.optimizer, params_avals, p_specs)
        )
        batch_sh = tree_shardings(
            jax.eval_shape(lambda: jax.tree.map(jnp.asarray, data.batch_at(0))),
            batch_logical_names(cfg, train=True),
        )

        start_step = 0
        if mgr is not None and mgr.latest_step() is not None:
            start_step = mgr.latest_step()
            tree = mgr.restore(
                start_step,
                {"params": params_avals, "opt": opt_avals},
                {"params": params_sh, "opt": opt_sh},
            )
            params, opt_state = tree["params"], tree["opt"]
            print(f"[train] resumed from step {start_step}")
        else:
            params = init_model(jax.random.PRNGKey(args.seed), cfg)
            opt_state = opt.init(params)

        jit_step = jax.jit(
            step_fn, in_shardings=(params_sh, opt_sh, batch_sh), donate_argnums=(0, 1)
        )

        monitor = StragglerMonitor()
        metrics_path = args.metrics or (os.path.join(args.ckpt_dir, "metrics.jsonl")
                                        if args.ckpt_dir else "")
        mf = open(metrics_path, "a") if metrics_path else None

        def batches():
            s = start_step
            while True:
                yield s, data.batch_at(s)
                s += 1

        it = PrefetchIterator(batches(), depth=2)
        wd = HangWatchdog(600.0, lambda: print("[train] WATCHDOG: step hang"))
        losses = []
        for s, batch in it:
            if s >= args.steps or stop["now"]:
                break
            monitor.start_step()
            wd.arm()
            params, opt_state, metrics = jit_step(params, opt_state, batch)
            loss = float(metrics["loss"])
            wd.disarm()
            slow = monitor.end_step()
            losses.append(loss)
            rec = {"step": s, "loss": loss, "straggler": slow,
                   "grad_norm": float(metrics["grad_norm"])}
            if mf:
                mf.write(json.dumps(rec) + "\n")
                mf.flush()
            if s % 5 == 0 or s == args.steps - 1:
                print(f"[train] step {s} loss {loss:.4f}"
                      + (" (straggler)" if slow else ""))
            if mgr is not None and (s + 1) % args.ckpt_every == 0:
                mgr.save(s + 1, {"params": params, "opt": opt_state}, blocking=False)
        if mgr is not None:
            mgr.wait()
            final = s if stop["now"] else args.steps
            mgr.save(final, {"params": params, "opt": opt_state})
            print(f"[train] checkpointed step {final}")
        if mf:
            mf.close()
        print(f"[train] done: first loss {losses[0]:.4f} last loss {losses[-1]:.4f} "
              f"stragglers {monitor.straggler_fraction:.2%}")
        return losses


if __name__ == "__main__":
    main()

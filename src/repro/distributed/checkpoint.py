"""Fault-tolerant sharded checkpointing with elastic restart.

Layout (no tensorstore dependency — plain npy shards + a JSON manifest):

    <dir>/step_000123/
        manifest.json       # step, leaf paths, shapes, dtypes, mesh hint
        leaf_<i>_<j>.npy    # addressable shard j of leaf i (host-local)
        _COMMITTED          # written last: torn checkpoints are never loaded

Guarantees:
  * atomicity — writes go to step_*.tmp, fsync'd, then os.rename (POSIX
    atomic); readers only trust directories containing _COMMITTED.
  * elastic restart — ``restore`` takes the *current* mesh + shardings and
    reassembles each leaf from its shards (shards are (index, data) pairs),
    so a checkpoint saved on mesh A loads onto mesh B (N -> M pods).
  * async — ``save(..., blocking=False)`` snapshots to host then writes on a
    background thread; ``wait()`` joins before the next save (one in flight).
  * retention — keep the newest ``keep`` checkpoints, never deleting the
    newest committed one.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _leaf_paths(tree):
    paths_and_leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [
        ("/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path), leaf)
        for path, leaf in paths_and_leaves
    ]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save ----

    def save(self, step: int, tree: Any, *, blocking: bool = True):
        """Snapshot ``tree`` (pytree of jax/np arrays) for ``step``."""
        self.wait()
        # snapshot to host memory synchronously (donation-safe), write async
        entries = []
        for name, leaf in _leaf_paths(tree):
            if hasattr(leaf, "addressable_shards"):
                shards = [
                    (s.index, np.asarray(s.data)) for s in leaf.addressable_shards
                ]
            else:
                shards = [(tuple([slice(None)] * np.ndim(leaf)), np.asarray(leaf))]
            entries.append((name, np.shape(leaf), np.asarray(leaf).dtype if not shards else shards[0][1].dtype, shards))

        def write():
            final = os.path.join(self.directory, f"step_{step:09d}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            manifest = {"step": step, "leaves": []}
            for i, (name, shape, dtype, shards) in enumerate(entries):
                files = []
                for j, (index, data) in enumerate(shards):
                    fn = f"leaf_{i:05d}_{j:04d}.npy"
                    np.save(os.path.join(tmp, fn), data)
                    files.append({"file": fn, "index": _index_to_json(index, shape)})
                manifest["leaves"].append(
                    {
                        "name": name,
                        "shape": list(shape),
                        "dtype": str(np.dtype(dtype)),
                        "shards": files,
                    }
                )
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
                f.write("ok")
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            # fsync the parent so the rename itself survives power loss
            dfd = os.open(self.directory, os.O_RDONLY)
            try:
                os.fsync(dfd)
            finally:
                os.close(dfd)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"), ignore_errors=True)

    # ---------------------------------------------------------- restore ----

    def _is_committed(self, d: str) -> bool:
        """True iff ``d`` holds a loadable checkpoint: the commit marker is
        present *and* the manifest parses. A crash between the npy writes and
        the rename can leave a ``step_*`` dir with a marker but a torn
        manifest; such dirs must never win over an older committed step."""
        if not os.path.exists(os.path.join(d, "_COMMITTED")):
            return False
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                json.load(f)
        except (OSError, ValueError):
            return False
        return True

    def all_steps(self):
        out = []
        for d in sorted(os.listdir(self.directory)):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if self._is_committed(os.path.join(self.directory, d)):
                    out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore_latest(self, target: Any, shardings: Any = None) -> tuple:
        """Restore the newest *loadable* checkpoint -> ``(step, tree)``.

        Walks committed steps newest -> oldest, skipping any that fail to
        load (torn shard files can slip past the commit marker if the crash
        raced the rename), so a single corrupt dir never blocks restart.
        Raises ``FileNotFoundError`` when no step restores."""
        for step in reversed(self.all_steps()):
            try:
                return step, self.restore(step, target, shardings)
            except (OSError, ValueError, KeyError):
                continue
        raise FileNotFoundError(f"no restorable checkpoint in {self.directory}")

    def restore(self, step: int, target: Any, shardings: Any = None) -> Any:
        """Rebuild the pytree for ``step``. ``target`` provides the structure;
        ``shardings`` (same structure, jax.sharding.Sharding leaves) places
        leaves on the *current* mesh — resharding happens here, which is what
        makes restarts elastic across mesh shapes."""
        d = os.path.join(self.directory, f"step_{step:09d}")
        if not os.path.exists(os.path.join(d, "_COMMITTED")):
            raise FileNotFoundError(f"no committed checkpoint at {d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_name = {leaf["name"]: leaf for leaf in manifest["leaves"]}

        names = [n for n, _ in _leaf_paths(target)]
        flat_t, tdef = jax.tree_util.tree_flatten(target)
        flat_sh = tdef.flatten_up_to(shardings) if shardings is not None else [None] * len(flat_t)

        out = []
        for name, t, sh in zip(names, flat_t, flat_sh):
            meta = by_name[name]
            full = np.zeros(tuple(meta["shape"]), dtype=np.dtype(meta["dtype"]))
            for shard in meta["shards"]:
                data = np.load(os.path.join(d, shard["file"]))
                full[_index_from_json(shard["index"], meta["shape"])] = data
            if sh is not None:
                arr = jax.make_array_from_callback(full.shape, sh, lambda idx: full[idx])
            else:
                arr = jax.numpy.asarray(full)
            out.append(arr)
        return jax.tree_util.tree_unflatten(tdef, out)


def _index_to_json(index, shape):
    out = []
    for sl, dim in zip(index, shape):
        out.append([0 if sl.start is None else int(sl.start),
                    int(dim) if sl.stop is None else int(sl.stop)])
    return out


def _index_from_json(index, shape):
    return tuple(slice(lo, hi) for lo, hi in index)

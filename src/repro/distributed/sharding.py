"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Model code never names mesh axes: it tags tensor dims with *logical* names
("batch", "heads", "mlp", "vocab", ...). A ``sharding_scope`` binds a mesh and
a rule table mapping logical names to mesh axes; ``constrain`` applies
``with_sharding_constraint`` inside jit, and ``tree_shardings`` builds
NamedShardings for in/out_shardings of pjit'd steps.

Fallback contract: a logical dim that is not divisible by its mesh-axes
product is *replicated* (the rule is dropped for that tensor). This is what
lets kv_heads=4 configs lower on a 16-way model axis without per-arch special
cases — and the roofline table shows the cost of the fallback explicitly.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "default_rules",
    "sharding_scope",
    "current_ctx",
    "constrain",
    "spec_for",
    "named_sharding",
    "tree_shardings",
]

Rules = Dict[str, Tuple[str, ...]]


def default_rules(multi_pod: bool = False) -> Rules:
    batch = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": batch,
        "seq": (),  # in-layer activations' sequence dim (temps, rematted away)
        # the residual stream / scan-carry seq dim: sharding THIS over model
        # (Megatron-style sequence parallelism) is what bounds remat memory —
        # carries are the only thing full-remat training keeps alive. Enabled
        # per-shape by the launcher (train cells); GSPMD inserts the
        # all-to-alls (Ulysses) around attention and gathers around MLP.
        "res_seq": (),
        "kv_seq": (),  # KV-cache sequence dim
        "act_embed": (),  # activations' d_model dim
        "heads": ("model",),
        "kv_heads": ("model",),
        # fallback TP dim for attention projections: when heads don't divide
        # the model axis (starcoder2 36H, qwen2-vl 28H, gemma2 8H), Dh=128
        # still shards — the duplicate-axis rule drops it when heads win.
        "head_dim": ("model",),
        # Ulysses-style attention sequence parallelism: per-arch override for
        # the same heads-indivisible archs (activations side).
        "attn_seq": (),
        "mlp": ("model",),
        "vocab": ("model",),
        "embed": (),  # weights' d_model dim; FSDP configs override to ("data",)
        "experts": ("model",),
        "expert_mlp": (),  # per-expert hidden dim (grok: ("model",))
        # expert matrices' d_model dim, separate from "embed" so FSDP can be
        # scoped to the expert weights alone (grok: experts are 98% of params;
        # FSDP-gathering the small attention weights too just burns links)
        "expert_embed": (),
        "ssm_heads": ("model",),
        "ssm_state": (),
        "heads_joined": ("model",),  # flattened H*Dh projections (LoRA B)
        "kv_joined": ("model",),
        "conv": (),
        "lora": (),
        "frames": (),  # encoder frames (audio)
        "stack": (),  # scan-over-layers leading axis — never sharded
    }


@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    mesh: Mesh
    rules: Rules


_CTX: contextvars.ContextVar[Optional[ShardingCtx]] = contextvars.ContextVar(
    "repro_sharding_ctx", default=None
)


@contextlib.contextmanager
def sharding_scope(mesh: Mesh, rules: Optional[Rules] = None, **overrides):
    base = dict(default_rules("pod" in mesh.axis_names)) if rules is None else dict(rules)
    base.update(overrides)
    token = _CTX.set(ShardingCtx(mesh=mesh, rules=base))
    try:
        yield
    finally:
        _CTX.reset(token)


def current_ctx() -> Optional[ShardingCtx]:
    return _CTX.get()


def _axes_for(name: Optional[str], dim: int, ctx: ShardingCtx):
    """Mesh axes for one logical dim, with divisibility fallback."""
    if name is None:
        return None
    axes = ctx.rules.get(name, ())
    axes = tuple(a for a in axes if a in ctx.mesh.axis_names)
    if not axes:
        return None
    size = 1
    for a in axes:
        size *= ctx.mesh.shape[a]
    if dim % size != 0:
        return None  # replicate: the fallback contract
    return axes if len(axes) > 1 else axes[0]


def spec_for(shape: Tuple[int, ...], names: Tuple[Optional[str], ...]) -> P:
    ctx = current_ctx()
    if ctx is None:
        return P()
    assert len(shape) == len(names), (shape, names)
    used: set = set()
    parts = []
    for dim, name in zip(shape, names):
        axes = _axes_for(name, dim, ctx)
        # an axis may appear only once in a spec
        flat = axes if isinstance(axes, tuple) else (axes,) if axes else ()
        if any(a in used for a in flat):
            axes = None
        else:
            used.update(flat)
        parts.append(axes)
    return P(*parts)


def constrain(x, *names: Optional[str]):
    """Apply a logical sharding constraint inside jit; no-op outside a scope."""
    ctx = current_ctx()
    if ctx is None:
        return x
    spec = spec_for(x.shape, names)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def named_sharding(shape, names) -> NamedSharding:
    ctx = current_ctx()
    assert ctx is not None, "named_sharding requires an active sharding_scope"
    return NamedSharding(ctx.mesh, spec_for(tuple(shape), tuple(names)))


def tree_shardings(avals, specs):
    """Map a pytree of ShapeDtypeStructs + a same-shape pytree of logical-name
    tuples to a pytree of NamedShardings."""
    flat_a, tdef = jax.tree.flatten(avals)
    flat_s = tdef.flatten_up_to(specs)
    return tdef.unflatten(
        [named_sharding(a.shape, s) for a, s in zip(flat_a, flat_s)]
    )

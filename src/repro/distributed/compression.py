"""Gradient compression with error feedback — for the cross-pod (DCN) axis.

The pod axis is pure DP: its all-reduce crosses the slowest links. int8
quantisation with error feedback (Seide et al. 2014; 1-bit SGD lineage) cuts
that traffic 4x vs f32 / 2x vs bf16 with no asymptotic convergence penalty:
the quantisation residual is carried to the next step, so the compression
error telescopes instead of accumulating.

Usage: wrap the train step's gradients:
    compressor = ErrorFeedbackInt8()
    state = compressor.init(params)
    grads, state = compressor.compress_decompress(grads, state)
The compress/decompress pair is what the wire format would be; under GSPMD
the all-reduce runs on the int8 tensors when the reduce is sliced out — here
we model it functionally and test the telescoping-error property.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["ErrorFeedbackInt8", "quantize_int8", "dequantize_int8"]


def quantize_int8(x: jnp.ndarray):
    """Symmetric per-tensor int8: returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


class EFState(NamedTuple):
    residual: Any


class ErrorFeedbackInt8:
    def init(self, params) -> EFState:
        return EFState(
            jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        )

    def compress_decompress(self, grads, state: EFState):
        """Returns (decompressed grads as seen post-all-reduce, new state)."""

        def one(g, r):
            corrected = g.astype(jnp.float32) + r
            q, scale = quantize_int8(corrected)
            deq = dequantize_int8(q, scale)
            return deq, corrected - deq

        flat_g, tdef = jax.tree.flatten(grads)
        flat_r = tdef.flatten_up_to(state.residual)
        outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
        deq = tdef.unflatten([o[0] for o in outs])
        res = tdef.unflatten([o[1] for o in outs])
        return deq, EFState(res)

"""Straggler / hang detection for the training loop.

At 1000+ nodes, slow hosts dominate tail latency. The watchdog keeps an EWMA
of step times; a step exceeding ``threshold x EWMA`` is flagged (logged and
counted). ``HangWatchdog`` arms a timer around blocking sections (collective
hangs, data stalls) and invokes a callback — in production that callback
triggers the preemption/restart path; tests inject a fake clock.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, List, Optional

__all__ = ["StragglerMonitor", "HangWatchdog"]


class StragglerMonitor:
    def __init__(self, threshold: float = 2.0, ewma: float = 0.9,
                 clock: Callable[[], float] = time.monotonic):
        self.threshold = threshold
        self.ewma_decay = ewma
        self.clock = clock
        self.ewma: Optional[float] = None
        self.slow_steps: List[int] = []
        self.step_idx = 0
        self._t0: Optional[float] = None

    def start_step(self):
        self._t0 = self.clock()

    def end_step(self) -> bool:
        """Returns True if this step was a straggler."""
        assert self._t0 is not None, "start_step not called"
        dt = self.clock() - self._t0
        self._t0 = None
        slow = False
        if self.ewma is not None and dt > self.threshold * self.ewma:
            self.slow_steps.append(self.step_idx)
            slow = True
            # do not fold outliers into the EWMA — keeps the baseline honest
        else:
            self.ewma = dt if self.ewma is None else (
                self.ewma_decay * self.ewma + (1 - self.ewma_decay) * dt
            )
        self.step_idx += 1
        return slow

    @property
    def straggler_fraction(self) -> float:
        return len(self.slow_steps) / max(self.step_idx, 1)


class HangWatchdog:
    """Fires ``on_hang`` if ``pet()`` is not called within ``timeout`` s."""

    def __init__(self, timeout: float, on_hang: Callable[[], None]):
        self.timeout = timeout
        self.on_hang = on_hang
        self._timer: Optional[threading.Timer] = None
        self.fired = False

    def _fire(self):
        self.fired = True
        self.on_hang()

    def arm(self):
        self.disarm()
        self._timer = threading.Timer(self.timeout, self._fire)
        self._timer.daemon = True
        self._timer.start()

    def pet(self):
        self.arm()

    @property
    def armed(self) -> bool:
        return self._timer is not None

    def disarm(self):
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None

    def __enter__(self):
        self.arm()
        return self

    def __exit__(self, *exc):
        self.disarm()
        return False

"""Structured span tracing for the serving stack.

A :class:`Tracer` records **nested spans** — named wall-time intervals with
attached attributes (block size, region size, shard id, which backend a
repair phase ran on, ...) — on a monotonic clock. Spans come from three
entry points:

* context manager: ``with tracer.span("serve.flush", batch=64) as sp:
  ... sp.set(cold=3)``;
* decorator: ``@tracer.wrap("retrain.train")``;
* pre-timed: ``tracer.record(name, t0, t1, **attrs)`` for code that already
  measures itself (the incremental-core phase timers hand their intervals
  straight in, so their numbers and the trace are the same measurement).

Disabled tracing is a **zero-work no-op**: ``span()`` returns one shared
:data:`NULL_SPAN` singleton, never touches the clock, and records nothing —
the overhead-guard test asserts this with a counting fake clock, and the
serving benchmark asserts the enabled path stays within a few percent of
ingest throughput.

Exports: JSON-lines (one span per line, machine-diffable) and Chrome
``trace_event`` format (``ph: "X"`` complete events), loadable in
chrome://tracing or https://ui.perfetto.dev. Nesting is reconstructed by the
viewers from containment on the per-thread timeline; ``depth``/``parent``
ride along in ``args`` for programmatic consumers.

A module-level default tracer (disabled until :func:`enable` / a launcher's
``--trace`` flag) is what the serve stack instruments against; tests swap in
their own instance via :func:`set_tracer`.
"""
from __future__ import annotations

import functools
import json
import threading
import time
from typing import Any, Callable, Dict, List, Optional

__all__ = [
    "NULL_SPAN",
    "Span",
    "Tracer",
    "tracer",
    "set_tracer",
    "enable",
    "disable",
    "span",
    "record",
]


class _NullSpan:
    """Shared do-nothing span returned while tracing is disabled.

    One module-level instance (:data:`NULL_SPAN`) serves every disabled
    ``span()`` call — no allocation, no clock read, no bookkeeping.
    """

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()


class Span:
    """One live interval; created by :meth:`Tracer.span`, closed on exit."""

    __slots__ = ("_tracer", "name", "attrs", "t0", "t1", "depth")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self.t0 = 0.0
        self.t1 = 0.0
        self.depth = 0

    def set(self, **attrs) -> "Span":
        """Attach/overwrite attributes mid-span (e.g. sizes known late)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        t = self._tracer
        stack = t._stack()
        self.depth = len(stack)
        stack.append(self.name)
        self.t0 = t._clock()
        return self

    def __exit__(self, *exc) -> bool:
        t = self._tracer
        self.t1 = t._clock()
        stack = t._stack()
        if stack and stack[-1] == self.name:
            stack.pop()
        t._emit(self.name, self.t0, self.t1, self.depth, self.attrs)
        return False


class Tracer:
    def __init__(
        self,
        enabled: bool = False,
        *,
        clock: Callable[[], float] = time.perf_counter,
        max_events: int = 1_000_000,
    ):
        self.enabled = bool(enabled)
        self._clock = clock
        self.max_events = int(max_events)
        self.events: List[Dict[str, Any]] = []
        self.dropped = 0  # events past max_events (never silently truncated)
        self._local = threading.local()

    # ------------------------------------------------------------- recording

    def _stack(self) -> List[str]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _emit(self, name, t0, t1, depth, attrs) -> None:
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        self.events.append(
            {
                "name": name,
                "ts": t0,
                "dur": t1 - t0,
                "depth": depth,
                "tid": threading.get_ident() & 0xFFFF,
                "attrs": attrs,
            }
        )

    def span(self, name: str, **attrs) -> Any:
        """Open a nested span; returns :data:`NULL_SPAN` when disabled."""
        if not self.enabled:
            return NULL_SPAN
        return Span(self, name, attrs)

    def record(self, name: str, t0: float, t1: float, **attrs) -> None:
        """Log an already-measured ``[t0, t1]`` interval as a complete span.

        ``t0``/``t1`` must come from this tracer's clock (the default is
        ``time.perf_counter``, which the serve stack's own timers use) so
        pre-timed spans land on the same timeline as context-manager ones.
        """
        if not self.enabled:
            return
        self._emit(name, t0, t1, len(self._stack()), attrs)

    def wrap(self, name: Optional[str] = None) -> Callable:
        """Decorator form: the wrapped call body becomes one span."""

        def deco(fn):
            span_name = name or fn.__qualname__

            @functools.wraps(fn)
            def inner(*args, **kwargs):
                if not self.enabled:
                    return fn(*args, **kwargs)
                with Span(self, span_name, {}):
                    return fn(*args, **kwargs)

            return inner

        return deco

    def reset(self) -> None:
        self.events = []
        self.dropped = 0
        self._local = threading.local()

    # --------------------------------------------------------------- exports

    def span_names(self) -> set:
        return {e["name"] for e in self.events}

    def export_jsonl(self, path: str) -> int:
        """One JSON object per line per span; returns #spans written."""
        with open(path, "w") as f:
            for e in self.events:
                f.write(json.dumps(e) + "\n")
        return len(self.events)

    def chrome_events(self) -> List[Dict[str, Any]]:
        """Spans as Chrome ``trace_event`` complete ("X") events.

        Timestamps/durations are microseconds since the first recorded span;
        ``pid`` is constant, ``tid`` the recording thread, so nesting renders
        from interval containment on each thread's track.
        """
        t_base = min((e["ts"] for e in self.events), default=0.0)
        out = []
        for e in self.events:
            args = dict(e["attrs"])
            args["depth"] = e["depth"]
            out.append(
                {
                    "name": e["name"],
                    "ph": "X",
                    "ts": (e["ts"] - t_base) * 1e6,
                    "dur": e["dur"] * 1e6,
                    "pid": 0,
                    "tid": e["tid"],
                    "args": args,
                }
            )
        return out

    def export_chrome(self, path: str) -> int:
        """Write the Chrome/Perfetto ``trace_event`` JSON; returns #spans."""
        payload = {
            "traceEvents": self.chrome_events(),
            "displayTimeUnit": "ms",
        }
        if self.dropped:
            payload["metadata"] = {"dropped_events": self.dropped}
        with open(path, "w") as f:
            json.dump(payload, f)
        return len(self.events)


# ------------------------------------------------------------ module default

_tracer = Tracer(enabled=False)


def tracer() -> Tracer:
    """The process-default tracer the serve stack is instrumented against."""
    return _tracer


def set_tracer(t: Tracer) -> Tracer:
    """Swap the default tracer (tests install fake-clock instances)."""
    global _tracer
    _tracer = t
    return t


def enable(**kwargs) -> Tracer:
    """Install a fresh enabled default tracer and return it."""
    return set_tracer(Tracer(enabled=True, **kwargs))


def disable() -> Tracer:
    """Disable default tracing (spans become the shared no-op singleton)."""
    _tracer.enabled = False
    return _tracer


def span(name: str, **attrs) -> Any:
    """``tracer().span(...)`` — the form instrumented code calls."""
    t = _tracer
    if not t.enabled:
        return NULL_SPAN
    return Span(t, name, attrs)


def record(name: str, t0: float, t1: float, **attrs) -> None:
    """``tracer().record(...)`` for pre-timed intervals."""
    t = _tracer
    if t.enabled:
        t._emit(name, t0, t1, len(t._stack()), attrs)

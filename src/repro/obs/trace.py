"""Structured span tracing for the serving stack.

A :class:`Tracer` records **nested spans** — named wall-time intervals with
attached attributes (block size, region size, shard id, which backend a
repair phase ran on, ...) — on a monotonic clock. Spans come from three
entry points:

* context manager: ``with tracer.span("serve.flush", batch=64) as sp:
  ... sp.set(cold=3)``;
* decorator: ``@tracer.wrap("retrain.train")``;
* pre-timed: ``tracer.record(name, t0, t1, **attrs)`` for code that already
  measures itself (the incremental-core phase timers hand their intervals
  straight in, so their numbers and the trace are the same measurement).

Disabled tracing is a **zero-work no-op**: ``span()`` returns one shared
:data:`NULL_SPAN` singleton, never touches the clock, and records nothing —
the overhead-guard test asserts this with a counting fake clock, and the
serving benchmark asserts the enabled path stays within a few percent of
ingest throughput.

Exports: JSON-lines (one span per line, machine-diffable) and Chrome
``trace_event`` format (``ph: "X"`` complete events), loadable in
chrome://tracing or https://ui.perfetto.dev. Nesting is reconstructed by the
viewers from containment on the per-thread timeline; ``depth``/``parent``
ride along in ``args`` for programmatic consumers.

**Tail-sampled exemplars.** A latency histogram's p99 tells you a slow
flush happened; it cannot tell you *which* dispatch was slow or what ran
inside it. For a small watch set of span names (``serve.flush``,
``serve.topk``, and every ``repair.*`` phase by default) the tracer keeps a
bounded ring of recent durations per name and, when a closing span exceeds
the ring's tail quantile (adaptive: the threshold tracks the workload, no
hand-tuned cutoff), it retains the span's **full subtree** — every same-
thread span contained in its interval — as an exemplar. Each exemplar is
keyed by the histogram bucket its root duration falls in (the same
geometric bounds :func:`repro.obs.metrics.default_latency_buckets` gives
the serving histograms), so a tail bucket in the metrics snapshot links to
the exact span tree that put it there. Export via
:meth:`Tracer.export_exemplars`; capture costs one sorted-ring quantile per
watched span close and nothing at all for unwatched names.

A module-level default tracer (disabled until :func:`enable` / a launcher's
``--trace`` flag) is what the serve stack instruments against; tests swap in
their own instance via :func:`set_tracer`.
"""
from __future__ import annotations

import functools
import json
import math
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

__all__ = [
    "NULL_SPAN",
    "Span",
    "Tracer",
    "tracer",
    "set_tracer",
    "enable",
    "disable",
    "span",
    "record",
    "DEFAULT_EXEMPLAR_WATCH",
]

# span names the tracer tail-samples exemplars for; a trailing "." matches
# the whole namespace (every repair phase, present and future)
DEFAULT_EXEMPLAR_WATCH = ("serve.flush", "serve.topk", "repair.")


class _NullSpan:
    """Shared do-nothing span returned while tracing is disabled.

    One module-level instance (:data:`NULL_SPAN`) serves every disabled
    ``span()`` call — no allocation, no clock read, no bookkeeping.
    """

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()


class Span:
    """One live interval; created by :meth:`Tracer.span`, closed on exit."""

    __slots__ = ("_tracer", "name", "attrs", "t0", "t1", "depth")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self.t0 = 0.0
        self.t1 = 0.0
        self.depth = 0

    def set(self, **attrs) -> "Span":
        """Attach/overwrite attributes mid-span (e.g. sizes known late)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        t = self._tracer
        stack = t._stack()
        self.depth = len(stack)
        stack.append(self.name)
        self.t0 = t._clock()
        return self

    def __exit__(self, *exc) -> bool:
        t = self._tracer
        self.t1 = t._clock()
        stack = t._stack()
        if stack and stack[-1] == self.name:
            stack.pop()
        t._emit(self.name, self.t0, self.t1, self.depth, self.attrs)
        return False


class Tracer:
    def __init__(
        self,
        enabled: bool = False,
        *,
        clock: Callable[[], float] = time.perf_counter,
        max_events: int = 1_000_000,
        exemplar_watch: Tuple[str, ...] = DEFAULT_EXEMPLAR_WATCH,
        exemplar_quantile: float = 99.0,
        exemplar_min_samples: int = 16,
        exemplar_ring: int = 512,
        max_exemplars: int = 64,
    ):
        self.enabled = bool(enabled)
        self._clock = clock
        self.max_events = int(max_events)
        self.events: List[Dict[str, Any]] = []
        self.dropped = 0  # events past max_events (never silently truncated)
        self._local = threading.local()
        # tail-sampled exemplars: per watched name, a bounded duration ring
        # drives the adaptive threshold; exemplars are keyed by (name,
        # histogram-bucket index) and keep the slowest capture per bucket
        self.exemplar_watch = tuple(exemplar_watch or ())
        self.exemplar_quantile = float(exemplar_quantile)
        self.exemplar_min_samples = int(exemplar_min_samples)
        self._exemplar_ring = int(exemplar_ring)
        self.max_exemplars = int(max_exemplars)
        self.exemplars: Dict[Tuple[str, int], Dict[str, Any]] = {}
        self.exemplars_dropped = 0
        self._tail_durs: Dict[str, deque] = {}
        self._bucket_bounds: Optional[List[float]] = None

    # ------------------------------------------------------------- recording

    def _stack(self) -> List[str]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _emit(self, name, t0, t1, depth, attrs) -> None:
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        tid = threading.get_ident() & 0xFFFF
        self.events.append(
            {
                "name": name,
                "ts": t0,
                "dur": t1 - t0,
                "depth": depth,
                "tid": tid,
                "attrs": attrs,
            }
        )
        if self.exemplar_watch and self._watched(name):
            self._note_tail(name, t0, t1, depth, tid)

    # ------------------------------------------------------------ exemplars

    def _watched(self, name: str) -> bool:
        for pat in self.exemplar_watch:
            if name == pat or (pat.endswith(".") and name.startswith(pat)):
                return True
        return False

    def _note_tail(self, name, t0, t1, depth, tid) -> None:
        """Adaptive tail check for one closed watched span.

        The threshold is the ring's ``exemplar_quantile`` over the most
        recent durations of this *name* — the workload defines its own
        tail, a cold-start outlier ages out of the ring. The closing span
        is compared before it joins the ring, so a new all-time-slowest
        dispatch is always eligible.
        """
        ring = self._tail_durs.get(name)
        if ring is None:
            ring = self._tail_durs[name] = deque(maxlen=self._exemplar_ring)
        dur = t1 - t0
        if len(ring) >= self.exemplar_min_samples:
            ordered = sorted(ring)
            rank = max(
                int(math.ceil(self.exemplar_quantile / 100.0 * len(ordered)))
                - 1,
                0,
            )
            threshold = ordered[rank]
            if dur > threshold:
                self._capture_exemplar(name, t0, t1, depth, tid, threshold)
        ring.append(dur)

    def _bucket_of(self, dur: float) -> Tuple[int, float, float]:
        """(index, lower, upper) of the latency-histogram bucket holding
        ``dur`` — the same geometric bounds the serving histograms use, so
        an exemplar's key matches the exported bucket it explains."""
        if self._bucket_bounds is None:
            from .metrics import default_latency_buckets

            self._bucket_bounds = [float(b) for b in
                                   default_latency_buckets()]
        b = self._bucket_bounds
        lo_idx, hi_idx = 0, len(b)
        while lo_idx < hi_idx:  # searchsorted(b, dur, side="left")
            mid = (lo_idx + hi_idx) // 2
            if b[mid] < dur:
                lo_idx = mid + 1
            else:
                hi_idx = mid
        lower = 0.0 if lo_idx == 0 else b[lo_idx - 1]
        upper = b[lo_idx] if lo_idx < len(b) else math.inf
        return lo_idx, lower, upper

    def _capture_exemplar(self, name, t0, t1, depth, tid, threshold) -> None:
        dur = t1 - t0
        idx, lower, upper = self._bucket_of(dur)
        key = (name, idx)
        prev = self.exemplars.get(key)
        if prev is not None and prev["dur"] >= dur:
            return  # keep the slowest representative per (name, bucket)
        if prev is None and len(self.exemplars) >= self.max_exemplars:
            self.exemplars_dropped += 1
            return
        # subtree = every same-thread span contained in the root interval.
        # Same-thread events land in close order (monotone end time), so
        # the scan stops at the first same-thread span ending before t0;
        # other threads' events interleave and are skipped.
        spans = []
        for e in reversed(self.events):
            if e["tid"] != tid:
                continue
            if e["ts"] + e["dur"] < t0:
                break
            if e["ts"] >= t0 and e["ts"] + e["dur"] <= t1 \
                    and e["depth"] >= depth:
                spans.append(dict(e))
        spans.reverse()
        self.exemplars[key] = {
            "name": name,
            "ts": t0,
            "dur": dur,
            "threshold": float(threshold),
            "bucket_index": idx,
            "bucket_lower_s": lower,
            "bucket_le_s": upper if math.isfinite(upper) else None,
            "tid": tid,
            "spans": spans,
        }

    def span(self, name: str, **attrs) -> Any:
        """Open a nested span; returns :data:`NULL_SPAN` when disabled."""
        if not self.enabled:
            return NULL_SPAN
        return Span(self, name, attrs)

    def record(self, name: str, t0: float, t1: float, **attrs) -> None:
        """Log an already-measured ``[t0, t1]`` interval as a complete span.

        ``t0``/``t1`` must come from this tracer's clock (the default is
        ``time.perf_counter``, which the serve stack's own timers use) so
        pre-timed spans land on the same timeline as context-manager ones.
        """
        if not self.enabled:
            return
        self._emit(name, t0, t1, len(self._stack()), attrs)

    def wrap(self, name: Optional[str] = None) -> Callable:
        """Decorator form: the wrapped call body becomes one span."""

        def deco(fn):
            span_name = name or fn.__qualname__

            @functools.wraps(fn)
            def inner(*args, **kwargs):
                if not self.enabled:
                    return fn(*args, **kwargs)
                with Span(self, span_name, {}):
                    return fn(*args, **kwargs)

            return inner

        return deco

    def reset(self) -> None:
        self.events = []
        self.dropped = 0
        self._local = threading.local()
        self.exemplars = {}
        self.exemplars_dropped = 0
        self._tail_durs = {}

    # --------------------------------------------------------------- exports

    def span_names(self) -> set:
        return {e["name"] for e in self.events}

    def export_jsonl(self, path: str) -> int:
        """One JSON object per line per span; returns #spans written."""
        with open(path, "w") as f:
            for e in self.events:
                f.write(json.dumps(e) + "\n")
        return len(self.events)

    def chrome_events(self) -> List[Dict[str, Any]]:
        """Spans as Chrome ``trace_event`` complete ("X") events.

        Timestamps/durations are microseconds since the first recorded span;
        ``pid`` is constant, ``tid`` the recording thread, so nesting renders
        from interval containment on each thread's track.
        """
        t_base = min((e["ts"] for e in self.events), default=0.0)
        out = []
        for e in self.events:
            args = dict(e["attrs"])
            args["depth"] = e["depth"]
            out.append(
                {
                    "name": e["name"],
                    "ph": "X",
                    "ts": (e["ts"] - t_base) * 1e6,
                    "dur": e["dur"] * 1e6,
                    "pid": 0,
                    "tid": e["tid"],
                    "args": args,
                }
            )
        return out

    def export_chrome(self, path: str) -> int:
        """Write the Chrome/Perfetto ``trace_event`` JSON; returns #spans."""
        payload = {
            "traceEvents": self.chrome_events(),
            "displayTimeUnit": "ms",
        }
        if self.dropped:
            payload["metadata"] = {"dropped_events": self.dropped}
        with open(path, "w") as f:
            json.dump(payload, f)
        return len(self.events)

    def exemplar_records(self) -> List[Dict[str, Any]]:
        """Exemplars ordered by (name, bucket index), JSON-ready."""
        return [self.exemplars[k] for k in sorted(self.exemplars)]

    def export_exemplars(self, path: str) -> int:
        """Write retained tail exemplars as JSON; returns #exemplars.

        Each record links a histogram bucket (``bucket_lower_s`` <
        ``dur`` <= ``bucket_le_s``) to the full span subtree of the slow
        dispatch that landed in it.
        """
        payload = {
            "exemplars": self.exemplar_records(),
            "dropped": self.exemplars_dropped,
            "quantile": self.exemplar_quantile,
            "watch": list(self.exemplar_watch),
        }
        with open(path, "w") as f:
            json.dump(payload, f)
        return len(self.exemplars)


# ------------------------------------------------------------ module default

_tracer = Tracer(enabled=False)


def tracer() -> Tracer:
    """The process-default tracer the serve stack is instrumented against."""
    return _tracer


def set_tracer(t: Tracer) -> Tracer:
    """Swap the default tracer (tests install fake-clock instances)."""
    global _tracer
    _tracer = t
    return t


def enable(**kwargs) -> Tracer:
    """Install a fresh enabled default tracer and return it."""
    return set_tracer(Tracer(enabled=True, **kwargs))


def disable() -> Tracer:
    """Disable default tracing (spans become the shared no-op singleton)."""
    _tracer.enabled = False
    return _tracer


def span(name: str, **attrs) -> Any:
    """``tracer().span(...)`` — the form instrumented code calls."""
    t = _tracer
    if not t.enabled:
        return NULL_SPAN
    return Span(t, name, attrs)


def record(name: str, t0: float, t1: float, **attrs) -> None:
    """``tracer().record(...)`` for pre-timed intervals."""
    t = _tracer
    if t.enabled:
        t._emit(name, t0, t1, len(t._stack()), attrs)

"""Counters, gauges, and fixed-bucket histograms for the serving stack.

The :class:`MetricsRegistry` is the one place serving numbers accumulate:
ingest edge counts, store hit/cold/spill rates, per-shard gather traffic,
repair-phase and retrain-stage wall time, flush latency. Exports are a JSON
snapshot (what ``benchmarks/serve_latency.py`` derives its artifact sections
from) and Prometheus text exposition format for scraping.

:class:`Histogram` is the bounded replacement for the old append-only
latency lists: it keeps

* **fixed-bucket counts** over the metric's full lifetime (geometric bucket
  upper bounds, Prometheus-style cumulative export), and
* a **bounded ring window** of the most recent ``window`` raw observations
  (default 4096), over which :meth:`percentile` is *exact* — so steady-state
  p50/p99 never pay unbounded memory and never smear over a cold warm-up
  from hours ago. The retained window is the documented semantics: with
  more than ``window`` observations, percentiles describe the latest
  ``window`` samples; bucket counts and count/sum/min/max cover everything.

Like the tracer, a module-level default registry serves the instrumented
stack (:func:`metrics`); tests isolate themselves with :func:`set_metrics`.
"""
from __future__ import annotations

import json
import math
import re
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "metrics",
    "set_metrics",
    "default_latency_buckets",
]


def default_latency_buckets() -> np.ndarray:
    """Geometric upper bounds 1 µs → ~69 s (x2 per bucket), 27 buckets.

    Wide enough for everything the stack times (sub-ms flushes to multi-
    second re-peels) at ~2x resolution; observations past the last edge land
    in the +Inf bucket.
    """
    return 1e-6 * np.power(2.0, np.arange(27))


class Counter:
    """Monotonically increasing count."""

    __slots__ = ("value",)
    kind = "counter"

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError("counters only go up; use a Gauge")
        self.value += n

    def snapshot(self):
        return self.value


class Gauge:
    """Point-in-time value (resident rows, device bytes in use, ...)."""

    __slots__ = ("value",)
    kind = "gauge"

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def dec(self, n: float = 1.0) -> None:
        self.value -= n

    def snapshot(self):
        return self.value


class Histogram:
    """Fixed-bucket histogram + bounded exact-percentile window (see module
    docstring for the retained-window semantics)."""

    kind = "histogram"

    def __init__(
        self,
        buckets: Optional[np.ndarray] = None,
        *,
        window: int = 4096,
    ):
        b = np.asarray(
            default_latency_buckets() if buckets is None else buckets,
            np.float64,
        )
        if b.ndim != 1 or len(b) < 1 or np.any(np.diff(b) <= 0):
            raise ValueError("buckets must be a 1-D increasing array")
        self.buckets = b
        self.counts = np.zeros(len(b) + 1, np.int64)  # last = +Inf bucket
        self.window = int(window)
        if self.window < 1:
            raise ValueError("window must be >= 1")
        self._ring = np.zeros(self.window, np.float64)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    # ------------------------------------------------------------- observe

    def observe(self, x: float) -> None:
        x = float(x)
        self.counts[int(np.searchsorted(self.buckets, x, side="left"))] += 1
        self._ring[self.count % self.window] = x
        self.count += 1
        self.sum += x
        if x < self.min:
            self.min = x
        if x > self.max:
            self.max = x

    # drop-in for the deques ``ServiceStats`` used to hold
    append = observe

    def clear(self) -> None:
        self.counts[:] = 0
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    # ------------------------------------------------------------- windows

    def __len__(self) -> int:
        """#observations retained in the exact-percentile window."""
        return min(self.count, self.window)

    def values(self) -> np.ndarray:
        """Retained window, oldest observation first."""
        if self.count <= self.window:
            return self._ring[: self.count].copy()
        split = self.count % self.window
        return np.concatenate([self._ring[split:], self._ring[:split]])

    def __iter__(self):
        return iter(self.values())

    def __array__(self, dtype=None):
        v = self.values()
        return v if dtype is None else v.astype(dtype)

    def percentile(self, q) -> Any:
        """Exact ``np.percentile`` over the retained window (0 when empty)."""
        v = self.values()
        if not len(v):
            return (
                0.0 if np.isscalar(q) else np.zeros(len(np.atleast_1d(q)))
            )
        return np.percentile(v, q)

    def bucket_percentile(self, q: float) -> float:
        """Percentile estimated from bucket counts alone (lifetime data).

        Linear interpolation inside the winning bucket — accurate to bucket
        resolution; the cross-check that window-exact percentiles and the
        exported bucket counts tell the same story.
        """
        if self.count == 0:
            return 0.0
        cum = np.cumsum(self.counts)
        rank = q / 100.0 * self.count
        i = int(np.searchsorted(cum, rank, side="left"))
        if i >= len(self.buckets):  # ran off into the +Inf bucket
            return float(max(self.max, self.buckets[-1]))
        lo = 0.0 if i == 0 else self.buckets[i - 1]
        hi = self.buckets[i]
        prev = 0 if i == 0 else cum[i - 1]
        in_bucket = max(int(self.counts[i]), 1)
        frac = min(max((rank - prev) / in_bucket, 0.0), 1.0)
        return float(lo + frac * (hi - lo))

    # ------------------------------------------------------------ snapshot

    def snapshot(self) -> Dict[str, Any]:
        p50, p99 = (
            (float(self.percentile(50)), float(self.percentile(99)))
            if self.count
            else (0.0, 0.0)
        )
        return {
            "count": int(self.count),
            "sum": float(self.sum),
            "min": float(self.min) if self.count else 0.0,
            "max": float(self.max) if self.count else 0.0,
            "window": int(self.window),
            "window_len": len(self),
            "p50": p50,
            "p99": p99,
            "buckets": [
                [float(le), int(c)]
                for le, c in zip(
                    list(self.buckets) + [math.inf],
                    np.cumsum(self.counts),
                )
            ],
        }


_METRIC_TYPES = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


def _label_key(labels: Dict[str, Any]) -> Tuple:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


class MetricsRegistry:
    def __init__(self):
        # name -> (kind, {label_key: metric})
        self._metrics: Dict[str, Tuple[str, Dict[Tuple, Any]]] = {}
        self._help: Dict[str, str] = {}

    def describe(self, name: str, text: str) -> None:
        """Attach the ``# HELP`` text exported for ``name``.

        Metrics never described export their own name as help — the
        exposition format wants a HELP line per family either way."""
        self._help[name] = str(text)

    # ------------------------------------------------------------- creation

    def _get(self, kind: str, name: str, labels: Dict[str, Any], factory):
        entry = self._metrics.get(name)
        if entry is None:
            entry = (kind, {})
            self._metrics[name] = entry
        elif entry[0] != kind:
            raise ValueError(
                f"metric {name!r} is a {entry[0]}, requested {kind}"
            )
        key = _label_key(labels)
        m = entry[1].get(key)
        if m is None:
            m = entry[1][key] = factory()
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels, Gauge)

    def histogram(
        self,
        name: str,
        buckets: Optional[np.ndarray] = None,
        window: int = 4096,
        **labels,
    ) -> Histogram:
        return self._get(
            "histogram",
            name,
            labels,
            lambda: Histogram(buckets, window=window),
        )

    def register(self, name: str, metric, *, replace: bool = False, **labels):
        """Adopt an externally owned metric object (e.g. the service's flush
        histogram) so exports read the same instance the owner mutates —
        one source of truth, no copies to drift."""
        kind = metric.kind
        entry = self._metrics.get(name)
        if entry is None:
            entry = (kind, {})
            self._metrics[name] = entry
        elif entry[0] != kind:
            raise ValueError(
                f"metric {name!r} is a {entry[0]}, registering {kind}"
            )
        key = _label_key(labels)
        if key in entry[1] and not replace and entry[1][key] is not metric:
            raise ValueError(f"metric {name!r}{dict(labels)!r} already exists")
        entry[1][key] = metric
        return metric

    # -------------------------------------------------------------- queries

    def get(self, name: str, **labels):
        entry = self._metrics.get(name)
        if entry is None:
            return None
        return entry[1].get(_label_key(labels))

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def series(self, name: str) -> Dict[Tuple, Any]:
        """All labeled instances of ``name`` ({label_key: metric})."""
        entry = self._metrics.get(name)
        return dict(entry[1]) if entry else {}

    def sum_series(self, name: str) -> float:
        """Sum of a counter/gauge across all its label sets (0 if absent)."""
        return float(
            sum(m.value for m in self.series(name).values())
        )

    def reset(self) -> None:
        self._metrics = {}
        self._help = {}

    # -------------------------------------------------------------- exports

    def snapshot(self) -> Dict[str, Any]:
        """JSON-ready tree: {name: {kind, series: [{labels, value}]}}."""
        out = {}
        for name in self.names():
            kind, series = self._metrics[name]
            out[name] = {
                "kind": kind,
                "series": [
                    {"labels": dict(key), "value": m.snapshot()}
                    for key, m in sorted(series.items())
                ],
            }
        return out

    def export_json(self, path: str) -> int:
        snap = self.snapshot()
        with open(path, "w") as f:
            json.dump(snap, f, indent=2)
        return len(snap)

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (v0.0.4).

        Every family gets a ``# HELP`` line (the :meth:`describe` text, or
        the family name when never described) and label values are escaped
        per the spec — backslash, newline, and double-quote — so a label
        carrying a path or an error message cannot corrupt the exposition.
        """
        lines = []
        for name in self.names():
            kind, series = self._metrics[name]
            pname = _NAME_RE.sub("_", name)
            lines.append(
                f"# HELP {pname} {_escape_help(self._help.get(name, pname))}"
            )
            lines.append(f"# TYPE {pname} {kind}")
            for key, m in sorted(series.items()):
                labels = dict(key)
                if kind in ("counter", "gauge"):
                    lines.append(f"{pname}{_fmt_labels(labels)} {m.value:g}")
                    continue
                cum = np.cumsum(m.counts)
                edges = [f"{le:g}" for le in m.buckets] + ["+Inf"]
                for le, c in zip(edges, cum):
                    lab = dict(labels, le=le)
                    lines.append(f"{pname}_bucket{_fmt_labels(lab)} {int(c)}")
                lines.append(f"{pname}_sum{_fmt_labels(labels)} {m.sum:g}")
                lines.append(
                    f"{pname}_count{_fmt_labels(labels)} {int(m.count)}"
                )
        return "\n".join(lines) + ("\n" if lines else "")

    def export_prometheus(self, path: str) -> int:
        text = self.to_prometheus()
        with open(path, "w") as f:
            f.write(text)
        return len(self._metrics)


def _escape_label(v: Any) -> str:
    """Escape one label value per the exposition format: backslash first
    (so the escapes it introduces are not re-escaped), then newline and
    double-quote."""
    return (
        str(v)
        .replace("\\", "\\\\")
        .replace("\n", "\\n")
        .replace('"', '\\"')
    )


def _escape_help(text: str) -> str:
    """HELP text escapes backslash and newline only (quotes are legal)."""
    return str(text).replace("\\", "\\\\").replace("\n", "\\n")


def _fmt_labels(labels: Dict[str, Any]) -> str:
    if not labels:
        return ""
    inner = ",".join(
        f'{k}="{_escape_label(v)}"' for k, v in sorted(labels.items())
    )
    return "{" + inner + "}"


# ------------------------------------------------------------ module default

_registry = MetricsRegistry()


def metrics() -> MetricsRegistry:
    """The process-default registry the serve stack records into."""
    return _registry


def set_metrics(reg: MetricsRegistry) -> MetricsRegistry:
    """Swap the default registry (tests isolate runs with fresh instances)."""
    global _registry
    _registry = reg
    return reg

"""Benchmark history series: append-only run records + robust trend slopes.

PR 7's trend gate compares exactly **two** artifacts (previous main vs this
run), so a regression split into many small steps — each under the pairwise
threshold — is invisible. This module keeps a *series* instead: every
``benchmarks/serve_latency.py`` run appends one schema-validated JSON-lines
record to ``results/history/serve_latency.jsonl`` (git SHA, wall-clock
timestamp, artifact ``schema_version``, and the flattened trend metrics —
per-phase repair seconds, query/topk latencies, ingest edges/s, and the
quality series recall@k / link-pred AUC, which drift just as silently as
latency), and ``scripts/trend_serve_latency.py --gate-slope`` fits a robust
**Theil–Sen** trend over the last N records per series, failing CI on
sustained creep that no single-step diff can see.

Theil–Sen (median of all pairwise slopes) rather than least squares: a CI
runner's occasional 3x outlier run drags an OLS line hard but moves the
median-of-slopes barely at all, so a flat-but-noisy series stays flat and a
genuine monotone creep keeps its slope. The gate condition projects the
fitted slope across the fitted window — ``slope * (n-1)`` is the drift the
trend implies over the window — and fails only when that projected drift
exceeds *both* the relative threshold (vs the series median) and the
absolute noise floor, mirroring the pairwise gate's two-threshold shape.

The flatten / per-phase aggregation helpers the pairwise differ has always
used live here now (one definition of "the trend series"), re-exported by
``scripts/trend_serve_latency.py`` for its existing consumers.
"""
from __future__ import annotations

import json
import os
import subprocess
import time
from typing import Any, Dict, List, Optional, Tuple

from .schema import validate_or_raise

__all__ = [
    "SCHEMA_VERSION",
    "HISTORY_SCHEMA",
    "flatten",
    "phase_aggregates",
    "trend_series",
    "direction",
    "git_sha",
    "append_record",
    "load_history",
    "theil_sen",
    "slope_failures",
]

# version of the results/serve_latency.json artifact layout. Bump when a
# section is renamed or its units change; the trend differ refuses to
# compare artifacts across versions (a near-empty diff would read as "all
# flat"), and the history store stamps every record with the version it
# was written under so slope fits never mix units.
SCHEMA_VERSION = 2

# one history record per benchmark run; validated on write AND on read so a
# hand-edited or truncated line fails loudly instead of skewing the slope
HISTORY_SCHEMA = {
    "type": "object",
    "required": ["schema_version", "git_sha", "timestamp", "metrics"],
    "properties": {
        "schema_version": {"type": "integer", "minimum": 1},
        "git_sha": {"type": "string"},
        "timestamp": {"type": "number", "minimum": 0},
        "quick": {"type": "boolean"},
        "metrics": {"type": "object"},
    },
}


def flatten(obj, prefix=""):
    """dict/list tree -> {dotted.key: leaf} (numbers and bools only)."""
    out = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(flatten(v, f"{prefix}{k}."))
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            out.update(flatten(v, f"{prefix}{i}."))
    elif isinstance(obj, bool):
        out[prefix[:-1]] = int(obj)
    elif isinstance(obj, (int, float)):
        out[prefix[:-1]] = float(obj)
    return out


def phase_aggregates(raw: dict) -> dict:
    """Artifact -> {name: seconds} totals the gates compare.

    Repair phase seconds are summed across every ingest-sweep row plus the
    churn run, keyed by phase name (region / candidates / descend /
    fallback), so the gate tracks where repair time goes overall rather
    than per block size — a single noisy row can't trip it, a systematic
    slowdown in one phase can. Query p50/p99 (the flush-visible latencies)
    ride along as their own rows.
    """
    agg: dict = {}
    sections = list(raw.get("ingest_sweep") or [])
    if raw.get("churn"):
        sections.append(raw["churn"])
    for sec in sections:
        for phase, info in (sec.get("phases") or {}).items():
            agg[phase] = agg.get(phase, 0.0) + float(info.get("seconds", 0))
    for key in ("query_p50_s", "query_p99_s"):
        if key in raw:
            agg[key] = float(raw[key])
    # retrieval latencies (the --topk leg) ride along under their own keys,
    # on both the single-device payload and the sharded section
    for prefix, sec in (("topk", raw.get("topk")),
                        ("sharding.topk", (raw.get("sharding") or {}).get(
                            "topk"))):
        for key in ("query_p50_s", "query_p99_s"):
            if sec and key in sec:
                agg[f"{prefix}.{key}"] = float(sec[key])
    return agg


# metrics where an increase is an improvement; everything else (latencies,
# mismatches, staleness) improves downward. Substring match on the key.
HIGHER_IS_BETTER = (
    "edges_per_s", "qps", "speedup", "auc", "queries", "retrains",
    "recall", "compliance",
)


def direction(key: str) -> int:
    return 1 if any(tok in key for tok in HIGHER_IS_BETTER) else -1


def trend_series(raw: dict) -> Dict[str, float]:
    """Artifact -> the flat series the history store tracks run over run.

    The per-phase seconds + latency aggregates the pairwise gate already
    uses, plus throughput and the **quality** series — recall@k from the
    retrieval oracle harness and held-out link-pred AUC from the retrain
    section — so embedding quality rides the same slope machinery as flush
    p99 (quality drifts just as silently as latency).
    """
    series = dict(phase_aggregates(raw))
    for key in ("ingest_edges_per_s", "qps", "cold_start_fraction"):
        if key in raw:
            series[key] = float(raw[key])
    topk = raw.get("topk") or {}
    if "recall_at_k" in topk:
        series["topk.recall_at_k"] = float(topk["recall_at_k"])
    retrain = raw.get("retrain") or {}
    for key in ("auc_after", "auc_all_after", "staleness_after"):
        if key in retrain:
            series[f"retrain.{key}"] = float(retrain[key])
    slo = raw.get("slo") or {}
    for name, obj in (slo.get("objectives") or {}).items():
        if isinstance(obj, dict) and "compliance" in obj:
            series[f"slo.{name}.compliance"] = float(obj["compliance"])
    return series


def git_sha(cwd: Optional[str] = None) -> str:
    """Current commit SHA, or "unknown" outside a repo / without git."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=cwd, capture_output=True,
            text=True, timeout=10,
        )
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def append_record(
    path: str,
    payload: dict,
    *,
    sha: Optional[str] = None,
    timestamp: Optional[float] = None,
    quick: Optional[bool] = None,
) -> dict:
    """Append one validated history record for ``payload`` to ``path``.

    The record is validated against :data:`HISTORY_SCHEMA` before the write
    (a malformed record must fail at the writer, not skew a later slope
    fit). Returns the record. The parent directory is created on demand so
    a fresh checkout's first benchmark run starts the series.
    """
    record = {
        "schema_version": int(payload.get("schema_version", 1)),
        "git_sha": git_sha() if sha is None else sha,
        "timestamp": float(time.time() if timestamp is None else timestamp),
        "metrics": trend_series(payload),
    }
    if quick is not None:
        record["quick"] = bool(quick)
    validate_or_raise(record, HISTORY_SCHEMA, "history record")
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(record, sort_keys=True) + "\n")
    return record


def load_history(
    path: str, *, last: int = 0, schema_version: Optional[int] = None
) -> List[dict]:
    """Read the JSON-lines history; oldest record first.

    Every line is schema-validated (a truncated tail line — the file is
    append-only, a crashed run can tear it — raises with the line number).
    ``last=N`` keeps only the newest N records; ``schema_version`` filters
    to records written under one artifact version so a slope never spans a
    unit change.
    """
    records: List[dict] = []
    if not os.path.exists(path):
        return records
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(
                    f"{path}:{lineno}: unreadable history record ({e}) — "
                    f"the history file is append-only JSON-lines; remove "
                    f"the torn line to continue the series"
                ) from e
            validate_or_raise(rec, HISTORY_SCHEMA, f"{path}:{lineno}")
            records.append(rec)
    if schema_version is not None:
        records = [
            r for r in records if r["schema_version"] == schema_version
        ]
    if last > 0:
        records = records[-last:]
    return records


def theil_sen(ys) -> Tuple[float, float]:
    """Robust (slope, intercept) of ``ys`` against x = 0..n-1.

    Theil–Sen: the slope is the **median of all pairwise slopes**, the
    intercept the median of ``y - slope*x``. Up to ~29% of points can be
    arbitrary outliers without moving the estimate — exactly the shared-CI
    -runner failure mode (one run on a loaded machine) that makes a least-
    squares fit useless as a gate. O(n^2) pairs; history windows are tens
    of runs, not thousands.
    """
    ys = [float(y) for y in ys]
    n = len(ys)
    if n < 2:
        return 0.0, ys[0] if ys else 0.0
    slopes = [
        (ys[j] - ys[i]) / (j - i)
        for i in range(n) for j in range(i + 1, n)
    ]
    slope = _median(slopes)
    intercept = _median([y - slope * x for x, y in enumerate(ys)])
    return slope, intercept


def _median(xs: List[float]) -> float:
    xs = sorted(xs)
    n = len(xs)
    if not n:
        return 0.0
    mid = n // 2
    return xs[mid] if n % 2 else 0.5 * (xs[mid - 1] + xs[mid])


def slope_failures(
    records: List[dict],
    *,
    pct: float,
    min_ms: float = 3.0,
    min_abs: float = 0.01,
    min_runs: int = 4,
) -> List[Tuple[str, float, float, float]]:
    """Series whose fitted trend projects past both gate thresholds.

    For every metric present in **all** of the given records (a series a
    run is missing has no comparable trend — e.g. a leg that only some
    invocations enable), fit Theil–Sen over run index and project the
    drift across the window: ``drift = slope * (n - 1)``, signed so that
    positive means *worse* (latencies grow / quality falls, via
    :func:`direction`). A series fails when

    * relative projected drift exceeds ``pct`` percent of the series
      median (scale-free: a 1 ms and a 1 s phase gate identically), and
    * absolute projected drift exceeds the noise floor — ``min_ms``
      milliseconds for seconds-valued series, ``min_abs`` for unitless
      ones (AUC, recall, fractions),

    mirroring the pairwise gate's two-threshold shape so runner jitter on
    tiny phases cannot trip it. Returns
    ``(name, median, projected_drift, rel_pct)`` rows; empty when fewer
    than ``min_runs`` records exist (a two-point "trend" is just the
    pairwise diff the single-step gate already covers).
    """
    if len(records) < max(min_runs, 2):
        return []
    common = set(records[0]["metrics"])
    for rec in records[1:]:
        common &= set(rec["metrics"])
    n = len(records)
    bad = []
    for name in sorted(common):
        ys = [float(r["metrics"][name]) for r in records]
        slope, _ = theil_sen(ys)
        # signed so positive drift == regression for every series
        drift = -direction(name) * slope * (n - 1)
        if drift <= 0:
            continue
        med = abs(_median(ys))
        floor = min_ms * 1e-3 if _is_seconds(name) else min_abs
        if drift <= floor:
            continue
        rel = drift / max(med, 1e-12) * 100.0
        if rel > pct:
            bad.append((name, med, drift, rel))
    return bad


def _is_seconds(name: str) -> bool:
    """Seconds-valued series get the millisecond noise floor; unitless
    series (AUC / recall / fractions / edges-per-s) get the absolute one."""
    if name.endswith("_s") or name.endswith("seconds"):
        return True
    # bare repair phase names (region / candidates / descend / fallback and
    # any future phase) are second aggregates from phase_aggregates
    return not any(
        tok in name
        for tok in ("auc", "recall", "fraction", "per_s", "qps",
                    "compliance", "staleness")
    )

"""Minimal JSON-schema validator for benchmark artifacts.

The serving benchmark's JSON (``results/serve_latency.json``) is diffed
across runs by ``scripts/trend_serve_latency.py``; a renamed or
mistyped section would silently diff *nothing* and the trend would look
flat. Validating against the checked-in schema
(``results/serve_latency.schema.json``) makes that failure loud at both
ends — the writer refuses to emit a malformed artifact, the differ refuses
to compare one.

Deliberately tiny (no external dependency): supports the subset of JSON
Schema the artifact needs — ``type`` (string or list of strings),
``properties``, ``required``, ``items``, ``enum``, ``minimum`` /
``maximum``. Unknown keywords are ignored, unknown properties allowed
(forward compatibility: new sections may appear before the schema learns
them; *renaming* an existing required section still fails).
"""
from __future__ import annotations

import json
from typing import Any, List

__all__ = ["SchemaError", "validate", "validate_or_raise", "load_schema"]

_TYPES = {
    "object": dict,
    "array": list,
    "string": str,
    "boolean": bool,
    "null": type(None),
}


class SchemaError(ValueError):
    """Raised by :func:`validate_or_raise` with every violation listed."""


def _type_ok(value: Any, t: str) -> bool:
    if t == "number":
        return isinstance(value, (int, float)) and not isinstance(value, bool)
    if t == "integer":
        return isinstance(value, int) and not isinstance(value, bool)
    py = _TYPES.get(t)
    return py is not None and isinstance(value, py)


def validate(instance: Any, schema: dict, path: str = "$") -> List[str]:
    """Return a list of human-readable violations (empty = valid)."""
    errors: List[str] = []
    t = schema.get("type")
    if t is not None:
        allowed = [t] if isinstance(t, str) else list(t)
        if not any(_type_ok(instance, a) for a in allowed):
            errors.append(
                f"{path}: expected type {'/'.join(allowed)}, "
                f"got {type(instance).__name__}"
            )
            return errors  # child checks would only cascade noise
    if "enum" in schema and instance not in schema["enum"]:
        errors.append(f"{path}: {instance!r} not in enum {schema['enum']}")
    if isinstance(instance, (int, float)) and not isinstance(instance, bool):
        if "minimum" in schema and instance < schema["minimum"]:
            errors.append(
                f"{path}: {instance} < minimum {schema['minimum']}"
            )
        if "maximum" in schema and instance > schema["maximum"]:
            errors.append(
                f"{path}: {instance} > maximum {schema['maximum']}"
            )
    if isinstance(instance, dict):
        for req in schema.get("required", ()):
            if req not in instance:
                errors.append(f"{path}: missing required key {req!r}")
        for key, sub in schema.get("properties", {}).items():
            if key in instance:
                errors.extend(validate(instance[key], sub, f"{path}.{key}"))
    if isinstance(instance, list) and "items" in schema:
        for i, item in enumerate(instance):
            errors.extend(validate(item, schema["items"], f"{path}[{i}]"))
    return errors


def validate_or_raise(instance: Any, schema: dict, name: str = "payload"):
    errors = validate(instance, schema)
    if errors:
        raise SchemaError(
            f"{name} does not match schema ({len(errors)} violation(s)):\n"
            + "\n".join(f"  - {e}" for e in errors)
        )


def load_schema(path: str) -> dict:
    with open(path) as f:
        return json.load(f)

"""Live SLO engine: declarative objectives, burn-rate alerts, health().

The metrics registry answers "what are the numbers"; this layer answers
"are we keeping the promises". An :class:`Objective` is a declarative
statement over one observed quantity — *flush latency ≤ 50 ms for 99% of
flushes*, *ingest throughput ≥ 10k edges/s*, *stale-row fraction ≤ 5%*,
*degraded-serving fraction ≤ 1%* — and the :class:`SLOEngine` evaluates
every objective continuously over **rolling time windows** of the events
the serving stack feeds it.

Alerting follows the multi-window burn-rate recipe: with error budget
``1 - objective`` (the fraction of bad events the SLO tolerates), the
**burn rate** of a window is ``bad_fraction / budget`` — 1.0 means the
budget is being spent exactly as fast as the SLO allows, N means N× too
fast. An alert fires only when the burn rate exceeds the objective's
threshold over the **long** window (the regression is sustained, not one
spike) *and* over the **short** window (it is still happening — a
long-window alert alone would keep paging for an hour after the incident
ended). Both windows prune by the engine's clock, injectable for tests.

Two observation styles:

* **event objectives** — the hot path calls ``engine.observe(name, value)``
  per event (each flush's seconds, each block's edges/s). Cost per call is
  one comparison + one deque append; the serving benchmark's
  ``--assert-overhead`` guard runs with the engine attached, so the budget
  covers it.
* **sampled objectives** — quantities that are expensive to compute per
  event (store staleness walks every resident row) register a ``provider``
  callable instead; :meth:`SLOEngine.sample` / :meth:`health` pull a
  reading on demand.

``health()`` returns the full snapshot (per-objective compliance, burn
rates, alert state, and an overall status), and :meth:`publish` exports the
same numbers through the metrics registry (``slo_compliance{slo=}``,
``slo_burn_rate{slo=,window=}``, ``slo_alert{slo=}``,
``slo_alerts_total{slo=}``) so the SLO view ships in every metrics
snapshot next to the raw histograms.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, Optional, Tuple

__all__ = ["Objective", "SLOEngine", "default_slos"]


@dataclasses.dataclass(frozen=True)
class Objective:
    """One service-level objective over a single observed quantity.

    ``op`` compares each observation against ``target`` ("<=" for
    latencies/fractions, ">=" for throughputs); an observation that fails
    the comparison is a *bad event*. ``objective`` is the promised good
    fraction (0.99 = 1% error budget). ``long_window`` / ``short_window``
    are the burn-rate windows in engine-clock seconds;
    ``alert_burn_rate`` is the multiple of budget-spend speed that pages.
    """

    name: str
    target: float
    op: str = "<="  # "<=" or ">="
    objective: float = 0.99
    long_window: float = 60.0
    short_window: float = 5.0
    alert_burn_rate: float = 4.0
    description: str = ""

    def __post_init__(self):
        if self.op not in ("<=", ">="):
            raise ValueError(f"op must be '<=' or '>=', got {self.op!r}")
        if not 0.0 < self.objective < 1.0:
            raise ValueError("objective must be in (0, 1)")
        if self.short_window > self.long_window:
            raise ValueError("short_window must not exceed long_window")

    def good(self, value: float) -> bool:
        return (value <= self.target) if self.op == "<=" \
            else (value >= self.target)


class _Window:
    """Rolling (t, good) events over the long window; prunes lazily."""

    __slots__ = ("events",)

    def __init__(self):
        self.events: Deque[Tuple[float, bool]] = deque()

    def add(self, t: float, good: bool, horizon: float) -> None:
        self.events.append((t, good))
        self.prune(t - horizon)

    def prune(self, cutoff: float) -> None:
        ev = self.events
        while ev and ev[0][0] < cutoff:
            ev.popleft()

    def stats(self, now: float, window: float) -> Tuple[int, int]:
        """(bad, total) among events within ``window`` seconds of ``now``."""
        cutoff = now - window
        bad = total = 0
        for t, good in reversed(self.events):
            if t < cutoff:
                break
            total += 1
            bad += not good
        return bad, total


class SLOEngine:
    def __init__(self, *, clock: Callable[[], float] = time.perf_counter):
        self._clock = clock
        self._objectives: Dict[str, Objective] = {}
        self._providers: Dict[str, Callable[[], float]] = {}
        self._windows: Dict[str, _Window] = {}
        self._alerting: Dict[str, bool] = {}
        self._alerts_total: Dict[str, int] = {}

    # ---------------------------------------------------------- definition

    def add(
        self,
        objective: Objective,
        *,
        provider: Optional[Callable[[], float]] = None,
    ) -> Objective:
        """Register an objective; ``provider`` makes it sampled-style."""
        if objective.name in self._objectives:
            raise ValueError(f"objective {objective.name!r} already defined")
        self._objectives[objective.name] = objective
        self._windows[objective.name] = _Window()
        self._alerting[objective.name] = False
        self._alerts_total[objective.name] = 0
        if provider is not None:
            self._providers[objective.name] = provider
        return objective

    def names(self):
        return sorted(self._objectives)

    def objective(self, name: str) -> Objective:
        return self._objectives[name]

    # --------------------------------------------------------- observation

    def observe(self, name: str, value: float) -> bool:
        """Record one event; returns whether it was good.

        Hot-path cost: one comparison, one deque append, one amortised
        prune. Unknown names raise — a typo'd observation would otherwise
        silently evaluate no objective at all.
        """
        obj = self._objectives[name]
        good = obj.good(float(value))
        self._windows[name].add(self._clock(), good, obj.long_window)
        return good

    def sample(self, name: Optional[str] = None) -> None:
        """Pull one reading from each (or one) provider-backed objective."""
        names = [name] if name is not None else list(self._providers)
        for n in names:
            provider = self._providers.get(n)
            if provider is not None:
                self.observe(n, float(provider()))

    # ---------------------------------------------------------- evaluation

    def evaluate(self, name: str) -> Dict[str, Any]:
        """Compliance + burn rates + alert state for one objective.

        The alert flag latches through :meth:`_update_alert` so
        ``slo_alerts_total`` counts alert *onsets*, not every evaluation
        while the condition persists.
        """
        obj = self._objectives[name]
        now = self._clock()
        win = self._windows[name]
        win.prune(now - obj.long_window)
        bad_l, n_l = win.stats(now, obj.long_window)
        bad_s, n_s = win.stats(now, obj.short_window)
        budget = 1.0 - obj.objective
        compliance = 1.0 - (bad_l / n_l) if n_l else 1.0
        burn_long = (bad_l / n_l) / budget if n_l else 0.0
        burn_short = (bad_s / n_s) / budget if n_s else 0.0
        alerting = (
            n_l > 0
            and burn_long >= obj.alert_burn_rate
            and burn_short >= obj.alert_burn_rate
        )
        self._update_alert(name, alerting)
        return {
            "target": obj.target,
            "op": obj.op,
            "objective": obj.objective,
            "events": int(n_l),
            "bad_events": int(bad_l),
            "compliance": float(compliance),
            "burn_rate_long": float(burn_long),
            "burn_rate_short": float(burn_short),
            "alert_burn_rate": obj.alert_burn_rate,
            "alerting": bool(alerting),
            "alerts_total": int(self._alerts_total[name]),
        }

    def _update_alert(self, name: str, alerting: bool) -> None:
        if alerting and not self._alerting[name]:
            self._alerts_total[name] += 1
        self._alerting[name] = alerting

    def health(self) -> Dict[str, Any]:
        """Whole-service snapshot: every objective + an overall status.

        ``status`` is ``"alert"`` if any objective's multi-window burn
        condition holds, ``"ok"`` when all objectives have data and none
        alert, ``"no_data"`` when nothing has been observed yet. Sampled
        objectives are pulled first so the snapshot is never staler than
        its own call.
        """
        self.sample()
        objectives = {name: self.evaluate(name) for name in self.names()}
        if not objectives or all(o["events"] == 0
                                 for o in objectives.values()):
            status = "no_data"
        elif any(o["alerting"] for o in objectives.values()):
            status = "alert"
        else:
            status = "ok"
        return {"status": status, "objectives": objectives}

    # ------------------------------------------------------------- exports

    def publish(self, registry) -> None:
        """Export the current health through a metrics registry."""
        health = self.health()
        for name, o in health["objectives"].items():
            registry.gauge("slo_compliance", slo=name).set(o["compliance"])
            registry.gauge(
                "slo_burn_rate", slo=name, window="long"
            ).set(o["burn_rate_long"])
            registry.gauge(
                "slo_burn_rate", slo=name, window="short"
            ).set(o["burn_rate_short"])
            registry.gauge("slo_alert", slo=name).set(int(o["alerting"]))
            c = registry.counter("slo_alerts_total", slo=name)
            c.inc(max(o["alerts_total"] - c.value, 0))
        registry.gauge("slo_healthy").set(
            int(health["status"] != "alert")
        )


def default_slos(
    *,
    flush_p99_s: float = 0.25,
    ingest_edges_per_s: float = 1000.0,
    staleness_fraction: float = 0.5,
    degraded_fraction: float = 0.01,
    clock: Callable[[], float] = time.perf_counter,
    staleness_provider: Optional[Callable[[], float]] = None,
) -> SLOEngine:
    """The serving stack's stock objectives, thresholds overridable.

    Defaults are deliberately loose for CI (shared-runner latency is
    noisy); production deployments tighten them per traffic class. The
    ``degraded`` objective's target is 0 with a tiny budget: any degraded
    flush is a bad event, and the budget/burn windows decide when enough
    of them page.
    """
    eng = SLOEngine(clock=clock)
    eng.add(Objective(
        "flush_latency", flush_p99_s, "<=", objective=0.99,
        description="per-flush wall seconds within target",
    ))
    eng.add(Objective(
        "ingest_rate", ingest_edges_per_s, ">=", objective=0.95,
        description="per-block ingest edges/s at or above target",
    ))
    eng.add(Objective(
        "degraded_serving", 0.0, "<=", objective=1.0 - degraded_fraction,
        description="flushes answered from stale rows (degraded fallback)",
    ))
    eng.add(
        Objective(
            "staleness", staleness_fraction, "<=", objective=0.9,
            description="fraction of store rows with a stale core tag",
        ),
        provider=staleness_provider,
    )
    return eng

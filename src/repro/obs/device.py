"""Device-side observability hooks: profiler traces, dispatch costs, memory.

Three independent hooks, each tolerant of backends that do not support it
(CPU has no ``memory_stats``; some jax builds lack pieces of the profiler
API) — observability must never take the serving path down:

* :func:`device_profile` — context manager around
  ``jax.profiler.start_trace`` / ``stop_trace``, so an ingest sweep or
  query replay can be captured as a full XLA device profile (open the
  resulting directory with TensorBoard or Perfetto). No-ops, recording why,
  when the profiler is unavailable.
* :func:`compiled_cost` — per-dispatch cost of a jitted function on
  concrete arguments via AOT ``lower().compile().cost_analysis()`` (flops
  and bytes accessed, the roofline inputs) plus ``memory_analysis`` byte
  sizes. This is how the Pallas h-index / ellmean dispatches get *measured*
  cost numbers instead of guessed ones.
* :func:`record_memory` — live per-device memory gauges
  (``device_bytes_in_use{device=...}``) from ``Device.memory_stats()``,
  skipping devices that report nothing.
"""
from __future__ import annotations

import contextlib
from typing import Any, Dict, Optional

import jax

from .metrics import MetricsRegistry, metrics

__all__ = ["device_profile", "compiled_cost", "record_memory"]


@contextlib.contextmanager
def device_profile(logdir: Optional[str]):
    """Capture a ``jax.profiler`` trace of the enclosed block into ``logdir``.

    Yields a status dict: ``{"active": bool, "logdir": ..., "error": ...}``.
    A ``None``/empty ``logdir`` or an unavailable profiler yields inactive
    instead of raising — callers wrap hot serving loops with this.
    """
    status: Dict[str, Any] = {"active": False, "logdir": logdir}
    if not logdir:
        yield status
        return
    try:
        jax.profiler.start_trace(logdir)
        status["active"] = True
    except Exception as e:  # pragma: no cover - backend/build specific
        status["error"] = f"{type(e).__name__}: {e}"
        yield status
        return
    try:
        yield status
    finally:
        try:
            jax.profiler.stop_trace()
        except Exception as e:  # pragma: no cover
            status["error"] = f"{type(e).__name__}: {e}"


def compiled_cost(fn, *args, **kwargs) -> Dict[str, Any]:
    """Cost/memory analysis of one jitted dispatch on concrete arguments.

    ``fn`` must be a ``jax.jit``-wrapped callable; ``args``/``kwargs`` are
    example inputs of the shapes the serving path actually dispatches.
    Returns ``{"flops", "bytes_accessed", "argument_bytes", "output_bytes",
    "temp_bytes"}`` with 0.0 where the backend reports nothing, or
    ``{"error": ...}`` when AOT lowering itself is unsupported.
    """
    try:
        compiled = fn.lower(*args, **kwargs).compile()
    except Exception as e:
        return {"error": f"{type(e).__name__}: {e}"}
    out: Dict[str, Any] = {}
    try:
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):  # older jax: one dict per program
            ca = ca[0] if ca else {}
        out["flops"] = float(ca.get("flops", 0.0))
        out["bytes_accessed"] = float(ca.get("bytes accessed", 0.0))
    except Exception:
        out["flops"] = out["bytes_accessed"] = 0.0
    try:
        ma = compiled.memory_analysis()
        out["argument_bytes"] = int(ma.argument_size_in_bytes)
        out["output_bytes"] = int(ma.output_size_in_bytes)
        out["temp_bytes"] = int(ma.temp_size_in_bytes)
    except Exception:
        out["argument_bytes"] = out["output_bytes"] = out["temp_bytes"] = 0
    return out


def record_memory(
    registry: Optional[MetricsRegistry] = None,
) -> Dict[str, int]:
    """Set ``device_bytes_in_use`` / ``device_bytes_limit`` gauges per device.

    Returns ``{device_label: bytes_in_use}`` for the devices that report
    stats (CPU's ``memory_stats()`` is ``None`` — those are skipped, so on
    host-only runs this is an empty dict, not an error).
    """
    reg = metrics() if registry is None else registry
    seen: Dict[str, int] = {}
    for d in jax.devices():
        try:
            stats = d.memory_stats()
        except Exception:  # pragma: no cover - backend specific
            stats = None
        if not stats:
            continue
        label = f"{d.platform}:{d.id}"
        in_use = int(stats.get("bytes_in_use", 0))
        reg.gauge("device_bytes_in_use", device=label).set(in_use)
        limit = stats.get("bytes_limit") or stats.get("bytes_reservable_limit")
        if limit:
            reg.gauge("device_bytes_limit", device=label).set(int(limit))
        peak = stats.get("peak_bytes_in_use")
        if peak:
            reg.gauge("device_peak_bytes_in_use", device=label).set(int(peak))
        seen[label] = in_use
    return seen

"""Structured observability for the serving stack.

Three layers, one import surface:

* ``obs.trace`` — nested span tracing (:class:`Tracer`), exportable as
  JSON-lines and Chrome ``trace_event`` format (chrome://tracing /
  Perfetto). Disabled tracing is a zero-work no-op singleton span, so the
  instrumentation can live on the ingest/flush hot paths permanently.
* ``obs.metrics`` — :class:`MetricsRegistry` of counters, gauges, and
  fixed-bucket histograms with a bounded exact-percentile window; exported
  as a JSON snapshot and Prometheus text format.
* ``obs.device`` — jax device hooks: ``jax.profiler`` trace capture around
  serving phases, per-dispatch ``cost_analysis`` of jitted programs, and
  live device-memory gauges.
* ``obs.slo`` — live SLO engine: declarative :class:`Objective` targets
  evaluated over rolling windows with multi-window burn-rate alerts and a
  ``health()`` snapshot, published through the metrics registry.
* ``obs.history`` — benchmark history store: schema-validated JSON-lines
  records per run (git SHA, timestamp, flattened metrics) and the robust
  Theil–Sen slope gate over the resulting series.

The serve stack records against the process-default tracer/registry
(:func:`tracer` / :func:`metrics`); launchers flip them on with ``--trace``
/ ``--metrics-out``; tests isolate state via :func:`set_tracer` /
:func:`set_metrics`.
"""
from .device import compiled_cost, device_profile, record_memory
from .history import (
    SCHEMA_VERSION,
    append_record,
    load_history,
    slope_failures,
    theil_sen,
    trend_series,
)
from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    default_latency_buckets,
    metrics,
    set_metrics,
)
from .schema import SchemaError, load_schema, validate, validate_or_raise
from .slo import Objective, SLOEngine, default_slos
from .trace import (
    DEFAULT_EXEMPLAR_WATCH,
    NULL_SPAN,
    Span,
    Tracer,
    disable,
    enable,
    record,
    set_tracer,
    span,
    tracer,
)

__all__ = [
    # trace
    "NULL_SPAN",
    "Span",
    "Tracer",
    "tracer",
    "set_tracer",
    "enable",
    "disable",
    "span",
    "record",
    "DEFAULT_EXEMPLAR_WATCH",
    # metrics
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "metrics",
    "set_metrics",
    "default_latency_buckets",
    # device
    "device_profile",
    "compiled_cost",
    "record_memory",
    # schema
    "SchemaError",
    "validate",
    "validate_or_raise",
    "load_schema",
    # slo
    "Objective",
    "SLOEngine",
    "default_slos",
    # history
    "SCHEMA_VERSION",
    "append_record",
    "load_history",
    "trend_series",
    "theil_sen",
    "slope_failures",
]

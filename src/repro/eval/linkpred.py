"""Downstream link-prediction evaluation (paper §1.2.2, §3.1.2).

A logistic regression is trained on the concatenation of the two node
embeddings of each candidate pair (the paper's protocol) and scored with F1.
Implemented in JAX (full-batch Adam); no sklearn dependency.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.train import optim

__all__ = [
    "LinkPredResult",
    "auc_score",
    "evaluate_link_prediction",
    "f1_score",
]


def auc_score(y_true: np.ndarray, scores: np.ndarray) -> float:
    """Ranking AUC: P(score of a positive > score of a negative), ties 0.5.

    Computed from the Mann–Whitney U statistic over average ranks — no
    threshold sweep and no sklearn dependency. The serving benchmark uses
    this on raw dot-product link scores (pre/post retrain), where a logistic
    fit would conflate embedding quality with classifier training.
    """
    y = np.asarray(y_true).astype(bool).reshape(-1)
    s = np.asarray(scores, np.float64).reshape(-1)
    n_pos = int(y.sum())
    n_neg = len(y) - n_pos
    if n_pos == 0 or n_neg == 0:
        return 0.5
    order = np.argsort(s, kind="mergesort")
    ranks = np.empty(len(s), np.float64)
    ranks[order] = np.arange(1, len(s) + 1)
    # average the ranks of tied scores so ties count half either way
    uniq, inv, counts = np.unique(s, return_inverse=True, return_counts=True)
    if len(uniq) != len(s):
        sums = np.zeros(len(uniq))
        np.add.at(sums, inv, ranks)
        ranks = (sums / counts)[inv]
    u = ranks[y].sum() - n_pos * (n_pos + 1) / 2
    return float(u / (n_pos * n_neg))


def f1_score(y_true: np.ndarray, y_pred: np.ndarray) -> float:
    tp = float(np.sum((y_pred == 1) & (y_true == 1)))
    fp = float(np.sum((y_pred == 1) & (y_true == 0)))
    fn = float(np.sum((y_pred == 0) & (y_true == 1)))
    precision = tp / (tp + fp) if tp + fp else 0.0
    recall = tp / (tp + fn) if tp + fn else 0.0
    if precision + recall == 0:
        return 0.0
    return 2 * precision * recall / (precision + recall)


@dataclasses.dataclass
class LinkPredResult:
    f1: float
    accuracy: float
    n_train: int
    n_test: int


@partial(jax.jit, static_argnames=("iters",))
def _fit_logreg(X, y, iters: int = 400, lr: float = 0.05):
    D = X.shape[1]
    params = {"w": jnp.zeros((D,), jnp.float32), "b": jnp.zeros((), jnp.float32)}
    opt = optim.adam(lr)
    state = opt.init(params)

    def loss_fn(p):
        logits = X @ p["w"] + p["b"]
        return jnp.mean(
            jax.nn.softplus(logits) - y * logits
        ) + 1e-4 * jnp.sum(p["w"] ** 2)

    def step(carry, _):
        p, s = carry
        g = jax.grad(loss_fn)(p)
        upd, s = opt.update(g, s, p)
        return (optim.apply_updates(p, upd), s), ()

    (params, _), _ = jax.lax.scan(step, (params, state), None, length=iters)
    return params


def _features(emb: np.ndarray, pairs: np.ndarray, mode: str = "concat") -> np.ndarray:
    a, b = emb[pairs[:, 0]], emb[pairs[:, 1]]
    if mode == "concat":  # the paper's choice
        return np.concatenate([a, b], axis=1)
    if mode == "hadamard":
        return a * b
    raise ValueError(mode)


def evaluate_link_prediction(
    emb: np.ndarray,
    pairs: np.ndarray,
    labels: np.ndarray,
    *,
    train_frac: float = 0.6,
    feature_mode: str = "concat",
    seed: int = 0,
) -> LinkPredResult:
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(pairs))
    n_train = int(train_frac * len(pairs))
    tr, te = order[:n_train], order[n_train:]

    X = _features(emb.astype(np.float32), pairs, feature_mode)
    mu, sd = X[tr].mean(0), X[tr].std(0) + 1e-8
    X = (X - mu) / sd

    params = _fit_logreg(jnp.asarray(X[tr]), jnp.asarray(labels[tr]))
    logits = X[te] @ np.asarray(params["w"]) + float(params["b"])
    pred = (logits > 0).astype(np.int32)
    y = labels[te].astype(np.int32)
    return LinkPredResult(
        f1=f1_score(y, pred),
        accuracy=float(np.mean(pred == y)),
        n_train=len(tr),
        n_test=len(te),
    )

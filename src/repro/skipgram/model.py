"""SGNS embedding model: parameters, loss, and the sharded train step.

Two embedding tables (input/"center" and output/"context"), as in word2vec.
The tables are the memory scaling axis — for a billion-node graph they are
row-sharded over the mesh `model` axis (see configs/deepwalk_web.py); on this
container they are replicated. The final node representation is ``emb_in``
(gensim convention, matching the paper's DeepWalk setup).
"""
from __future__ import annotations

from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp

from repro.kernels import ops

__all__ = ["init_params", "batch_loss", "Params"]

Params = Dict[str, jnp.ndarray]


def init_params(n_nodes: int, dim: int, key, dtype=jnp.float32) -> Params:
    """word2vec-style init: uniform(-0.5, 0.5)/dim for input, zeros for output."""
    k1, _ = jax.random.split(key)
    emb_in = (jax.random.uniform(k1, (n_nodes, dim), jnp.float32) - 0.5) / dim
    emb_out = jnp.zeros((n_nodes, dim), jnp.float32)
    return {"emb_in": emb_in.astype(dtype), "emb_out": emb_out.astype(dtype)}


@partial(jax.jit, static_argnames=("impl",))
def batch_loss(params: Params, centers, contexts, negatives, impl: str = "auto"):
    """Mean SGNS loss over a batch of (center, context, K negatives) ids."""
    c = params["emb_in"][centers]  # (B, D)
    x = params["emb_out"][contexts]  # (B, D)
    n = params["emb_out"][negatives]  # (B, K, D)
    return ops.sgns_loss(c, x, n, impl=impl).mean()

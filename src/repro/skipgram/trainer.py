"""SGNS trainer: jit'd step, epoch accounting proportional to corpus size.

The paper's speedups come from corpus reduction; this trainer makes that
explicit: ``steps = pairs_per_epoch(window) * epochs / batch``. Wall-clock on
this CPU container tracks step count (same step shape for all plans), so the
paper's speedup columns are reproduced both in wall-clock and in step counts.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.train import optim

from .corpus import WalkCorpus, sample_batch
from .model import batch_loss, init_params

__all__ = ["SGNSConfig", "SGNSResult", "train_sgns"]


@dataclasses.dataclass
class SGNSConfig:
    dim: int = 150  # paper §3.1.2
    window: int = 4
    n_neg: int = 5
    batch: int = 4096
    epochs: float = 1.0
    lr: float = 0.025
    seed: int = 0
    impl: str = "auto"  # kernel dispatch: auto | ref | pallas | pallas_interpret


@dataclasses.dataclass
class SGNSResult:
    embeddings: np.ndarray  # (V, dim) float32 — emb_in
    n_steps: int
    train_seconds: float
    final_loss: float


@partial(jax.jit, static_argnames=("impl", "window", "n_neg", "batch", "opt_update"), donate_argnums=(0, 1))
def _train_step(params, opt_state, walks_nreal_cdf, key, *, impl, window, n_neg, batch, opt_update):
    walks, n_real, noise_cdf = walks_nreal_cdf
    from .corpus import _sample  # jit-inlined

    centers, contexts, negatives = _sample(
        walks, noise_cdf, key, batch, window, n_neg, walks.shape[1], n_real
    )
    loss, grads = jax.value_and_grad(batch_loss)(
        params, centers, contexts, negatives, impl
    )
    updates, opt_state = opt_update(grads, opt_state, params)
    params = optim.apply_updates(params, updates)
    return params, opt_state, loss


def train_sgns(
    corpus: WalkCorpus, cfg: SGNSConfig, *, params=None, steps: Optional[int] = None
) -> SGNSResult:
    key = jax.random.PRNGKey(cfg.seed)
    kinit, ktrain = jax.random.split(key)
    if params is None:
        params = init_params(corpus.n_nodes, cfg.dim, kinit)
    opt = optim.adam(cfg.lr)
    opt_state = opt.init(params)
    if steps is None:
        steps = max(1, int(cfg.epochs * corpus.pairs_per_epoch(cfg.window) // cfg.batch))

    n_real = corpus.n_real
    loss = jnp.zeros(())
    t0 = time.perf_counter()
    for s in range(steps):
        params, opt_state, loss = _train_step(
            params,
            opt_state,
            (corpus.walks, n_real, corpus.noise_cdf),
            jax.random.fold_in(ktrain, s),
            impl=cfg.impl,
            window=cfg.window,
            n_neg=cfg.n_neg,
            batch=cfg.batch,
            opt_update=opt.update,
        )
    loss = float(loss)
    dt = time.perf_counter() - t0
    return SGNSResult(
        embeddings=np.asarray(params["emb_in"], dtype=np.float32),
        n_steps=steps,
        train_seconds=dt,
        final_loss=loss,
    )

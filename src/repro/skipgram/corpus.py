"""Walk corpus construction and SGNS batch sampling.

The corpus is the set of random walks (W, L) generated from a WalkPlan —
the *size* of this corpus is what the paper's CoreWalk shrinks. Training
samples (center, context) pairs exactly like word2vec: uniform walk, uniform
position, uniform offset in [1, window] with random sign (equivalent to the
standard dynamic-window trick in expectation), and draws K negatives from the
unigram^0.75 noise distribution over corpus token counts.

Epoch accounting follows the paper: one epoch = ``pairs_per_walk * n_real``
sampled pairs, so a smaller corpus (CoreWalk / k-core) trains in
proportionally fewer steps — the hardware-independent speedup.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.corewalk import WalkPlan
from repro.graph.csr import EllGraph
from repro.walks.engine import node2vec_walks, random_walks

__all__ = ["WalkCorpus", "build_corpus", "sample_batch"]


@dataclasses.dataclass
class WalkCorpus:
    walks: jnp.ndarray  # (W, L) int32, padding walks included
    n_real: int  # number of real (non-padding) walks
    length: int
    noise_cdf: jnp.ndarray  # (V,) float32 cumulative unigram^0.75
    n_nodes: int

    @property
    def n_tokens(self) -> int:
        return self.n_real * self.length

    def pairs_per_epoch(self, window: int) -> int:
        # every position pairs with ~window contexts on average (edge-clipped)
        return self.n_real * self.length * window


def build_corpus(
    ell: EllGraph,
    plan: WalkPlan,
    length: int,
    key,
    *,
    p: float = 1.0,
    q: float = 1.0,
    chunk: int = 65536,
) -> WalkCorpus:
    """Run the plan's walks in bounded-memory chunks and assemble the corpus."""
    roots = jnp.asarray(plan.roots)
    outs = []
    for start in range(0, plan.n_slots, chunk):
        sub = roots[start : start + chunk]
        k = jax.random.fold_in(key, start)
        if p == 1.0 and q == 1.0:
            outs.append(random_walks(ell, sub, length, k))
        else:
            outs.append(node2vec_walks(ell, sub, length, k, p=p, q=q))
    walks = jnp.concatenate(outs, axis=0) if len(outs) > 1 else outs[0]

    counts = np.bincount(
        np.asarray(walks[: plan.n_real]).reshape(-1), minlength=ell.n_nodes
    ).astype(np.float64)
    probs = counts**0.75
    total = probs.sum()
    probs = probs / total if total > 0 else np.full_like(probs, 1.0 / len(probs))
    cdf = jnp.asarray(np.cumsum(probs), dtype=jnp.float32)
    return WalkCorpus(
        walks=walks,
        n_real=plan.n_real,
        length=length,
        noise_cdf=cdf,
        n_nodes=ell.n_nodes,
    )


@partial(jax.jit, static_argnames=("batch", "n_neg"))
def _sample(walks, noise_cdf, key, batch, window, n_neg, length, n_real):
    kw, kp, ko, ks, kn = jax.random.split(key, 5)
    w = jax.random.randint(kw, (batch,), 0, n_real)
    i = jax.random.randint(kp, (batch,), 0, length)
    off = jax.random.randint(ko, (batch,), 1, window + 1)
    sign = jax.random.bernoulli(ks, 0.5, (batch,)).astype(jnp.int32) * 2 - 1
    j = i + sign * off
    # reflect at the boundaries (keeps offset magnitude, stays in-walk)
    j = jnp.where(j < 0, i + off, j)
    j = jnp.where(j >= length, i - off, j)
    centers = walks[w, i]
    contexts = walks[w, j]
    u = jax.random.uniform(kn, (batch, n_neg))
    negatives = jnp.searchsorted(noise_cdf, u).astype(jnp.int32)
    negatives = jnp.minimum(negatives, noise_cdf.shape[0] - 1)
    return centers, contexts, negatives


def sample_batch(corpus: WalkCorpus, key, *, batch: int, window: int, n_neg: int):
    """-> centers (B,), contexts (B,), negatives (B, K) int32 node ids."""
    return _sample(
        corpus.walks,
        corpus.noise_cdf,
        key,
        batch,
        window,
        n_neg,
        corpus.length,
        corpus.n_real,
    )

"""Small shared helpers for the serve package."""
from __future__ import annotations

__all__ = ["pow2"]


def pow2(n: int) -> int:
    """Smallest power of two >= n (and >= 1).

    Batched scatters and repair sweeps pad their leading dimension to this so
    eager XLA compiles a logarithmic number of distinct shapes instead of one
    per batch size.
    """
    return 1 << max(int(n) - 1, 0).bit_length()

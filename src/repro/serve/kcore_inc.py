"""Incremental core-number maintenance under edge insertion *and* deletion.

The offline path peels the whole graph (``core_numbers_host``, O(E)); doing
that per streamed edge would make ingestion quadratic. Streams admit exact
local repair instead (Sarıyüce et al., "Streaming algorithms for k-core
decomposition", VLDB 2013), and this module batches that repair over whole
**edge blocks**: one region discovery + one h-index descent per block,
instead of one per edge.

Block repair (``on_edge_block`` / ``on_remove`` / ``on_update``):

* All mutations of the block are first applied to the graph. The nodes whose
  core number can change lie in a **union subcore**: nodes reachable from any
  block endpoint through nodes whose old core number falls in a level window
  around the block's endpoint levels (purecore-style traversal; for a single
  insertion the window degenerates to the classical "core == K" subcore).
* Candidates are seeded at an upper bound of their new core number
  (``min(new_degree, old_core + #inserted)``) and swept with the *same*
  row-masked h-index operator the offline device fixpoint uses
  (``repro.core.kcore.h_index_sweep``), with non-candidate neighbours frozen
  at their true (unchanged) core numbers. The operator is monotone, so the
  sweep descends to the exact new core numbers: with a correct frozen
  boundary the restricted iteration coincides with the full-graph iteration
  from an upper bound, which converges to the core numbers (Lü et al. 2016).
* A block can cascade promotions/demotions across several levels, so the
  window half-width is **adaptive**: the repair re-runs with a wider window
  whenever the computed level changes touch the window boundary (a truncated
  cascade would otherwise go unnoticed). Single-edge repairs never widen.
* **Bounded re-peel fallback**: when the candidate region exceeds
  ``repeel_frac`` of the graph (huge blocks, low-level windows), repairing
  locally buys nothing — the maintainer falls back to one Matula–Beck peel
  of the snapshot (the same oracle ``resync`` checks against), which is exact
  and O(E). ``repeels`` counts how often that happened.

Core-number **drift** (how many nodes changed level since the embedding table
was last refreshed) is the staleness signal the store/service use to gate
retraining: the paper's §2.2 propagation stays valid while the k0-core is
stable, and drift in deep shells — in either direction, now that edges can
be retracted — is what invalidates it.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.kcore import _h_index_sweep_jit, core_numbers_host

from .stream import DynamicGraph
from .util import pow2

__all__ = ["IncrementalCore"]

_EMPTY = np.zeros((0, 2), np.int64)


class IncrementalCore:
    def __init__(
        self,
        g: DynamicGraph,
        core: Optional[np.ndarray] = None,
        *,
        repeel_frac: float = 0.6,
        margin0: int = 8,
    ):
        self.g = g
        if core is None:
            core = (
                core_numbers_host(g.snapshot())
                if g.n_nodes
                else np.zeros(0, np.int32)
            )
        self._core = np.asarray(core, np.int32).copy()
        self._baseline = self._core.copy()  # levels at last embedding refresh
        self.repeel_frac = float(repeel_frac)
        self.margin0 = int(margin0)
        self.repairs = 0
        self.sweeps = 0
        self.promoted = 0
        self.demoted = 0
        self.repeels = 0

    # ------------------------------------------------------------- views

    @property
    def core(self) -> np.ndarray:
        """(n_nodes,) int32 current core numbers (live view, do not mutate)."""
        return self._core[: self.g.n_nodes]

    def _ensure_size(self) -> None:
        n = self.g.n_nodes
        if len(self._core) < n:
            pad = np.zeros(n - len(self._core), np.int32)
            self._core = np.concatenate([self._core, pad])
            self._baseline = np.concatenate([self._baseline, pad])

    # ------------------------------------------------------------- repair

    def _region(self, ends: np.ndarray, lo: int, hi: int, removed) -> list:
        """Union subcore: nodes reachable from the block endpoints through
        nodes with old core in [lo, hi], over the post-block adjacency plus
        the removed block edges (a deletion must not sever its own discovery
        path). Endpoints are always included.

        Must cover every node whose core changes — truncating it would seed
        only part of the repair region and silently break exactness; the
        caller guards that with the adaptive window + boundary check.
        """
        extra = {}
        for u, v in removed:
            extra.setdefault(int(u), []).append(int(v))
            extra.setdefault(int(v), []).append(int(u))
        seen = {int(r) for r in ends}
        stack = list(seen)
        while stack:
            w = stack.pop()
            nbrs = self.g.neighbours(w)
            ex = extra.get(w)
            if ex:
                nbrs = np.concatenate([nbrs, np.asarray(ex, np.int64)])
            for x in nbrs:
                x = int(x)
                if x not in seen and lo <= self._core[x] <= hi:
                    seen.add(x)
                    stack.append(x)
        return sorted(seen)

    def _repeel(self) -> int:
        """Exact O(E) fallback: one Matula–Beck peel of the snapshot."""
        n = self.g.n_nodes
        oracle = core_numbers_host(self.g.snapshot())
        changed = oracle != self._core[:n]
        self.promoted += int((oracle > self._core[:n]).sum())
        self.demoted += int((oracle < self._core[:n]).sum())
        self._core[:n] = oracle
        self.repeels += 1
        return int(changed.sum())

    def _descend(self, cand: np.ndarray, seed: np.ndarray) -> np.ndarray:
        """H-index descent over candidate rows from ``seed`` (an upper bound
        on the new cores), non-candidates frozen. Returns the fixed point."""
        rows = [self.g.neighbours(w) for w in cand]
        n_rows = pow2(len(cand))
        width = pow2(max((len(r) for r in rows), default=1))
        idx = np.zeros((n_rows, width), np.int64)
        valid = np.zeros((n_rows, width), bool)
        for i, r in enumerate(rows):
            idx[i, : len(r)] = r
            valid[i, : len(r)] = True

        est = self._core.copy()
        est[cand] = seed
        est_p = np.zeros(n_rows, np.int32)  # padded rows descend from 0 to 0
        while True:
            self.sweeps += 1
            vals = est[idx].astype(np.int32)
            est_p[: len(cand)] = est[cand]
            new = np.asarray(
                _h_index_sweep_jit(vals, valid, est_p), np.int32
            )[: len(cand)]
            if np.array_equal(new, est[cand]):
                return new
            est[cand] = new

    def on_update(self, added=None, removed=None) -> int:
        """Repair after a mixed block of graph mutations has been applied.

        ``added``/``removed`` are the (m, 2) edge arrays the graph actually
        accepted (the return values of ``add_edges``/``remove_edges``).
        Returns the number of nodes whose core number changed.
        """
        added = np.asarray(added, np.int64).reshape(-1, 2) if added is not None else _EMPTY
        removed = np.asarray(removed, np.int64).reshape(-1, 2) if removed is not None else _EMPTY
        m_ins, m_del = len(added), len(removed)
        m = m_ins + m_del
        if m == 0:
            return 0
        self._ensure_size()
        n = self.g.n_nodes
        old = self._core[:n].copy()

        touched = np.concatenate([added, removed]) if m_del and m_ins else (
            added if m_ins else removed
        )
        k_edge = np.minimum(self._core[touched[:, 0]], self._core[touched[:, 1]])
        k_min, k_max = int(k_edge.min()), int(k_edge.max())
        ends = np.unique(touched.reshape(-1))

        # Adaptive window: grow the half-width until the computed changes sit
        # strictly inside it (a change at the boundary may be a truncated
        # cascade). A single mutation cannot cascade, so it never widens.
        margin = 0 if m == 1 else self.margin0
        while True:
            lo = max(0, k_min - (margin if m_del else 0))
            hi = k_max + (margin if m_ins else 0)
            cand = np.asarray(
                self._region(ends, lo, hi, removed), np.int64
            )
            if len(cand) > max(256, self.repeel_frac * n):
                changed = self._repeel()
                self.repairs += 1
                return changed
            cand_deg = np.array([self.g.degree(int(w)) for w in cand])
            seed = np.minimum(cand_deg, old[cand] + m_ins).astype(np.int32)
            seed = np.maximum(seed, 0)
            new = self._descend(cand, seed)
            # a changed node's old level sits within the *deepest per-node
            # cascade* of the block's endpoint levels (min(a+x, b+y) <=
            # min(a, b) + max(x, y)), so the window is sufficient as long as
            # the margin exceeds the largest single-node level change
            max_gain = int(np.maximum(new - old[cand], 0).max(initial=0))
            max_loss = int(np.maximum(old[cand] - new, 0).max(initial=0))
            # only *changed* nodes at/past the boundary suggest truncation;
            # an unchanged high-core endpoint legitimately sits above it
            ceiling_hit = bool(m_ins and ((new > hi) & (new > old[cand])).any())
            floor_hit = bool(
                m_del and lo > 0 and ((new < lo) & (new < old[cand])).any()
            )
            if m == 1 or (
                max_gain < margin
                and max_loss < margin
                and not ceiling_hit
                and not floor_hit
            ):
                break
            margin = 2 * margin + max_gain + max_loss + 1

        self.repairs += 1
        self._core[cand] = new
        self.promoted += int((new > old[cand]).sum())
        self.demoted += int((new < old[cand]).sum())
        return int((new != old[cand]).sum())

    def on_edge_block(self, edges) -> int:
        """Repair after ``g.add_edges(edges)`` accepted ``edges`` (one union
        subcore sweep for the whole block). Returns #nodes promoted."""
        before = self.promoted
        self.on_update(added=edges)
        return self.promoted - before

    def on_remove(self, edges) -> int:
        """Repair after ``g.remove_edges(edges)`` removed ``edges``.
        Returns #nodes demoted."""
        before = self.demoted
        self.on_update(removed=edges)
        return self.demoted - before

    def on_edge(self, u: int, v: int) -> int:
        """Repair after ``g.add_edge(u, v)`` returned True.

        Single-edge compatibility wrapper over ``on_edge_block``; returns the
        number of nodes whose core number was promoted.
        """
        return self.on_edge_block(np.array([[u, v]], np.int64))

    # ------------------------------------------------------------- oracle

    def resync(self) -> int:
        """Recompute from the oracle; returns #mismatches found (0 expected).

        Called after compaction as a safety net — block maintenance is exact,
        so a nonzero return indicates a bug upstream.
        """
        self._ensure_size()
        oracle = core_numbers_host(self.g.snapshot())
        n = self.g.n_nodes
        mismatches = int(np.sum(oracle != self._core[:n]))
        self._core[:n] = oracle
        return mismatches

    # ------------------------------------------------------------- drift

    def drift(self) -> int:
        """#nodes whose core number changed since the last ``mark_refresh``.

        Newly appeared nodes count (their baseline level is 0); so do nodes
        demoted by deletions — drift is direction-agnostic.
        """
        self._ensure_size()
        n = self.g.n_nodes
        return int(np.sum(self._core[:n] != self._baseline[:n]))

    def membership_drift(self, k0: int) -> tuple:
        """k0-core membership churn since the last ``mark_refresh``.

        Returns (#nodes whose (core >= k0) flag flipped, current k0-core
        size). Counts departures (deletion-driven demotion out of the core)
        as well as arrivals.
        """
        self._ensure_size()
        n = self.g.n_nodes
        now = self._core[:n] >= k0
        was = self._baseline[:n] >= k0
        return int(np.sum(now != was)), int(now.sum())

    def mark_refresh(self) -> None:
        """Record current levels as the embedding-table baseline."""
        self._ensure_size()
        self._baseline = self._core.copy()

"""Incremental core-number maintenance under edge insertion *and* deletion.

The offline path peels the whole graph (``core_numbers_host``, O(E)); doing
that per streamed edge would make ingestion quadratic. Streams admit exact
local repair instead (Sarıyüce et al., "Streaming algorithms for k-core
decomposition", VLDB 2013), and this module batches that repair over whole
**edge blocks**: one region discovery + one h-index descent per block,
instead of one per edge.

Block repair (``on_edge_block`` / ``on_remove`` / ``on_update``):

* All mutations of the block are first applied to the graph. The nodes whose
  core number can change lie in a **union subcore**: nodes reachable from any
  block endpoint through nodes whose old core number falls in a level window
  around the block's endpoint levels (purecore-style traversal; for a single
  insertion the window degenerates to the classical "core == K" subcore).
* Candidates are seeded at an upper bound of their new core number
  (``min(new_degree, old_core + #inserted)``, one vectorized gather from the
  graph's maintained degree array) and swept with the *same* row-masked
  h-index operator the offline device fixpoint uses
  (``repro.kernels.ops.h_index_sweep``, Pallas-backed on TPU), with
  non-candidate neighbours frozen at their true (unchanged) core numbers.
  The operator is monotone, so the sweep descends to the exact new core
  numbers (Lü et al. 2016).
* A block can cascade promotions/demotions across several levels, so the
  window half-width is **adaptive**: the repair re-runs with a wider window
  whenever the computed level changes touch the window boundary. Single-edge
  repairs never widen.
* **Measured repair policy** (``repair_policy="adaptive"``, the default):
  instead of the old static trigger (abort region discovery past
  ``repeel_frac * n`` and re-peel the whole graph — which on real block sizes
  meant the fused descent *never* ran), the maintainer predicts both paths'
  cost from per-regime EMAs of its own measured phase seconds (the same
  intervals exported as ``repair_phase_seconds{phase=}`` through the metrics
  registry, which also warm-starts the priors across maintainer instances in
  one process) and runs whichever is cheaper. Cold start — before either
  path has been measured — falls back to a shape heuristic: descend unless
  the padded candidate matrix dwarfs the affected-shell arc mass
  (``cold_cells_per_arc``) or busts ``descend_budget``. ``"region"``
  restores the legacy static trigger for A/B runs; ``"fallback"`` always
  re-peels.
* **Shell-incremental re-peel**: when re-peeling *is* chosen (or forced by a
  truncated descent), only the shells at level ``<= hi`` (the repair
  window's top) are re-peeled — upper shells are frozen and enter as
  boundary degrees (``core.kcore.core_numbers_shell_peel``), so fallback
  cost scales with the affected sub-level set, not the graph. A survivor
  past ``hi`` disproves the freeze (possible only under insertions) and
  widens ``hi`` until certified; deletions-only blocks can never hit the
  ceiling. Exactness argument in ``core_numbers_shell_peel``'s docstring.
* **Pipelined handoff**: ``begin_update`` runs region discovery + the policy
  decision and *dispatches* the fused descent without reading it back
  (``jax`` async dispatch); ``finish_update`` blocks on the result, runs any
  window widenings, and commits. ``on_update`` is simply the two
  back-to-back; the serving layer calls them split so block N+1's host-side
  dedup/scatter overlaps block N's in-flight descent. Every other public
  entry point settles an in-flight ticket first, so results are
  bit-identical to the serial path.

Device-resident path (``impl="device"``, the ``"auto"`` default) — every
repair stage is vectorized or fused:

* **Region growing** is a frontier-masked traversal: boolean frontier /
  visited masks expanded one level per step with the ``[lo, hi]`` core-window
  filter applied in bulk, plus a static-shaped **side table** of extra arcs
  (the removed block edges, so deletions keep their discovery path, and the
  overflow arcs the device mirror cannot see between compactions). On TPU it
  runs as a jitted ``lax.while_loop`` over the ``DynamicGraph`` device ELL
  mirror (``_region_fixpoint``); elsewhere the same traversal runs as
  vectorized numpy over the host table, where XLA scatters lose to the host.
  Both are bounded: discovery aborts early once it exceeds the fallback cap.
* **Candidate matrices** come from one vectorized gather
  (``DynamicGraph.gather_rows``), trimmed to the candidates' true max degree.
* **The h-index descent is one fused jitted fixpoint** (``_fused_descent``):
  seeding, every sweep, the convergence test, and the adaptive-window
  boundary statistics all run inside a single ``lax.while_loop`` dispatch —
  no per-iteration ``est[cand]`` ping-pong between host and device. Each
  sweep applies ``kernels.ops.h_index_sweep`` (the Pallas kernel on TPU, the
  sort-free counting search elsewhere).
* **The fallback** is the same fused descent seeded over *all* nodes on TPU
  (still one dispatch); off-TPU it is the vectorized rounds peel
  (``core_numbers_rounds``) fed straight from the graph's arc arrays.

The PR 2 host path survives as ``impl="ref"`` — the dict/set BFS, the
per-iteration jitted sweep, and the snapshot re-peel — and doubles as the
correctness oracle for the device path. ``phase_report()`` exposes per-phase
wall time (region / candidates / descend / fallback) and which backend each
phase ran on, so benchmarks can show *where* repair time goes.

Core-number **drift** (how many nodes changed level since the embedding table
was last refreshed) is the staleness signal the store/service use to gate
retraining: the paper's §2.2 propagation stays valid while the k0-core is
stable, and drift in deep shells — in either direction, now that edges can
be retracted — is what invalidates it.
"""
from __future__ import annotations

import time
from collections import deque
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kcore import (
    _h_index_sweep_jit,
    core_numbers_host,
    core_numbers_rounds,
    core_numbers_shell_peel,
)
from repro.kernels import ops as kops
from repro.obs import metrics
from repro.obs import trace as obs

from . import faults
from .stream import DynamicGraph
from .util import pow2

__all__ = ["IncrementalCore", "RepairPolicy"]

_EMPTY = np.zeros((0, 2), np.int64)

# two-tier descent split: rows with degree <= this go in the narrow matrix
_W_SMALL = 32

# size-distribution buckets (region node counts): powers of 4 up to ~4M
_COUNT_BUCKETS = 4.0 ** np.arange(12)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("impl", "max_sweeps"))
def _fused_descent(idx, valid, cand, seed, old, est_full, lo, hi, *,
                   impl: str, max_sweeps: int):
    """Whole h-index descent as one device dispatch.

    ``idx``/``valid``: (R, W) candidate neighbour matrix (global node ids,
    padding = sentinel); ``cand``: (R,) candidate ids (padded rows point at
    the sentinel, whose estimate stays 0); ``seed``: (R,) upper bound on the
    new cores; ``old``: (R,) old cores (0 on padded rows); ``est_full``:
    (node_cap + 1,) frozen boundary = current cores. Runs the row-masked
    sweep to its fixed point inside one ``lax.while_loop`` and returns
    ``(new, max_gain, max_loss, ceiling_hit, floor_hit, sweeps)`` — the
    adaptive-window boundary statistics ride along so the caller reads back
    five scalars plus the repaired levels, never per-sweep intermediates.
    """
    est = est_full.at[cand].set(seed)

    def cond(state):
        _, _, changed, it = state
        return jnp.logical_and(changed, it < max_sweeps)

    def body(state):
        est, cur, _, it = state
        vals = est[idx]
        new = kops.h_index_sweep(vals, valid, cur, impl=impl)
        est = est.at[cand].set(new)
        return est, new, jnp.any(new != cur), it + 1

    _, new, changed, sweeps = jax.lax.while_loop(
        cond, body, (est, seed, jnp.asarray(True), jnp.asarray(0, jnp.int32))
    )
    gain = jnp.max(jnp.maximum(new - old, 0), initial=0)
    loss = jnp.max(jnp.maximum(old - new, 0), initial=0)
    # only *changed* nodes at/past the boundary suggest a truncated cascade;
    # an unchanged high-core endpoint legitimately sits above the window
    ceiling = jnp.any((new > hi) & (new > old))
    floor = jnp.any((new < lo) & (new < old))
    # ``changed`` still true at exit means the sweep cap truncated the
    # descent — the estimates are NOT a fixed point and must not be committed
    return new, gain, loss, ceiling, floor, sweeps, changed


@partial(jax.jit, static_argnames=("impl", "max_sweeps"))
def _fused_descent_two(idx_s, valid_s, idx_b, valid_b, cand, seed, old,
                       est_full, lo, hi, *, impl: str, max_sweeps: int):
    """Two-tier variant of :func:`_fused_descent`.

    One ELL candidate matrix pays the hub tax: a handful of high-degree
    rows force ``w_pad`` to 4-8x the typical degree, so most swept cells
    are padding. Here the rows are split into a narrow matrix
    (``idx_s``/``valid_s``, degree <= ``_W_SMALL``) and a small hub matrix
    (``idx_b``/``valid_b``); ``cand``/``seed``/``old`` are the
    concatenated per-row vectors in the same [small rows..., hub rows...]
    order. Each sweep applies the identical row operator to both tiers
    against the shared estimate, so the fixpoint trajectory — and the
    result — is bit-identical to the single-matrix descent, at a fraction
    of the swept cells.
    """
    r_s = idx_s.shape[0]
    est = est_full.at[cand].set(seed)

    def cond(state):
        _, _, changed, it = state
        return jnp.logical_and(changed, it < max_sweeps)

    def body(state):
        est, cur, _, it = state
        new_s = kops.h_index_sweep(est[idx_s], valid_s, cur[:r_s], impl=impl)
        new_b = kops.h_index_sweep(est[idx_b], valid_b, cur[r_s:], impl=impl)
        new = jnp.concatenate([new_s, new_b])
        est = est.at[cand].set(new)
        return est, new, jnp.any(new != cur), it + 1

    _, new, changed, sweeps = jax.lax.while_loop(
        cond, body, (est, seed, jnp.asarray(True), jnp.asarray(0, jnp.int32))
    )
    gain = jnp.max(jnp.maximum(new - old, 0), initial=0)
    loss = jnp.max(jnp.maximum(old - new, 0), initial=0)
    ceiling = jnp.any((new > hi) & (new > old))
    floor = jnp.any((new < lo) & (new < old))
    return new, gain, loss, ceiling, floor, sweeps, changed


@jax.jit
def _region_fixpoint(nbr, deg, core, ends, side_src, side_dst, side_valid,
                     lo, hi, cap):
    """Frontier-masked union-subcore traversal, one jitted while-loop.

    ``nbr``/``deg`` are the device ELL mirror; ``side_*`` is the padded side
    table of extra arcs (removed block edges + overflow arcs the mirror
    cannot see). Expands boolean frontier/visited masks one level per
    iteration, filtering discovered nodes by old core in ``[lo, hi]``;
    endpoints are pre-seeded regardless of their level. Aborts early once
    the visited count exceeds ``cap`` (the caller falls back to a full
    recompute, so a partial region is never used).
    """
    n1, width = nbr.shape
    valid = jnp.arange(width, dtype=jnp.int32)[None, :] < deg[:, None]
    eligible = (core >= lo) & (core <= hi)

    def cond(state):
        frontier, _, count = state
        return jnp.logical_and(frontier.any(), count <= cap)

    def body(state):
        frontier, visited, _ = state
        contrib = frontier[:, None] & valid
        nxt = jnp.zeros(n1, bool).at[nbr].max(contrib)
        nxt = nxt.at[side_dst].max(frontier[side_src] & side_valid)
        newf = nxt & eligible & ~visited
        visited = visited | newf
        return newf, visited, jnp.sum(visited)

    _, visited, count = jax.lax.while_loop(
        cond, body, (ends, ends, jnp.sum(ends))
    )
    return visited, count


def _fit_width(idx: np.ndarray, valid: np.ndarray, w_pad: int,
               sentinel: int):
    """Trim/pad the gathered candidate matrix to a static ``w_pad`` columns.

    Safe to trim: ``w_pad >= max candidate degree``, and a row only owns
    overflow columns when its degree exceeds the table width, which forces
    ``w_pad`` past them.
    """
    w = idx.shape[1]
    if w > w_pad:
        return np.ascontiguousarray(idx[:, :w_pad]), np.ascontiguousarray(
            valid[:, :w_pad]
        )
    if w < w_pad:
        rows = idx.shape[0]
        idx = np.concatenate(
            [idx, np.full((rows, w_pad - w), sentinel, np.int32)], axis=1
        )
        valid = np.concatenate(
            [valid, np.zeros((rows, w_pad - w), bool)], axis=1
        )
    return idx, valid


def _pad_rows(idx: np.ndarray, valid: np.ndarray, r_pad: int, sentinel: int):
    """Pad the candidate matrix to a static ``r_pad`` rows (sentinel rows)."""
    rows, w = idx.shape
    if rows == r_pad:
        return idx, valid
    pad = r_pad - rows
    idx = np.concatenate([idx, np.full((pad, w), sentinel, np.int32)])
    valid = np.concatenate([valid, np.zeros((pad, w), bool)])
    return idx, valid


class RepairPolicy:
    """Measured-crossover choice of which *exact* repair path runs.

    Both paths (window-validated fused descent, shell-incremental re-peel)
    are exact, so the policy only affects cost, never results. Per decision
    it predicts each path's wall time at the block's work scale — descend
    work = padded candidate-matrix cells, re-peel work = affected-shell arc
    mass — from an EMA kept per ``(path, regime)`` where a regime is a
    power-of-4 work bucket (nearest-regime predictions extrapolate linearly
    in work). Observations come from the maintainer's own phase timers, the
    very intervals exported as ``repair_phase_seconds{phase=}``; the
    registry feeds back in two ways: :meth:`refresh_from_metrics`
    warm-starts absolute priors from the live histograms (so a fresh
    maintainer in a warmed process doesn't start cold), and every
    observation lands back in the registry via the phase histograms.

    Modes: ``adaptive`` (measured crossover, the default), ``region`` (the
    legacy PR 3 static trigger: region capped at ``repeel_frac * n``,
    ``descend_budget`` bound, full-graph re-peel), ``fallback`` (always
    re-peel; with the shell-incremental path when a window is available).
    """

    MODES = ("adaptive", "region", "fallback")

    def __init__(
        self,
        mode: str = "adaptive",
        *,
        alpha: float = 0.25,
        crossover_margin: float = 1.0,
        cold_cells_per_arc: float = 32.0,
        probe_every: int = 6,
        history: int = 512,
    ):
        if mode not in self.MODES:
            raise ValueError(
                f"unknown repair policy {mode!r}; expected one of {self.MODES}"
            )
        self.mode = mode
        self.alpha = float(alpha)
        self.crossover_margin = float(crossover_margin)
        self.cold_cells_per_arc = float(cold_cells_per_arc)
        self.probe_every = int(probe_every)
        self._ema: dict = {}  # (path, regime) -> [ema_seconds, ema_work]
        self._prior: dict = {}  # path -> absolute prior seconds (registry)
        self._stale = {"descend": 0, "repeel": 0}  # decisions since measured
        self.decisions = {"descend": 0, "repeel": 0}
        self.cold_decisions = 0
        self.probes = 0
        self._history: deque = deque(maxlen=int(history))
        self._pending: dict = {}  # path -> (work, predicted) awaiting actual
        self.refresh_from_metrics()

    @staticmethod
    def _regime(work: float) -> int:
        return max(int(work).bit_length() // 2, 1)

    def refresh_from_metrics(self, registry=None) -> None:
        """Warm-start absolute cost priors from the live phase histograms."""
        reg = metrics() if registry is None else registry
        for path, phase in (("descend", "descend"), ("repeel", "fallback")):
            h = reg.get("repair_phase_seconds", phase=phase)
            if h is not None and len(h):
                self._prior[path] = float(np.mean(h.values()))

    def _measured(self, path: str, work: float) -> Optional[float]:
        """EMA-predicted seconds from this policy's own observations only."""
        b = self._regime(work)
        cell = self._ema.get((path, b))
        if cell is not None:
            return cell[0]
        near = [r for (p, r) in self._ema if p == path]
        if near:
            r = min(near, key=lambda r: abs(r - b))
            sec, w = self._ema[(path, r)]
            return sec * (float(work) / max(w, 1.0))
        return None

    def predict(self, path: str, work: float) -> Optional[float]:
        """Predicted seconds for ``path`` at ``work`` units; None = no data.

        Own measurements first; the registry-fed absolute prior stands in
        until then (work-blind, so only a coarse magnitude).
        """
        m = self._measured(path, work)
        return m if m is not None else self._prior.get(path)

    def observe(self, path: str, work: float, seconds: float) -> None:
        """Feed one measured phase interval back into the regime EMAs."""
        self._stale[path] = 0
        cell = self._ema.get((path, self._regime(work)))
        if cell is None:
            self._ema[(path, self._regime(work))] = [
                float(seconds), float(work)
            ]
        else:
            cell[0] += self.alpha * (float(seconds) - cell[0])
            cell[1] += self.alpha * (float(work) - cell[1])
        pend = self._pending.pop(path, None)
        if pend is not None:
            self._history.append(
                (path, int(work), float(pend[1]), float(seconds))
            )

    def choose(self, *, cells: int, repeel_work: int, budget: int) -> str:
        """``"descend"`` or ``"repeel"`` for one block repair.

        ``cells``: padded candidate-matrix area the fused descent would
        sweep; ``repeel_work``: arc mass of the shells a re-peel would
        touch; ``budget``: hard cold-start cap on ``cells``.
        """
        pd = self._measured("descend", cells)
        pred = None
        if pd is None:
            # the descent has not been *measured* yet — a work-blind prior
            # (possibly from some other maintainer's regime) must not starve
            # it, or the crossover never gets data. Run it unless the shape
            # heuristic says the padded matrix dwarfs the affected-shell
            # arc mass (block size x shell span) or busts the budget.
            self.cold_decisions += 1
            cold_ok = cells <= self.cold_cells_per_arc * max(repeel_work, 64)
            choice = "descend" if (cold_ok and cells <= budget) else "repeel"
        else:
            pr = self._measured("repeel", repeel_work)
            if pr is None:
                # descend is measured but the re-peel side never has been —
                # explore it once (it is exact too, and cheap at shell
                # granularity) so the crossover gets data for both paths
                # instead of riding the first measurement forever
                choice = "repeel"
                pred = self._prior.get("repeel")
            else:
                choice = (
                    "descend" if pd <= self.crossover_margin * pr
                    else "repeel"
                )
                pred = pd if choice == "descend" else pr
                # EMA freshness: the losing path stops getting measured the
                # moment it loses, so its estimate would never track drift
                # (bigger graph, warmer caches, changed shapes). Probe it
                # after ``probe_every`` consecutive unmeasured decisions —
                # bounded overhead, and the crossover stays live both ways.
                loser = "repeel" if choice == "descend" else "descend"
                if self.probe_every and \
                        self._stale[loser] >= self.probe_every:
                    choice, pred = loser, (pd if loser == "descend" else pr)
                    self.probes += 1
        self.decisions[choice] += 1
        self._stale["descend"] += 1
        self._stale["repeel"] += 1
        if pred is not None:
            self._pending[choice] = (
                cells if choice == "descend" else repeel_work, pred
            )
        return choice

    def report(self) -> dict:
        """Decision counts, predicted-vs-actual error, learned regimes."""
        by: dict = {}
        for path, _work, pred, act in self._history:
            d = by.setdefault(path, {"n": 0, "_err": 0.0})
            d["n"] += 1
            d["_err"] += abs(pred - act) / max(act, 1e-9)
        for d in by.values():
            d["mean_abs_rel_err"] = round(d.pop("_err") / d["n"], 3)
        return {
            "mode": self.mode,
            "decisions": dict(self.decisions),
            "cold_decisions": int(self.cold_decisions),
            "probes": int(self.probes),
            "predicted_vs_actual": by,
            "regimes": {
                f"{p}/{r}": [round(s, 6), round(w, 1)]
                for (p, r), (s, w) in sorted(self._ema.items())
            },
        }


class _RepairTicket:
    """Handle for one block repair started by ``begin_update``.

    ``done`` tickets already committed (synchronous paths); live tickets
    hold the in-flight fused-descent dispatch plus everything
    ``finish_update`` needs to validate the window and commit.
    """

    __slots__ = ("done", "changed", "pending", "ctx", "margin", "lo", "hi",
                 "cand")

    def __init__(self, *, changed=None, pending=None, ctx=None, margin=0,
                 lo=0, hi=0, cand=None):
        self.done = changed is not None
        self.changed = int(changed or 0)
        self.pending = pending
        self.ctx = ctx
        self.margin = margin
        self.lo = lo
        self.hi = hi
        self.cand = cand


class IncrementalCore:
    def __init__(
        self,
        g: DynamicGraph,
        core: Optional[np.ndarray] = None,
        *,
        repeel_frac: float = 0.6,
        margin0: int = 8,
        impl: str = "auto",
        region_impl: Optional[str] = None,
        kernel_impl: Optional[str] = None,
        repeel_impl: Optional[str] = None,
        descend_budget: int = 1 << 20,
        max_sweeps: int = 512,
        repair_policy: str = "adaptive",
        policy: Optional[RepairPolicy] = None,
        crossover_margin: float = 1.0,
        cold_cells_per_arc: float = 32.0,
    ):
        self.g = g
        if core is None:
            core = (
                core_numbers_host(g.snapshot())
                if g.n_nodes
                else np.zeros(0, np.int32)
            )
        self._core = np.asarray(core, np.int32).copy()
        self._baseline = self._core.copy()  # levels at last embedding refresh
        self.repeel_frac = float(repeel_frac)
        self.margin0 = int(margin0)
        if impl not in ("auto", "ref", "device"):
            raise ValueError(f"unknown impl {impl!r}")
        self.impl = impl
        self.region_impl = region_impl  # None=auto | "jit" | "np"
        self.kernel_impl = kernel_impl  # None=auto | ops.h_index_sweep impl
        # None=auto | "shell"|"descend"|"rounds"|"peel"
        self.repeel_impl = repeel_impl
        self.descend_budget = int(descend_budget)
        self.max_sweeps = int(max_sweeps)
        self.policy = policy if policy is not None else RepairPolicy(
            repair_policy,
            crossover_margin=crossover_margin,
            cold_cells_per_arc=cold_cells_per_arc,
        )
        self.repairs = 0
        self.sweeps = 0
        self.descends = 0
        # bounded retry around the fused-descent dispatch; exhaustion falls
        # back to the exact host peel (never an inexact answer)
        self.dispatch_retries = 2
        self.retry_backoff = 0.05
        self.dispatch_failures = 0
        self.dispatch_recoveries = 0
        self.promoted = 0
        self.demoted = 0
        self.repeels = 0
        self.shell_repeels = 0  # re-peels that stayed shell-incremental
        self.shell_widens = 0  # ceiling hits that widened the peel window
        self._shell_depths: list = []  # (hi, peeled_nodes, n) per shell peel
        self._inflight: Optional[_RepairTicket] = None
        self.phase_seconds: dict = {}
        self.phase_impl: dict = {}

    # ---------------------------------------------------------- dispatch

    def _device(self) -> bool:
        return self.impl != "ref"

    def _region_mode(self) -> str:
        if not self._device():
            return "ref"
        if self.region_impl:
            return self.region_impl
        return "jit" if _on_tpu() else "np"

    def _kernel_mode(self) -> str:
        if self.kernel_impl:
            return self.kernel_impl
        return "pallas" if _on_tpu() else "count"

    def _repeel_mode(self) -> str:
        if not self._device():
            return "peel"
        if self.repeel_impl:
            return self.repeel_impl
        if _on_tpu():
            return "descend"
        # legacy "region" policy keeps the PR 3 full-graph rounds peel so
        # A/B runs against the old trigger measure the old fallback too
        return "rounds" if self.policy.mode == "region" else "shell"

    def _tick(self, phase: str, mode: str, t0: float,
              t1: Optional[float] = None) -> None:
        if t1 is None:
            t1 = time.perf_counter()
        self.phase_seconds[phase] = (
            self.phase_seconds.get(phase, 0.0) + t1 - t0
        )
        self.phase_impl[phase] = mode
        # the same interval feeds the trace (one span per phase occurrence,
        # nested under the enclosing serve.ingest/retract span) and the
        # metrics registry — phase_report(), the trace, and the exporter all
        # describe one measurement
        obs.record(f"repair.{phase}", t0, t1, impl=mode)
        metrics().histogram("repair_phase_seconds", phase=phase).observe(
            t1 - t0
        )

    def phase_report(self) -> dict:
        """Per-phase repair wall time + which backend each phase ran on."""
        return {
            k: {"seconds": round(v, 6), "impl": self.phase_impl.get(k, "")}
            for k, v in sorted(self.phase_seconds.items())
        }

    def policy_report(self) -> dict:
        """Repair-policy decisions + shell re-peel depth for one maintainer.

        Extends :meth:`RepairPolicy.report` with the shell-incremental
        re-peel telemetry: how many re-peels stayed incremental, how often
        a ceiling hit widened the peel window, and a histogram of the peel
        depth (``hi``) and peeled-node fraction.
        """
        rep = self.policy.report()
        depth_hist: dict = {}
        frac_sum = 0.0
        for hi, peeled, n in self._shell_depths:
            depth_hist[str(hi)] = depth_hist.get(str(hi), 0) + 1
            frac_sum += peeled / max(n, 1)
        rep["shell_repeel"] = {
            "count": int(self.shell_repeels),
            "widens": int(self.shell_widens),
            "depth_hist": depth_hist,
            "mean_frac_peeled": round(
                frac_sum / max(len(self._shell_depths), 1), 4
            ),
        }
        rep["repeels"] = int(self.repeels)
        rep["descends"] = int(self.descends)
        return rep

    def reset_phases(self) -> None:
        """Zero the per-phase timers (benchmarks call this after warmup)."""
        self.phase_seconds = {}

    # ------------------------------------------------------------- views

    @property
    def core(self) -> np.ndarray:
        """(n_nodes,) int32 current core numbers (live view, do not mutate).

        Settles any in-flight pipelined repair first — readers always see
        committed levels.
        """
        self._settle()
        return self._core[: self.g.n_nodes]

    @property
    def baseline(self) -> np.ndarray:
        """(n_nodes,) int32 core numbers at the last ``mark_refresh``.

        The retraining subsystem reads this to pick alignment anchors
        (nodes whose level has not moved since the serving table was built).
        """
        self._ensure_size()
        return self._baseline[: self.g.n_nodes]

    def _ensure_size(self) -> None:
        n = self.g.n_nodes
        if len(self._core) < n:
            pad = np.zeros(n - len(self._core), np.int32)
            self._core = np.concatenate([self._core, pad])
            self._baseline = np.concatenate([self._baseline, pad])

    # ------------------------------------------------------------ regions

    def _region(self, ends: np.ndarray, lo: int, hi: int, removed) -> list:
        """Union subcore, host reference: nodes reachable from the block
        endpoints through nodes with old core in [lo, hi], over the
        post-block adjacency plus the removed block edges (a deletion must
        not sever its own discovery path). Endpoints are always included.

        Must cover every node whose core changes — truncating it would seed
        only part of the repair region and silently break exactness; the
        caller guards that with the adaptive window + boundary check.
        """
        extra = {}
        for u, v in removed:
            extra.setdefault(int(u), set()).add(int(v))
            extra.setdefault(int(v), set()).add(int(u))
        seen = {int(r) for r in ends}
        stack = list(seen)
        while stack:
            w = stack.pop()
            nbrs = self.g.neighbours(w)
            ex = extra.get(w)
            if ex:
                nbrs = np.concatenate(
                    [nbrs, np.fromiter(ex, np.int64, len(ex))]
                )
            for x in nbrs:
                x = int(x)
                if x not in seen and lo <= self._core[x] <= hi:
                    seen.add(x)
                    stack.append(x)
        return sorted(seen)

    def _region_np(self, ends, lo, hi, side_src, side_dst, cap):
        """Vectorized host frontier traversal (same masks as the jitted
        device loop, minus the dispatch). Returns None once past ``cap``."""
        g = self.g
        n, n1 = g.n_nodes, g.node_cap + 1
        eligible = np.zeros(n1, bool)
        eligible[:n] = (self._core[:n] >= lo) & (self._core[:n] <= hi)
        visited = np.zeros(n1, bool)
        visited[ends] = True
        frontier = visited.copy()
        width_iota = np.arange(g.width)
        while frontier.any():
            rows = np.where(frontier)[0]
            live = width_iota[None, :] < g._deg[rows][:, None]
            nxt = np.zeros(n1, bool)
            nxt[g._nbr[rows][live]] = True
            if len(side_src):
                sm = frontier[side_src]
                if sm.any():
                    nxt[side_dst[sm]] = True
            frontier = nxt & eligible & ~visited
            visited |= frontier
            if int(visited.sum()) > cap:
                return None
        return np.where(visited[:n])[0].astype(np.int64)

    def _region_device(self, ends, lo, hi, side_src, side_dst, cap):
        """Jitted frontier traversal over the device ELL mirror + side table.

        Under a ShardPlan the mirror arrives row-sharded (and row-padded);
        the frontier/visited masks and the static-shaped side table — the
        halo buffer carrying the arcs shards cannot see locally (removed
        block edges + overflow arcs) — stay replicated, so each traversal
        level is still one dispatch with GSPMD exchanging the frontier.
        """
        g = self.g
        n = g.n_nodes
        ell = g.ell()
        n1 = ell.neighbours.shape[0]  # node_cap + 1, plus any shard padding
        ends_mask = np.zeros(n1, bool)
        ends_mask[ends] = True
        core = np.zeros(n1, np.int32)
        core[:n] = self._core[:n]
        s_pad = pow2(max(len(side_src), 1))
        ss = np.zeros(s_pad, np.int32)
        sd = np.zeros(s_pad, np.int32)
        sv = np.zeros(s_pad, bool)
        ss[: len(side_src)] = side_src
        sd[: len(side_dst)] = side_dst
        sv[: len(side_src)] = True
        plan = g.plan
        rep = jnp.asarray if plan is None else plan.replicate
        visited, count = _region_fixpoint(
            ell.neighbours, ell.degrees, rep(core),
            rep(ends_mask), rep(ss), rep(sd),
            rep(sv), lo, hi, cap,
        )
        if int(count) > cap:
            return None
        return np.where(np.asarray(visited)[:n])[0].astype(np.int64)

    # ------------------------------------------------------------ repairs

    def _repeel_shell(self, old: np.ndarray, hi: Optional[int]):
        """Shell-incremental re-peel: recompute only levels ``<= hi``.

        Upper shells are frozen and enter the peel as boundary degrees
        (``core_numbers_shell_peel``); a ceiling hit disproves the freeze
        and widens ``hi`` geometrically until certified (reaching the top
        level degenerates to the full rounds peel — still exact, just no
        longer incremental). Returns ``(cores, impl_tag, work)`` where
        ``work`` is the arc/node mass actually peeled (the policy's re-peel
        cost unit).
        """
        n = self.g.n_nodes
        src, dst = self.g.arc_arrays()
        max_core = int(old.max(initial=0))
        widen = max(self.margin0, 1)
        hi = max_core if hi is None else int(hi)
        deg = None
        while hi < max_core:
            if deg is None:
                deg = np.bincount(src, minlength=n)
            peel = old <= hi
            inner = peel[src] & peel[dst]
            core_s, ok = core_numbers_shell_peel(
                n, src[inner], dst[inner], peel, deg, hi
            )
            if ok:
                new = old.copy()
                new[peel] = core_s[peel]
                self.shell_repeels += 1
                self._shell_depths.append((int(hi), int(peel.sum()), n))
                metrics().counter("repair_shell_repeels_total").inc()
                return new, "shell", int(inner.sum()) + int(peel.sum())
            self.shell_widens += 1
            hi += widen
            widen *= 2
        # the window reached the top level: nothing left to freeze
        return core_numbers_rounds(n, src, dst), "rounds", len(src)

    def _repeel(self, old: np.ndarray, m_ins: int,
                hi: Optional[int] = None) -> int:
        """Exact re-peel fallback: shell-incremental from the repair
        window's top off-TPU (full rounds peel when the window covers every
        level), fused descent over all nodes on TPU, the legacy snapshot
        peel for ``impl="ref"``."""
        n = self.g.n_nodes
        mode = self._repeel_mode()
        t0 = time.perf_counter()
        work = 2 * self.g.n_edges
        if mode == "shell":
            oracle, mode, work = self._repeel_shell(old, hi)
        elif mode == "descend":
            deg = self.g.degrees_of(np.arange(n))
            seed = np.maximum(
                np.minimum(deg.astype(np.int64), old.astype(np.int64) + m_ins),
                0,
            ).astype(np.int32)
            # the inner gather/descent ticks belong to the fallback bucket:
            # roll them back so the phase report stays non-overlapping
            before = {
                k: self.phase_seconds.get(k)
                for k in ("candidates", "descend")
            }
            pending = self._descend_dispatch(
                np.arange(n, dtype=np.int64), seed, old, 0, 1 << 30,
                cand_deg=deg,
            )
            res = self._descend_read(pending)
            for k, b in before.items():
                if b is None:
                    self.phase_seconds.pop(k, None)
                    self.phase_impl.pop(k, None)
                else:
                    self.phase_seconds[k] = b
            if res is None:
                # the sweep cap truncated the full descent (pathological
                # cascade depth) — recover with the uncapped exact peel
                src, dst = self.g.arc_arrays()
                oracle = core_numbers_rounds(n, src, dst)
                mode = "rounds"
            else:
                # the dispatch may tier-reorder rows: scatter back by id
                oracle = np.zeros(n, np.int32)
                oracle[pending["cand"]] = res[0]
        elif mode == "rounds":
            src, dst = self.g.arc_arrays()
            oracle = core_numbers_rounds(n, src, dst)
            work = len(src)
        else:
            oracle = core_numbers_host(self.g.snapshot())
        t1 = time.perf_counter()
        self._tick("fallback", mode, t0, t1)
        self.policy.observe("repeel", max(work, 1), t1 - t0)
        changed = oracle != self._core[:n]
        self.promoted += int((oracle > self._core[:n]).sum())
        self.demoted += int((oracle < self._core[:n]).sum())
        self._core[:n] = oracle
        self.repeels += 1
        metrics().counter("repair_repeels_total").inc()
        return int(changed.sum())

    @staticmethod
    def _pad_shape(n_cand: int, cand_deg: np.ndarray):
        """Static (r_pad, w_pad) of the fused-descent candidate matrix.

        Floored at 64x64: masked rows/lanes are near-free to sweep, and
        fewer distinct (R, W) combinations means far fewer jit compiles
        across a stream of variously-sized repairs. The adaptive policy
        costs the descent on exactly this padded area.
        """
        w_pad = max(pow2(max(int(cand_deg.max(initial=1)), 1)), 64)
        r_pad = max(pow2(n_cand), 64)
        return r_pad, w_pad

    @classmethod
    def _tier_plan(cls, n_cand: int, cand_deg: np.ndarray):
        """Static tier shapes of the descent matrix, plus padded cell count.

        A single ELL matrix pays the hub tax: a few high-degree rows force
        ``w_pad`` to 4-8x the typical degree and the sweep is mostly
        padding. When splitting the rows at ``_W_SMALL`` into a narrow
        matrix plus a small hub matrix strictly shrinks the swept area, do
        it — the per-row operator is unchanged, so the fixpoint (and the
        policy's cells-proportional cost model) is the same computation
        over fewer cells. Returns ``(r_small, r_big, w_big, n_big, cells)``
        with ``r_big == 0`` meaning single-tier.
        """
        r_pad, w_pad = cls._pad_shape(n_cand, cand_deg)
        cells = r_pad * w_pad
        if w_pad <= 2 * _W_SMALL:
            return r_pad, 0, w_pad, 0, cells
        n_big = int((cand_deg > _W_SMALL).sum())
        if not 0 < n_big < n_cand:
            return r_pad, 0, w_pad, 0, cells
        r_small = max(pow2(n_cand - n_big), 64)
        r_big = max(pow2(n_big), 64)
        split_cells = r_small * _W_SMALL + r_big * w_pad
        if split_cells >= cells:
            return r_pad, 0, w_pad, 0, cells
        return r_small, r_big, w_pad, n_big, split_cells

    def _descend_dispatch(self, cand, seed, old_cand, lo, hi, *, cand_deg):
        """Gather/pad the candidate matrix and *launch* the fused descent.

        Returns the pending dispatch (in-flight device arrays plus readback
        bookkeeping) without blocking: jax dispatch is asynchronous, so the
        host is free until ``_descend_read`` — the pipelined ingest stages
        the next block's dedup/scatter in that gap.
        """
        g = self.g
        node_cap = g.node_cap
        faults.check("device_dispatch")
        t0 = time.perf_counter()
        n_rows = len(cand)
        r_small, r_big, w_big, n_big, cells = self._tier_plan(
            n_rows, cand_deg
        )
        keep = None
        if r_big:
            # hubs last; the stable partition keeps each tier's rows in
            # input order, and ``keep`` maps the padded concat back to them
            order = np.argsort(cand_deg > _W_SMALL, kind="stable")
            cand, seed = cand[order], seed[order]
            old_cand = old_cand[order]
            keep = np.concatenate(
                [np.arange(n_rows - n_big), r_small + np.arange(n_big)]
            )
        cand_out = cand  # unpadded (tier-ordered) rows the result maps to
        idx, valid = g.gather_rows(cand)
        est_full = np.zeros(node_cap + 1, np.int32)
        est_full[: g.n_nodes] = self._core[: g.n_nodes]

        def vec(x, fill, dtype):
            out = np.full(r_small + r_big, fill, dtype)
            out[: n_rows - n_big] = x[: n_rows - n_big]
            out[r_small : r_small + n_big] = x[n_rows - n_big :]
            return out

        if r_big:
            n_small = n_rows - n_big
            idx_s, valid_s = _fit_width(
                idx[:n_small], valid[:n_small], _W_SMALL, node_cap
            )
            idx_b, valid_b = _fit_width(
                idx[n_small:], valid[n_small:], w_big, node_cap
            )
            idx_s, valid_s = _pad_rows(idx_s, valid_s, r_small, node_cap)
            idx_b, valid_b = _pad_rows(idx_b, valid_b, r_big, node_cap)
            cand_p = vec(cand, node_cap, np.int64)
            seed_p = vec(seed, 0, np.int32)
            old_p = vec(old_cand, 0, np.int32)
        else:
            idx, valid = _fit_width(idx, valid, w_big, node_cap)
            idx, valid = _pad_rows(idx, valid, r_small, node_cap)
            cand_p = vec(cand, node_cap, np.int64)
            seed_p = vec(seed, 0, np.int32)
            old_p = vec(old_cand, 0, np.int32)
        self._tick("candidates", "gather", t0)

        t0 = time.perf_counter()
        # under a ShardPlan (and a GSPMD-partitionable kernel impl) the
        # candidate matrix rows are split across the mesh: each shard sweeps
        # its own rows and the frozen-boundary estimate stays replicated
        plan = g.plan if self._kernel_mode() in ("count", "ref") else None
        row = jnp.asarray if plan is None else plan.place_rows
        rep = jnp.asarray if plan is None else plan.replicate
        if r_big:
            out = _fused_descent_two(
                row(idx_s), row(valid_s), row(idx_b), row(valid_b),
                row(np.asarray(cand_p, np.int32)),
                row(np.asarray(seed_p, np.int32)),
                row(np.asarray(old_p, np.int32)),
                rep(est_full), lo, hi,
                impl=self._kernel_mode(), max_sweeps=self.max_sweeps,
            )
        else:
            out = _fused_descent(
                row(idx), row(valid),
                row(np.asarray(cand_p, np.int32)),
                row(np.asarray(seed_p, np.int32)),
                row(np.asarray(old_p, np.int32)),
                rep(est_full), lo, hi,
                impl=self._kernel_mode(), max_sweeps=self.max_sweeps,
            )
        return {"out": out, "n_rows": n_rows, "t0": t0, "cells": cells,
                "cand": cand_out, "keep": keep}

    def _descend_read(self, pending, *, full_interval: bool = True):
        """Block on a pending descent and pull the result back.

        ``full_interval=True`` charges the descend phase from the dispatch
        (the serial semantics); ``False`` charges only the blocking wait —
        in pipelined mode that is the descent's *non-overlapped* cost, which
        is both what the phase report should show and the right quantity for
        the policy's crossover (overlapped device time is free wall-clock).
        Returns ``(new, max_gain, max_loss, ceiling_hit, floor_hit)`` or
        None when the sweep cap truncated the descent.
        """
        t_read = time.perf_counter()
        new, gain, loss, ceiling, floor, sweeps, truncated = pending["out"]
        new = np.asarray(new, np.int32)
        keep = pending["keep"]
        new = new[: pending["n_rows"]] if keep is None else new[keep]
        t0 = pending["t0"] if full_interval else t_read
        self.sweeps += int(sweeps)
        self.descends += 1
        metrics().counter("repair_descends_total").inc()
        t1 = time.perf_counter()
        self._tick("descend", f"fused[{self._kernel_mode()}]", t0, t1)
        self.policy.observe("descend", pending["cells"], t1 - t0)
        if bool(truncated):  # max_sweeps cap hit before the fixed point
            return None
        return new, int(gain), int(loss), bool(ceiling), bool(floor)

    def _descend(self, cand: np.ndarray, seed: np.ndarray) -> np.ndarray:
        """Reference host descent: per-iteration jitted sweeps over a
        host-maintained estimate (the PR 2 path, kept as the oracle)."""
        g = self.g
        idx, valid = g.gather_rows(cand)
        w_pad = pow2(max(int(valid.sum(axis=1).max(initial=1)), 1))
        idx, valid = _fit_width(idx, valid, w_pad, g.node_cap)
        n_rows = pow2(len(cand))
        if n_rows != len(cand):
            pad = n_rows - len(cand)
            idx = np.concatenate(
                [idx, np.full((pad, w_pad), g.node_cap, np.int32)]
            )
            valid = np.concatenate([valid, np.zeros((pad, w_pad), bool)])

        est = np.zeros(g.node_cap + 1, np.int32)
        est[: len(self._core)] = self._core
        est[cand] = seed
        est_p = np.zeros(n_rows, np.int32)  # padded rows descend from 0 to 0
        while True:
            self.sweeps += 1
            vals = est[idx].astype(np.int32)
            est_p[: len(cand)] = est[cand]
            new = np.asarray(
                _h_index_sweep_jit(vals, valid, est_p, impl="ref"), np.int32
            )[: len(cand)]
            if np.array_equal(new, est[cand]):
                return new
            est[cand] = new

    def _finish_repeel(self, ctx: dict, hi: int) -> _RepairTicket:
        changed = self._repeel(ctx["old"], ctx["m_ins"], hi=hi)
        self.repairs += 1
        return _RepairTicket(changed=changed)

    def _dispatch_with_retry(self, cand, seed, old_cand, lo, hi, *,
                             cand_deg):
        """Bounded retry-with-backoff around the fused-descent dispatch.

        Re-raises the last error after ``dispatch_retries`` retries; the
        callers then fall back to :meth:`_recover_ref`.
        """
        for attempt in range(self.dispatch_retries + 1):
            try:
                return self._descend_dispatch(
                    cand, seed, old_cand, lo, hi, cand_deg=cand_deg
                )
            except Exception:
                self.dispatch_failures += 1
                metrics().counter("repair_dispatch_failures_total").inc()
                if attempt >= self.dispatch_retries:
                    raise
                time.sleep(self.retry_backoff * (2 ** attempt))

    def _recover_ref(self, ctx: dict, hi: int) -> _RepairTicket:
        """Device repair kept failing: force the exact host ``"peel"`` path
        for this block so cores stay exact while the device path recovers.

        InjectedCrash is a BaseException and never lands here — a simulated
        process death must not be absorbed into a host fallback."""
        self.dispatch_recoveries += 1
        metrics().counter("repair_dispatch_recoveries_total").inc()
        saved = self.repeel_impl
        self.repeel_impl = "peel"
        try:
            return self._finish_repeel(ctx, hi)
        finally:
            self.repeel_impl = saved

    def _commit(self, ctx: dict, cand, new) -> _RepairTicket:
        old = ctx["old"]
        self.repairs += 1
        self._core[cand] = new
        self.promoted += int((new > old[cand]).sum())
        self.demoted += int((new < old[cand]).sum())
        return _RepairTicket(changed=int((new != old[cand]).sum()))

    def _resolve(self, ctx: dict, margin: int, lo: int, hi: int, cand,
                 res) -> _RepairTicket:
        """Validate one descent result against the window; commit or widen."""
        if res is None:  # sweep cap hit: recover via exact recompute
            return self._finish_repeel(ctx, hi)
        new, max_gain, max_loss, ceil_hit, floor_hit = res
        ceiling_hit = bool(ctx["m_ins"]) and ceil_hit
        floor_hit = bool(ctx["m_del"] and lo > 0) and floor_hit
        if ctx["m"] == 1 or (
            max_gain < margin
            and max_loss < margin
            and not ceiling_hit
            and not floor_hit
        ):
            return self._commit(ctx, cand, new)
        # a change at the boundary may be a truncated cascade: re-run wider
        # (synchronously — widenings are rare and already mid-repair)
        return self._advance(
            ctx, 2 * margin + max_gain + max_loss + 1, pipeline=False
        )

    def _advance(self, ctx: dict, margin: int, *,
                 pipeline: bool) -> _RepairTicket:
        """One window attempt: region discovery, policy decision, repair.

        Adaptive window: the half-width grows until the computed level
        changes sit strictly inside it (a change at the boundary may be a
        truncated cascade). A single mutation cannot cascade, so it never
        widens. With ``pipeline=True`` a device fused descent is returned
        in-flight (live ticket) instead of read back here.
        """
        m_ins, m_del, m = ctx["m_ins"], ctx["m_del"], ctx["m"]
        old, n = ctx["old"], ctx["n"]
        mode = self.policy.mode
        adaptive = self._device() and mode == "adaptive"
        # legacy static trigger caps discovery at repeel_frac * n; the
        # adaptive policy never aborts on size — it decides *after* seeing
        # the region, from measured cost, so eager-trigger full re-peels
        # can't starve the fused descent. The ref impl (PR 2 oracle) keeps
        # the legacy cap.
        cap = n if adaptive else int(max(256, self.repeel_frac * n))
        region_mode = self._region_mode()
        lo = max(0, ctx["k_min"] - (margin if m_del else 0))
        hi = ctx["k_max"] + (margin if m_ins else 0)
        if mode == "fallback":
            return self._finish_repeel(ctx, hi)

        t0 = time.perf_counter()
        if region_mode == "ref":
            cand = np.asarray(
                self._region(ctx["ends"], lo, hi, ctx["removed"]), np.int64
            )
            if len(cand) > cap:
                cand = None
        elif region_mode == "jit":
            cand = self._region_device(
                ctx["ends"], lo, hi, ctx["side_src"], ctx["side_dst"], cap
            )
        else:
            cand = self._region_np(
                ctx["ends"], lo, hi, ctx["side_src"], ctx["side_dst"], cap
            )
        self._tick("region", region_mode, t0)
        if cand is not None:
            metrics().histogram(
                "repair_region_nodes", buckets=_COUNT_BUCKETS
            ).observe(len(cand))

        if cand is None:  # legacy trigger fired (region/ref modes only)
            return self._finish_repeel(ctx, hi)

        t0 = time.perf_counter()
        cand_deg = self.g.degrees_of(cand)
        seed = np.minimum(
            cand_deg.astype(np.int64), old[cand].astype(np.int64) + m_ins
        )
        seed = np.maximum(seed, 0).astype(np.int32)
        self._tick("candidates", "gather", t0)

        if self._device():
            cells = self._tier_plan(len(cand), cand_deg)[4]
            budget = self.descend_budget if not _on_tpu() else 1 << 62
            if adaptive:
                deg = self.g.degrees()
                repeel_work = int(deg[old[:n] <= hi].sum()) + n
                if self.policy.choose(
                    cells=cells, repeel_work=repeel_work, budget=budget
                ) == "repeel":
                    return self._finish_repeel(ctx, hi)
            elif cells > budget:
                # legacy static trigger: a huge candidate matrix costs
                # more to sweep than one exact vectorized re-peel
                return self._finish_repeel(ctx, hi)
            try:
                pending = self._dispatch_with_retry(
                    cand, seed, old[cand], lo, hi, cand_deg=cand_deg
                )
            except Exception:
                return self._recover_ref(ctx, hi)
            # the dispatch may tier-reorder the rows: resolve/commit against
            # the ordering the result actually maps to
            if pipeline:
                return _RepairTicket(pending=pending, ctx=ctx,
                                     margin=margin, lo=lo, hi=hi,
                                     cand=pending["cand"])
            try:
                res = self._descend_read(pending)
            except Exception:
                metrics().counter("repair_dispatch_failures_total").inc()
                return self._recover_ref(ctx, hi)
            return self._resolve(ctx, margin, lo, hi, pending["cand"], res)

        t0 = time.perf_counter()
        new = self._descend(cand, seed)
        # a changed node's old level sits within the *deepest per-node
        # cascade* of the block's endpoint levels, so the window is
        # sufficient as long as the margin exceeds the largest single-node
        # level change
        max_gain = int(np.maximum(new - old[cand], 0).max(initial=0))
        max_loss = int(np.maximum(old[cand] - new, 0).max(initial=0))
        ceil_hit = bool(((new > hi) & (new > old[cand])).any())
        floor_hit = bool(((new < lo) & (new < old[cand])).any())
        self._tick("descend", "host", t0)
        return self._resolve(
            ctx, margin, lo, hi, cand,
            (new, max_gain, max_loss, ceil_hit, floor_hit),
        )

    def begin_update(self, added=None, removed=None) -> _RepairTicket:
        """Start a block repair; ``finish_update`` completes it.

        The returned ticket is either already committed (fallback re-peel,
        host impl, empty block) or holds an *in-flight* fused-descent
        dispatch. In the latter case the caller may overlap host work (the
        pipelined ingest stages the next block's dedup/scatter here) before
        ``finish_update`` reads the result back — but must not mutate the
        graph until then.
        """
        self._settle()
        faults.check("repair")
        added = (
            np.asarray(added, np.int64).reshape(-1, 2)
            if added is not None else _EMPTY
        )
        removed = (
            np.asarray(removed, np.int64).reshape(-1, 2)
            if removed is not None else _EMPTY
        )
        m_ins, m_del = len(added), len(removed)
        m = m_ins + m_del
        if m == 0:
            return _RepairTicket(changed=0)
        self._ensure_size()
        n = self.g.n_nodes
        old = self._core[:n].copy()

        touched = np.concatenate([added, removed]) if m_del and m_ins else (
            added if m_ins else removed
        )
        k_edge = np.minimum(
            self._core[touched[:, 0]], self._core[touched[:, 1]]
        )
        ctx = {
            "added": added, "removed": removed, "m_ins": m_ins,
            "m_del": m_del, "m": m, "n": n, "old": old,
            "ends": np.unique(touched.reshape(-1)),
            "k_min": int(k_edge.min()), "k_max": int(k_edge.max()),
        }
        if self._region_mode() != "ref":
            # side table: removed block edges (both arcs) + overflow arcs the
            # table/mirror cannot carry — built once, reused across widenings
            ov_src, ov_dst = self.g.overflow_arc_arrays()
            ctx["side_src"] = np.concatenate(
                [ov_src, removed[:, 0], removed[:, 1]]
            )
            ctx["side_dst"] = np.concatenate(
                [ov_dst, removed[:, 1], removed[:, 0]]
            )
        ticket = self._advance(
            ctx, 0 if m == 1 else self.margin0, pipeline=True
        )
        if not ticket.done:
            self._inflight = ticket
        return ticket

    def finish_update(self, ticket: Optional[_RepairTicket] = None) -> int:
        """Complete a repair started by ``begin_update``.

        Blocks on the in-flight descent (charging only the non-overlapped
        wait to the descend phase), validates the window, widens/commits.
        Returns the number of nodes whose core number changed.
        """
        if ticket is None:
            ticket = self._inflight
        if ticket is None:
            return 0
        if ticket.done:
            return ticket.changed
        if ticket is self._inflight:
            self._inflight = None
        try:
            res = self._descend_read(ticket.pending, full_interval=False)
        except Exception:
            # the in-flight device result is unreadable (device error
            # surfaced at the sync point): recover with the exact host peel
            metrics().counter("repair_dispatch_failures_total").inc()
            return self._recover_ref(ticket.ctx, ticket.hi).changed
        return self._resolve(
            ticket.ctx, ticket.margin, ticket.lo, ticket.hi, ticket.cand,
            res,
        ).changed

    def _settle(self) -> None:
        """Finish any in-flight ticket (public entry points call this so an
        overlapped repair is never observable)."""
        if self._inflight is not None:
            self.finish_update(self._inflight)

    def on_update(self, added=None, removed=None) -> int:
        """Repair after a mixed block of graph mutations has been applied.

        ``added``/``removed`` are the (m, 2) edge arrays the graph actually
        accepted (the return values of ``add_edges``/``remove_edges``).
        Returns the number of nodes whose core number changed. Synchronous:
        ``begin_update`` + ``finish_update`` back to back.
        """
        return self.finish_update(self.begin_update(added, removed))

    def on_edge_block(self, edges) -> int:
        """Repair after ``g.add_edges(edges)`` accepted ``edges`` (one union
        subcore sweep for the whole block). Returns #nodes promoted."""
        before = self.promoted
        self.on_update(added=edges)
        return self.promoted - before

    def on_remove(self, edges) -> int:
        """Repair after ``g.remove_edges(edges)`` removed ``edges``.
        Returns #nodes demoted."""
        before = self.demoted
        self.on_update(removed=edges)
        return self.demoted - before

    def on_edge(self, u: int, v: int) -> int:
        """Repair after ``g.add_edge(u, v)`` returned True.

        Single-edge compatibility wrapper over ``on_edge_block``; returns the
        number of nodes whose core number was promoted.
        """
        return self.on_edge_block(np.array([[u, v]], np.int64))

    # ------------------------------------------------------------- oracle

    def resync(self) -> int:
        """Recompute from the oracle; returns #mismatches found (0 expected).

        Called after compaction as a safety net — block maintenance is exact,
        so a nonzero return indicates a bug upstream.
        """
        self._settle()
        self._ensure_size()
        oracle = core_numbers_host(self.g.snapshot())
        n = self.g.n_nodes
        mismatches = int(np.sum(oracle != self._core[:n]))
        self._core[:n] = oracle
        return mismatches

    # ------------------------------------------------------------- drift

    def drift(self) -> int:
        """#nodes whose core number changed since the last ``mark_refresh``.

        Newly appeared nodes count (their baseline level is 0); so do nodes
        demoted by deletions — drift is direction-agnostic.
        """
        self._settle()
        self._ensure_size()
        n = self.g.n_nodes
        return int(np.sum(self._core[:n] != self._baseline[:n]))

    def membership_drift(self, k0: int) -> tuple:
        """k0-core membership churn since the last ``mark_refresh``.

        Returns (#nodes whose (core >= k0) flag flipped, current k0-core
        size). Counts departures (deletion-driven demotion out of the core)
        as well as arrivals.
        """
        self._settle()
        self._ensure_size()
        n = self.g.n_nodes
        now = self._core[:n] >= k0
        was = self._baseline[:n] >= k0
        return int(np.sum(now != was)), int(now.sum())

    def mark_refresh(self) -> None:
        """Record current levels as the embedding-table baseline."""
        self._settle()
        self._ensure_size()
        self._baseline = self._core.copy()

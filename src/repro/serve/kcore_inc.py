"""Incremental core-number maintenance under edge insertion.

The offline path peels the whole graph (``core_numbers_host``, O(E)); doing
that per streamed edge would make ingestion quadratic. Insertion-only streams
admit an exact local repair instead (Sarıyüce et al., "Streaming algorithms
for k-core decomposition", VLDB 2013):

* inserting (u, v) can only *increase* core numbers, each by at most 1;
* the only nodes that can change live in the **subcore** of the lower
  endpoint r — nodes with core == K := min(core(u), core(v)) reachable from r
  through nodes of core exactly K (both endpoints' subcores when the cores
  tie).

The repair itself reuses the device path's h-index operator
(``repro.core.kcore._h_index_rows``): seed every candidate at K+1 and sweep

    c(w) <- min(c(w), H({c(x) : x in N(w)}))

over candidate rows only, with non-candidate neighbours frozen at their true
(unchanged) core numbers. The operator is monotone, so the sweep descends to
the greatest fixed point <= K+1 — exactly the set of candidates that gain a
level. ``core_numbers_host`` on a snapshot is the oracle (``resync`` checks
against it; tests assert exact agreement after every compaction).

Core-number **drift** (how many nodes changed level since the embedding table
was last refreshed) is the staleness signal the store/service use to gate
retraining: the paper's §2.2 propagation stays valid while the k0-core is
stable, and drift in deep shells is what invalidates it.
"""
from __future__ import annotations

from typing import Optional, Set

import jax
import numpy as np

from repro.core.kcore import _h_index_rows, core_numbers_host

from .stream import DynamicGraph
from .util import pow2

__all__ = ["IncrementalCore"]

# Repair sweeps run the same operator as the offline device fixpoint. Jitted,
# with candidate matrices padded to power-of-two shapes so the number of
# distinct compilations stays logarithmic in repair size (padding rows are
# all-invalid -> h = 0, and are ignored on the way out).
_h_index_rows_jit = jax.jit(_h_index_rows)


class IncrementalCore:
    def __init__(self, g: DynamicGraph, core: Optional[np.ndarray] = None):
        self.g = g
        if core is None:
            core = (
                core_numbers_host(g.snapshot())
                if g.n_nodes
                else np.zeros(0, np.int32)
            )
        self._core = np.asarray(core, np.int32).copy()
        self._baseline = self._core.copy()  # levels at last embedding refresh
        self.repairs = 0
        self.sweeps = 0
        self.promoted = 0

    # ------------------------------------------------------------- views

    @property
    def core(self) -> np.ndarray:
        """(n_nodes,) int32 current core numbers (live view, do not mutate)."""
        return self._core[: self.g.n_nodes]

    def _ensure_size(self) -> None:
        n = self.g.n_nodes
        if len(self._core) < n:
            pad = np.zeros(n - len(self._core), np.int32)
            self._core = np.concatenate([self._core, pad])
            self._baseline = np.concatenate([self._baseline, pad])

    # ------------------------------------------------------------- repair

    def _subcore(self, roots, k: int) -> Set[int]:
        """Nodes with core == k reachable from ``roots`` via core-k nodes.

        Must be the full subcore — truncating it would seed only part of the
        repair region and silently break the exactness guarantee.
        """
        seen = {int(r) for r in roots if self._core[r] == k}
        stack = list(seen)
        while stack:
            w = stack.pop()
            for x in self.g.neighbours(w):
                x = int(x)
                if self._core[x] == k and x not in seen:
                    seen.add(x)
                    stack.append(x)
        return seen

    def on_edge(self, u: int, v: int) -> int:
        """Repair after ``g.add_edge(u, v)`` returned True.

        Returns the number of nodes whose core number was promoted.
        """
        self._ensure_size()
        u, v = int(u), int(v)
        k = int(min(self._core[u], self._core[v]))
        roots = [w for w in (u, v) if self._core[w] == k]
        cand = sorted(self._subcore(roots, k))
        if not cand:
            return 0
        self.repairs += 1

        # Padded candidate adjacency (true host adjacency incl. overflow).
        rows = [self.g.neighbours(w) for w in cand]
        n_rows = pow2(len(cand))
        width = pow2(max(len(r) for r in rows))
        idx = np.zeros((n_rows, width), np.int64)
        valid = np.zeros((n_rows, width), bool)
        for i, r in enumerate(rows):
            idx[i, : len(r)] = r
            valid[i, : len(r)] = True

        est = self._core.astype(np.int32).copy()
        cand_arr = np.asarray(cand, np.int64)
        est[cand_arr] = k + 1
        while True:
            self.sweeps += 1
            vals = est[idx].astype(np.int32)
            h = np.asarray(_h_index_rows_jit(vals, valid), np.int32)[: len(cand)]
            new = np.minimum(est[cand_arr], h)
            if np.array_equal(new, est[cand_arr]):
                break
            est[cand_arr] = new

        promoted = est[cand_arr] != self._core[cand_arr]
        self._core[cand_arr] = est[cand_arr]
        n_promoted = int(promoted.sum())
        self.promoted += n_promoted
        return n_promoted

    # ------------------------------------------------------------- oracle

    def resync(self) -> int:
        """Recompute from the oracle; returns #mismatches found (0 expected).

        Called after compaction as a safety net — insertion-only maintenance
        is exact, so a nonzero return indicates a bug upstream.
        """
        self._ensure_size()
        oracle = core_numbers_host(self.g.snapshot())
        n = self.g.n_nodes
        mismatches = int(np.sum(oracle != self._core[:n]))
        self._core[:n] = oracle
        return mismatches

    # ------------------------------------------------------------- drift

    def drift(self) -> int:
        """#nodes whose core number changed since the last ``mark_refresh``.

        Newly appeared nodes count (their baseline level is 0).
        """
        self._ensure_size()
        n = self.g.n_nodes
        return int(np.sum(self._core[:n] != self._baseline[:n]))

    def membership_drift(self, k0: int) -> tuple:
        """k0-core membership churn since the last ``mark_refresh``.

        Returns (#nodes whose (core >= k0) flag flipped, current k0-core size).
        """
        self._ensure_size()
        n = self.g.n_nodes
        now = self._core[:n] >= k0
        was = self._baseline[:n] >= k0
        return int(np.sum(now != was)), int(now.sum())

    def mark_refresh(self) -> None:
        """Record current levels as the embedding-table baseline."""
        self._ensure_size()
        self._baseline = self._core.copy()

"""Incremental core-number maintenance under edge insertion *and* deletion.

The offline path peels the whole graph (``core_numbers_host``, O(E)); doing
that per streamed edge would make ingestion quadratic. Streams admit exact
local repair instead (Sarıyüce et al., "Streaming algorithms for k-core
decomposition", VLDB 2013), and this module batches that repair over whole
**edge blocks**: one region discovery + one h-index descent per block,
instead of one per edge.

Block repair (``on_edge_block`` / ``on_remove`` / ``on_update``):

* All mutations of the block are first applied to the graph. The nodes whose
  core number can change lie in a **union subcore**: nodes reachable from any
  block endpoint through nodes whose old core number falls in a level window
  around the block's endpoint levels (purecore-style traversal; for a single
  insertion the window degenerates to the classical "core == K" subcore).
* Candidates are seeded at an upper bound of their new core number
  (``min(new_degree, old_core + #inserted)``, one vectorized gather from the
  graph's maintained degree array) and swept with the *same* row-masked
  h-index operator the offline device fixpoint uses
  (``repro.kernels.ops.h_index_sweep``, Pallas-backed on TPU), with
  non-candidate neighbours frozen at their true (unchanged) core numbers.
  The operator is monotone, so the sweep descends to the exact new core
  numbers (Lü et al. 2016).
* A block can cascade promotions/demotions across several levels, so the
  window half-width is **adaptive**: the repair re-runs with a wider window
  whenever the computed level changes touch the window boundary. Single-edge
  repairs never widen.
* **Bounded fallback**: when the candidate region exceeds ``repeel_frac`` of
  the graph (or the candidate matrix exceeds ``descend_budget`` off-TPU),
  local repair buys nothing — the maintainer recomputes the whole snapshot
  exactly, which ``repeels`` counts.

Device-resident path (``impl="device"``, the ``"auto"`` default) — every
repair stage is vectorized or fused:

* **Region growing** is a frontier-masked traversal: boolean frontier /
  visited masks expanded one level per step with the ``[lo, hi]`` core-window
  filter applied in bulk, plus a static-shaped **side table** of extra arcs
  (the removed block edges, so deletions keep their discovery path, and the
  overflow arcs the device mirror cannot see between compactions). On TPU it
  runs as a jitted ``lax.while_loop`` over the ``DynamicGraph`` device ELL
  mirror (``_region_fixpoint``); elsewhere the same traversal runs as
  vectorized numpy over the host table, where XLA scatters lose to the host.
  Both are bounded: discovery aborts early once it exceeds the fallback cap.
* **Candidate matrices** come from one vectorized gather
  (``DynamicGraph.gather_rows``), trimmed to the candidates' true max degree.
* **The h-index descent is one fused jitted fixpoint** (``_fused_descent``):
  seeding, every sweep, the convergence test, and the adaptive-window
  boundary statistics all run inside a single ``lax.while_loop`` dispatch —
  no per-iteration ``est[cand]`` ping-pong between host and device. Each
  sweep applies ``kernels.ops.h_index_sweep`` (the Pallas kernel on TPU, the
  sort-free counting search elsewhere).
* **The fallback** is the same fused descent seeded over *all* nodes on TPU
  (still one dispatch); off-TPU it is the vectorized rounds peel
  (``core_numbers_rounds``) fed straight from the graph's arc arrays.

The PR 2 host path survives as ``impl="ref"`` — the dict/set BFS, the
per-iteration jitted sweep, and the snapshot re-peel — and doubles as the
correctness oracle for the device path. ``phase_report()`` exposes per-phase
wall time (region / candidates / descend / fallback) and which backend each
phase ran on, so benchmarks can show *where* repair time goes.

Core-number **drift** (how many nodes changed level since the embedding table
was last refreshed) is the staleness signal the store/service use to gate
retraining: the paper's §2.2 propagation stays valid while the k0-core is
stable, and drift in deep shells — in either direction, now that edges can
be retracted — is what invalidates it.
"""
from __future__ import annotations

import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kcore import (
    _h_index_sweep_jit,
    core_numbers_host,
    core_numbers_rounds,
)
from repro.kernels import ops as kops
from repro.obs import metrics
from repro.obs import trace as obs

from .stream import DynamicGraph
from .util import pow2

__all__ = ["IncrementalCore"]

_EMPTY = np.zeros((0, 2), np.int64)

# size-distribution buckets (region node counts): powers of 4 up to ~4M
_COUNT_BUCKETS = 4.0 ** np.arange(12)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("impl", "max_sweeps"))
def _fused_descent(idx, valid, cand, seed, old, est_full, lo, hi, *,
                   impl: str, max_sweeps: int):
    """Whole h-index descent as one device dispatch.

    ``idx``/``valid``: (R, W) candidate neighbour matrix (global node ids,
    padding = sentinel); ``cand``: (R,) candidate ids (padded rows point at
    the sentinel, whose estimate stays 0); ``seed``: (R,) upper bound on the
    new cores; ``old``: (R,) old cores (0 on padded rows); ``est_full``:
    (node_cap + 1,) frozen boundary = current cores. Runs the row-masked
    sweep to its fixed point inside one ``lax.while_loop`` and returns
    ``(new, max_gain, max_loss, ceiling_hit, floor_hit, sweeps)`` — the
    adaptive-window boundary statistics ride along so the caller reads back
    five scalars plus the repaired levels, never per-sweep intermediates.
    """
    est = est_full.at[cand].set(seed)

    def cond(state):
        _, _, changed, it = state
        return jnp.logical_and(changed, it < max_sweeps)

    def body(state):
        est, cur, _, it = state
        vals = est[idx]
        new = kops.h_index_sweep(vals, valid, cur, impl=impl)
        est = est.at[cand].set(new)
        return est, new, jnp.any(new != cur), it + 1

    _, new, changed, sweeps = jax.lax.while_loop(
        cond, body, (est, seed, jnp.asarray(True), jnp.asarray(0, jnp.int32))
    )
    gain = jnp.max(jnp.maximum(new - old, 0), initial=0)
    loss = jnp.max(jnp.maximum(old - new, 0), initial=0)
    # only *changed* nodes at/past the boundary suggest a truncated cascade;
    # an unchanged high-core endpoint legitimately sits above the window
    ceiling = jnp.any((new > hi) & (new > old))
    floor = jnp.any((new < lo) & (new < old))
    # ``changed`` still true at exit means the sweep cap truncated the
    # descent — the estimates are NOT a fixed point and must not be committed
    return new, gain, loss, ceiling, floor, sweeps, changed


@jax.jit
def _region_fixpoint(nbr, deg, core, ends, side_src, side_dst, side_valid,
                     lo, hi, cap):
    """Frontier-masked union-subcore traversal, one jitted while-loop.

    ``nbr``/``deg`` are the device ELL mirror; ``side_*`` is the padded side
    table of extra arcs (removed block edges + overflow arcs the mirror
    cannot see). Expands boolean frontier/visited masks one level per
    iteration, filtering discovered nodes by old core in ``[lo, hi]``;
    endpoints are pre-seeded regardless of their level. Aborts early once
    the visited count exceeds ``cap`` (the caller falls back to a full
    recompute, so a partial region is never used).
    """
    n1, width = nbr.shape
    valid = jnp.arange(width, dtype=jnp.int32)[None, :] < deg[:, None]
    eligible = (core >= lo) & (core <= hi)

    def cond(state):
        frontier, _, count = state
        return jnp.logical_and(frontier.any(), count <= cap)

    def body(state):
        frontier, visited, _ = state
        contrib = frontier[:, None] & valid
        nxt = jnp.zeros(n1, bool).at[nbr].max(contrib)
        nxt = nxt.at[side_dst].max(frontier[side_src] & side_valid)
        newf = nxt & eligible & ~visited
        visited = visited | newf
        return newf, visited, jnp.sum(visited)

    _, visited, count = jax.lax.while_loop(
        cond, body, (ends, ends, jnp.sum(ends))
    )
    return visited, count


def _fit_width(idx: np.ndarray, valid: np.ndarray, w_pad: int,
               sentinel: int):
    """Trim/pad the gathered candidate matrix to a static ``w_pad`` columns.

    Safe to trim: ``w_pad >= max candidate degree``, and a row only owns
    overflow columns when its degree exceeds the table width, which forces
    ``w_pad`` past them.
    """
    w = idx.shape[1]
    if w > w_pad:
        return np.ascontiguousarray(idx[:, :w_pad]), np.ascontiguousarray(
            valid[:, :w_pad]
        )
    if w < w_pad:
        rows = idx.shape[0]
        idx = np.concatenate(
            [idx, np.full((rows, w_pad - w), sentinel, np.int32)], axis=1
        )
        valid = np.concatenate(
            [valid, np.zeros((rows, w_pad - w), bool)], axis=1
        )
    return idx, valid


class IncrementalCore:
    def __init__(
        self,
        g: DynamicGraph,
        core: Optional[np.ndarray] = None,
        *,
        repeel_frac: float = 0.6,
        margin0: int = 8,
        impl: str = "auto",
        region_impl: Optional[str] = None,
        kernel_impl: Optional[str] = None,
        repeel_impl: Optional[str] = None,
        descend_budget: int = 1 << 20,
        max_sweeps: int = 512,
    ):
        self.g = g
        if core is None:
            core = (
                core_numbers_host(g.snapshot())
                if g.n_nodes
                else np.zeros(0, np.int32)
            )
        self._core = np.asarray(core, np.int32).copy()
        self._baseline = self._core.copy()  # levels at last embedding refresh
        self.repeel_frac = float(repeel_frac)
        self.margin0 = int(margin0)
        if impl not in ("auto", "ref", "device"):
            raise ValueError(f"unknown impl {impl!r}")
        self.impl = impl
        self.region_impl = region_impl  # None=auto | "jit" | "np"
        self.kernel_impl = kernel_impl  # None=auto | ops.h_index_sweep impl
        self.repeel_impl = repeel_impl  # None=auto | "descend"|"rounds"|"peel"
        self.descend_budget = int(descend_budget)
        self.max_sweeps = int(max_sweeps)
        self.repairs = 0
        self.sweeps = 0
        self.descends = 0
        self.promoted = 0
        self.demoted = 0
        self.repeels = 0
        self.phase_seconds: dict = {}
        self.phase_impl: dict = {}

    # ---------------------------------------------------------- dispatch

    def _device(self) -> bool:
        return self.impl != "ref"

    def _region_mode(self) -> str:
        if not self._device():
            return "ref"
        if self.region_impl:
            return self.region_impl
        return "jit" if _on_tpu() else "np"

    def _kernel_mode(self) -> str:
        if self.kernel_impl:
            return self.kernel_impl
        return "pallas" if _on_tpu() else "count"

    def _repeel_mode(self) -> str:
        if not self._device():
            return "peel"
        if self.repeel_impl:
            return self.repeel_impl
        return "descend" if _on_tpu() else "rounds"

    def _tick(self, phase: str, mode: str, t0: float) -> None:
        t1 = time.perf_counter()
        self.phase_seconds[phase] = (
            self.phase_seconds.get(phase, 0.0) + t1 - t0
        )
        self.phase_impl[phase] = mode
        # the same interval feeds the trace (one span per phase occurrence,
        # nested under the enclosing serve.ingest/retract span) and the
        # metrics registry — phase_report(), the trace, and the exporter all
        # describe one measurement
        obs.record(f"repair.{phase}", t0, t1, impl=mode)
        metrics().histogram("repair_phase_seconds", phase=phase).observe(
            t1 - t0
        )

    def phase_report(self) -> dict:
        """Per-phase repair wall time + which backend each phase ran on."""
        return {
            k: {"seconds": round(v, 6), "impl": self.phase_impl.get(k, "")}
            for k, v in sorted(self.phase_seconds.items())
        }

    def reset_phases(self) -> None:
        """Zero the per-phase timers (benchmarks call this after warmup)."""
        self.phase_seconds = {}

    # ------------------------------------------------------------- views

    @property
    def core(self) -> np.ndarray:
        """(n_nodes,) int32 current core numbers (live view, do not mutate)."""
        return self._core[: self.g.n_nodes]

    @property
    def baseline(self) -> np.ndarray:
        """(n_nodes,) int32 core numbers at the last ``mark_refresh``.

        The retraining subsystem reads this to pick alignment anchors
        (nodes whose level has not moved since the serving table was built).
        """
        self._ensure_size()
        return self._baseline[: self.g.n_nodes]

    def _ensure_size(self) -> None:
        n = self.g.n_nodes
        if len(self._core) < n:
            pad = np.zeros(n - len(self._core), np.int32)
            self._core = np.concatenate([self._core, pad])
            self._baseline = np.concatenate([self._baseline, pad])

    # ------------------------------------------------------------ regions

    def _region(self, ends: np.ndarray, lo: int, hi: int, removed) -> list:
        """Union subcore, host reference: nodes reachable from the block
        endpoints through nodes with old core in [lo, hi], over the
        post-block adjacency plus the removed block edges (a deletion must
        not sever its own discovery path). Endpoints are always included.

        Must cover every node whose core changes — truncating it would seed
        only part of the repair region and silently break exactness; the
        caller guards that with the adaptive window + boundary check.
        """
        extra = {}
        for u, v in removed:
            extra.setdefault(int(u), set()).add(int(v))
            extra.setdefault(int(v), set()).add(int(u))
        seen = {int(r) for r in ends}
        stack = list(seen)
        while stack:
            w = stack.pop()
            nbrs = self.g.neighbours(w)
            ex = extra.get(w)
            if ex:
                nbrs = np.concatenate(
                    [nbrs, np.fromiter(ex, np.int64, len(ex))]
                )
            for x in nbrs:
                x = int(x)
                if x not in seen and lo <= self._core[x] <= hi:
                    seen.add(x)
                    stack.append(x)
        return sorted(seen)

    def _region_np(self, ends, lo, hi, side_src, side_dst, cap):
        """Vectorized host frontier traversal (same masks as the jitted
        device loop, minus the dispatch). Returns None once past ``cap``."""
        g = self.g
        n, n1 = g.n_nodes, g.node_cap + 1
        eligible = np.zeros(n1, bool)
        eligible[:n] = (self._core[:n] >= lo) & (self._core[:n] <= hi)
        visited = np.zeros(n1, bool)
        visited[ends] = True
        frontier = visited.copy()
        width_iota = np.arange(g.width)
        while frontier.any():
            rows = np.where(frontier)[0]
            live = width_iota[None, :] < g._deg[rows][:, None]
            nxt = np.zeros(n1, bool)
            nxt[g._nbr[rows][live]] = True
            if len(side_src):
                sm = frontier[side_src]
                if sm.any():
                    nxt[side_dst[sm]] = True
            frontier = nxt & eligible & ~visited
            visited |= frontier
            if int(visited.sum()) > cap:
                return None
        return np.where(visited[:n])[0].astype(np.int64)

    def _region_device(self, ends, lo, hi, side_src, side_dst, cap):
        """Jitted frontier traversal over the device ELL mirror + side table.

        Under a ShardPlan the mirror arrives row-sharded (and row-padded);
        the frontier/visited masks and the static-shaped side table — the
        halo buffer carrying the arcs shards cannot see locally (removed
        block edges + overflow arcs) — stay replicated, so each traversal
        level is still one dispatch with GSPMD exchanging the frontier.
        """
        g = self.g
        n = g.n_nodes
        ell = g.ell()
        n1 = ell.neighbours.shape[0]  # node_cap + 1, plus any shard padding
        ends_mask = np.zeros(n1, bool)
        ends_mask[ends] = True
        core = np.zeros(n1, np.int32)
        core[:n] = self._core[:n]
        s_pad = pow2(max(len(side_src), 1))
        ss = np.zeros(s_pad, np.int32)
        sd = np.zeros(s_pad, np.int32)
        sv = np.zeros(s_pad, bool)
        ss[: len(side_src)] = side_src
        sd[: len(side_dst)] = side_dst
        sv[: len(side_src)] = True
        plan = g.plan
        rep = jnp.asarray if plan is None else plan.replicate
        visited, count = _region_fixpoint(
            ell.neighbours, ell.degrees, rep(core),
            rep(ends_mask), rep(ss), rep(sd),
            rep(sv), lo, hi, cap,
        )
        if int(count) > cap:
            return None
        return np.where(np.asarray(visited)[:n])[0].astype(np.int64)

    # ------------------------------------------------------------ repairs

    def _repeel(self, old: np.ndarray, m_ins: int) -> int:
        """Exact full recompute: fused descent over all nodes on TPU, the
        vectorized rounds peel elsewhere, the legacy snapshot peel for
        ``impl="ref"``."""
        n = self.g.n_nodes
        mode = self._repeel_mode()
        t0 = time.perf_counter()
        if mode == "descend":
            deg = self.g.degrees_of(np.arange(n))
            seed = np.maximum(
                np.minimum(deg.astype(np.int64), old.astype(np.int64) + m_ins),
                0,
            ).astype(np.int32)
            # the inner gather/descent ticks belong to the fallback bucket:
            # roll them back so the phase report stays non-overlapping
            before = {
                k: self.phase_seconds.get(k)
                for k in ("candidates", "descend")
            }
            res = self._descend_fused(
                np.arange(n, dtype=np.int64), seed, old, 0, 1 << 30,
                cand_deg=deg,
            )
            for k, b in before.items():
                if b is None:
                    self.phase_seconds.pop(k, None)
                    self.phase_impl.pop(k, None)
                else:
                    self.phase_seconds[k] = b
            if res is None:
                # the sweep cap truncated the full descent (pathological
                # cascade depth) — recover with the uncapped exact peel
                src, dst = self.g.arc_arrays()
                oracle = core_numbers_rounds(n, src, dst)
                mode = "rounds"
            else:
                oracle = res[0]
        elif mode == "rounds":
            src, dst = self.g.arc_arrays()
            oracle = core_numbers_rounds(n, src, dst)
        else:
            oracle = core_numbers_host(self.g.snapshot())
        self._tick("fallback", mode, t0)
        changed = oracle != self._core[:n]
        self.promoted += int((oracle > self._core[:n]).sum())
        self.demoted += int((oracle < self._core[:n]).sum())
        self._core[:n] = oracle
        self.repeels += 1
        metrics().counter("repair_repeels_total").inc()
        return int(changed.sum())

    def _descend_fused(self, cand, seed, old_cand, lo, hi, *, cand_deg):
        """Gather the candidate matrix and run the one-dispatch descent.

        Returns (new, max_gain, max_loss, ceiling_hit, floor_hit) with the
        boundary statistics already pulled back as python scalars.
        """
        g = self.g
        node_cap = g.node_cap
        t0 = time.perf_counter()
        idx, valid = g.gather_rows(cand)
        # floor the padded shapes: masked rows/lanes are near-free to sweep,
        # and fewer distinct (R, W) combinations means far fewer jit compiles
        # across a stream of variously-sized repairs
        w_pad = max(pow2(max(int(cand_deg.max(initial=1)), 1)), 64)
        idx, valid = _fit_width(idx, valid, w_pad, node_cap)
        n_rows = len(cand)
        r_pad = max(pow2(n_rows), 64)
        if r_pad != n_rows:
            pad = r_pad - n_rows
            idx = np.concatenate(
                [idx, np.full((pad, w_pad), node_cap, np.int32)]
            )
            valid = np.concatenate([valid, np.zeros((pad, w_pad), bool)])
            cand = np.concatenate([cand, np.full(pad, node_cap, np.int64)])
            seed = np.concatenate([seed, np.zeros(pad, np.int32)])
            old_cand = np.concatenate([old_cand, np.zeros(pad, np.int32)])
        est_full = np.zeros(node_cap + 1, np.int32)
        est_full[: g.n_nodes] = self._core[: g.n_nodes]
        self._tick("candidates", "gather", t0)

        t0 = time.perf_counter()
        # under a ShardPlan (and a GSPMD-partitionable kernel impl) the
        # candidate matrix rows are split across the mesh: each shard sweeps
        # its own rows and the frozen-boundary estimate stays replicated
        plan = g.plan if self._kernel_mode() in ("count", "ref") else None
        row = jnp.asarray if plan is None else plan.place_rows
        rep = jnp.asarray if plan is None else plan.replicate
        new, gain, loss, ceiling, floor, sweeps, truncated = _fused_descent(
            row(idx), row(valid),
            row(np.asarray(cand, np.int32)),
            row(np.asarray(seed, np.int32)),
            row(np.asarray(old_cand, np.int32)),
            rep(est_full), lo, hi,
            impl=self._kernel_mode(), max_sweeps=self.max_sweeps,
        )
        new = np.asarray(new, np.int32)[:n_rows]
        self.sweeps += int(sweeps)
        self.descends += 1
        metrics().counter("repair_descends_total").inc()
        self._tick("descend", f"fused[{self._kernel_mode()}]", t0)
        if bool(truncated):  # max_sweeps cap hit before the fixed point
            return None
        return new, int(gain), int(loss), bool(ceiling), bool(floor)

    def _descend(self, cand: np.ndarray, seed: np.ndarray) -> np.ndarray:
        """Reference host descent: per-iteration jitted sweeps over a
        host-maintained estimate (the PR 2 path, kept as the oracle)."""
        g = self.g
        idx, valid = g.gather_rows(cand)
        w_pad = pow2(max(int(valid.sum(axis=1).max(initial=1)), 1))
        idx, valid = _fit_width(idx, valid, w_pad, g.node_cap)
        n_rows = pow2(len(cand))
        if n_rows != len(cand):
            pad = n_rows - len(cand)
            idx = np.concatenate(
                [idx, np.full((pad, w_pad), g.node_cap, np.int32)]
            )
            valid = np.concatenate([valid, np.zeros((pad, w_pad), bool)])

        est = np.zeros(g.node_cap + 1, np.int32)
        est[: len(self._core)] = self._core
        est[cand] = seed
        est_p = np.zeros(n_rows, np.int32)  # padded rows descend from 0 to 0
        while True:
            self.sweeps += 1
            vals = est[idx].astype(np.int32)
            est_p[: len(cand)] = est[cand]
            new = np.asarray(
                _h_index_sweep_jit(vals, valid, est_p, impl="ref"), np.int32
            )[: len(cand)]
            if np.array_equal(new, est[cand]):
                return new
            est[cand] = new

    def on_update(self, added=None, removed=None) -> int:
        """Repair after a mixed block of graph mutations has been applied.

        ``added``/``removed`` are the (m, 2) edge arrays the graph actually
        accepted (the return values of ``add_edges``/``remove_edges``).
        Returns the number of nodes whose core number changed.
        """
        added = np.asarray(added, np.int64).reshape(-1, 2) if added is not None else _EMPTY
        removed = np.asarray(removed, np.int64).reshape(-1, 2) if removed is not None else _EMPTY
        m_ins, m_del = len(added), len(removed)
        m = m_ins + m_del
        if m == 0:
            return 0
        self._ensure_size()
        n = self.g.n_nodes
        old = self._core[:n].copy()

        touched = np.concatenate([added, removed]) if m_del and m_ins else (
            added if m_ins else removed
        )
        k_edge = np.minimum(self._core[touched[:, 0]], self._core[touched[:, 1]])
        k_min, k_max = int(k_edge.min()), int(k_edge.max())
        ends = np.unique(touched.reshape(-1))
        cap = int(max(256, self.repeel_frac * n))
        region_mode = self._region_mode()
        if region_mode != "ref":
            # side table: removed block edges (both arcs) + overflow arcs the
            # table/mirror cannot carry — built once, reused across widenings
            ov_src, ov_dst = self.g.overflow_arc_arrays()
            side_src = np.concatenate([ov_src, removed[:, 0], removed[:, 1]])
            side_dst = np.concatenate([ov_dst, removed[:, 1], removed[:, 0]])

        # Adaptive window: grow the half-width until the computed changes sit
        # strictly inside it (a change at the boundary may be a truncated
        # cascade). A single mutation cannot cascade, so it never widens.
        margin = 0 if m == 1 else self.margin0
        while True:
            lo = max(0, k_min - (margin if m_del else 0))
            hi = k_max + (margin if m_ins else 0)

            t0 = time.perf_counter()
            if region_mode == "ref":
                cand = np.asarray(self._region(ends, lo, hi, removed), np.int64)
                if len(cand) > cap:
                    cand = None
            elif region_mode == "jit":
                cand = self._region_device(ends, lo, hi, side_src, side_dst, cap)
            else:
                cand = self._region_np(ends, lo, hi, side_src, side_dst, cap)
            self._tick("region", region_mode, t0)
            if cand is not None:
                metrics().histogram(
                    "repair_region_nodes", buckets=_COUNT_BUCKETS
                ).observe(len(cand))

            if cand is None:
                changed = self._repeel(old, m_ins)
                self.repairs += 1
                return changed

            t0 = time.perf_counter()
            cand_deg = self.g.degrees_of(cand)
            seed = np.minimum(
                cand_deg.astype(np.int64), old[cand].astype(np.int64) + m_ins
            )
            seed = np.maximum(seed, 0).astype(np.int32)
            self._tick("candidates", "gather", t0)

            if self._device():
                # off-TPU, a huge candidate matrix costs more to sweep than
                # one exact vectorized re-peel — bound the fused work
                if not _on_tpu() and pow2(len(cand)) * pow2(
                    max(int(cand_deg.max(initial=1)), 1)
                ) > self.descend_budget:
                    changed = self._repeel(old, m_ins)
                    self.repairs += 1
                    return changed
                res = self._descend_fused(
                    cand, seed, old[cand], lo, hi, cand_deg=cand_deg
                )
                if res is None:  # sweep cap hit: recover via exact recompute
                    changed = self._repeel(old, m_ins)
                    self.repairs += 1
                    return changed
                new, max_gain, max_loss, ceil_hit, floor_hit = res
            else:
                t0 = time.perf_counter()
                new = self._descend(cand, seed)
                # a changed node's old level sits within the *deepest
                # per-node cascade* of the block's endpoint levels, so the
                # window is sufficient as long as the margin exceeds the
                # largest single-node level change
                max_gain = int(np.maximum(new - old[cand], 0).max(initial=0))
                max_loss = int(np.maximum(old[cand] - new, 0).max(initial=0))
                ceil_hit = bool(((new > hi) & (new > old[cand])).any())
                floor_hit = bool(((new < lo) & (new < old[cand])).any())
                self._tick("descend", "host", t0)

            ceiling_hit = bool(m_ins) and ceil_hit
            floor_hit = bool(m_del and lo > 0) and floor_hit
            if m == 1 or (
                max_gain < margin
                and max_loss < margin
                and not ceiling_hit
                and not floor_hit
            ):
                break
            margin = 2 * margin + max_gain + max_loss + 1

        self.repairs += 1
        self._core[cand] = new
        self.promoted += int((new > old[cand]).sum())
        self.demoted += int((new < old[cand]).sum())
        return int((new != old[cand]).sum())

    def on_edge_block(self, edges) -> int:
        """Repair after ``g.add_edges(edges)`` accepted ``edges`` (one union
        subcore sweep for the whole block). Returns #nodes promoted."""
        before = self.promoted
        self.on_update(added=edges)
        return self.promoted - before

    def on_remove(self, edges) -> int:
        """Repair after ``g.remove_edges(edges)`` removed ``edges``.
        Returns #nodes demoted."""
        before = self.demoted
        self.on_update(removed=edges)
        return self.demoted - before

    def on_edge(self, u: int, v: int) -> int:
        """Repair after ``g.add_edge(u, v)`` returned True.

        Single-edge compatibility wrapper over ``on_edge_block``; returns the
        number of nodes whose core number was promoted.
        """
        return self.on_edge_block(np.array([[u, v]], np.int64))

    # ------------------------------------------------------------- oracle

    def resync(self) -> int:
        """Recompute from the oracle; returns #mismatches found (0 expected).

        Called after compaction as a safety net — block maintenance is exact,
        so a nonzero return indicates a bug upstream.
        """
        self._ensure_size()
        oracle = core_numbers_host(self.g.snapshot())
        n = self.g.n_nodes
        mismatches = int(np.sum(oracle != self._core[:n]))
        self._core[:n] = oracle
        return mismatches

    # ------------------------------------------------------------- drift

    def drift(self) -> int:
        """#nodes whose core number changed since the last ``mark_refresh``.

        Newly appeared nodes count (their baseline level is 0); so do nodes
        demoted by deletions — drift is direction-agnostic.
        """
        self._ensure_size()
        n = self.g.n_nodes
        return int(np.sum(self._core[:n] != self._baseline[:n]))

    def membership_drift(self, k0: int) -> tuple:
        """k0-core membership churn since the last ``mark_refresh``.

        Returns (#nodes whose (core >= k0) flag flipped, current k0-core
        size). Counts departures (deletion-driven demotion out of the core)
        as well as arrivals.
        """
        self._ensure_size()
        n = self.g.n_nodes
        now = self._core[:n] >= k0
        was = self._baseline[:n] >= k0
        return int(np.sum(now != was)), int(now.sum())

    def mark_refresh(self) -> None:
        """Record current levels as the embedding-table baseline."""
        self._ensure_size()
        self._baseline = self._core.copy()

"""Streaming graph container for the online embedding service.

``DynamicGraph`` keeps a mutable adjacency in a host-side ELL table with
degree-growth slack, mirrored lazily onto the device as an ``EllGraph`` view.
Edges are append-only (the paper's serving story is insertion-only: new users
and new interactions arrive, nothing is retracted), which is also what keeps
incremental core maintenance exact (core numbers are monotone non-decreasing
under insertion).

Layout:

* Host table ``(node_cap + 1, width)`` int32, padding/sentinel = ``node_cap``.
  ``width`` carries slack beyond the current max degree so most insertions are
  a single slot write. Rows that outgrow the width spill into a per-node
  overflow list — those arcs are invisible to the *device* view until the next
  ``compact()`` (the same "capped table subsamples neighbours" semantics as
  ``Graph.to_ell(max_width=...)``) but always visible to the host-side
  adjacency that incremental k-core reads, so core maintenance stays exact.
* Device mirror: pending single-slot writes are batch-applied with one
  scatter per ``ell()`` call; compaction and node growth rebuild it.

``compact()`` re-packs the table at a fresh slacked width, merges overflow,
sorts rows, and bumps ``compactions`` — the service calls it periodically and
after bursts of overflow.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.graph.csr import EllGraph, Graph

from .util import pow2

__all__ = ["DynamicGraph"]


class DynamicGraph:
    def __init__(
        self,
        n_nodes: int = 0,
        edges: Optional[np.ndarray] = None,
        *,
        width: int = 8,
        slack: float = 1.5,
        node_slack: float = 1.25,
    ):
        if slack < 1.0 or node_slack < 1.0:
            raise ValueError("slack factors must be >= 1")
        self.slack = float(slack)
        self.node_slack = float(node_slack)
        self.n_nodes = int(n_nodes)
        self.node_cap = max(int(np.ceil(self.n_nodes * self.node_slack)), 16)
        self.width = max(int(width), 1)
        self._nbr = np.full((self.node_cap + 1, self.width), self.node_cap, np.int32)
        self._deg = np.zeros(self.node_cap + 1, np.int32)  # in-table entries
        self._overflow: Dict[int, List[int]] = {}
        self.n_edges = 0
        self.compactions = 0
        self.edges_since_compact = 0
        # device mirror state
        self._dev_nbr: Optional[jnp.ndarray] = None
        self._dev_deg: Optional[jnp.ndarray] = None
        self._pending: List[Tuple[int, int, int]] = []  # (row, slot, value)
        self._dirty_full = True
        if edges is not None and len(edges):
            self.add_edges(np.asarray(edges))

    # ------------------------------------------------------------- host side

    def degree(self, v: int) -> int:
        return int(self._deg[v]) + len(self._overflow.get(v, ()))

    def degrees(self) -> np.ndarray:
        deg = self._deg[: self.n_nodes].astype(np.int64).copy()
        for v, extra in self._overflow.items():
            deg[v] += len(extra)
        return deg.astype(np.int32)

    def neighbours(self, v: int) -> np.ndarray:
        """True neighbour list (table + overflow), unsorted."""
        row = self._nbr[v, : self._deg[v]]
        extra = self._overflow.get(v)
        if extra:
            return np.concatenate([row, np.asarray(extra, np.int32)])
        return row.copy()

    def has_edge(self, u: int, v: int) -> bool:
        if u >= self.node_cap:
            return False
        if np.any(self._nbr[u, : self._deg[u]] == v):
            return True
        return v in self._overflow.get(u, ())

    # ------------------------------------------------------------- mutation

    def _grow_nodes(self, need: int) -> None:
        new_cap = max(int(np.ceil(need * self.node_slack)), self.node_cap * 2)
        nbr = np.full((new_cap + 1, self.width), new_cap, np.int32)
        valid = self._nbr[:-1] != self.node_cap
        nbr[: self.node_cap][valid] = self._nbr[:-1][valid]
        deg = np.zeros(new_cap + 1, np.int32)
        deg[: self.node_cap] = self._deg[:-1]
        self._nbr, self._deg, self.node_cap = nbr, deg, new_cap
        self._dirty_full = True
        self._pending.clear()

    def add_edge(self, u: int, v: int) -> bool:
        """Insert undirected edge. Returns False for self-loops/duplicates."""
        u, v = int(u), int(v)
        if u < 0 or v < 0:
            # negative ids would wrap into the sentinel row and corrupt the
            # padding semantics every batched consumer relies on
            raise ValueError(f"node ids must be non-negative, got ({u}, {v})")
        if u == v:
            return False
        hi = max(u, v)
        if hi >= self.node_cap:
            self._grow_nodes(hi + 1)
        if self.has_edge(u, v):
            return False
        self.n_nodes = max(self.n_nodes, hi + 1)
        for a, b in ((u, v), (v, u)):
            d = int(self._deg[a])
            if d < self.width:
                self._nbr[a, d] = b
                self._deg[a] = d + 1
                if not self._dirty_full:
                    self._pending.append((a, d, b))
            else:
                self._overflow.setdefault(a, []).append(b)
        self.n_edges += 1
        self.edges_since_compact += 1
        return True

    def add_edges(self, edges: np.ndarray) -> int:
        return sum(self.add_edge(int(e[0]), int(e[1])) for e in np.asarray(edges))

    @property
    def overflow_arcs(self) -> int:
        return sum(len(x) for x in self._overflow.values())

    @property
    def needs_compact(self) -> bool:
        return bool(self._overflow)

    def compact(self, min_width: int = 4) -> None:
        """Re-pack at a fresh slacked width; merges overflow, sorts rows."""
        deg = self.degrees()
        max_deg = int(deg.max()) if deg.size else 0
        width = max(int(np.ceil(max_deg * self.slack)), min_width, 1)
        nbr = np.full((self.node_cap + 1, width), self.node_cap, np.int32)
        for v in range(self.n_nodes):
            row = np.sort(self.neighbours(v))
            nbr[v, : len(row)] = row
        new_deg = np.zeros(self.node_cap + 1, np.int32)
        new_deg[: self.n_nodes] = deg
        self._nbr, self._deg, self.width = nbr, new_deg, width
        self._overflow.clear()
        self.compactions += 1
        self.edges_since_compact = 0
        self._dirty_full = True
        self._pending.clear()

    # ------------------------------------------------------------ snapshots

    def snapshot(self) -> Graph:
        """Immutable host CSR of the current graph (sorted rows, both arcs)."""
        srcs, dsts = [], []
        for v in range(self.n_nodes):
            row = self.neighbours(v)
            srcs.append(np.full(len(row), v, np.int64))
            dsts.append(row.astype(np.int64))
        if srcs:
            edges = np.stack(
                [np.concatenate(srcs), np.concatenate(dsts)], axis=1
            )
        else:
            edges = np.zeros((0, 2), np.int64)
        return Graph.from_edges(self.n_nodes, edges, undirected=False)

    def ell(self) -> EllGraph:
        """Device ELL view (overflow arcs excluded until the next compact).

        Pending single-slot writes since the last call are applied as one
        batched scatter; compaction/growth trigger a full re-upload.
        """
        if self._dirty_full or self._dev_nbr is None:
            self._dev_nbr = jnp.asarray(self._nbr)
            self._dev_deg = jnp.asarray(self._deg)
            self._dirty_full = False
            self._pending.clear()
        elif self._pending:
            upd = np.asarray(self._pending, np.int32)
            # pad to a power-of-two count by repeating the first write (an
            # idempotent duplicate) so eager scatter compiles O(log) shapes
            n_pad = pow2(len(upd))
            upd = np.concatenate([upd, np.repeat(upd[:1], n_pad - len(upd), 0)])
            rows, slots, vals = upd[:, 0], upd[:, 1], upd[:, 2]
            self._dev_nbr = self._dev_nbr.at[rows, slots].set(vals)
            # degrees: scatter only the touched rows (duplicates idempotent —
            # every write carries the row's final host-side degree)
            self._dev_deg = self._dev_deg.at[rows].set(self._deg[rows])
            self._pending.clear()
        return EllGraph(
            n_nodes=self.node_cap, neighbours=self._dev_nbr, degrees=self._dev_deg
        )

"""Streaming graph container for the online embedding service.

``DynamicGraph`` keeps a mutable adjacency in a host-side ELL table with
degree-growth slack, mirrored lazily onto the device as an ``EllGraph`` view.
Mutations are **block-oriented**: ``add_edges`` / ``remove_edges`` stage a
whole edge block, dedup it vectorized (within the block and against the
current adjacency), and apply it with one grouped scatter — the per-edge
Python loop only survives as a thin compatibility wrapper. Deletions use
swap-with-last slot removal (backfilling from the overflow list when one
exists), so rows stay dense and the device mirror needs at most two slot
writes per removed arc.

Layout:

* Host table ``(node_cap + 1, width)`` int32, padding/sentinel = ``node_cap``.
  ``width`` carries slack beyond the current max degree so most insertions are
  a single slot write. Rows that outgrow the width spill into a per-node
  overflow list — those arcs are invisible to the *device* view until the next
  ``compact()`` (the same "capped table subsamples neighbours" semantics as
  ``Graph.to_ell(max_width=...)``) but always visible to the host-side
  adjacency that incremental k-core reads, so core maintenance stays exact.
* Device mirror: pending slot writes (inserts *and* removals) are
  batch-applied with one scatter per ``ell()`` call. Under a
  :class:`~repro.serve.shard.ShardPlan` the mirror is **row-sharded** over
  the plan's mesh (rows padded to the shard multiple with sentinel rows), the
  pending scatter stays shard-local, and consumers (the cold-start gather,
  the jitted region traversal) read it through the same one-dispatch jit
  programs with GSPMD stitching the cross-shard edges.

``compact()`` is **double-buffered**: the re-packed table is built off to the
side (host arrays + device upload) and swapped in atomically, so ``ell()``
consumers never observe a rebuild pause — ``EllGraph`` views handed out
before the swap keep referencing the old immutable device buffers, and the
first ``ell()`` after the swap returns the pre-uploaded new ones without a
full re-upload on the query path.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.graph.csr import EllGraph, Graph
from repro.obs import metrics
from repro.obs import trace as obs

from .util import pow2

__all__ = ["DynamicGraph"]

_EMPTY_EDGES = np.zeros((0, 2), np.int64)


class DynamicGraph:
    def __init__(
        self,
        n_nodes: int = 0,
        edges: Optional[np.ndarray] = None,
        *,
        width: int = 8,
        slack: float = 1.5,
        node_slack: float = 1.25,
        plan=None,
    ):
        if slack < 1.0 or node_slack < 1.0:
            raise ValueError("slack factors must be >= 1")
        self.slack = float(slack)
        self.node_slack = float(node_slack)
        self.plan = plan if plan is not None and plan.enabled else None
        self.n_nodes = int(n_nodes)
        self.node_cap = max(int(np.ceil(self.n_nodes * self.node_slack)), 16)
        self.width = max(int(width), 1)
        self._nbr = np.full((self.node_cap + 1, self.width), self.node_cap, np.int32)
        self._deg = np.zeros(self.node_cap + 1, np.int32)  # in-table entries
        self._overflow: Dict[int, List[int]] = {}
        self.n_edges = 0
        self.compactions = 0
        self.edges_since_compact = 0
        # device mirror state
        self._dev_nbr: Optional[jnp.ndarray] = None
        self._dev_deg: Optional[jnp.ndarray] = None
        self._pending: List[Tuple[int, int, int]] = []  # (row, slot, value)
        self._dirty_full = True
        if edges is not None and len(edges):
            self.add_edges(np.asarray(edges))

    # ------------------------------------------------------------- host side

    def degree(self, v: int) -> int:
        return int(self._deg[v]) + len(self._overflow.get(v, ()))

    def degrees(self) -> np.ndarray:
        deg = self._deg[: self.n_nodes].astype(np.int64).copy()
        for v, extra in self._overflow.items():
            deg[v] += len(extra)
        return deg.astype(np.int32)

    def degrees_of(self, nodes) -> np.ndarray:
        """True degrees of ``nodes`` (table + overflow), one vectorized gather.

        The block repair seeds every candidate from this instead of one
        ``degree()`` call per candidate.
        """
        nodes = np.asarray(nodes, np.int64)
        deg = self._deg[nodes].astype(np.int64)
        if self._overflow:  # cost stays O(queried), not O(node_cap)
            ov = self._overflow
            deg += np.fromiter(
                (len(ov.get(v, ())) for v in nodes.tolist()),
                np.int64, len(nodes),
            )
        return deg.astype(np.int32)

    def neighbours(self, v: int) -> np.ndarray:
        """True neighbour list (table + overflow), unsorted."""
        row = self._nbr[v, : self._deg[v]]
        extra = self._overflow.get(v)
        if extra:
            return np.concatenate([row, np.asarray(extra, np.int32)])
        return row.copy()

    def has_edge(self, u: int, v: int) -> bool:
        if u >= self.node_cap:
            return False
        if np.any(self._nbr[u, : self._deg[u]] == v):
            return True
        return v in self._overflow.get(u, ())

    def arc_arrays(self) -> Tuple[np.ndarray, np.ndarray]:
        """All current arcs as (src, dst) int64 arrays (table + overflow).

        One vectorized mask-flatten of the ELL table plus the overflow lists —
        no per-node Python loop. Unsorted; both directions of every edge.
        """
        n = self.n_nodes
        slot_live = np.arange(self.width)[None, :] < self._deg[:n, None]
        rows = np.repeat(np.arange(n, dtype=np.int64), self._deg[:n])
        dsts = self._nbr[:n][slot_live].astype(np.int64)
        if self._overflow:
            ov_rows, ov_dsts = self.overflow_arc_arrays()
            rows = np.concatenate([rows, ov_rows])
            dsts = np.concatenate([dsts, ov_dsts])
        return rows, dsts

    def overflow_arc_arrays(self) -> Tuple[np.ndarray, np.ndarray]:
        """Overflow arcs as (src, dst) int64 arrays (empty when none spilled).

        These arcs are invisible to the device ELL mirror until the next
        ``compact()``; device-side traversals append them as a side table.
        """
        if not self._overflow:
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        rows = np.concatenate(
            [np.full(len(x), v, np.int64) for v, x in self._overflow.items()]
        )
        dsts = np.concatenate(
            [np.asarray(x, np.int64) for x in self._overflow.values()]
        )
        return rows, dsts

    def gather_rows(self, nodes) -> Tuple[np.ndarray, np.ndarray]:
        """Neighbour matrix of ``nodes``: (idx, valid) with overflow merged.

        ``idx`` is (len(nodes), W') int32 of neighbour ids (padding =
        ``node_cap``, the sentinel row), ``valid`` the matching bool mask.
        The table part is one vectorized gather; only rows that currently
        hold overflow arcs (rare between compactions) widen the matrix and
        are patched individually.
        """
        nodes = np.asarray(nodes, np.int64)
        idx = self._nbr[nodes]  # fancy indexing: already a fresh copy
        valid = np.arange(self.width)[None, :] < self._deg[nodes][:, None]
        if self._overflow:
            pos = {int(v): i for i, v in enumerate(nodes)}
            hits = [
                (pos[v], lst)
                for v, lst in self._overflow.items()
                if v in pos
            ]
            if hits:
                extra_w = max(len(lst) for _, lst in hits)
                idx = np.concatenate(
                    [idx, np.full((len(nodes), extra_w), self.node_cap,
                                  np.int32)], axis=1
                )
                valid = np.concatenate(
                    [valid, np.zeros((len(nodes), extra_w), bool)], axis=1
                )
                for i, lst in hits:
                    idx[i, self.width : self.width + len(lst)] = lst
                    valid[i, self.width : self.width + len(lst)] = True
        return idx, valid

    # ------------------------------------------------------------- staging

    def _canonical_block(self, edges) -> np.ndarray:
        """(m, 2) block -> deduped canonical (lo, hi) rows, self-loops gone."""
        edges = np.asarray(edges, np.int64).reshape(-1, 2)
        if edges.size == 0:
            return _EMPTY_EDGES
        if (edges < 0).any():
            # negative ids would wrap into the sentinel row and corrupt the
            # padding semantics every batched consumer relies on
            raise ValueError("node ids must be non-negative")
        edges = edges[edges[:, 0] != edges[:, 1]]
        if not len(edges):
            return _EMPTY_EDGES
        lo = np.minimum(edges[:, 0], edges[:, 1])
        hi = np.maximum(edges[:, 0], edges[:, 1])
        return np.unique(np.stack([lo, hi], axis=1), axis=0)

    def stage_block(self, edges) -> np.ndarray:
        """Graph-independent half of a block mutation: canonicalise + dedup.

        Pure host preprocessing — it reads no graph state — so a pipelined
        caller can stage block N+1 while block N's device dispatch is still
        in flight, then hand the result back via ``add_edges(..., staged=True)``
        (or ``remove_edges``). Staging then applying is bit-identical to the
        plain call.
        """
        return self._canonical_block(edges)

    def _present_mask(self, edges: np.ndarray) -> np.ndarray:
        """Vectorized membership of canonical ``edges`` in the current graph."""
        u = np.minimum(edges[:, 0], self.node_cap)
        present = (self._nbr[u] == edges[:, 1][:, None]).any(axis=1)
        # ids at/past node_cap are absent by definition (and the clipped row
        # gather above could only have matched padding sentinels for them)
        present &= (edges[:, 0] < self.node_cap) & (edges[:, 1] < self.node_cap)
        if self._overflow:  # rare: only rows past the table width
            for i in np.where(~present)[0]:
                ov = self._overflow.get(int(edges[i, 0]))
                if ov and int(edges[i, 1]) in ov:
                    present[i] = True
        return present

    # ------------------------------------------------------------- mutation

    def _grow_nodes(self, need: int) -> None:
        new_cap = max(int(np.ceil(need * self.node_slack)), self.node_cap * 2)
        nbr = np.full((new_cap + 1, self.width), new_cap, np.int32)
        valid = self._nbr[:-1] != self.node_cap
        nbr[: self.node_cap][valid] = self._nbr[:-1][valid]
        deg = np.zeros(new_cap + 1, np.int32)
        deg[: self.node_cap] = self._deg[:-1]
        self._nbr, self._deg, self.node_cap = nbr, deg, new_cap
        self._dirty_full = True
        self._pending.clear()

    def add_edges(self, edges, *, staged: bool = False) -> np.ndarray:
        """Vectorized block insert; returns the (m', 2) accepted edges.

        The block is canonicalised and deduped (within itself and against the
        existing adjacency) in one vectorized pass, then both arc directions
        are applied with a single grouped scatter: slots are assigned per row
        by intra-block rank, arcs that do not fit the table width go to the
        overflow lists. Self-loops and duplicates are dropped (not errors);
        negative ids raise. ``staged=True`` marks ``edges`` as the output of
        :meth:`stage_block` and skips re-canonicalisation.
        """
        with obs.span("graph.add_edges") as sp:
            edges = (np.asarray(edges, np.int64).reshape(-1, 2) if staged
                     else self._canonical_block(edges))
            if not len(edges):
                return _EMPTY_EDGES
            hi_max = int(edges[:, 1].max())
            if hi_max >= self.node_cap:
                self._grow_nodes(hi_max + 1)
            edges = edges[~self._present_mask(edges)]
            if not len(edges):
                return _EMPTY_EDGES
            sp.set(accepted=len(edges))
            self.n_nodes = max(self.n_nodes, hi_max + 1)

            # stage both arc directions, grouped by source row
            src = np.concatenate([edges[:, 0], edges[:, 1]])
            dst = np.concatenate([edges[:, 1], edges[:, 0]])
            order = np.argsort(src, kind="stable")
            src, dst = src[order], dst[order]
            rows, start, counts = np.unique(
                src, return_index=True, return_counts=True
            )
            rank = np.arange(len(src)) - np.repeat(start, counts)
            slot = self._deg[src] + rank
            in_table = slot < self.width
            ts, tslot, td = src[in_table], slot[in_table], dst[in_table]
            self._nbr[ts, tslot] = td  # (row, slot) unique: one scatter
            for s, d in zip(src[~in_table], dst[~in_table]):
                self._overflow.setdefault(int(s), []).append(int(d))
            self._deg[rows] = np.minimum(self._deg[rows] + counts, self.width)
            if not self._dirty_full:
                self._pending.extend(
                    zip(ts.tolist(), tslot.tolist(), td.tolist())
                )
            self.n_edges += len(edges)
            self.edges_since_compact += len(edges)
            metrics().counter("graph_edges_added_total").inc(len(edges))
        return edges

    def add_edge(self, u: int, v: int) -> bool:
        """Insert one undirected edge. Returns False for self-loops/duplicates."""
        return bool(len(self.add_edges(np.array([[u, v]], np.int64))))

    def _remove_arc(self, a: int, b: int) -> None:
        """Drop arc a->b: swap-with-last in the table, backfill from overflow."""
        d = int(self._deg[a])
        j = np.where(self._nbr[a, :d] == b)[0]
        if len(j) == 0:  # the arc lives in the overflow list
            ov = self._overflow[a]
            ov.remove(b)
            if not ov:
                del self._overflow[a]
            return
        j, last = int(j[0]), d - 1
        writes = []
        if j != last:
            self._nbr[a, j] = self._nbr[a, last]
            writes.append((a, j, int(self._nbr[a, j])))
        ov = self._overflow.get(a)
        if ov:  # backfill the freed slot; in-table degree is unchanged
            fill = ov.pop()
            if not ov:
                del self._overflow[a]
            self._nbr[a, last] = fill
            writes.append((a, last, int(fill)))
        else:
            self._nbr[a, last] = self.node_cap
            self._deg[a] = last
            writes.append((a, last, self.node_cap))
        if not self._dirty_full:
            self._pending.extend(writes)

    def remove_edges(self, edges, *, staged: bool = False) -> np.ndarray:
        """Vectorized block delete; returns the (m', 2) edges actually removed.

        The block is canonicalised/deduped and filtered to edges that exist
        (one vectorized membership pass); each surviving edge drops both arcs
        via swap-with-last, and the touched slots join the same pending-write
        scatter the insert path uses. Unknown edges are skipped, not errors.
        ``staged=True`` accepts :meth:`stage_block` output unchanged.
        """
        with obs.span("graph.remove_edges") as sp:
            edges = (np.asarray(edges, np.int64).reshape(-1, 2) if staged
                     else self._canonical_block(edges))
            if not len(edges):
                return _EMPTY_EDGES
            edges = edges[self._present_mask(edges)]
            sp.set(removed=len(edges))
            for u, v in edges:
                self._remove_arc(int(u), int(v))
                self._remove_arc(int(v), int(u))
            self.n_edges -= len(edges)
            # churn counts toward compaction
            self.edges_since_compact += len(edges)
            metrics().counter("graph_edges_removed_total").inc(len(edges))
        return edges

    def remove_edge(self, u: int, v: int) -> bool:
        """Delete one undirected edge. Returns False if it was not present."""
        return bool(len(self.remove_edges(np.array([[u, v]], np.int64))))

    @property
    def overflow_arcs(self) -> int:
        return sum(len(x) for x in self._overflow.values())

    @property
    def needs_compact(self) -> bool:
        return bool(self._overflow)

    def compact(self, min_width: int = 4) -> None:
        """Pause-free re-pack at a fresh slacked width (merges overflow).

        Double-buffered: the new table is built off to the side (vectorized
        gather of every arc -> lexsort -> one scatter), its device upload is
        dispatched, and only then is the live state swapped. ``ell()`` views
        handed out earlier keep the old buffers; the next ``ell()`` call
        returns the new ones without a full re-upload on the query path.
        """
        with obs.span(
            "graph.compact", overflow_arcs=self.overflow_arcs
        ) as sp:
            deg = self.degrees()
            max_deg = int(deg.max()) if deg.size else 0
            width = max(int(np.ceil(max_deg * self.slack)), min_width, 1)
            nbr = np.full(
                (self.node_cap + 1, width), self.node_cap, np.int32
            )
            n = self.n_nodes
            # gather all arcs: in-table rows (row-major mask flatten) +
            # overflow
            rows, dsts = self.arc_arrays()
            order = np.lexsort((dsts, rows))  # sorted rows, like Graph CSR
            rows, dsts = rows[order], dsts[order]
            uniq, start, counts = np.unique(
                rows, return_index=True, return_counts=True
            )
            slot = np.arange(len(rows)) - np.repeat(start, counts)
            nbr[rows, slot] = dsts
            new_deg = np.zeros(self.node_cap + 1, np.int32)
            new_deg[:n] = deg
            # dispatch the device upload of the side buffer *before* the swap
            dev_nbr, dev_deg = self._upload_mirror(nbr, new_deg)
            self._nbr, self._deg, self.width = nbr, new_deg, width
            self._dev_nbr, self._dev_deg = dev_nbr, dev_deg
            self._overflow.clear()
            self._pending.clear()
            self._dirty_full = False
            self.compactions += 1
            self.edges_since_compact = 0
            sp.set(width=width)
            metrics().counter("graph_compactions_total").inc()

    # --------------------------------------------------------- device mirror

    def _upload_mirror(
        self, nbr: np.ndarray, deg: np.ndarray
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Upload a full host mirror, row-sharded under a ShardPlan.

        Rows are padded to the plan's shard multiple with sentinel rows
        (neighbours = ``node_cap``, degree 0) so every shard owns an equal
        chunk; consumers keep addressing ids ``<= node_cap`` and never see
        the padding.
        """
        if self.plan is None:
            return jnp.asarray(nbr), jnp.asarray(deg)
        rows = self.plan.pad_rows(self.node_cap + 1)
        pad = rows - (self.node_cap + 1)
        if pad:
            nbr = np.concatenate(
                [nbr, np.full((pad, nbr.shape[1]), self.node_cap, np.int32)]
            )
            deg = np.concatenate([deg, np.zeros(pad, np.int32)])
        return self.plan.place_rows(nbr), self.plan.place_rows(deg)

    # ------------------------------------------------------------ snapshots

    def snapshot(self) -> Graph:
        """Immutable host CSR of the current graph (sorted rows, both arcs).

        One vectorized arc gather — the oracle/re-peel paths call this, so a
        per-node Python loop here would dominate their cost.
        """
        rows, dsts = self.arc_arrays()
        edges = np.stack([rows, dsts], axis=1)
        return Graph.from_edges(self.n_nodes, edges, undirected=False)

    def state_dict(self) -> Dict[str, np.ndarray]:
        """Full mutable state as host arrays (for snapshots / rollback).

        Overflow lists are flattened to ``(keys, counts, values)`` so the
        whole dict round-trips through ``np.savez`` losslessly; the device
        mirror is deliberately excluded (it is derived state and rebuilt
        lazily on the first ``ell()`` after :meth:`from_state`).
        """
        ov_keys = np.asarray(sorted(self._overflow), np.int64)
        ov_counts = np.asarray(
            [len(self._overflow[int(k)]) for k in ov_keys], np.int64
        )
        ov_vals = (
            np.concatenate(
                [np.asarray(self._overflow[int(k)], np.int64) for k in ov_keys]
            )
            if len(ov_keys) else np.zeros(0, np.int64)
        )
        return {
            "nbr": self._nbr.copy(),
            "deg": self._deg.copy(),
            "ov_keys": ov_keys,
            "ov_counts": ov_counts,
            "ov_vals": ov_vals,
            "n_nodes": np.int64(self.n_nodes),
            "node_cap": np.int64(self.node_cap),
            "width": np.int64(self.width),
            "n_edges": np.int64(self.n_edges),
            "compactions": np.int64(self.compactions),
            "edges_since_compact": np.int64(self.edges_since_compact),
            "slack": np.float64(self.slack),
            "node_slack": np.float64(self.node_slack),
        }

    @classmethod
    def from_state(cls, state, *, plan=None) -> "DynamicGraph":
        """Rebuild a graph bit-identical to the one that produced ``state``."""
        g = cls(
            0,
            width=int(state["width"]),
            slack=float(state["slack"]),
            node_slack=float(state["node_slack"]),
            plan=plan,
        )
        g.n_nodes = int(state["n_nodes"])
        g.node_cap = int(state["node_cap"])
        g._nbr = np.array(state["nbr"], np.int32)
        g._deg = np.array(state["deg"], np.int32)
        g._overflow = {}
        off = 0
        vals = np.asarray(state["ov_vals"], np.int64)
        for k, c in zip(np.asarray(state["ov_keys"], np.int64),
                        np.asarray(state["ov_counts"], np.int64)):
            g._overflow[int(k)] = [int(x) for x in vals[off : off + int(c)]]
            off += int(c)
        g.n_edges = int(state["n_edges"])
        g.compactions = int(state["compactions"])
        g.edges_since_compact = int(state["edges_since_compact"])
        g._dev_nbr = g._dev_deg = None
        g._pending = []
        g._dirty_full = True
        return g

    def ell(self) -> EllGraph:
        """Device ELL view (overflow arcs excluded until the next compact).

        Pending slot writes since the last call are applied as one batched
        scatter; node growth triggers a full re-upload, compaction never does
        (the compactor pre-uploads its double buffer). Under a ShardPlan the
        view's arrays carry extra sentinel rows past ``node_cap`` (the shard
        padding) — consumers must use ``node_cap`` as the sentinel id, not
        ``neighbours.shape[0] - 1``.
        """
        if self._dirty_full or self._dev_nbr is None:
            self._dev_nbr, self._dev_deg = self._upload_mirror(
                self._nbr, self._deg
            )
            self._dirty_full = False
            self._pending.clear()
        elif self._pending:
            upd = np.asarray(self._pending, np.int32)
            # a slot can be written more than once between ell() calls
            # (removal swap then re-insert); a single scatter with duplicate
            # indices is order-unspecified, so keep only the last write per
            # (row, slot)
            key = upd[:, 0].astype(np.int64) * (self.width + 1) + upd[:, 1]
            _, last_idx = np.unique(key[::-1], return_index=True)
            upd = upd[::-1][last_idx]
            # pad to a power-of-two count by repeating the first write (an
            # idempotent duplicate) so eager scatter compiles O(log) shapes
            n_pad = pow2(len(upd))
            upd = np.concatenate([upd, np.repeat(upd[:1], n_pad - len(upd), 0)])
            rows, slots, vals = upd[:, 0], upd[:, 1], upd[:, 2]
            if self.plan is None:
                self._dev_nbr = self._dev_nbr.at[rows, slots].set(vals)
                # degrees: scatter only the touched rows (duplicates
                # idempotent — every write carries the row's final
                # host-side degree)
                self._dev_deg = self._dev_deg.at[rows].set(self._deg[rows])
            else:  # same scatter, keeping both mirrors row-sharded
                self._dev_nbr = self.plan.set_cells_fn(
                    self._dev_nbr, jnp.asarray(rows), jnp.asarray(slots),
                    jnp.asarray(vals),
                )
                self._dev_deg = self.plan.set_rows1_fn(
                    self._dev_deg, jnp.asarray(rows),
                    jnp.asarray(self._deg[rows]),
                )
            self._pending.clear()
        return EllGraph(
            n_nodes=self.node_cap, neighbours=self._dev_nbr, degrees=self._dev_deg
        )

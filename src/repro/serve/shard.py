"""Row-sharding plan for the serving stack's node-indexed device state.

The paper's offline side already row-shards its embedding tables for the
web-scale configs (the ``deepwalk-web1b`` recipe: 2D tables split over the
``data`` mesh axis). ``ShardPlan`` brings the same placement to the online
stack: every *node-indexed* device array — the ``EmbeddingStore`` table, the
``DynamicGraph`` ELL mirror, and the candidate matrices of the fused h-index
descent — is laid out row-sharded over a 1D ``data`` mesh.

Sharding here is strictly a **placement** concern, never a semantics one:
the host-side state machines (slot assignment, LRU clocks, spill dicts,
core-repair control flow) are byte-identical across shard counts, and the
device programs are the same integer/float math partitioned by GSPMD. That
is what the multi-device parity suite (``tests/multidevice/``) proves:
``--shards N`` equals ``--shards 1`` bit-for-bit on every serve operation —
embeddings, core numbers, staleness, eviction counts.

A disabled plan (``n_shards == 1``) is inert: callers skip every plan hook
and run today's exact single-device code path.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import make_mesh
from repro.obs import metrics

__all__ = ["ShardPlan"]


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """Row-sharding of the node axis across a 1D mesh.

    Rows ``[0, n_rows)`` are split into ``n_shards`` contiguous chunks;
    shard ``s`` owns rows ``[s * chunk, (s + 1) * chunk)`` where
    ``chunk = n_rows / n_shards`` (callers pad row counts with
    ``pad_rows`` so the split is exact).
    """

    n_shards: int = 1
    axis: str = "data"
    mesh: Optional[Mesh] = None

    @staticmethod
    def build(n_shards: int = 1, axis: str = "data") -> "ShardPlan":
        """Build a plan over ``n_shards`` devices (1 = disabled, no mesh).

        Shard counts must be powers of two: the serve stack pads its row
        dimensions to powers of two (``pow2``), and a non-power-of-two split
        would force uneven shards XLA cannot place.

        Plans are cached per ``(n_shards, axis)``: every store/graph built
        for the same shard count shares one mesh and one compilation of
        each jit program below.
        """
        return _build_cached(int(n_shards), axis)

    # ----------------------------------------------------------- predicates

    @property
    def enabled(self) -> bool:
        return self.n_shards > 1 and self.mesh is not None

    # ------------------------------------------------------------ placement

    def pad_rows(self, n_rows: int) -> int:
        """Smallest row count >= ``n_rows`` divisible by ``n_shards``."""
        if not self.enabled:
            return int(n_rows)
        return -(-int(n_rows) // self.n_shards) * self.n_shards

    def row_sharding(self, ndim: int = 1) -> NamedSharding:
        """NamedSharding splitting axis 0, replicating the rest."""
        return NamedSharding(
            self.mesh, P(self.axis, *([None] * (max(ndim, 1) - 1)))
        )

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    def place_rows(self, x) -> jnp.ndarray:
        """Upload ``x`` with axis 0 split across the mesh.

        Falls back to replicated placement when axis 0 does not divide (the
        caller forgot ``pad_rows``) — placement must never change results,
        but the memory win silently disappears, so the fallback warns.
        """
        x = jnp.asarray(x)
        if x.shape[0] % self.n_shards:
            warnings.warn(
                f"ShardPlan.place_rows: axis 0 ({x.shape[0]} rows) is not "
                f"divisible by n_shards={self.n_shards}; replicating instead "
                "of sharding (pad the row count with plan.pad_rows first)",
                stacklevel=2,
            )
            metrics().counter("shard_replicated_fallbacks_total").inc()
            return jax.device_put(x, self.replicated())
        metrics().counter("shard_row_placements_total").inc()
        return jax.device_put(x, self.row_sharding(x.ndim))

    def replicate(self, x) -> jnp.ndarray:
        return jax.device_put(jnp.asarray(x), self.replicated())

    # ----------------------------------------------------------- accounting

    def shard_of_rows(self, rows, n_rows: int) -> np.ndarray:
        """Owning shard of each row id under a ``n_rows``-row layout."""
        rows = np.asarray(rows, np.int64)
        if not self.enabled:
            return np.zeros(rows.shape, np.int64)
        chunk = max(self.pad_rows(n_rows) // self.n_shards, 1)
        return np.minimum(rows // chunk, self.n_shards - 1)

    def balance_of(self, rows, n_rows: int) -> np.ndarray:
        """(n_shards,) count of ``rows`` owned by each shard."""
        return np.bincount(
            self.shard_of_rows(rows, n_rows), minlength=max(self.n_shards, 1)
        )

    # ------------------------------------------------------- jit programs
    # cached per plan (not per store/graph instance) so twin stacks and
    # benchmark services share one XLA compilation of each program

    @functools.cached_property
    def gather_rows_fn(self):
        """jit: (row-sharded table, row ids) -> replicated gathered rows."""
        return jax.jit(lambda t, s: t[s], out_shardings=self.replicated())

    @functools.cached_property
    def set_rows_fn(self):
        """jit: scatter whole rows into a row-sharded rank-2 table."""
        return jax.jit(
            lambda t, s, v: t.at[s].set(v),
            out_shardings=self.row_sharding(2),
        )

    @functools.cached_property
    def set_cells_fn(self):
        """jit: scatter (row, col) cells into a row-sharded rank-2 table."""
        return jax.jit(
            lambda t, r, s, v: t.at[r, s].set(v),
            out_shardings=self.row_sharding(2),
        )

    @functools.cached_property
    def set_rows1_fn(self):
        """jit: scatter entries into a row-sharded rank-1 array."""
        return jax.jit(
            lambda t, r, v: t.at[r].set(v),
            out_shardings=self.row_sharding(1),
        )

    @functools.cached_property
    def partial_topk_fn(self):
        """jit: shard-local partial top-k over a row-sharded score table.

        ``(q (Q, D) replicated, table (rows, D) row-sharded, bias (rows,)
        additive validity mask) -> ((S, Q, kl) values, (S, Q, kl) global
        row indices)``, kl = min(k, rows per shard). Each shard scores the
        queries against only its own row chunk and reduces its local top-k
        under the (score desc, index asc) total order — the (Q, rows)
        score matrix never crosses shards; only the (S, Q, kl) candidate
        lists do, and :meth:`merge_topk` stitches them on the host. Any
        global top-k row is necessarily in its owner's local top-k, so the
        stitch is exact.
        """

        def fn(q, table, bias, k):
            S = self.n_shards
            chunk = table.shape[0] // S
            kl = min(int(k), chunk)
            tb = table.reshape(S, chunk, table.shape[1])
            bb = bias.reshape(S, chunk)
            off = jnp.arange(S, dtype=jnp.int32) * chunk

            def one(t, b, o):
                scores = jnp.einsum(
                    "qd,nd->qn", q.astype(jnp.float32),
                    t.astype(jnp.float32),
                ) + b[None, :]
                idx = jnp.broadcast_to(
                    jnp.arange(chunk, dtype=jnp.int32)[None, :], scores.shape
                )
                neg, sidx = jax.lax.sort(
                    (-scores, idx), dimension=1, num_keys=2
                )
                vals = -neg[:, :kl]
                gidx = jnp.where(vals > -jnp.inf, sidx[:, :kl] + o, -1)
                return vals, gidx

            return jax.vmap(one)(tb, bb, off)

        return jax.jit(
            fn, static_argnames="k",
            out_shardings=(self.replicated(), self.replicated()),
        )

    @staticmethod
    def merge_topk(vals, idx, k: int):
        """Host-side stitch of per-shard partial top-k candidate lists.

        vals, idx: (S, Q, kl) shard-local candidates (global row indices,
        -inf/-1 padded) -> ``((Q, k) float32, (Q, k) int64)`` under the
        global (score desc, index asc) order, -inf/-1 padded when fewer
        than k live candidates exist in total.
        """
        vals = np.asarray(vals, np.float32)
        idx = np.asarray(idx, np.int64)
        S, Q, kl = vals.shape
        v = np.swapaxes(vals, 0, 1).reshape(Q, S * kl)
        i = np.swapaxes(idx, 0, 1).reshape(Q, S * kl)
        ikey = np.where(i < 0, np.iinfo(np.int64).max, i)
        order = np.lexsort((ikey, -v), axis=-1)
        kk = min(k, S * kl)
        out_v = np.full((Q, k), -np.inf, np.float32)
        out_i = np.full((Q, k), -1, np.int64)
        out_v[:, :kk] = np.take_along_axis(v, order, 1)[:, :kk]
        out_i[:, :kk] = np.take_along_axis(i, order, 1)[:, :kk]
        out_i[~np.isfinite(out_v)] = -1
        return out_v, out_i


@functools.lru_cache(maxsize=None)
def _build_cached(n_shards: int, axis: str) -> ShardPlan:
    if n_shards <= 1:
        return ShardPlan()
    if n_shards & (n_shards - 1):
        raise ValueError(f"n_shards must be a power of two, got {n_shards}")
    avail = jax.device_count()
    if avail < n_shards:
        raise ValueError(
            f"ShardPlan needs {n_shards} devices but only {avail} are "
            "visible; on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n_shards}"
        )
    return ShardPlan(n_shards, axis, make_mesh((n_shards,), (axis,)))

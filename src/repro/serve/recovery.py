"""Crash safety for the serving stack: WAL + atomic snapshots + recovery.

Durability model, two layers:

* **Write-ahead edge log** (:class:`WriteAheadLog`) — every
  ``ingest_block`` / ``retract_block`` appends one checksummed record
  (*before* any mutation) and fsyncs it. A record is ``<IBQI`` header
  (magic, kind, sequence number, edge count) + ``n×2`` int64 edge pairs +
  a CRC32 trailer over header+payload. On open, the log scans itself and
  truncates a torn tail (short record, bad magic/CRC, non-monotonic seq) —
  a crash mid-append loses at most the record being written, never earlier
  ones.
* **Atomic snapshots** (:class:`SnapshotStore`) — the full serving state
  (adjacency + overflow side tables, exact core numbers + retrain
  baseline, store table/versions/spill/LRU, service counters, WAL offset)
  written with the same tmp-dir → fsync → ``_COMMITTED`` → rename
  protocol as ``distributed/checkpoint.py``. Readers skip torn directories
  (missing ``_COMMITTED``, unparseable manifest, payload CRC mismatch)
  even when they are the newest.

**Recovery = newest committed snapshot + WAL tail replay.** Replay drives
the edges back through the service's own ``ingest_block``/``retract_block``
(with WAL logging suppressed), so the recovered state is *bit-identical*
to a process that never crashed: same adjacency bytes, same core numbers,
same store table/slot assignment/version counters. Snapshots call
``service.sync()`` first — that lands the pipelined repair tail at a block
boundary where it would have landed anyway, so snapshot cadence never
perturbs the stream's final state.

:class:`RecoveryManager` wires both layers into a live service: logging
before every mutation, snapshotting on a block-count (and optional
wall-clock) cadence with the serialization + fsync handed to a background
writer thread so ingest does not pause, and a :meth:`RecoveryManager.recover`
classmethod that restores a service from the directory.
"""
from __future__ import annotations

import io
import json
import os
import shutil
import struct
import threading
import time
import zlib
from typing import Callable, Dict, Iterator, Optional, Tuple

import numpy as np

from repro.obs import metrics
from repro.obs import trace as obs

from . import faults

__all__ = [
    "WriteAheadLog",
    "SnapshotStore",
    "RecoveryManager",
    "capture_state",
    "restore_service",
]

_MAGIC = 0x57414C31  # "WAL1"
_HEADER = struct.Struct("<IBQI")  # magic, kind, seq, n_edges
_CRC = struct.Struct("<I")

KIND_INGEST = 1
KIND_RETRACT = 2


class WriteAheadLog:
    """Append-only checksummed edge log with torn-tail detection.

    ``fsync=False`` trades durability for speed in tests; the torn-tail
    scan still runs on open either way.
    """

    def __init__(self, path: str, *, fsync: bool = True):
        self.path = path
        self.fsync = bool(fsync)
        self.torn_truncated = 0  # bytes dropped from a torn tail on open
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        self.seq = 0  # last durable sequence number
        end = self._scan()
        self._f = open(path, "r+b" if os.path.exists(path) else "w+b")
        self._f.seek(0, os.SEEK_END)
        if self._f.tell() != end:  # torn tail: drop it before appending
            self.torn_truncated = self._f.tell() - end
            self._f.truncate(end)
            self._f.seek(end)

    def _scan(self) -> int:
        """Validate existing records; returns the clean end offset and
        leaves ``self.seq`` at the last valid record's sequence number."""
        if not os.path.exists(self.path):
            return 0
        end = 0
        with open(self.path, "rb") as f:
            while True:
                head = f.read(_HEADER.size)
                if len(head) < _HEADER.size:
                    break
                magic, kind, seq, n = _HEADER.unpack(head)
                if magic != _MAGIC or kind not in (KIND_INGEST, KIND_RETRACT):
                    break
                payload = f.read(16 * n)
                trailer = f.read(_CRC.size)
                if len(payload) < 16 * n or len(trailer) < _CRC.size:
                    break
                if _CRC.unpack(trailer)[0] != zlib.crc32(head + payload):
                    break
                if seq != self.seq + 1:  # non-monotonic: corrupt tail
                    break
                self.seq = seq
                end = f.tell()
        return end

    def append(self, kind: int, edges: np.ndarray) -> int:
        """Durably log one block; returns its sequence number.

        Injection points: ``wal_append`` fires *mid-record* (half the bytes
        reach the file — a real torn tail the next open must truncate);
        ``wal_fsync`` fires after the write but before the fsync (the
        record is cleanly lost, as an OS crash before writeback would)."""
        edges = np.ascontiguousarray(np.asarray(edges, np.int64).reshape(-1, 2))
        seq = self.seq + 1
        head = _HEADER.pack(_MAGIC, kind, seq, len(edges))
        payload = edges.tobytes()
        buf = head + payload + _CRC.pack(zlib.crc32(head + payload))
        start = self._f.tell()
        try:
            faults.check("wal_append")
        except BaseException:
            self._f.write(buf[: max(len(buf) // 2, 1)])
            self._f.flush()
            os.fsync(self._f.fileno())
            raise
        self._f.write(buf)
        try:
            faults.check("wal_fsync")
        except BaseException:
            self._f.flush()
            self._f.truncate(start)
            self._f.seek(start)
            raise
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())
        self.seq = seq
        return seq

    def records(
        self, after_seq: int = 0
    ) -> Iterator[Tuple[int, int, np.ndarray]]:
        """Yield ``(seq, kind, edges)`` for every valid record past
        ``after_seq``, stopping silently at a torn tail."""
        with open(self.path, "rb") as f:
            last = 0
            while True:
                head = f.read(_HEADER.size)
                if len(head) < _HEADER.size:
                    return
                magic, kind, seq, n = _HEADER.unpack(head)
                if magic != _MAGIC or kind not in (KIND_INGEST, KIND_RETRACT):
                    return
                payload = f.read(16 * n)
                trailer = f.read(_CRC.size)
                if len(payload) < 16 * n or len(trailer) < _CRC.size:
                    return
                if _CRC.unpack(trailer)[0] != zlib.crc32(head + payload):
                    return
                if seq != last + 1:
                    return
                last = seq
                if seq > after_seq:
                    yield seq, kind, np.frombuffer(
                        payload, np.int64
                    ).reshape(-1, 2).copy()

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


class SnapshotStore:
    """Atomic snapshot directory: ``snap_<wal_seq>`` children, each
    committed via tmp-dir → fsync → ``_COMMITTED`` → rename."""

    def __init__(self, directory: str, *, keep: int = 2):
        self.directory = directory
        self.keep = max(int(keep), 1)
        os.makedirs(directory, exist_ok=True)

    def _path(self, wal_seq: int) -> str:
        return os.path.join(self.directory, f"snap_{wal_seq:012d}")

    def write(self, arrays: Dict[str, np.ndarray], manifest: dict) -> str:
        """Commit one snapshot; ``manifest['wal_seq']`` names the directory.

        Injection points: ``snapshot_write`` fires after the payload lands
        but before the manifest/``_COMMITTED`` (a torn dir recovery must
        skip); ``snapshot_commit`` fires after ``_COMMITTED`` but before
        the rename (the tmp dir is simply garbage — never visible)."""
        final = self._path(int(manifest["wal_seq"]))
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        buf = io.BytesIO()
        np.savez(buf, **arrays)
        payload = buf.getvalue()
        manifest = dict(manifest, npz_crc=zlib.crc32(payload))
        with open(os.path.join(tmp, "state.npz"), "wb") as f:
            f.write(payload)
            f.flush()
            os.fsync(f.fileno())
        faults.check("snapshot_write")
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
            f.write("ok")
            f.flush()
            os.fsync(f.fileno())
        faults.check("snapshot_commit")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        dir_fd = os.open(self.directory, os.O_RDONLY)
        try:
            os.fsync(dir_fd)
        finally:
            os.close(dir_fd)
        self._gc()
        return final

    def _gc(self) -> None:
        names = sorted(
            d for d in os.listdir(self.directory)
            if d.startswith("snap_") and not d.endswith(".tmp")
        )
        for d in names[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, d), ignore_errors=True)

    def _load(self, path: str) -> Tuple[Dict[str, np.ndarray], dict]:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with open(os.path.join(path, "state.npz"), "rb") as f:
            payload = f.read()
        if zlib.crc32(payload) != manifest.get("npz_crc"):
            raise ValueError(f"snapshot payload CRC mismatch in {path}")
        with np.load(io.BytesIO(payload)) as z:
            arrays = {k: z[k] for k in z.files}
        return arrays, manifest

    def load_latest(
        self,
    ) -> Tuple[Optional[Dict[str, np.ndarray]], Optional[dict], int]:
        """Newest loadable snapshot -> ``(arrays, manifest, n_skipped)``.

        Torn directories — mid-write crash left no ``_COMMITTED``, or the
        manifest/payload fails to parse/verify — are skipped even when
        newest. ``(None, None, skipped)`` when nothing is loadable."""
        names = sorted(
            (d for d in os.listdir(self.directory)
             if d.startswith("snap_") and not d.endswith(".tmp")),
            reverse=True,
        )
        skipped = 0
        for d in names:
            path = os.path.join(self.directory, d)
            if not os.path.exists(os.path.join(path, "_COMMITTED")):
                skipped += 1
                continue
            try:
                arrays, manifest = self._load(path)
            except Exception:
                skipped += 1
                continue
            return arrays, manifest, skipped
        return None, None, skipped


# --------------------------------------------------------------- state I/O

_STATS_FIELDS = (
    "queries", "store_hits", "cold_starts", "unresolved", "flushes",
    "edges_ingested", "edges_removed", "ingest_blocks", "compactions",
    "retrains", "last_swap_version", "degraded_queries",
    "retrain_failures", "hangs",
)


def capture_state(svc, wal_seq: int) -> Tuple[Dict[str, np.ndarray], dict]:
    """Live service -> ``(arrays, manifest)`` for :class:`SnapshotStore`.

    Calls ``svc.sync()`` first: the pipelined repair tail lands at this
    block boundary exactly as it would at the next block's start, so the
    capture point never changes the stream's final state.
    """
    svc.sync()
    arrays: Dict[str, np.ndarray] = {}
    for k, v in svc.graph.state_dict().items():
        arrays[f"g.{k}"] = v
    for k, v in svc.store.state_dict().items():
        arrays[f"s.{k}"] = v
    arrays["core"] = svc.cores._core.copy()
    arrays["baseline"] = svc.cores._baseline.copy()
    cores = svc.cores
    pol = cores.policy
    st = svc.stats
    manifest = {
        "wal_seq": int(wal_seq),
        "service": {
            "batch": svc.batch,
            "write_back": bool(svc.write_back),
            "compact_every": svc.compact_every,
            "k0": None if svc.k0 is None else int(svc.k0),
            "retrain_threshold": svc.retrain_threshold,
            "impl": svc.impl,
            "pipeline": bool(svc.pipeline),
        },
        "cores": {
            "repeel_frac": cores.repeel_frac,
            "margin0": cores.margin0,
            "impl": cores.impl,
            "region_impl": cores.region_impl,
            "kernel_impl": cores.kernel_impl,
            "repeel_impl": cores.repeel_impl,
            "descend_budget": cores.descend_budget,
            "max_sweeps": cores.max_sweeps,
            "repair_policy": pol.mode,
            "crossover_margin": pol.crossover_margin,
            "cold_cells_per_arc": pol.cold_cells_per_arc,
        },
        "stats": {k: int(getattr(st, k)) for k in _STATS_FIELDS},
    }
    return arrays, manifest


def restore_service(
    arrays: Dict[str, np.ndarray], manifest: dict, *, plan=None
):
    """Snapshot payload -> a fresh ``EmbeddingService``, bit-identical to
    the one :func:`capture_state` saw."""
    from .kcore_inc import IncrementalCore
    from .service import EmbeddingService
    from .store import EmbeddingStore
    from .stream import DynamicGraph

    g_state = {k[2:]: v for k, v in arrays.items() if k.startswith("g.")}
    s_state = {k[2:]: v for k, v in arrays.items() if k.startswith("s.")}
    graph = DynamicGraph.from_state(g_state, plan=plan)
    store = EmbeddingStore.from_state(s_state, plan=plan)
    ccfg = manifest["cores"]
    cores = IncrementalCore(
        graph,
        np.asarray(arrays["core"], np.int32),
        repeel_frac=ccfg["repeel_frac"],
        margin0=ccfg["margin0"],
        impl=ccfg["impl"],
        region_impl=ccfg["region_impl"],
        kernel_impl=ccfg["kernel_impl"],
        repeel_impl=ccfg["repeel_impl"],
        descend_budget=ccfg["descend_budget"],
        max_sweeps=ccfg["max_sweeps"],
        repair_policy=ccfg["repair_policy"],
        crossover_margin=ccfg["crossover_margin"],
        cold_cells_per_arc=ccfg["cold_cells_per_arc"],
    )
    cores._baseline = np.asarray(arrays["baseline"], np.int32).copy()
    scfg = manifest["service"]
    svc = EmbeddingService(
        graph, cores, store,
        batch=scfg["batch"],
        write_back=scfg["write_back"],
        compact_every=scfg["compact_every"],
        k0=scfg["k0"],
        retrain_threshold=scfg["retrain_threshold"],
        impl=scfg["impl"],
        pipeline=scfg["pipeline"],
    )
    for k, v in manifest.get("stats", {}).items():
        if hasattr(svc.stats, k):
            setattr(svc.stats, k, int(v))
    return svc


# ---------------------------------------------------------------- manager


class RecoveryManager:
    """Attach WAL + snapshot cadence to a live service.

    ``snapshot_every`` blocks (and optionally every ``snapshot_secs``
    seconds of wall clock) the full state is captured on the ingest thread
    (host copies — cheap) and committed by a background writer thread, so
    ingest never pauses for the fsyncs. ``bootstrap=True`` writes snapshot
    0 immediately so recovery always has a base to replay from.
    """

    def __init__(
        self,
        service,
        directory: str,
        *,
        snapshot_every: int = 64,
        snapshot_secs: float = 0.0,
        keep: int = 2,
        fsync: bool = True,
        bootstrap: bool = True,
    ):
        self.service = service
        self.directory = directory
        self.snapshot_every = max(int(snapshot_every), 1)
        self.snapshot_secs = float(snapshot_secs)
        os.makedirs(directory, exist_ok=True)
        self.wal = WriteAheadLog(
            os.path.join(directory, "wal.log"), fsync=fsync
        )
        self.snapshots = SnapshotStore(
            os.path.join(directory, "snapshots"), keep=keep
        )
        self.snapshots_written = 0
        self._blocks_since_snap = 0
        self._last_snap_t = time.monotonic()
        self._replaying = False
        self._writer: Optional[threading.Thread] = None
        self._writer_error: Optional[BaseException] = None
        service.attach_recovery(self)
        if bootstrap:
            self.snapshot(blocking=True)

    # -- called by the service ------------------------------------------

    def log_block(self, kind: int, edges: np.ndarray) -> None:
        """Durably log one block *before* the service mutates anything."""
        if self._replaying:
            return
        with obs.span("recovery.wal_append", edges=len(edges)):
            self.wal.append(kind, edges)
        metrics().counter("recovery_wal_records_total").inc()
        self._blocks_since_snap += 1

    def after_block(self) -> None:
        """Snapshot-cadence check; runs after a block fully lands."""
        if self._replaying:
            return
        self._raise_writer_error()
        due = self._blocks_since_snap >= self.snapshot_every
        if not due and self.snapshot_secs > 0:
            due = time.monotonic() - self._last_snap_t >= self.snapshot_secs
        if due:
            self.snapshot(blocking=False)

    # -- snapshots ------------------------------------------------------

    def _raise_writer_error(self) -> None:
        err, self._writer_error = self._writer_error, None
        if err is not None:
            raise err

    def wait(self) -> None:
        """Join any in-flight snapshot write (re-raising its error)."""
        if self._writer is not None:
            self._writer.join()
            self._writer = None
        self._raise_writer_error()

    def snapshot(self, *, blocking: bool = True) -> None:
        """Capture now; commit inline (``blocking``) or on the writer
        thread. Capture itself always runs on the caller's thread — it
        reads mutable host state that must not race the next block."""
        self.wait()
        t0 = time.perf_counter()
        arrays, manifest = capture_state(self.service, self.wal.seq)
        self._blocks_since_snap = 0
        self._last_snap_t = time.monotonic()

        def commit():
            with obs.span("recovery.snapshot", wal_seq=manifest["wal_seq"]):
                self.snapshots.write(arrays, manifest)
            self.snapshots_written += 1
            metrics().counter("recovery_snapshots_total").inc()
            metrics().histogram("recovery_snapshot_seconds").observe(
                time.perf_counter() - t0
            )

        if blocking:
            commit()
            return

        def worker():
            try:
                commit()
            except BaseException as e:  # surfaced on the ingest thread
                self._writer_error = e

        self._writer = threading.Thread(
            target=worker, name="snapshot-writer", daemon=True
        )
        self._writer.start()

    def close(self) -> None:
        self.wait()
        self.wal.close()

    # -- recovery -------------------------------------------------------

    @classmethod
    def recover(
        cls,
        directory: str,
        *,
        plan=None,
        configure: Optional[Callable] = None,
        snapshot_every: int = 64,
        snapshot_secs: float = 0.0,
        keep: int = 2,
        fsync: bool = True,
    ):
        """Restore from ``directory`` -> ``(service, manager, report)``.

        ``configure(service)`` runs after the snapshot restore but *before*
        the WAL replay — reattach a Retrainer there so auto-retrains that
        fired during the original stream re-fire identically during replay.
        """
        t0 = time.perf_counter()
        snaps = SnapshotStore(os.path.join(directory, "snapshots"), keep=keep)
        arrays, manifest, skipped = snaps.load_latest()
        if arrays is None:
            raise FileNotFoundError(
                f"no committed snapshot under {directory!r} "
                f"({skipped} torn directories skipped)"
            )
        with obs.span("recovery.restore", wal_seq=manifest["wal_seq"]):
            svc = restore_service(arrays, manifest, plan=plan)
        if configure is not None:
            configure(svc)
        mgr = cls(
            svc, directory, snapshot_every=snapshot_every,
            snapshot_secs=snapshot_secs, keep=keep, fsync=fsync,
            bootstrap=False,
        )
        snap_seq = int(manifest["wal_seq"])
        replayed = replayed_edges = 0
        mgr._replaying = True
        try:
            with obs.span("recovery.replay", after_seq=snap_seq) as sp:
                for _, kind, edges in mgr.wal.records(after_seq=snap_seq):
                    if kind == KIND_INGEST:
                        svc.ingest_block(edges)
                    else:
                        svc.retract_block(edges)
                    replayed += 1
                    replayed_edges += len(edges)
                svc.sync()
                sp.set(records=replayed, edges=replayed_edges)
        finally:
            mgr._replaying = False
        metrics().counter("serve_recoveries_total").inc()
        metrics().counter("recovery_replayed_edges_total").inc(replayed_edges)
        report = {
            "snapshot_wal_seq": snap_seq,
            "wal_seq": int(mgr.wal.seq),
            "replayed_records": int(replayed),
            "replayed_edges": int(replayed_edges),
            "torn_wal_bytes": int(mgr.wal.torn_truncated),
            "snapshots_skipped": int(skipped),
            "recovery_seconds": float(time.perf_counter() - t0),
        }
        return svc, mgr, report

"""Deterministic fault injection for the serving stack.

A :class:`FaultPlan` names *injection points* — fixed call sites threaded
through the serve stack (``faults.check("wal_append")`` etc.) — and for
each point says on which hit to fire and what to raise. Plans are parsed
from a compact spec string so the launcher and benchmark can drive
crash-point sweeps from the command line::

    wal_append:1:crash          # crash on the 1st wal_append hit
    device_dispatch:3+          # fault on every hit from the 3rd on
    retrain_swap_chunk:2:fault  # fault on the 2nd swap chunk only

Two distinct failure semantics:

* :class:`InjectedFault` (a ``RuntimeError``) models a *recoverable*
  failure — a device dispatch error, a flaky IO call. Degradation paths
  (retry loops, ref fallback, transactional retrain) are expected to
  catch it.
* :class:`InjectedCrash` (a ``BaseException``) models *process death*.
  No ``except Exception`` handler may swallow it; the harness catches it
  at top level and recovers from durable state (snapshot + WAL), exactly
  as a restarted process would.

When no plan is installed, :func:`check` is a near-no-op (one global
load + ``is None`` test), so production paths pay nothing.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional


class InjectedFault(RuntimeError):
    """Recoverable injected failure (device error, IO error, ...)."""


class InjectedCrash(BaseException):
    """Simulated process death. Deliberately NOT an ``Exception`` so no
    recovery/degradation handler can swallow it — only the top-level
    harness (standing in for a process restart) catches it."""


#: every injection point threaded through the stack, for --help text and
#: sweep enumeration. Keep in sync with the ``check()`` call sites.
POINTS = (
    "wal_append",        # mid-WAL-append: half the record hits disk
    "wal_fsync",         # after write, before fsync: record lost cleanly
    "snapshot_write",    # after state.npz, before manifest/_COMMITTED
    "snapshot_commit",   # after _COMMITTED, before tmp-dir rename
    "ingest_apply",      # after the WAL append, before graph mutation
    "device_dispatch",   # inside the fused descent dispatch
    "repair",            # top of IncrementalCore.begin_update
    "spill_io",          # store spill tier IO (evict / promote)
    "flush_dispatch",    # the cold-start gather dispatch in _flush_batch
    "retrain_plan",
    "retrain_walks",
    "retrain_train",
    "retrain_align",
    "retrain_propagate",
    "retrain_swap",
    "retrain_swap_chunk",  # mid-commit: the mixed-version window
)


@dataclass
class _Rule:
    hit: int            # fire on the Nth hit (1-based)
    sticky: bool        # "N+": keep firing on every hit >= N
    crash: bool         # raise InjectedCrash instead of InjectedFault


@dataclass
class FaultPlan:
    """Seeded, deterministic fault schedule over named injection points."""

    rules: Dict[str, _Rule] = field(default_factory=dict)
    counts: Dict[str, int] = field(default_factory=dict)
    fired: Dict[str, int] = field(default_factory=dict)

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """``"point:hit[:mode],..."`` -> plan. hit = ``N`` or ``N+``;
        mode in {fault, crash} (default fault)."""
        plan = cls()
        for part in filter(None, (p.strip() for p in spec.split(","))):
            bits = part.split(":")
            if len(bits) not in (2, 3):
                raise ValueError(
                    f"bad fault spec {part!r}: want point:hit[:mode]"
                )
            point, hit = bits[0], bits[1]
            mode = bits[2] if len(bits) == 3 else "fault"
            if point not in POINTS:
                raise ValueError(
                    f"unknown fault point {point!r}; known: {', '.join(POINTS)}"
                )
            if mode not in ("fault", "crash"):
                raise ValueError(f"bad fault mode {mode!r} in {part!r}")
            sticky = hit.endswith("+")
            n = int(hit[:-1] if sticky else hit)
            if n < 1:
                raise ValueError(f"hit index must be >= 1 in {part!r}")
            plan.rules[point] = _Rule(hit=n, sticky=sticky,
                                      crash=(mode == "crash"))
        return plan

    def check(self, point: str) -> None:
        """Count a hit at ``point``; raise if a rule says so."""
        self.counts[point] = self.counts.get(point, 0) + 1
        rule = self.rules.get(point)
        if rule is None:
            return
        n = self.counts[point]
        if n == rule.hit or (rule.sticky and n > rule.hit):
            self.fired[point] = self.fired.get(point, 0) + 1
            _count_fired(point)
            if rule.crash:
                raise InjectedCrash(f"injected crash at {point} (hit {n})")
            raise InjectedFault(f"injected fault at {point} (hit {n})")

    @property
    def total_fired(self) -> int:
        return sum(self.fired.values())


_PLAN: Optional[FaultPlan] = None


def install(plan: Optional[FaultPlan]) -> None:
    """Install (or clear, with ``None``) the process-wide fault plan."""
    global _PLAN
    _PLAN = plan


def active() -> Optional[FaultPlan]:
    return _PLAN


def check(point: str) -> None:
    if _PLAN is None:
        return
    _PLAN.check(point)


def _count_fired(point: str) -> None:
    try:
        from repro.obs import metrics
        metrics().counter("faults_injected_total", point=point).inc()
    except Exception:
        pass

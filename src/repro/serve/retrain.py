"""Online retraining subsystem: drift-triggered CoreWalk+SGNS refresh.

``EmbeddingService.should_retrain()`` detects k0-core membership drift; this
module closes the loop that acts on it. The paper's whole economy (walks and
SGNS restricted to the k0-core, §2.1/§2.2 propagation for everyone else)
makes the refresh cheap enough to run *online*: the drifted subcore is a
small fraction of the graph, and the previous run's vectors warm-start the
new one, so a refresh is a few SGNS epochs on a subgraph — not a cold
offline rebuild.

Four stages, each its own component so tests/benchmarks can drive them
separately:

* :class:`RetrainPlanner` — snapshots the **drifted k0-core** from the live
  ``DynamicGraph`` (one vectorized ``snapshot()`` CSR conversion) using the
  maintainer's *exact* incremental core numbers — no re-peel needed — and
  clamps k0 to the current degeneracy (deletion churn can lower it).
* :class:`Retrainer` — re-runs CoreWalk walks + SGNS on the subcore (the
  same components ``core/pipeline.embed_graph`` composes: ``corewalk_plan``
  -> ``build_corpus`` -> ``train_sgns``), **warm-starting** ``emb_in`` rows
  from the previous vectors of nodes that persist in the store.
* :class:`EmbeddingAligner` — SGNS is rotation-invariant, so a fresh run
  lands in an arbitrarily rotated copy of the old space. Orthogonal
  Procrustes on **stable anchor nodes** (in-core, core number unchanged
  since the last refresh, previous vector held) maps the new table back
  into the old space, so mixed-version ``gather`` results and §2.2
  cold-start propagation stay mutually comparable during rollout.
* :class:`VersionRollout` — stages the aligned table off to the side (the
  store's double buffer) and hot-swaps it: ``bump_version`` then **chunked**
  ``put_many`` scatters, optionally yielding to the serving loop between
  chunks, so query flushes interleave with the swap and p99 is unaffected.
  Rows not refreshed keep their old version (the store's per-row version
  tags reconcile the mixture); sharded stores swap through the same
  ``ShardPlan`` scatter path, so the rollout composes with ``--shards N``.

``Retrainer.run()`` chains the four stages and finishes with
``IncrementalCore.mark_refresh()``, resetting the drift baseline the next
trigger measures against.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.core.corewalk import WalkPlan, corewalk_plan, deepwalk_plan
from repro.obs import metrics
from repro.obs import trace as obs
from repro.core.kcore import degeneracy, kcore_subgraph
from repro.core.propagation import propagate
from repro.graph.csr import Graph
from repro.skipgram.corpus import build_corpus
from repro.skipgram.model import init_params
from repro.skipgram.trainer import SGNSConfig, train_sgns

from . import faults
from .kcore_inc import IncrementalCore
from .store import EmbeddingStore
from .stream import DynamicGraph

__all__ = [
    "RetrainConfig",
    "RetrainPlan",
    "RetrainPlanner",
    "RetrainReport",
    "Retrainer",
    "EmbeddingAligner",
    "VersionRollout",
    "procrustes_rotation",
]


def _mark_stage(stage: str, t0: float) -> float:
    """Close one retrain stage: emit its span + latency histogram sample.

    Returns the stage duration so call sites can keep the ``times`` dict
    (the report API) without re-reading the clock.
    """
    t1 = time.perf_counter()
    obs.record(f"retrain.{stage}", t0, t1)
    metrics().histogram("retrain_stage_seconds", stage=stage).observe(t1 - t0)
    return t1 - t0


# --------------------------------------------------------------- planning


@dataclasses.dataclass
class RetrainConfig:
    """Knobs for one drift-triggered refresh (defaults sized for serving)."""

    method: str = "corewalk"  # corewalk | deepwalk (budget plan on the core)
    n_walks: int = 10
    walk_length: int = 20
    sgns: SGNSConfig = dataclasses.field(
        default_factory=lambda: SGNSConfig(dim=64, epochs=0.5, impl="ref")
    )
    warm_start: bool = True  # seed emb_in from the previous vectors
    # epoch accounting scales steps with the (small) subcore corpus; the
    # floor matters because emb_out restarts at zero on every refresh, so
    # the first step's emb_in gradient is exactly zero — a 1-step "refresh"
    # would be a no-op on the served table
    min_sgns_steps: int = 50
    align: bool = True  # Procrustes back into the old space
    min_anchors: int = 8  # below this, alignment is skipped (identity)
    propagate: bool = True  # refill every shell below k0 (§2.2) in the swap
    prop_iters: int = 10
    swap_chunk: int = 1024  # put_many rows per rollout chunk
    seed: int = 0


@dataclasses.dataclass
class RetrainPlan:
    """A snapshot of the drifted k0-core, ready to walk and train."""

    snapshot: Graph  # immutable CSR of the whole live graph
    sub: Graph  # induced k0-core subgraph (original node ids)
    core: np.ndarray  # (n,) exact current core numbers (copied)
    baseline: np.ndarray  # (n,) core numbers at the last refresh
    k0: int  # effective k0 (clamped to current degeneracy)
    nodes: np.ndarray  # (m,) k0-core node ids
    drifted: int  # nodes whose (core >= k0) flag flipped since refresh


class RetrainPlanner:
    """Turns the live ``DynamicGraph`` + ``IncrementalCore`` into a plan.

    The maintainer's core numbers are exact (oracle-checked elsewhere), so
    planning costs one vectorized snapshot + one induced-subgraph build —
    no re-peel of the full graph.
    """

    def __init__(self, graph: DynamicGraph, cores: IncrementalCore, k0: int):
        if k0 is None or k0 < 1:
            raise ValueError(f"k0 must be a positive int, got {k0!r}")
        self.graph = graph
        self.cores = cores
        self.k0 = int(k0)

    def plan(self) -> RetrainPlan:
        snap = self.graph.snapshot()
        core = self.cores.core.copy()
        base = self.cores.baseline.copy()
        # deletions can drop the degeneracy below the configured k0; an empty
        # subcore would leave nothing to train on
        k0 = max(1, min(self.k0, degeneracy(core)))
        nodes = np.where(core >= k0)[0]
        drifted = int(np.sum((core >= k0) != (base >= k0)))
        return RetrainPlan(
            snapshot=snap,
            sub=kcore_subgraph(snap, core, k0),
            core=core,
            baseline=base,
            k0=k0,
            nodes=nodes,
            drifted=drifted,
        )


# -------------------------------------------------------------- alignment


def procrustes_rotation(X: np.ndarray, Y: np.ndarray) -> np.ndarray:
    """Orthogonal Procrustes: R = argmin_{R orthogonal} ||X R - Y||_F.

    Closed form (Schönemann 1966): with M = Xᵀ Y = U S Vᵀ, R = U Vᵀ.
    R is exactly orthogonal by construction, so applying it preserves row
    norms and pairwise dot products — the property the alignment tests
    assert.
    """
    X = np.asarray(X, np.float64)
    Y = np.asarray(Y, np.float64)
    if X.shape != Y.shape or X.ndim != 2:
        raise ValueError(f"anchor shapes must match, got {X.shape} vs {Y.shape}")
    U, _, Vt = np.linalg.svd(X.T @ Y)
    return (U @ Vt).astype(np.float32)


class EmbeddingAligner:
    """Maps a freshly trained table back into the serving embedding space.

    Anchors should be nodes whose representation has no reason to have
    moved: still in the k0-core, core number unchanged since the last
    refresh, previous vector available. With enough of them the rotation is
    well-conditioned; with fewer than ``min_anchors`` the aligner returns
    the input unchanged (identity), which the report flags.
    """

    def __init__(self, min_anchors: int = 8):
        self.min_anchors = int(min_anchors)

    def align(
        self, new_emb: np.ndarray, old_vecs: np.ndarray, anchors: np.ndarray
    ) -> tuple:
        """-> (aligned (n, d) float32, report dict).

        ``old_vecs`` is (len(anchors), d): the previous vector of each
        anchor node; ``anchors`` indexes rows of ``new_emb``.
        """
        anchors = np.asarray(anchors, np.int64)
        if len(anchors) < self.min_anchors:
            return np.asarray(new_emb, np.float32), {
                "aligned": False,
                "anchors": int(len(anchors)),
                "residual": 0.0,
            }
        X = new_emb[anchors]
        R = procrustes_rotation(X, old_vecs)
        aligned = np.asarray(new_emb, np.float32) @ R
        resid = float(
            np.linalg.norm(aligned[anchors] - old_vecs)
            / max(np.linalg.norm(old_vecs), 1e-12)
        )
        return aligned, {
            "aligned": True,
            "anchors": int(len(anchors)),
            "residual": resid,
        }


# ---------------------------------------------------------------- rollout


class VersionRollout:
    """Double-buffered hot swap of a refreshed table into the store.

    ``stage()`` keeps the new rows host-side (the store's live device table
    is untouched — that is the double buffer); ``commit()`` bumps the store
    version once, then scatters the staged rows in bounded ``chunk``-row
    ``put_many`` batches, invoking ``between()`` after each so the caller
    can interleave query flushes — the serving loop never pauses for a
    monolithic rebuild. Rows the refresh did not cover keep their previous
    version tag; the store's per-row versions (and ``version_counts()``)
    reconcile the mixture, and promotion from spill preserves old tags, so
    mixed-version gathers stay well-defined mid-rollout. Under a
    ``ShardPlan`` every chunk goes through the plan's shard-local scatter,
    so the swap is shard-aware for free.
    """

    def __init__(self, store: EmbeddingStore, *, chunk: int = 1024):
        self.store = store
        self.chunk = max(int(chunk), 1)
        self._staged: Optional[tuple] = None

    def stage(self, nodes: np.ndarray, vecs: np.ndarray, cores: np.ndarray):
        nodes = np.asarray(nodes, np.int64)
        vecs = np.asarray(vecs, np.float32)
        cores = np.broadcast_to(np.asarray(cores, np.int32), nodes.shape)
        if len(nodes) != len(vecs):
            raise ValueError("nodes/vecs row counts differ")
        self._staged = (nodes, vecs, cores)

    def commit(self, between: Optional[Callable[[], None]] = None) -> dict:
        if self._staged is None:
            raise RuntimeError("nothing staged; call stage() first")
        nodes, vecs, cores = self._staged
        self._staged = None
        version = self.store.bump_version()
        chunk_seconds = []
        for s in range(0, len(nodes), self.chunk):
            faults.check("retrain_swap_chunk")
            t0 = time.perf_counter()
            self.store.put_many(
                nodes[s : s + self.chunk],
                vecs[s : s + self.chunk],
                cores[s : s + self.chunk],
            )
            t1 = time.perf_counter()
            chunk_seconds.append(t1 - t0)
            obs.record(
                "retrain.swap_chunk", t0, t1,
                rows=int(min(self.chunk, len(nodes) - s)),
            )
            if between is not None:
                between()
        return {
            "version": int(version),
            "rows": int(len(nodes)),
            "chunks": len(chunk_seconds),
            "swap_seconds": float(sum(chunk_seconds)),
            "max_chunk_seconds": float(max(chunk_seconds, default=0.0)),
            "version_counts": self.store.version_counts(),
        }


# -------------------------------------------------------------- retrainer


@dataclasses.dataclass
class RetrainReport:
    k0: int
    core_size: int  # nodes in the retrained subcore
    drifted: int  # membership flips that triggered the refresh
    n_walks: int
    sgns_steps: int
    warm_rows: int  # emb_in rows seeded from previous vectors
    anchors: int
    aligned: bool
    align_residual: float
    version: int  # store version the swap installed
    rows_swapped: int
    swap_chunks: int
    staleness_before: float
    staleness_after: float
    pressure_before: float
    pressure_after: float
    times: dict  # plan / walks / train / align / propagate / swap / total


class Retrainer:
    """Drives one full detect→snapshot→retrain→align→swap cycle.

    Holds the service only by reference; ``run()`` reads the live graph /
    cores / store through it, and the optional ``between`` callback is
    forwarded to the rollout so callers can keep serving mid-swap.
    """

    def __init__(self, service, cfg: Optional[RetrainConfig] = None):
        if service.k0 is None:
            raise ValueError("service.k0 must be set to retrain (drift gate)")
        self.service = service
        self.cfg = cfg or RetrainConfig()
        self.planner = RetrainPlanner(service.graph, service.cores, service.k0)
        self.aligner = EmbeddingAligner(self.cfg.min_anchors)

    # one stage per method so components stay independently testable

    def _train(self, plan: RetrainPlan) -> tuple:
        """CoreWalk walks + warm-started SGNS on the subcore.

        Returns (emb (n, d) float32, meta dict, times dict).
        """
        cfg = self.cfg
        times = {}
        n = plan.snapshot.n_nodes
        if cfg.method == "corewalk":
            budgets = corewalk_plan(plan.core, cfg.n_walks).per_node
        elif cfg.method == "deepwalk":
            budgets = deepwalk_plan(n, cfg.n_walks).per_node
        else:
            raise ValueError(cfg.method)
        budgets = np.where(plan.core >= plan.k0, budgets, 0).astype(np.int32)
        roots = np.repeat(np.arange(n, dtype=np.int32), budgets)
        wplan = WalkPlan(roots=roots, n_real=len(roots), per_node=budgets)

        self.service.pet_watchdog()
        faults.check("retrain_walks")
        t0 = time.perf_counter()
        corpus = build_corpus(
            plan.sub.to_ell(),
            wplan,
            cfg.walk_length,
            jax.random.PRNGKey(cfg.seed),
        )
        corpus.walks.block_until_ready()
        times["walks"] = _mark_stage("walks", t0)

        self.service.pet_watchdog()
        faults.check("retrain_train")
        t0 = time.perf_counter()
        params = init_params(
            n, cfg.sgns.dim, jax.random.PRNGKey(cfg.sgns.seed)
        )
        warm_rows = 0
        if cfg.warm_start:
            old, found, _, _ = self.service.store.peek_many(plan.nodes)
            keep = found & (np.linalg.norm(old, axis=1) > 1e-12)
            warm_rows = int(keep.sum())
            if warm_rows:
                params["emb_in"] = (
                    params["emb_in"].at[plan.nodes[keep]].set(old[keep])
                )
        steps = max(
            cfg.min_sgns_steps,
            int(cfg.sgns.epochs * corpus.pairs_per_epoch(cfg.sgns.window)
                // cfg.sgns.batch),
        )
        res = train_sgns(corpus, cfg.sgns, params=params, steps=steps)
        times["train"] = _mark_stage("train", t0)
        meta = {
            "n_walks": int(wplan.n_real),
            "sgns_steps": int(res.n_steps),
            "warm_rows": warm_rows,
        }
        return res.embeddings, meta, times

    def _anchors(self, plan: RetrainPlan) -> tuple:
        """Stable anchors + their previous vectors (store peek, no LRU churn)."""
        stable = plan.nodes[
            plan.core[plan.nodes] == plan.baseline[plan.nodes]
        ]
        old, found, _, _ = self.service.store.peek_many(stable)
        keep = found & (np.linalg.norm(old, axis=1) > 1e-12)
        if int(keep.sum()) < self.aligner.min_anchors:
            # heavy churn can leave too few level-stable survivors; fall back
            # to every in-core node whose previous vector is still held
            old, found, _, _ = self.service.store.peek_many(plan.nodes)
            keep = found & (np.linalg.norm(old, axis=1) > 1e-12)
            return plan.nodes[keep], old[keep]
        return stable[keep], old[keep]

    def run(
        self, between: Optional[Callable[[], None]] = None
    ) -> Optional[RetrainReport]:
        svc = self.service
        cfg = self.cfg
        times = {}
        t_total = time.perf_counter()
        pressure_before = svc.retrain_pressure()
        staleness_before = svc.store.staleness(svc.cores.core)

        svc.pet_watchdog()
        faults.check("retrain_plan")
        t0 = time.perf_counter()
        plan = self.planner.plan()
        times["plan"] = _mark_stage("plan", t0)
        if len(plan.nodes) == 0:
            return None  # nothing alive at any k0 — nothing to refresh

        emb, meta, t_train = self._train(plan)
        times.update(t_train)

        svc.pet_watchdog()
        faults.check("retrain_align")
        t0 = time.perf_counter()
        if cfg.align:
            anchors, old_vecs = self._anchors(plan)
            emb, align_rep = self.aligner.align(emb, old_vecs, anchors)
        else:
            align_rep = {"aligned": False, "anchors": 0, "residual": 0.0}
        times["align"] = _mark_stage("align", t0)

        svc.pet_watchdog()
        faults.check("retrain_propagate")
        t0 = time.perf_counter()
        if cfg.propagate:
            # §2.2: refill every shell below k0 from the aligned subcore, so
            # the swap covers the whole served id space, not just the core
            emb = propagate(
                plan.snapshot, plan.core, plan.k0, emb,
                n_iters=cfg.prop_iters,
            )
            served = np.where(
                (plan.snapshot.degrees() > 0) | (plan.core >= plan.k0)
            )[0]
        else:
            served = plan.nodes
        times["propagate"] = _mark_stage("propagate", t0)

        svc.pet_watchdog()
        faults.check("retrain_swap")
        t0 = time.perf_counter()
        rollout = VersionRollout(svc.store, chunk=cfg.swap_chunk)
        rollout.stage(served, emb[served], plan.core[served])
        roll = rollout.commit(between)
        svc.cores.mark_refresh()
        times["swap"] = _mark_stage("swap", t0)
        times["total"] = time.perf_counter() - t_total

        return RetrainReport(
            k0=plan.k0,
            core_size=int(len(plan.nodes)),
            drifted=plan.drifted,
            n_walks=meta["n_walks"],
            sgns_steps=meta["sgns_steps"],
            warm_rows=meta["warm_rows"],
            anchors=align_rep["anchors"],
            aligned=align_rep["aligned"],
            align_residual=align_rep["residual"],
            version=roll["version"],
            rows_swapped=roll["rows"],
            swap_chunks=roll["chunks"],
            staleness_before=float(staleness_before),
            staleness_after=float(svc.store.staleness(svc.cores.core)),
            pressure_before=float(pressure_before),
            pressure_after=float(svc.retrain_pressure()),
            times={k: round(v, 6) for k, v in times.items()},
        )

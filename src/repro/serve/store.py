"""Versioned fixed-capacity embedding store for online serving.

Two tiers:

* **device-resident table** ``(capacity + 1, dim)`` — the hot set, gathered
  with static shapes on the query path (row ``capacity`` is an all-zero
  sentinel so misses/padding gather zeros);
* **host spillover** — rows evicted from the device table are kept in a host
  dict and transparently promoted back on access (an LRU cache over the
  device table, not data loss).

Every row remembers the store ``version`` and the node's core number at write
time. Core-number **drift** between write time and now is the staleness
signal (paper §2.2: propagation-filled embeddings are valid while the node's
shell is stable); ``staleness()`` reports the stale fraction and the service
uses it to gate retraining.

Under a :class:`~repro.serve.shard.ShardPlan` the device table is **row-
sharded** across the plan's 1D mesh: slot rows live in contiguous per-shard
chunks, gathers run as one jitted shard-local gather stitched by an
all-gather of the requested rows, and scatters stay shard-local. All host
metadata (slot map, LRU clock, spill dict) keeps the exact single-device
semantics — the parity suite asserts sharded == unsharded bit-for-bit —
while per-shard balance and cross-shard gather traffic are tracked for the
serving benchmark.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.obs import metrics
from repro.obs import trace as obs

from . import faults
from .util import pow2

__all__ = ["EmbeddingStore"]


class EmbeddingStore:
    def __init__(
        self,
        capacity: int,
        dim: int,
        node_cap: int,
        *,
        plan=None,
    ):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self.dim = int(dim)
        self.node_cap = int(node_cap)
        self.plan = plan if plan is not None and plan.enabled else None
        if self.plan is None:
            self._rows = self.capacity + 1
            self._table = jnp.zeros((self._rows, self.dim), jnp.float32)
        else:
            # row-sharded table: slots [0, capacity) + the zero-sentinel row
            # at ``capacity``, padded so every shard owns an equal chunk
            # (padding rows stay zero and are never referenced by any slot)
            self._rows = self.plan.pad_rows(self.capacity + 1)
            self._table = self.plan.place_rows(
                jnp.zeros((self._rows, self.dim), jnp.float32)
            )
            # ownership histogram of gathered resident rows + total row
            # copies the stitching all-gather moved across shards
            self.shard_gather_rows = np.zeros(self.plan.n_shards, np.int64)
            self.cross_shard_row_copies = 0
        # node id -> slot; sentinel value ``capacity`` means absent. The extra
        # entry (index node_cap) lets ELL sentinel ids flow through gathers.
        self._slot_of = np.full(self.node_cap + 1, self.capacity, np.int32)
        self._node_at = np.full(self.capacity, -1, np.int64)
        self._version_at = np.zeros(self.capacity, np.int64)
        self._core_at = np.zeros(self.capacity, np.int32)
        self._last_used = np.zeros(self.capacity, np.int64)
        self._spill: Dict[int, Tuple[np.ndarray, int, int]] = {}
        self.version = 0
        self.evictions = 0
        self._clock = 0
        self._free = list(range(self.capacity - 1, -1, -1))
        self._slot_dev: Optional[jnp.ndarray] = None
        self._slot_dirty = True

    # ------------------------------------------------------------- queries

    def __len__(self) -> int:
        return self.capacity - len(self._free)

    def __contains__(self, node: int) -> bool:
        return self._slot_of[node] < self.capacity or node in self._spill

    @property
    def resident(self) -> int:
        return len(self)

    @property
    def spilled(self) -> int:
        return len(self._spill)

    def slots_of(self, nodes: np.ndarray) -> np.ndarray:
        """(B,) int32 device-table slots; absent/spilled -> ``capacity``."""
        return self._slot_of[np.asarray(nodes)]

    def slot_table(self) -> np.ndarray:
        """(node_cap + 1,) node->slot map (sentinel = capacity). Live view."""
        return self._slot_of

    def slot_table_dev(self) -> jnp.ndarray:
        """Device copy of the node->slot map, re-uploaded only after writes."""
        if self._slot_dirty or self._slot_dev is None:
            self._slot_dev = jnp.asarray(self._slot_of)
            self._slot_dirty = False
        return self._slot_dev

    def table(self) -> jnp.ndarray:
        """Device table; row ``capacity`` is the zero sentinel.

        Shape is ``(capacity + 1, dim)`` single-device, padded to the shard
        plan's row multiple (trailing rows zero, never referenced) when
        row-sharded.
        """
        return self._table

    def node_of_slots(self, slots: np.ndarray) -> np.ndarray:
        """(B,) device-table slots -> node ids (-1 for dead/sentinel rows)."""
        slots = np.asarray(slots)
        out = np.full(slots.shape, -1, np.int64)
        live = (slots >= 0) & (slots < self.capacity)
        out[live] = self._node_at[slots[live]]
        return out

    def row_valid(self) -> np.ndarray:
        """(rows,) bool: table rows holding a live embedding right now
        (the zero-sentinel row and shard-padding rows are always False)."""
        valid = np.zeros(self._rows, bool)
        valid[: self.capacity] = self._node_at >= 0
        return valid

    def candidate_bias(self) -> np.ndarray:
        """(rows,) float32 additive retrieval mask: 0 on live rows, -inf on
        dead/sentinel/padding rows — the top-k kernels add it to scores so
        dead rows can never enter a result."""
        return np.where(self.row_valid(), 0.0, -np.inf).astype(np.float32)

    # ------------------------------------------------------------- writes

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _evict_lru(self, staged) -> int:
        faults.check("spill_io")
        used = np.where(self._node_at >= 0, self._last_used, np.iinfo(np.int64).max)
        slot = int(np.argmin(used))
        node = int(self._node_at[slot])
        # the victim's value may still be staged (written earlier in the same
        # batch, device scatter pending) — spill the staged copy, not the row
        vec = staged.get(slot)
        if vec is None:
            vec = np.asarray(self._table[slot])
        self._spill[node] = (
            np.asarray(vec),
            int(self._version_at[slot]),
            int(self._core_at[slot]),
        )
        self._slot_of[node] = self.capacity
        self._node_at[slot] = -1
        self.evictions += 1
        metrics().counter("store_evictions_total").inc()
        self._slot_dirty = True
        return slot

    def ensure_nodes(self, node_cap: int) -> None:
        """Grow the node->slot map to cover ids below ``node_cap``.

        Growth is geometric so the map's device shape (and every jit program
        gathering through it) changes O(log n) times, not once per new id.
        """
        if node_cap <= self.node_cap:
            return
        node_cap = max(int(node_cap), self.node_cap * 3 // 2)
        extra = np.full(node_cap - self.node_cap, self.capacity, np.int32)
        self._slot_of = np.concatenate([self._slot_of[:-1], extra,
                                        self._slot_of[-1:]])
        self.node_cap = node_cap
        self._slot_dirty = True

    def put_many(
        self,
        nodes: np.ndarray,
        vecs: np.ndarray,
        cores: np.ndarray,
        version: Optional[np.ndarray] = None,
    ) -> None:
        """Insert/overwrite rows (batched device scatter; evicts LRU as needed).

        ``version`` may be a scalar or per-row array (promotion restores each
        row's original write version); defaults to the store version.
        """
        nodes = np.asarray(nodes, np.int64)
        vecs = np.asarray(vecs, np.float32)
        cores = np.broadcast_to(np.asarray(cores, np.int32), nodes.shape)
        vers = np.broadcast_to(
            np.asarray(
                self.version if version is None else version, np.int64
            ),
            nodes.shape,
        )
        if nodes.size == 0:
            return
        self.ensure_nodes(int(nodes.max()) + 1)
        staged = {}  # slot -> pending vector; also resolves same-slot reuse
        for i, node in enumerate(nodes):
            node = int(node)
            s = int(self._slot_of[node])
            if s >= self.capacity:
                s = self._free.pop() if self._free else self._evict_lru(staged)
            self._spill.pop(node, None)
            self._slot_of[node] = s
            self._node_at[s] = node
            self._version_at[s] = vers[i]
            self._core_at[s] = cores[i]
            self._last_used[s] = self._tick()
            staged[s] = vecs[i]
        # one batched scatter of the surviving slot->vector writes, padded to
        # a power-of-two row count (extra rows rewrite the zero sentinel row)
        # so eager .at[].set compiles O(log) distinct shapes
        n_pad = pow2(len(staged))
        slots_p = np.full(n_pad, self.capacity, np.int32)
        vecs_p = np.zeros((n_pad, self.dim), np.float32)
        for j, (s, vec) in enumerate(staged.items()):
            slots_p[j] = s
            vecs_p[j] = vec
        if self.plan is None:
            self._table = self._table.at[slots_p].set(jnp.asarray(vecs_p))
        else:  # shard-local scatter, table stays row-sharded
            self._table = self.plan.set_rows_fn(
                self._table, jnp.asarray(slots_p), jnp.asarray(vecs_p)
            )
        self._slot_dirty = True
        metrics().counter("store_rows_written_total").inc(len(staged))

    def put(self, node: int, vec: np.ndarray, core: int) -> None:
        self.put_many(np.asarray([node]), np.asarray(vec)[None], np.asarray([core]))

    # ------------------------------------------------- fused-flush support
    # The fused flush dispatch (service._flush_batch) gathers, cold-starts,
    # and scatters resolved rows back in ONE jitted program. The store's
    # part of the contract: hand out target slots up front (reserve), adopt
    # the post-scatter table plus the matching host metadata afterwards
    # (adopt_fused), and keep the gather-path bookkeeping (LRU, traffic
    # counters) identical to :meth:`gather` (note_fused_gather).

    def reserve_slots(self, n: int) -> Optional[np.ndarray]:
        """Pop ``n`` free device slots for a fused write-back scatter.

        Returns None when the free list cannot cover the request — eviction
        needs a host readback of the victim rows, so the caller falls back
        to the evicting :meth:`put_many` path for that batch. Pop order
        mirrors put_many's assignment order; :meth:`release_slots` undoes
        an unused reservation exactly.
        """
        if n > len(self._free):
            return None
        return np.asarray([self._free.pop() for _ in range(n)], np.int32)

    def release_slots(self, slots: np.ndarray) -> None:
        """Return reserved-but-unwritten slots (reverse pop order restores
        the free list bit-exactly, as if the reservation never happened)."""
        self._free.extend(int(s) for s in reversed(np.asarray(slots).tolist()))

    def adopt_fused(self, table: jnp.ndarray, nodes: np.ndarray,
                    slots: np.ndarray, cores: np.ndarray) -> None:
        """Adopt the fused flush's post-scatter table and commit the host
        metadata for its write-back rows.

        ``nodes[i]`` was scattered into reserved slot ``slots[i]`` by the
        device program; here the slot map, reverse map, LRU stamp, and the
        version/core staleness tags catch up — rows are tagged at the
        current store version exactly as a :meth:`put_many` write would be.
        """
        self._table = table
        nodes = np.asarray(nodes, np.int64)
        slots = np.asarray(slots, np.int32)
        cores = np.broadcast_to(np.asarray(cores, np.int32), nodes.shape)
        for node, s, c in zip(nodes.tolist(), slots.tolist(), cores.tolist()):
            self._spill.pop(node, None)
            self._slot_of[node] = s
            self._node_at[s] = node
            self._version_at[s] = self.version
            self._core_at[s] = c
            self._last_used[s] = self._tick()
        if len(nodes):
            self._slot_dirty = True
            metrics().counter("store_rows_written_total").inc(len(nodes))

    def note_fused_gather(self, slots: np.ndarray, resident: np.ndarray,
                          spill_served: int = 0) -> None:
        """Bookkeeping for a device-side gather the fused flush performed:
        LRU ticks for the resident hits plus the exact traffic accounting
        :meth:`gather` would have recorded for the same request."""
        slots = np.asarray(slots)
        resident = np.asarray(resident, bool)
        # the row movement itself happened inside the fused device program;
        # this span marks the gather in the trace (fused=1) so pipeline-
        # coverage checks keep seeing the stage, with the same attributes
        # the host-side gather() recorded
        with obs.span("store.gather", batch=len(slots), fused=1) as sp:
            if resident.any():
                self._last_used[slots[resident]] = self._tick()
            if self.plan is not None:
                self.shard_gather_rows += self.plan.balance_of(
                    slots[resident], self._rows
                )
                self.cross_shard_row_copies += int(resident.sum()) * (
                    self.plan.n_shards - 1
                )
            reg = metrics()
            reg.counter("store_gather_requests_total").inc(len(slots))
            reg.counter("store_gather_found_total").inc(
                int(resident.sum()) + int(spill_served)
            )
            if spill_served:
                reg.counter("store_spill_serves_total").inc(int(spill_served))
            sp.set(found=int(resident.sum()) + int(spill_served))

    def peek_spill(self, node: int) -> Optional[np.ndarray]:
        """Spill-tier vector for ``node`` (None if not spilled); no side
        effects — the fused flush overlays these rows host-side."""
        hit = self._spill.get(int(node))
        return None if hit is None else hit[0]

    # ------------------------------------------------------------- lookups

    def promote(self, nodes: np.ndarray) -> int:
        """Bring spilled rows among ``nodes`` back into the device table.

        Requested rows that are already resident are LRU-pinned first, so a
        promotion's eviction never lands on another node of the same request.
        """
        nodes_u = np.unique(np.clip(np.asarray(nodes, np.int64), 0, self.node_cap))
        slots = self._slot_of[nodes_u]
        res = slots < self.capacity
        if res.any():
            self._last_used[slots[res]] = self._tick()
        hits = [int(n) for n in nodes_u if int(n) in self._spill]
        if not hits:
            return 0
        faults.check("spill_io")
        # one batched put, preserving each row's original version/core
        rows = [self._spill[n] for n in hits]
        with obs.span("store.promote", rows=len(hits)):
            self.put_many(
                np.asarray(hits),
                np.stack([r[0] for r in rows]),
                np.asarray([r[2] for r in rows]),
                version=np.asarray([r[1] for r in rows]),
            )
        metrics().counter("store_promotions_total").inc(len(hits))
        return len(hits)

    def peek_many(
        self, nodes: np.ndarray
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Side-effect-free batched lookup across both tiers.

        Returns ``(vecs (B, dim) float32, found (B,) bool, versions (B,)
        int64, cores (B,) int32)``. Unlike :meth:`gather`, nothing is
        promoted, no LRU clock ticks, and no traffic counters move — the
        retraining subsystem uses this to read previous vectors (warm
        start, Procrustes anchors) without disturbing serving state.
        """
        nodes = np.asarray(nodes, np.int64)
        vecs = np.zeros((len(nodes), self.dim), np.float32)
        vers = np.zeros(len(nodes), np.int64)
        cores = np.zeros(len(nodes), np.int32)
        in_map = (nodes >= 0) & (nodes <= self.node_cap)
        slots = np.full(len(nodes), self.capacity, np.int32)
        slots[in_map] = self._slot_of[nodes[in_map]]
        found = slots < self.capacity
        if found.any():
            table = np.asarray(self._table)  # one host pull for the batch
            vecs[found] = table[slots[found]]
            vers[found] = self._version_at[slots[found]]
            cores[found] = self._core_at[slots[found]]
        if self._spill and not found.all():
            for i in np.where(~found)[0]:
                hit = self._spill.get(int(nodes[i]))
                if hit is not None:
                    vecs[i], vers[i], cores[i] = hit[0], hit[1], hit[2]
                    found[i] = True
        return vecs, found, vers, cores

    def gather(
        self, nodes: np.ndarray
    ) -> Tuple[Union[jnp.ndarray, np.ndarray], np.ndarray]:
        """(B,) node ids -> ((B, dim) vectors, (B,) found mask).

        Spilled rows are promoted first; misses gather the zero sentinel.
        Touches LRU timestamps for resident hits.

        Rows the promotion pass could not keep resident — when the request's
        spill hits outnumber the evictable slots, a row promoted earlier in
        this same call can be bounced straight back to spill (its slot-map
        entry left at the sentinel) — are served from the host spill tier
        instead of being misreported as misses: ``found`` is true for every
        node the store holds in either tier.
        """
        nodes = np.asarray(nodes, np.int64)
        with obs.span("store.gather", batch=len(nodes)) as sp:
            nodes_c = np.clip(nodes, 0, self.node_cap)
            self.promote(nodes_c)  # pins resident hits, restores spills
            slots = self._slot_of[nodes_c]
            found = slots < self.capacity
            if found.any():
                self._last_used[slots[found]] = self._tick()
            if self.plan is None:
                vecs = self._table[jnp.asarray(slots)]
            else:
                vecs = self.plan.gather_rows_fn(
                    self._table, jnp.asarray(slots)
                )
                owned = self.plan.balance_of(slots[found], self._rows)
                self.shard_gather_rows += owned
                # the stitching all-gather broadcasts each owned row to
                # the other shards once
                self.cross_shard_row_copies += int(found.sum()) * (
                    self.plan.n_shards - 1
                )
            spill_served = 0
            if self._spill and not found.all():
                over = {}
                for i in np.where(~found)[0]:
                    hit = self._spill.get(int(nodes_c[i]))
                    if hit is not None:
                        over[int(i)] = hit[0]
                        found[i] = True
                if over:  # spill-tier overlay (host copy; rows stay spilled)
                    out = np.asarray(vecs).copy()
                    for i, vec in over.items():
                        out[i] = vec
                    vecs = out
                    spill_served = len(over)
            reg = metrics()
            reg.counter("store_gather_requests_total").inc(len(nodes))
            reg.counter("store_gather_found_total").inc(int(found.sum()))
            if spill_served:
                reg.counter("store_spill_serves_total").inc(spill_served)
            sp.set(found=int(found.sum()), spill=spill_served)
        return vecs, found

    # ------------------------------------------------------------ staleness

    def bump_version(self) -> int:
        self.version += 1
        return self.version

    def staleness(self, core_now: np.ndarray) -> float:
        """Fraction of resident rows whose core number drifted since write."""
        core_now = np.asarray(core_now)
        live = self._node_at >= 0
        if not live.any():
            return 0.0
        nodes = self._node_at[live]
        in_range = nodes < len(core_now)
        now = np.where(in_range, core_now[np.minimum(nodes, len(core_now) - 1)], 0)
        return float(np.mean(now != self._core_at[live]))

    def version_counts(self) -> Dict[int, int]:
        live = self._node_at >= 0
        vers, counts = np.unique(self._version_at[live], return_counts=True)
        return {int(v): int(c) for v, c in zip(vers, counts)}

    # ------------------------------------------------------------- snapshots

    def state_dict(self) -> Dict[str, np.ndarray]:
        """Both tiers plus all host metadata as host arrays.

        The device table is pulled down to its logical ``capacity + 1`` rows
        (shard padding rows are derived zeros); the spill dict is flattened
        to parallel arrays; the free-slot stack keeps its order so slot
        assignment after a restore is bit-identical.
        """
        table = np.asarray(self._table)[: self.capacity + 1].copy()
        spill_nodes = np.asarray(sorted(self._spill), np.int64)
        if len(spill_nodes):
            spill_vecs = np.stack(
                [self._spill[int(n)][0] for n in spill_nodes]
            ).astype(np.float32)
            spill_vers = np.asarray(
                [self._spill[int(n)][1] for n in spill_nodes], np.int64
            )
            spill_cores = np.asarray(
                [self._spill[int(n)][2] for n in spill_nodes], np.int32
            )
        else:
            spill_vecs = np.zeros((0, self.dim), np.float32)
            spill_vers = np.zeros(0, np.int64)
            spill_cores = np.zeros(0, np.int32)
        return {
            "table": table,
            "slot_of": self._slot_of.copy(),
            "node_at": self._node_at.copy(),
            "version_at": self._version_at.copy(),
            "core_at": self._core_at.copy(),
            "last_used": self._last_used.copy(),
            "spill_nodes": spill_nodes,
            "spill_vecs": spill_vecs,
            "spill_vers": spill_vers,
            "spill_cores": spill_cores,
            "free": np.asarray(self._free, np.int64),
            "capacity": np.int64(self.capacity),
            "dim": np.int64(self.dim),
            "node_cap": np.int64(self.node_cap),
            "version": np.int64(self.version),
            "evictions": np.int64(self.evictions),
            "clock": np.int64(self._clock),
        }

    def load_state_dict(self, state) -> None:
        """Overwrite this store with ``state`` (shape/plan must match cfg).

        Also the retrain rollback path: a captured pre-retrain state is
        restored wholesale so a failed swap leaves zero mixed-version rows.
        """
        self.capacity = int(state["capacity"])
        self.dim = int(state["dim"])
        self.node_cap = int(state["node_cap"])
        table = np.asarray(state["table"], np.float32)
        if self.plan is None:
            self._rows = self.capacity + 1
            self._table = jnp.asarray(table)
        else:
            self._rows = self.plan.pad_rows(self.capacity + 1)
            pad = self._rows - (self.capacity + 1)
            if pad:
                table = np.concatenate(
                    [table, np.zeros((pad, self.dim), np.float32)]
                )
            self._table = self.plan.place_rows(jnp.asarray(table))
        self._slot_of = np.array(state["slot_of"], np.int32)
        self._node_at = np.array(state["node_at"], np.int64)
        self._version_at = np.array(state["version_at"], np.int64)
        self._core_at = np.array(state["core_at"], np.int32)
        self._last_used = np.array(state["last_used"], np.int64)
        self._spill = {
            int(n): (np.array(v, np.float32), int(ver), int(c))
            for n, v, ver, c in zip(
                np.asarray(state["spill_nodes"], np.int64),
                np.asarray(state["spill_vecs"], np.float32),
                np.asarray(state["spill_vers"], np.int64),
                np.asarray(state["spill_cores"], np.int32),
            )
        }
        self._free = [int(s) for s in np.asarray(state["free"], np.int64)]
        self.version = int(state["version"])
        self.evictions = int(state["evictions"])
        self._clock = int(state["clock"])
        self._slot_dev = None
        self._slot_dirty = True

    @classmethod
    def from_state(cls, state, *, plan=None) -> "EmbeddingStore":
        store = cls(
            int(state["capacity"]), int(state["dim"]),
            int(state["node_cap"]), plan=plan,
        )
        store.load_state_dict(state)
        return store

    # ------------------------------------------------------------- sharding

    def shard_balance(self) -> np.ndarray:
        """(n_shards,) resident-row count per shard ([resident] unsharded)."""
        live = np.where(self._node_at >= 0)[0]
        if self.plan is None:
            return np.asarray([len(live)], np.int64)
        return self.plan.balance_of(live, self._rows)

    def reset_shard_traffic(self) -> None:
        """Zero the gather-traffic counters (benchmarks call after warmup)."""
        if self.plan is not None:
            self.shard_gather_rows[:] = 0
            self.cross_shard_row_copies = 0

    def shard_report(self) -> dict:
        """Per-shard balance + gather-traffic summary for the benchmark."""
        balance = self.shard_balance()
        rep = {
            "n_shards": 1 if self.plan is None else self.plan.n_shards,
            "resident_per_shard": balance.tolist(),
            "imbalance": float(balance.max() / max(balance.mean(), 1e-9))
            if balance.size
            else 0.0,
        }
        if self.plan is not None:
            rep["gather_rows_per_shard"] = self.shard_gather_rows.tolist()
            rep["cross_shard_row_copies"] = int(self.cross_shard_row_copies)
        return rep

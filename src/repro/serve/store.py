"""Versioned fixed-capacity embedding store for online serving.

Two tiers:

* **device-resident table** ``(capacity + 1, dim)`` — the hot set, gathered
  with static shapes on the query path (row ``capacity`` is an all-zero
  sentinel so misses/padding gather zeros);
* **host spillover** — rows evicted from the device table are kept in a host
  dict and transparently promoted back on access (an LRU cache over the
  device table, not data loss).

Every row remembers the store ``version`` and the node's core number at write
time. Core-number **drift** between write time and now is the staleness
signal (paper §2.2: propagation-filled embeddings are valid while the node's
shell is stable); ``staleness()`` reports the stale fraction and the service
uses it to gate retraining.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from .util import pow2

__all__ = ["EmbeddingStore"]


class EmbeddingStore:
    def __init__(self, capacity: int, dim: int, node_cap: int):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self.dim = int(dim)
        self.node_cap = int(node_cap)
        self._table = jnp.zeros((self.capacity + 1, self.dim), jnp.float32)
        # node id -> slot; sentinel value ``capacity`` means absent. The extra
        # entry (index node_cap) lets ELL sentinel ids flow through gathers.
        self._slot_of = np.full(self.node_cap + 1, self.capacity, np.int32)
        self._node_at = np.full(self.capacity, -1, np.int64)
        self._version_at = np.zeros(self.capacity, np.int64)
        self._core_at = np.zeros(self.capacity, np.int32)
        self._last_used = np.zeros(self.capacity, np.int64)
        self._spill: Dict[int, Tuple[np.ndarray, int, int]] = {}
        self.version = 0
        self.evictions = 0
        self._clock = 0
        self._free = list(range(self.capacity - 1, -1, -1))
        self._slot_dev: Optional[jnp.ndarray] = None
        self._slot_dirty = True

    # ------------------------------------------------------------- queries

    def __len__(self) -> int:
        return self.capacity - len(self._free)

    def __contains__(self, node: int) -> bool:
        return self._slot_of[node] < self.capacity or node in self._spill

    @property
    def resident(self) -> int:
        return len(self)

    @property
    def spilled(self) -> int:
        return len(self._spill)

    def slots_of(self, nodes: np.ndarray) -> np.ndarray:
        """(B,) int32 device-table slots; absent/spilled -> ``capacity``."""
        return self._slot_of[np.asarray(nodes)]

    def slot_table(self) -> np.ndarray:
        """(node_cap + 1,) node->slot map (sentinel = capacity). Live view."""
        return self._slot_of

    def slot_table_dev(self) -> jnp.ndarray:
        """Device copy of the node->slot map, re-uploaded only after writes."""
        if self._slot_dirty or self._slot_dev is None:
            self._slot_dev = jnp.asarray(self._slot_of)
            self._slot_dirty = False
        return self._slot_dev

    def table(self) -> jnp.ndarray:
        """(capacity + 1, dim) device table; last row is the zero sentinel."""
        return self._table

    # ------------------------------------------------------------- writes

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _evict_lru(self, staged) -> int:
        used = np.where(self._node_at >= 0, self._last_used, np.iinfo(np.int64).max)
        slot = int(np.argmin(used))
        node = int(self._node_at[slot])
        # the victim's value may still be staged (written earlier in the same
        # batch, device scatter pending) — spill the staged copy, not the row
        vec = staged.get(slot)
        if vec is None:
            vec = np.asarray(self._table[slot])
        self._spill[node] = (
            np.asarray(vec),
            int(self._version_at[slot]),
            int(self._core_at[slot]),
        )
        self._slot_of[node] = self.capacity
        self._node_at[slot] = -1
        self.evictions += 1
        self._slot_dirty = True
        return slot

    def ensure_nodes(self, node_cap: int) -> None:
        """Grow the node->slot map to cover ids below ``node_cap``.

        Growth is geometric so the map's device shape (and every jit program
        gathering through it) changes O(log n) times, not once per new id.
        """
        if node_cap <= self.node_cap:
            return
        node_cap = max(int(node_cap), self.node_cap * 3 // 2)
        extra = np.full(node_cap - self.node_cap, self.capacity, np.int32)
        self._slot_of = np.concatenate([self._slot_of[:-1], extra,
                                        self._slot_of[-1:]])
        self.node_cap = node_cap
        self._slot_dirty = True

    def put_many(
        self,
        nodes: np.ndarray,
        vecs: np.ndarray,
        cores: np.ndarray,
        version: Optional[np.ndarray] = None,
    ) -> None:
        """Insert/overwrite rows (batched device scatter; evicts LRU as needed).

        ``version`` may be a scalar or per-row array (promotion restores each
        row's original write version); defaults to the store version.
        """
        nodes = np.asarray(nodes, np.int64)
        vecs = np.asarray(vecs, np.float32)
        cores = np.broadcast_to(np.asarray(cores, np.int32), nodes.shape)
        vers = np.broadcast_to(
            np.asarray(
                self.version if version is None else version, np.int64
            ),
            nodes.shape,
        )
        if nodes.size == 0:
            return
        self.ensure_nodes(int(nodes.max()) + 1)
        staged = {}  # slot -> pending vector; also resolves same-slot reuse
        for i, node in enumerate(nodes):
            node = int(node)
            s = int(self._slot_of[node])
            if s >= self.capacity:
                s = self._free.pop() if self._free else self._evict_lru(staged)
            self._spill.pop(node, None)
            self._slot_of[node] = s
            self._node_at[s] = node
            self._version_at[s] = vers[i]
            self._core_at[s] = cores[i]
            self._last_used[s] = self._tick()
            staged[s] = vecs[i]
        # one batched scatter of the surviving slot->vector writes, padded to
        # a power-of-two row count (extra rows rewrite the zero sentinel row)
        # so eager .at[].set compiles O(log) distinct shapes
        n_pad = pow2(len(staged))
        slots_p = np.full(n_pad, self.capacity, np.int32)
        vecs_p = np.zeros((n_pad, self.dim), np.float32)
        for j, (s, vec) in enumerate(staged.items()):
            slots_p[j] = s
            vecs_p[j] = vec
        self._table = self._table.at[slots_p].set(jnp.asarray(vecs_p))
        self._slot_dirty = True

    def put(self, node: int, vec: np.ndarray, core: int) -> None:
        self.put_many(np.asarray([node]), np.asarray(vec)[None], np.asarray([core]))

    # ------------------------------------------------------------- lookups

    def promote(self, nodes: np.ndarray) -> int:
        """Bring spilled rows among ``nodes`` back into the device table.

        Requested rows that are already resident are LRU-pinned first, so a
        promotion's eviction never lands on another node of the same request.
        """
        nodes_u = np.unique(np.clip(np.asarray(nodes, np.int64), 0, self.node_cap))
        slots = self._slot_of[nodes_u]
        res = slots < self.capacity
        if res.any():
            self._last_used[slots[res]] = self._tick()
        hits = [int(n) for n in nodes_u if int(n) in self._spill]
        if not hits:
            return 0
        # one batched put, preserving each row's original version/core
        rows = [self._spill[n] for n in hits]
        self.put_many(
            np.asarray(hits),
            np.stack([r[0] for r in rows]),
            np.asarray([r[2] for r in rows]),
            version=np.asarray([r[1] for r in rows]),
        )
        return len(hits)

    def peek(self, node: int) -> Optional[np.ndarray]:
        """Host read of a spilled row without promoting it (None if absent)."""
        hit = self._spill.get(int(node))
        return None if hit is None else hit[0]

    def gather(self, nodes: np.ndarray) -> Tuple[jnp.ndarray, np.ndarray]:
        """(B,) node ids -> ((B, dim) vectors, (B,) found mask).

        Spilled rows are promoted first; misses gather the zero sentinel.
        Touches LRU timestamps for resident hits.
        """
        nodes = np.asarray(nodes, np.int64)
        nodes_c = np.clip(nodes, 0, self.node_cap)
        self.promote(nodes_c)  # pins resident hits, then restores spills
        slots = self._slot_of[nodes_c]
        found = slots < self.capacity
        if found.any():
            self._last_used[slots[found]] = self._tick()
        return self._table[jnp.asarray(slots)], found

    # ------------------------------------------------------------ staleness

    def bump_version(self) -> int:
        self.version += 1
        return self.version

    def staleness(self, core_now: np.ndarray) -> float:
        """Fraction of resident rows whose core number drifted since write."""
        core_now = np.asarray(core_now)
        live = self._node_at >= 0
        if not live.any():
            return 0.0
        nodes = self._node_at[live]
        in_range = nodes < len(core_now)
        now = np.where(in_range, core_now[np.minimum(nodes, len(core_now) - 1)], 0)
        return float(np.mean(now != self._core_at[live]))

    def version_counts(self) -> Dict[int, int]:
        live = self._node_at >= 0
        vers, counts = np.unique(self._version_at[live], return_counts=True)
        return {int(v): int(c) for v, c in zip(vers, counts)}

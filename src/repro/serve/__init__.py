"""Online embedding service: block-oriented streaming ingestion (inserts and
deletions), incremental k-core maintenance (one union-subcore repair per edge
block — device-resident: frontier-masked region growing, vectorized candidate
gathers, and a fused single-dispatch h-index descent, exact vs the peeling
oracle), propagation-based cold-start serving (paper §2.2 as an online
inference rule), and a ``ShardPlan`` row-sharding the node-indexed device
state (store table, ELL mirror, descent candidates) across a 1D mesh with
single-device semantics preserved bit-for-bit."""
from .kcore_inc import IncrementalCore
from .service import EmbeddingService, ServiceStats
from .shard import ShardPlan
from .store import EmbeddingStore
from .stream import DynamicGraph

__all__ = [
    "DynamicGraph",
    "IncrementalCore",
    "EmbeddingStore",
    "EmbeddingService",
    "ServiceStats",
    "ShardPlan",
]

"""Online embedding service: block-oriented streaming ingestion (inserts and
deletions), incremental k-core maintenance (one union-subcore repair per edge
block — device-resident: frontier-masked region growing, vectorized candidate
gathers, and a fused single-dispatch h-index descent, exact vs the peeling
oracle), propagation-based cold-start serving (paper §2.2 as an online
inference rule), and a ``ShardPlan`` row-sharding the node-indexed device
state (store table, ELL mirror, descent candidates) across a 1D mesh with
single-device semantics preserved bit-for-bit. The retraining subsystem
(``serve.retrain``) closes the drift loop: snapshot the drifted k0-core,
re-run CoreWalk+SGNS warm-started from the previous vectors, Procrustes-align
the new table into the old space, and hot-swap it version-by-version with no
serving pause. ``serve.recovery`` makes the whole stack crash-safe: a
checksummed write-ahead edge log, atomic snapshot/restore of the full serving
state, and deterministic replay that reproduces an uninterrupted run
bit-for-bit; ``serve.faults`` is the seeded fault-injection harness that
proves it."""
from .faults import FaultPlan, InjectedCrash, InjectedFault
from .kcore_inc import IncrementalCore
from .recovery import RecoveryManager, SnapshotStore, WriteAheadLog
from .retrain import (
    EmbeddingAligner,
    RetrainConfig,
    Retrainer,
    RetrainPlanner,
    RetrainReport,
    VersionRollout,
    procrustes_rotation,
)
from .service import EmbeddingService, ServiceStats
from .shard import ShardPlan
from .store import EmbeddingStore
from .stream import DynamicGraph

__all__ = [
    "DynamicGraph",
    "IncrementalCore",
    "EmbeddingStore",
    "EmbeddingService",
    "ServiceStats",
    "ShardPlan",
    "RetrainConfig",
    "RetrainPlanner",
    "Retrainer",
    "RetrainReport",
    "EmbeddingAligner",
    "VersionRollout",
    "procrustes_rotation",
    "FaultPlan",
    "InjectedFault",
    "InjectedCrash",
    "RecoveryManager",
    "SnapshotStore",
    "WriteAheadLog",
]

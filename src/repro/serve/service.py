"""Microbatching query front end of the online embedding service.

Queries (embedding lookups and link scores) are coalesced into fixed-size
batches so every flush runs the same static-shaped jit program regardless of
traffic: node lists are padded with the graph's sentinel id, and the sentinel
threads through every gather (sentinel ELL row -> no valid neighbours; slot
sentinel -> zero table row), so padding costs nothing and never branches.

Per flush:

1. known nodes answer straight from the store's device table;
2. unknown ("cold-start") nodes get the paper's §2.2 rule, one shot: the
   masked mean of their *already-embedded* neighbours, computed by the same
   ``ell_mean`` kernel path the offline propagation uses — a gather over the
   ELL rows remapped node->slot into the store table;
3. resolved cold starts are written back (with the node's current core
   number, so staleness tracking covers them), turning one-shot propagation
   into a cascade as traffic touches successive shells.

The service also owns ingestion policy: streamed edges arrive in **blocks**
through ``ingest_block`` (``DynamicGraph.add_edges`` + one
``IncrementalCore.on_edge_block`` repair for the whole block) and are
retracted through ``retract_block`` (``remove_edges`` + ``on_remove``), with
periodic double-buffered compaction. ``retrain_pressure`` (k0-core membership
drift since the last refresh — arrivals *and* deletion-driven departures)
gates when retraining is actually needed, and ``maybe_retrain`` acts on it:
with a :class:`~repro.serve.retrain.Retrainer` attached (``set_retrainer``),
the drifted k0-core is re-embedded (CoreWalk+SGNS, warm start), Procrustes-
aligned into the serving space, and hot-swapped into the store with query
flushes interleaved between the swap's chunked scatters.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.obs import Histogram, compiled_cost, metrics
from repro.obs import trace as obs

from . import faults
from .kcore_inc import IncrementalCore
from .recovery import KIND_INGEST, KIND_RETRACT
from .store import EmbeddingStore
from .stream import DynamicGraph

__all__ = ["EmbeddingService", "ServiceStats"]

# exact-percentile retention: latency percentiles describe the most recent
# FLUSH_WINDOW flushes / RETRAIN_WINDOW retrains (steady state, bounded
# memory); the histograms' bucket counts still cover the whole lifetime
FLUSH_WINDOW = 4096
RETRAIN_WINDOW = 64


@dataclasses.dataclass
class ServiceStats:
    queries: int = 0
    store_hits: int = 0
    cold_starts: int = 0
    unresolved: int = 0
    flushes: int = 0
    edges_ingested: int = 0
    edges_removed: int = 0
    ingest_blocks: int = 0
    compactions: int = 0
    retrains: int = 0
    last_swap_version: int = -1  # -1 = no retrain swap has happened yet
    degraded_queries: int = 0  # answered from stale rows (flush fallback)
    retrain_failures: int = 0  # retrains rolled back transactionally
    hangs: int = 0  # HangWatchdog firings around blocking device syncs
    # bounded fixed-bucket histograms (obs.metrics.Histogram): percentiles
    # are exact over the retained window (FLUSH_WINDOW / RETRAIN_WINDOW most
    # recent samples), lifetime bucket counts feed the metrics exporters —
    # long-lived services keep steady-state percentiles without unbounded
    # growth or warm-up skew
    topk_queries: int = 0  # nodes served through top_k_neighbors
    flush_seconds: Histogram = dataclasses.field(
        default_factory=lambda: Histogram(window=FLUSH_WINDOW)
    )
    retrain_seconds: Histogram = dataclasses.field(
        default_factory=lambda: Histogram(window=RETRAIN_WINDOW)
    )
    topk_seconds: Histogram = dataclasses.field(
        default_factory=lambda: Histogram(window=FLUSH_WINDOW)
    )

    @property
    def cold_fraction(self) -> float:
        return self.cold_starts / max(self.queries, 1)


class EmbeddingService:
    def __init__(
        self,
        graph: DynamicGraph,
        cores: IncrementalCore,
        store: EmbeddingStore,
        *,
        batch: int = 64,
        write_back: bool = True,
        compact_every: int = 1024,
        k0: Optional[int] = None,
        retrain_threshold: float = 0.1,
        impl: str = "auto",
        pipeline: bool = True,
        hang_timeout: Optional[float] = None,
        flush_retries: int = 1,
        retry_backoff: float = 0.05,
        transactional_retrain: bool = True,
    ):
        self.graph = graph
        self.cores = cores
        self.store = store
        self.batch = int(batch)
        self.write_back = write_back
        self.compact_every = int(compact_every)
        self.k0 = k0
        self.retrain_threshold = float(retrain_threshold)
        self.impl = impl
        # pipelined ingest: stage block N+1 (host dedup/canonicalise) while
        # block N's jitted descent dispatch is still in flight, then land the
        # repair + deferred per-block tail at the next sync point. Results
        # are bit-identical to the serial path (pipeline=False).
        self.pipeline = bool(pipeline)
        self._tail_due = False
        self.stats = ServiceStats()
        # retraining loop: a Retrainer (serve.retrain) attached via
        # set_retrainer; auto mode re-checks drift after every ingested block
        self.retrainer = None
        self.auto_retrain = False
        self.retrain_budget = 0  # max retrains per service life (0 = no cap)
        self._pending: List[np.ndarray] = []
        self._n_pending = 0
        # fault tolerance: optional recovery manager (WAL + snapshots),
        # bounded flush retries with a stale-row degraded fallback, a
        # transactional retrain (store rolled back on any stage failure),
        # and an optional hang watchdog around blocking device syncs
        self._recovery = None
        # live SLO engine (obs.slo): attach_slo() wires the stock
        # objectives; hot paths feed it only when attached (None check)
        self._slo = None
        self.flush_retries = max(int(flush_retries), 0)
        self.retry_backoff = float(retry_backoff)
        self.transactional_retrain = bool(transactional_retrain)
        self.degraded = False
        self._watchdog = None
        if hang_timeout is not None and hang_timeout > 0:
            from repro.distributed.watchdog import HangWatchdog

            self._watchdog = HangWatchdog(float(hang_timeout), self._on_hang)

        def _cold(nodes, nbr, slot_of, table, sentinel, cap, found):
            # sentinel / cap arrive as data: under a ShardPlan both the ELL
            # mirror and the store table carry shard-padding rows, so the
            # sentinel id / slot bound are NOT shape[0] - 1
            idx = nbr[nodes]  # (B, W) neighbour node ids
            slots = slot_of[idx]  # (B, W) store slots (sentinel = capacity)
            valid = (idx != sentinel) & (slots < cap)
            cold = ops.ell_mean(slots, valid, table, impl=impl)
            resolved = valid.any(axis=1)
            # slot gather of the found rows + select against the cold-start
            # means — spill-tier rows carry found=True with a sentinel slot
            # (zero row) and are overlaid host-side after the readback
            own = jnp.where(found, slot_of[nodes], cap)
            out = jnp.where(found[:, None], table[own], cold)
            return out, resolved

        def _fused_wb(nodes, nbr, slot_of, table, sentinel, cap, found,
                      wb_slots):
            # the full fused dispatch: gather -> §2.2 cold-start -> select
            # -> write-back scatter, one program, one device round trip.
            # wb_slots[i] is the pre-reserved target slot for a cold row
            # (``cap`` = no write-back); unresolved rows redirect to the
            # zero sentinel row and scatter zeros, so the sentinel stays
            # zero and no branch depends on the readback
            out, resolved = _cold(
                nodes, nbr, slot_of, table, sentinel, cap, found
            )
            do_wb = (~found) & resolved & (wb_slots < cap)
            wslots = jnp.where(do_wb, wb_slots, cap)
            wvals = jnp.where(do_wb[:, None], out, 0.0)
            return out, resolved, table.at[wslots].set(wvals)

        # recompile only when ELL width / table capacity / node_cap change;
        # under a ShardPlan the scattered table must come back row-sharded
        plan = store.plan
        if plan is None:
            self._fused_ro_fn = jax.jit(_cold)
            self._fused_wb_fn = jax.jit(_fused_wb)
        else:
            rep = plan.replicated()
            self._fused_ro_fn = jax.jit(_cold, out_shardings=(rep, rep))
            self._fused_wb_fn = jax.jit(
                _fused_wb, out_shardings=(rep, rep, plan.row_sharding(2))
            )
        self._fused_key = None  # last (capacity, ELL, node_cap) compiled

    # ------------------------------------------------------------ ingestion

    def attach_recovery(self, manager) -> None:
        """Attach a :class:`~repro.serve.recovery.RecoveryManager`: every
        block is WAL-logged before mutation, snapshots run on its cadence."""
        self._recovery = manager

    def attach_slo(self, engine=None, **thresholds):
        """Attach a live :class:`~repro.obs.slo.SLOEngine` (or build the
        stock one, ``thresholds`` forwarded to
        :func:`~repro.obs.slo.default_slos`).

        Event objectives (flush latency, per-block ingest rate, degraded
        fraction) are fed from the hot paths at one comparison + one deque
        append per event; the staleness objective is provider-backed (the
        stale-row walk is O(resident rows)) and sampled only when
        ``slo_health()`` / ``publish_metrics`` pull it. Returns the engine.
        """
        if engine is None:
            from repro.obs.slo import default_slos

            thresholds.setdefault(
                "staleness_provider",
                lambda: self.store.staleness(self.cores.core),
            )
            engine = default_slos(**thresholds)
        self._slo = engine
        return engine

    def slo_health(self):
        """Current SLO snapshot (``None`` when no engine is attached)."""
        return None if self._slo is None else self._slo.health()

    def _on_hang(self) -> None:
        """HangWatchdog callback: count the hang, enter degraded mode."""
        self.stats.hangs += 1
        self.degraded = True
        metrics().counter("serve_hangs_total").inc()
        metrics().gauge("serve_degraded").set(1)

    def pet_watchdog(self) -> None:
        """Reset the hang timer from inside a long multi-stage section
        (the retrainer pets between stages)."""
        if self._watchdog is not None and self._watchdog.armed:
            self._watchdog.pet()

    @staticmethod
    def _validate_block(edges) -> np.ndarray:
        """Strict block validation: the graph layer silently drops
        self-loops/duplicates, but at the service boundary malformed input
        is an error — a negative id or a float block would otherwise wrap
        into the sentinel row and corrupt the grouped scatter silently."""
        arr = np.asarray(edges)
        if arr.dtype == object or not np.issubdtype(arr.dtype, np.integer):
            raise ValueError(
                f"edge block must have an integer dtype, got {arr.dtype}"
            )
        try:
            arr = arr.reshape(-1, 2)
        except ValueError:
            raise ValueError(
                f"edge block must be (m, 2)-shaped, got shape {arr.shape}"
            )
        arr = arr.astype(np.int64, copy=False)
        if arr.size:
            if int(arr.min()) < 0:
                bad = arr[(arr < 0).any(axis=1)][0]
                raise ValueError(
                    f"node ids must be non-negative, got edge {tuple(bad)}"
                )
            loops = arr[:, 0] == arr[:, 1]
            if loops.any():
                v = int(arr[loops][0, 0])
                raise ValueError(f"self-loops are not allowed, got ({v}, {v})")
        return arr

    def _maybe_compact(self) -> None:
        if self.graph.edges_since_compact >= self.compact_every or (
            self.graph.overflow_arcs > max(16, self.graph.n_edges // 20)
        ):
            self.graph.compact()
            self.stats.compactions += 1
            metrics().counter("serve_compactions_total").inc()

    def _sync_ingest(self) -> None:
        """Land the in-flight repair and run the deferred per-block tail.

        Pipelined ingest defers the post-repair tail (compaction check, auto
        retrain) to the next sync point. Running it here — after the repair
        landed and *before* any new mutation — keeps the graph state at tail
        time identical to the serial path, which is what makes pipelining
        bit-exact. The flag flips before the tail runs so a retrain-triggered
        flush re-entering this method is a no-op.
        """
        self.cores.finish_update()
        if self._tail_due:
            self._tail_due = False
            self._maybe_compact()
            if self.auto_retrain:
                self.maybe_retrain()

    def sync(self) -> None:
        """Explicit flush boundary: block until pipelined ingest fully lands."""
        self._sync_ingest()

    def ingest_block(self, edges: np.ndarray) -> np.ndarray:
        """Stream an edge block: one staged insert + one block core repair.

        Returns the (m', 2) edges accepted (self-loops, duplicates, and
        edges already present are dropped by the graph). With ``pipeline``
        on, this block's canonicalisation overlaps the previous block's
        in-flight descent dispatch, and the repair readback + per-block tail
        are deferred to the next ingest/retract/flush/``sync()``.
        """
        edges = self._validate_block(edges)
        t_slo = time.perf_counter() if self._slo is not None else 0.0
        with obs.span("serve.ingest", block=len(edges)) as sp:
            if self._recovery is not None:  # durable *before* any mutation
                self._recovery.log_block(KIND_INGEST, edges)
            faults.check("ingest_apply")
            if self.pipeline:
                # host-only staging overlaps block N-1's device dispatch
                staged = self.graph.stage_block(edges)
                self._sync_ingest()
                accepted = self.graph.add_edges(staged, staged=True)
                if len(accepted):
                    self.cores.begin_update(added=accepted)
                self._tail_due = True
            else:
                accepted = self.graph.add_edges(edges)
                if len(accepted):
                    self.cores.on_edge_block(accepted)
            sp.set(accepted=len(accepted))
            self.stats.edges_ingested += len(accepted)
            self.stats.ingest_blocks += 1
            metrics().counter("serve_edges_ingested_total").inc(len(accepted))
            if not self.pipeline:
                self._maybe_compact()
                if self.auto_retrain:
                    self.maybe_retrain()
        if self._slo is not None and len(accepted):
            # pipelined blocks measure staging + the previous block's sync —
            # the rate traffic actually experiences at this boundary
            self._slo.observe(
                "ingest_rate",
                len(accepted) / max(time.perf_counter() - t_slo, 1e-9),
            )
        if self._recovery is not None:
            self._recovery.after_block()
        return accepted

    def retract_block(self, edges: np.ndarray) -> int:
        """Retract an edge block: staged delete + one block core repair.

        Unknown edges are skipped; returns the number actually removed.
        Demotions feed the same drift/staleness signals as promotions.
        Pipelines exactly like ``ingest_block``.
        """
        edges = self._validate_block(edges)
        with obs.span("serve.retract", block=len(edges)) as sp:
            if self._recovery is not None:  # durable *before* any mutation
                self._recovery.log_block(KIND_RETRACT, edges)
            faults.check("ingest_apply")
            if self.pipeline:
                staged = self.graph.stage_block(edges)
                self._sync_ingest()
                removed = self.graph.remove_edges(staged, staged=True)
                if len(removed):
                    self.cores.begin_update(removed=removed)
                self._tail_due = True
            else:
                removed = self.graph.remove_edges(edges)
                if len(removed):
                    self.cores.on_remove(removed)
            sp.set(removed=len(removed))
            self.stats.edges_removed += len(removed)
            metrics().counter("serve_edges_removed_total").inc(len(removed))
            if not self.pipeline:
                self._maybe_compact()
                if self.auto_retrain:
                    self.maybe_retrain()
        if self._recovery is not None:
            self._recovery.after_block()
        return len(removed)

    def ingest(self, u: int, v: int) -> bool:
        """Stream one edge (single-edge convenience over ``ingest_block``)."""
        return bool(len(self.ingest_block(np.array([[u, v]], np.int64))))

    def retract(self, u: int, v: int) -> bool:
        """Retract one edge (single-edge convenience over ``retract_block``)."""
        return bool(self.retract_block(np.array([[u, v]], np.int64)))

    def ingest_edges(self, edges: np.ndarray, block_size: int = 256) -> int:
        """Stream an edge array in ``block_size`` chunks; returns #accepted."""
        edges = np.asarray(edges)
        block_size = max(int(block_size), 1)
        n = sum(
            len(self.ingest_block(edges[s : s + block_size]))
            for s in range(0, len(edges), block_size)
        )
        self.sync()  # land the last block's in-flight repair + deferred tail
        return n

    def stream_with_churn(
        self,
        edges: np.ndarray,
        *,
        block_size: int = 256,
        churn: float = 0.0,
        rng: Optional[np.random.Generator] = None,
    ) -> Tuple[int, int]:
        """Stream ``edges`` in blocks, retracting a ``churn`` fraction of the
        previously streamed (and not yet retracted) edges after each block.

        The replay loop the launcher and the serving benchmark share; returns
        (#edges accepted, #edges retracted).
        """
        edges = np.asarray(edges)
        block_size = max(int(block_size), 1)
        rng = np.random.default_rng() if rng is None else rng
        live: List[Tuple[int, int]] = []  # accepted and not yet retracted
        n_in = n_out = 0
        for start in range(0, len(edges), block_size):
            block = edges[start : start + block_size]
            accepted = self.ingest_block(block)
            n_in += len(accepted)
            live.extend(map(tuple, accepted))
            n_churn = min(int(round(churn * len(block))), len(live))
            if n_churn:
                pick = rng.choice(len(live), size=n_churn, replace=False)
                gone = set(pick.tolist())
                n_out += self.retract_block(np.array([live[i] for i in pick]))
                live = [e for i, e in enumerate(live) if i not in gone]
        self.sync()  # land the last block's in-flight repair + deferred tail
        return n_in, n_out

    # ------------------------------------------------------------- queries

    def submit(self, node: int) -> int:
        """Queue an embedding query; returns its index in the next flush."""
        return int(self.submit_many(np.asarray([node], np.int64))[0])

    def submit_many(self, nodes: Sequence[int]) -> np.ndarray:
        """Queue a whole batch of queries in one vectorized append.

        Returns the (len(nodes),) indices the queries will occupy in the
        next ``flush()`` output. The pending queue holds arrays, not Python
        ints, so submitting N nodes costs O(1) list work — the per-node
        Python loop the old ``embed`` path paid is gone.
        """
        nodes = np.asarray(nodes, np.int64).reshape(-1)
        if nodes.size and int(nodes.min()) < 0:
            bad = int(nodes[nodes < 0][0])
            raise ValueError(f"node id must be non-negative, got {bad}")
        start = self._n_pending
        if nodes.size:
            self._pending.append(nodes)
            self._n_pending += len(nodes)
        return np.arange(start, start + len(nodes))

    @property
    def pending(self) -> int:
        return self._n_pending

    def _wb_cores(self, wb_nodes: np.ndarray) -> np.ndarray:
        """Current core numbers for write-back staleness tagging."""
        core = self.cores.core
        return np.where(
            wb_nodes < len(core),
            core[np.minimum(wb_nodes, max(len(core) - 1, 0))], 0
        )

    def _flush_batch(self, nodes: np.ndarray) -> np.ndarray:
        """One static-shaped batch (len == self.batch, padded with -1).

        The whole batch touches the device **once**: slot gather, §2.2
        ELL neighbour-mean cold start, found/cold select, and the write-back
        scatter of resolved cold rows all run inside one jitted dispatch
        (``_fused_wb_fn``). The host's only jobs are slot reservation
        before the dispatch and metadata commit after the readback.
        """
        t0 = time.perf_counter()
        sp = obs.span("serve.flush", batch=self.batch).__enter__()
        st = self.store
        sentinel = self.graph.node_cap
        # align the slot map with the graph's id space up front so its device
        # shape only changes when the graph grows (O(log n) jit recompiles).
        # Padding travels as -1 and is masked here — a sentinel snapshotted
        # at enqueue time could alias a node id minted by later growth
        st.ensure_nodes(sentinel)
        real = (nodes >= 0) & (nodes < sentinel)
        nodes_c = np.where(real, nodes, sentinel)
        degraded_batch = False
        wb_slots_u = None
        for attempt in range(self.flush_retries + 1):
            try:
                # spill-tier rows must answer queries directly (capacity <
                # working set must never thrash real embeddings into
                # cold-start means): restore what fits, overlay the rest
                st.promote(nodes_c)
                slots = st.slots_of(nodes_c)
                resident = slots < st.capacity
                bounced = {}  # row -> spilled vec served host-side
                if st.spilled:
                    for i in np.where(real & ~resident)[0]:
                        hit = st.peek_spill(int(nodes_c[i]))
                        if hit is not None:
                            bounced[int(i)] = hit
                st.note_fused_gather(slots, resident, len(bounced))
                found = resident.copy()
                if bounced:
                    found[list(bounced)] = True
                cold = real & ~found
                # cold-start means must see every *embedded* neighbour,
                # including rows currently spilled to host
                if cold.any() and st.spilled:
                    nbrs = np.concatenate(
                        [self.graph.neighbours(int(v))
                         for v in nodes_c[cold]]
                    )
                    st.promote(nbrs)
                # dedup within the batch: duplicate cold ids share one
                # reserved slot (and later count as one cold start)
                uniq_cold, first_pos = np.unique(
                    nodes_c[cold], return_index=True
                )
                if self.write_back and len(uniq_cold):
                    wb_slots_u = st.reserve_slots(len(uniq_cold))
                wb_slots = np.full(len(nodes), st.capacity, np.int32)
                if wb_slots_u is not None:
                    slot_of_cold = dict(
                        zip(uniq_cold.tolist(), wb_slots_u.tolist())
                    )
                    for i in np.where(cold)[0]:
                        wb_slots[i] = slot_of_cold[int(nodes_c[i])]

                ell = self.graph.ell()
                faults.check("flush_dispatch")
                args = (
                    jnp.asarray(nodes_c),
                    ell.neighbours,
                    st.slot_table_dev(),
                    st.table(),
                    jnp.int32(sentinel),
                    jnp.int32(st.capacity),
                    jnp.asarray(found),
                )
                key = (int(st.capacity), ell.neighbours.shape,
                       int(sentinel))
                if key != self._fused_key:
                    # compile BOTH dispatch variants at every shape change:
                    # which one a batch takes depends on its cold/warm mix,
                    # and a steady-state flush must never eat the other
                    # variant's cold compile mid-run. The warmup scatter
                    # targets only the zero sentinel row (all slots ==
                    # capacity, wvals 0), so it is a no-op on real rows and
                    # both outputs are discarded.
                    self._fused_ro_fn(*args)
                    self._fused_wb_fn(
                        *args,
                        jnp.asarray(
                            np.full(len(nodes), st.capacity, np.int32)
                        ),
                    )
                    self._fused_key = key
                if wb_slots_u is not None:
                    out, resolved, table2 = self._fused_wb_fn(
                        *args, jnp.asarray(wb_slots)
                    )
                else:  # nothing to scatter: skip the table write entirely
                    out, resolved = self._fused_ro_fn(*args)
                wd = self._watchdog
                if wd is not None:
                    wd.arm()
                try:
                    out = np.asarray(out)  # the blocking device sync
                finally:
                    if wd is not None:
                        wd.disarm()
                resolved = np.asarray(resolved)
                # commit the scattered rows: adopt the post-scatter table,
                # tag versions/cores, return unresolved slots to the pool
                if wb_slots_u is not None:
                    cold_rows = np.where(cold)[0][first_pos]
                    ok = resolved[cold_rows]
                    st.adopt_fused(
                        table2, uniq_cold[ok], wb_slots_u[ok],
                        self._wb_cores(uniq_cold[ok]),
                    )
                    if (~ok).any():
                        st.release_slots(wb_slots_u[~ok])
                elif self.write_back and len(uniq_cold):
                    # free list could not cover the batch: evicting
                    # write-back through put_many (host readback path)
                    cold_rows = np.where(cold)[0][first_pos]
                    ok = resolved[cold_rows]
                    if ok.any():
                        st.put_many(
                            uniq_cold[ok], out[cold_rows[ok]],
                            self._wb_cores(uniq_cold[ok]),
                        )
                for i, vec in bounced.items():  # spill-tier overlay
                    out[i] = vec
                if self.degraded:  # a healthy flush clears degraded mode
                    self.degraded = False
                    metrics().gauge("serve_degraded").set(0)
                break
            except Exception:
                metrics().counter("serve_flush_failures_total").inc()
                if wb_slots_u is not None:  # undo the reservation exactly
                    st.release_slots(wb_slots_u)
                    wb_slots_u = None
                if attempt < self.flush_retries:
                    time.sleep(self.retry_backoff * (2 ** attempt))
                    continue
                # degraded serving: answer from whatever rows both store
                # tiers already hold (side-effect free peek — no promote,
                # no device dispatch), cold starts stay unresolved
                vecs, found, _, _ = self.store.peek_many(nodes_c)
                cold = real & ~found
                uniq_cold = np.unique(nodes_c[cold])
                out = np.asarray(vecs, np.float32).copy()
                resolved = np.zeros(len(nodes), bool)
                degraded_batch = True
                if not self.degraded:
                    self.degraded = True
                    metrics().gauge("serve_degraded").set(1)

        n_real = int(real.sum())
        n_hits = int((real & found).sum())
        # duplicates within one batch are one cold start, not several
        n_cold = int(len(uniq_cold))
        if len(uniq_cold):
            uniq_resolved = resolved[
                np.where(cold)[0][
                    np.unique(nodes_c[cold], return_index=True)[1]
                ]
            ]
            n_unresolved = int((~uniq_resolved).sum())
        else:
            n_unresolved = 0
        self.stats.queries += n_real
        self.stats.store_hits += n_hits
        self.stats.cold_starts += n_cold
        self.stats.unresolved += n_unresolved
        reg = metrics()
        if degraded_batch:
            self.stats.degraded_queries += n_real
            reg.counter("serve_degraded_queries_total").inc(n_real)
        reg.counter("serve_queries_total").inc(n_real)
        reg.counter("serve_store_hits_total").inc(n_hits)
        reg.counter("serve_cold_starts_total").inc(n_cold)
        reg.counter("serve_unresolved_total").inc(n_unresolved)
        self.stats.flushes += 1
        dt = time.perf_counter() - t0
        self.stats.flush_seconds.observe(dt)
        if self._slo is not None:
            self._slo.observe("flush_latency", dt)
            self._slo.observe(
                "degraded_serving", 1.0 if degraded_batch else 0.0
            )
        sp.set(hits=n_hits, cold=n_cold, unresolved=n_unresolved)
        sp.__exit__(None, None, None)
        return out

    def flush(self) -> np.ndarray:
        """Drain the pending queue in static batches; returns (Q, dim)."""
        self._sync_ingest()  # queries must see fully-landed cores/compaction
        queue = (
            np.concatenate(self._pending)
            if self._pending
            else np.zeros(0, np.int64)
        )
        self._pending = []
        self._n_pending = 0
        outs = []
        for start in range(0, len(queue), self.batch):
            chunk = queue[start : start + self.batch]
            # pad with -1, not the current graph sentinel: node_cap grows
            # under ensure_nodes/compaction, so a sentinel snapshotted here
            # could alias a node id that is valid by the time the batch
            # dispatches — -1 can never collide with a real id
            padded = np.full(self.batch, -1, np.int64)
            padded[: len(chunk)] = chunk
            outs.append(self._flush_batch(padded)[: len(chunk)])
        if not outs:
            return np.zeros((0, self.store.dim), np.float32)
        return np.concatenate(outs, axis=0)

    def embed(self, nodes: Sequence[int]) -> np.ndarray:
        """Convenience: submit_many + flush. Returns (len(nodes), dim)."""
        self.submit_many(nodes)
        return self.flush()

    def link_scores(self, pairs: np.ndarray) -> np.ndarray:
        """Cosine link scores for (P, 2) node pairs (cold-starts both ends).

        Cosine, matching the retrain-eval AUC ranking (propagation shrinks
        norms shell by shell, so raw dot products rank by depth as much as
        affinity); normalisation goes through the same
        :func:`~repro.kernels.ops.normalize_rows` scoring tile the top-k
        retrieval kernel uses, so link scores and ``top_k_neighbors``
        scores are the same numbers. Repeated endpoints are deduplicated
        into a single flush slot — a pair list touching few distinct nodes
        no longer triggers redundant cold-start dispatches.
        """
        pairs = np.asarray(pairs, np.int64)
        flat = pairs.reshape(-1)
        uniq, inv = np.unique(flat, return_inverse=True)
        emb = np.asarray(ops.normalize_rows(jnp.asarray(self.embed(uniq))))
        e = emb[inv]
        return np.sum(e[0::2] * e[1::2], axis=1)

    def top_k_neighbors(
        self, nodes: Sequence[int], k: int
    ) -> Tuple[np.ndarray, np.ndarray]:
        """k nearest resident embeddings by cosine, per query node.

        Queries resolve through the normal flush path first (cold starts
        get their §2.2 propagation mean and are written back), then score
        against every *live* device-table row via the blockwise top-k
        kernel (``kernels.topk``) — the (Q, N) score matrix is never
        materialised. Each query node is excluded from its own result.

        Returns ``(node_ids (Q, k) int64, scores (Q, k) float32)`` ordered
        by (score desc, node-slot asc); -1 / -inf pad when fewer than k
        candidates are resident. Under a ShardPlan every shard reduces a
        partial top-k over its own rows and the host stitches the lists.
        """
        nodes = np.asarray(nodes, np.int64).reshape(-1)
        k = int(k)
        if k < 1 or not len(nodes):
            return (np.zeros((len(nodes), max(k, 0)), np.int64),
                    np.zeros((len(nodes), max(k, 0)), np.float32))
        t0 = time.perf_counter()
        with obs.span("serve.topk", batch=len(nodes), k=k) as sp:
            qv = self.embed(nodes)  # resolves cold starts + write-back
            st = self.store
            qn = ops.normalize_rows(jnp.asarray(qv))
            tn = ops.normalize_rows(st.table())
            # ask for k+1 candidates: the query's own row (when resident)
            # is dropped host-side, leaving a full k for everyone
            kk = k + 1
            if st.plan is None:
                vals, idx = ops.top_k_scores(
                    qn, tn, kk, valid=jnp.asarray(st.row_valid()),
                    impl=self.impl,
                )
                vals = np.asarray(vals)
                idx = np.asarray(idx, np.int64)
            else:
                pv, pi = st.plan.partial_topk_fn(
                    qn, tn, jnp.asarray(st.candidate_bias()), kk
                )
                vals, idx = st.plan.merge_topk(pv, pi, kk)
            own = st.slots_of(nodes).astype(np.int64)  # capacity = absent
            keep = (idx >= 0) & (idx != own[:, None])
            order = np.argsort(~keep, axis=1, kind="stable")
            sel = np.take_along_axis(idx, order, 1)[:, :k]
            sval = np.take_along_axis(vals, order, 1)[:, :k]
            kept = np.take_along_axis(keep, order, 1)[:, :k]
            ids = np.where(
                kept, st.node_of_slots(np.maximum(sel, 0)), -1
            )
            scores = np.where(kept, sval, -np.inf).astype(np.float32)
            self.stats.topk_queries += len(nodes)
            dt = time.perf_counter() - t0
            self.stats.topk_seconds.observe(dt)
            reg = metrics()
            reg.counter("serve_topk_queries_total").inc(len(nodes))
            sp.set(candidates=int(st.resident))
        return ids, scores

    # ----------------------------------------------------------- retraining

    def retrain_pressure(self) -> float:
        """Fraction of the k0-core whose membership flipped since refresh."""
        if self.k0 is None:
            return 0.0
        changed, size = self.cores.membership_drift(self.k0)
        return changed / max(size, 1)

    def should_retrain(self) -> bool:
        return self.retrain_pressure() >= self.retrain_threshold

    def set_retrainer(self, retrainer, *, auto: bool = False,
                      budget: int = 0) -> None:
        """Attach a :class:`~repro.serve.retrain.Retrainer` to close the loop.

        ``auto=True`` re-checks drift after every ingested/retracted block
        and refreshes in place; ``budget`` caps how many refreshes this
        service will run (0 = uncapped).
        """
        self.retrainer = retrainer
        self.auto_retrain = bool(auto)
        self.retrain_budget = int(budget)

    def maybe_retrain(self, force: bool = False, between=None):
        """Run one drift-triggered retrain+hot-swap cycle when due.

        Returns the :class:`~repro.serve.retrain.RetrainReport` (or None if
        no retrainer is attached, pressure is below threshold and ``force``
        is unset, the budget is spent, or the planner found nothing to
        refresh). ``between`` is forwarded to the rollout so query flushes
        can interleave with the swap's chunked scatters.
        """
        if self.retrainer is None:
            return None
        if self.retrain_budget and self.stats.retrains >= self.retrain_budget:
            return None
        if not force and not self.should_retrain():
            return None
        t0 = time.perf_counter()
        # transactional: capture the store (host copy) before any stage
        # runs, restore it wholesale on failure — a retrain that dies
        # mid-VersionRollout must not leave mixed-version rows. The core
        # baseline needs no rollback: mark_refresh only runs after a
        # successful swap. InjectedCrash (simulated process death) is a
        # BaseException and deliberately passes through.
        pre = self.store.state_dict() if self.transactional_retrain else None
        wd = self._watchdog
        if wd is not None:
            wd.arm()
        try:
            with obs.span("serve.retrain") as sp:
                report = self.retrainer.run(between=between)
        except Exception:
            self.stats.retrain_failures += 1
            metrics().counter("serve_retrain_failures_total").inc()
            if pre is not None:
                self.store.load_state_dict(pre)
                return None
            raise
        finally:
            if wd is not None:
                wd.disarm()
        if report is None:
            return None
        sp.set(version=report.version, rows=report.rows_swapped)
        self.stats.retrains += 1
        self.stats.last_swap_version = report.version
        dt = time.perf_counter() - t0
        self.stats.retrain_seconds.observe(dt)
        metrics().counter("serve_retrains_total").inc()
        return report

    def mark_refreshed(self) -> None:
        """Call after reloading the store from an offline retrain."""
        self.cores.mark_refresh()
        self.store.bump_version()

    # ------------------------------------------------------------- reports

    def latency_percentiles(self) -> Tuple[float, float]:
        """(p50, p99) per-flush seconds (each flush serves ``batch`` slots).

        Exact percentiles over the histogram's retained window — the most
        recent ``FLUSH_WINDOW`` (4096) flushes; earlier flushes still count
        in the histogram's bucket totals but no longer move the percentiles.
        """
        h = self.stats.flush_seconds
        if not len(h):
            return 0.0, 0.0
        p50, p99 = h.percentile([50, 99])
        return float(p50), float(p99)

    def topk_latency_percentiles(self) -> Tuple[float, float]:
        """(p50, p99) per-call ``top_k_neighbors`` seconds (same retained
        window semantics as :meth:`latency_percentiles`)."""
        h = self.stats.topk_seconds
        if not len(h):
            return 0.0, 0.0
        p50, p99 = h.percentile([50, 99])
        return float(p50), float(p99)

    def publish_metrics(self, registry=None) -> None:
        """Register this service's live stats into a metrics registry.

        The flush/retrain histograms are adopted by reference (the exporter
        reads the very objects ``_flush_batch`` observes into — one source
        of truth), counters/gauges are set to the current totals. Launchers
        call this right before exporting a snapshot; calling it again after
        a ``stats`` reset re-points the registry at the new histograms.
        """
        reg = metrics() if registry is None else registry
        st = self.stats
        reg.register("serve_flush_seconds", st.flush_seconds, replace=True)
        reg.register("serve_retrain_seconds", st.retrain_seconds,
                     replace=True)
        reg.register("serve_topk_seconds", st.topk_seconds, replace=True)
        for name, value in (
            ("serve_queries", st.queries),
            ("serve_store_hits", st.store_hits),
            ("serve_cold_starts", st.cold_starts),
            ("serve_unresolved", st.unresolved),
            ("serve_flushes", st.flushes),
            ("serve_ingest_blocks", st.ingest_blocks),
            ("serve_edges_ingested", st.edges_ingested),
            ("serve_edges_removed", st.edges_removed),
            ("serve_compactions", st.compactions),
            ("serve_retrains", st.retrains),
            ("serve_topk_queries", st.topk_queries),
            ("serve_degraded_queries", st.degraded_queries),
            ("serve_retrain_failures", st.retrain_failures),
            ("serve_hangs", st.hangs),
            ("serve_pending_queries", self.pending),
            ("store_resident_rows", self.store.resident),
            ("store_spilled_rows", self.store.spilled),
            ("store_evictions", self.store.evictions),
            ("graph_nodes", self.graph.n_nodes),
            ("graph_edges", self.graph.n_edges),
            ("graph_overflow_arcs", self.graph.overflow_arcs),
        ):
            reg.gauge(name).set(value)
        reg.gauge("serve_degraded").set(int(self.degraded))
        reg.gauge("serve_retrain_pressure").set(self.retrain_pressure())
        reg.gauge("store_staleness").set(
            self.store.staleness(self.cores.core)
        )
        if self.store.plan is not None:
            for s, rows in enumerate(self.store.shard_gather_rows):
                reg.gauge("store_gather_rows", shard=s).set(int(rows))
            reg.gauge("store_cross_shard_row_copies").set(
                int(self.store.cross_shard_row_copies)
            )
        if self._slo is not None:
            self._slo.publish(reg)

    def dispatch_cost_report(self) -> dict:
        """Measured per-dispatch cost of the fused flush program.

        AOT-compiles ``_fused_wb_fn`` (gather -> cold-start -> select ->
        write-back scatter) on the shapes the serving path currently
        dispatches and returns its ``cost_analysis``/``memory_analysis``
        numbers (flops, bytes accessed, argument/output/temp bytes) — the
        fused program's cost measured, not guessed. Cheap enough to call
        at export time only (one extra AOT compile, never on the hot path).
        """
        sentinel = self.graph.node_cap
        self.store.ensure_nodes(sentinel)
        ell = self.graph.ell()
        # mirror the flush path's host->device conversion so the AOT trace
        # sees the exact dtypes the live dispatch uses
        nodes = jnp.asarray(np.zeros(self.batch, np.int64))
        return compiled_cost(
            self._fused_wb_fn,
            nodes,
            ell.neighbours,
            self.store.slot_table_dev(),
            self.store.table(),
            jnp.int32(sentinel),
            jnp.int32(self.store.capacity),
            jnp.asarray(np.zeros(self.batch, bool)),
            jnp.asarray(np.full(self.batch, self.store.capacity, np.int32)),
        )

"""Neighbour-mean propagation kernel over ELL adjacency (paper §2.2).

One Jacobi sweep of the mean-embedding propagation is, per node, a gather of
its neighbours' embedding rows followed by a masked mean. The GPU/CPU-natural
formulation (materialise emb[idx] as an (N, L, D) tensor, then reduce) writes
the gathered tensor to HBM. The TPU-native formulation implemented here never
materialises it: neighbour indices are scalar-prefetched into SMEM, and the
kernel issues per-row HBM->VMEM DMAs (double-buffered) accumulating the mean
in a VMEM register block — the gather lives entirely in the memory hierarchy
(HBM -> VMEM -> VREG), which is exactly the adaptation DESIGN.md §3 calls out.

Grid: one program per destination row block is overkill for DMA latency, so
the grid is one program per row, with a 2-deep DMA pipeline across the
neighbour loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ell_mean_kernel(idx_ref, cnt_ref, emb_ref, out_ref, buf_ref, sem_ref):
    i = pl.program_id(0)
    L = idx_ref.shape[1]
    D = out_ref.shape[1]
    cnt = cnt_ref[i]

    def dma(slot, j):
        row = idx_ref[i, j]
        return pltpu.make_async_copy(
            emb_ref.at[pl.ds(row, 1)], buf_ref.at[pl.ds(slot, 1)], sem_ref.at[slot]
        )

    # warm-up: start DMA for neighbour 0 into slot 0
    @pl.when(cnt > 0)
    def _():
        dma(0, 0).start()

    def body(j, acc):
        slot = jax.lax.rem(j, 2)
        nxt = jax.lax.rem(j + 1, 2)

        @pl.when(j + 1 < cnt)
        def _():
            dma(nxt, j + 1).start()

        dma(slot, j).wait()
        return acc + buf_ref[slot, :].astype(jnp.float32)

    acc0 = jnp.zeros((D,), jnp.float32)
    acc = jax.lax.fori_loop(0, cnt, body, acc0)
    denom = jnp.maximum(cnt.astype(jnp.float32), 1.0)
    out_ref[0, :] = (acc / denom).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ell_mean_pallas(idx, cnt, emb, *, interpret=False):
    """Masked neighbour mean: out[i] = mean(emb[idx[i, :cnt[i]]]).

    idx: (N, L) int32 — valid entries must be left-packed (first cnt[i] slots);
    cnt: (N,) int32; emb: (M, D). Returns (N, D) in emb.dtype.
    """
    N, L = idx.shape
    M, D = emb.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # idx, cnt
        grid=(N,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],  # emb stays in HBM
        out_specs=pl.BlockSpec((1, D), lambda i, *_: (i, 0)),
        scratch_shapes=[
            pltpu.VMEM((2, D), emb.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    return pl.pallas_call(
        _ell_mean_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, D), emb.dtype),
        interpret=interpret,
    )(idx, cnt, emb)

"""Fused SGNS loss/grad Pallas kernels — the paper's compute hot spot.

The SkipGram-negative-sampling inner loop is, per example, one positive dot
product, K negative dot products, K+1 sigmoids, and rank-1 gradient updates.
Done naively (gather -> einsum -> sigmoid -> three einsums) XLA materialises
the (B, K) logits and (B, K, D) gradient tensors in HBM several times. The
kernels here keep the whole per-block working set — center/context blocks
(BB, D), negatives (BB, K, D), logits (BB, K) — resident in VMEM and emit
loss (fwd) or all three gradients (bwd) in a single pass.

TPU adaptation notes (vs the paper's gensim/CPU hogwild):
  * D is padded to a multiple of 128 (lane width) by the ops.py wrapper.
  * Logits accumulate in fp32; inputs may be bf16 (MXU-friendly).
  * The batch is blocked at BB=256 rows by default — working set at
    K=5, D=256, bf16 is ~(2+5)*256*256*2B + logits = ~1 MB, far under VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 256


def _fwd_kernel(center_ref, ctx_ref, neg_ref, loss_ref):
    c = center_ref[...].astype(jnp.float32)  # (BB, D)
    x = ctx_ref[...].astype(jnp.float32)  # (BB, D)
    n = neg_ref[...].astype(jnp.float32)  # (BB, K, D)
    pos = jnp.sum(c * x, axis=-1)  # (BB,)
    negl = jax.lax.dot_general(
        n, c, (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )  # (BB, K)
    loss = jax.nn.softplus(-pos) + jnp.sum(jax.nn.softplus(negl), axis=-1)
    loss_ref[...] = loss.astype(loss_ref.dtype)


def _bwd_kernel(center_ref, ctx_ref, neg_ref, dout_ref, dc_ref, dx_ref, dn_ref):
    c = center_ref[...].astype(jnp.float32)
    x = ctx_ref[...].astype(jnp.float32)
    n = neg_ref[...].astype(jnp.float32)
    d = dout_ref[...].astype(jnp.float32)  # (BB,)
    pos = jnp.sum(c * x, axis=-1)
    negl = jax.lax.dot_general(
        n, c, (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )
    dpos = (jax.nn.sigmoid(pos) - 1.0) * d  # (BB,)
    dneg = jax.nn.sigmoid(negl) * d[:, None]  # (BB, K)
    # dcenter = dpos * ctx + sum_k dneg_k * neg_k
    dc = dpos[:, None] * x + jax.lax.dot_general(
        dneg, n, (((1,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )
    dc_ref[...] = dc.astype(dc_ref.dtype)
    dx_ref[...] = (dpos[:, None] * c).astype(dx_ref.dtype)
    dn_ref[...] = (dneg[:, :, None] * c[:, None, :]).astype(dn_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def sgns_loss_fwd_pallas(center, ctx, neg, *, block_b=DEFAULT_BLOCK_B, interpret=False):
    B, D = center.shape
    K = neg.shape[1]
    bb = min(block_b, B)
    assert B % bb == 0, f"batch {B} not divisible by block {bb}"
    return pl.pallas_call(
        _fwd_kernel,
        grid=(B // bb,),
        in_specs=[
            pl.BlockSpec((bb, D), lambda i: (i, 0)),
            pl.BlockSpec((bb, D), lambda i: (i, 0)),
            pl.BlockSpec((bb, K, D), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.float32),
        interpret=interpret,
    )(center, ctx, neg)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def sgns_loss_bwd_pallas(center, ctx, neg, dout, *, block_b=DEFAULT_BLOCK_B, interpret=False):
    B, D = center.shape
    K = neg.shape[1]
    bb = min(block_b, B)
    assert B % bb == 0
    return pl.pallas_call(
        _bwd_kernel,
        grid=(B // bb,),
        in_specs=[
            pl.BlockSpec((bb, D), lambda i: (i, 0)),
            pl.BlockSpec((bb, D), lambda i: (i, 0)),
            pl.BlockSpec((bb, K, D), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bb, D), lambda i: (i, 0)),
            pl.BlockSpec((bb, D), lambda i: (i, 0)),
            pl.BlockSpec((bb, K, D), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, D), center.dtype),
            jax.ShapeDtypeStruct((B, D), ctx.dtype),
            jax.ShapeDtypeStruct((B, K, D), neg.dtype),
        ],
        interpret=interpret,
    )(center, ctx, neg, dout)

"""Public jit'd wrappers around the Pallas kernels.

Dispatch contract: ``impl="auto"`` runs the Pallas kernel on TPU and the pure
jnp reference elsewhere (interpret-mode Pallas is a correctness tool, not a
CPU execution engine). Tests force ``impl="pallas_interpret"`` to validate the
kernels on this CPU-only container.

``sgns_loss`` carries a custom_vjp whose forward/backward are both single
fused kernels (recompute-in-backward: residuals are just the inputs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import flash_decode as _fd
from . import hindex as _hx
from . import ref as _ref
from . import sgns as _sgns
from . import topk as _tk
from .ellmean import ell_mean_pallas

__all__ = [
    "sgns_loss",
    "ell_mean",
    "h_index_sweep",
    "decode_attention",
    "top_k_scores",
    "normalize_rows",
    "pad_dim",
]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pad_dim(x: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    size = x.shape[axis]
    rem = size % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, multiple - rem)
    return jnp.pad(x, pad)


# ---------------------------------------------------------------- SGNS ----


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _sgns_loss_inner(center, ctx, neg, block_b, interpret):
    return _sgns.sgns_loss_fwd_pallas(
        center, ctx, neg, block_b=block_b, interpret=interpret
    )


def _sgns_fwd(center, ctx, neg, block_b, interpret):
    loss = _sgns.sgns_loss_fwd_pallas(
        center, ctx, neg, block_b=block_b, interpret=interpret
    )
    return loss, (center, ctx, neg)


def _sgns_bwd(block_b, interpret, res, dout):
    center, ctx, neg = res
    dc, dx, dn = _sgns.sgns_loss_bwd_pallas(
        center, ctx, neg, dout, block_b=block_b, interpret=interpret
    )
    return dc, dx, dn


_sgns_loss_inner.defvjp(_sgns_fwd, _sgns_bwd)


def sgns_loss(center, ctx, neg, *, impl: str = "auto", block_b: int = 256):
    """Per-example SGNS loss, differentiable wrt all three inputs.

    center, ctx: (B, D); neg: (B, K, D) -> (B,) float32.
    """
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return _ref.sgns_loss_ref(center, ctx, neg)
    interpret = impl == "pallas_interpret"
    B, D = center.shape
    # pad D to the lane width and B to the block size
    cp = pad_dim(center, 1, 128)
    xp = pad_dim(ctx, 1, 128)
    np_ = pad_dim(neg, 2, 128)
    bb = min(block_b, B) if B % min(block_b, B) == 0 else B
    while B % bb:
        bb //= 2
    return _sgns_loss_inner(cp, xp, np_, bb, interpret)


# ------------------------------------------------------------- ELL mean ----


def _left_pack(idx, valid, sentinel):
    """Stable-sort each row so valid entries come first; returns (idx, cnt)."""
    order = jnp.argsort(~valid, axis=1, stable=True)
    packed = jnp.take_along_axis(idx, order, axis=1)
    cnt = valid.sum(axis=1).astype(jnp.int32)
    packed = jnp.where(
        jnp.arange(idx.shape[1])[None, :] < cnt[:, None], packed, sentinel
    )
    return packed, cnt


def ell_mean(idx, valid, emb, *, impl: str = "auto"):
    """Masked neighbour mean: out[i] = mean over valid j of emb[idx[i, j]].

    idx: (N, L) int32; valid: (N, L) bool; emb: (M, D) -> (N, D).
    """
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return _ref.ell_mean_ref(idx, valid, emb)
    interpret = impl == "pallas_interpret"
    packed, cnt = _left_pack(idx, valid, emb.shape[0] - 1)
    embp = pad_dim(emb, 1, 128)
    out = ell_mean_pallas(packed, cnt, embp, interpret=interpret)
    return out[:, : emb.shape[1]]


# -------------------------------------------------------------- h-index ----


def h_index_sweep(values, valid, est, *, impl: str = "auto"):
    """One row-masked h-index repair sweep: ``min(est, H(row))``.

    values: (R, W) neighbour core estimates; valid: (R, W) bool; est: (R,)
    current row estimates -> (R,) int32. The shared operator of the offline
    core fixpoint (``core.kcore``) and the online block repair
    (``serve.kcore_inc``). ``impl``: "ref" (sort-based semantics of record),
    "count" (sort-free counting, the non-TPU default), "pallas" /
    "pallas_interpret" (the ``kernels.hindex`` kernel).
    """
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "count"
    if impl == "ref":
        return _ref.h_index_ref(values, valid, est)
    if impl == "count":
        return _hx.h_index_count(values, valid, est)
    interpret = impl == "pallas_interpret"
    R, W = values.shape
    vals = jnp.where(valid, values.astype(jnp.int32), -1)
    if W % 128:  # pad lanes with -1 (never counted by any probed threshold)
        vals = jnp.pad(vals, ((0, 0), (0, 128 - W % 128)), constant_values=-1)
    rb = min(_hx.DEFAULT_BLOCK_R, 1 << max(R - 1, 0).bit_length())
    r_pad = -(-R // rb) * rb
    if r_pad != R:
        vals = jnp.pad(vals, ((0, r_pad - R), (0, 0)), constant_values=-1)
    est_p = jnp.maximum(est.astype(jnp.int32), 0)
    if r_pad != R:
        est_p = jnp.pad(est_p, (0, r_pad - R))
    return _hx.h_index_pallas(vals, est_p, block_r=rb, interpret=interpret)[:R]


# ----------------------------------------------------------------- top-k ----


def normalize_rows(x, *, eps: float = 1e-9):
    """L2-normalize rows in float32 (the cosine prep of the top-k scoring
    tile — ``link_scores`` and ``top_k_neighbors`` share this exact helper
    so service scores and kernel scores are the same numbers)."""
    x = x.astype(jnp.float32)
    n = jnp.sqrt(jnp.sum(x * x, axis=-1, keepdims=True))
    return x / jnp.maximum(n, eps)


def _order_topk(vals, idx, k):
    """Sort candidate lanes by (score desc, index asc) and slice to k."""
    key = jnp.where(idx < 0, jnp.iinfo(jnp.int32).max, idx)
    neg, _, sidx = jax.lax.sort((-vals, key, idx), dimension=1, num_keys=2)
    return -neg[:, :k], sidx[:, :k]


def top_k_scores(q, table, k, *, valid=None, impl: str = "auto",
                 block_n: int = 512):
    """Per-query top-k candidate rows by dot-product score.

    q: (Q, D); table: (N, D); valid: optional (N,) bool row mask. Returns
    ``(vals (Q, k) float32, idx (Q, k) int32)`` ordered by (score desc,
    index asc); -inf / -1 pad when fewer than k valid candidates exist.
    Cosine retrieval = pass both sides through :func:`normalize_rows` first.

    The Pallas path streams the table in ``block_n``-row tiles with an
    on-chip running top-k (``kernels.topk``) — the (Q, N) score matrix is
    never materialised. k is a compile-time constant (the reduce unrolls k
    tournament rounds); keep it <= ~128.
    """
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return _ref.topk_ref(q, table, k, valid=valid)
    interpret = impl == "pallas_interpret"
    Q, D = q.shape
    N = table.shape[0]
    qp = pad_dim(pad_dim(q.astype(jnp.float32), 1, 128), 0, 8)
    tp = pad_dim(table.astype(jnp.float32), 1, 128)
    bias = (
        jnp.where(valid, 0.0, -jnp.inf)
        if valid is not None
        else jnp.zeros(N, jnp.float32)
    )
    # pad rows to the block multiple; padding rows are masked via the bias
    tp = pad_dim(tp, 0, 128)
    bn = min(block_n, tp.shape[0])
    tp = pad_dim(tp, 0, bn)
    bias = jnp.pad(bias, (0, tp.shape[0] - N), constant_values=-jnp.inf)
    vals, idx = _tk.topk_pallas(
        qp, tp, bias, k=int(k), block_n=bn, interpret=interpret
    )
    vals, idx = _order_topk(vals[:Q], idx[:Q], int(k))
    if k > vals.shape[1]:  # k exceeds the padded lane count: pad out
        vals = jnp.pad(vals, ((0, 0), (0, k - vals.shape[1])),
                       constant_values=-jnp.inf)
        idx = jnp.pad(idx, ((0, 0), (0, k - idx.shape[1])),
                      constant_values=-1)
    return vals, idx


# ------------------------------------------------------ decode attention ----


def decode_attention(
    q, k, v, cache_len, *, softcap: float = 0.0, window=0, impl: str = "auto",
    block_s: int = 512, k_scale=None, v_scale=None,
):
    """Single-token GQA decode attention over a padded KV cache.

    q: (B, H, Dh); k, v: (B, S, Hkv, Dh); cache_len: (B,) -> (B, H, Dh).
    ``window`` may be a python int or a traced scalar (0 = full attention) —
    the sliding bound reaches the kernel as data, so scanned per-layer windows
    (gemma2 local/global) share one compilation.
    """
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return _ref.decode_attention_ref(
            q, k, v, cache_len, softcap=softcap, window=window,
            k_scale=k_scale, v_scale=v_scale,
        )
    interpret = impl == "pallas_interpret"
    S = k.shape[1]
    bs = min(block_s, S)
    while S % bs:
        bs //= 2
    window = jnp.asarray(window)
    win_lo = jnp.where(window > 0, jnp.maximum(cache_len - window, 0), 0)
    win_lo = jnp.broadcast_to(win_lo, cache_len.shape).astype(jnp.int32)
    return _fd.decode_attention_pallas(
        q, k, v, cache_len, win_lo, softcap=softcap, block_s=bs,
        interpret=interpret, k_scale=k_scale, v_scale=v_scale,
    )

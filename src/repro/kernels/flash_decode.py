"""GQA decode attention (flash-decode) Pallas kernel — serving hot spot.

Decode attention at long context is pure HBM traffic: one (H, Dh) query reads
an (S, Hkv, Dh) KV cache. The kernel streams the cache through VMEM in BS-row
blocks with an online-softmax accumulator per query group, so HBM traffic is
exactly one pass over K and V (the roofline floor) and nothing but the (H, Dh)
result is written back.

Supports the attention variants the assigned archs need at decode time:
  * GQA (H = G * Hkv query heads per cache head) — gemma2/qwen3/starcoder2/...
  * logit softcapping (gemma2: cap=50)
  * sliding-window masking (gemma2 local layers, zamba2 shared-attn at 500k)
  * per-batch cache lengths (continuous batching leaves ragged caches)

Forward-only by design: serving needs no gradients (DESIGN.md §5).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_S = 512
NEG_INF = -1.0e30


def _decode_kernel(
    len_ref, lo_ref, q_ref, k_ref, v_ref, *rest, scale, softcap, block_s, quant
):
    if quant:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    b = pl.program_id(0)
    s = pl.program_id(2)
    n_s = pl.num_programs(2)

    @pl.when(s == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (G, Dh)
    k = k_ref[0, 0].astype(jnp.float32)  # (BS, Dh)
    v = v_ref[0, 0].astype(jnp.float32)  # (BS, Dh)
    if quant:  # int8 cache: dequantise the streamed block in VMEM
        k = k * ks_ref[0, 0][:, None].astype(jnp.float32)
        v = v * vs_ref[0, 0][:, None].astype(jnp.float32)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (G, BS)
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)

    length = len_ref[b]
    win_lo = lo_ref[b]  # first visible position (sliding window), 0 = full
    pos = s * block_s + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    mask = jnp.logical_and(pos < length, pos >= win_lo)
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_ref[...]  # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)  # (G, 1)
    p = jnp.exp(logits - m_new)  # (G, BS)
    p = jnp.where(mask, p, 0.0)
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_new = alpha * acc_ref[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_new

    @pl.when(s == n_s - 1)
    def _():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("softcap", "block_s", "interpret")
)
def decode_attention_pallas(
    q, k, v, cache_len, win_lo, *, k_scale=None, v_scale=None,
    softcap=0.0, block_s=DEFAULT_BLOCK_S, interpret=False,
):
    """q: (B, H, Dh); k, v: (B, S, Hkv, Dh); cache_len, win_lo: (B,) -> (B, H, Dh).

    win_lo[b] is the first visible cache position (sliding-window lower bound,
    0 for full attention) — passed as data so a scanned per-layer window
    (gemma2 local/global alternation) needs no recompilation."""
    B, H, Dh = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    assert H % Hkv == 0
    G = H // Hkv
    bs = min(block_s, S)
    assert S % bs == 0, f"cache length {S} not divisible by block {bs}"
    scale = 1.0 / (Dh**0.5)

    qg = q.reshape(B, Hkv, G, Dh)
    kh = jnp.swapaxes(k, 1, 2)  # (B, Hkv, S, Dh)
    vh = jnp.swapaxes(v, 1, 2)

    quant = k_scale is not None
    in_specs = [
        pl.BlockSpec((1, 1, G, Dh), lambda b, h, s, *_: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, bs, Dh), lambda b, h, s, *_: (b, h, s, 0)),
        pl.BlockSpec((1, 1, bs, Dh), lambda b, h, s, *_: (b, h, s, 0)),
    ]
    args = [cache_len, win_lo, qg, kh, vh]
    if quant:
        in_specs += [pl.BlockSpec((1, 1, bs), lambda b, h, s, *_: (b, h, s))] * 2
        args += [jnp.swapaxes(k_scale, 1, 2), jnp.swapaxes(v_scale, 1, 2)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # cache_len, win_lo
        grid=(B, Hkv, S // bs),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, Dh), lambda b, h, s, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, Dh), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _decode_kernel, scale=scale, softcap=softcap, block_s=bs, quant=quant
    )
    out_dtype = q.dtype
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dh), out_dtype),
        interpret=interpret,
    )(*args)
    return out.reshape(B, H, Dh)

"""Blockwise score+reduce top-k Pallas kernel — the retrieval hot spot.

Nearest-neighbour retrieval over the serving store is one (Q, D) query block
against an (N, D) embedding table. Materialising the (Q, N) score matrix is
what kills scaling — at N in the millions it is gigabytes of HBM traffic per
batch. This kernel reuses the ``flash_decode`` streaming-tile idiom: the
table is streamed through VMEM in BN-row blocks, each block's scores are
reduced **on-chip** into a running per-query top-k accumulator (a (Q, K)
value/index pair in VMEM scratch), and nothing but the final (Q, K) result
is ever written back. HBM traffic is exactly one pass over the table.

Per grid step ``s`` (sequential over table blocks, like the decode kernel's
cache axis):

1. ``scores = q @ block.T + bias`` — one MXU matmul; ``bias`` carries row
   validity (0 for live rows, -inf for dead/padding rows), so masking costs
   an add, not a gather;
2. k rounds of extract-max / replace-worst tournament against the running
   accumulator. Each round pulls the block's best remaining candidate
   (``argmax`` takes the *first* hit, so ties break toward the lower index)
   and replaces the accumulator's worst entry when the candidate wins under
   the total order (score desc, index asc). A candidate that loses implies
   every remaining one loses too, so correctness needs no early exit.

The accumulator keeps at most k live lanes (lanes past k are pinned to +inf
so the worst-entry argmin never lands on them), and the output is *unsorted*
— the ``ops.top_k_scores`` wrapper does one (Q, K)-sized lexicographic sort
at the end, which is noise next to the streamed reduction.

Forward-only by design: retrieval needs no gradients.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_N = 512
NEG_INF = float("-inf")
IDX_PAD = jnp.iinfo(jnp.int32).max


def _topk_kernel(q_ref, t_ref, b_ref, ov_ref, oi_ref, vals_ref, idx_ref,
                 *, k, block_n):
    s = pl.program_id(0)
    n_s = pl.num_programs(0)
    Q = q_ref.shape[0]
    Kp = vals_ref.shape[1]
    lane = jax.lax.broadcasted_iota(jnp.int32, (Q, Kp), 1)

    @pl.when(s == 0)
    def _():
        # lanes < k are live (start at -inf, any real candidate beats them);
        # lanes >= k are pinned to +inf so the worst-entry argmin below can
        # never select them
        vals_ref[...] = jnp.where(lane < k, NEG_INF, jnp.inf)
        idx_ref[...] = jnp.full((Q, Kp), IDX_PAD, jnp.int32)

    q = q_ref[...]  # (Q, D)
    t = t_ref[...]  # (BN, D)
    scores = jax.lax.dot_general(
        q, t, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) + b_ref[...]  # (Q, BN); bias = -inf on dead/padding rows
    col = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)

    for _ in range(k):
        # block's best remaining candidate; first-hit argmax -> on ties the
        # lower (local, hence global: blocks stream in ascending row order)
        # index wins, matching the (score desc, index asc) total order
        c_val = jnp.max(scores, axis=1, keepdims=True)  # (Q, 1)
        c_arg = jnp.argmax(scores, axis=1)  # (Q,)
        c_idx = (s * block_n + c_arg).astype(jnp.int32)[:, None]  # (Q, 1)
        scores = jnp.where(col == c_arg[:, None], NEG_INF, scores)

        # accumulator's worst entry: min value, ties -> largest index
        vals = vals_ref[...]
        idx = idx_ref[...]
        w_val = jnp.min(vals, axis=1, keepdims=True)  # (Q, 1)
        at_w = vals == w_val
        w_idx = jnp.max(jnp.where(at_w, idx, -1), axis=1, keepdims=True)
        w_pos = jnp.argmax(at_w & (idx == w_idx), axis=1)  # (Q,)

        better = (c_val > w_val) | ((c_val == w_val) & (c_idx < w_idx))
        better = better & (c_val > NEG_INF)  # masked lanes never enter
        write = better & (lane == w_pos[:, None])
        vals_ref[...] = jnp.where(write, c_val, vals)
        idx_ref[...] = jnp.where(write, c_idx, idx)

    @pl.when(s == n_s - 1)
    def _():
        vals = vals_ref[...]
        filled = (lane < k) & (vals > NEG_INF)
        ov_ref[...] = jnp.where(filled, vals, NEG_INF)
        oi_ref[...] = jnp.where(filled, idx_ref[...], -1)


@functools.partial(
    jax.jit, static_argnames=("k", "block_n", "interpret")
)
def topk_pallas(q, table, bias, *, k, block_n=DEFAULT_BLOCK_N,
                interpret=False):
    """q: (Q, D); table: (N, D); bias: (N,) 0/-inf validity -> ((Q, Kp)
    float32 scores, (Q, Kp) int32 row indices), **unsorted**, -inf/-1 on
    unfilled lanes. Kp = k padded to the lane width; the caller sorts and
    slices. Q, D, N must already be padded (sublane/lane/block multiples).
    """
    Q, D = q.shape
    N = table.shape[0]
    bn = min(block_n, N)
    assert N % bn == 0, f"table rows {N} not divisible by block {bn}"
    Kp = -(-max(k, 1) // 128) * 128

    kernel = functools.partial(_topk_kernel, k=k, block_n=bn)
    return pl.pallas_call(
        kernel,
        grid=(N // bn,),
        in_specs=[
            pl.BlockSpec((Q, D), lambda s: (0, 0)),
            pl.BlockSpec((bn, D), lambda s: (s, 0)),
            pl.BlockSpec((1, bn), lambda s: (0, s)),
        ],
        out_specs=[
            pl.BlockSpec((Q, Kp), lambda s: (0, 0)),
            pl.BlockSpec((Q, Kp), lambda s: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, Kp), jnp.float32),
            jax.ShapeDtypeStruct((Q, Kp), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((Q, Kp), jnp.float32),
            pltpu.VMEM((Q, Kp), jnp.int32),
        ],
        interpret=interpret,
    )(q.astype(jnp.float32), table.astype(jnp.float32),
      bias.astype(jnp.float32).reshape(1, N))

"""Row-masked h-index kernel — the repair sweep of the k-core fixpoint.

One repair sweep computes, per candidate row, ``min(est, H(row))`` where
``H(row)`` is the h-index of the row's neighbour core estimates (max h such
that at least h entries are >= h). The reference formulation sorts each row
(``kernels.ref.h_index_ref``); XLA sort is a comparator network and is the
wrong shape for both the TPU VPU and the CPU backend.

The kernel here never sorts. ``H`` bounded by ``est`` is the largest
``h <= est`` with ``count(row >= h) >= h``; ``count(row >= h)`` is
non-increasing in ``h``, so a branchless per-row **binary search** finds it in
``ceil(log2(W))`` masked count-reductions — each one compare + lane-sum over
the (rows, W) block resident in VMEM, an ideal VPU shape. Two equivalent
implementations share the search:

* ``h_index_count`` — pure jnp, jit-friendly (traces into ``lax.while_loop``
  bodies); the non-TPU execution path of ``ops.h_index_sweep`` and the
  operator inside the fused incremental-repair fixpoint.
* ``h_index_pallas`` — the Pallas kernel (same ref / ``pallas_interpret`` /
  tpu triple as ``ellmean``/``sgns``), gridded over row blocks.

Invalid lanes are encoded as ``-1`` (strictly below every threshold the
search probes), so padding the width costs nothing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_R = 128


def _bisect_h(vals: jnp.ndarray, est: jnp.ndarray, n_iters: int) -> jnp.ndarray:
    """Shared branchless search: max h <= est with count(vals >= h) >= h.

    ``vals``: (R, W) int32 with invalid lanes already set to -1; ``est``:
    (R,) int32 non-negative upper bound. The invariant is pred(lo) true /
    answer in [lo, hi]; pred(0) holds trivially, and the range halves every
    step, so ``n_iters = W.bit_length()`` pins the answer exactly.
    """
    lo = jnp.zeros_like(est)
    hi = jnp.minimum(est, vals.shape[-1])

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi + 1) // 2
        cnt = jnp.sum((vals >= mid[:, None]).astype(jnp.int32), axis=-1)
        ok = cnt >= mid
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid - 1)

    lo, _ = jax.lax.fori_loop(0, n_iters, body, (lo, hi))
    return lo


def h_index_count(values: jnp.ndarray, valid: jnp.ndarray,
                  est: jnp.ndarray) -> jnp.ndarray:
    """``min(est, H(row))`` by counting — exact, sort-free, jit-friendly.

    values: (R, W) int; valid: (R, W) bool; est: (R,) int. Returns (R,) int32.
    """
    vals = jnp.where(valid, values.astype(jnp.int32), -1)
    est = jnp.maximum(est.astype(jnp.int32), 0)
    n_iters = max(1, int(values.shape[-1]).bit_length())
    return _bisect_h(vals, est, n_iters)


def _hindex_kernel(n_iters, vals_ref, est_ref, out_ref):
    vals = vals_ref[...]  # (RB, W) int32, invalid lanes = -1
    est = est_ref[...]  # (RB,) int32
    out_ref[...] = _bisect_h(vals, est, n_iters)


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def h_index_pallas(vals, est, *, block_r: int = DEFAULT_BLOCK_R,
                   interpret: bool = False):
    """Blocked h-index search: out[i] = max h <= est[i] with cnt(row >= h) >= h.

    vals: (R, W) int32, invalid lanes = -1, W ideally a lane multiple;
    est: (R,) int32 non-negative. R must divide into ``block_r`` blocks.
    """
    R, W = vals.shape
    rb = min(block_r, R)
    assert R % rb == 0, f"rows {R} not divisible by block {rb}"
    n_iters = max(1, int(W).bit_length())
    return pl.pallas_call(
        functools.partial(_hindex_kernel, n_iters),
        grid=(R // rb,),
        in_specs=[
            pl.BlockSpec((rb, W), lambda i: (i, 0)),
            pl.BlockSpec((rb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((rb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((R,), jnp.int32),
        interpret=interpret,
    )(vals, est)

"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics of record: kernels must match them (allclose) across
shape/dtype sweeps in interpret mode, and they double as the CPU execution
path (interpret-mode Pallas is a Python loop — fine for validation, wrong for
CPU benchmarking).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "sgns_loss_ref",
    "sgns_grads_ref",
    "ell_mean_ref",
    "h_index_ref",
    "decode_attention_ref",
    "topk_ref",
]


def _log_sigmoid(x):
    # stable: -softplus(-x)
    return -jax.nn.softplus(-x)


def sgns_loss_ref(center: jnp.ndarray, ctx: jnp.ndarray, neg: jnp.ndarray) -> jnp.ndarray:
    """SkipGram negative-sampling loss per example.

    center, ctx: (B, D); neg: (B, K, D). Returns (B,) float32.
    Logits accumulate in float32 regardless of input dtype.
    """
    c = center.astype(jnp.float32)
    x = ctx.astype(jnp.float32)
    n = neg.astype(jnp.float32)
    pos = jnp.sum(c * x, axis=-1)
    negl = jnp.einsum("bkd,bd->bk", n, c)
    return -(_log_sigmoid(pos) + jnp.sum(_log_sigmoid(-negl), axis=-1))


def sgns_grads_ref(center, ctx, neg, dout):
    """Analytic gradients of sum(sgns_loss * dout) wrt (center, ctx, neg)."""
    c = center.astype(jnp.float32)
    x = ctx.astype(jnp.float32)
    n = neg.astype(jnp.float32)
    d = dout.astype(jnp.float32)
    pos = jnp.sum(c * x, axis=-1)
    negl = jnp.einsum("bkd,bd->bk", n, c)
    dpos = (jax.nn.sigmoid(pos) - 1.0) * d  # (B,)
    dneg = jax.nn.sigmoid(negl) * d[:, None]  # (B, K)
    dcenter = dpos[:, None] * x + jnp.einsum("bk,bkd->bd", dneg, n)
    dctx = dpos[:, None] * c
    dnegs = dneg[:, :, None] * c[:, None, :]
    return (
        dcenter.astype(center.dtype),
        dctx.astype(ctx.dtype),
        dnegs.astype(neg.dtype),
    )


def ell_mean_ref(idx: jnp.ndarray, valid: jnp.ndarray, emb: jnp.ndarray) -> jnp.ndarray:
    """Masked neighbour mean over an ELL table.

    idx: (N, L) int32 rows into emb; valid: (N, L) bool; emb: (M, D).
    Rows with no valid neighbour return zeros.
    """
    gathered = emb[idx].astype(jnp.float32)  # (N, L, D)
    m = valid.astype(jnp.float32)[..., None]
    s = jnp.sum(gathered * m, axis=1)
    cnt = jnp.sum(m, axis=1)
    return (s / jnp.maximum(cnt, 1.0)).astype(emb.dtype)


def h_index_ref(values: jnp.ndarray, valid: jnp.ndarray,
                est: jnp.ndarray) -> jnp.ndarray:
    """Row-masked h-index repair sweep: ``min(est, H(row))``, by sorting.

    values: (R, W) neighbour estimates; valid: (R, W) bool; est: (R,) current
    row estimates. H = max h such that at least h valid entries are >= h.
    The sort-based formulation is the semantics of record; the Pallas kernel
    (``kernels.hindex``) computes the same quantity by binary-searched
    threshold counting and must match it exactly.
    """
    vals = jnp.where(valid, values.astype(jnp.int32), -1)
    svals = -jnp.sort(-vals, axis=-1)  # descending
    ranks = jnp.arange(1, vals.shape[-1] + 1, dtype=svals.dtype)
    ok = svals >= ranks
    h = jnp.max(jnp.where(ok, ranks, 0), axis=-1)
    return jnp.minimum(est.astype(jnp.int32), h)


def topk_ref(q: jnp.ndarray, table: jnp.ndarray, k: int,
             valid: jnp.ndarray = None) -> tuple:
    """Dense top-k by dot-product score — the semantics of record.

    q: (Q, D); table: (N, D); valid: optional (N,) bool row mask. Returns
    ``(vals (Q, k) float32, idx (Q, k) int32)`` ordered by the total order
    (score desc, index asc) — ties always break toward the lower row index,
    which is what makes results exactly comparable across block sizes and
    shard counts. Missing candidates (k > #valid rows) pad with -inf / -1.

    Materialises the full (Q, N) score matrix; the Pallas kernel
    (``kernels.topk``) streams it blockwise and must match this exactly.
    """
    scores = jnp.einsum(
        "qd,nd->qn", q.astype(jnp.float32), table.astype(jnp.float32)
    )
    if valid is not None:
        scores = jnp.where(valid[None, :], scores, -jnp.inf)
    Q, N = scores.shape
    idx = jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32)[None, :], (Q, N))
    neg, sidx = jax.lax.sort((-scores, idx), dimension=1, num_keys=2)
    kk = min(k, N)
    vals = -neg[:, :kk]
    sidx = jnp.where(vals > -jnp.inf, sidx[:, :kk], -1)
    if kk < k:
        vals = jnp.pad(vals, ((0, 0), (0, k - kk)),
                       constant_values=-jnp.inf)
        sidx = jnp.pad(sidx, ((0, 0), (0, k - kk)), constant_values=-1)
    return vals, sidx


def decode_attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    cache_len: jnp.ndarray,
    *,
    softcap: float = 0.0,
    window: int = 0,
    k_scale=None,
    v_scale=None,
) -> jnp.ndarray:
    """Single-token GQA decode attention.

    q: (B, H, Dh) for the new token; k, v: (B, S, Hkv, Dh) cache (padded to S);
    cache_len: (B,) valid lengths. H = G * Hkv. Sliding ``window`` > 0 keeps
    only the last ``window`` positions; it may be a traced scalar (0 disables).
    Returns (B, H, Dh).
    """
    B, H, Dh = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, Dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if k_scale is not None:  # int8 cache: dequantise with (B, S, Hkv) scales
        kf = kf * k_scale[..., None].astype(jnp.float32)
    if v_scale is not None:
        vf = vf * v_scale[..., None].astype(jnp.float32)
    logits = jnp.einsum("bhgd,bshd->bhgs", qf, kf) / jnp.sqrt(Dh).astype(jnp.float32)
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    pos = jnp.arange(S)[None, :]
    mask = pos < cache_len[:, None]
    window = jnp.asarray(window)
    win_lo = jnp.where(window > 0, cache_len[:, None] - window, 0)
    mask = mask & (pos >= win_lo)
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, vf)
    return out.reshape(B, H, Dh).astype(q.dtype)

"""Token data pipeline: deterministic synthetic corpus, packing, prefetch.

No network access, so the corpus is a seeded Zipf stream (heavy-tailed like
natural text) — deterministic per (seed, step), which makes restarts exact:
the loader is stateless given the step counter, the strongest checkpoint
guarantee a pipeline can offer (nothing to snapshot).

``PrefetchIterator`` overlaps host batch assembly with device compute via a
background thread (the host side of async dispatch).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["SyntheticLMData", "PrefetchIterator", "pack_documents"]


class SyntheticLMData:
    """Deterministic Zipf token stream shaped like a causal-LM batch."""

    def __init__(self, vocab_size: int, batch: int, seq_len: int, seed: int = 0,
                 zipf_a: float = 1.3):
        self.vocab_size = vocab_size
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self.zipf_a = zipf_a

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        raw = rng.zipf(self.zipf_a, size=(self.batch, self.seq_len + 1))
        toks = (raw - 1) % self.vocab_size
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "targets": toks[:, 1:].astype(np.int32),
            "mask": np.ones((self.batch, self.seq_len), np.float32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def pack_documents(docs, seq_len: int, pad_id: int = 0, eos_id: int = 1):
    """Greedy sequence packing: concatenate docs with EOS, split into rows.

    Returns (tokens (N, seq_len), mask) — mask zeroes padding. Standard
    throughput trick: no row is mostly padding.
    """
    stream: list[int] = []
    for d in docs:
        stream.extend(int(t) for t in d)
        stream.append(eos_id)
    n = max(1, (len(stream) + seq_len - 1) // seq_len)
    out = np.full((n, seq_len), pad_id, np.int32)
    mask = np.zeros((n, seq_len), np.float32)
    for i in range(n):
        row = stream[i * seq_len : (i + 1) * seq_len]
        out[i, : len(row)] = row
        mask[i, : len(row)] = 1.0
    return out, mask


class PrefetchIterator:
    """Wrap an iterator with a bounded background prefetch queue."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._done = object()
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                self._q.put(item)
        except BaseException as e:  # surfaced on next()
            self._err = e
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item

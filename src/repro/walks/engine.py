"""Random-walk engine (paper §1.2.4) — static-shaped, vmapped, on-device.

Walks over the padded ELL adjacency are a ``lax.scan`` over steps; a batch of
walks is one program (no per-node Python). Uniform (DeepWalk) and (p, q)
biased (Node2Vec) transition rules are provided. Dead ends (degree 0) hold
position; datasets exclude isolated nodes per the paper's 0-core == 1-core
assumption, so this only triggers on the sentinel row.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.graph.csr import EllGraph

__all__ = ["random_walks", "node2vec_walks"]


@partial(jax.jit, static_argnames=("length",))
def _uniform_walks(neighbours, degrees, roots, length: int, key):
    def step(cur, key):
        deg = degrees[cur]
        u = jax.random.randint(key, cur.shape, 0, jnp.maximum(deg, 1))
        nxt = neighbours[cur, u]
        nxt = jnp.where(deg > 0, nxt, cur)
        return nxt, cur

    keys = jax.random.split(key, length)
    last, trace = jax.lax.scan(step, roots, keys)
    del last
    return jnp.swapaxes(trace, 0, 1)  # (n_walks, length)


def random_walks(ell: EllGraph, roots: jnp.ndarray, length: int, key) -> jnp.ndarray:
    """Uniform random walks. roots: (W,) int32 -> (W, length) int32."""
    return _uniform_walks(ell.neighbours, ell.degrees, roots, length, key)


@partial(jax.jit, static_argnames=("length",))
def _n2v_walks(neighbours, degrees, roots, length: int, key, p: float, q: float):
    n_sentinel = neighbours.shape[0] - 1
    valid_tbl = neighbours != n_sentinel

    def first(cur, key):
        deg = degrees[cur]
        u = jax.random.randint(key, cur.shape, 0, jnp.maximum(deg, 1))
        nxt = neighbours[cur, u]
        return jnp.where(deg > 0, nxt, cur)

    def step(state, key):
        prev, cur = state
        cand = neighbours[cur]  # (W, L) sorted, sentinel-padded
        valid = valid_tbl[cur]
        prev_row = neighbours[prev]  # (W, L) sorted
        # membership of each candidate in N(prev) via row-wise searchsorted
        idx = jax.vmap(jnp.searchsorted)(prev_row, cand)
        idx = jnp.clip(idx, 0, prev_row.shape[-1] - 1)
        in_prev = jnp.take_along_axis(prev_row, idx, axis=-1) == cand
        w = jnp.where(
            cand == prev[:, None],
            1.0 / p,
            jnp.where(in_prev, 1.0, 1.0 / q),
        )
        logits = jnp.where(valid, jnp.log(w), -jnp.inf)
        g = jax.random.gumbel(key, cand.shape)
        choice = jnp.argmax(logits + g, axis=-1)
        nxt = jnp.take_along_axis(cand, choice[:, None], axis=-1)[:, 0]
        nxt = jnp.where(degrees[cur] > 0, nxt, cur)
        return (cur, nxt), cur

    k0, krest = key, None
    keys = jax.random.split(k0, length)
    second = first(roots, keys[0])
    (_, _), trace = jax.lax.scan(step, (roots, second), keys[1:])
    out = jnp.concatenate([roots[None], trace], axis=0)
    return jnp.swapaxes(out, 0, 1)


def node2vec_walks(
    ell: EllGraph, roots: jnp.ndarray, length: int, key, p: float = 1.0, q: float = 1.0
) -> jnp.ndarray:
    """Node2Vec (p, q)-biased walks. p=q=1 reduces to DeepWalk's uniform walk."""
    return _n2v_walks(ell.neighbours, ell.degrees, roots, length, key, p, q)

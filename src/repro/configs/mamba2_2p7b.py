"""mamba2-2.7b [ssm] — SSD (state-space duality), attention-free.

64L d_model=2560 vocab=50280 (padded to 50304 for sharding) ssm_state=128
[arXiv:2405.21060; unverified]

n_groups=8 on B/C (upstream uses 1) for TP shardability — noted in DESIGN.md.
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=1,                    # unused (attention-free)
    n_kv_heads=1,
    head_dim=1,
    d_ff=0,
    vocab_size=50304,             # 50280 padded to a 64-multiple
    norm_type="rmsnorm",
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=8, chunk=256),
    tie_embeddings=True,
)

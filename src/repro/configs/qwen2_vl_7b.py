"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution; vision frontend stubbed.

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064
[arXiv:2409.12191; hf]

The vision frontend is a STUB: input_specs() provides precomputed patch
embeddings that replace the first n_vision_patches token slots, plus the
(3, B, S) t/h/w position streams M-RoPE consumes.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    mrope_sections=(16, 24, 24),
    rope_theta=1000000.0,
    frontend="vision",
    n_vision_patches=1024,
    tie_embeddings=False,
)

"""Architecture registry: ``--arch <id>`` resolves here.

``get_config(id)`` returns the exact assigned config; ``sharding_overrides(id)``
returns per-arch logical-rule overrides (e.g. grok's TP+FSDP 2D expert
sharding). The paper's own workload registers as ``deepwalk-web1b``.
"""
from __future__ import annotations

from typing import Dict

from repro.models.config import ModelConfig

from . import (
    deepwalk_web,
    gemma2_2b,
    grok1_314b,
    mamba2_2p7b,
    moonshot_v1_16b_a3b,
    nemotron4_15b,
    qwen2_vl_7b,
    qwen3_4b,
    seamless_m4t_large_v2,
    starcoder2_7b,
    zamba2_7b,
)

REGISTRY: Dict[str, ModelConfig] = {
    c.CONFIG.name: c.CONFIG
    for c in (
        gemma2_2b,
        nemotron4_15b,
        starcoder2_7b,
        qwen3_4b,
        zamba2_7b,
        mamba2_2p7b,
        seamless_m4t_large_v2,
        qwen2_vl_7b,
        grok1_314b,
        moonshot_v1_16b_a3b,
    )
}

GRAPH_REGISTRY = {deepwalk_web.CONFIG.name: deepwalk_web.CONFIG}

# Per-arch logical-axis rule overrides (merged over distributed.sharding
# defaults). grok-1's experts are too few (8) to shard on the 16-way model
# axis, and its weights are too big for TP alone: shard every expert matrix
# 2D over data x model (FSDP+TP).
SHARDING_OVERRIDES = {
    # heads (8/36/28) don't divide the 16-way model axis: weights fall back
    # to head_dim TP (rule default) and attention activations go Ulysses
    # (sequence-sharded q with replicated GQA KV).
    "gemma2-2b": {"attn_seq": ("model",)},
    "starcoder2-7b": {"attn_seq": ("model",)},
    "qwen2-vl-7b": {"attn_seq": ("model",)},
    "grok-1-314b": {
        # FSDP over the d_model dim of all weight matrices. (§Perf iteration
        # 8 tried scoping FSDP to expert weights only — refuted: the data-axis
        # gathers are expert-weight traffic, which FSDP needs either way, and
        # un-sharding attention cost +1.2 GiB args / +5 GiB temp.)
        "embed": ("data",),
        "expert_embed": ("data",),
        "expert_mlp": ("model",),
        "experts": (),  # 8 experts: replicated grouping, matrices 2D-sharded
        # d_model of activations sharded over model: bounds the (G, E, C, d)
        # expert dispatch buffers that dominate MoE live memory
        "act_embed": ("model",),
    },
    "moonshot-v1-16b-a3b": {
        "experts": ("model",),  # 64 experts: true expert parallelism
        "expert_mlp": (),
    },
    # SSM archs: shard the wide inner dim and the ssd heads over model
    "mamba2-2.7b": {"mlp": ("model",), "ssm_heads": ("model",)},
    "zamba2-7b": {"mlp": ("model",), "ssm_heads": ("model",)},
    # the paper's workload: 2D row-sharding of the embedding tables over
    # data x model — the axis that fits a 10^9-node graph on a pod — and the
    # pair batch sharded over both axes (B/256 ids per device)
    "deepwalk-web1b": {"vocab": ("data", "model"), "batch": ("data", "model")},
}


def list_archs():
    return sorted(REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; options: {list_archs()}")
    return REGISTRY[name]


def sharding_overrides(name: str) -> dict:
    return dict(SHARDING_OVERRIDES.get(name, {}))

"""gemma2-2b [dense] — local+global alternating attention, logit softcaps.

26L d_model=2304 8H (GQA kv=4, head_dim 256) d_ff=9216 vocab=256000
[arXiv:2408.00118; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    mlp_type="geglu",
    norm_type="rmsnorm",
    post_norm=True,               # gemma2 post-attn/post-mlp norms
    attn_softcap=50.0,
    final_softcap=30.0,
    sliding_window=4096,
    local_global_pattern=True,    # even layers local (4096), odd global
    rope_theta=10000.0,
    tie_embeddings=True,
)

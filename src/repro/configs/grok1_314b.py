"""grok-1-314b [moe] — 8 experts top-2, logit softcaps.

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072
[hf:xai-org/grok-1; unverified]

Memory posture (DESIGN.md §4): adafactor optimizer (Adam fp32 states would
not fit 256 x 16 GB), expert weights 2D-sharded data x model (TP+FSDP) via
the embed->data / expert_mlp->model rule overrides in configs/__init__.py.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    mlp_type="geglu",             # experts are gated-GELU
    norm_type="rmsnorm",
    attn_softcap=30.0,
    final_softcap=30.0,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32768, capacity_factor=1.25),
    rope_theta=10000.0,
    tie_embeddings=True,
    optimizer="adafactor",
)

"""The assigned input-shape sets and their ShapeDtypeStruct input specs.

Four shapes per LM arch (train_4k / prefill_32k / decode_32k / long_500k);
``decode_*``/``long_*`` lower ``serve_step`` (one token against a cache of
seq_len), not ``train_step``. ``long_500k`` requires sub-quadratic attention:
it runs for ssm/hybrid families and is marked skipped (with the reason) for
pure full-attention archs — see DESIGN.md §4.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import init_cache

__all__ = ["ShapeSpec", "SHAPES", "shape_supported", "input_specs", "cache_specs_avals"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def shape_supported(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return (
            False,
            "long_500k needs sub-quadratic attention; "
            f"{cfg.name} is full-attention (family={cfg.family})",
        )
    return True, ""


def _token_batch(cfg: ModelConfig, B: int, S: int, *, train: bool):
    i32 = jnp.int32
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    if train:
        batch["targets"] = jax.ShapeDtypeStruct((B, S), i32)
        batch["mask"] = jax.ShapeDtypeStruct((B, S), jnp.float32)
    if cfg.family == "encdec":
        # audio frontend stub: precomputed frame embeddings, 4x compressed
        batch["src_embeds"] = jax.ShapeDtypeStruct(
            (B, max(S // 4, 8), cfg.d_model), cfg.cdtype()
        )
    if cfg.frontend == "vision":
        P = min(cfg.n_vision_patches, S)
        batch["vision_embeds"] = jax.ShapeDtypeStruct((B, P, cfg.d_model), cfg.cdtype())
        batch["positions"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
    return batch


def batch_logical_names(cfg: ModelConfig, *, train: bool):
    names = {"tokens": ("batch", "seq")}
    if train:
        names["targets"] = ("batch", "seq")
        names["mask"] = ("batch", "seq")
    if cfg.family == "encdec":
        names["src_embeds"] = ("batch", "frames", "act_embed")
    if cfg.frontend == "vision":
        names["vision_embeds"] = ("batch", None, "act_embed")
        names["positions"] = (None, "batch", "seq")
    return names


def cache_specs_avals(cfg: ModelConfig, batch: int, max_len: int, enc_len: int = 0):
    """ShapeDtypeStructs of the decode cache (no allocation)."""
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len, enc_len))


def input_specs(cfg: ModelConfig, shape: ShapeSpec):
    """Returns (args tuple of ShapeDtypeStructs pytrees) for the step kind."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return (_token_batch(cfg, B, S, train=True),)
    if shape.kind == "prefill":
        return (_token_batch(cfg, B, S, train=False),)
    if shape.kind == "decode":
        enc_len = max(S // 4, 8) if cfg.family == "encdec" else 0
        cache = cache_specs_avals(cfg, B, S, enc_len)
        tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        return (cache, tokens)
    raise ValueError(shape.kind)

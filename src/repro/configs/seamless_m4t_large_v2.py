"""seamless-m4t-large-v2 [audio] — encoder-decoder backbone, frontend stubbed.

24L (enc) + 24L (dec) d_model=1024 16H (MHA) d_ff=8192 vocab=256206
(padded to 256208) [arXiv:2308.11596; hf]

The audio frontend is a STUB: input_specs() provides precomputed frame
embeddings (B, T/4, d) as the encoder input.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256208,            # 256206 padded to a 16-multiple
    mlp_type="gelu",
    norm_type="layernorm",
    frontend="audio",
    rope_theta=10000.0,
    tie_embeddings=True,
)

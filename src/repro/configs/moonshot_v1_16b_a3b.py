"""moonshot-v1-16b-a3b (Moonlight-16B-A3B) [moe] — 64 experts top-6.

48L d_model=2048 16H (MHA kv=16) expert d_ff=1408 vocab=163840
[hf:moonshotai/Moonlight-16B-A3B; hf]
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=163840,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, capacity_factor=1.25),
    rope_theta=10000.0,
    tie_embeddings=True,
)

"""zamba2-7b [hybrid] — Mamba2 blocks + one shared attention block (LoRA'd).

81L d_model=3584 32H (kv=32, MHA) d_ff=14336 vocab=32000 ssm_state=64
[arXiv:2411.15242; unverified]

Layout: 13 groups of (6 mamba + shared-attn invocation) + 3 trailing mamba.
The shared block's attention is bounded by a 4096 sliding window so the
long_500k decode cell stays sub-quadratic in cache traffic (DESIGN.md §4).
n_groups=8 on B/C projections for TP shardability (upstream uses 2).
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,                  # mamba blocks; shared attn every 6
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    sliding_window=4096,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, n_groups=8, chunk=256),
    shared_every=6,
    shared_lora_rank=128,
    rope_theta=10000.0,
    tie_embeddings=True,
)

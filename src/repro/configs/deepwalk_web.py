"""deepwalk-web1b — the paper's own workload at production scale.

SGNS embedding training for a web-scale graph: 2^27 (~134M) nodes, dim 128,
5 negatives. The two embedding tables are row-sharded over the `model` axis
(vocab rule) — this is the memory scaling axis that lets a billion-node graph
fit a pod — and the (center, context, negatives) id batches are data-parallel.
CoreWalk/k-core enter as *data pipeline* operators (they shape the walk
corpus, not the step), so this one train_step serves every §2 pipeline.
"""
import dataclasses

__all__ = ["GraphEmbedConfig", "CONFIG"]


@dataclasses.dataclass(frozen=True)
class GraphEmbedConfig:
    name: str = "deepwalk-web1b"
    n_nodes: int = 1 << 27
    dim: int = 128
    n_neg: int = 5
    global_batch: int = 1 << 20  # (center, context) pairs per step
    param_dtype: str = "float32"


CONFIG = GraphEmbedConfig()

"""K-core decomposition (paper §1.2.3) — the degeneracy primitive.

Two implementations:

* ``core_numbers_host`` — Matula–Beck bucket peeling, O(E), numpy. Used for
  dataset preparation and as the oracle for the device path.
* ``core_numbers_jax`` — jit-able fixed point of the neighbourhood h-index
  operator on the padded ELL adjacency (Lü et al., "The H-index of a network
  node", 2016): initialise c⁰ = deg and iterate
  c^{t+1}(v) = H({c^t(u) : u ∈ N(v)}) until convergence; the fixed point is
  exactly the core number. Each sweep is a gather + per-row sorted reduction,
  i.e. TPU-friendly (no serial peeling), and converges in a few dozen sweeps
  on real graphs.

Shell/core helpers used by CoreWalk (§2.1) and propagation (§2.2) live here
too: ``core_mask`` (k-core membership) and ``shells`` (nodes per core index).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.csr import EllGraph, Graph
from repro.kernels import ops as _kernel_ops

__all__ = [
    "core_numbers_host",
    "core_numbers_rounds",
    "core_numbers_shell_peel",
    "core_numbers_jax",
    "h_index_sweep",
    "degeneracy",
    "core_mask",
    "shells",
    "kcore_subgraph",
]


def core_numbers_host(g: Graph) -> np.ndarray:
    """Matula–Beck O(E) peeling. Returns (n_nodes,) int32 core numbers."""
    n = g.n_nodes
    deg = g.degrees().astype(np.int64)
    max_deg = int(deg.max()) if n else 0
    # bucket sort nodes by degree
    bin_start = np.zeros(max_deg + 2, dtype=np.int64)
    counts = np.bincount(deg, minlength=max_deg + 1)
    np.cumsum(counts, out=bin_start[1:])
    pos = np.empty(n, dtype=np.int64)
    vert = np.empty(n, dtype=np.int64)
    fill = bin_start[:-1].copy()
    for v in range(n):
        pos[v] = fill[deg[v]]
        vert[pos[v]] = v
        fill[deg[v]] += 1
    bin_ptr = bin_start[:-1].copy()
    core = deg.copy()
    for i in range(n):
        v = vert[i]
        for u in g.neighbours(v):
            u = int(u)
            if core[u] > core[v]:
                du = core[u]
                pu = pos[u]
                pw = bin_ptr[du]
                w = vert[pw]
                if u != w:
                    pos[u], pos[w] = pw, pu
                    vert[pu], vert[pw] = w, u
                bin_ptr[du] += 1
                core[u] -= 1
    return core.astype(np.int32)


def core_numbers_rounds(n_nodes: int, arc_src: np.ndarray,
                        arc_dst: np.ndarray) -> np.ndarray:
    """Vectorized Matula–Beck: peel whole degree-``<=k`` layers per round.

    ``arc_src``/``arc_dst`` hold every arc (both directions of each edge),
    unsorted. Same exact core numbers as ``core_numbers_host``, but each
    round strips *all* currently peelable nodes with numpy boolean masks and
    one grouped degree decrement, so the Python-level loop runs O(#rounds)
    times (graph-diameter-ish) instead of O(n). This is the host fallback of
    the online block repair: it reads the streaming graph's arc arrays
    directly, no CSR snapshot required.
    """
    n = int(n_nodes)
    if n == 0:
        return np.zeros(0, np.int32)
    arc_src = np.asarray(arc_src, np.int64)
    arc_dst = np.asarray(arc_dst, np.int64)
    deg = np.bincount(arc_src, minlength=n).astype(np.int64)
    core = np.zeros(n, np.int32)
    active = deg > 0
    k = 0
    n_active = int(active.sum())
    while n_active:
        k = max(k, int(deg[active].min()))
        frontier = active & (deg <= k)
        while frontier.any():
            core[frontier] = k
            active &= ~frontier
            n_active -= int(frontier.sum())
            # arcs leaving the peeled layer into still-active nodes; arcs
            # between two peeled nodes need no decrement (both are gone)
            m = frontier[arc_src] & active[arc_dst]
            if m.any():
                deg -= np.bincount(arc_dst[m], minlength=n)
            frontier = active & (deg <= k)
        # every inner round scans the arc arrays: drop arcs whose endpoints
        # are peeled once a level finishes, so the scans shrink geometrically
        if len(arc_src) > 1024:
            keep = active[arc_src]
            keep &= active[arc_dst]
            if int(keep.sum()) * 2 < len(arc_src):
                arc_src, arc_dst = arc_src[keep], arc_dst[keep]
    return core


def core_numbers_shell_peel(
    n_nodes: int,
    arc_src: np.ndarray,
    arc_dst: np.ndarray,
    peel: np.ndarray,
    degrees: np.ndarray,
    hi: int,
) -> Tuple[np.ndarray, bool]:
    """Boundary-frozen rounds peel of the sub-level set ``peel``.

    Incremental counterpart of :func:`core_numbers_rounds`: only the nodes in
    ``peel`` (the shells at level ``<= hi`` *before* the mutation block) are
    re-peeled; everything above stays frozen and acts purely as boundary
    support. ``degrees`` must be every node's **full** current degree (frozen
    neighbours included), and ``arc_src``/``arc_dst`` only the arcs with both
    endpoints inside ``peel`` — peeling a node therefore decrements peel-side
    neighbours only, while its frozen support is baked into the starting
    degrees, exactly as if the upper shells were peeled last.

    Returns ``(core, ok)`` where ``core`` holds the recomputed levels of the
    ``peel`` nodes (untouched elsewhere). Soundness: anchoring the frozen
    side *over-estimates* the peel side pointwise, so if the frozen
    assumption is wrong (the block pushed some peeled node past ``hi``,
    which could in turn invalidate frozen levels) the over-estimate must
    also push a node past ``hi`` — detected as a survivor whose remaining
    degree exceeds ``hi``, returned as ``ok=False`` with the result
    discarded. ``ok=True`` certifies the freeze and makes the result exact.
    With no insertions (levels only fall) a window top ``hi >= `` the max
    touched level can never ceiling-hit.
    """
    n = int(n_nodes)
    core = np.zeros(n, np.int32)
    if n == 0:
        return core, True
    arc_src = np.asarray(arc_src, np.int64)
    arc_dst = np.asarray(arc_dst, np.int64)
    deg = np.asarray(degrees, np.int64).copy()
    active = np.asarray(peel, bool).copy()
    core[active] = 0  # isolated / degree-0 peel nodes resolve to level 0
    active &= deg > 0
    k = 0
    n_active = int(active.sum())
    while n_active:
        k = max(k, int(deg[active].min()))
        if k > hi:  # survivor past the ceiling: freeze assumption violated
            return core, False
        frontier = active & (deg <= k)
        while frontier.any():
            core[frontier] = k
            active &= ~frontier
            n_active -= int(frontier.sum())
            m = frontier[arc_src] & active[arc_dst]
            if m.any():
                deg -= np.bincount(arc_dst[m], minlength=n)
            frontier = active & (deg <= k)
        if len(arc_src) > 1024:  # same geometric arc-drop as the full peel
            keep = active[arc_src]
            keep &= active[arc_dst]
            if int(keep.sum()) * 2 < len(arc_src):
                arc_src, arc_dst = arc_src[keep], arc_dst[keep]
    return core, True


def h_index_sweep(values: jnp.ndarray, valid: jnp.ndarray,
                  est: jnp.ndarray, *, impl: str = "ref") -> jnp.ndarray:
    """One row-masked h-index repair sweep (the shared operator).

    ``values`` is the (R, W) matrix of neighbour core estimates for R
    candidate rows, ``valid`` masks the real entries, ``est`` is the (R,)
    current estimate of the candidate rows themselves. Returns
    ``min(est, H(row))`` — monotone non-increasing, so iterating from any
    upper bound descends to the greatest fixed point below it. Both the
    offline fixpoint (``core_numbers_jax``, all rows) and the incremental
    repair (``repro.serve.kcore_inc``, candidate rows only) drive this same
    operator; the mask is simply which rows the caller gathers. ``impl``
    selects the backend (``kernels.ops.h_index_sweep``): the sort-based ref,
    the sort-free counting search, or the Pallas kernel.
    """
    return _kernel_ops.h_index_sweep(values, valid, est, impl=impl)


_h_index_sweep_jit = jax.jit(h_index_sweep, static_argnames=("impl",))


@partial(jax.jit, static_argnames=("max_sweeps", "impl"))
def _core_fixpoint(neighbours, degrees, max_sweeps: int, impl: str = "ref"):
    n_plus_1 = neighbours.shape[0]
    valid = neighbours != (n_plus_1 - 1)
    core0 = degrees.astype(jnp.int32)

    def cond(state):
        core, prev, it = state
        return jnp.logical_and(it < max_sweeps, jnp.any(core != prev))

    def body(state):
        core, _, it = state
        nbr_core = core[neighbours]  # (N+1, L)
        new = h_index_sweep(nbr_core, valid, core, impl=impl)
        new = new.at[-1].set(0)  # sentinel row
        return new, core, it + 1

    core, _, sweeps = jax.lax.while_loop(cond, body, (core0, core0 - 1, 0))
    return core, sweeps


def core_numbers_jax(ell: EllGraph, max_sweeps: int = 256,
                     impl: str = "auto") -> jnp.ndarray:
    """Core numbers via the h-index fixed point. Returns (n_nodes,) int32.

    Exact when the ELL table is not width-capped (uses true degrees); with a
    capped table the result is a lower bound (documented; tests use uncapped).
    ``impl="auto"`` backs each sweep with the Pallas h-index kernel on TPU
    and the counting search elsewhere (XLA sort is the slow path on both).
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "count"
    core, _ = _core_fixpoint(ell.neighbours, ell.degrees, max_sweeps, impl)
    return core[: ell.n_nodes]


def degeneracy(core: np.ndarray) -> int:
    return int(np.max(core)) if len(core) else 0


def core_mask(core: np.ndarray, k: int) -> np.ndarray:
    """Membership mask of the k-core (nodes with core number >= k)."""
    return np.asarray(core) >= k


def shells(core: np.ndarray) -> Dict[int, np.ndarray]:
    """core index -> node ids whose core number equals that index."""
    core = np.asarray(core)
    return {int(k): np.where(core == k)[0] for k in np.unique(core)}


def kcore_subgraph(g: Graph, core: np.ndarray, k: int) -> Graph:
    """Induced subgraph on the k-core (original node ids preserved)."""
    return g.subgraph(core_mask(core, k))

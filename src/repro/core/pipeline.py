"""End-to-end embedding pipelines — the paper's four model rows.

  * DeepWalk            : fixed walk budget on the full graph (baseline)
  * CoreWalk            : Eq. 13 budgets on the full graph (§2.1)
  * k-core(Dw)/k-core(Cw): embed only the k₀-core, then mean-propagate (§2.2)

Every run returns the paper's time breakdown (decomposition / walks+embedding
/ propagation) so the benchmark tables can mirror Tables 1-10.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import numpy as np

from repro.graph.csr import Graph
from repro.skipgram.corpus import build_corpus
from repro.skipgram.trainer import SGNSConfig, train_sgns

from .corewalk import corewalk_plan, deepwalk_plan
from .kcore import core_numbers_host, degeneracy, kcore_subgraph
from .propagation import propagate

__all__ = ["EmbedConfig", "EmbedResult", "embed_graph"]


@dataclasses.dataclass
class EmbedConfig:
    method: str = "deepwalk"  # deepwalk | corewalk
    k0: Optional[int] = None  # embed only the k0-core, then propagate
    n_walks: int = 15  # paper defaults (§3.1.2)
    walk_length: int = 30
    sgns: SGNSConfig = dataclasses.field(default_factory=SGNSConfig)
    prop_iters: int = 30
    prop_backend: str = "scipy"
    seed: int = 0


@dataclasses.dataclass
class EmbedResult:
    embeddings: np.ndarray
    core: np.ndarray
    degeneracy: int
    n_walks_run: int
    n_sgns_steps: int
    times: dict  # decomposition / walks / embedding / propagation / total


def embed_graph(g: Graph, cfg: EmbedConfig) -> EmbedResult:
    times = {}
    t_total = time.perf_counter()

    # --- k-core decomposition (cheap; always computed: CoreWalk and k-core
    # pipelines need it, and reporting matches the paper's breakdown) ---
    t0 = time.perf_counter()
    core = core_numbers_host(g)
    kdeg = degeneracy(core)
    times["decomposition"] = time.perf_counter() - t0

    # --- choose the graph to embed and the walk budget plan ---
    if cfg.k0 is not None:
        # edge-removal can lower the degeneracy below a k0 chosen on the full
        # graph (cora + 30% removal does): clamp to the deepest alive core
        k0 = min(cfg.k0, kdeg)
        sub = kcore_subgraph(g, core, k0)
        in_core = core >= k0
    else:
        sub = g
        in_core = np.ones(g.n_nodes, dtype=bool)

    if cfg.method == "corewalk":
        budgets = corewalk_plan(core, cfg.n_walks).per_node
    elif cfg.method == "deepwalk":
        budgets = deepwalk_plan(g.n_nodes, cfg.n_walks).per_node
    else:
        raise ValueError(cfg.method)
    budgets = np.where(in_core, budgets, 0)
    roots = np.repeat(np.arange(g.n_nodes, dtype=np.int32), budgets)

    from repro.core.corewalk import WalkPlan

    plan = WalkPlan(roots=roots, n_real=len(roots), per_node=budgets.astype(np.int32))

    # --- walks + SGNS on the (sub)graph ---
    t0 = time.perf_counter()
    ell = sub.to_ell()
    corpus = build_corpus(
        ell, plan, cfg.walk_length, jax.random.PRNGKey(cfg.seed)
    )
    corpus.walks.block_until_ready()
    times["walks"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    sg = train_sgns(corpus, cfg.sgns)
    times["embedding"] = time.perf_counter() - t0

    emb = sg.embeddings

    # --- mean-embedding propagation to the full graph ---
    t0 = time.perf_counter()
    if cfg.k0 is not None:
        emb = propagate(
            g,
            core,
            k0,
            emb,
            n_iters=cfg.prop_iters,
            backend=cfg.prop_backend,
        )
    times["propagation"] = time.perf_counter() - t0
    times["total"] = time.perf_counter() - t_total

    return EmbedResult(
        embeddings=emb,
        core=core,
        degeneracy=kdeg,
        n_walks_run=plan.n_real,
        n_sgns_steps=sg.n_steps,
        times=times,
    )

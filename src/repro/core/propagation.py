"""Mean-embedding propagation from a k₀-core (paper §2.2, after Salha et al.).

Embeddings are computed only on the k₀-core; every lower shell is then filled
in, shell by shell (k-core -> (k-1)-core). New nodes T (core index == k-1)
satisfy the linear system

    x_t = mean_{u in N(t) ∩ ((k-1)-core)} x_u        for t in T,

whose unknowns are only the T rows (S = nodes with core >= k are fixed). As
in the paper we solve it with Jacobi-style iterative averaging (linear per
sweep) instead of the cubic exact solve; ``solve_shell_exact`` is the oracle.

Backends:
  * ``jax``  — ELL neighbour-mean sweeps (the ellmean Pallas kernel on TPU);
               this is the path the dry-run shards.
  * ``scipy``— CSR sparse matvec sweeps (the paper's own implementation
               choice), used for large CPU reproduction benchmarks.
"""
from __future__ import annotations

from typing import Literal

import numpy as np
import scipy.sparse as sp

from repro.graph.csr import Graph

__all__ = ["propagate", "solve_shell_exact", "propagation_schedule"]


def propagation_schedule(core: np.ndarray, k0: int) -> list[int]:
    """Shell indices processed: k0-1, k0-2, ..., min core index present."""
    core = np.asarray(core)
    lo = int(core.min())
    return [k for k in range(k0 - 1, lo - 1, -1) if np.any(core == k)]


def _to_scipy(g: Graph) -> sp.csr_matrix:
    data = np.ones(g.n_arcs, dtype=np.float32)
    return sp.csr_matrix((data, g.indices, g.indptr), shape=(g.n_nodes, g.n_nodes))


def propagate(
    g: Graph,
    core: np.ndarray,
    k0: int,
    base_emb: np.ndarray,
    *,
    n_iters: int = 30,
    backend: Literal["scipy", "jax"] = "scipy",
    impl: str = "auto",
) -> np.ndarray:
    """Fill embeddings for all nodes below the k₀-core.

    base_emb: (n_nodes, D); rows with core >= k0 must already be embedded.
    Returns a full (n_nodes, D) float32 embedding matrix.
    """
    core = np.asarray(core)
    x = np.array(base_emb, dtype=np.float32, copy=True)
    if backend == "scipy":
        A = _to_scipy(g)
        for k in propagation_schedule(core, k0):
            T = core == k
            allowed = core >= k
            deg_allowed = np.asarray(A[T] @ allowed.astype(np.float32)).reshape(-1)
            denom = np.maximum(deg_allowed, 1.0)[:, None]
            x[T] = 0.0
            AT = A[T].multiply(allowed.astype(np.float32)[None, :]).tocsr()
            for _ in range(n_iters):
                x[T] = (AT @ x) / denom
        return x

    # jax backend: ELL sweeps (ellmean kernel on TPU, jnp ref elsewhere)
    import jax.numpy as jnp

    from repro.kernels import ops

    ell = g.to_ell()
    nbr = np.asarray(ell.neighbours)
    core_ext = np.concatenate([core, [-1]])  # sentinel row never allowed
    xj = jnp.asarray(np.concatenate([x, np.zeros((1, x.shape[1]), np.float32)]))
    for k in propagation_schedule(core, k0):
        T = np.where(core == k)[0]
        idx_T = jnp.asarray(nbr[T])
        valid_T = jnp.asarray(
            (nbr[T] != g.n_nodes) & (core_ext[nbr[T]] >= k)
        )
        xj = xj.at[T].set(0.0)
        for _ in range(n_iters):
            xj = xj.at[T].set(ops.ell_mean(idx_T, valid_T, xj, impl=impl))
    return np.asarray(xj[:-1])


def solve_shell_exact(
    g: Graph, core: np.ndarray, k: int, x: np.ndarray, reg: float = 1e-6
) -> np.ndarray:
    """Exact solve of one shell's system (oracle for tests).

    Returns x with rows of shell k replaced by the exact solution of
    (D - A_TT) x_T = A_TS x_S restricted to the (k)-core-allowed neighbours.
    """
    core = np.asarray(core)
    T = np.where(core == k)[0]
    S_mask = core >= k + 1
    allowed = core >= k
    A = _to_scipy(g)
    AT = A[T].multiply(allowed.astype(np.float32)[None, :]).tocsr()
    deg = np.asarray(AT.sum(axis=1)).reshape(-1)
    A_TT = AT[:, T]
    A_TS = AT[:, S_mask]
    D = sp.diags(np.maximum(deg, 1.0))
    rhs = A_TS @ x[S_mask]
    M = (D - A_TT) + reg * sp.eye(len(T))
    x = np.array(x, copy=True)
    x[T] = sp.linalg.spsolve(M.tocsr(), rhs)
    return x

"""CoreWalk — core-adaptive random-walk budgets (paper §2.1, Eq. 13).

``n_v = max(floor(n * k_v / k_degeneracy), 1)`` walks are rooted at node v.
Because core populations are bottom-heavy, the total walk count (and hence
the SGNS training corpus) shrinks drastically versus the fixed-n DeepWalk
plan, which is exactly the paper's speedup mechanism.

The planner emits a flat ``roots`` array (one entry per walk). Shapes are
static per graph: Eq. 13 changes *how many* slots exist, not the per-walk
program, so the walk engine stays a single compiled computation. ``pad_to``
rounds the slot count up (padding walks root at node 0 and are masked out of
the corpus statistics) so distributed shards stay equal-sized.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["WalkPlan", "deepwalk_plan", "corewalk_plan"]


@dataclasses.dataclass
class WalkPlan:
    roots: np.ndarray  # (W,) int32 walk roots (padding slots included)
    n_real: int  # number of non-padding walks
    per_node: np.ndarray  # (n_nodes,) int32 walks rooted at each node

    @property
    def n_slots(self) -> int:
        return int(self.roots.shape[0])

    def reduction_vs(self, other: "WalkPlan") -> float:
        """Corpus-size ratio vs another plan (hardware-independent speedup)."""
        return other.n_real / max(self.n_real, 1)


def _plan_from_counts(per_node: np.ndarray, pad_to: int | None) -> WalkPlan:
    roots = np.repeat(np.arange(len(per_node), dtype=np.int32), per_node)
    n_real = len(roots)
    if pad_to is not None and n_real % pad_to:
        pad = pad_to - n_real % pad_to
        roots = np.concatenate([roots, np.zeros(pad, dtype=np.int32)])
    return WalkPlan(roots=roots, n_real=n_real, per_node=per_node.astype(np.int32))


def deepwalk_plan(n_nodes: int, n_walks: int, pad_to: int | None = None) -> WalkPlan:
    """Fixed budget: n walks per node (DeepWalk / Node2Vec baseline)."""
    return _plan_from_counts(np.full(n_nodes, n_walks, dtype=np.int64), pad_to)


def corewalk_plan(
    core: np.ndarray, n_walks: int, pad_to: int | None = None
) -> WalkPlan:
    """Eq. 13 budget: n_v = max(floor(n * k_v / degeneracy), 1)."""
    core = np.asarray(core, dtype=np.int64)
    kdeg = max(int(core.max()), 1)
    per_node = np.maximum((n_walks * core) // kdeg, 1)
    return _plan_from_counts(per_node, pad_to)

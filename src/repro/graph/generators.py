"""Deterministic synthetic graph generators (numpy, no networkx dependency).

The container has no network access, so the paper's three datasets (Cora,
SNAP-Facebook, SNAP-Github) are replaced by synthetic graphs calibrated to the
same node/edge counts and a similarly bottom-heavy core profile (preferential
attachment yields the power-law degree + core distributions the paper's §3.1.1
plots show for Github/Facebook).
"""
from __future__ import annotations

import numpy as np

from .csr import Graph

__all__ = [
    "barabasi_albert",
    "barabasi_albert_varying",
    "erdos_renyi",
    "powerlaw_cluster",
    "stochastic_block_model",
]


def barabasi_albert(n: int, m: int, seed: int = 0) -> Graph:
    """Barabási–Albert preferential attachment (repeated-nodes implementation)."""
    if n <= m:
        raise ValueError("n must exceed m")
    rng = np.random.default_rng(seed)
    # Start from a star on m+1 nodes so every node has degree >= 1.
    edges = [(i, m) for i in range(m)]
    repeated = [x for e in edges for x in e]
    for v in range(m + 1, n):
        targets = set()
        while len(targets) < m:
            targets.add(int(repeated[rng.integers(len(repeated))]))
        for t in targets:
            edges.append((v, t))
            repeated.append(v)
            repeated.append(t)
    return Graph.from_edges(n, np.array(edges, dtype=np.int64))


def barabasi_albert_varying(
    n: int, m_mean: float, alpha: float = 1.6, m_max: int = 120, seed: int = 0
) -> Graph:
    """Preferential attachment with per-node attachment count m_v ~ zipf(alpha).

    Plain BA puts EVERY node in the m-core (a single shell) — useless for
    studying degeneracy. Drawing m_v from a heavy-tailed distribution yields
    the bottom-heavy multi-shell core profile the paper's §3.1.1 plots show
    for Facebook/Github (many nodes in low cores, few in the deepest cores).
    """
    rng = np.random.default_rng(seed)
    raw = np.minimum(rng.zipf(alpha, size=n).astype(float), m_max)
    m_v = np.maximum(1, np.round(raw * (m_mean / raw.mean())).astype(int))
    m_v = np.minimum(m_v, m_max)
    m0 = int(m_v.max()) + 1
    if n <= m0:
        raise ValueError("n too small for the drawn attachment counts")
    edges = [(i, m0) for i in range(m0)]
    repeated = [x for e in edges for x in e]
    for v in range(m0 + 1, n):
        m = min(int(m_v[v]), v - 1)
        targets = set()
        while len(targets) < m:
            targets.add(int(repeated[rng.integers(len(repeated))]))
        for t in targets:
            edges.append((v, t))
            repeated.append(v)
            repeated.append(t)
    return Graph.from_edges(n, np.array(edges, dtype=np.int64))


def powerlaw_cluster(n: int, m: int, p: float, seed: int = 0) -> Graph:
    """Holme–Kim powerlaw-cluster graph: BA + triad closure with prob ``p``."""
    if n <= m:
        raise ValueError("n must exceed m")
    rng = np.random.default_rng(seed)
    edges = [(i, m) for i in range(m)]
    adj = {i: {m} for i in range(m)}
    adj[m] = set(range(m))
    repeated = [x for e in edges for x in e]

    def add_edge(u, v):
        if u == v or v in adj.setdefault(u, set()):
            return False
        adj[u].add(v)
        adj.setdefault(v, set()).add(u)
        edges.append((u, v))
        repeated.append(u)
        repeated.append(v)
        return True

    for v in range(m + 1, n):
        count = 0
        target = int(repeated[rng.integers(len(repeated))])
        while count < m:
            if add_edge(v, target):
                count += 1
                # triad closure: connect to a neighbour of the last target
                if count < m and rng.random() < p:
                    nbrs = list(adj[target] - adj.get(v, set()) - {v})
                    if nbrs:
                        w = int(nbrs[rng.integers(len(nbrs))])
                        if add_edge(v, w):
                            count += 1
            target = int(repeated[rng.integers(len(repeated))])
    return Graph.from_edges(n, np.array(edges, dtype=np.int64))


def erdos_renyi(n: int, n_edges: int, seed: int = 0) -> Graph:
    """G(n, M): exactly ``n_edges`` distinct undirected edges."""
    rng = np.random.default_rng(seed)
    seen = set()
    out = []
    while len(out) < n_edges:
        u = int(rng.integers(n))
        v = int(rng.integers(n))
        if u == v:
            continue
        key = (min(u, v), max(u, v))
        if key in seen:
            continue
        seen.add(key)
        out.append(key)
    return Graph.from_edges(n, np.array(out, dtype=np.int64))


def stochastic_block_model(
    sizes: list[int], p_in: float, p_out: float, seed: int = 0
) -> Graph:
    """SBM with dense diagonal blocks — used to build *disconnected-core* cases
    (paper §4 discusses k₀-cores that split into distant clusters)."""
    rng = np.random.default_rng(seed)
    n = sum(sizes)
    bounds = np.cumsum([0] + list(sizes))
    block = np.zeros(n, dtype=np.int64)
    for b in range(len(sizes)):
        block[bounds[b] : bounds[b + 1]] = b
    edges = []
    for u in range(n):
        for v in range(u + 1, n):
            p = p_in if block[u] == block[v] else p_out
            if rng.random() < p:
                edges.append((u, v))
    return Graph.from_edges(n, np.array(edges, dtype=np.int64))

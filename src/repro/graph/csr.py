"""Graph containers used across the framework.

Two representations:

* ``Graph`` — host-side CSR (numpy). Used for dataset preparation, k-core
  peeling, and edge splits. Undirected graphs store both arc directions.
* ``EllGraph`` — device-side padded ELL (jnp). Fixed-width neighbour table so
  random walks / propagation are static-shaped ``vmap``/``scan`` programs.
  Padding slots point at row ``n_nodes`` (a sentinel row) and are masked.

The ELL width is the max degree by default; callers embedding very skewed
graphs can cap it (neighbours are then subsampled deterministically), which
bounds the memory of the walk engine on hub-heavy graphs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

__all__ = ["Graph", "EllGraph", "edges_to_csr"]


def edges_to_csr(n_nodes: int, edges: np.ndarray, undirected: bool = True):
    """Build CSR (indptr, indices) from an (E, 2) int array of edges.

    Self-loops and duplicate edges are removed. Neighbour lists are sorted,
    which downstream code relies on (membership tests via searchsorted).
    """
    edges = np.asarray(edges, dtype=np.int64)
    if edges.size == 0:
        edges = edges.reshape(0, 2)
    if undirected:
        edges = np.concatenate([edges, edges[:, ::-1]], axis=0)
    # drop self loops
    edges = edges[edges[:, 0] != edges[:, 1]]
    # dedupe
    key = edges[:, 0] * n_nodes + edges[:, 1]
    order = np.argsort(key, kind="stable")
    key = key[order]
    keep = np.ones(len(key), dtype=bool)
    keep[1:] = key[1:] != key[:-1]
    edges = edges[order][keep]
    counts = np.bincount(edges[:, 0], minlength=n_nodes)
    indptr = np.zeros(n_nodes + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    indices = edges[:, 1].astype(np.int32)
    return indptr, indices


@dataclasses.dataclass
class Graph:
    """Host-side CSR graph (undirected unless stated otherwise)."""

    n_nodes: int
    indptr: np.ndarray  # (n_nodes + 1,) int64
    indices: np.ndarray  # (n_arcs,) int32, sorted within each row

    @staticmethod
    def from_edges(n_nodes: int, edges: np.ndarray, undirected: bool = True) -> "Graph":
        indptr, indices = edges_to_csr(n_nodes, edges, undirected=undirected)
        return Graph(n_nodes=n_nodes, indptr=indptr, indices=indices)

    @property
    def n_arcs(self) -> int:
        return int(self.indices.shape[0])

    @property
    def n_edges(self) -> int:
        """Number of undirected edges (arcs / 2)."""
        return self.n_arcs // 2

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int32)

    def neighbours(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v] : self.indptr[v + 1]]

    def has_edge(self, u: int, v: int) -> bool:
        row = self.neighbours(u)
        i = np.searchsorted(row, v)
        return bool(i < len(row) and row[i] == v)

    def edge_list(self) -> np.ndarray:
        """(E, 2) array with u < v, each undirected edge once."""
        src = np.repeat(np.arange(self.n_nodes), np.diff(self.indptr))
        dst = self.indices
        keep = src < dst
        return np.stack([src[keep], dst[keep]], axis=1).astype(np.int32)

    def subgraph(self, node_mask: np.ndarray) -> "Graph":
        """Induced subgraph on ``node_mask`` (keeps original node ids)."""
        node_mask = np.asarray(node_mask, dtype=bool)
        src = np.repeat(np.arange(self.n_nodes), np.diff(self.indptr))
        dst = self.indices
        keep = node_mask[src] & node_mask[dst]
        edges = np.stack([src[keep], dst[keep]], axis=1)
        indptr, indices = edges_to_csr(self.n_nodes, edges, undirected=False)
        return Graph(n_nodes=self.n_nodes, indptr=indptr, indices=indices)

    def largest_connected_component(self) -> np.ndarray:
        """Boolean mask of the largest connected component (BFS, host)."""
        n = self.n_nodes
        comp = np.full(n, -1, dtype=np.int64)
        cur = 0
        for seed in range(n):
            if comp[seed] >= 0:
                continue
            stack = [seed]
            comp[seed] = cur
            while stack:
                u = stack.pop()
                for w in self.neighbours(u):
                    if comp[w] < 0:
                        comp[w] = cur
                        stack.append(int(w))
            cur += 1
        sizes = np.bincount(comp, minlength=cur)
        return comp == np.argmax(sizes)

    def to_ell(self, max_width: Optional[int] = None, seed: int = 0) -> "EllGraph":
        deg = self.degrees()
        width = int(deg.max()) if deg.size else 0
        if max_width is not None:
            width = min(width, int(max_width))
        width = max(width, 1)
        n = self.n_nodes
        nbr = np.full((n + 1, width), n, dtype=np.int32)  # sentinel row n
        eff_deg = np.minimum(deg, width).astype(np.int32)
        rng = np.random.default_rng(seed)
        for v in range(n):
            row = self.indices[self.indptr[v] : self.indptr[v + 1]]
            if len(row) > width:
                row = rng.choice(row, size=width, replace=False)
                row = np.sort(row)
            nbr[v, : len(row)] = row
        return EllGraph(
            n_nodes=n,
            neighbours=jnp.asarray(nbr),
            degrees=jnp.asarray(np.concatenate([eff_deg, np.zeros(1, np.int32)])),
        )


@dataclasses.dataclass
class EllGraph:
    """Device-side padded neighbour table.

    ``neighbours``: (n_nodes + 1, width) int32; row ``n_nodes`` is a sentinel
    whose entries all point at itself. Padding entries equal ``n_nodes``.
    ``degrees``: (n_nodes + 1,) int32 effective (possibly capped) degree.
    """

    n_nodes: int
    neighbours: jnp.ndarray
    degrees: jnp.ndarray

    @property
    def width(self) -> int:
        return int(self.neighbours.shape[1])

    def mask(self) -> jnp.ndarray:
        """(n_nodes + 1, width) bool validity mask."""
        return self.neighbours != self.n_nodes

"""Link-prediction edge splits (paper §3.1.2).

Remove a fraction of edges (10/30/50%) as positive test samples, sample the
same number of non-edges as negatives, train embeddings on the residual
graph. Removal avoids creating isolated nodes (the paper only embeds nodes
with non-empty context: 0-core == 1-core assumption, §2).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .csr import Graph

__all__ = ["LinkSplit", "make_link_split"]


@dataclasses.dataclass
class LinkSplit:
    train_graph: Graph
    pos_edges: np.ndarray  # (P, 2) removed (held-out) edges
    neg_edges: np.ndarray  # (P, 2) sampled non-edges
    frac_removed: float

    def eval_arrays(self):
        """(pairs, labels) for the downstream classifier."""
        pairs = np.concatenate([self.pos_edges, self.neg_edges], axis=0)
        labels = np.concatenate(
            [np.ones(len(self.pos_edges)), np.zeros(len(self.neg_edges))]
        ).astype(np.float32)
        return pairs, labels


def make_link_split(g: Graph, frac: float, seed: int = 0) -> LinkSplit:
    rng = np.random.default_rng(seed)
    edges = g.edge_list()
    n_remove = int(round(frac * len(edges)))
    order = rng.permutation(len(edges))
    deg = g.degrees().astype(np.int64)
    removed = []
    for idx in order:
        if len(removed) >= n_remove:
            break
        u, v = edges[idx]
        if deg[u] > 1 and deg[v] > 1:
            removed.append(idx)
            deg[u] -= 1
            deg[v] -= 1
    removed = np.array(removed, dtype=np.int64)
    keep_mask = np.ones(len(edges), dtype=bool)
    keep_mask[removed] = False
    train_graph = Graph.from_edges(g.n_nodes, edges[keep_mask])
    pos = edges[~keep_mask]

    # negatives: distinct non-edges of the *original* graph
    neg = []
    seen = set()
    while len(neg) < len(pos):
        u = int(rng.integers(g.n_nodes))
        v = int(rng.integers(g.n_nodes))
        if u == v:
            continue
        key = (min(u, v), max(u, v))
        if key in seen:
            continue
        seen.add(key)
        if not g.has_edge(u, v):
            neg.append(key)
    neg = np.array(neg, dtype=np.int32).reshape(-1, 2)
    return LinkSplit(
        train_graph=train_graph,
        pos_edges=pos.astype(np.int32),
        neg_edges=neg,
        frac_removed=frac,
    )

"""Dataset presets mirroring the paper's three graphs (§3.1.1) plus loaders.

Sizes match the paper: Cora 2,708 / 5,429; Facebook 4,039 / 88,234;
Github 37,700 / 289,003. Graphs are synthetic (see generators.py) but
calibrated to the same scale and a bottom-heavy core profile. Every preset
returns the largest connected component restricted graph, matching the
paper's "we always consider the largest connected subgraph".
"""
from __future__ import annotations

import os
from typing import Callable, Dict

import numpy as np

from .csr import Graph
from .generators import (
    barabasi_albert_varying,
    erdos_renyi,
)

__all__ = ["load", "DATASETS", "load_edge_list"]


def _lcc(g: Graph) -> Graph:
    mask = g.largest_connected_component()
    if mask.all():
        return g
    # compact node ids
    new_id = np.cumsum(mask) - 1
    edges = g.edge_list()
    keep = mask[edges[:, 0]] & mask[edges[:, 1]]
    edges = new_id[edges[keep]]
    return Graph.from_edges(int(mask.sum()), edges)


def _cora_like(seed: int = 0) -> Graph:
    # Cora is sparse (avg deg ~4) and rather irregular: ER at the same density.
    return _lcc(erdos_renyi(2708, 5429, seed=seed))


def _facebook_like(seed: int = 0) -> Graph:
    # SNAP ego-Facebook: 4,039 nodes / 88,234 edges, degeneracy ~115.
    # Varying-m preferential attachment -> deep bottom-heavy core hierarchy.
    return _lcc(barabasi_albert_varying(4039, 30.0, alpha=1.6, m_max=150, seed=seed))


def _github_like(seed: int = 0) -> Graph:
    # SNAP musae-github: 37,700 nodes / 289,003 edges, "regular" core profile.
    return _lcc(barabasi_albert_varying(37700, 8.6, alpha=1.8, m_max=60, seed=seed))


def _karate_like(seed: int = 0) -> Graph:
    return _lcc(barabasi_albert_varying(64, 4.0, alpha=1.6, m_max=12, seed=seed))


DATASETS: Dict[str, Callable[..., Graph]] = {
    "cora-like": _cora_like,
    "facebook-like": _facebook_like,
    "github-like": _github_like,
    "tiny": _karate_like,
}


def load(name: str, seed: int = 0) -> Graph:
    if name not in DATASETS:
        raise KeyError(f"unknown dataset {name!r}; options: {sorted(DATASETS)}")
    return DATASETS[name](seed=seed)


def load_edge_list(path: str, comments: str = "#") -> Graph:
    """Load a whitespace-separated edge list file (SNAP format)."""
    if not os.path.exists(path):
        raise FileNotFoundError(path)
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith(comments):
                continue
            u, v = line.split()[:2]
            rows.append((int(u), int(v)))
    edges = np.array(rows, dtype=np.int64)
    # compact ids
    ids = np.unique(edges)
    remap = {int(x): i for i, x in enumerate(ids)}
    edges = np.vectorize(remap.get)(edges)
    return _lcc(Graph.from_edges(len(ids), edges))

"""Minimal optax-style optimizer library (no external deps).

Transforms compose with ``chain``; every optimizer is a ``GradientTransform``
(init, update) pair over pytrees. Moment/statistics accumulators are kept in
float32 regardless of parameter dtype (bf16-safe), and updates are cast back
to the parameter dtype — the standard mixed-precision contract.

``adafactor`` implements factored second moments for >=2D tensors (Shazeer &
Stern 2018) — required to fit grok-1-314b optimizer state on the production
mesh (see DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "GradientTransform",
    "apply_updates",
    "chain",
    "clip_by_global_norm",
    "scale",
    "add_decayed_weights",
    "scale_by_adam",
    "scale_by_schedule",
    "sgd",
    "adam",
    "adamw",
    "adafactor",
    "global_norm",
    "warmup_cosine",
    "constant_schedule",
]

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


class GradientTransform(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]  # (grads, state, params) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree.map(
        lambda p, u: (p + u.astype(p.dtype)).astype(p.dtype), params, updates
    )


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def chain(*transforms: GradientTransform) -> GradientTransform:
    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(grads, state, params=None):
        new_state = []
        for t, s in zip(transforms, state):
            grads, s = t.update(grads, s, params)
            new_state.append(s)
        return grads, tuple(new_state)

    return GradientTransform(init, update)


def scale(factor: float) -> GradientTransform:
    def update(grads, state, params=None):
        return jax.tree.map(lambda g: g * factor, grads), state

    return GradientTransform(lambda p: (), update)


def scale_by_schedule(schedule: Schedule) -> GradientTransform:
    def init(params):
        return jnp.zeros([], jnp.int32)

    def update(grads, count, params=None):
        s = schedule(count)
        return jax.tree.map(lambda g: g * s, grads), count + 1

    return GradientTransform(init, update)


def clip_by_global_norm(max_norm: float) -> GradientTransform:
    def update(grads, state, params=None):
        norm = global_norm(grads)
        factor = jnp.minimum(1.0, max_norm / (norm + 1e-9))
        return jax.tree.map(lambda g: g * factor, grads), state

    return GradientTransform(lambda p: (), update)


def add_decayed_weights(weight_decay: float) -> GradientTransform:
    def update(grads, state, params):
        if weight_decay == 0.0 or params is None:
            return grads, state
        return (
            jax.tree.map(
                lambda g, p: g + weight_decay * p.astype(g.dtype), grads, params
            ),
            state,
        )

    return GradientTransform(lambda p: (), update)


class AdamState(NamedTuple):
    count: jnp.ndarray
    mu: Any
    nu: Any


def scale_by_adam(b1=0.9, b2=0.999, eps=1e-8) -> GradientTransform:
    def init(params):
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(jnp.zeros([], jnp.int32), jax.tree.map(f32, params), jax.tree.map(f32, params))

    def update(grads, state, params=None):
        count = state.count + 1
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, g32)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, g32)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)
        updates = jax.tree.map(
            lambda m, v: (m / c1) / (jnp.sqrt(v / c2) + eps), mu, nu
        )
        return updates, AdamState(count, mu, nu)

    return GradientTransform(init, update)


def sgd(lr: float | Schedule, momentum: float = 0.0) -> GradientTransform:
    def init(params):
        if momentum == 0.0:
            return jnp.zeros([], jnp.int32)
        return (
            jnp.zeros([], jnp.int32),
            jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        )

    def update(grads, state, params=None):
        if momentum == 0.0:
            count = state
            vel = None
        else:
            count, vel = state
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if vel is not None:
            vel = jax.tree.map(lambda v, g: momentum * v + g, vel, g32)
            g32 = vel
        step = lr(count) if callable(lr) else lr
        updates = jax.tree.map(lambda g: -step * g, g32)
        count = count + 1
        return updates, (count, vel) if momentum != 0.0 else count

    return GradientTransform(init, update)


def adam(lr: float | Schedule, b1=0.9, b2=0.999, eps=1e-8) -> GradientTransform:
    return adamw(lr, b1=b1, b2=b2, eps=eps, weight_decay=0.0)


def adamw(
    lr: float | Schedule, b1=0.9, b2=0.999, eps=1e-8, weight_decay: float = 0.0
) -> GradientTransform:
    sched = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))
    return chain(
        scale_by_adam(b1, b2, eps),
        add_decayed_weights(weight_decay),
        scale_by_schedule(lambda c: -sched(c)),
    )


class AdafactorState(NamedTuple):
    count: jnp.ndarray
    vr: Any  # row second-moment (or full moment for <2D)
    vc: Any  # col second-moment (or () for <2D)


def adafactor(
    lr: float | Schedule,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    min_dim_size_to_factor: int = 128,
    weight_decay: float = 0.0,
) -> GradientTransform:
    """Factored second-moment optimizer (memory ~O(rows+cols) per matrix)."""
    sched = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def factored(p):
        return p.ndim >= 2 and p.shape[-1] >= min_dim_size_to_factor and p.shape[-2] >= min_dim_size_to_factor

    def init_one(p):
        if factored(p):
            return (
                jnp.zeros(p.shape[:-1], jnp.float32),
                jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            )
        return (jnp.zeros(p.shape, jnp.float32), ())

    def init(params):
        vr = jax.tree.map(lambda p: init_one(p)[0], params)
        vc = jax.tree.map(lambda p: init_one(p)[1], params)
        return AdafactorState(jnp.zeros([], jnp.int32), vr, vc)

    def update(grads, state, params=None):
        count = state.count + 1
        beta = 1.0 - (count.astype(jnp.float32)) ** -decay

        def upd_one(g, vr, vc, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if factored(g):
                vr = beta * vr + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
                r = vr / jnp.clip(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
                u = g / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc)[..., None, :])
            else:
                vr = beta * vr + (1 - beta) * g2
                u = g / jnp.sqrt(vr)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay and p is not None:
                u = u + weight_decay * p.astype(jnp.float32)
            return u, vr, vc

        ps = params if params is not None else jax.tree.map(lambda g: None, grads)
        flat_g, tdef = jax.tree.flatten(grads)
        flat_vr = tdef.flatten_up_to(state.vr)
        flat_vc = tdef.flatten_up_to(state.vc)
        flat_p = tdef.flatten_up_to(ps)
        outs = [upd_one(g, vr, vc, p) for g, vr, vc, p in zip(flat_g, flat_vr, flat_vc, flat_p)]
        step = sched(state.count)
        updates = tdef.unflatten([-step * o[0] for o in outs])
        vr = tdef.unflatten([o[1] for o in outs])
        vc = tdef.unflatten([o[2] for o in outs])
        return updates, AdafactorState(count, vr, vc)

    return GradientTransform(init, update)


def adam_state_specs(param_specs):
    """Logical-name tree mirroring adamw's state (for sharded lowering)."""
    scalar = ()
    return (
        AdamState(count=scalar, mu=param_specs, nu=param_specs),
        (),  # add_decayed_weights
        scalar,  # scale_by_schedule count
    )


def adafactor_state_specs(params_avals, param_specs, min_dim_size_to_factor=128):
    """Logical-name tree mirroring adafactor's factored state."""

    def factored(a):
        return (
            a.ndim >= 2
            and a.shape[-1] >= min_dim_size_to_factor
            and a.shape[-2] >= min_dim_size_to_factor
        )

    flat_a, tdef = jax.tree.flatten(params_avals)
    flat_s = tdef.flatten_up_to(param_specs)
    vr = tdef.unflatten([s[:-1] if factored(a) else s for a, s in zip(flat_a, flat_s)])
    vc = tdef.unflatten(
        [s[:-2] + s[-1:] if factored(a) else () for a, s in zip(flat_a, flat_s)]
    )
    return AdafactorState(count=(), vr=vr, vc=vc)


def make_optimizer(name: str, lr, weight_decay: float = 0.0) -> GradientTransform:
    if name == "adamw":
        return adamw(lr, weight_decay=weight_decay)
    if name == "adam":
        return adam(lr)
    if name == "adafactor":
        return adafactor(lr, weight_decay=weight_decay)
    if name == "sgd":
        return sgd(lr, momentum=0.9)
    raise ValueError(name)


def optimizer_state_specs(name: str, params_avals, param_specs):
    if name in ("adamw", "adam"):
        return adam_state_specs(param_specs)
    if name == "adafactor":
        return adafactor_state_specs(params_avals, param_specs)
    if name == "sgd":
        return ((), param_specs)
    raise ValueError(name)


def warmup_cosine(
    peak_lr: float, warmup_steps: int, total_steps: int, end_lr_ratio: float = 0.1
) -> Schedule:
    def sched(count):
        c = count.astype(jnp.float32)
        warm = peak_lr * (c + 1) / max(warmup_steps, 1)
        t = jnp.clip((c - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = end_lr_ratio * peak_lr + (1 - end_lr_ratio) * peak_lr * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(c < warmup_steps, warm, cos)

    return sched


def constant_schedule(lr: float) -> Schedule:
    return lambda c: jnp.asarray(lr, jnp.float32)

"""Model assembly: decoder-only / MoE / SSM / hybrid / enc-dec LMs.

Structure of params (all families):
  embed        token table (+ unembed if untied)
  layers       scan-stacked block params (leading dim = n_layers or groups)
  shared,loras (hybrid only) zamba2 shared block + per-invocation LoRA stack
  encoder      (encdec only) stacked encoder blocks + final norm
  final_norm

Forward modes:
  * full   — whole sequence (training fwd / serving prefill); optionally
             returns the serving cache.
  * decode — one token against a cache (KV for attention, conv+ssd for SSM).

The (B, S, V) logits tensor is never materialised in training: the loss runs
in sequence chunks under jax.checkpoint (``lm_loss``).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain

from .attention import AttnInputs, apply_attention_decode
from .blocks import (
    apply_mamba_block,
    apply_shared_block,
    apply_transformer_block,
    init_mamba_block,
    init_shared_block,
    init_shared_lora,
    init_transformer_block,
    lora_attention_params,
    spec_mamba_block,
    spec_shared_block,
    spec_shared_lora,
    spec_transformer_block,
)
from .config import ModelConfig
from .layers import apply_norm, init_embedding, init_norm, spec_embedding, spec_norm
from .mamba2 import init_mamba_cache, mamba_decode, mamba_forward

__all__ = [
    "init_model", "model_specs", "forward_full", "forward_decode",
    "logits_from_hidden", "lm_loss", "init_cache", "hybrid_layout",
]


# ----------------------------------------------------------------- layout --


def hybrid_layout(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(n_groups, mamba_per_group, trailing_mamba) for zamba2-style hybrids."""
    per = cfg.shared_every
    groups = cfg.n_layers // per
    trailing = cfg.n_layers - groups * per
    return groups, per, trailing


def _stack_init(key, n, fn):
    return jax.vmap(fn)(jax.random.split(key, n))


# ------------------------------------------------------------------- init --


def init_model(key, cfg: ModelConfig):
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {"embed": init_embedding(ks[0], cfg)}
    fam = cfg.family

    if fam in ("dense", "moe", "encdec"):
        cross = fam == "encdec"
        params["layers"] = _stack_init(
            ks[1], cfg.n_layers, lambda k: init_transformer_block(k, cfg, cross=cross)
        )
    elif fam == "ssm":
        params["layers"] = _stack_init(
            ks[1], cfg.n_layers, lambda k: init_mamba_block(k, cfg)
        )
    elif fam == "hybrid":
        groups, per, trailing = hybrid_layout(cfg)
        params["layers"] = _stack_init(
            ks[1], groups * per, lambda k: init_mamba_block(k, cfg)
        )
        # reshape leading dim to (groups, per)
        params["layers"] = jax.tree.map(
            lambda x: x.reshape((groups, per) + x.shape[1:]), params["layers"]
        )
        if trailing:
            params["tail"] = _stack_init(
                ks[2], trailing, lambda k: init_mamba_block(k, cfg)
            )
        params["shared"] = init_shared_block(ks[3], cfg)
        params["loras"] = _stack_init(
            ks[4], groups, lambda k: init_shared_lora(k, cfg)
        )
    else:
        raise ValueError(fam)

    if fam == "encdec":
        params["encoder"] = _stack_init(
            ks[5], cfg.n_encoder_layers,
            lambda k: init_transformer_block(k, cfg, cross=False),
        )
        params["enc_norm"] = init_norm(cfg, cfg.d_model)

    params["final_norm"] = init_norm(cfg, cfg.d_model)
    return params


def _stack_spec(spec):
    """Prefix every leaf tuple with the scan ('stack') axis."""
    return jax.tree.map(
        lambda t: ("stack",) + t,
        spec,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(i, (str, type(None))) for i in x),
    )


def model_specs(cfg: ModelConfig):
    specs: Dict[str, Any] = {"embed": spec_embedding(cfg)}
    fam = cfg.family
    if fam in ("dense", "moe", "encdec"):
        specs["layers"] = _stack_spec(spec_transformer_block(cfg, cross=fam == "encdec"))
    elif fam == "ssm":
        specs["layers"] = _stack_spec(spec_mamba_block(cfg))
    elif fam == "hybrid":
        groups, per, trailing = hybrid_layout(cfg)
        specs["layers"] = _stack_spec(_stack_spec(spec_mamba_block(cfg)))
        if trailing:
            specs["tail"] = _stack_spec(spec_mamba_block(cfg))
        specs["shared"] = spec_shared_block(cfg)
        specs["loras"] = _stack_spec(spec_shared_lora(cfg))
    if fam == "encdec":
        specs["encoder"] = _stack_spec(spec_transformer_block(cfg, cross=False))
        specs["enc_norm"] = spec_norm(cfg)
    specs["final_norm"] = spec_norm(cfg)
    return specs


# ------------------------------------------------------------------ embed --


def embed_tokens(params, tokens, cfg: ModelConfig):
    h = params["embed"]["embedding"][tokens].astype(cfg.cdtype())
    if cfg.name.startswith("gemma"):
        h = h * np.sqrt(cfg.d_model).astype(np.float32)
    return constrain(h, "batch", "res_seq", "act_embed")


def logits_from_hidden(params, hidden, cfg: ModelConfig):
    h = apply_norm(params["final_norm"], hidden, cfg)
    if cfg.tie_embeddings:
        logits = jnp.einsum(
            "...d,vd->...v", h, params["embed"]["embedding"]
        ).astype(jnp.float32)
    else:
        logits = jnp.einsum(
            "...d,dv->...v", h, params["embed"]["unembed"]
        ).astype(jnp.float32)
    if cfg.final_softcap > 0:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return constrain(logits, "batch", "seq", "vocab")


# ------------------------------------------------------------------- remat --


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


# ----------------------------------------------------------- full forward --


def _layer_slice(params, i):
    return jax.tree.map(lambda x: x[i], params)


def _dense_stack(params, h, cfg, *, causal, positions, enc_out=None):
    """Scan (or unrolled loop) over stacked transformer blocks.

    cfg.scan_layers=False unrolls: one HLO per layer — used by the roofline
    depth-calibration (scan bodies are cost-counted once by XLA analysis)
    and available for scan-vs-unroll perf experiments.
    """
    L = jax.tree.leaves(params)[0].shape[0]
    is_local = jnp.asarray([cfg.layer_is_local(i) for i in range(L)])

    def body(carry, xs):
        h, aux_acc = carry
        layer, local = xs
        inputs = AttnInputs(positions=positions, layer_local=local)
        h, aux = apply_transformer_block(
            layer, h, cfg, causal=causal, inputs=inputs, enc_out=enc_out
        )
        if aux:
            aux_acc = {k: aux_acc[k] + v for k, v in aux.items()}
        return (h, aux_acc), None

    aux0 = (
        {"load_balance_loss": jnp.zeros(()), "router_z_loss": jnp.zeros(())}
        if cfg.moe is not None
        else {}
    )
    if cfg.scan_layers:
        (h, aux), _ = jax.lax.scan(
            _maybe_remat(body, cfg), (h, aux0), (params, is_local)
        )
    else:
        carry = (h, aux0)
        wrapped = _maybe_remat(body, cfg)
        for i in range(L):
            carry, _ = wrapped(carry, (_layer_slice(params, i), is_local[i]))
        h, aux = carry
    return h, aux


def _ssm_stack(layers, h, cfg):
    def body(carry, layer):
        return apply_mamba_block(layer, carry, cfg), None

    if cfg.scan_layers:
        h, _ = jax.lax.scan(_maybe_remat(body, cfg), h, layers)
    else:
        L = jax.tree.leaves(layers)[0].shape[0]
        wrapped = _maybe_remat(body, cfg)
        for i in range(L):
            h, _ = wrapped(h, _layer_slice(layers, i))
    return h


def _hybrid_stack(params, h, cfg, emb0):
    groups, per, trailing = hybrid_layout(cfg)

    def group_body(carry, xs):
        h = carry
        mamba_layers, lora = xs

        def inner(carry2, layer):
            return apply_mamba_block(layer, carry2, cfg), None

        if cfg.scan_layers:
            h, _ = jax.lax.scan(inner, h, mamba_layers)
        else:
            for j in range(per):
                h, _ = inner(h, _layer_slice(mamba_layers, j))
        h = apply_shared_block(params["shared"], lora, h, emb0, cfg)
        return h, None

    if cfg.scan_layers:
        h, _ = jax.lax.scan(
            _maybe_remat(group_body, cfg), h, (params["layers"], params["loras"])
        )
    else:
        wrapped = _maybe_remat(group_body, cfg)
        for gi in range(groups):
            h, _ = wrapped(
                h, (_layer_slice(params["layers"], gi), _layer_slice(params["loras"], gi))
            )
    if trailing:
        h = _ssm_stack(params["tail"], h, cfg)
    return h


def forward_full(
    params, cfg: ModelConfig, *, tokens=None, embeds=None, positions=None,
    enc_tokens=None, enc_embeds=None, causal=True,
):
    """Full-sequence forward -> (hidden, aux). Provide tokens or embeds.

    encdec: enc_embeds (audio frontend stub output) is encoded first and
    cross-attended by every decoder layer.
    """
    h = embed_tokens(params, tokens, cfg) if embeds is None else embeds
    h = h.astype(cfg.cdtype())
    aux = {}

    enc_out = None
    if cfg.family == "encdec":
        eh = enc_embeds.astype(cfg.cdtype())
        eh, _ = _dense_stack(params["encoder"], eh, cfg, causal=False, positions=None)
        enc_out = apply_norm(params["enc_norm"], eh, cfg)

    if cfg.family in ("dense", "moe", "encdec"):
        h, aux = _dense_stack(
            params["layers"], h, cfg, causal=causal, positions=positions,
            enc_out=enc_out,
        )
    elif cfg.family == "ssm":
        h = _ssm_stack(params["layers"], h, cfg)
    elif cfg.family == "hybrid":
        h = _hybrid_stack(params, h, cfg, emb0=h)
    return h, aux


# ------------------------------------------------------------------- loss --


def lm_loss(params, hidden, targets, mask, cfg: ModelConfig):
    """Chunked softmax cross-entropy; (B, S, V) logits never materialise."""
    # gather the residual stream out of sequence-parallel sharding: the loss
    # scan re-chunks S, and one (B, S, d) copy is cheap relative to logits
    hidden = constrain(hidden, "batch", "seq", "act_embed")
    B, S, _ = hidden.shape
    C = min(cfg.loss_chunk, S)
    while S % C:
        C //= 2
    n = S // C
    hs = jnp.moveaxis(hidden.reshape(B, n, C, -1), 1, 0)
    ts = jnp.moveaxis(targets.reshape(B, n, C), 1, 0)
    ms = jnp.moveaxis(mask.reshape(B, n, C), 1, 0)

    def body(carry, xs):
        h_c, t_c, m_c = xs
        logits = logits_from_hidden(params, h_c, cfg)  # (B, C, V) f32
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t_c[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * m_c
        tot, cnt = carry
        return (tot + jnp.sum(nll), cnt + jnp.sum(m_c)), None

    (tot, cnt), _ = jax.lax.scan(
        jax.checkpoint(body), (jnp.zeros(()), jnp.zeros(())), (hs, ts, ms)
    )
    return tot / jnp.maximum(cnt, 1.0)


# ------------------------------------------------------------------ cache --


@dataclasses.dataclass
class CacheSpec:
    """Shapes of the serving cache for (cfg, batch, max_len)."""

    kv: Optional[tuple] = None  # (L, B, S, Hkv, Dh) x2
    mamba_conv: Optional[tuple] = None
    mamba_ssd: Optional[tuple] = None
    hybrid_kv: Optional[tuple] = None
    cross_kv: Optional[tuple] = None


def init_cache(cfg: ModelConfig, batch: int, max_len: int, enc_len: int = 0):
    """Zeroed cache pytree + length counter for decode."""
    dt = cfg.cdtype()
    Hkv, Dh = cfg.n_kv_heads, cfg.head_dim
    cache: Dict[str, Any] = {"len": jnp.zeros((batch,), jnp.int32)}
    if cfg.family in ("dense", "moe", "encdec"):
        L = cfg.n_layers
        kv_dt = jnp.int8 if cfg.kv_quant else dt
        cache["k"] = jnp.zeros((L, batch, max_len, Hkv, Dh), kv_dt)
        cache["v"] = jnp.zeros((L, batch, max_len, Hkv, Dh), kv_dt)
        if cfg.kv_quant:
            cache["k_scale"] = jnp.zeros((L, batch, max_len, Hkv), jnp.float32)
            cache["v_scale"] = jnp.zeros((L, batch, max_len, Hkv), jnp.float32)
    if cfg.family == "encdec":
        cache["cross_k"] = jnp.zeros((cfg.n_layers, batch, enc_len, Hkv, Dh), dt)
        cache["cross_v"] = jnp.zeros((cfg.n_layers, batch, enc_len, Hkv, Dh), dt)
    if cfg.family in ("ssm", "hybrid"):
        groups = cfg.n_layers if cfg.family == "ssm" else None
        if cfg.family == "ssm":
            conv, ssd = init_mamba_cache(batch, cfg, dt)
            cache["conv"] = jnp.tile(conv[None], (cfg.n_layers,) + (1,) * conv.ndim)
            cache["ssd"] = jnp.tile(ssd[None], (cfg.n_layers,) + (1,) * ssd.ndim)
        else:
            g, per, trailing = hybrid_layout(cfg)
            conv, ssd = init_mamba_cache(batch, cfg, dt)
            cache["conv"] = jnp.tile(conv[None, None], (g, per) + (1,) * conv.ndim)
            cache["ssd"] = jnp.tile(ssd[None, None], (g, per) + (1,) * ssd.ndim)
            if trailing:
                cache["tail_conv"] = jnp.tile(conv[None], (trailing,) + (1,) * conv.ndim)
                cache["tail_ssd"] = jnp.tile(ssd[None], (trailing,) + (1,) * ssd.ndim)
            cache["k"] = jnp.zeros((g, batch, max_len, Hkv, Dh), dt)
            cache["v"] = jnp.zeros((g, batch, max_len, Hkv, Dh), dt)
    return cache


def cache_specs(cfg: ModelConfig):
    """Logical sharding names for each cache leaf."""
    names: Dict[str, Any] = {"len": ("batch",)}
    if cfg.family in ("dense", "moe", "encdec"):
        names["k"] = ("stack", "batch", "kv_seq", "kv_heads", None)
        names["v"] = ("stack", "batch", "kv_seq", "kv_heads", None)
        if cfg.kv_quant:
            names["k_scale"] = ("stack", "batch", "kv_seq", "kv_heads")
            names["v_scale"] = ("stack", "batch", "kv_seq", "kv_heads")
    if cfg.family == "encdec":
        names["cross_k"] = ("stack", "batch", "kv_seq", "kv_heads", None)
        names["cross_v"] = ("stack", "batch", "kv_seq", "kv_heads", None)
    if cfg.family in ("ssm", "hybrid"):
        if cfg.family == "ssm":
            names["conv"] = ("stack", "batch", None, "mlp")
            names["ssd"] = ("stack", "batch", "ssm_heads", None, None)
        else:
            names["conv"] = ("stack", "stack", "batch", None, "mlp")
            names["ssd"] = ("stack", "stack", "batch", "ssm_heads", None, None)
            _, _, trailing = hybrid_layout(cfg)
            if trailing:
                names["tail_conv"] = ("stack", "batch", None, "mlp")
                names["tail_ssd"] = ("stack", "batch", "ssm_heads", None, None)
            names["k"] = ("stack", "batch", "kv_seq", "kv_heads", None)
            names["v"] = ("stack", "batch", "kv_seq", "kv_heads", None)
    return names


# ---------------------------------------------------------------- prefill --


def _pad_cache_seq(x, max_len):
    """Pad a (..., S, Hkv, Dh) cache tensor along S to max_len."""
    S = x.shape[-3]
    if S >= max_len:
        return x[..., :max_len, :, :]
    pad = [(0, 0)] * x.ndim
    pad[-3] = (0, max_len - S)
    return jnp.pad(x, pad)


def forward_prefill(
    params, cfg: ModelConfig, *, tokens=None, embeds=None, positions=None,
    enc_embeds=None, max_len: Optional[int] = None,
):
    """Full-sequence forward that also builds the serving cache.

    Returns (hidden, cache). max_len pads the KV cache for later decoding.
    """
    h = embed_tokens(params, tokens, cfg) if embeds is None else embeds
    h = h.astype(cfg.cdtype())
    B, S = h.shape[0], h.shape[1]
    max_len = max_len or S
    cache: Dict[str, Any] = {"len": jnp.full((B,), S, jnp.int32)}

    enc_out = None
    if cfg.family == "encdec":
        eh = enc_embeds.astype(cfg.cdtype())
        eh, _ = _dense_stack(params["encoder"], eh, cfg, causal=False, positions=None)
        enc_out = apply_norm(params["enc_norm"], eh, cfg)

    if cfg.family in ("dense", "moe", "encdec"):
        L = cfg.n_layers
        is_local = jnp.asarray([cfg.layer_is_local(i) for i in range(L)])

        def body(h, xs):
            layer, local = xs
            inputs = AttnInputs(positions=positions, layer_local=local)
            h, _, kv = apply_transformer_block(
                layer, h, cfg, causal=True, inputs=inputs, enc_out=enc_out,
                return_kv=True,
            )
            return h, kv

        if cfg.scan_layers:
            h, (ks_, vs_) = jax.lax.scan(body, h, (params["layers"], is_local))
        else:
            kvs = []
            for i in range(L):
                h, kv = body(h, (_layer_slice(params["layers"], i), is_local[i]))
                kvs.append(kv)
            ks_ = jnp.stack([k for k, _ in kvs])
            vs_ = jnp.stack([v for _, v in kvs])
        if cfg.kv_quant:
            from .attention import quantize_kv_rows

            kq, ks_sc = quantize_kv_rows(ks_)
            vq, vs_sc = quantize_kv_rows(vs_)
            cache["k"] = _pad_cache_seq(kq, max_len)
            cache["v"] = _pad_cache_seq(vq, max_len)
            pad_sc = lambda s: jnp.pad(
                s, [(0, 0)] * (s.ndim - 2) + [(0, max_len - s.shape[-2]), (0, 0)]
            ) if s.shape[-2] < max_len else s[..., :max_len, :]
            cache["k_scale"] = pad_sc(ks_sc)
            cache["v_scale"] = pad_sc(vs_sc)
        else:
            cache["k"] = _pad_cache_seq(ks_, max_len)
            cache["v"] = _pad_cache_seq(vs_, max_len)
        if cfg.family == "encdec":
            def cross_kv(layer):
                k = jnp.einsum("bsd,dhe->bshe", enc_out, layer["cross_attn"]["wk"])
                v = jnp.einsum("bsd,dhe->bshe", enc_out, layer["cross_attn"]["wv"])
                return k, v

            ck, cv = jax.vmap(cross_kv)(params["layers"])
            cache["cross_k"], cache["cross_v"] = ck, cv

    elif cfg.family == "ssm":
        def body(h, layer):
            h, state = apply_mamba_block(layer, h, cfg, return_state=True)
            return h, state

        if cfg.scan_layers:
            h, (convs, ssds) = jax.lax.scan(body, h, params["layers"])
        else:
            states = []
            for i in range(cfg.n_layers):
                h, st = body(h, _layer_slice(params["layers"], i))
                states.append(st)
            convs = jnp.stack([c for c, _ in states])
            ssds = jnp.stack([s for _, s in states])
        cache["conv"], cache["ssd"] = convs, ssds

    elif cfg.family == "hybrid":
        emb0 = h
        groups, per, trailing = hybrid_layout(cfg)

        def inner(carry, layer):
            h2, state = apply_mamba_block(layer, carry, cfg, return_state=True)
            return h2, state

        def group_body(h, xs):
            mamba_layers, lora = xs
            if cfg.scan_layers:
                h, states = jax.lax.scan(inner, h, mamba_layers)
            else:
                sts = []
                for j in range(per):
                    h, st = inner(h, _layer_slice(mamba_layers, j))
                    sts.append(st)
                states = (jnp.stack([c for c, _ in sts]), jnp.stack([s for _, s in sts]))
            h, kv = apply_shared_block(
                params["shared"], lora, h, emb0, cfg, return_kv=True
            )
            return h, (states, kv)

        if cfg.scan_layers:
            h, ((convs, ssds), (ks_, vs_)) = jax.lax.scan(
                group_body, h, (params["layers"], params["loras"])
            )
        else:
            outs = []
            for gi in range(groups):
                h, out = group_body(
                    h,
                    (_layer_slice(params["layers"], gi), _layer_slice(params["loras"], gi)),
                )
                outs.append(out)
            convs = jnp.stack([o[0][0] for o in outs])
            ssds = jnp.stack([o[0][1] for o in outs])
            ks_ = jnp.stack([o[1][0] for o in outs])
            vs_ = jnp.stack([o[1][1] for o in outs])
        cache["conv"], cache["ssd"] = convs, ssds
        cache["k"] = _pad_cache_seq(ks_, max_len)
        cache["v"] = _pad_cache_seq(vs_, max_len)
        if trailing:
            def tail_body(carry, layer):
                h2, state = apply_mamba_block(layer, carry, cfg, return_state=True)
                return h2, state

            if cfg.scan_layers:
                h, (tc, ts) = jax.lax.scan(tail_body, h, params["tail"])
            else:
                sts = []
                for i in range(trailing):
                    h, st = tail_body(h, _layer_slice(params["tail"], i))
                    sts.append(st)
                tc = jnp.stack([c for c, _ in sts])
                ts = jnp.stack([s for _, s in sts])
            cache["tail_conv"], cache["tail_ssd"] = tc, ts

    return h, cache


# ----------------------------------------------------------------- decode --


def forward_decode(params, cache, tokens, cfg: ModelConfig, *, embeds=None):
    """One-token decode. tokens: (B, 1) -> (logits (B, 1, V), new cache)."""
    h = embed_tokens(params, tokens, cfg) if embeds is None else embeds
    h = h.astype(cfg.cdtype())
    cache = dict(cache)
    length = cache["len"]

    if cfg.family in ("dense", "moe", "encdec"):
        from repro.kernels import ops as kops

        from .layers import apply_mlp
        from .moe import apply_moe

        L = cfg.n_layers
        is_local = jnp.asarray(
            [cfg.layer_is_local(i) for i in range(L)], jnp.int32
        )
        is_encdec = cfg.family == "encdec"

        def body(h, xs):
            scales = None
            if is_encdec:
                layer, ck, cv, cross_k, cross_v, local = xs
            elif cfg.kv_quant:
                layer, ck, cv, ks_s, vs_s, local = xs
                scales = (ks_s, vs_s)
            else:
                layer, ck, cv, local = xs
            # per-layer window as data: gemma2 alternates local/global
            if cfg.local_global_pattern:
                window = local * cfg.sliding_window
            else:
                window = cfg.sliding_window
            x = apply_norm(layer["attn_norm"], h, cfg)
            out = apply_attention_decode(
                layer["attn"], x, ck, cv, length, cfg, window=window, scales=scales
            )
            if cfg.kv_quant:
                a, nk, nv, nscales = out
            else:
                a, nk, nv = out
                nscales = None
            if cfg.post_norm:
                a = apply_norm(layer["attn_post_norm"], a, cfg)
            h = h + a
            if is_encdec:
                cx = apply_norm(layer["cross_norm"], h, cfg)
                q = jnp.einsum("bsd,dhe->bshe", cx, layer["cross_attn"]["wq"])[:, 0]
                enc_len = jnp.full((h.shape[0],), cross_k.shape[1], jnp.int32)
                o = kops.decode_attention(q, cross_k, cross_v, enc_len)
                c = jnp.einsum("bhe,hed->bd", o, layer["cross_attn"]["wo"])[:, None]
                h = h + c
            x = apply_norm(layer["mlp_norm"], h, cfg)
            if cfg.moe is not None:
                m, _ = apply_moe(layer["moe"], x, cfg)
            else:
                m = apply_mlp(layer["mlp"], x, cfg)
            if cfg.post_norm:
                m = apply_norm(layer["mlp_post_norm"], m, cfg)
            return h + m, (nk, nv, nscales) if cfg.kv_quant else (nk, nv)

        xs = (params["layers"], cache["k"], cache["v"])
        if is_encdec:
            xs = xs + (cache["cross_k"], cache["cross_v"])
        elif cfg.kv_quant:
            xs = xs + (cache["k_scale"], cache["v_scale"])
        xs = xs + (is_local,)
        if cfg.scan_layers:
            h, outs = jax.lax.scan(body, h, xs)
        else:
            collected = []
            for i in range(L):
                h, out = body(h, jax.tree.map(lambda x: x[i], xs))
                collected.append(out)
            outs = jax.tree.map(lambda *xs_: jnp.stack(xs_), *collected)
        if cfg.kv_quant:
            nk, nv, (nks, nvs) = outs
            cache["k_scale"], cache["v_scale"] = nks, nvs
        else:
            nk, nv = outs
        cache["k"], cache["v"] = nk, nv

    elif cfg.family == "ssm":
        def body(h, xs):
            layer, conv, ssd = xs
            x = apply_norm(layer["norm"], h, cfg)
            y, (nconv, nssd) = mamba_decode(layer["mamba"], x, conv, ssd, cfg)
            return h + y, (nconv, nssd)

        xs = (params["layers"], cache["conv"], cache["ssd"])
        if cfg.scan_layers:
            h, (nconv, nssd) = jax.lax.scan(body, h, xs)
        else:
            sts = []
            for i in range(cfg.n_layers):
                h, st = body(h, jax.tree.map(lambda x: x[i], xs))
                sts.append(st)
            nconv = jnp.stack([c for c, _ in sts])
            nssd = jnp.stack([s for _, s in sts])
        cache["conv"], cache["ssd"] = nconv, nssd

    elif cfg.family == "hybrid":
        emb0 = h

        def group_body(h, xs):
            mamba_layers, lora, convs, ssds, ck, cv = xs

            def inner(carry, ys):
                layer, conv, ssd = ys
                x = apply_norm(layer["norm"], carry, cfg)
                y, (nconv, nssd) = mamba_decode(layer["mamba"], x, conv, ssd, cfg)
                return carry + y, (nconv, nssd)

            if cfg.scan_layers:
                h2, (nconvs, nssds) = jax.lax.scan(
                    inner, h, (mamba_layers, convs, ssds)
                )
            else:
                h2 = h
                sts2 = []
                for j in range(cfg.shared_every):
                    h2, st2 = inner(
                        h2, jax.tree.map(lambda x: x[j], (mamba_layers, convs, ssds))
                    )
                    sts2.append(st2)
                nconvs = jnp.stack([c for c, _ in sts2])
                nssds = jnp.stack([s for _, s in sts2])
            # shared attention block, decode form
            u = jnp.concatenate([h2, emb0], axis=-1) @ params["shared"]["in_proj"]
            x = apply_norm(params["shared"]["norm"], u, cfg)
            attn_p = lora_attention_params(params["shared"], lora, cfg)
            a, nk, nv = apply_attention_decode(
                attn_p, x, ck, cv, length, cfg, window=cfg.sliding_window
            )
            h2 = h2 + a
            from .layers import apply_mlp
            m = apply_mlp(
                params["shared"]["mlp"],
                apply_norm(params["shared"]["mlp_norm"], h2, cfg),
                cfg,
            )
            return h2 + m, (nconvs, nssds, nk, nv)

        xs = (params["layers"], params["loras"], cache["conv"], cache["ssd"],
              cache["k"], cache["v"])
        if cfg.scan_layers:
            h, (nconv, nssd, nk, nv) = jax.lax.scan(group_body, h, xs)
        else:
            groups = hybrid_layout(cfg)[0]
            outs = []
            for gi in range(groups):
                h, out = group_body(h, jax.tree.map(lambda x: x[gi], xs))
                outs.append(out)
            nconv = jnp.stack([o[0] for o in outs])
            nssd = jnp.stack([o[1] for o in outs])
            nk = jnp.stack([o[2] for o in outs])
            nv = jnp.stack([o[3] for o in outs])
        cache["conv"], cache["ssd"] = nconv, nssd
        cache["k"], cache["v"] = nk, nv
        _, _, trailing = hybrid_layout(cfg)
        if trailing:
            def tail_body(carry, ys):
                layer, conv, ssd = ys
                x = apply_norm(layer["norm"], carry, cfg)
                y, (nc2, ns2) = mamba_decode(layer["mamba"], x, conv, ssd, cfg)
                return carry + y, (nc2, ns2)

            txs = (params["tail"], cache["tail_conv"], cache["tail_ssd"])
            if cfg.scan_layers:
                h, (tc, ts) = jax.lax.scan(tail_body, h, txs)
            else:
                sts = []
                for i in range(trailing):
                    h, st = tail_body(h, jax.tree.map(lambda x: x[i], txs))
                    sts.append(st)
                tc = jnp.stack([c for c, _ in sts])
                ts = jnp.stack([s for _, s in sts])
            cache["tail_conv"], cache["tail_ssd"] = tc, ts

    cache["len"] = length + 1
    logits = logits_from_hidden(params, h, cfg)
    return logits, cache

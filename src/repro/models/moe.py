"""Mixture-of-Experts layer: top-k routing with capacity, einsum dispatch.

GShard/Switch-style: tokens are routed to their top-k experts subject to a
per-expert capacity C = ceil(T / E * capacity_factor * k); overflow tokens
drop that expert (their gate mass is lost, the residual stream carries them).
Dispatch/combine are one-hot einsums — under GSPMD with expert weights
sharded over the `model` (or `data`×`model` for grok) axes, the partitioner
lowers these to all-to-alls: this IS expert parallelism in pjit form.

Router math runs in float32 (bf16 router logits are a known training hazard).
Aux losses: load-balance (Switch eq. 4) + router z-loss (ST-MoE).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain

from .config import ModelConfig

__all__ = ["init_moe", "spec_moe", "apply_moe"]


def init_moe(key, cfg: ModelConfig):
    m = cfg.moe
    dt = cfg.pdtype()
    d, f, E = cfg.d_model, m.d_ff_expert, m.n_experts
    ks = jax.random.split(key, 4)
    s_in, s_out = 1.0 / np.sqrt(d), 1.0 / np.sqrt(f)
    return {
        "router": (jax.random.normal(ks[0], (d, E), jnp.float32) * s_in).astype(
            jnp.float32
        ),
        "w_gate": (jax.random.normal(ks[1], (E, d, f), jnp.float32) * s_in).astype(dt),
        "w_up": (jax.random.normal(ks[2], (E, d, f), jnp.float32) * s_in).astype(dt),
        "w_down": (jax.random.normal(ks[3], (E, f, d), jnp.float32) * s_out).astype(dt),
    }


def spec_moe(cfg: ModelConfig):
    return {
        "router": ("embed", None),
        "w_gate": ("experts", "expert_embed", "expert_mlp"),
        "w_up": ("experts", "expert_embed", "expert_mlp"),
        "w_down": ("experts", "expert_mlp", "expert_embed"),
    }


def _top_k_gates(logits, k):
    """Normalised top-k gates. logits: (G, Tg, E) f32 -> sparse gates."""
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, _ = jax.lax.top_k(probs, k)  # (G, Tg, k)
    thresh = top_vals[..., -1:]
    sel = probs >= thresh  # (G, Tg, E) — top-k membership
    gates = jnp.where(sel, probs, 0.0)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    return gates


def apply_moe(params, x, cfg: ModelConfig):
    """x: (B, S, d) -> (y, aux) with aux = {load_balance_loss, router_z_loss}."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, k = m.n_experts, m.top_k
    Tg = min(m.group_size, T)
    while T % Tg:
        Tg //= 2
    G = T // Tg
    C = int(np.ceil(Tg / E * m.capacity_factor * k))
    C = max(C, k)

    xt = x.reshape(G, Tg, d)
    xt = constrain(xt, "batch", None, "act_embed")
    logits = xt.astype(jnp.float32) @ params["router"]  # (G, Tg, E)
    gates = _top_k_gates(logits, k)

    # aux losses (Switch-style load balance + ST-MoE z-loss)
    probs = jax.nn.softmax(logits, axis=-1)
    me = jnp.mean(probs, axis=(0, 1))  # (E,)
    ce = jnp.mean((gates > 0).astype(jnp.float32), axis=(0, 1)) * E / k
    lb_loss = jnp.sum(me * ce)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))

    # position of each token within each expert's per-group capacity buffer
    mask = (gates > 0).astype(jnp.int32)  # (G, Tg, E)
    pos_in_expert = jnp.cumsum(mask, axis=1) * mask - 1  # -1 if unrouted
    keep = (pos_in_expert >= 0) & (pos_in_expert < C)
    gates = jnp.where(keep, gates, 0.0)

    # dispatch tensor (G, Tg, E, C) — one-hot over capacity slots
    pos_clip = jnp.clip(pos_in_expert, 0, C - 1)
    dispatch = jax.nn.one_hot(pos_clip, C, dtype=x.dtype) * keep[..., None].astype(x.dtype)
    combine = dispatch * gates[..., None].astype(x.dtype)

    expert_in = jnp.einsum("gtec,gtd->gecd", dispatch, xt)
    expert_in = constrain(expert_in, "batch", "experts", None, "act_embed")
    h = jnp.einsum("gecd,edf->gecf", expert_in, params["w_up"])
    g = jnp.einsum("gecd,edf->gecf", expert_in, params["w_gate"])
    h = jax.nn.gelu(g, approximate=True) * h
    h = constrain(h, "batch", "experts", None, "expert_mlp")
    expert_out = jnp.einsum("gecf,efd->gecd", h, params["w_down"])
    expert_out = constrain(expert_out, "batch", "experts", None, "act_embed")
    y = jnp.einsum("gtec,gecd->gtd", combine, expert_out)

    aux = {"load_balance_loss": lb_loss, "router_z_loss": z_loss}
    return y.reshape(B, S, d), aux

"""Train / serve step factories — the functions the launcher jits and shards.

``make_train_step``: loss + grad + optimizer update, with optional microbatch
gradient accumulation (the per-microbatch psum overlaps the next microbatch's
compute under GSPMD — DESIGN.md §6 "distributed-optimization tricks").

``make_prefill_step`` / ``make_decode_step``: the serving pair. Decode takes
the cache as an argument and returns the updated cache (functional style, so
the same lowering serves continuous batching: the host swaps finished rows).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.train import optim as optim_lib

from .config import ModelConfig
from .transformer import (
    forward_decode,
    forward_full,
    forward_prefill,
    lm_loss,
)

__all__ = ["loss_fn", "make_train_step", "make_prefill_step", "make_decode_step"]

MOE_LB_WEIGHT = 0.01
MOE_Z_WEIGHT = 1e-3


def loss_fn(params, batch: Dict, cfg: ModelConfig):
    """Scalar training loss for one (micro)batch."""
    kwargs = {}
    if cfg.family == "encdec":
        kwargs["enc_embeds"] = batch["src_embeds"]
    if "positions" in batch:
        kwargs["positions"] = batch["positions"]
    if cfg.frontend == "vision" and "vision_embeds" in batch:
        # stub frontend: patch embeddings replace the first P token slots
        from .transformer import embed_tokens

        h = embed_tokens(params, batch["tokens"], cfg)
        P = batch["vision_embeds"].shape[1]
        h = jnp.concatenate(
            [batch["vision_embeds"].astype(h.dtype), h[:, P:]], axis=1
        )
        kwargs["embeds"] = h
    else:
        kwargs["tokens"] = batch["tokens"]

    hidden, aux = forward_full(params, cfg, **kwargs)
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(batch["targets"], jnp.float32)
    loss = lm_loss(params, hidden, batch["targets"], mask, cfg)
    metrics = {"xent": loss}
    if aux:
        loss = (
            loss
            + MOE_LB_WEIGHT * aux["load_balance_loss"]
            + MOE_Z_WEIGHT * aux["router_z_loss"]
        )
        metrics.update(aux)
    metrics["loss"] = loss
    return loss, metrics


def make_train_step(
    cfg: ModelConfig,
    optimizer: optim_lib.GradientTransform,
    accum_steps: int = 1,
    grad_transform: Optional[Callable] = None,
) -> Callable:
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``grad_transform`` hooks (e.g. cross-pod gradient compression) run on the
    accumulated gradients before the optimizer.
    """

    def single_grads(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch, cfg
        )
        return grads, metrics

    def step(params, opt_state, batch):
        if accum_steps == 1:
            grads, metrics = single_grads(params, batch)
        else:
            def micro(carry, mb):
                acc, = carry
                g, m = single_grads(params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc,), m

            micro_batches = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps) + x.shape[1:]),
                batch,
            )
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (acc,), ms = jax.lax.scan(micro, (zeros,), micro_batches)
            grads = jax.tree.map(lambda g: g / accum_steps, acc)
            metrics = jax.tree.map(lambda m: m[-1], ms)
        if grad_transform is not None:
            grads = grad_transform(grads)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = optim_lib.apply_updates(params, updates)
        metrics["grad_norm"] = optim_lib.global_norm(grads)
        return params, opt_state, metrics

    return step


def make_prefill_step(cfg: ModelConfig, max_len: Optional[int] = None) -> Callable:
    """step(params, batch) -> (last-token logits, cache)."""

    def step(params, batch):
        kwargs = {}
        if cfg.family == "encdec":
            kwargs["enc_embeds"] = batch["src_embeds"]
        if "positions" in batch:
            kwargs["positions"] = batch["positions"]
        if cfg.frontend == "vision" and "vision_embeds" in batch:
            from .transformer import embed_tokens

            h = embed_tokens(params, batch["tokens"], cfg)
            P = batch["vision_embeds"].shape[1]
            h = jnp.concatenate(
                [batch["vision_embeds"].astype(h.dtype), h[:, P:]], axis=1
            )
            kwargs["embeds"] = h
        else:
            kwargs["tokens"] = batch["tokens"]
        hidden, cache = forward_prefill(params, cfg, max_len=max_len, **kwargs)
        from .transformer import logits_from_hidden

        logits = logits_from_hidden(params, hidden[:, -1:], cfg)
        return logits, cache

    return step


def make_decode_step(cfg: ModelConfig) -> Callable:
    """step(params, cache, tokens (B,1)) -> (logits (B,1,V), cache)."""

    def step(params, cache, tokens):
        return forward_decode(params, cache, tokens, cfg)

    return step

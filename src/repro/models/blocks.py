"""Residual blocks: dense/MoE transformer, mamba, and zamba2's shared block.

Block params are built per-layer and stacked by the model assembly (vmap over
layer keys) so the forward is a single scanned program — one lowered layer in
the HLO regardless of depth, which is what keeps 512-device dry-run compiles
tractable (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain

from .attention import AttnInputs, apply_attention, init_attention, spec_attention
from .config import ModelConfig
from .layers import apply_mlp, apply_norm, init_mlp, init_norm, spec_mlp, spec_norm
from .mamba2 import init_mamba, mamba_forward, spec_mamba
from .moe import apply_moe, init_moe, spec_moe

__all__ = [
    "init_transformer_block", "spec_transformer_block", "apply_transformer_block",
    "init_mamba_block", "spec_mamba_block", "apply_mamba_block",
    "init_shared_block", "spec_shared_block", "init_shared_lora",
    "spec_shared_lora", "apply_shared_block",
]


# ------------------------------------------------- dense / moe transformer --


def init_transformer_block(key, cfg: ModelConfig, *, cross: bool = False):
    ks = jax.random.split(key, 4)
    p = {
        "attn_norm": init_norm(cfg, cfg.d_model),
        "attn": init_attention(ks[0], cfg),
        "mlp_norm": init_norm(cfg, cfg.d_model),
    }
    if cfg.moe is not None:
        p["moe"] = init_moe(ks[1], cfg)
    else:
        p["mlp"] = init_mlp(ks[1], cfg, cfg.d_model, cfg.d_ff)
    if cfg.post_norm:
        p["attn_post_norm"] = init_norm(cfg, cfg.d_model)
        p["mlp_post_norm"] = init_norm(cfg, cfg.d_model)
    if cross:
        p["cross_norm"] = init_norm(cfg, cfg.d_model)
        p["cross_attn"] = init_attention(ks[2], cfg, cross=True)
    return p


def spec_transformer_block(cfg: ModelConfig, *, cross: bool = False):
    p = {
        "attn_norm": spec_norm(cfg),
        "attn": spec_attention(cfg),
        "mlp_norm": spec_norm(cfg),
    }
    if cfg.moe is not None:
        p["moe"] = spec_moe(cfg)
    else:
        p["mlp"] = spec_mlp(cfg)
    if cfg.post_norm:
        p["attn_post_norm"] = spec_norm(cfg)
        p["mlp_post_norm"] = spec_norm(cfg)
    if cross:
        p["cross_norm"] = spec_norm(cfg)
        p["cross_attn"] = spec_attention(cfg, cross=True)
    return p


def apply_transformer_block(
    params, h, cfg: ModelConfig, *, causal=True, inputs: AttnInputs = None,
    enc_out=None, use_chunked=True, return_kv=False,
):
    """Pre-norm residual block. Returns (h, aux[, kv]) — aux carries MoE losses."""
    aux = {}
    a = apply_attention(
        params["attn"], apply_norm(params["attn_norm"], h, cfg), cfg,
        causal=causal, inputs=inputs, use_chunked=use_chunked, return_kv=return_kv,
    )
    kv = None
    if return_kv:
        a, kv = a
    if cfg.post_norm:
        a = apply_norm(params["attn_post_norm"], a, cfg)
    # constrain the sublayer OUTPUT (a TP partial-sum) straight to the
    # sequence-parallel layout: the partitioner then lowers it as a
    # reduce-scatter instead of an all-reduce followed by an all-gather
    # (§Perf iteration 7)
    a = constrain(a, "batch", "res_seq", "act_embed")
    h = constrain(h + a, "batch", "res_seq", "act_embed")

    if enc_out is not None:
        c = apply_attention(
            params["cross_attn"], apply_norm(params["cross_norm"], h, cfg), cfg,
            causal=False, kv_override=enc_out, use_chunked=use_chunked,
        )
        h = constrain(h + c, "batch", "res_seq", "act_embed")

    x = apply_norm(params["mlp_norm"], h, cfg)
    if cfg.moe is not None:
        m, aux = apply_moe(params["moe"], x, cfg)
    else:
        m = apply_mlp(params["mlp"], x, cfg)
    if cfg.post_norm:
        m = apply_norm(params["mlp_post_norm"], m, cfg)
    m = constrain(m, "batch", "res_seq", "act_embed")  # RS not AR+AG (§Perf)
    h = constrain(h + m, "batch", "res_seq", "act_embed")
    if return_kv:
        return h, aux, kv
    return h, aux


# ------------------------------------------------------------------- mamba --


def init_mamba_block(key, cfg: ModelConfig):
    return {"norm": init_norm(cfg, cfg.d_model), "mamba": init_mamba(key, cfg)}


def spec_mamba_block(cfg: ModelConfig):
    return {"norm": spec_norm(cfg), "mamba": spec_mamba(cfg)}


def apply_mamba_block(params, h, cfg: ModelConfig, *, return_state=False):
    y = mamba_forward(
        params["mamba"], apply_norm(params["norm"], h, cfg), cfg,
        return_state=return_state,
    )
    state = None
    if return_state:
        y, state = y
    h = constrain(h + y, "batch", "res_seq", "act_embed")
    if return_state:
        return h, state
    return h


# ------------------------------------------------- zamba2 shared attention --


def init_shared_block(key, cfg: ModelConfig):
    """One set of attention+MLP weights, reused at every shared invocation.

    The block sees concat(hidden, initial_embedding) projected back to d
    (zamba2's global-memory trick)."""
    ks = jax.random.split(key, 3)
    dt = cfg.pdtype()
    d = cfg.d_model
    return {
        "in_proj": (
            jax.random.normal(ks[0], (2 * d, d), jnp.float32) / np.sqrt(2 * d)
        ).astype(dt),
        "norm": init_norm(cfg, d),
        "attn": init_attention(ks[1], cfg),
        "mlp_norm": init_norm(cfg, d),
        "mlp": init_mlp(ks[2], cfg, d, cfg.d_ff),
    }


def spec_shared_block(cfg: ModelConfig):
    return {
        "in_proj": ("embed", "embed"),
        "norm": spec_norm(cfg),
        "attn": spec_attention(cfg),
        "mlp_norm": spec_norm(cfg),
        "mlp": spec_mlp(cfg),
    }


def init_shared_lora(key, cfg: ModelConfig):
    """Per-invocation LoRA on the shared block's qkv projections."""
    r = cfg.shared_lora_rank
    d = cfg.d_model
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = cfg.pdtype()
    ks = jax.random.split(key, 6)
    s = 1.0 / np.sqrt(d)

    def pair(ka, kb, out):
        return (
            (jax.random.normal(ka, (d, r), jnp.float32) * s).astype(dt),
            jnp.zeros((r, out), dt),  # zero-init B: LoRA starts as identity
        )

    qA, qB = pair(ks[0], ks[1], H * Dh)
    kA, kB = pair(ks[2], ks[3], Hkv * Dh)
    vA, vB = pair(ks[4], ks[5], Hkv * Dh)
    return {"qA": qA, "qB": qB, "kA": kA, "kB": kB, "vA": vA, "vB": vB}


def spec_shared_lora(cfg: ModelConfig):
    return {
        "qA": ("embed", "lora"), "qB": ("lora", "heads_joined"),
        "kA": ("embed", "lora"), "kB": ("lora", "kv_joined"),
        "vA": ("embed", "lora"), "vB": ("lora", "kv_joined"),
    }


def lora_attention_params(shared, lora, cfg: ModelConfig):
    """Shared attention weights with this invocation's LoRA deltas folded in."""
    d = cfg.d_model
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    attn_p = dict(shared["attn"])
    attn_p["wq"] = attn_p["wq"] + (lora["qA"] @ lora["qB"]).reshape(d, H, Dh)
    attn_p["wk"] = attn_p["wk"] + (lora["kA"] @ lora["kB"]).reshape(d, Hkv, Dh)
    attn_p["wv"] = attn_p["wv"] + (lora["vA"] @ lora["vB"]).reshape(d, Hkv, Dh)
    return attn_p


def apply_shared_block(shared, lora, h, emb0, cfg: ModelConfig, *, use_chunked=True,
                       return_kv=False):
    """Zamba2 shared block with per-invocation LoRA deltas."""
    u = jnp.concatenate([h, emb0], axis=-1) @ shared["in_proj"]
    x = apply_norm(shared["norm"], u, cfg)
    attn_p = lora_attention_params(shared, lora, cfg)
    a = apply_attention(attn_p, x, cfg, causal=True, use_chunked=use_chunked,
                        return_kv=return_kv)
    kv = None
    if return_kv:
        a, kv = a
    h = constrain(h + a, "batch", "res_seq", "act_embed")
    m = apply_mlp(shared["mlp"], apply_norm(shared["mlp_norm"], h, cfg), cfg)
    h = constrain(h + m, "batch", "res_seq", "act_embed")
    if return_kv:
        return h, kv
    return h

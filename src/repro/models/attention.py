"""GQA attention: reference, chunked (memory-efficient) train path, decode.

Three execution paths, one semantics:
  * ``attention_reference`` — full (B, Hkv, G, Sq, Skv) scores; tests/small S.
  * ``attention_chunked``  — online-softmax over KV chunks inside a scan over
    Q chunks; never materialises the score matrix. This is the train/prefill
    path (XLA on TPU pipelines the chunk einsums through the MXU; the scan
    body is rematerialised in backward). Peak live buffer per step:
    (B, Hkv, G, cq, ckv) — independent of sequence length.
  * ``kernels.ops.decode_attention`` — single-token flash-decode (Pallas on
    TPU), used by serve_step.

Variants handled uniformly: GQA grouping (never repeats KV into H heads),
logit softcap (gemma2), sliding window (gemma2 local / zamba2-500k),
per-head qk RMSNorm (qwen3), partial RoPE (nemotron), M-RoPE (qwen2-vl).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain
from repro.kernels import ops as kops

from .config import ModelConfig
from .layers import apply_mrope, apply_rope

NEG_INF = -1.0e30


# ------------------------------------------------------------------ params --


def init_attention(key, cfg: ModelConfig, *, cross: bool = False):
    dt = cfg.pdtype()
    d = cfg.d_model
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(d)
    so = 1.0 / np.sqrt(H * Dh)
    p = {
        "wq": (jax.random.normal(ks[0], (d, H, Dh), jnp.float32) * s).astype(dt),
        "wk": (jax.random.normal(ks[1], (d, Hkv, Dh), jnp.float32) * s).astype(dt),
        "wv": (jax.random.normal(ks[2], (d, Hkv, Dh), jnp.float32) * s).astype(dt),
        "wo": (jax.random.normal(ks[3], (H, Dh, d), jnp.float32) * so).astype(dt),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((Dh,), jnp.float32)
        p["k_norm"] = jnp.ones((Dh,), jnp.float32)
    return p


def spec_attention(cfg: ModelConfig, *, cross: bool = False):
    p = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = (None,)
        p["k_norm"] = (None,)
    return p


def _qk_norm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    rms = jnp.sqrt(jnp.mean(jnp.square(xf), axis=-1, keepdims=True) + eps)
    return (xf / rms * scale).astype(x.dtype)


# ------------------------------------------------------------------- cores --


def _mask(pos_q, pos_k, *, causal: bool, window, kv_len=None):
    """(..., Sq, Sk) boolean mask from absolute positions.

    ``window`` may be a python int or a traced scalar (scanned per-layer
    local/global alternation); window <= 0 disables it.
    """
    m = jnp.ones(pos_q.shape[:-1] + (pos_q.shape[-1], pos_k.shape[-1]), bool)
    pq = pos_q[..., :, None]
    pk = pos_k[..., None, :]
    if causal:
        m = m & (pk <= pq)
    window = jnp.asarray(window)
    m = m & ((pq - pk < window) | (window <= 0))
    if kv_len is not None:
        m = m & (pk < kv_len[..., None, None])
    return m


def attention_reference(
    q, k, v, *, causal: bool, window: int = 0, softcap: float = 0.0,
    q_offset: int = 0, kv_len=None,
):
    """q: (B, Sq, H, Dh); k, v: (B, Sk, Hkv, Dh) -> (B, Sq, H, Dh)."""
    B, Sq, H, Dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, Dh).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    s = s / np.sqrt(Dh)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    pos_q = q_offset + jnp.arange(Sq)
    pos_k = jnp.arange(Sk)
    m = _mask(pos_q, pos_k, causal=causal, window=window)  # (Sq, Sk)
    m = m[None, None, None, :, :]  # -> (1, 1, 1, Sq, Sk)
    if kv_len is not None:
        m = m & (pos_k[None, :] < kv_len[:, None])[:, None, None, None, :]
    s = jnp.where(m, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, Dh).astype(q.dtype)


def attention_chunked(
    q, k, v, *, causal: bool, window: int = 0, softcap: float = 0.0,
    chunk_q: int = 512, chunk_kv: int = 1024,
):
    """Online-softmax attention; same contract as attention_reference
    (q_offset=0, no kv_len — the padded-cache case goes through the decode
    kernel instead)."""
    B, Sq, H, Dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    cq = min(chunk_q, Sq)
    ckv = min(chunk_kv, Sk)
    if Sq % cq or Sk % ckv:
        # fall back for ragged shapes (tests with odd sizes)
        return attention_reference(
            q, k, v, causal=causal, window=window, softcap=softcap
        )
    nq, nk = Sq // cq, Sk // ckv
    scale = 1.0 / np.sqrt(Dh)

    qg = q.reshape(B, nq, cq, Hkv, G, Dh)
    qg = jnp.moveaxis(qg, 1, 0)  # (nq, B, cq, Hkv, G, Dh)
    kc = jnp.moveaxis(k.reshape(B, nk, ckv, Hkv, Dh), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nk, ckv, Hkv, Dh), 1, 0)

    def q_step(_, qi_qc):
        qi, qcnk = qi_qc
        qc = qcnk.astype(jnp.float32)

        def kv_step(carry, ki_kv):
            m_run, l_run, acc = carry
            ki, kb, vb = ki_kv

            def compute(args):
                m_run, l_run, acc = args
                s = jnp.einsum(
                    "bqhgd,bkhd->bhgqk", qc, kb.astype(jnp.float32),
                    preferred_element_type=jnp.float32,
                ) * scale
                if softcap > 0:
                    s = softcap * jnp.tanh(s / softcap)
                pos_q = qi * cq + jnp.arange(cq)
                pos_k = ki * ckv + jnp.arange(ckv)
                msk = _mask(pos_q, pos_k, causal=causal, window=window)
                s = jnp.where(msk, s, NEG_INF)
                m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
                alpha = jnp.exp(m_run - m_new)
                p = jnp.exp(s - m_new[..., None])
                p = jnp.where(msk, p, 0.0)
                l_new = alpha * l_run + jnp.sum(p, axis=-1)
                pv = jnp.einsum(
                    "bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32),
                    preferred_element_type=jnp.float32,
                )
                acc_new = alpha[..., None] * acc + pv
                return m_new, l_new, acc_new

            def skip(args):
                return args

            # block-level visibility: skip blocks with no unmasked pair
            # (runtime win on TPU; static FLOP analysis still counts both
            # branches — corrected analytically in the roofline, §Roofline)
            first_q, last_q = qi * cq, qi * cq + cq - 1
            first_k, last_k = ki * ckv, ki * ckv + ckv - 1
            win = jnp.asarray(window)
            visible = jnp.array(True)
            if causal:
                visible = visible & (first_k <= last_q)
            visible = visible & ((last_k > first_q - win) | (win <= 0))
            carry = jax.lax.cond(visible, compute, skip, (m_run, l_run, acc))
            return carry, None

        m0 = jnp.full((B, Hkv, G, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, cq), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, cq, Dh), jnp.float32)
        (m_run, l_run, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_step), (m0, l0, a0), (jnp.arange(nk), kc, vc)
        )
        out = acc / jnp.maximum(l_run, 1e-30)[..., None]  # (B, Hkv, G, cq, Dh)
        out = jnp.moveaxis(out, 3, 1).reshape(B, cq, H, Dh)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qg))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, Dh)
    return out


# ------------------------------------------------------------ full module --


@dataclasses.dataclass
class AttnInputs:
    positions: Optional[jnp.ndarray] = None  # (B, S) or (3, B, S) for mrope
    layer_local: bool = False  # gemma2: this layer uses the sliding window


def apply_attention(
    params, x, cfg: ModelConfig, *, causal: bool = True, inputs: AttnInputs = None,
    kv_override=None, use_chunked: bool = True, return_kv: bool = False,
):
    """Self- (or cross-, via kv_override) attention sublayer, train/prefill.

    return_kv=True additionally returns the post-rope (k, v) — the serving
    cache entries for this layer."""
    inputs = inputs or AttnInputs()
    B, S, _ = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"])
    kv_src = x if kv_override is None else kv_override
    k = jnp.einsum("bsd,dhe->bshe", kv_src, params["wk"])
    v = jnp.einsum("bsd,dhe->bshe", kv_src, params["wv"])

    if cfg.qk_norm and "q_norm" in params:
        q = _qk_norm(q, params["q_norm"])
        k = _qk_norm(k, params["k_norm"])

    if kv_override is None:  # rope only on self-attention
        pos = inputs.positions
        if pos is None:
            pos = jnp.arange(S)[None, :].astype(jnp.int32)
            pos = jnp.broadcast_to(pos, (B, S))
        if cfg.mrope_sections is not None and pos.ndim == 3:
            q = apply_mrope(q, pos, cfg)
            k = apply_mrope(k, pos, cfg)
        else:
            if pos.ndim == 3:
                pos = pos[0]
            q = apply_rope(q, pos, cfg)
            k = apply_rope(k, pos, cfg)

    # "attn_seq" is () by default (pure head-TP); archs whose head counts
    # don't divide the model axis override it to ("model",) — Ulysses-style
    # sequence parallelism with the (small, GQA) KV replicated.
    q = constrain(q, "batch", "attn_seq", "heads", None)
    k = constrain(k, "batch", "seq", "kv_heads", None)
    v = constrain(v, "batch", "seq", "kv_heads", None)

    if cfg.local_global_pattern:
        # layer_local may be traced (scanned per-layer flag)
        window = jnp.asarray(inputs.layer_local).astype(jnp.int32) * cfg.sliding_window
    else:
        window = cfg.sliding_window
    attn = attention_chunked if use_chunked else attention_reference
    out = attn(
        q, k, v, causal=causal, window=window, softcap=cfg.attn_softcap,
        **({"chunk_q": cfg.attn_chunk_q, "chunk_kv": cfg.attn_chunk_kv} if use_chunked else {}),
    )
    out = constrain(out, "batch", "attn_seq", "heads", None)
    y = jnp.einsum("bshe,hed->bsd", out, params["wo"])
    if return_kv:
        return y, (k, v)
    return y


def quantize_kv_rows(x):
    """Symmetric int8 per-(batch, head) quantisation of new K/V rows.

    x: (B, Hkv, Dh) -> (int8 rows, (B, Hkv) f32 scales)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = amax / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def apply_attention_decode(params, x, cache_k, cache_v, cache_len, cfg: ModelConfig,
                           *, window: int = 0, positions=None, scales=None):
    """Single-token decode. x: (B, 1, d); cache: (B, S, Hkv, Dh); returns
    ((B, 1, d), new_k, new_v[, new_scales]) with the token appended at
    cache_len. With cfg.kv_quant the cache is int8 and ``scales`` is the
    ((B, S, Hkv), (B, S, Hkv)) f32 scale pair."""
    B = x.shape[0]
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"])[:, 0]  # (B, H, Dh)
    k = jnp.einsum("bsd,dhe->bshe", x, params["wk"])[:, 0]
    v = jnp.einsum("bsd,dhe->bshe", x, params["wv"])[:, 0]
    if cfg.qk_norm and "q_norm" in params:
        q = _qk_norm(q, params["q_norm"])
        k = _qk_norm(k, params["k_norm"])
    pos = cache_len if positions is None else positions
    if cfg.mrope_sections is not None:
        pos3 = jnp.broadcast_to(pos[None, :, None], (3, B, 1))
        q = apply_mrope(q[:, None], pos3, cfg)[:, 0]
        k = apply_mrope(k[:, None], pos3, cfg)[:, 0]
    else:
        q = apply_rope(q[:, None], pos[:, None], cfg)[:, 0]
        k = apply_rope(k[:, None], pos[:, None], cfg)[:, 0]

    # append to cache at position cache_len (per-row scatter; with donation
    # this is an in-place update, not a cache-sized temp)
    rows = jnp.arange(B)
    k_scale = v_scale = None
    if cfg.kv_quant:
        k_scale, v_scale = scales
        kq, ks = quantize_kv_rows(k)
        vq, vs = quantize_kv_rows(v)
        cache_k = cache_k.at[rows, cache_len].set(kq)
        cache_v = cache_v.at[rows, cache_len].set(vq)
        k_scale = k_scale.at[rows, cache_len].set(ks)
        v_scale = v_scale.at[rows, cache_len].set(vs)
    else:
        cache_k = cache_k.at[rows, cache_len].set(k)
        cache_v = cache_v.at[rows, cache_len].set(v)

    out = kops.decode_attention(
        q, cache_k, cache_v, cache_len + 1, softcap=cfg.attn_softcap,
        window=window, k_scale=k_scale, v_scale=v_scale,
    )  # (B, H, Dh)
    y = jnp.einsum("bhe,hed->bd", out, params["wo"])
    if cfg.kv_quant:
        return y[:, None], cache_k, cache_v, (k_scale, v_scale)
    return y[:, None], cache_k, cache_v

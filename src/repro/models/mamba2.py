"""Mamba2 (SSD — state-space duality) block: chunked train path + O(1) decode.

The SSD algorithm (Dao & Gu 2024) splits the sequence into chunks of length Q:
within a chunk the recurrence is computed as a (masked, decay-weighted)
quadratic form — MXU-friendly matmuls; across chunks a tiny (h, n, p) state is
carried by a scan. Decode is a single state update: this is why the SSM/hybrid
archs are the ones that run the 500k long-context shape (DESIGN.md §4).

Layout conventions (B batch, S seq, h heads, p head_dim, g groups, n state):
  x: (B, S, h, p)   B_in/C: (B, S, g, n)   dt: (B, S, h)   state: (B, h, n, p)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain

from .config import ModelConfig
from .layers import rms_norm_groups

__all__ = [
    "init_mamba",
    "spec_mamba",
    "mamba_forward",
    "mamba_decode",
    "init_mamba_cache",
    "ssd_reference",
    "ssd_chunked",
]


# ------------------------------------------------------------------ params --


def init_mamba(key, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    h = s.n_heads(d)
    g, n, w = s.n_groups, s.d_state, s.d_conv
    conv_dim = di + 2 * g * n
    dt = cfg.pdtype()
    ks = jax.random.split(key, 4)
    sc = 1.0 / np.sqrt(d)
    proj_out = 2 * di + 2 * g * n + h
    # dt_bias: inverse-softplus of dt ~ U[1e-3, 1e-1] (mamba2 init)
    u = jax.random.uniform(ks[2], (h,), jnp.float32)
    dt0 = jnp.exp(u * (np.log(0.1) - np.log(1e-3)) + np.log(1e-3))
    return {
        "in_proj": (jax.random.normal(ks[0], (d, proj_out), jnp.float32) * sc).astype(dt),
        "conv_w": (jax.random.normal(ks[1], (w, conv_dim), jnp.float32) * 0.1).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "a_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "dt_bias": dt0 + jnp.log(-jnp.expm1(-dt0)),  # inverse softplus
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.ones((di,), jnp.float32),
        "out_proj": (
            jax.random.normal(ks[3], (di, d), jnp.float32) / np.sqrt(di)
        ).astype(dt),
    }


def spec_mamba(cfg: ModelConfig):
    return {
        "in_proj": ("embed", "mlp"),
        "conv_w": ("conv", "mlp"),
        "conv_b": ("mlp",),
        "a_log": ("ssm_heads",),
        "dt_bias": ("ssm_heads",),
        "d_skip": ("ssm_heads",),
        "norm_scale": ("mlp",),
        "out_proj": ("mlp", "embed"),
    }


# --------------------------------------------------------------------- ssd --


def _segsum(logd):
    """Stable segment-sum: out[..., i, j] = sum_{j<k<=i} logd[..., k] (i >= j),
    -inf above the diagonal. logd: (..., Q) -> (..., Q, Q)."""
    Q = logd.shape[-1]
    cum = jnp.cumsum(logd, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_reference(x, dt, a, B_in, C, *, h_per_g: int):
    """Sequential recurrence oracle. x: (B,S,h,p), dt: (B,S,h), a: (h,),
    B_in/C: (B,S,g,n). Returns (y, final_state)."""
    Bb, S, h, p = x.shape
    n = B_in.shape[-1]
    Br = jnp.repeat(B_in, h_per_g, axis=2)  # (B,S,h,n)
    Cr = jnp.repeat(C, h_per_g, axis=2)

    def step(state, inp):
        xt, dtt, bt, ct = inp  # (B,h,p), (B,h), (B,h,n), (B,h,n)
        decay = jnp.exp(a * dtt)[..., None, None]  # (B,h,1,1)
        state = state * decay + bt[..., :, None] * (xt * dtt[..., None])[..., None, :]
        y = jnp.einsum("bhn,bhnp->bhp", ct, state)
        return state, y

    state0 = jnp.zeros((Bb, h, n, p), jnp.float32)
    xs = (
        jnp.moveaxis(x, 1, 0).astype(jnp.float32),
        jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
        jnp.moveaxis(Br, 1, 0).astype(jnp.float32),
        jnp.moveaxis(Cr, 1, 0).astype(jnp.float32),
    )
    state, ys = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 1), state


def ssd_chunked(x, dt, a, B_in, C, *, h_per_g: int, chunk: int, unroll: bool = False):
    """Chunked SSD. Same contract as ssd_reference. ``unroll`` replaces the
    inter-chunk lax.scan with a python loop (used by the roofline calibration
    — XLA cost analysis cannot see scan trip counts)."""
    Bb, S, h, p = x.shape
    n = B_in.shape[-1]
    Q = min(chunk, S)
    while S % Q:
        Q //= 2
    nc = S // Q

    f32 = jnp.float32
    xr = x.reshape(Bb, nc, Q, h, p).astype(f32)
    dtr = dt.reshape(Bb, nc, Q, h).astype(f32)
    Br = jnp.repeat(B_in, h_per_g, axis=2).reshape(Bb, nc, Q, h, n).astype(f32)
    Cr = jnp.repeat(C, h_per_g, axis=2).reshape(Bb, nc, Q, h, n).astype(f32)

    xd = xr * dtr[..., None]  # discretised input
    logd = a * dtr  # (B,nc,Q,h) log decay per step
    cum = jnp.cumsum(logd, axis=2)  # (B,nc,Q,h)

    # intra-chunk: quadratic form with decay mask
    L = jnp.exp(_segsum(jnp.moveaxis(logd, 3, 2)))  # (B,nc,h,Q,Q)
    CB = jnp.einsum("bcihn,bcjhn->bchij", Cr, Br)
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", CB * L, xd)

    # chunk summary states: S_c = sum_j exp(cum_end - cum_j) B_j x~_j
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,nc,Q,h)
    S_c = jnp.einsum("bcjhn,bcjh,bcjhp->bchnp", Br, decay_to_end, xd)

    # inter-chunk scan: H_c = exp(sum logd_c) H_{c-1} + S_c
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,nc,h)

    def scan_fn(Hprev, inp):
        dec, Sc = inp  # (B,h), (B,h,n,p)
        Hnew = Hprev * dec[..., None, None] + Sc
        return Hnew, Hprev

    H0 = jnp.zeros((Bb, h, n, p), f32)
    xs = (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(S_c, 1, 0))
    if unroll:
        Hcur, prevs = H0, []
        for c in range(nc):
            Hcur, Hp = scan_fn(Hcur, jax.tree.map(lambda t: t[c], xs))
            prevs.append(Hp)
        Hlast, Hprevs = Hcur, jnp.stack(prevs)
    else:
        Hlast, Hprevs = jax.lax.scan(scan_fn, H0, xs)
    Hprev = jnp.moveaxis(Hprevs, 0, 1)  # (B,nc,h,n,p) state entering chunk c

    # inter-chunk contribution: C_i . H_{c-1} scaled by decay from chunk start
    state_decay = jnp.exp(cum)  # (B,nc,Q,h)
    y_inter = jnp.einsum("bcihn,bchnp->bcihp", Cr, Hprev) * state_decay[..., None]

    y = (y_intra + y_inter).reshape(Bb, S, h, p)
    return y, Hlast


# ------------------------------------------------------------------- block --


def _split_proj(z, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    g, n = s.n_groups, s.d_state
    h = s.n_heads(d)
    idx = np.cumsum([di, di, g * n, g * n])
    zg, x, B_in, C, dt = jnp.split(z, idx, axis=-1)
    return zg, x, B_in, C, dt


def _depthwise_conv(x, w, b):
    """Causal depthwise conv. x: (B, S, C); w: (w, C)."""
    W = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(W):
        out = out + pad[:, i : i + x.shape[1], :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return (out + b).astype(x.dtype)


def mamba_forward(params, xin, cfg: ModelConfig, *, return_state: bool = False):
    """xin: (B, S, d) -> (B, S, d) [+ (conv_state, ssm_state) if requested]."""
    s = cfg.ssm
    d = cfg.d_model
    di, g, n, w = s.d_inner(d), s.n_groups, s.d_state, s.d_conv
    h, p = s.n_heads(d), s.head_dim

    z = xin @ params["in_proj"]
    zg, x, B_in, C, dt = _split_proj(z, cfg)
    xbc = jnp.concatenate([x, B_in, C], axis=-1)
    xbc = _depthwise_conv(xbc, params["conv_w"], params["conv_b"])
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(xin.dtype)
    x, B_in, C = jnp.split(xbc, [di, di + g * n], axis=-1)

    Bsz, S = xin.shape[0], xin.shape[1]
    xh = x.reshape(Bsz, S, h, p)
    xh = constrain(xh, "batch", "seq", "ssm_heads", None)
    Bg = B_in.reshape(Bsz, S, g, n)
    Cg = C.reshape(Bsz, S, g, n)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])

    y, state = ssd_chunked(
        xh, dtv, a, Bg, Cg, h_per_g=h // g, chunk=s.chunk,
        unroll=not cfg.scan_layers,
    )
    y = y + params["d_skip"][:, None] * xh.astype(jnp.float32)
    y = y.reshape(Bsz, S, di)
    y = rms_norm_groups(
        y * jax.nn.silu(zg.astype(jnp.float32)), params["norm_scale"], g
    )
    out = y.astype(xin.dtype) @ params["out_proj"]
    if not return_state:
        return out
    conv_state = xbc_conv_state(xin, params, cfg)
    return out, (conv_state, state)


def xbc_conv_state(xin, params, cfg: ModelConfig):
    """Last (w-1) pre-conv features — the decode-time conv cache."""
    s = cfg.ssm
    z = xin[:, -(s.d_conv - 1) :] @ params["in_proj"]
    _, x, B_in, C, _ = _split_proj(z, cfg)
    return jnp.concatenate([x, B_in, C], axis=-1)  # (B, w-1, conv_dim)


def init_mamba_cache(batch: int, cfg: ModelConfig, dtype=jnp.float32):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    conv_dim = di + 2 * s.n_groups * s.d_state
    h, p, n = s.n_heads(d), s.head_dim, s.d_state
    return (
        jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
        jnp.zeros((batch, h, n, p), jnp.float32),
    )


def mamba_decode(params, xin, conv_state, ssm_state, cfg: ModelConfig):
    """One-token step. xin: (B, 1, d); returns (y, (conv_state, ssm_state))."""
    s = cfg.ssm
    d = cfg.d_model
    di, g, n = s.d_inner(d), s.n_groups, s.d_state
    h, p = s.n_heads(d), s.head_dim

    z = xin @ params["in_proj"]
    zg, x, B_in, C, dt = _split_proj(z, cfg)
    xbc_new = jnp.concatenate([x, B_in, C], axis=-1)  # (B,1,conv_dim)
    window = jnp.concatenate([conv_state, xbc_new], axis=1)  # (B,w,conv_dim)
    wgt = params["conv_w"].astype(jnp.float32)
    xbc = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), wgt) + params["conv_b"]
    xbc = jax.nn.silu(xbc).astype(xin.dtype)
    x, B_in, C = jnp.split(xbc, [di, di + g * n], axis=-1)

    Bsz = xin.shape[0]
    xh = x.reshape(Bsz, h, p).astype(jnp.float32)
    Bg = jnp.repeat(B_in.reshape(Bsz, g, n), h // g, axis=1).astype(jnp.float32)
    Cg = jnp.repeat(C.reshape(Bsz, g, n), h // g, axis=1).astype(jnp.float32)
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,h)
    a = -jnp.exp(params["a_log"])

    decay = jnp.exp(a * dtv)[..., None, None]
    ssm_state = ssm_state * decay + Bg[..., :, None] * (xh * dtv[..., None])[..., None, :]
    y = jnp.einsum("bhn,bhnp->bhp", Cg, ssm_state)
    y = y + params["d_skip"][:, None] * xh
    y = y.reshape(Bsz, 1, di)
    y = rms_norm_groups(y * jax.nn.silu(zg.astype(jnp.float32)), params["norm_scale"], g)
    out = y.astype(xin.dtype) @ params["out_proj"]
    return out, (window[:, 1:], ssm_state)

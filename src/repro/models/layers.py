"""Shared neural layers: norms, rotary embeddings (incl. M-RoPE), MLPs.

Every ``init_*`` has a twin ``spec_*`` returning the same pytree of logical
axis-name tuples (consumed by distributed/sharding.py); tests assert the two
trees are structurally identical. Compute follows the mixed-precision
contract: params may be bf16, all norms/softmax/rope math runs in f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig

# ------------------------------------------------------------------ norms --


def init_norm(cfg: ModelConfig, d: int):
    if cfg.norm_type == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32)}


def spec_norm(cfg: ModelConfig):
    if cfg.norm_type == "layernorm":
        return {"scale": ("embed",), "bias": ("embed",)}
    return {"scale": ("embed",)}


def apply_norm(params, x, cfg: ModelConfig, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) / jnp.sqrt(var + eps) * params["scale"] + params["bias"]
    else:
        rms = jnp.sqrt(jnp.mean(jnp.square(xf), axis=-1, keepdims=True) + eps)
        out = xf / rms * params["scale"]
    return out.astype(x.dtype)


def rms_norm_groups(x, scale, n_groups: int, eps: float = 1e-6):
    """Grouped RMSNorm used by mamba2's gated output norm."""
    xf = x.astype(jnp.float32)
    shape = xf.shape
    xg = xf.reshape(shape[:-1] + (n_groups, shape[-1] // n_groups))
    rms = jnp.sqrt(jnp.mean(jnp.square(xg), axis=-1, keepdims=True) + eps)
    out = (xg / rms).reshape(shape) * scale
    return out.astype(x.dtype)


# ------------------------------------------------------------------- rope --


def rope_frequencies(head_dim: int, fraction: float, theta: float):
    rot = int(head_dim * fraction) // 2 * 2
    inv = 1.0 / (theta ** (np.arange(0, rot, 2, dtype=np.float32) / rot))
    return jnp.asarray(inv), rot  # (rot/2,), rotated dims


def apply_rope(x, positions, cfg: ModelConfig):
    """x: (..., S, H, Dh); positions: (..., S) int32."""
    inv, rot = rope_frequencies(cfg.head_dim, cfg.rope_fraction, cfg.rope_theta)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., S, rot/2)
    sin = jnp.sin(ang)[..., :, None, :]
    cos = jnp.cos(ang)[..., :, None, :]
    xf = x.astype(jnp.float32)
    xr, xp = xf[..., :rot], xf[..., rot:]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2 :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin, xp], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, cfg: ModelConfig):
    """Multimodal RoPE (qwen2-vl): positions3 (3, ..., S) for (t, h, w).

    The rotary dim halves are split into the configured sections; each section
    rotates with its own position stream.
    """
    sections = cfg.mrope_sections
    assert sections is not None
    inv, rot = rope_frequencies(cfg.head_dim, cfg.rope_fraction, cfg.rope_theta)
    assert sum(sections) == rot // 2, (sections, rot)
    # per-frequency position id: section s uses positions3[s]
    sec_id = jnp.asarray(
        np.repeat(np.arange(3), np.asarray(sections)), dtype=jnp.int32
    )  # (rot/2,) -> which of (t, h, w) each frequency uses
    pos_sec = jnp.moveaxis(positions3, 0, -1).astype(jnp.float32)  # (..., S, 3)
    pos_f = pos_sec[..., sec_id]  # (..., S, rot/2)
    ang = pos_f * inv
    sin = jnp.sin(ang)[..., :, None, :]
    cos = jnp.cos(ang)[..., :, None, :]
    xf = x.astype(jnp.float32)
    xr, xp = xf[..., :rot], xf[..., rot:]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2 :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin, xp], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------------- mlp --


def init_mlp(key, cfg: ModelConfig, d_in: int, d_ff: int):
    dt = cfg.pdtype()
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / np.sqrt(d_in)
    s_ff = 1.0 / np.sqrt(d_ff)
    gated = cfg.mlp_type in ("swiglu", "geglu")
    p = {
        "w_up": (jax.random.normal(k1, (d_in, d_ff), jnp.float32) * s_in).astype(dt),
        "w_down": (jax.random.normal(k2, (d_ff, d_in), jnp.float32) * s_ff).astype(dt),
    }
    if gated:
        p["w_gate"] = (jax.random.normal(k3, (d_in, d_ff), jnp.float32) * s_in).astype(dt)
    return p


def spec_mlp(cfg: ModelConfig):
    gated = cfg.mlp_type in ("swiglu", "geglu")
    p = {"w_up": ("embed", "mlp"), "w_down": ("mlp", "embed")}
    if gated:
        p["w_gate"] = ("embed", "mlp")
    return p


def apply_mlp(params, x, cfg: ModelConfig):
    from repro.distributed.sharding import constrain

    h = x @ params["w_up"]
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * h
    elif cfg.mlp_type == "geglu":
        h = jax.nn.gelu(x @ params["w_gate"], approximate=True) * h
    elif cfg.mlp_type == "gelu":
        h = jax.nn.gelu(h, approximate=True)
    elif cfg.mlp_type == "relu2":  # nemotron squared-ReLU
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(cfg.mlp_type)
    h = constrain(h, "batch", "seq", "mlp")
    return h @ params["w_down"]


# -------------------------------------------------------------- embedding --


def init_embedding(key, cfg: ModelConfig):
    dt = cfg.pdtype()
    emb = jax.random.normal(key, (cfg.vocab_size, cfg.d_model), jnp.float32)
    p = {"embedding": (emb * 0.02).astype(dt)}
    if not cfg.tie_embeddings:
        k2 = jax.random.fold_in(key, 1)
        p["unembed"] = (
            jax.random.normal(k2, (cfg.d_model, cfg.vocab_size), jnp.float32)
            / np.sqrt(cfg.d_model)
        ).astype(dt)
    return p


def spec_embedding(cfg: ModelConfig):
    p = {"embedding": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        p["unembed"] = ("embed", "vocab")
    return p

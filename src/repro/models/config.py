"""Model configuration schema covering all 10 assigned architectures.

One dataclass, explicit feature flags — a config IS the architecture
(gemma2's softcaps + alternating local/global, qwen3's qk-norm, grok's MoE,
mamba2's SSD, zamba2's shared block, seamless' enc-dec, qwen2-vl's M-RoPE).
``reduced()`` produces the CPU-smoke-test variant of any config.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

__all__ = ["MoEConfig", "SSMConfig", "ModelConfig"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    # GShard-style routing groups: capacity is enforced per group of tokens,
    # keeping the (group, E, capacity) dispatch tensors linear in batch size
    # (a global one-hot dispatch would be quadratic in tokens).
    group_size: int = 1024


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 8
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # families: "dense" | "moe" | "ssm" | "hybrid" | "encdec"
    family: str = "dense"

    # attention features
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    sliding_window: int = 0  # 0 = full attention
    local_global_pattern: bool = False  # gemma2: alternate local/global layers
    qk_norm: bool = False
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0  # nemotron: partial rope
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl

    # mlp
    mlp_type: str = "swiglu"  # swiglu | geglu | gelu | relu2
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm
    post_norm: bool = False  # gemma2: extra norms after attn/mlp

    # mixture of experts
    moe: Optional[MoEConfig] = None

    # state-space (mamba2 / zamba2)
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): one shared attention block applied every `shared_every`
    # ssm blocks, with per-invocation LoRA of this rank on qkv
    shared_every: int = 0
    shared_lora_rank: int = 0

    # encoder-decoder (seamless)
    n_encoder_layers: int = 0
    # modality frontends are stubs: inputs arrive as precomputed embeddings
    frontend: Optional[str] = None  # None | "audio" | "vision"
    n_vision_patches: int = 0

    tie_embeddings: bool = True
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # scan-over-layers keeps the HLO compact (one lowered layer) — required
    # for tractable 512-device dry-run compiles
    scan_layers: bool = True
    remat: str = "full"  # full | dots | none
    # attention chunking (memory-efficient online-softmax path)
    attn_chunk_q: int = 512
    attn_chunk_kv: int = 1024
    # loss computed in sequence chunks so (B, S, V) logits never materialise
    loss_chunk: int = 512
    optimizer: str = "adamw"  # adamw | adafactor
    # int8 KV cache (decode): halves cache HBM traffic — the memory-bound
    # decode cells' dominant term. Symmetric per-(position, kv-head) scales.
    kv_quant: bool = False

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def is_attention_layer(self, i: int) -> bool:
        """hybrid (zamba2): which block indices are the shared attn block."""
        if self.family != "hybrid" or self.shared_every <= 0:
            return False
        return (i + 1) % (self.shared_every + 1) == 0

    def layer_is_local(self, i: int) -> bool:
        """gemma2 alternation: even layers local (sliding window), odd global."""
        return self.local_global_pattern and i % 2 == 0

    def reduced(self, **over) -> "ModelConfig":
        """Small same-family variant for CPU smoke tests."""
        changes = dict(
            n_layers=min(self.n_layers, 4 if self.family != "hybrid" else 7),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads < self.n_heads else 4,
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            attn_chunk_q=64,
            attn_chunk_kv=64,
            loss_chunk=64,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            n_vision_patches=min(self.n_vision_patches, 16),
            param_dtype="float32",
            compute_dtype="float32",
        )
        if self.moe is not None:
            changes["moe"] = MoEConfig(
                n_experts=min(self.moe.n_experts, 8),
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=128,
                capacity_factor=self.moe.capacity_factor,
            )
        if self.ssm is not None:
            changes["ssm"] = SSMConfig(
                d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=2, chunk=32
            )
        if self.shared_every:
            changes["shared_every"] = 2
            changes["shared_lora_rank"] = 8
        if self.mrope_sections is not None:
            half = changes["head_dim"] // 2  # sections must sum to rot/2
            q = half // 4
            changes["mrope_sections"] = (half - 2 * q, q, q)
        changes.update(over)
        return dataclasses.replace(self, **changes)

"""Paper §3.1.1: nodes-per-shell distribution of the three datasets."""
from __future__ import annotations

import time

import numpy as np

from repro.core import kcore
from repro.graph import datasets

from .common import csv_line


def run(quick: bool = False):
    lines = []
    print("== core_distribution ==")
    names = ["cora-like", "facebook-like"] + ([] if quick else ["github-like"])
    for name in names:
        g = datasets.load(name)
        t0 = time.perf_counter()
        core = kcore.core_numbers_host(g)
        dt = time.perf_counter() - t0
        ks, cnt = np.unique(core, return_counts=True)
        kdeg = int(core.max())
        frac_low = cnt[ks <= max(1, kdeg // 4)].sum() / g.n_nodes
        print(f"{name}: n={g.n_nodes} m={g.n_edges} degeneracy={kdeg} "
              f"shells={len(ks)} bottom-quartile-cores hold {frac_low:.0%} of nodes "
              f"(decomposition {dt*1e3:.0f} ms)")
        hist = ", ".join(f"{int(k)}:{int(c)}" for k, c in zip(ks[:10], cnt[:10]))
        print(f"  first shells: {hist} ...")
        lines.append(csv_line(
            f"core_distribution_{name}", dt,
            f"degeneracy={kdeg};shells={len(ks)};bottom_frac={frac_low:.2f}"))
    return lines


if __name__ == "__main__":
    run()

"""Paper Figures 5/6: PCA of propagated embeddings (connected vs disconnected
k0-core). No display in this container: saves coordinates + prints the
variance pathology the paper describes (propagation shrinks the cloud and,
for disconnected cores, puts most variance on the between-cluster axis).
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import kcore
from repro.core.pipeline import EmbedConfig, embed_graph
from repro.graph import datasets, generators, splits
from repro.skipgram.trainer import SGNSConfig

from .common import csv_line


def _pca2(x):
    x = x - x.mean(0)
    u, s, vt = np.linalg.svd(x, full_matrices=False)
    var = (s**2) / max(len(x) - 1, 1)
    return x @ vt[:2].T, var / var.sum()


def run(quick: bool = False, outdir: str = "results"):
    os.makedirs(outdir, exist_ok=True)
    lines = []
    print("== embedding_viz ==")

    # connected case: facebook-like deep core
    t0 = time.perf_counter()
    g = datasets.load("tiny" if quick else "facebook-like")
    sp = splits.make_link_split(g, 0.1, seed=0)
    core = kcore.core_numbers_host(sp.train_graph)
    k0 = max(2, int(kcore.degeneracy(core) * 0.9))
    cfg = EmbedConfig(
        method="deepwalk", k0=k0, n_walks=5, walk_length=20,
        sgns=SGNSConfig(dim=64, batch=4096, epochs=0.5, impl="ref"),
    )
    res = embed_graph(sp.train_graph, cfg)
    coords, evr = _pca2(res.embeddings)
    np.savez(os.path.join(outdir, "viz_connected.npz"),
             coords=coords, core=core, k0=k0)
    in_core = core >= k0
    spread_core = np.linalg.norm(coords[in_core].std(0))
    spread_prop = np.linalg.norm(coords[~in_core].std(0))
    print(f"connected {k0}-core: PCA evr={evr[:2].round(3)}, core-node spread "
          f"{spread_core:.3f} vs propagated {spread_prop:.3f} "
          f"(propagation shrinks the cloud: {spread_prop < spread_core})")
    lines.append(csv_line("viz_connected", time.perf_counter() - t0,
                          f"evr1={evr[0]:.3f};shrunk={spread_prop < spread_core}"))

    # disconnected case: two dense SBM blocks, embed the (disconnected) core
    t0 = time.perf_counter()
    g2 = generators.stochastic_block_model([60, 60], 0.5, 0.02, seed=1)
    sp2 = splits.make_link_split(g2, 0.1, seed=0)
    core2 = kcore.core_numbers_host(sp2.train_graph)
    k02 = max(2, int(np.percentile(core2, 80)))
    cfg2 = EmbedConfig(
        method="deepwalk", k0=k02, n_walks=8, walk_length=16,
        sgns=SGNSConfig(dim=32, batch=2048, epochs=1.0, impl="ref"),
    )
    res2 = embed_graph(sp2.train_graph, cfg2)
    coords2, evr2 = _pca2(res2.embeddings)
    np.savez(os.path.join(outdir, "viz_disconnected.npz"),
             coords=coords2, core=core2, k0=k02)
    print(f"disconnected {k02}-core: first-PC variance share {evr2[0]:.2f} "
          f"(paper Fig. 6: the between-cluster direction dominates)")
    lines.append(csv_line("viz_disconnected", time.perf_counter() - t0,
                          f"evr1={evr2[0]:.3f}"))
    return lines


if __name__ == "__main__":
    run()

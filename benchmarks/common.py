"""Shared harness for the paper-table benchmarks.

Each table bench runs the paper's protocol (§3.1.2): link split -> embed with
{DeepWalk, CoreWalk, k-core(Dw), k-core(Cw)} -> logistic-regression F1, with
the paper's wall-clock breakdown, repeated over seeds with mean ± std.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import kcore
from repro.core.pipeline import EmbedConfig, embed_graph
from repro.eval.linkpred import evaluate_link_prediction
from repro.graph import datasets, splits
from repro.skipgram.trainer import SGNSConfig

ROW_FMT = ("{model:16s} {f1:6.2f} (±{f1_std:4.2f})  drop {drop:+5.1f}  "
           "decomp {decomposition:6.2f}s walks {walks:6.2f}s embed "
           "{embedding:7.2f}s prop {propagation:5.2f}s total {total:7.2f}s "
           "speedup x{speedup:4.1f}")


@dataclasses.dataclass
class BenchSettings:
    dataset: str
    frac_removed: float = 0.1
    n_walks: int = 15
    walk_length: int = 30
    dim: int = 150
    window: int = 4
    n_neg: int = 5
    batch: int = 8192
    epochs: float = 1.0
    seeds: int = 2
    k0_fracs: tuple = (0.15, 0.4, 0.65, 0.9)
    prop_iters: int = 30


def k0_schedule(core: np.ndarray, fracs) -> List[int]:
    kdeg = kcore.degeneracy(core)
    ks = sorted({max(2, int(round(kdeg * f))) for f in fracs})
    return [k for k in ks if k <= kdeg]


def run_model(sp, method: str, k0: Optional[int], s: BenchSettings, seed: int):
    cfg = EmbedConfig(
        method=method,
        k0=k0,
        n_walks=s.n_walks,
        walk_length=s.walk_length,
        sgns=SGNSConfig(
            dim=s.dim, window=s.window, n_neg=s.n_neg, batch=s.batch,
            epochs=s.epochs, seed=seed, impl="ref",
        ),
        prop_iters=s.prop_iters,
        seed=seed,
    )
    t0 = time.perf_counter()
    res = embed_graph(sp.train_graph, cfg)
    total = time.perf_counter() - t0
    pairs, labels = sp.eval_arrays()
    lp = evaluate_link_prediction(res.embeddings, pairs, labels, seed=seed)
    return {
        "f1": lp.f1 * 100,
        "times": res.times,
        "total": total,
        "n_walks_run": res.n_walks_run,
        "n_sgns_steps": res.n_sgns_steps,
        "degeneracy": res.degeneracy,
    }


def run_table(s: BenchSettings, models: List[tuple]) -> List[Dict]:
    """models: list of (label, method, k0_frac_or_None)."""
    g = datasets.load(s.dataset)
    core = kcore.core_numbers_host(g)
    rows = []
    baseline_time = None
    baseline_f1 = None
    for label, method, k0f in models:
        k0 = None
        if k0f is not None:
            kdeg = kcore.degeneracy(core)
            k0 = max(2, int(round(kdeg * k0f)))
        f1s, totals, times_list, steps = [], [], [], []
        for seed in range(s.seeds):
            sp = splits.make_link_split(g, s.frac_removed, seed=seed)
            out = run_model(sp, method, k0, s, seed)
            f1s.append(out["f1"])
            totals.append(out["total"])
            times_list.append(out["times"])
            steps.append(out["n_sgns_steps"])
        mean_t = {k: float(np.mean([t[k] for t in times_list]))
                  for k in times_list[0]}
        row = {
            "model": label if k0 is None else f"{k0}-core ({label})",
            "f1": float(np.mean(f1s)),
            "f1_std": float(np.std(f1s)),
            "total": float(np.mean(totals)),
            "sgns_steps": int(np.mean(steps)),
            **{k: v for k, v in mean_t.items() if k != "total"},
        }
        if baseline_time is None:
            baseline_time, baseline_f1 = row["total"], row["f1"]
        row["speedup"] = baseline_time / row["total"]
        row["drop"] = row["f1"] - baseline_f1
        rows.append(row)
        print(ROW_FMT.format(**row))
    return rows


def csv_line(name: str, seconds: float, derived: str) -> str:
    """run.py contract: ``name,us_per_call,derived``."""
    return f"{name},{seconds * 1e6:.0f},{derived}"

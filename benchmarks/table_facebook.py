"""Paper Tables 2/3/7/8 + Figures 2/3: facebook-like graph.

DeepWalk baseline vs CoreWalk (§2.1) vs k-core propagation with both
embedders (§2.2), sweeping k0 — the paper's central experiment.
"""
from __future__ import annotations

from .common import BenchSettings, csv_line, run_table


def run(quick: bool = False, frac: float = 0.1):
    s = BenchSettings(
        dataset="facebook-like",
        frac_removed=frac,
        seeds=1 if quick else 3,
        epochs=0.5 if quick else 1.0,
    )
    ks = (0.4, 0.9) if quick else (0.15, 0.4, 0.65, 0.9)
    models = [("DeepWalk", "deepwalk", None)]
    models += [("Dw", "deepwalk", f) for f in ks]
    models += [("CoreWalk", "corewalk", None)]
    models += [("Cw", "corewalk", f) for f in ks]
    print(f"== table_facebook (frac={frac}) ==")
    rows = run_table(s, models)
    lines = [
        csv_line(f"table_facebook_f{int(frac*100)}_{r['model'].replace(' ', '')}",
                 r["total"], f"F1={r['f1']:.2f};speedup=x{r['speedup']:.1f}")
        for r in rows
    ]
    return rows, lines


if __name__ == "__main__":
    run()

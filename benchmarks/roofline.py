import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^ must precede jax import: the calibration lowers on the production mesh.

"""§Roofline: three-term analysis per (arch x shape) from the compiled dry-run.

    compute term    = HLO_FLOPs / peak_FLOP/s          (per chip)
    memory term     = HLO_bytes / HBM_bw               (per chip)
    collective term = collective_bytes / link_bw       (per chip)

cost_analysis() and the parsed HLO are the per-chip SPMD module, so no /chips
is needed. TWO corrections applied and documented:

1. **Depth calibration** — XLA cost analysis counts a scanned layer body ONCE
   (while-loop trip counts are invisible to it). Each cell is re-lowered at
   two reduced depths with scan_layers=False; the per-layer delta
   extrapolates to full depth:   total = m(d2) + (L - d2) * (m(d4)-m(d2))/2.
2. **bf16 legalisation** — XLA *CPU* upcasts bf16 dots/buffers to f32, so
   HLO byte counts are inflated vs the TPU target (native bf16). Bytes are
   reported as-parsed (upper bound) with the caveat in EXPERIMENTS.md.

MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = matmul-participating
params (active experts only for MoE) + analytic attention/SSD term; the ratio
MODEL_FLOPS / HLO_FLOPs exposes remat/capacity/causal-padding waste.

Run:  PYTHONPATH=src python -m benchmarks.roofline --dryrun results/dryrun.json
"""
import argparse
import dataclasses
import json
import time

import jax
import numpy as np

# v5e hardware constants (assignment brief)
PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s
LINK_BW = 50e9  # bytes/s per ICI link

KINDS = {"train_4k": "train", "prefill_32k": "prefill",
         "decode_32k": "decode", "long_500k": "decode"}


# ------------------------------------------------------- analytic flops ----


def _param_count(cfg):
    from repro.models.transformer import init_model

    avals = jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg))
    total = sum(int(np.prod(a.shape)) for a in jax.tree.leaves(avals))
    embed = int(np.prod(avals["embed"]["embedding"].shape))
    return total, embed, avals


def analytic_model_flops(cfg, shape):
    """MODEL_FLOPS for the whole step, per chip (/512 single-pod=256... the
    dry-run modules are per-chip; divide global by mesh size at the caller)."""
    from repro.configs.shapes import SHAPES

    sh = SHAPES[shape]
    B, S = sh.global_batch, sh.seq_len
    total, embed, _ = _param_count(cfg)
    n_mat = total - embed  # gather-only table
    if cfg.tie_embeddings:
        n_mat += embed  # tied table re-used as the unembed matmul
    if cfg.moe is not None:
        # experts: only top_k of n_experts are "useful" per token
        expert = cfg.n_layers * cfg.moe.n_experts * 3 * cfg.d_model * cfg.moe.d_ff_expert
        n_mat = n_mat - expert + expert * cfg.moe.top_k / cfg.moe.n_experts
    tokens = B * S if sh.kind != "decode" else B
    mult = 6 if sh.kind == "train" else 2
    flops = mult * n_mat * tokens

    # attention context term (causal-halved); decode reads the whole cache
    if cfg.family in ("dense", "moe", "encdec"):
        Hd = cfg.n_heads * cfg.head_dim
        if sh.kind == "decode":
            flops += cfg.n_layers * 4 * B * S * Hd
        else:
            ctx = S if not cfg.sliding_window else min(S, cfg.sliding_window)
            att = cfg.n_layers * 4 * B * S * ctx * Hd * 0.5
            flops += att * (3 if sh.kind == "train" else 1)
    elif cfg.ssm is not None:
        s = cfg.ssm
        di = s.d_inner(cfg.d_model)
        n_ssm_layers = cfg.n_layers
        # SSD state math ~ 6 * tokens * d_inner * d_state per layer (fwd)
        ssd = n_ssm_layers * 6 * tokens * di * s.d_state
        flops += ssd * (3 if sh.kind == "train" else 1)
    return flops


# ------------------------------------------------------ depth calibration ---


def _variant_depths(cfg):
    if cfg.family == "hybrid":
        per = cfg.shared_every
        return (per, 2 * per)  # 1 group vs 2 groups
    return (1, 2)


def calibrate_cell(arch, shape_name):
    """Lower reduced-depth unrolled variants; return per-depth metrics."""
    from repro.configs import get_config, sharding_overrides
    from repro.configs.shapes import SHAPES
    from repro.distributed.sharding import sharding_scope
    from repro.launch import dryrun as dr
    from repro.launch.mesh import make_production_mesh, use_mesh

    cfg0 = get_config(arch)
    depths = _variant_depths(cfg0)
    mesh = make_production_mesh(multi_pod=False)
    out = {}
    S = 1 << 22  # "single chunk" sentinel: min(chunk, S) applies downstream
    for d in depths:
        # encoder depth tracks decoder depth so the per-layer delta covers an
        # (enc, dec) layer PAIR — valid for seamless where both stacks are 24.
        # Inner loops are de-scanned too (XLA cost analysis cannot see scan
        # trip counts): attention/loss run single-chunk (compile-only, so the
        # dense score/logit buffers are never allocated) and SSD's chunk scan
        # unrolls via scan_layers=False.
        cfg = dataclasses.replace(
            cfg0, n_layers=d, scan_layers=False,
            n_encoder_layers=min(cfg0.n_encoder_layers, d),
            attn_chunk_q=S, attn_chunk_kv=S, loss_chunk=S,
        )
        ov = dr.cell_overrides(arch, shape_name)
        with use_mesh(mesh), sharding_scope(mesh, **ov):
            # patch the registry-free path: build_cell reads get_config, so
            # construct the cell manually with the variant cfg
            fn, avals, in_sh, donate = _build_variant(cfg, shape_name)
            compiled = (
                jax.jit(fn, in_shardings=in_sh, donate_argnums=donate)
                .lower(*avals)
                .compile()
            )
            ca = compiled.cost_analysis() or {}
            if isinstance(ca, (list, tuple)):  # older jax: one dict per program
                ca = ca[0] if ca else {}
            coll, _ = dr.parse_collective_bytes(compiled.as_text())
        out[d] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": float(sum(coll.values())),
        }
    d2, d4 = depths
    per_layer = {k: (out[d4][k] - out[d2][k]) / (d4 - d2) for k in out[d2]}
    base = {k: out[d2][k] - d2 * per_layer[k] for k in out[d2]}
    return per_layer, base, depths


def _build_variant(cfg, shape_name):
    """dryrun.build_cell but with an explicit (depth-reduced) cfg."""
    from repro.configs.shapes import (
        SHAPES, batch_logical_names, input_specs, shape_supported,
    )
    from repro.distributed.sharding import tree_shardings
    from repro.models.steps import make_decode_step, make_prefill_step, make_train_step
    from repro.models.transformer import cache_specs, init_model, model_specs
    from repro.train import optim

    shape = SHAPES[shape_name]
    params_avals = jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg))
    p_specs = model_specs(cfg)
    params_sh = tree_shardings(params_avals, p_specs)
    if shape.kind == "train":
        opt = optim.make_optimizer(cfg.optimizer, 1e-4)
        opt_avals = jax.eval_shape(opt.init, params_avals)
        opt_sh = tree_shardings(
            opt_avals, optim.optimizer_state_specs(cfg.optimizer, params_avals, p_specs)
        )
        (batch_avals,) = input_specs(cfg, shape)
        batch_sh = tree_shardings(batch_avals, batch_logical_names(cfg, train=True))
        # accum=1 for calibration: the microbatch loop is a scan (invisible
        # trip count); per-step totals are accumulation-invariant anyway.
        step = make_train_step(cfg, opt, accum_steps=1)
        return step, (params_avals, opt_avals, batch_avals), (params_sh, opt_sh, batch_sh), (0, 1)
    if shape.kind == "prefill":
        (batch_avals,) = input_specs(cfg, shape)
        batch_sh = tree_shardings(batch_avals, batch_logical_names(cfg, train=False))
        return make_prefill_step(cfg), (params_avals, batch_avals), (params_sh, batch_sh), ()
    cache_avals, tok_aval = input_specs(cfg, shape)
    cache_sh = tree_shardings(cache_avals, cache_specs(cfg))
    tok_sh = tree_shardings(tok_aval, ("batch", None))
    return (
        make_decode_step(cfg),
        (params_avals, cache_avals, tok_aval),
        (params_sh, cache_sh, tok_sh),
        (1,),
    )


def full_depth_units(cfg):
    """How many per-layer units the full model has. Hybrid depths are
    expressed in n_layers (mamba blocks) too — the per-unit delta from the
    (per, 2*per) variants is already per *block* (incl. its 1/shared_every
    share of the shared attention block)."""
    return cfg.n_layers


# ------------------------------------------------------------------ main ----


def suggest(dom, kind, cfg):
    if dom == "collective":
        return ("shrink cross-shard traffic: reshard to cut the SP gathers "
                "(bigger per-device batch) or overlap collectives with the "
                "next microbatch's compute")
    if dom == "memory":
        if kind == "decode":
            return ("decode is KV/state-bandwidth bound: quantise the cache "
                    "(int8 KV), shard it wider, or batch more requests per "
                    "cache pass")
        return "raise arithmetic intensity: larger fused blocks, bf16 end-to-end"
    return ("compute-bound (good): push MXU utilisation via Pallas-fused "
            "attention and capacity-factor reduction" if cfg.moe else
            "compute-bound (good): push MXU utilisation via Pallas-fused "
            "attention / larger matmul tiles")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun.json")
    ap.add_argument("--out", default="results/roofline.json")
    ap.add_argument("--md", default="results/roofline.md")
    ap.add_argument("--calibrate", action="store_true", default=True)
    ap.add_argument("--no-calibrate", dest="calibrate", action="store_false")
    ap.add_argument("--cells", default="", help="arch:shape,... subset filter")
    args = ap.parse_args()

    from repro.configs import REGISTRY, get_config

    with open(args.dryrun) as f:
        records = json.load(f)
    cells = [r for r in records if r["mesh"] == "single" and r["status"] == "ok"
             and r["arch"] in REGISTRY]
    if args.cells:
        keep = {tuple(c.split(":")) for c in args.cells.split(",")}
        cells = [r for r in cells if (r["arch"], r["shape"]) in keep]

    rows = []
    for r in cells:
        arch, shape = r["arch"], r["shape"]
        cfg = get_config(arch)
        flops = r["flops"]
        byts = r["bytes_accessed"]
        coll = float(sum(r["collective_bytes"].values()))
        corrected = False
        if args.calibrate:
            try:
                t0 = time.time()
                per_layer, base, depths = calibrate_cell(arch, shape)
                L = full_depth_units(cfg)
                flops = base["flops"] + per_layer["flops"] * L
                byts = base["bytes"] + per_layer["bytes"] * L
                coll = base["coll"] + per_layer["coll"] * L
                corrected = True
                print(f"[roofline] calibrated {arch}x{shape} at depths {depths} "
                      f"({time.time()-t0:.0f}s)")
            except Exception as e:  # fall back to raw (underestimates depth)
                print(f"[roofline] calibration FAILED {arch}x{shape}: {e}")
        t_c = flops / PEAK_FLOPS
        t_m = byts / HBM_BW
        t_n = coll / LINK_BW
        dom = max((("compute", t_c), ("memory", t_m), ("collective", t_n)),
                  key=lambda kv: kv[1])[0]
        model_flops = analytic_model_flops(cfg, shape) / 256  # per chip
        rows.append({
            "arch": arch, "shape": shape,
            "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
            "dominant": dom,
            "model_flops_per_chip": model_flops,
            "hlo_flops_per_chip": flops,
            "useful_ratio": model_flops / flops if flops else 0.0,
            "roofline_fraction": t_c / max(t_c, t_m, t_n),
            "calibrated": corrected,
            "suggestion": suggest(dom, KINDS.get(shape, "train"), cfg),
        })
        print(f"[roofline] {arch:22s} {shape:12s} compute {t_c*1e3:9.3f}ms "
              f"memory {t_m*1e3:9.3f}ms collective {t_n*1e3:9.3f}ms "
              f"-> {dom:10s} useful={rows[-1]['useful_ratio']:.2f}")

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)

    skips = [r for r in records if r["mesh"] == "single" and r["status"] == "skip"]
    with open(args.md, "w") as f:
        f.write("| arch | shape | compute (ms) | memory (ms) | collective (ms) "
                "| dominant | MODEL/HLO flops | roofline frac |\n|---|---|---|---|---|---|---|---|\n")
        for r in rows:
            f.write(
                f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.3f} | "
                f"{r['memory_s']*1e3:.3f} | {r['collective_s']*1e3:.3f} | "
                f"{r['dominant']} | {r['useful_ratio']:.2f} | "
                f"{r['roofline_fraction']:.2f} |\n")
        for r in skips:
            f.write(f"| {r['arch']} | {r['shape']} | — | — | — | skipped: "
                    f"{r['reason'][:60]} | — | — |\n")
    print(f"[roofline] wrote {args.out} and {args.md} "
          f"({len(rows)} cells, {len(skips)} skips)")


if __name__ == "__main__":
    main()

"""Paper Tables 1/5/6: link prediction on the cora-like graph.

Cora is shallow (degeneracy ~3-4 at this density), so the k-core rows use the
small absolute cores the paper used (2-core, 3-core).
"""
from __future__ import annotations

from .common import BenchSettings, csv_line, run_table


def run(quick: bool = False, frac: float = 0.1):
    s = BenchSettings(
        dataset="cora-like",
        frac_removed=frac,
        seeds=1 if quick else 3,
        epochs=0.5 if quick else 1.0,
    )
    models = [
        ("DeepWalk", "deepwalk", None),
        ("Dw", "deepwalk", 0.55),   # ~2-core
        ("Dw", "deepwalk", 0.95),   # ~3-core (the degeneracy core)
    ]
    print(f"== table_cora (frac={frac}) ==")
    rows = run_table(s, models)
    base, last = rows[0], rows[-1]
    lines = [
        csv_line(f"table_cora_f{int(frac*100)}_{r['model'].replace(' ', '')}",
                 r["total"], f"F1={r['f1']:.2f};speedup=x{r['speedup']:.1f}")
        for r in rows
    ]
    return rows, lines


if __name__ == "__main__":
    run()

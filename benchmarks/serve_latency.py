"""Online-serving benchmark: ingest throughput + query latency.

Streams a held-out edge set into the online service (incremental core
maintenance on), then replays synthetic query traffic through the
microbatching front end and reports steady-state latency percentiles.

Emits ``name,us_per_call,derived`` CSV lines (harness contract) and writes
``results/serve_latency.json`` with ingest edges/s, query p50/p99, QPS, and
the cold-start fraction.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.graph import generators
from repro.launch.serve_embed import build_service
from repro.serve import ServiceStats

from .common import csv_line


def run(quick: bool = False, seed: int = 0):
    n = 1000 if quick else 4000
    requests = 256 if quick else 1024
    batch = 64
    g = generators.barabasi_albert_varying(n, 6.0, seed=seed)
    svc, stream_edges, _, k0 = build_service(
        g, seed=seed, batch=batch, compact_every=256 if quick else 1024
    )

    t0 = time.perf_counter()
    n_in = svc.ingest_edges(stream_edges)
    t_ingest = time.perf_counter() - t0
    mismatches = svc.cores.resync()
    edges_per_s = n_in / max(t_ingest, 1e-9)

    rng = np.random.default_rng(seed + 1)
    n_now = svc.graph.n_nodes
    for _ in range(6):  # untimed warmup (jit compiles incl. write-back shapes)
        svc.embed(rng.integers(0, n_now, size=batch))
    svc.stats = ServiceStats()

    t0 = time.perf_counter()
    for _ in range(requests // batch):
        svc.embed(rng.integers(0, n_now, size=batch))
    t_query = time.perf_counter() - t0
    p50, p99 = svc.latency_percentiles()
    st = svc.stats
    qps = st.queries / max(t_query, 1e-9)

    os.makedirs("results", exist_ok=True)
    payload = {
        "n_nodes": int(n_now),
        "n_edges": int(svc.graph.n_edges),
        "k0": int(k0),
        "ingest_edges": int(n_in),
        "ingest_edges_per_s": float(edges_per_s),
        "core_mismatches": int(mismatches),
        "compactions": int(svc.graph.compactions),
        "queries": int(st.queries),
        "batch": batch,
        "query_p50_s": p50,
        "query_p99_s": p99,
        "qps": float(qps),
        "cold_start_fraction": float(st.cold_fraction),
        "unresolved": int(st.unresolved),
    }
    with open("results/serve_latency.json", "w") as f:
        json.dump(payload, f, indent=2)

    ingest_us = t_ingest / max(n_in, 1) * 1e6
    return [
        csv_line("serve_ingest_edge", ingest_us / 1e6,
                 f"edges_per_s={edges_per_s:.0f};mismatches={mismatches}"),
        csv_line("serve_query_p50", p50,
                 f"qps={qps:.0f};batch={batch}"),
        csv_line("serve_query_p99", p99,
                 f"cold_frac={st.cold_fraction:.3f};unresolved={st.unresolved}"),
    ]


if __name__ == "__main__":
    for line in run(quick=True):
        print(line)

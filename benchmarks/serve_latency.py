"""Online-serving benchmark: ingest throughput + query latency.

Sweeps ingest throughput over block sizes — block size 1 is the per-edge
baseline (one core repair per edge), larger blocks stage the whole block and
run one union-subcore repair — then streams a mixed insert/delete workload to
exercise deletion-aware maintenance, and finally replays synthetic query
traffic through the microbatching front end for steady-state latency
percentiles.

Emits ``name,us_per_call,derived`` CSV lines (harness contract) and writes
``results/serve_latency.json`` with the block-size sweep (edges/s each, plus
the speedup of the largest block over the per-edge baseline), mixed-churn
oracle mismatches, query p50/p99, QPS, and the cold-start fraction. Every
ingest run also records a per-phase repair breakdown (region /
candidate-build / descend / fallback seconds, each tagged host vs device
backend) so the trajectory shows *where* repair time goes, not just edges/s.

``--shards N`` additionally runs the row-sharded serve stack (store table +
ELL mirror split over N devices via ``ShardPlan``) through the same ingest
and query replay, and records a ``sharding`` section: per-shard resident
balance, gather-row ownership per shard, cross-shard row copies, and the
sharded run's oracle mismatches (0 expected — sharding is placement-only).
On CPU run it under ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.graph import generators
from repro.launch.serve_embed import build_service
from repro.serve import ServiceStats


from .common import csv_line

BASELINE_CAP = 256  # per-edge baseline is slow by design; time a slice of it


WARMUP_EDGES = 32  # untimed prefix: jit-compiles the repair sweep shapes


def _ingest_run(g, block_size: int, *, seed: int, churn: float = 0.0,
                compact_every: int = 1024, max_edges: int = 0,
                shards: int = 1):
    """Fresh service; stream held-out edges in blocks.

    Returns ``(service, metrics dict)`` — the fully ingested service so the
    sharded leg can replay queries without re-streaming. The first
    ``WARMUP_EDGES`` of the stream are ingested untimed so the per-edge
    baseline does not amortise first-use jit compilation over its (short)
    timed run while the block runs start warm.
    """
    svc, stream_edges, _, _ = build_service(
        g, seed=seed, compact_every=compact_every, shards=shards
    )
    warm, stream_edges = stream_edges[:WARMUP_EDGES], stream_edges[WARMUP_EDGES:]
    if max_edges:
        stream_edges = stream_edges[:max_edges]
    svc.stream_with_churn(warm, block_size=block_size, churn=churn,
                          rng=np.random.default_rng(seed + 6))
    svc.cores.reset_phases()  # report where *timed* repair seconds go
    repeels0, descends0 = svc.cores.repeels, svc.cores.descends
    t0 = time.perf_counter()
    n_in, n_out = svc.stream_with_churn(
        stream_edges, block_size=block_size, churn=churn,
        rng=np.random.default_rng(seed + 7),
    )
    dt = time.perf_counter() - t0
    mismatches = svc.cores.resync()
    return svc, {
        "block_size": block_size,
        "edges_in": int(n_in),
        "edges_out": int(n_out),
        "edges_per_s": float((n_in + n_out) / max(dt, 1e-9)),
        "seconds": dt,
        "mismatches": int(mismatches),
        "compactions": int(svc.graph.compactions),
        # counters as timed-run deltas, matching the post-warmup phase timers
        "repeels": int(svc.cores.repeels - repeels0),
        "descends": int(svc.cores.descends - descends0),
        # region / candidate-build / descend / fallback split, each tagged
        # with the backend it ran on (host numpy vs jitted device path)
        "phases": svc.cores.phase_report(),
    }


def _sharded_run(g, *, seed: int, shards: int, requests: int, batch: int,
                 compact_every: int):
    """Ingest + query replay on the row-sharded stack; returns the JSON
    ``sharding`` section (balance, traffic, oracle mismatches)."""
    # churn-free like the sweep's block-256 row, so sharded vs unsharded
    # ingest edges/s measure the same stream (deletions are parity-tested
    # in tests/multidevice, not timed here); the fully ingested service is
    # reused for the query replay rather than rebuilt and re-streamed
    svc, ingest = _ingest_run(
        g, 256, seed=seed, compact_every=compact_every, shards=shards
    )
    rng = np.random.default_rng(seed + 1)
    n_now = svc.graph.n_nodes
    for _ in range(4):  # untimed warmup (sharded jit programs)
        svc.embed(rng.integers(0, n_now, size=batch))
    svc.stats = ServiceStats()
    # traffic counters restart with the timed run, like the phase timers,
    # so balance/copies describe the same window as qps/p50
    svc.store.reset_shard_traffic()
    t0 = time.perf_counter()
    for _ in range(max(requests // (2 * batch), 1)):
        svc.embed(rng.integers(0, n_now, size=batch))
    t_query = time.perf_counter() - t0
    p50, p99 = svc.latency_percentiles()
    report = svc.store.shard_report()
    report.update(
        ingest_edges_per_s=ingest["edges_per_s"],
        mismatches=int(ingest["mismatches"]),
        query_p50_s=p50,
        query_p99_s=p99,
        qps=float(svc.stats.queries / max(t_query, 1e-9)),
    )
    return report


def run(quick: bool = False, seed: int = 0, shards: int = 1):
    n = 1000 if quick else 4000
    requests = 256 if quick else 1024
    batch = 64
    g = generators.barabasi_albert_varying(n, 6.0, seed=seed)

    # --- ingest-throughput sweep over block sizes (1 = per-edge baseline)
    sweep_blocks = [1, 64, 256] if quick else [1, 64, 256, 1024]
    sweep = []
    for bs in sweep_blocks:
        _, metrics = _ingest_run(
            g, bs, seed=seed,
            compact_every=256 if quick else 1024,
            max_edges=BASELINE_CAP if bs == 1 else 0,
        )
        sweep.append(metrics)
    base_eps = sweep[0]["edges_per_s"]
    best = sweep[-1]
    speedup_256 = next(
        (s["edges_per_s"] / max(base_eps, 1e-9) for s in sweep
         if s["block_size"] == 256), 0.0
    )

    # --- mixed insert/delete stream (deletion-aware maintenance, exactness)
    _, churn_run = _ingest_run(
        g, 256, seed=seed + 1, churn=0.25,
        compact_every=256 if quick else 1024,
    )

    # --- query-latency replay on a fully ingested service
    svc, stream_edges, _, k0 = build_service(
        g, seed=seed, batch=batch, compact_every=256 if quick else 1024
    )
    n_in = svc.ingest_edges(stream_edges, block_size=256)
    rng = np.random.default_rng(seed + 1)
    n_now = svc.graph.n_nodes
    for _ in range(6):  # untimed warmup (jit compiles incl. write-back shapes)
        svc.embed(rng.integers(0, n_now, size=batch))
    svc.stats = ServiceStats()

    t0 = time.perf_counter()
    for _ in range(requests // batch):
        svc.embed(rng.integers(0, n_now, size=batch))
    t_query = time.perf_counter() - t0
    p50, p99 = svc.latency_percentiles()
    st = svc.stats
    qps = st.queries / max(t_query, 1e-9)

    # --- row-sharded stack (placement-only: must stay oracle-exact)
    sharded = None
    if shards > 1:
        sharded = _sharded_run(
            g, seed=seed, shards=shards, requests=requests, batch=batch,
            compact_every=256 if quick else 1024,
        )

    os.makedirs("results", exist_ok=True)
    payload = {
        "n_nodes": int(n_now),
        "n_edges": int(svc.graph.n_edges),
        "k0": int(k0),
        "ingest_edges": int(n_in),
        "ingest_sweep": sweep,
        "ingest_edges_per_s": best["edges_per_s"],
        "ingest_speedup_block256_vs_per_edge": float(speedup_256),
        "churn": churn_run,
        "core_mismatches": int(
            max(s["mismatches"] for s in sweep + [churn_run])
        ),
        "compactions": int(svc.graph.compactions),
        "queries": int(st.queries),
        "batch": batch,
        "query_p50_s": p50,
        "query_p99_s": p99,
        "qps": float(qps),
        "cold_start_fraction": float(st.cold_fraction),
        "unresolved": int(st.unresolved),
        "sharding": sharded if sharded is not None else {"n_shards": 1},
    }
    if sharded is not None:
        payload["core_mismatches"] = int(
            max(payload["core_mismatches"], sharded["mismatches"])
        )
    with open("results/serve_latency.json", "w") as f:
        json.dump(payload, f, indent=2)

    lines = [
        csv_line(
            f"serve_ingest_block{s['block_size']}",
            1.0 / max(s["edges_per_s"], 1e-9),
            f"edges_per_s={s['edges_per_s']:.0f};mismatches={s['mismatches']};"
            f"repeels={s['repeels']}",
        )
        for s in sweep
    ]
    best_phases = ";".join(
        f"{k}={v['seconds'] * 1e3:.0f}ms[{v['impl']}]"
        for k, v in best.get("phases", {}).items()
    )
    lines += [
        csv_line(
            f"serve_repair_phases_block{best['block_size']}", 0.0,
            best_phases or "none",
        ),
        csv_line(
            "serve_ingest_churn",
            1.0 / max(churn_run["edges_per_s"], 1e-9),
            f"edges_per_s={churn_run['edges_per_s']:.0f};"
            f"removed={churn_run['edges_out']};"
            f"mismatches={churn_run['mismatches']}",
        ),
        csv_line("serve_ingest_speedup", 0.0,
                 f"block256_vs_per_edge={speedup_256:.1f}x"),
        csv_line("serve_query_p50", p50, f"qps={qps:.0f};batch={batch}"),
        csv_line("serve_query_p99", p99,
                 f"cold_frac={st.cold_fraction:.3f};unresolved={st.unresolved}"),
    ]
    if sharded is not None:
        balance = ",".join(str(c) for c in sharded["resident_per_shard"])
        lines += [
            csv_line(
                f"serve_shard{shards}_ingest",
                1.0 / max(sharded["ingest_edges_per_s"], 1e-9),
                f"edges_per_s={sharded['ingest_edges_per_s']:.0f};"
                f"mismatches={sharded['mismatches']}",
            ),
            csv_line(
                f"serve_shard{shards}_query_p50",
                sharded["query_p50_s"],
                f"qps={sharded['qps']:.0f};"
                f"imbalance={sharded['imbalance']:.2f}x",
            ),
            csv_line(
                f"serve_shard{shards}_balance", 0.0,
                f"resident={balance};"
                f"cross_shard_copies={sharded['cross_shard_row_copies']}",
            ),
        ]
    return lines


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full-size sweep (default: quick)")
    ap.add_argument("--shards", type=int, default=1,
                    help="also run the row-sharded stack over N devices "
                         "(power of two; on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    for line in run(quick=not args.full, seed=args.seed, shards=args.shards):
        print(line)


if __name__ == "__main__":
    main()
